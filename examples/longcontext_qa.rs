//! Long-context QA under KV-cache compression — the interactive version
//! of the Tab. 4 benchmark (the full table is `bench_table4_kvcache`).
//!
//! ```bash
//! cargo run --release --example longcontext_qa -- \
//!     --compressors compresskv,snapkv,uniform --budget 96 --trials 8
//! ```
//!
//! Evaluates the chosen compression policies on the 13-task suite with the
//! build-time-trained LM and prints per-task scores.

use wildcat::kvcache::{
    BalanceKv, CompressKvPolicy, KvCompressor, PyramidKv, SnapKv, StreamingLlm, UniformKv,
};
use wildcat::model::{generate::greedy_decode_with_query, ModelConfig, Transformer, WeightFile};
use wildcat::rng::Rng;
use wildcat::util::cli::Args;
use wildcat::util::table::Table;
use wildcat::workload::tasks::{score, task_suite};

fn by_name(name: &str) -> Box<dyn KvCompressor> {
    match name {
        "compresskv" => Box::new(CompressKvPolicy::default()),
        "streaming" => Box::new(StreamingLlm),
        "snapkv" => Box::new(SnapKv::default()),
        "pyramidkv" => Box::new(PyramidKv::default()),
        "balancekv" => Box::new(BalanceKv),
        "uniform" => Box::new(UniformKv),
        other => panic!("unknown compressor {other:?}"),
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let artifacts = args.get_or("artifacts", "artifacts");
    let budget = args.get_parse::<usize>("budget", 96);
    let context = args.get_parse::<usize>("context", 256);
    let trials = args.get_parse::<usize>("trials", 8);
    let seed = args.get_parse::<u64>("seed", 0);
    let names: Vec<String> = args.get_list(
        "compressors",
        &["compresskv".to_string(), "snapkv".to_string(), "uniform".to_string()],
    );

    let w = WeightFile::load(format!("{artifacts}/weights.bin"))
        .expect("weights.bin missing — run `make artifacts` first");
    let model = Transformer::from_weights(&w, ModelConfig::default())?;

    let suite = task_suite();
    let mut header: Vec<&str> = vec!["method"];
    let task_names: Vec<String> = suite.iter().map(|t| t.name.to_string()).collect();
    for tn in &task_names {
        header.push(tn);
    }
    header.push("average");
    let mut table = Table::new(
        &format!("long-context QA, budget={budget}, context={context}, {trials} trials/task"),
        &header,
    );

    for name in &names {
        let comp = by_name(name);
        let mut row = vec![comp.name().to_string()];
        let mut total = 0.0;
        for task in &suite {
            // fixed per-task seed: every method sees identical instances
            let mut task_rng = Rng::seed_from(seed ^ fxhash(task.name));
            let mut s = 0.0;
            for _ in 0..trials {
                let inst = task.kind.generate(&mut task_rng, context, model.cfg.vocab as u32);
                let mut decode_rng = Rng::seed_from(seed + 1);
                let out = greedy_decode_with_query(
                    &model,
                    &inst.context,
                    &inst.query,
                    inst.expected.len(),
                    budget,
                    comp.as_ref(),
                    &mut decode_rng,
                );
                s += score(&inst.expected, &out.tokens);
            }
            let pct = 100.0 * s / trials as f64;
            total += pct;
            row.push(format!("{pct:.1}"));
        }
        row.push(format!("{:.1}", total / suite.len() as f64));
        table.add_row(row);
    }
    table.print();
    Ok(())
}

/// Tiny deterministic string hash for per-task seeds.
fn fxhash(s: &str) -> u64 {
    s.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    })
}
