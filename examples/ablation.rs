//! Rank/bin ablation (interactive version of Fig. M.1's sweep; the full
//! series is `bench_figm1_ablation`).
//!
//! ```bash
//! cargo run --release --example ablation -- --n 8192 --ranks 64,128,256 --bins 2,16,64
//! ```
//!
//! For each (r, B) prints runtime and ‖O − Ô‖_max against exact attention,
//! showing the paper's time-accuracy trade-off (Sec. 2.5: larger B =
//! faster, slightly less accurate).

use std::time::Instant;
use wildcat::attention::{exact_attention, wildcat_attention, WildcatParams};
use wildcat::linalg::norms::max_abs_diff;
use wildcat::rng::Rng;
use wildcat::util::cli::Args;
use wildcat::util::table::Table;
use wildcat::workload::gaussian_qkv;

fn main() {
    let args = Args::from_env();
    let n = args.get_parse::<usize>("n", 8192);
    let d = args.get_parse::<usize>("d", 64);
    let seed = args.get_parse::<u64>("seed", 0);
    let ranks: Vec<usize> = args.get_list("ranks", &[64, 128, 256]);
    let bins: Vec<usize> = args.get_list("bins", &[2, 16, 64]);
    let seeds = args.get_parse::<u64>("seeds", 3);

    let mut rng = Rng::seed_from(seed);
    let w = gaussian_qkv(&mut rng, n, n, d, d);
    println!("computing exact attention baseline at n={n}...");
    let t0 = Instant::now();
    let exact = exact_attention(&w.q, &w.k, &w.v, w.beta);
    let t_exact = t0.elapsed().as_secs_f64();
    println!("exact: {:.1} ms", t_exact * 1e3);

    let mut table = Table::new(
        &format!("WildCat (r, B) ablation at n={n}, d={d} ({seeds} seeds)"),
        &["r", "B", "time", "speed-up", "err_max"],
    );
    for &r in &ranks {
        for &b in &bins {
            if b > r {
                continue;
            }
            let mut t_sum = 0.0;
            let mut err_sum = 0.0;
            for s in 0..seeds {
                let mut run_rng = Rng::seed_from(seed + 100 + s);
                let params = WildcatParams { rank: r, bins: b, beta: Some(w.beta as f64) };
                let t1 = Instant::now();
                let approx = wildcat_attention(&w.q, &w.k, &w.v, &params, &mut run_rng);
                t_sum += t1.elapsed().as_secs_f64();
                err_sum += max_abs_diff(&approx, &exact);
            }
            let t_avg = t_sum / seeds as f64;
            table.add_row(vec![
                r.to_string(),
                b.to_string(),
                format!("{:.1} ms", t_avg * 1e3),
                format!("{:.2}x", t_exact / t_avg),
                format!("{:.3e}", err_sum / seeds as f64),
            ]);
        }
    }
    table.print();
}
