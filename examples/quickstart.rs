//! Quickstart: WildCat attention as a drop-in replacement.
//!
//! ```bash
//! cargo run --release --example quickstart -- --n 4096 --rank 96 --bins 8
//! ```
//!
//! Generates a synthetic attention problem, runs exact attention and
//! WILDCAT (Alg. 4), and reports the speed-up and the paper's error
//! metric ‖O − Ô‖_max, plus the COMPRESSKV coreset that produced it.

use std::time::Instant;
use wildcat::attention::{
    compress_kv, exact_attention, wildcat_attention, CompressOpts, WildcatParams,
};
use wildcat::linalg::norms::{max_abs, max_abs_diff};
use wildcat::rng::Rng;
use wildcat::util::cli::Args;
use wildcat::workload::gaussian_qkv;

fn main() {
    let args = Args::from_env();
    let n = args.get_parse::<usize>("n", 4096);
    let d = args.get_parse::<usize>("d", 64);
    let rank = args.get_parse::<usize>("rank", 96);
    let bins = args.get_parse::<usize>("bins", 8);
    let seed = args.get_parse::<u64>("seed", 0);

    let mut rng = Rng::seed_from(seed);
    let w = gaussian_qkv(&mut rng, n, n, d, d);
    println!("workload: {} (beta = {:.4})", w.label, w.beta);

    // --- exact attention ------------------------------------------------
    let t0 = Instant::now();
    let exact = exact_attention(&w.q, &w.k, &w.v, w.beta);
    let t_exact = t0.elapsed();
    println!("exact attention:   {:>8.1} ms", t_exact.as_secs_f64() * 1e3);

    // --- WildCat ----------------------------------------------------------
    let params = WildcatParams { rank, bins, beta: Some(w.beta as f64) };
    let t1 = Instant::now();
    let approx = wildcat_attention(&w.q, &w.k, &w.v, &params, &mut rng);
    let t_wc = t1.elapsed();
    println!(
        "wildcat (r={rank}, B={bins}): {:>8.1} ms   speed-up {:.2}x",
        t_wc.as_secs_f64() * 1e3,
        t_exact.as_secs_f64() / t_wc.as_secs_f64()
    );
    let err = max_abs_diff(&approx, &exact);
    println!(
        "‖O − Ô‖_max = {err:.4e}   (‖V‖_max = {:.3}, relative {:.2e})",
        max_abs(&w.v),
        err / max_abs(&w.v)
    );

    // --- peek inside the coreset -----------------------------------------
    let opts = CompressOpts {
        rank,
        bins,
        beta: w.beta as f64,
        r_q: w.q.max_row_norm(),
    };
    let c = compress_kv(&w.k, &w.v, &opts, &mut rng);
    println!(
        "coreset: {} weighted keys summarise {} tokens ({:.1}x memory reduction)",
        c.rank(),
        c.source_len,
        (c.source_len * (d + d)) as f64 / c.footprint_floats() as f64
    );
    let wmin = c.weights.iter().cloned().fold(f64::INFINITY, f64::min);
    let wmax = c.weights.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    println!("Nystrom weight range: [{wmin:.3}, {wmax:.3}]");
}
