//! End-to-end serving demo — the full three-layer system on a real small
//! workload.
//!
//! ```bash
//! make artifacts                         # train LM + AOT-lower once
//! cargo run --release --example serving_demo            # native backend
//! cargo run --release --example serving_demo -- --pjrt  # PJRT artifacts
//! ```
//!
//! Loads the build-time-trained LM, spins up the coordinator (router →
//! admission queue → continuous batcher → prefill/decode scheduler with
//! COMPRESSKV cache compression), replays a Poisson arrival trace of
//! long-context retrieval requests, and reports latency/throughput plus
//! answer quality — proving L1 (Pallas-kernel HLO), L2 (JAX model) and
//! L3 (rust coordinator) compose. Results recorded in EXPERIMENTS.md.

use std::sync::Arc;
use std::time::{Duration, Instant};
use wildcat::coordinator::{Server, ServerConfig};
use wildcat::kvcache::CompressKvPolicy;
use wildcat::model::{ModelConfig, Transformer, WeightFile};
use wildcat::rng::Rng;
use wildcat::util::cli::Args;
use wildcat::workload::tasks::{score, TaskKind};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let artifacts = args.get_or("artifacts", "artifacts");
    let use_pjrt = args.flag("pjrt");
    let n_requests = args.get_parse::<usize>("requests", 24);
    let rate = args.get_parse::<f64>("rate", 6.0);
    let budget = args.get_parse::<usize>("budget", 96);
    let context = args.get_parse::<usize>("context", 256);
    let seed = args.get_parse::<u64>("seed", 0);

    let mut cfg = ServerConfig::default();
    cfg.scheduler.cache_budget = budget;
    cfg.seed = seed;

    println!(
        "== WildCat serving demo ==\nbackend: {}   budget: {budget}   context: {context}",
        if use_pjrt { "PJRT (AOT artifacts)" } else { "native" }
    );

    let handle = if use_pjrt {
        let dir = artifacts.clone();
        Server::spawn(cfg, Arc::new(CompressKvPolicy::default()), move || {
            let b = wildcat::runtime::PjrtBackend::open(&dir).expect("run `make artifacts` first");
            println!("PJRT platform: {}", b.platform());
            b
        })
    } else {
        let dir = artifacts.clone();
        Server::spawn(cfg, Arc::new(CompressKvPolicy::default()), move || {
            let w = WeightFile::load(format!("{dir}/weights.bin"))
                .expect("weights.bin missing — run `make artifacts` first");
            Transformer::from_weights(&w, ModelConfig::default()).expect("model load")
        })
    };

    // Long-context retrieval workload: every request hides a passkey pair
    // in a `context`-token prompt; the served answer is verifiable.
    let mut rng = Rng::seed_from(seed);
    let mut expected = Vec::new();
    let mut rxs = Vec::new();
    let start = Instant::now();
    let gap = Duration::from_secs_f64(1.0 / rate);
    for i in 0..n_requests {
        let kind = if i % 2 == 0 { TaskKind::Passkey } else { TaskKind::Induction { period: 16 } };
        let inst = kind.generate(&mut rng, context, 64);
        let mut prompt = inst.context.clone();
        prompt.extend_from_slice(&inst.query);
        match handle.submit(prompt, inst.expected.len()) {
            Ok((id, rx)) => {
                expected.push((id, inst.expected));
                rxs.push(rx);
            }
            Err(e) => println!("request {i} rejected: {e:?}"),
        }
        std::thread::sleep(gap.min(Duration::from_millis(50)));
    }

    let mut total_score = 0.0;
    let mut n_scored = 0usize;
    for ((id, want), rx) in expected.into_iter().zip(rxs) {
        let resp = rx.recv_timeout(Duration::from_secs(600))?;
        assert_eq!(resp.id, id);
        total_score += score(&want, &resp.tokens);
        n_scored += 1;
    }
    let wall = start.elapsed();

    println!("\n-- serving metrics --------------------------------------");
    println!("{}", handle.metrics().report());
    println!("wall time: {:.2}s for {n_scored} requests", wall.as_secs_f64());
    println!(
        "answer quality under {}x cache compression: {:.1}%",
        (context as f64 / budget as f64 * 10.0).round() / 10.0,
        100.0 * total_score / n_scored.max(1) as f64
    );
    handle.shutdown();
    Ok(())
}
