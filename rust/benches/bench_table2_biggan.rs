//! Tab. 2 reproduction: BigGAN image-generation attention benchmark.
//!
//! Paper setting: BigGAN-512's single attention layer with
//! Q ∈ R^{4096×64}, K ∈ R^{1024×64}, V ∈ R^{1024×256}; WildCat with
//! r = 96, B = 8; five baselines; speed-up measured over 10 batches and
//! quality via IS/FID degradation of 5000 generations.
//!
//! Substitution (DESIGN.md §3): the pretrained generator and ImageNet are
//! unavailable offline, so we benchmark the *identical shapes* on
//! activation-statistics workloads and report the attention-output error
//! that drives IS/FID (err_max_rel ≈ "IS degradation" direction;
//! rel_frob ≈ "FID degradation" direction). Expected shape vs the paper:
//! WildCat fastest with the smallest degradation; Reformer slowest with
//! the largest.
//!
//! `WILDCAT_BENCH_FAST=1` shrinks iterations for smoke runs.

use wildcat::bench::harness::{speedup, BenchOpts};
use wildcat::bench::paperbench::{roster, run_roster};
use wildcat::rng::Rng;
use wildcat::util::cli::Args;
use wildcat::util::table::{fmt_pct, fmt_speedup, Table};
use wildcat::workload::gaussian::{activation_qkv, biggan_shapes};

fn main() {
    let args = Args::from_env();
    let seed = args.get_parse::<u64>("seed", 0);
    let seeds = args.get_parse::<u64>("quality-seeds", 3);
    let (m, n, d, dv) = biggan_shapes();
    let mut rng = Rng::seed_from(seed);
    let w = activation_qkv(&mut rng, m, n, d, dv, 4, 2.0);
    println!("[table2] BigGAN shapes: Q {m}x{d}, K {n}x{d}, V {n}x{dv} (beta={:.4})", w.beta);

    let opts = BenchOpts::from_env();
    // paper setting: WildCat r=96, B=8
    let methods = roster(96, 8, n);
    let (exact_t, results) = run_roster(&w, methods, opts, seeds, seed);

    let mut table = Table::new(
        "Table 2 — BigGAN attention: speed-up and quality degradation",
        &["Attention Algorithm", "Speed-up over Exact", "MeanErr/Vmax (IS-proxy)", "RelFrob (FID-proxy)", "ErrMax/Vmax"],
    );
    table.add_row(vec![
        "Exact".into(),
        "1.00x".into(),
        fmt_pct(0.0),
        fmt_pct(0.0),
        fmt_pct(0.0),
    ]);
    for r in &results {
        table.add_row(vec![
            r.name.into(),
            fmt_speedup(speedup(&exact_t, &r.timing)),
            fmt_pct(100.0 * r.quality.err_mean_rel),
            fmt_pct(100.0 * r.quality.rel_frob),
            fmt_pct(100.0 * r.quality.err_max_rel),
        ]);
    }
    table.print();
    println!("\n(markdown for EXPERIMENTS.md)\n{}", table.render_markdown());

    // sanity: the paper's headline — WildCat is the fastest approximation
    // with the smallest degradation — should reproduce in *shape*.
    let wc = results.iter().find(|r| r.name == "WILDCAT").unwrap();
    println!(
        "[table2] WildCat: {:.2}x speed-up, {:.2}% rel-frob degradation",
        speedup(&exact_t, &wc.timing),
        100.0 * wc.quality.rel_frob
    );
}
