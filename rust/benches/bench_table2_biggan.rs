//! Tab. 2 reproduction: BigGAN image-generation attention benchmark.
//!
//! Paper setting: BigGAN-512's single attention layer with
//! Q ∈ R^{4096×64}, K ∈ R^{1024×64}, V ∈ R^{1024×256}; WildCat with
//! r = 96, B = 8; five baselines; speed-up measured over 10 batches and
//! quality via IS/FID degradation of 5000 generations.
//!
//! Substitution (DESIGN.md §3): the pretrained generator and ImageNet are
//! unavailable offline, so we benchmark the *identical shapes* on
//! activation-statistics workloads and report the attention-output error
//! that drives IS/FID (err_max_rel ≈ "IS degradation" direction;
//! rel_frob ≈ "FID degradation" direction). Expected shape vs the paper:
//! WildCat fastest with the smallest degradation; Reformer slowest with
//! the largest.
//!
//! All logic lives in `wildcat::bench::runners::run_table2`, shared with
//! `wildcat bench --smoke`. `WILDCAT_BENCH_FAST=1` shrinks iterations.

use wildcat::bench::runners::{maybe_write_json, run_table2, RunCfg};
use wildcat::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let cfg = RunCfg::from_args(&args);
    let report = run_table2(&cfg)?;
    maybe_write_json(&report, &args)
}
