//! Micro-benchmarks of the hot-path primitives — the §Perf profiling
//! companion (EXPERIMENTS.md §Perf records before/after numbers from this
//! bench during the optimisation pass).
//!
//! Covered: GEMM (matmul / matmul_transb), RPNYS (unbinned vs binned),
//! kernel-matrix evaluation, WTDATTN, exact vs flash attention, the
//! native model decode step, and compressor throughput.
//!
//! All logic lives in `wildcat::bench::runners::run_micro`, shared with
//! `wildcat bench --smoke`.

use wildcat::bench::runners::{maybe_write_json, run_micro, RunCfg};
use wildcat::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let cfg = RunCfg::from_args(&args);
    let report = run_micro(&cfg)?;
    maybe_write_json(&report, &args)
}
