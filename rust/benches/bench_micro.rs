//! Micro-benchmarks of the hot-path primitives — the §Perf profiling
//! companion (EXPERIMENTS.md §Perf records before/after numbers from this
//! bench during the optimisation pass).
//!
//! Covered: GEMM (matmul / matmul_transb), RPNYS (unbinned vs binned),
//! kernel-matrix evaluation, WTDATTN, exact vs flash attention, the
//! native model decode step, and compressor throughput.

use std::sync::Arc;
use wildcat::attention::{
    compress_kv, exact_attention, flash_attention, wtd_attention, ClipRange, CompressOpts,
};
use wildcat::bench::harness::{bench, BenchOpts};
use wildcat::coordinator::ServingMetrics;
use wildcat::kvcache::{CompressKvPolicy, CompressionCtx, KvCompressor, SnapKv};
use wildcat::linalg::{gemm, Matrix};
use wildcat::model::{ModelConfig, Transformer};
use wildcat::rng::Rng;
use wildcat::rpnys::rpnys;
use wildcat::util::table::Table;

fn main() {
    let opts = BenchOpts::from_env();
    let mut rng = Rng::seed_from(0);
    let mut table = Table::new("micro-benchmarks", &["op", "median", "notes"]);
    let mut add = |name: &str, secs: f64, notes: String| {
        table.add_row(vec![name.into(), format!("{:.3} ms", secs * 1e3), notes]);
    };

    // GEMM
    let a = Matrix::randn(&mut rng, 1024, 64);
    let b = Matrix::randn(&mut rng, 64, 1024);
    let bt = Matrix::randn(&mut rng, 1024, 64);
    let r = bench("matmul 1024x64x1024", opts, || gemm::matmul(&a, &b));
    let flops = 2.0 * 1024.0 * 64.0 * 1024.0;
    add("matmul 1024x64x1024", r.median(), format!("{:.2} GFLOP/s", flops / r.median() / 1e9));
    let r = bench("matmul_transb", opts, || gemm::matmul_transb(&a, &bt));
    add("matmul_transb 1024x64x1024", r.median(), format!("{:.2} GFLOP/s", flops / r.median() / 1e9));

    // attention kernels
    let n = 4096;
    let q = Matrix::randn(&mut rng, n, 64);
    let k = Matrix::randn(&mut rng, n, 64);
    let v = Matrix::randn(&mut rng, n, 64);
    let r = bench("exact_attention 4096", opts, || exact_attention(&q, &k, &v, 0.125));
    add("exact_attention n=4096", r.median(), String::new());
    let r = bench("flash_attention 4096", opts, || flash_attention(&q, &k, &v, 0.125));
    add("flash_attention n=4096", r.median(), String::new());

    // WTDATTN over a 96-point coreset
    let ks = k.slice_rows(0, 96);
    let vs = v.slice_rows(0, 96);
    let wts = vec![1.0f64; 96];
    let clip = ClipRange::from_values(&vs);
    let r = bench("wtd_attention 4096x96", opts, || {
        wtd_attention(&q, &ks, &vs, &wts, &clip, 0.125)
    });
    add("wtd_attention m=4096 r=96", r.median(), String::new());

    // RPNYS: unbinned vs binned (Sec. 2.5 speed-up)
    let r1 = bench("rpnys r=96 B=1", opts, || {
        let mut r = Rng::seed_from(1);
        rpnys(&k, 0.125, 96, &mut r)
    });
    add("rpnys n=4096 r=96 (B=1)", r1.median(), String::new());
    let copts = CompressOpts { rank: 96, bins: 8, beta: 0.125, r_q: q.max_row_norm() };
    let r8 = bench("compress_kv B=8", opts, || {
        let mut r = Rng::seed_from(1);
        compress_kv(&k, &v, &copts, &mut r)
    });
    add(
        "compress_kv n=4096 r=96 B=8",
        r8.median(),
        format!("{:.2}x vs B=1", r1.median() / r8.median()),
    );

    // compressors at serving shapes
    let keys = Matrix::randn(&mut rng, 1024, 32);
    let vals = Matrix::randn(&mut rng, 1024, 32);
    for comp in [
        Box::new(SnapKv::default()) as Box<dyn KvCompressor>,
        Box::new(CompressKvPolicy::default()),
    ] {
        let r = bench(comp.name(), opts, || {
            let mut rr = Rng::seed_from(2);
            let ctx = CompressionCtx {
                keys: &keys,
                values: &vals,
                budget: 256,
                beta: 0.176,
                layer: 0,
                n_layers: 2,
                obs_queries: None,
            };
            comp.compress(&ctx, &mut rr)
        });
        add(&format!("compress[{}] 1024->256", comp.name()), r.median(), String::new());
    }

    // native model steps
    let mcfg = ModelConfig::default();
    let model = Transformer::random(mcfg, &mut rng);
    let toks: Vec<u32> = (0..256).map(|i| (i % 60 + 2) as u32).collect();
    let r = bench("prefill 256", opts, || model.prefill(&toks));
    add("model prefill n=256", r.median(), String::new());
    let out = model.prefill(&toks);
    let caches: Vec<(Matrix, Matrix, Vec<f64>)> = out
        .k_cache
        .iter()
        .zip(&out.v_cache)
        .map(|(k, v)| (k.clone(), v.clone(), vec![1.0f64; k.rows()]))
        .collect();
    let r = bench("decode", opts, || {
        let refs: Vec<(&Matrix, &Matrix, &[f64])> =
            caches.iter().map(|(k, v, w)| (k, v, w.as_slice())).collect();
        model.decode(5, 256, &refs)
    });
    add("model decode @ 256 ctx", r.median(), String::new());

    // streaming/causal extension (§5 future work): per-token attend cost
    // over a compressed stream vs exact causal attention
    let n_s = 512usize;
    let ks = Matrix::randn(&mut rng, n_s, 32);
    let vs2 = Matrix::randn(&mut rng, n_s, 32);
    let qs = Matrix::randn(&mut rng, n_s, 32);
    let r = bench("causal wildcat", opts, || {
        wildcat::attention::causal_wildcat_attention(&qs, &ks, &vs2, 64, 16, 1, 0.176, 3)
    });
    add("causal wildcat n=512 (c=64,r=16)", r.median(), String::new());
    let r = bench("causal exact", opts, || {
        let mut out = Matrix::zeros(n_s, 32);
        for i in 0..n_s {
            let qi = Matrix::from_vec(qs.row(i).to_vec(), 1, 32);
            let o = exact_attention(&qi, &ks.slice_rows(0, i + 1), &vs2.slice_rows(0, i + 1), 0.176);
            out.row_mut(i).copy_from_slice(o.row(0));
        }
        out
    });
    add("causal exact n=512", r.median(), String::new());

    // metrics overhead (coordinator lock contention sanity)
    let metrics = Arc::new(ServingMetrics::new());
    let r = bench("metrics record", opts, || {
        for _ in 0..1000 {
            metrics.on_submit();
        }
    });
    add("metrics 1000 submits", r.median(), String::new());

    table.print();
}
