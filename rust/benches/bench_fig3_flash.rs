//! Fig. 3 reproduction: WildCat vs FlashAttention-2 — approximation error
//! and speed-up as the sequence length grows.
//!
//! Paper setting: r = 64, B = 16, d = 64, i.i.d. standard Gaussian QKV,
//! n = 2^13 … 2^18; findings: speed-up grows from 1.1× to 68× and
//! ‖O − Ô‖_max *decreases* with n.
//!
//! Substitution (DESIGN.md §3): FA2 is GPU-only; the exact baseline here
//! is our multi-threaded blocked online-softmax kernel
//! (`attention::flash`), the strongest exact attention on this CPU
//! substrate. The default sweep caps at 2^14 (exact attention is O(n²d)
//! on CPU); pass `--max-exp 18` to run the full paper range.
//!
//! All logic lives in `wildcat::bench::runners::run_fig3`, shared with
//! `wildcat bench --smoke`. Pass `--json DIR` to also write
//! `BENCH_fig3.json`; `--smoke` switches to the seconds-scale preset.

use wildcat::bench::runners::{maybe_write_json, run_fig3, RunCfg};
use wildcat::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let cfg = RunCfg::from_args(&args);
    let report = run_fig3(&cfg)?;
    maybe_write_json(&report, &args)
}
