//! Fig. 3 reproduction: WildCat vs FlashAttention-2 — approximation error
//! and speed-up as the sequence length grows.
//!
//! Paper setting: r = 64, B = 16, d = 64, i.i.d. standard Gaussian QKV,
//! n = 2^13 … 2^18; findings: speed-up grows from 1.1× to 68× and
//! ‖O − Ô‖_max *decreases* with n.
//!
//! Substitution (DESIGN.md §3): FA2 is GPU-only; the exact baseline here
//! is our multi-threaded blocked online-softmax kernel
//! (`attention::flash`), the strongest exact attention on this CPU
//! substrate. The default sweep caps at 2^14 (exact attention is O(n²d)
//! on CPU); pass `--max-exp 18` to run the full paper range.
//!
//! Also prints the Tab. 1-oriented error-decay panel: measured error vs n
//! for WildCat at fixed (r, B) — the empirical counterpart of the
//! super-polynomial decay guarantee.

use wildcat::attention::{flash_attention, wildcat_attention, WildcatParams};
use wildcat::bench::harness::{bench, BenchOpts};
use wildcat::linalg::norms::max_abs_diff;
use wildcat::rng::Rng;
use wildcat::util::cli::Args;
use wildcat::util::table::Table;
use wildcat::workload::gaussian_qkv;

fn main() {
    let args = Args::from_env();
    let seed = args.get_parse::<u64>("seed", 0);
    let fast = std::env::var("WILDCAT_BENCH_FAST").as_deref() == Ok("1");
    let min_exp = args.get_parse::<u32>("min-exp", 10);
    let max_exp = args.get_parse::<u32>("max-exp", if fast { 12 } else { 14 });
    let rank = args.get_parse::<usize>("rank", 64);
    let bins = args.get_parse::<usize>("bins", 16);
    let d = args.get_parse::<usize>("d", 64);
    let err_seeds = args.get_parse::<u64>("err-seeds", 3);

    let opts = BenchOpts::from_env();
    let mut table = Table::new(
        &format!("Fig. 3 — WildCat (r={rank}, B={bins}) vs exact blocked attention, d={d}"),
        &["n", "exact (ms)", "wildcat (ms)", "speed-up", "err_max"],
    );

    let mut errs = Vec::new();
    let mut speedups = Vec::new();
    for exp in min_exp..=max_exp {
        let n = 1usize << exp;
        let mut rng = Rng::seed_from(seed + exp as u64);
        let w = gaussian_qkv(&mut rng, n, n, d, d);
        let t_exact = bench(&format!("exact n={n}"), opts, || {
            flash_attention(&w.q, &w.k, &w.v, w.beta)
        });
        let exact_out = flash_attention(&w.q, &w.k, &w.v, w.beta);
        let params = WildcatParams { rank, bins, beta: Some(w.beta as f64) };
        let t_wc = bench(&format!("wildcat n={n}"), opts, || {
            let mut r = Rng::seed_from(seed);
            wildcat_attention(&w.q, &w.k, &w.v, &params, &mut r)
        });
        let mut err = 0.0;
        for s in 0..err_seeds {
            let mut r = Rng::seed_from(seed + 10 + s);
            let approx = wildcat_attention(&w.q, &w.k, &w.v, &params, &mut r);
            err += max_abs_diff(&approx, &exact_out);
        }
        let err = err / err_seeds as f64;
        let sp = t_exact.median() / t_wc.median();
        errs.push(err);
        speedups.push(sp);
        table.add_row(vec![
            format!("2^{exp}"),
            format!("{:.1}", t_exact.median() * 1e3),
            format!("{:.1}", t_wc.median() * 1e3),
            format!("{sp:.2}x"),
            format!("{err:.3e}"),
        ]);
    }
    table.print();
    println!("\n(markdown)\n{}", table.render_markdown());

    // paper-shape checks: speed-up increasing, error non-increasing in n
    let sp_up = speedups.windows(2).all(|w| w[1] >= w[0] * 0.85);
    let err_down = errs.first().zip(errs.last()).map(|(a, b)| *b <= a * 1.1).unwrap_or(true);
    println!(
        "[fig3] speed-up increasing with n: {}   error decreasing with n: {}",
        if sp_up { "YES" } else { "NO" },
        if err_down { "YES" } else { "NO" }
    );
}
