//! Tab. 4 reproduction: KV-cache compression quality on the 13-task
//! long-context suite (LongBench-E analogue; substitution in DESIGN.md §3).
//!
//! Protocol per Sec. 4.3 / Han et al. (2025): compression levels 75%,
//! 87.5% and 93.75% of the context; first/last tokens protected
//! (adaptively scaled for small budgets); CompressKV uses B = r/12.
//! Methods: Exact (no compression), StreamingLLM, PyramidKV, BalanceKV,
//! Uniform, SnapKV, CompressKV.
//!
//! Also measures the §M.3 prefill-compression overhead (pass `--overhead`).
//!
//! Requires `make artifacts` (the build-time-trained LM).

use std::time::Instant;
use wildcat::kvcache::{
    BalanceKv, CompressKvPolicy, CompressionCtx, KvCompressor, PyramidKv, SnapKv, StreamingLlm,
    UniformKv,
};
use wildcat::model::{generate::greedy_decode_with_query, ModelConfig, Transformer, WeightFile};
use wildcat::rng::Rng;
use wildcat::util::cli::Args;
use wildcat::util::table::Table;
use wildcat::workload::tasks::{score, task_suite};

fn methods() -> Vec<Box<dyn KvCompressor>> {
    vec![
        Box::new(StreamingLlm),
        Box::new(PyramidKv::default()),
        Box::new(BalanceKv),
        Box::new(UniformKv),
        Box::new(SnapKv::default()),
        Box::new(CompressKvPolicy::default()),
    ]
}

fn fxhash(s: &str) -> u64 {
    s.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    })
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let artifacts = args.get_or("artifacts", "artifacts");
    let context = args.get_parse::<usize>("context", 256);
    let fast = std::env::var("WILDCAT_BENCH_FAST").as_deref() == Ok("1");
    let trials = args.get_parse::<usize>("trials", if fast { 3 } else { 10 });
    let seed = args.get_parse::<u64>("seed", 0);

    let w = WeightFile::load(format!("{artifacts}/weights.bin"))
        .expect("weights.bin missing — run `make artifacts` first");
    let model = Transformer::from_weights(&w, ModelConfig::default())?;
    let suite = task_suite();

    if args.flag("overhead") {
        return overhead_measurement(&model, context, seed);
    }

    // compression levels of Tab. 4 (budget = context * (1 - level))
    for (level_name, keep_frac) in
        [("75.0%", 0.25f64), ("87.5%", 0.125), ("93.75%", 0.0625)]
    {
        let budget = ((context as f64) * keep_frac).round() as usize;
        let mut header: Vec<String> = vec!["Method".into()];
        header.extend(suite.iter().map(|t| t.name.to_string()));
        header.push("average".into());
        let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let mut table = Table::new(
            &format!("Table 4 — {level_name} compression (context {context}, budget {budget}, {trials} trials)"),
            &header_refs,
        );

        // Exact row: no compression
        let mut run_method = |name: &str, comp: Option<&dyn KvCompressor>| {
            let mut row = vec![name.to_string()];
            let mut total = 0.0;
            for task in &suite {
                let mut task_rng = Rng::seed_from(seed ^ fxhash(task.name));
                let mut s = 0.0;
                for _ in 0..trials {
                    let inst = task.kind.generate(&mut task_rng, context, model.cfg.vocab as u32);
                    let mut decode_rng = Rng::seed_from(seed + 1);
                    let out = match comp {
                        None => greedy_decode_with_query(
                            &model,
                            &inst.context,
                            &inst.query,
                            inst.expected.len(),
                            usize::MAX,
                            &UniformKv,
                            &mut decode_rng,
                        ),
                        Some(c) => greedy_decode_with_query(
                            &model,
                            &inst.context,
                            &inst.query,
                            inst.expected.len(),
                            budget,
                            c,
                            &mut decode_rng,
                        ),
                    };
                    s += score(&inst.expected, &out.tokens);
                }
                let pct = 100.0 * s / trials as f64;
                total += pct;
                row.push(format!("{pct:.1}"));
            }
            row.push(format!("{:.1}", total / suite.len() as f64));
            row
        };

        table.add_row(run_method("Exact", None));
        for comp in methods() {
            table.add_row(run_method(comp.name(), Some(comp.as_ref())));
        }
        table.print();
        println!("\n(markdown)\n{}", table.render_markdown());
    }
    Ok(())
}

/// §M.3: prefill + compression wall time, CompressKV vs SnapKV.
fn overhead_measurement(model: &Transformer, context: usize, seed: u64) -> anyhow::Result<()> {
    let mut rng = Rng::seed_from(seed);
    let inst =
        wildcat::workload::tasks::TaskKind::Passkey.generate(&mut rng, context, model.cfg.vocab as u32);
    let budget = context / 4;
    let mut table = Table::new(
        &format!("§M.3 prefill overhead at {context} tokens, 75% compression"),
        &["Method", "prefill+compress", "overhead vs SnapKV"],
    );
    let mut t_snap = 0.0;
    for comp in [
        Box::new(SnapKv::default()) as Box<dyn KvCompressor>,
        Box::new(CompressKvPolicy::default()),
    ] {
        let t0 = Instant::now();
        for _ in 0..5 {
            let out = model.prefill(&inst.context);
            for lh in 0..model.cfg.n_layers * model.cfg.n_heads {
                let ctx = CompressionCtx {
                    keys: &out.k_cache[lh],
                    values: &out.v_cache[lh],
                    budget,
                    beta: model.cfg.beta() as f64,
                    layer: lh / model.cfg.n_heads,
                    n_layers: model.cfg.n_layers,
                    obs_queries: None,
                };
                let _ = comp.compress(&ctx, &mut rng);
            }
        }
        let dt = t0.elapsed().as_secs_f64() / 5.0;
        if comp.name() == "SnapKV" {
            t_snap = dt;
        }
        table.add_row(vec![
            comp.name().into(),
            format!("{:.2} ms", dt * 1e3),
            if t_snap > 0.0 {
                format!("{:+.1}%", 100.0 * (dt - t_snap) / t_snap)
            } else {
                "-".into()
            },
        ]);
    }
    table.print();
    Ok(())
}
