//! Tab. 4 reproduction: KV-cache compression quality on the 13-task
//! long-context suite (LongBench-E analogue; substitution in DESIGN.md §3).
//!
//! Protocol per Sec. 4.3 / Han et al. (2025): compression levels 75%,
//! 87.5% and 93.75% of the context; first/last tokens protected
//! (adaptively scaled for small budgets); CompressKV uses B = r/12.
//! Methods: Exact (no compression), StreamingLLM, PyramidKV, BalanceKV,
//! Uniform, SnapKV, CompressKV.
//!
//! Also measures the §M.3 prefill-compression overhead (pass `--overhead`).
//!
//! Requires `make artifacts` (the build-time-trained LM) in full mode;
//! `--smoke` falls back to a seeded random model of the same shape.
//! All logic lives in `wildcat::bench::runners::run_table4`, shared with
//! `wildcat bench --smoke`.

use wildcat::bench::runners::{maybe_write_json, run_table4, RunCfg};
use wildcat::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let cfg = RunCfg::from_args(&args);
    let report = run_table4(&cfg)?;
    maybe_write_json(&report, &args)
}
