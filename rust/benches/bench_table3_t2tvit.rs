//! Tab. 3 reproduction: T2T-ViT image-classification attention benchmark.
//!
//! Paper setting: the two tokens-to-token layers, (n1, d1) = (3136, 64)
//! and (n2, d2) = (784, 64); WildCat with (r1, B1) = (224, 224) and
//! (r2, B2) = (196, 196); per-layer speed-ups over 50 batches and top-1
//! accuracy over ImageNet val.
//!
//! Substitution (DESIGN.md §3): identical layer shapes on
//! activation-statistics inputs; "Top-1 agreement" = fraction of rows
//! whose argmax under a fixed random readout head matches exact attention
//! (the monotone readout the paper's top-1 accuracy responds to).
//!
//! All logic lives in `wildcat::bench::runners::run_table3`, shared with
//! `wildcat bench --smoke`.

use wildcat::bench::runners::{maybe_write_json, run_table3, RunCfg};
use wildcat::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let cfg = RunCfg::from_args(&args);
    let report = run_table3(&cfg)?;
    maybe_write_json(&report, &args)
}
