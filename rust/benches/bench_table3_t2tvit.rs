//! Tab. 3 reproduction: T2T-ViT image-classification attention benchmark.
//!
//! Paper setting: the two tokens-to-token layers, (n1, d1) = (3136, 64)
//! and (n2, d2) = (784, 64); WildCat with (r1, B1) = (224, 224) and
//! (r2, B2) = (196, 196); per-layer speed-ups over 50 batches and top-1
//! accuracy over ImageNet val.
//!
//! Substitution (DESIGN.md §3): identical layer shapes on
//! activation-statistics inputs; "Top-1 agreement" = fraction of rows
//! whose argmax under a fixed random readout head matches exact attention
//! (the monotone readout the paper's top-1 accuracy responds to).

use wildcat::bench::harness::{speedup, BenchOpts};
use wildcat::bench::paperbench::{roster, run_roster, MethodResult};
use wildcat::bench::harness::BenchResult;
use wildcat::rng::Rng;
use wildcat::util::cli::Args;
use wildcat::util::table::{fmt_pct, fmt_speedup, Table};
use wildcat::workload::gaussian::activation_qkv;

fn main() {
    let args = Args::from_env();
    let seed = args.get_parse::<u64>("seed", 0);
    let seeds = args.get_parse::<u64>("quality-seeds", 3);
    let opts = BenchOpts::from_env();

    // (n, d, r, B) per layer, from Sec. 4.2
    let layers = [(3136usize, 64usize, 224usize, 224usize), (784, 64, 196, 196)];
    let mut per_layer: Vec<(BenchResult, Vec<MethodResult>)> = Vec::new();
    for (li, &(n, d, r, b)) in layers.iter().enumerate() {
        let mut rng = Rng::seed_from(seed + li as u64);
        let w = activation_qkv(&mut rng, n, n, d, d, 4, 2.0);
        println!("[table3] layer {} shapes: n={n}, d={d}, r={r}, B={b}", li + 1);
        per_layer.push(run_roster(&w, roster(r, b, n), opts, seeds, seed));
    }

    let mut table = Table::new(
        "Table 3 — T2T-ViT attention: top-1 agreement and per-layer speed-ups",
        &["Attention Algorithm", "Top-1 Agreement (%)", "Layer 1 Speed-up", "Layer 2 Speed-up"],
    );
    table.add_row(vec!["Exact".into(), "100.00%".into(), "1.00x".into(), "1.00x".into()]);
    let (e1, r1) = &per_layer[0];
    let (e2, r2) = &per_layer[1];
    for (m1, m2) in r1.iter().zip(r2.iter()) {
        assert_eq!(m1.name, m2.name);
        // accuracy dominated by the (larger) layer 1; report its agreement
        table.add_row(vec![
            m1.name.into(),
            fmt_pct(100.0 * m1.quality.top1_agree),
            fmt_speedup(speedup(e1, &m1.timing)),
            fmt_speedup(speedup(e2, &m2.timing)),
        ]);
    }
    table.print();
    println!("\n(markdown for EXPERIMENTS.md)\n{}", table.render_markdown());
}
