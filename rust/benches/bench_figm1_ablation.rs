//! Fig. M.1 reproduction: time-accuracy trade-off curves for WildCat with
//! varying rank r ∈ {64, 128, 256, 512} and bin count B ∈ {2, 16, 64}
//! (Sec. M.4). One series per B; each point is (median runtime, mean
//! ‖O − Ô‖_max over replicates) at a given r.
//!
//! Expected shape: larger r → slower + more accurate; larger B → faster
//! at slightly higher error (the Sec. 2.5 trade-off).

use wildcat::attention::{flash_attention, wildcat_attention, WildcatParams};
use wildcat::bench::harness::{bench, BenchOpts};
use wildcat::linalg::norms::max_abs_diff;
use wildcat::rng::Rng;
use wildcat::util::cli::Args;
use wildcat::util::table::Table;
use wildcat::workload::gaussian_qkv;

fn main() {
    let args = Args::from_env();
    let seed = args.get_parse::<u64>("seed", 0);
    let fast = std::env::var("WILDCAT_BENCH_FAST").as_deref() == Ok("1");
    let n = args.get_parse::<usize>("n", if fast { 4096 } else { 8192 });
    let d = args.get_parse::<usize>("d", 64);
    let ranks: Vec<usize> = args.get_list("ranks", &[64, 128, 256, 512]);
    let bins: Vec<usize> = args.get_list("bins", &[2, 16, 64]);
    let err_seeds = args.get_parse::<u64>("err-seeds", if fast { 2 } else { 5 });

    let mut rng = Rng::seed_from(seed);
    let w = gaussian_qkv(&mut rng, n, n, d, d);
    let exact = flash_attention(&w.q, &w.k, &w.v, w.beta);
    let opts = BenchOpts::from_env();
    let t_exact = bench("exact", opts, || flash_attention(&w.q, &w.k, &w.v, w.beta));
    println!(
        "[figM1] n={n}, d={d}; exact attention median {:.1} ms",
        t_exact.median() * 1e3
    );

    let mut table = Table::new(
        "Fig. M.1 — WildCat time-accuracy trade-off",
        &["B", "r", "time (ms)", "speed-up", "err_max"],
    );
    for &b in &bins {
        let mut last_err = f64::INFINITY;
        for &r in &ranks {
            if b > r {
                continue;
            }
            let params = WildcatParams { rank: r, bins: b, beta: Some(w.beta as f64) };
            let t = bench(&format!("r={r} B={b}"), opts, || {
                let mut run_rng = Rng::seed_from(seed);
                wildcat_attention(&w.q, &w.k, &w.v, &params, &mut run_rng)
            });
            let mut err = 0.0;
            for s in 0..err_seeds {
                let mut run_rng = Rng::seed_from(seed + 20 + s);
                err += max_abs_diff(
                    &wildcat_attention(&w.q, &w.k, &w.v, &params, &mut run_rng),
                    &exact,
                );
            }
            let err = err / err_seeds as f64;
            table.add_row(vec![
                b.to_string(),
                r.to_string(),
                format!("{:.1}", t.median() * 1e3),
                format!("{:.2}x", t_exact.median() / t.median()),
                format!("{err:.3e}"),
            ]);
            // within a series, error should broadly decrease with r
            if err < last_err {
                last_err = err;
            }
        }
    }
    table.print();
    println!("\n(markdown)\n{}", table.render_markdown());
}
