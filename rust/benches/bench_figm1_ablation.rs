//! Fig. M.1 reproduction: time-accuracy trade-off curves for WildCat with
//! varying rank r ∈ {64, 128, 256, 512} and bin count B ∈ {2, 16, 64}
//! (Sec. M.4). One series per B; each point is (median runtime, mean
//! ‖O − Ô‖_max over replicates) at a given r.
//!
//! Expected shape: larger r → slower + more accurate; larger B → faster
//! at slightly higher error (the Sec. 2.5 trade-off).
//!
//! All logic lives in `wildcat::bench::runners::run_figm1`, shared with
//! `wildcat bench --smoke`.

use wildcat::bench::runners::{maybe_write_json, run_figm1, RunCfg};
use wildcat::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let cfg = RunCfg::from_args(&args);
    let report = run_figm1(&cfg)?;
    maybe_write_json(&report, &args)
}
