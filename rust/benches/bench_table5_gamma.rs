//! Tab. 5 reproduction: the entry growth factor
//! `γ(n) = β R_Q R_K / log(n)` as a function of context length, measured
//! on the build-time-trained LM's attention layers (the paper measures
//! Qwen2.5-7B on QASPER; substitution in DESIGN.md §3).
//!
//! Expected shape: γ(n) decreasing in n — the Cor. 2 assumption that
//! justifies near-constant cache sizes (Veličković et al. 2025 show Q/K
//! norms of any trained transformer are bounded in n).
//!
//! All logic lives in `wildcat::bench::runners::run_table5`, shared with
//! `wildcat bench --smoke` (which substitutes a seeded random model when
//! `make artifacts` has not run).

use wildcat::bench::runners::{maybe_write_json, run_table5, RunCfg};
use wildcat::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let cfg = RunCfg::from_args(&args);
    let report = run_table5(&cfg)?;
    maybe_write_json(&report, &args)
}
