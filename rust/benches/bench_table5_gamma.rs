//! Tab. 5 reproduction: the entry growth factor
//! `γ(n) = β R_Q R_K / log(n)` as a function of context length, measured
//! on the build-time-trained LM's attention layers (the paper measures
//! Qwen2.5-7B on QASPER; substitution in DESIGN.md §3).
//!
//! Expected shape: γ(n) decreasing in n — the Cor. 2 assumption that
//! justifies near-constant cache sizes (Veličković et al. 2025 show Q/K
//! norms of any trained transformer are bounded in n).

use wildcat::kernels::gamma_growth;
use wildcat::model::{ModelConfig, Transformer, WeightFile};
use wildcat::rng::Rng;
use wildcat::util::cli::Args;
use wildcat::util::table::Table;
use wildcat::workload::tasks::TaskKind;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let artifacts = args.get_or("artifacts", "artifacts");
    let seed = args.get_parse::<u64>("seed", 0);
    let trials = args.get_parse::<usize>("trials", 5);

    let w = WeightFile::load(format!("{artifacts}/weights.bin"))
        .expect("weights.bin missing — run `make artifacts` first");
    let model = Transformer::from_weights(&w, ModelConfig::default())?;
    let beta = model.cfg.beta() as f64;
    let n_lh = model.cfg.n_layers * model.cfg.n_heads;

    // paper sweeps n = 4 … 16384; our model's max_len caps the range
    let lens: Vec<usize> = [4usize, 16, 64, 128, 256, 512]
        .into_iter()
        .filter(|&n| n <= model.cfg.max_len)
        .collect();

    let mut table = Table::new(
        "Table 5 — entry growth factor γ(n) = β·R_Q·R_K / log(n)",
        &["n", "R_K (mean)", "gamma(n)"],
    );
    let mut gammas = Vec::new();
    for &n in &lens {
        let mut rng = Rng::seed_from(seed);
        let mut g_acc = 0.0;
        let mut rk_acc = 0.0;
        for _ in 0..trials {
            let inst = TaskKind::Passkey.generate(&mut rng, n.max(16), model.cfg.vocab as u32);
            let toks = &inst.context[..n.min(inst.context.len())];
            let out = model.prefill(toks);
            // R_K per (layer, head); R_Q proxied by R_K of the same head
            // (queries and keys share scale in trained layers; the paper
            // measures both from activations — we average over heads)
            let mut g = 0.0;
            let mut rk_mean = 0.0;
            for lh in 0..n_lh {
                let r_k = out.k_cache[lh].max_row_norm();
                rk_mean += r_k / n_lh as f64;
                g += gamma_growth(beta, r_k, r_k, toks.len().max(2)) / n_lh as f64;
            }
            g_acc += g;
            rk_acc += rk_mean;
        }
        let g = g_acc / trials as f64;
        gammas.push(g);
        table.add_row(vec![
            n.to_string(),
            format!("{:.3}", rk_acc / trials as f64),
            format!("{g:.3}"),
        ]);
    }
    table.print();
    println!("\n(markdown)\n{}", table.render_markdown());

    // headline check: γ decreasing in n (Tab. 5's finding)
    let decreasing = gammas.windows(2).all(|w| w[1] <= w[0] * 1.05);
    println!(
        "[table5] gamma(n) decreasing: {} ({:?})",
        if decreasing { "YES (matches paper)" } else { "NO" },
        gammas.iter().map(|g| (g * 1000.0).round() / 1000.0).collect::<Vec<_>>()
    );
    Ok(())
}
