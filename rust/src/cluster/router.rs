//! The cluster router: pluggable load-balancing over a replica pool.
//!
//! Policies:
//! * `round_robin` — rotate the first-choice replica per request.
//! * `join_shortest_queue` — pick the replica with the least
//!   accepted-but-unfinished work (in-flight gauge, queue depth as the
//!   tie-break) at submission time.
//! * `affinity` — hash a session key to a home replica so repeated
//!   requests of one session land on the same warm KV cache; falls back
//!   to least-loaded siblings under backpressure.
//!
//! Backpressure: a replica that refuses a request is cooled down
//! ([`ReplicaHealth`]) and the request is re-routed to the next
//! candidate. Every replica (cooled ones last) is tried before the
//! router surfaces a rejection — requests are answered or rejected,
//! never dropped silently.

use super::health::ReplicaHealth;
use super::metrics::{ClusterMetrics, ClusterSnapshot};
use crate::coordinator::admission::RejectReason;
use crate::coordinator::request::{RequestId, Response};
use crate::coordinator::ServerClient;
use crate::kvpool::{aggregate_snapshots, PoolSnapshot};
use crate::obs::trace::{self, SpanKind, NO_REQ, ROUTE_REJECTED};
use crate::rng::splitmix64;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A pluggable load-balancing policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutingPolicy {
    /// Rotate the first-choice replica per request.
    RoundRobin,
    /// Pick the replica with the least accepted-but-unfinished work.
    JoinShortestQueue,
    /// Hash the session key to a home replica (warm KV-cache reuse).
    Affinity,
}

impl RoutingPolicy {
    /// Every policy, in the order the serving bench compares them.
    pub const ALL: [RoutingPolicy; 3] =
        [RoutingPolicy::RoundRobin, RoutingPolicy::JoinShortestQueue, RoutingPolicy::Affinity];

    /// Parse a CLI name (`round_robin` / `join_shortest_queue` /
    /// `affinity`, plus the obvious short forms).
    pub fn parse(name: &str) -> anyhow::Result<RoutingPolicy> {
        Ok(match name {
            "round_robin" | "rr" => RoutingPolicy::RoundRobin,
            "join_shortest_queue" | "jsq" => RoutingPolicy::JoinShortestQueue,
            "affinity" => RoutingPolicy::Affinity,
            other => anyhow::bail!(
                "unknown routing policy {other:?} (try round_robin/join_shortest_queue/affinity)"
            ),
        })
    }

    /// The policy's canonical CLI name.
    pub fn name(&self) -> &'static str {
        match self {
            RoutingPolicy::RoundRobin => "round_robin",
            RoutingPolicy::JoinShortestQueue => "join_shortest_queue",
            RoutingPolicy::Affinity => "affinity",
        }
    }
}

/// Router tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct RouterConfig {
    /// The load-balancing policy.
    pub policy: RoutingPolicy,
    /// How long a replica that refused a request is de-preferred.
    pub cooldown: Duration,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            policy: RoutingPolicy::JoinShortestQueue,
            cooldown: Duration::from_millis(50),
        }
    }
}

/// An accepted, routed request: await the response with
/// [`RoutedRequest::wait`], which also records cluster-level end-to-end
/// latency at receipt.
pub struct RoutedRequest {
    /// Replica index the request landed on.
    pub replica: usize,
    /// Per-replica request id.
    pub id: RequestId,
    rx: Receiver<Response>,
    submitted_at: Instant,
    metrics: Arc<ClusterMetrics>,
}

impl RoutedRequest {
    /// Block for the response up to `timeout`. `None` on timeout (the
    /// replica keeps working; the response is simply no longer awaited).
    pub fn wait(self, timeout: Duration) -> Option<Response> {
        match self.rx.recv_timeout(timeout) {
            Ok(resp) => {
                self.metrics.on_complete(self.submitted_at.elapsed(), resp.tokens.len());
                Some(resp)
            }
            Err(_) => None,
        }
    }
}

/// The router: submit-side front door of a replica pool.
pub struct Router {
    clients: Vec<ServerClient>,
    cfg: RouterConfig,
    health: Vec<ReplicaHealth>,
    rr: AtomicUsize,
    metrics: Arc<ClusterMetrics>,
}

impl Router {
    /// Build a router over one client per replica (panics on zero).
    pub fn new(clients: Vec<ServerClient>, cfg: RouterConfig) -> Self {
        assert!(!clients.is_empty(), "router needs at least one replica");
        let n = clients.len();
        Router {
            clients,
            cfg,
            health: (0..n).map(|_| ReplicaHealth::new()).collect(),
            rr: AtomicUsize::new(0),
            metrics: Arc::new(ClusterMetrics::new(n)),
        }
    }

    /// Number of replicas routed over.
    pub fn n_replicas(&self) -> usize {
        self.clients.len()
    }

    /// The configured routing policy.
    pub fn policy(&self) -> RoutingPolicy {
        self.cfg.policy
    }

    /// Router-side counters and latency sink.
    pub fn metrics(&self) -> &ClusterMetrics {
        &self.metrics
    }

    /// Cluster snapshot with the KV and prefill-skipping totals filled
    /// in from the per-replica clients.
    pub fn snapshot(&self) -> ClusterSnapshot {
        let mut s = self.metrics.snapshot();
        let kv = self.pool_aggregate();
        s.kv_bytes_used = kv.used_bytes();
        s.kv_bytes_peak = kv.peak_bytes();
        for c in &self.clients {
            let counters = c.metrics().counters();
            s.prefill_tokens_computed += counters.prefill_tokens_computed;
            s.prefill_tokens_skipped += counters.prefill_tokens_skipped;
            s.prefix_hits += counters.prefix_hits;
            s.prefix_misses += counters.prefix_misses;
            if let Some(q) = c.metrics().quality_snapshot() {
                s.quality_audited_samples += q.audited_total();
                s.quality_slo_degradations += q.degradations;
                s.quality_degraded_replicas += u64::from(q.degraded);
            }
        }
        s
    }

    /// Per-replica KV pool snapshots, in replica order.
    pub fn pool_snapshots(&self) -> Vec<PoolSnapshot> {
        self.clients.iter().map(|c| c.pool_snapshot()).collect()
    }

    /// The replicas' pool gauges summed into one cluster-level view.
    pub fn pool_aggregate(&self) -> PoolSnapshot {
        aggregate_snapshots(&self.pool_snapshots())
    }

    /// Submit a request, re-routing around backpressure. `session` keys
    /// the `affinity` policy; other policies ignore it. On success the
    /// replica's health resets; a rejection here means *every* replica
    /// refused (or the request is malformed, e.g. over-long prompt).
    pub fn submit(
        &self,
        tokens: Vec<u32>,
        max_new: usize,
        session: Option<u64>,
    ) -> Result<RoutedRequest, RejectReason> {
        let order = self.candidate_order(session);
        let mut last = RejectReason::QueueFull;
        let mut tokens = Some(tokens);
        // route span: decision start → accept/reject, tagged with the
        // attempt count and the landing replica (or ROUTE_REJECTED)
        let t0 = if trace::enabled() { Some(Instant::now()) } else { None };
        for (attempt, &i) in order.iter().enumerate() {
            if attempt > 0 {
                self.metrics.on_reroute();
            }
            // clone only while re-route targets remain; the last
            // candidate consumes the prompt without copying
            let attempt_tokens = if attempt + 1 == order.len() {
                tokens.take().expect("prompt consumed before last attempt")
            } else {
                tokens.as_ref().expect("prompt missing").clone()
            };
            match self.clients[i].submit(attempt_tokens, max_new) {
                Ok((id, rx)) => {
                    self.health[i].on_accept();
                    self.metrics.on_routed(i);
                    if let Some(t0) = t0 {
                        let attempts = attempt as u64 + 1;
                        let now = Instant::now();
                        trace::span_on(i as u32, SpanKind::Route, t0, now, id, attempts, i as u64);
                    }
                    return Ok(RoutedRequest {
                        replica: i,
                        id,
                        rx,
                        submitted_at: Instant::now(),
                        metrics: self.metrics.clone(),
                    });
                }
                Err(reason @ RejectReason::PromptTooLong { .. }) => {
                    // deterministic across identically-configured
                    // replicas: re-routing cannot help
                    self.metrics.on_reject();
                    if let Some(t0) = t0 {
                        let attempts = attempt as u64 + 1;
                        let now = Instant::now();
                        trace::span_on(
                            0,
                            SpanKind::Route,
                            t0,
                            now,
                            NO_REQ,
                            attempts,
                            ROUTE_REJECTED,
                        );
                    }
                    return Err(reason);
                }
                Err(reason) => {
                    self.health[i].on_reject(Instant::now(), self.cfg.cooldown);
                    last = reason;
                }
            }
        }
        self.metrics.on_reject();
        if let Some(t0) = t0 {
            let attempts = order.len() as u64;
            let now = Instant::now();
            trace::span_on(0, SpanKind::Route, t0, now, NO_REQ, attempts, ROUTE_REJECTED);
        }
        Err(last)
    }

    /// Replica indices in preference order: the policy's choice first,
    /// then the remaining replicas least-loaded-first as re-route
    /// targets; cooled-down replicas are demoted to the tail (still
    /// tried, as the last resort before rejecting).
    fn candidate_order(&self, session: Option<u64>) -> Vec<usize> {
        let n = self.clients.len();
        let mut order: Vec<usize> = match self.cfg.policy {
            RoutingPolicy::RoundRobin => {
                let start = self.rr.fetch_add(1, Ordering::Relaxed) % n;
                (0..n).map(|k| (start + k) % n).collect()
            }
            RoutingPolicy::JoinShortestQueue => self.least_loaded(),
            RoutingPolicy::Affinity => {
                let home = match session {
                    Some(key) => {
                        let mut s = key;
                        (splitmix64(&mut s) % n as u64) as usize
                    }
                    // sessionless requests rotate like round_robin
                    None => self.rr.fetch_add(1, Ordering::Relaxed) % n,
                };
                let mut rest = self.least_loaded();
                rest.retain(|&i| i != home);
                std::iter::once(home).chain(rest).collect()
            }
        };
        // stable partition: healthy replicas first, cooled ones last
        // (snapshot health before sorting — the gauges are live and a
        // key that changes mid-sort is an inconsistent comparator)
        let now = Instant::now();
        let cooled: Vec<bool> = (0..n).map(|i| self.health[i].is_cooled(now)).collect();
        order.sort_by_key(|&i| cooled[i]);
        order
    }

    /// All replica indices sorted by load: in-flight gauge, then queue
    /// depth, then index (deterministic tie-break). Loads are snapshotted
    /// once up front: the gauges move concurrently with the sort, and a
    /// live key would be an inconsistent comparator (and take the metrics
    /// lock O(n log n) times).
    fn least_loaded(&self) -> Vec<usize> {
        let mut loads: Vec<(u64, usize, usize)> = self
            .clients
            .iter()
            .enumerate()
            .map(|(i, c)| (c.in_flight(), c.queue_depth(), i))
            .collect();
        loads.sort_unstable();
        loads.into_iter().map(|(_, _, i)| i).collect()
    }

    /// One JSON document: the cluster aggregate plus a per-replica block
    /// (serving metrics snapshot + router-side gauges), the cluster
    /// counterpart of `ServingMetrics::to_json`.
    pub fn metrics_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("policy".to_string(), Json::Str(self.cfg.policy.name().to_string()));
        o.insert("n_replicas".to_string(), Json::Num(self.clients.len() as f64));
        o.insert("aggregate".to_string(), self.metrics.to_json());
        o.insert("kv".to_string(), self.pool_aggregate().to_json());
        // cluster-wide prefill-skipping totals (summed per-replica
        // serving counters; per-replica values appear in each replica
        // block below)
        let (mut computed, mut skipped) = (0u64, 0u64);
        let (mut hits, mut misses) = (0u64, 0u64);
        for c in &self.clients {
            let counters = c.metrics().counters();
            computed += counters.prefill_tokens_computed;
            skipped += counters.prefill_tokens_skipped;
            hits += counters.prefix_hits;
            misses += counters.prefix_misses;
        }
        o.insert("prefill_tokens_computed".to_string(), Json::Num(computed as f64));
        o.insert("prefill_tokens_skipped".to_string(), Json::Num(skipped as f64));
        o.insert("prefix_hits".to_string(), Json::Num(hits as f64));
        o.insert("prefix_misses".to_string(), Json::Num(misses as f64));
        // cluster-wide approximation-quality totals, flattened like the
        // prefill totals above (absent when no replica runs an auditor,
        // i.e. `--audit-rate 0`); the full per-replica quality blocks
        // appear inside each replica snapshot below
        let quality: Vec<_> =
            self.clients.iter().filter_map(|c| c.metrics().quality_snapshot()).collect();
        if !quality.is_empty() {
            let audited: u64 = quality.iter().map(|s| s.audited_total()).sum();
            let degradations: u64 = quality.iter().map(|s| s.degradations).sum();
            let recoveries: u64 = quality.iter().map(|s| s.recoveries).sum();
            let degraded: u64 = quality.iter().map(|s| u64::from(s.degraded)).sum();
            let worst_p99 = quality.iter().map(|s| s.err_p99).fold(0.0f64, f64::max);
            o.insert("quality_audited_samples".to_string(), Json::Num(audited as f64));
            o.insert("quality_slo_degradations".to_string(), Json::Num(degradations as f64));
            o.insert("quality_slo_recoveries".to_string(), Json::Num(recoveries as f64));
            o.insert("quality_degraded_replicas".to_string(), Json::Num(degraded as f64));
            o.insert(
                "quality_worst_max_abs_err_p99".to_string(),
                Json::Num(if worst_p99.is_finite() { worst_p99 } else { 0.0 }),
            );
        }
        let replicas: Vec<Json> = self
            .clients
            .iter()
            .enumerate()
            .map(|(i, c)| {
                let mut r = match c.metrics().to_json() {
                    Json::Obj(m) => m,
                    _ => BTreeMap::new(),
                };
                r.insert("replica".to_string(), Json::Num(i as f64));
                r.insert("routed".to_string(), Json::Num(self.metrics.routed_to(i) as f64));
                r.insert("queue_depth".to_string(), Json::Num(c.queue_depth() as f64));
                r.insert("router_rejects".to_string(), Json::Num(self.health[i].rejects() as f64));
                r.insert("cooldowns".to_string(), Json::Num(self.health[i].cooldowns() as f64));
                r.insert("kv_pool".to_string(), c.pool_snapshot().to_json());
                Json::Obj(r)
            })
            .collect();
        o.insert("replicas".to_string(), Json::Arr(replicas));
        Json::Obj(o)
    }

    /// Cluster-wide Prometheus text exposition (format 0.0.4): the
    /// router counters and end-to-end quantiles, plus every replica's
    /// serving and KV-pool metrics labeled `replica="i"` — the scrape
    /// counterpart of [`Router::metrics_json`].
    pub fn to_prometheus(&self) -> String {
        let mut b = crate::obs::PromBuilder::new();
        let s = self.metrics.snapshot();
        b.declare(
            "wildcat_cluster_routed_total",
            "counter",
            "Requests accepted by a replica, by landing replica.",
        );
        for i in 0..self.clients.len() {
            let label = i.to_string();
            b.sample(
                "wildcat_cluster_routed_total",
                &[("replica", label.as_str())],
                self.metrics.routed_to(i) as f64,
            );
        }
        let totals: [(&str, &str, u64); 3] = [
            (
                "wildcat_cluster_rejected_total",
                "Requests rejected by every replica.",
                s.rejected,
            ),
            (
                "wildcat_cluster_rerouted_total",
                "Re-route attempts after a replica refused.",
                s.rerouted,
            ),
            (
                "wildcat_cluster_completed_total",
                "Responses received by awaiting callers.",
                s.completed,
            ),
        ];
        for (name, help, v) in totals {
            b.declare(name, "counter", help);
            b.sample(name, &[], v as f64);
        }
        b.declare(
            "wildcat_cluster_e2e_latency_ms",
            "gauge",
            "Cluster end-to-end latency quantiles in milliseconds.",
        );
        for (q, v) in [("0.5", s.p50_ms), ("0.95", s.p95_ms), ("0.99", s.p99_ms)] {
            b.sample("wildcat_cluster_e2e_latency_ms", &[("quantile", q)], v);
        }
        for (i, c) in self.clients.iter().enumerate() {
            let label = i.to_string();
            let labels = [("replica", label.as_str())];
            c.metrics().prom_write(&mut b, &labels);
            c.pool_snapshot().prom_write(&mut b, &labels);
            b.declare("wildcat_queue_depth", "gauge", "Requests waiting in the replica queue.");
            b.sample("wildcat_queue_depth", &labels, c.queue_depth() as f64);
        }
        b.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::pool::ReplicaPool;
    use crate::coordinator::ServerConfig;
    use crate::kvcache::StreamingLlm;
    use crate::model::{ModelConfig, Transformer};
    use crate::rng::Rng;

    fn tiny_pool(n: usize) -> ReplicaPool {
        ReplicaPool::spawn(n, ServerConfig::default(), Arc::new(StreamingLlm), |i| {
            let cfg = ModelConfig {
                vocab: 16,
                d_model: 16,
                n_layers: 2,
                n_heads: 2,
                d_ff: 32,
                max_len: 256,
            };
            Transformer::random(cfg, &mut Rng::seed_from(50 + i as u64))
        })
    }

    #[test]
    fn round_robin_spreads_requests() {
        let pool = tiny_pool(3);
        let router = Router::new(
            pool.clients(),
            RouterConfig { policy: RoutingPolicy::RoundRobin, ..Default::default() },
        );
        let mut pending = Vec::new();
        for _ in 0..9 {
            pending.push(router.submit(vec![1, 2, 3], 1, None).unwrap());
        }
        for p in pending {
            assert!(p.wait(Duration::from_secs(30)).is_some());
        }
        for i in 0..3 {
            assert_eq!(router.metrics().routed_to(i), 3, "replica {i} share");
        }
        let s = router.snapshot();
        assert_eq!(s.completed, 9);
        assert_eq!(s.rejected, 0);
        pool.shutdown();
    }

    #[test]
    fn affinity_pins_sessions() {
        let pool = tiny_pool(4);
        let router = Router::new(
            pool.clients(),
            RouterConfig { policy: RoutingPolicy::Affinity, ..Default::default() },
        );
        let mut homes = std::collections::BTreeMap::new();
        let mut pending = Vec::new();
        for turn in 0..3 {
            for session in 0..6u64 {
                let r = router.submit(vec![1, 2, 3, 4], 1, Some(session)).unwrap();
                let prev = homes.insert(session, r.replica);
                if turn > 0 {
                    assert_eq!(prev, Some(r.replica), "session {session} moved replicas");
                }
                pending.push(r);
            }
        }
        // 6 sessions over 4 replicas: at least two distinct homes
        let distinct: std::collections::BTreeSet<_> = homes.values().collect();
        assert!(distinct.len() >= 2, "all sessions hashed to one replica");
        for p in pending {
            assert!(p.wait(Duration::from_secs(30)).is_some());
        }
        pool.shutdown();
    }

    #[test]
    fn overlong_prompt_rejects_without_reroute() {
        let pool = tiny_pool(2);
        let router = Router::new(pool.clients(), RouterConfig::default());
        let err = router.submit(vec![0; 5000], 1, None).unwrap_err();
        assert!(matches!(err, RejectReason::PromptTooLong { .. }));
        let s = router.snapshot();
        assert_eq!(s.rejected, 1);
        assert_eq!(s.rerouted, 0, "malformed requests must not be re-routed");
        pool.shutdown();
    }

    #[test]
    fn audited_cluster_aggregates_quality_across_replicas() {
        use crate::obs::quality::QualityConfig;
        let mut cfg = ServerConfig::default();
        cfg.quality = QualityConfig { rate: 1, slo_abs_err: 0.0, seed: 7 };
        let pool = ReplicaPool::spawn(2, cfg, Arc::new(StreamingLlm), |i| {
            let mc = ModelConfig {
                vocab: 16,
                d_model: 16,
                n_layers: 2,
                n_heads: 2,
                d_ff: 32,
                max_len: 256,
            };
            Transformer::random(mc, &mut Rng::seed_from(90 + i as u64))
        });
        let router = Router::new(
            pool.clients(),
            RouterConfig { policy: RoutingPolicy::RoundRobin, ..Default::default() },
        );
        let mut pending = Vec::new();
        for _ in 0..4 {
            pending.push(router.submit(vec![1, 2, 3, 4], 3, None).unwrap());
        }
        for p in pending {
            assert!(p.wait(Duration::from_secs(30)).is_some());
        }
        let s = router.snapshot();
        assert!(s.quality_audited_samples > 0, "rate-1 audit must sample decode steps");
        assert_eq!(s.quality_slo_degradations, 0, "SLO disabled: no degradations");
        assert_eq!(s.quality_degraded_replicas, 0);
        let j = router.metrics_json();
        assert_eq!(
            j.get("quality_audited_samples").and_then(Json::as_f64),
            Some(s.quality_audited_samples as f64)
        );
        assert_eq!(j.get("quality_degraded_replicas").and_then(Json::as_f64), Some(0.0));
        // the document still satisfies the obs --metrics validator: the
        // per-replica quality blocks are the only "quality" objects
        assert_eq!(crate::obs::validate_quality_json(&j), Ok(2));
        // per-replica blocks each carry their own quality snapshot, and
        // the cluster total is their sum
        let reps = j.get("replicas").unwrap().as_arr().unwrap();
        let per_replica: f64 = reps
            .iter()
            .map(|r| {
                r.get("quality")
                    .and_then(|q| q.get("audited_samples"))
                    .and_then(Json::as_f64)
                    .unwrap_or(0.0)
            })
            .sum();
        assert_eq!(per_replica, s.quality_audited_samples as f64);
        // the scrape surface carries the quality families per replica
        let prom = router.to_prometheus();
        assert!(prom.contains("wildcat_quality_audited_samples_total"), "prom:\n{prom}");
        pool.shutdown();
    }

    #[test]
    fn policy_parsing() {
        assert_eq!(RoutingPolicy::parse("rr").unwrap(), RoutingPolicy::RoundRobin);
        assert_eq!(
            RoutingPolicy::parse("join_shortest_queue").unwrap(),
            RoutingPolicy::JoinShortestQueue
        );
        assert_eq!(RoutingPolicy::parse("affinity").unwrap(), RoutingPolicy::Affinity);
        assert!(RoutingPolicy::parse("random").is_err());
        for p in RoutingPolicy::ALL {
            assert_eq!(RoutingPolicy::parse(p.name()).unwrap(), p);
        }
    }

    #[test]
    fn metrics_json_has_aggregate_and_replicas() {
        let pool = tiny_pool(2);
        let router = Router::new(pool.clients(), RouterConfig::default());
        let r = router.submit(vec![1, 2, 3], 1, None).unwrap();
        assert!(r.wait(Duration::from_secs(30)).is_some());
        let j = router.metrics_json();
        assert_eq!(j.get("policy").and_then(Json::as_str), Some("join_shortest_queue"));
        assert_eq!(j.get("n_replicas").and_then(Json::as_f64), Some(2.0));
        let agg = j.get("aggregate").unwrap();
        assert_eq!(agg.get("completed").and_then(Json::as_f64), Some(1.0));
        let reps = j.get("replicas").unwrap().as_arr().unwrap();
        assert_eq!(reps.len(), 2);
        let routed_sum: f64 =
            reps.iter().map(|r| r.get("routed").and_then(Json::as_f64).unwrap()).sum();
        assert_eq!(routed_sum, 1.0);
        // every replica block carries its pool gauges; the one request
        // landed on exactly one replica, whose pool saw KV bytes
        let peaks: Vec<f64> = reps
            .iter()
            .map(|r| {
                let kvp = r.get("kv_pool").expect("replica kv_pool block");
                kvp.get("peak_bytes").and_then(Json::as_f64).unwrap()
            })
            .collect();
        assert!(peaks.iter().any(|&p| p > 0.0), "no replica pool held KV state");
        // the cluster aggregate sums the per-replica pools
        let peak_sum: f64 = peaks.iter().sum();
        let kv = j.get("kv").expect("cluster kv aggregate");
        assert_eq!(kv.get("peak_bytes").and_then(Json::as_f64), Some(peak_sum));
        assert_eq!(router.snapshot().kv_bytes_peak as f64, peak_sum);
        // document parses back (fixed point)
        let text = j.to_string_compact();
        assert_eq!(crate::util::json::parse(&text).unwrap(), j);
        // cluster-wide prefix counters are present and consistent
        let hits = j.get("prefix_hits").and_then(Json::as_f64).unwrap();
        let misses = j.get("prefix_misses").and_then(Json::as_f64).unwrap();
        assert_eq!(hits + misses, 1.0, "one admission must be a hit or a miss");
        // default config audits nothing: no cluster quality keys, zero totals
        assert!(
            j.get("quality_audited_samples").is_none(),
            "quality totals must be absent at audit rate 0"
        );
        assert_eq!(router.snapshot().quality_audited_samples, 0);
        // Prometheus exposition carries the router counters per replica
        let prom = router.to_prometheus();
        assert!(prom.contains("wildcat_cluster_completed_total 1\n"), "prom:\n{prom}");
        assert!(prom.contains("wildcat_cluster_routed_total{replica=\"0\"}"), "prom:\n{prom}");
        assert!(prom.contains("wildcat_kv_pool_bytes{replica=\"1\",state=\"peak\"}"));
        pool.shutdown();
    }
}
