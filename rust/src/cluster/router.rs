//! The cluster router: pluggable load-balancing over a replica pool,
//! hardened with deadlines, bounded retries and circuit breakers.
//!
//! Policies:
//! * `round_robin` — rotate the first-choice replica per request.
//! * `join_shortest_queue` — pick the replica with the least
//!   accepted-but-unfinished work (in-flight gauge, queue depth as the
//!   tie-break) at submission time.
//! * `affinity` — hash a session key to a home replica so repeated
//!   requests of one session land on the same warm KV cache; falls back
//!   to least-loaded siblings under backpressure.
//!
//! Robustness (see `docs/ROBUSTNESS.md`):
//! * every submitted request reaches **exactly one terminal
//!   [`Outcome`]** — completed, rejected(reason), or deadline exceeded —
//!   under any fault schedule; never dropped silently;
//! * a replica that refuses or fails trips a per-replica closed → open →
//!   half-open **circuit breaker** ([`ReplicaHealth`]); open replicas are
//!   demoted (still tried last-resort), half-open ones admit one probe;
//! * full-cluster refusals are **retried** up to `max_retries` rounds
//!   with exponential backoff and deterministic jitter;
//! * a request in flight on a replica whose worker dies is **failed
//!   over**: the pool supervisor respawns the replica, the router
//!   resubmits the prompt to a survivor ([`Router::await_outcome`]),
//!   and under the `affinity` policy the session is **re-pinned** to
//!   that survivor (its warm KV state now lives there, not on the
//!   freshly respawned home);
//! * optional per-request **deadlines** (`request_timeout`) bound the
//!   total time to a terminal outcome.
//!
//! Time is read through a [`Clock`], so deadline/backoff/breaker tests
//! run deterministic and instant on virtual time.

use super::clock::Clock;
use super::health::{BreakerConfig, BreakerState, ReplicaHealth};
use super::metrics::{ClusterMetrics, ClusterSnapshot};
use super::pool::ReplicaPool;
use crate::coordinator::admission::RejectReason;
use crate::coordinator::request::{RequestId, Response};
use crate::kvpool::{aggregate_snapshots, PoolSnapshot};
use crate::obs::trace::{self, SpanKind, NO_REQ, ROUTE_REJECTED};
use crate::rng::splitmix64;
use crate::util::json::Json;
use crate::util::sync::lock_recover;
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Wall-clock slice between liveness checks while awaiting a response.
const WALL_POLL_SLICE: Duration = Duration::from_millis(5);
/// Wall-clock slice per poll on a manual clock (lets worker threads make
/// real progress inside virtual waits).
const MANUAL_WAIT_SLICE: Duration = Duration::from_micros(500);
/// Virtual microseconds a manual clock advances per empty poll, bounding
/// virtual-time waits (a hung request exhausts its deadline in
/// `deadline / MANUAL_TICK_US` polls).
const MANUAL_TICK_US: u64 = 1_000;

/// A pluggable load-balancing policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutingPolicy {
    /// Rotate the first-choice replica per request.
    RoundRobin,
    /// Pick the replica with the least accepted-but-unfinished work.
    JoinShortestQueue,
    /// Hash the session key to a home replica (warm KV-cache reuse).
    Affinity,
}

impl RoutingPolicy {
    /// Every policy, in the order the serving bench compares them.
    pub const ALL: [RoutingPolicy; 3] =
        [RoutingPolicy::RoundRobin, RoutingPolicy::JoinShortestQueue, RoutingPolicy::Affinity];

    /// Parse a CLI name (`round_robin` / `join_shortest_queue` /
    /// `affinity`, plus the obvious short forms).
    pub fn parse(name: &str) -> anyhow::Result<RoutingPolicy> {
        Ok(match name {
            "round_robin" | "rr" => RoutingPolicy::RoundRobin,
            "join_shortest_queue" | "jsq" => RoutingPolicy::JoinShortestQueue,
            "affinity" => RoutingPolicy::Affinity,
            other => anyhow::bail!(
                "unknown routing policy {other:?} (try round_robin/join_shortest_queue/affinity)"
            ),
        })
    }

    /// The policy's canonical CLI name.
    pub fn name(&self) -> &'static str {
        match self {
            RoutingPolicy::RoundRobin => "round_robin",
            RoutingPolicy::JoinShortestQueue => "join_shortest_queue",
            RoutingPolicy::Affinity => "affinity",
        }
    }
}

/// Router tuning knobs.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// The load-balancing policy.
    pub policy: RoutingPolicy,
    /// The circuit breaker's open window: how long a tripped replica is
    /// demoted before a probe is allowed (PR 2 called this the cooldown).
    pub cooldown: Duration,
    /// Consecutive failures that trip a replica's breaker open. The
    /// default 1 preserves the original one-reject-demotes behaviour.
    pub failure_threshold: u32,
    /// Per-request deadline: the request reaches
    /// [`Outcome::DeadlineExceeded`] if no terminal outcome arrived in
    /// time. [`Duration::ZERO`] (the default) disables deadlines.
    pub request_timeout: Duration,
    /// Extra full-cluster submission rounds after the first refusal
    /// (each preceded by backoff), and the failover-resubmission budget.
    pub max_retries: u32,
    /// Base backoff before retry round 1 (doubles per round).
    pub backoff_base: Duration,
    /// Upper bound on any single backoff sleep.
    pub backoff_cap: Duration,
    /// Seed for the deterministic backoff jitter.
    pub seed: u64,
    /// Time source for deadlines, backoff and breaker windows. Tests
    /// inject [`Clock::manual`] for instant, deterministic timing.
    pub clock: Arc<Clock>,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            policy: RoutingPolicy::JoinShortestQueue,
            cooldown: Duration::from_millis(50),
            failure_threshold: 1,
            request_timeout: Duration::ZERO,
            max_retries: 2,
            backoff_base: Duration::from_millis(2),
            backoff_cap: Duration::from_millis(50),
            seed: 0,
            clock: Clock::wall(),
        }
    }
}

/// The exactly-one-terminal-outcome taxonomy: every submitted request
/// ends in precisely one of these, under any fault schedule.
#[derive(Debug)]
pub enum Outcome {
    /// The response arrived.
    Completed(Response),
    /// Every replica refused (or the request is malformed, or its
    /// failover budget ran out while replicas kept dying).
    Rejected(RejectReason),
    /// The per-request deadline expired before a response.
    DeadlineExceeded,
}

impl Outcome {
    /// Stable snake_case name for reporting.
    pub fn name(&self) -> &'static str {
        match self {
            Outcome::Completed(_) => "completed",
            Outcome::Rejected(_) => "rejected",
            Outcome::DeadlineExceeded => "deadline_exceeded",
        }
    }

    /// True for [`Outcome::Completed`].
    pub fn is_completed(&self) -> bool {
        matches!(self, Outcome::Completed(_))
    }

    /// The response, if completed.
    pub fn response(self) -> Option<Response> {
        match self {
            Outcome::Completed(resp) => Some(resp),
            _ => None,
        }
    }
}

/// An accepted, routed request. Await it with [`Router::await_outcome`]
/// (deadline-aware, fails over off dead replicas) or the simpler
/// [`RoutedRequest::wait`].
pub struct RoutedRequest {
    /// Replica index the request currently lives on (failover updates it).
    pub replica: usize,
    /// Per-replica request id (failover re-assigns it).
    pub id: RequestId,
    rx: Receiver<Response>,
    submitted_at: Instant,
    metrics: Arc<ClusterMetrics>,
    prompt: Vec<u32>,
    max_new: usize,
    session: Option<u64>,
    deadline_us: Option<u64>,
    failovers: u32,
}

impl RoutedRequest {
    /// Block for the response up to `timeout`. `None` on timeout or a
    /// dead replica (no failover — use [`Router::await_outcome`] for the
    /// fault-tolerant path). Records cluster end-to-end latency at
    /// receipt.
    pub fn wait(self, timeout: Duration) -> Option<Response> {
        match self.rx.recv_timeout(timeout) {
            Ok(resp) => {
                self.metrics.on_complete(self.submitted_at.elapsed(), resp.tokens.len());
                Some(resp)
            }
            Err(_) => None,
        }
    }
}

/// Internal: how a routing pass ended without acceptance.
enum RouteFail {
    Rejected(RejectReason),
    Deadline,
}

/// Internal: a successful routing pass.
struct Accepted {
    replica: usize,
    id: RequestId,
    rx: Receiver<Response>,
}

/// Internal: one wait step while awaiting a response.
enum Waited {
    Response(Response),
    Deadline,
    /// The serving replica died — the supervisor dropped our sender.
    Lost,
}

/// The router: submit-side front door of a replica pool.
pub struct Router {
    pool: Arc<ReplicaPool>,
    cfg: RouterConfig,
    breaker: BreakerConfig,
    health: Vec<ReplicaHealth>,
    rr: AtomicUsize,
    jitter_seq: AtomicU64,
    metrics: Arc<ClusterMetrics>,
    /// Crash-failover affinity overrides: session key → the replica a
    /// failed-over request of that session completed its re-route on.
    /// Consulted before the hash in the `affinity` policy, so a session
    /// whose home replica died keeps landing on the survivor that now
    /// holds its warm KV state instead of bouncing back to the freshly
    /// respawned (cold) home.
    pins: Mutex<HashMap<u64, usize>>,
}

impl Router {
    /// Build a router over a (supervised) replica pool. The router
    /// fetches clients from the pool per submission, so respawned
    /// replicas are reachable without rebuilding anything.
    pub fn new(pool: Arc<ReplicaPool>, cfg: RouterConfig) -> Self {
        assert!(!pool.is_empty(), "router needs at least one replica");
        let n = pool.len();
        let breaker = BreakerConfig {
            failure_threshold: cfg.failure_threshold.max(1),
            open_for_us: cfg.cooldown.as_micros() as u64,
        };
        Router {
            pool,
            cfg,
            breaker,
            health: (0..n).map(|_| ReplicaHealth::new()).collect(),
            rr: AtomicUsize::new(0),
            jitter_seq: AtomicU64::new(0),
            metrics: Arc::new(ClusterMetrics::new(n)),
            pins: Mutex::new(HashMap::new()),
        }
    }

    /// Where an affinity session is currently pinned: `Some(replica)`
    /// after a crash-failover moved the session off its hash-derived home
    /// (the pin is the survivor that served the failed-over request),
    /// `None` while the session still follows the hash.
    pub fn pinned_replica(&self, session: u64) -> Option<usize> {
        lock_recover(&self.pins).get(&session).copied()
    }

    /// Number of replicas routed over.
    pub fn n_replicas(&self) -> usize {
        self.pool.len()
    }

    /// The configured routing policy.
    pub fn policy(&self) -> RoutingPolicy {
        self.cfg.policy
    }

    /// Router-side counters and latency sink.
    pub fn metrics(&self) -> &ClusterMetrics {
        &self.metrics
    }

    /// The supervised pool this router submits into.
    pub fn pool(&self) -> &Arc<ReplicaPool> {
        &self.pool
    }

    /// Cluster snapshot with the KV, prefill-skipping and restart totals
    /// filled in from the per-replica clients and the pool supervisor.
    pub fn snapshot(&self) -> ClusterSnapshot {
        let mut s = self.metrics.snapshot();
        let kv = self.pool_aggregate();
        s.kv_bytes_used = kv.used_bytes();
        s.kv_bytes_peak = kv.peak_bytes();
        s.restarts = self.pool.restarts_total();
        for i in 0..self.pool.len() {
            let c = self.pool.client(i);
            let counters = c.metrics().counters();
            s.prefill_tokens_computed += counters.prefill_tokens_computed;
            s.prefill_tokens_skipped += counters.prefill_tokens_skipped;
            s.prefix_hits += counters.prefix_hits;
            s.prefix_misses += counters.prefix_misses;
            if let Some(q) = c.metrics().quality_snapshot() {
                s.quality_audited_samples += q.audited_total();
                s.quality_slo_degradations += q.degradations;
                s.quality_degraded_replicas += u64::from(q.degraded);
            }
        }
        s
    }

    /// Per-replica KV pool snapshots, in replica order.
    pub fn pool_snapshots(&self) -> Vec<PoolSnapshot> {
        self.pool.pool_snapshots()
    }

    /// The replicas' pool gauges summed into one cluster-level view.
    pub fn pool_aggregate(&self) -> PoolSnapshot {
        aggregate_snapshots(&self.pool_snapshots())
    }

    /// Submit a request, re-routing around backpressure and retrying
    /// full-cluster refusals with backoff. `session` keys the `affinity`
    /// policy; other policies ignore it. `Err` carries the request's
    /// terminal outcome (already counted); `Ok` must be driven to its
    /// terminal outcome with [`Router::await_outcome`] (or the legacy
    /// [`RoutedRequest::wait`]).
    pub fn submit(
        &self,
        tokens: Vec<u32>,
        max_new: usize,
        session: Option<u64>,
    ) -> Result<RoutedRequest, Outcome> {
        self.metrics.on_request();
        let deadline_us = if self.cfg.request_timeout.is_zero() {
            None
        } else {
            Some(
                self.cfg
                    .clock
                    .now_us()
                    .saturating_add(self.cfg.request_timeout.as_micros() as u64),
            )
        };
        match self.route(&tokens, max_new, session, deadline_us) {
            Ok(acc) => Ok(RoutedRequest {
                replica: acc.replica,
                id: acc.id,
                rx: acc.rx,
                submitted_at: Instant::now(),
                metrics: self.metrics.clone(),
                prompt: tokens,
                max_new,
                session,
                deadline_us,
                failovers: 0,
            }),
            Err(fail) => Err(self.terminal(fail)),
        }
    }

    /// Drive a routed request to its terminal outcome: wait for the
    /// response, observing the deadline, and fail over to a surviving
    /// replica (resubmitting the prompt) if the serving replica's worker
    /// dies. `wait_cap` bounds total wall-clock blocking when no deadline
    /// is configured (its expiry counts as a deadline exceeded).
    pub fn await_outcome(&self, mut r: RoutedRequest, wait_cap: Duration) -> Outcome {
        let wait_started = Instant::now();
        loop {
            match self.wait_response(&r, wait_started, wait_cap) {
                Waited::Response(resp) => {
                    self.metrics.on_complete(r.submitted_at.elapsed(), resp.tokens.len());
                    return Outcome::Completed(resp);
                }
                Waited::Deadline => return self.terminal(RouteFail::Deadline),
                Waited::Lost => {
                    r.failovers += 1;
                    self.metrics.on_failover();
                    if trace::enabled() {
                        let now = Instant::now();
                        trace::span_on(
                            r.replica as u32,
                            SpanKind::Failover,
                            now,
                            now,
                            r.id,
                            r.failovers as u64,
                            r.replica as u64,
                        );
                    }
                    let now_us = self.cfg.clock.now_us();
                    if self.health[r.replica].on_failure(now_us, &self.breaker) {
                        self.trace_breaker(r.replica, BreakerState::Open);
                    }
                    self.pool.restart_if_dead(r.replica);
                    // bounded failovers: a request cannot chase dying
                    // replicas forever
                    if r.failovers > self.cfg.max_retries.saturating_add(1) {
                        return self.terminal(RouteFail::Rejected(RejectReason::ShuttingDown));
                    }
                    if !self.backoff(r.failovers, r.deadline_us) {
                        return self.terminal(RouteFail::Deadline);
                    }
                    match self.route(&r.prompt, r.max_new, r.session, r.deadline_us) {
                        Ok(acc) => {
                            r.replica = acc.replica;
                            r.id = acc.id;
                            r.rx = acc.rx;
                            // re-pin the session to the survivor: the
                            // failed-over request is rebuilding warm KV
                            // state there, so later requests of the same
                            // session must follow it rather than return
                            // to the respawned (cold) home replica
                            if self.cfg.policy == RoutingPolicy::Affinity {
                                if let Some(key) = r.session {
                                    lock_recover(&self.pins).insert(key, acc.replica);
                                }
                            }
                        }
                        Err(fail) => return self.terminal(fail),
                    }
                }
            }
        }
    }

    /// Count and build the terminal outcome for a failed request.
    fn terminal(&self, fail: RouteFail) -> Outcome {
        match fail {
            RouteFail::Rejected(reason) => {
                self.metrics.on_reject(reason);
                Outcome::Rejected(reason)
            }
            RouteFail::Deadline => {
                self.metrics.on_deadline_exceeded();
                Outcome::DeadlineExceeded
            }
        }
    }

    /// One wait step: poll the response channel in short slices so a
    /// dead worker is detected (and its waiters freed) even when every
    /// caller is blocked awaiting it.
    fn wait_response(&self, r: &RoutedRequest, wait_started: Instant, wait_cap: Duration) -> Waited {
        let manual = self.cfg.clock.is_manual();
        loop {
            if let Some(d) = r.deadline_us {
                if self.cfg.clock.now_us() >= d {
                    return Waited::Deadline;
                }
            }
            if wait_started.elapsed() >= wait_cap {
                return Waited::Deadline;
            }
            let slice = if manual { MANUAL_WAIT_SLICE } else { WALL_POLL_SLICE };
            match r.rx.recv_timeout(slice) {
                Ok(resp) => return Waited::Response(resp),
                Err(RecvTimeoutError::Timeout) => {
                    if manual {
                        self.cfg.clock.advance_us(MANUAL_TICK_US);
                    }
                    // liveness: a panicked worker never answers its
                    // waiters; supervising here fails them over (our own
                    // sender drops → Lost on the next poll) and respawns
                    self.pool.restart_if_dead(r.replica);
                }
                Err(RecvTimeoutError::Disconnected) => return Waited::Lost,
            }
        }
    }

    /// Routing passes with retry rounds: round 0 plus up to `max_retries`
    /// backoff-separated rounds, each trying every replica in breaker-
    /// aware preference order.
    fn route(
        &self,
        tokens: &[u32],
        max_new: usize,
        session: Option<u64>,
        deadline_us: Option<u64>,
    ) -> Result<Accepted, RouteFail> {
        let t0 = if trace::enabled() { Some(Instant::now()) } else { None };
        let mut last = RejectReason::QueueFull;
        let mut total_attempts = 0u64;
        for round in 0..=self.cfg.max_retries {
            if round > 0 {
                self.metrics.on_retry();
                if !self.backoff(round, deadline_us) {
                    return Err(RouteFail::Deadline);
                }
            }
            if let Some(d) = deadline_us {
                if self.cfg.clock.now_us() >= d {
                    return Err(RouteFail::Deadline);
                }
            }
            for (attempt, &i) in self.candidate_order(session).iter().enumerate() {
                if attempt > 0 {
                    self.metrics.on_reroute();
                }
                total_attempts += 1;
                let now_us = self.cfg.clock.now_us();
                self.health[i].begin_probe(now_us, &self.breaker);
                match self.pool.client(i).submit(tokens.to_vec(), max_new) {
                    Ok((id, rx)) => {
                        if self.health[i].on_success() {
                            self.trace_breaker(i, BreakerState::Closed);
                        }
                        self.metrics.on_routed(i);
                        if let Some(t0) = t0 {
                            let now = Instant::now();
                            trace::span_on(
                                i as u32,
                                SpanKind::Route,
                                t0,
                                now,
                                id,
                                total_attempts,
                                i as u64,
                            );
                        }
                        return Ok(Accepted { replica: i, id, rx });
                    }
                    Err(reason @ RejectReason::PromptTooLong { .. }) => {
                        // deterministic across identically-configured
                        // replicas: re-routing/retrying cannot help
                        if let Some(t0) = t0 {
                            let now = Instant::now();
                            trace::span_on(
                                0,
                                SpanKind::Route,
                                t0,
                                now,
                                NO_REQ,
                                total_attempts,
                                ROUTE_REJECTED,
                            );
                        }
                        return Err(RouteFail::Rejected(reason));
                    }
                    Err(reason) => {
                        // a ShuttingDown verdict may mean the worker
                        // crashed (its exit guard closed the queue):
                        // supervise so a later round reaches the respawn
                        if reason == RejectReason::ShuttingDown {
                            self.pool.restart_if_dead(i);
                        }
                        if self.health[i].on_failure(self.cfg.clock.now_us(), &self.breaker) {
                            self.trace_breaker(i, BreakerState::Open);
                        }
                        last = reason;
                    }
                }
            }
        }
        if let Some(t0) = t0 {
            let now = Instant::now();
            trace::span_on(0, SpanKind::Route, t0, now, NO_REQ, total_attempts, ROUTE_REJECTED);
        }
        Err(RouteFail::Rejected(last))
    }

    /// Sleep the backoff for retry/failover round `round` (≥ 1):
    /// exponential in the round, capped, with deterministic jitter, and
    /// clamped to never sleep past the deadline. Returns `false` when the
    /// deadline is (or would be) exhausted.
    fn backoff(&self, round: u32, deadline_us: Option<u64>) -> bool {
        let base = self.cfg.backoff_base.as_micros() as u64;
        let cap = (self.cfg.backoff_cap.as_micros() as u64).max(1);
        let exp = base.saturating_mul(1u64 << (round.saturating_sub(1)).min(16)).min(cap);
        // deterministic jitter in [0, exp/2]: seeded by config, streamed
        // by a per-router sequence so concurrent submitters decorrelate
        let mut s = self
            .cfg
            .seed
            .wrapping_add(self.jitter_seq.fetch_add(1, Ordering::Relaxed).wrapping_mul(0x9E37));
        let jitter = if exp == 0 { 0 } else { splitmix64(&mut s) % (exp / 2 + 1) };
        let mut sleep = exp + jitter;
        if let Some(d) = deadline_us {
            let now = self.cfg.clock.now_us();
            if now >= d {
                return false;
            }
            sleep = sleep.min(d - now);
        }
        self.cfg.clock.sleep_us(sleep);
        if let Some(d) = deadline_us {
            if self.cfg.clock.now_us() >= d {
                return false;
            }
        }
        true
    }

    /// Record a breaker transition span for replica `i`.
    fn trace_breaker(&self, i: usize, state: BreakerState) {
        if trace::enabled() {
            let now = Instant::now();
            trace::span_on(
                i as u32,
                SpanKind::Breaker,
                now,
                now,
                NO_REQ,
                state.code(),
                self.health[i].rejects(),
            );
        }
    }

    /// Replica indices in preference order: the policy's choice first,
    /// then the remaining replicas least-loaded-first as re-route
    /// targets; breaker state demotes tripped replicas to the tail
    /// (still tried, as the last resort before rejecting).
    fn candidate_order(&self, session: Option<u64>) -> Vec<usize> {
        let n = self.pool.len();
        let mut order: Vec<usize> = match self.cfg.policy {
            RoutingPolicy::RoundRobin => {
                let start = self.rr.fetch_add(1, Ordering::Relaxed) % n;
                (0..n).map(|k| (start + k) % n).collect()
            }
            RoutingPolicy::JoinShortestQueue => self.least_loaded(),
            RoutingPolicy::Affinity => {
                let home = match session {
                    // a crash-failover pin overrides the hash-derived
                    // home (the session's warm KV lives on the survivor)
                    Some(key) => self.pinned_replica(key).unwrap_or_else(|| {
                        let mut s = key;
                        (splitmix64(&mut s) % n as u64) as usize
                    }),
                    // sessionless requests rotate like round_robin
                    None => self.rr.fetch_add(1, Ordering::Relaxed) % n,
                };
                let mut rest = self.least_loaded();
                rest.retain(|&i| i != home);
                std::iter::once(home).chain(rest).collect()
            }
        };
        // stable partition by breaker rank: closed first, half-open
        // (probe available) next, open / probe-in-flight last (snapshot
        // ranks before sorting — breaker state is live and a key that
        // changes mid-sort is an inconsistent comparator)
        let now_us = self.cfg.clock.now_us();
        let rank: Vec<u8> = (0..n).map(|i| self.health[i].rank(now_us, &self.breaker)).collect();
        order.sort_by_key(|&i| rank[i]);
        order
    }

    /// All replica indices sorted by load: in-flight gauge, then queue
    /// depth, then index (deterministic tie-break). Loads are snapshotted
    /// once up front: the gauges move concurrently with the sort, and a
    /// live key would be an inconsistent comparator (and take the metrics
    /// lock O(n log n) times).
    fn least_loaded(&self) -> Vec<usize> {
        let mut loads: Vec<(u64, usize, usize)> = (0..self.pool.len())
            .map(|i| {
                let c = self.pool.client(i);
                (c.in_flight(), c.queue_depth(), i)
            })
            .collect();
        loads.sort_unstable();
        loads.into_iter().map(|(_, _, i)| i).collect()
    }

    /// One JSON document: the cluster aggregate plus a per-replica block
    /// (serving metrics snapshot + router-side gauges), the cluster
    /// counterpart of `ServingMetrics::to_json`.
    pub fn metrics_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("policy".to_string(), Json::Str(self.cfg.policy.name().to_string()));
        o.insert("n_replicas".to_string(), Json::Num(self.pool.len() as f64));
        o.insert("restarts".to_string(), Json::Num(self.pool.restarts_total() as f64));
        o.insert("aggregate".to_string(), self.metrics.to_json());
        o.insert("kv".to_string(), self.pool_aggregate().to_json());
        // cluster-wide prefill-skipping totals (summed per-replica
        // serving counters; per-replica values appear in each replica
        // block below)
        let clients: Vec<_> = (0..self.pool.len()).map(|i| self.pool.client(i)).collect();
        let (mut computed, mut skipped) = (0u64, 0u64);
        let (mut hits, mut misses) = (0u64, 0u64);
        for c in &clients {
            let counters = c.metrics().counters();
            computed += counters.prefill_tokens_computed;
            skipped += counters.prefill_tokens_skipped;
            hits += counters.prefix_hits;
            misses += counters.prefix_misses;
        }
        o.insert("prefill_tokens_computed".to_string(), Json::Num(computed as f64));
        o.insert("prefill_tokens_skipped".to_string(), Json::Num(skipped as f64));
        o.insert("prefix_hits".to_string(), Json::Num(hits as f64));
        o.insert("prefix_misses".to_string(), Json::Num(misses as f64));
        // cluster-wide approximation-quality totals, flattened like the
        // prefill totals above (absent when no replica runs an auditor,
        // i.e. `--audit-rate 0`); the full per-replica quality blocks
        // appear inside each replica snapshot below
        let quality: Vec<_> = clients.iter().filter_map(|c| c.metrics().quality_snapshot()).collect();
        if !quality.is_empty() {
            let audited: u64 = quality.iter().map(|s| s.audited_total()).sum();
            let degradations: u64 = quality.iter().map(|s| s.degradations).sum();
            let recoveries: u64 = quality.iter().map(|s| s.recoveries).sum();
            let degraded: u64 = quality.iter().map(|s| u64::from(s.degraded)).sum();
            let worst_p99 = quality.iter().map(|s| s.err_p99).fold(0.0f64, f64::max);
            o.insert("quality_audited_samples".to_string(), Json::Num(audited as f64));
            o.insert("quality_slo_degradations".to_string(), Json::Num(degradations as f64));
            o.insert("quality_slo_recoveries".to_string(), Json::Num(recoveries as f64));
            o.insert("quality_degraded_replicas".to_string(), Json::Num(degraded as f64));
            o.insert(
                "quality_worst_max_abs_err_p99".to_string(),
                Json::Num(if worst_p99.is_finite() { worst_p99 } else { 0.0 }),
            );
        }
        let now_us = self.cfg.clock.now_us();
        let replicas: Vec<Json> = clients
            .iter()
            .enumerate()
            .map(|(i, c)| {
                let mut r = match c.metrics().to_json() {
                    Json::Obj(m) => m,
                    _ => BTreeMap::new(),
                };
                r.insert("replica".to_string(), Json::Num(i as f64));
                r.insert("routed".to_string(), Json::Num(self.metrics.routed_to(i) as f64));
                r.insert("queue_depth".to_string(), Json::Num(c.queue_depth() as f64));
                r.insert("router_rejects".to_string(), Json::Num(self.health[i].rejects() as f64));
                r.insert("cooldowns".to_string(), Json::Num(self.health[i].opens() as f64));
                r.insert(
                    "breaker_state".to_string(),
                    Json::Str(self.health[i].state(now_us, &self.breaker).name().to_string()),
                );
                r.insert(
                    "breaker_transitions".to_string(),
                    Json::Num(self.health[i].transitions() as f64),
                );
                r.insert("restarts".to_string(), Json::Num(self.pool.restarts(i) as f64));
                r.insert("kv_pool".to_string(), c.pool_snapshot().to_json());
                Json::Obj(r)
            })
            .collect();
        o.insert("replicas".to_string(), Json::Arr(replicas));
        Json::Obj(o)
    }

    /// Cluster-wide Prometheus text exposition (format 0.0.4): the
    /// router counters and end-to-end quantiles, plus every replica's
    /// serving and KV-pool metrics labeled `replica="i"` — the scrape
    /// counterpart of [`Router::metrics_json`].
    pub fn to_prometheus(&self) -> String {
        let mut b = crate::obs::PromBuilder::new();
        let s = self.metrics.snapshot();
        b.declare(
            "wildcat_cluster_routed_total",
            "counter",
            "Requests accepted by a replica, by landing replica.",
        );
        for i in 0..self.pool.len() {
            let label = i.to_string();
            b.sample(
                "wildcat_cluster_routed_total",
                &[("replica", label.as_str())],
                self.metrics.routed_to(i) as f64,
            );
        }
        let totals: [(&str, &str, u64); 8] = [
            (
                "wildcat_cluster_requests_total",
                "Requests submitted to the router (each reaches one terminal outcome).",
                s.requests,
            ),
            (
                "wildcat_cluster_rejected_total",
                "Requests rejected by every replica.",
                s.rejected,
            ),
            (
                "wildcat_cluster_rerouted_total",
                "Re-route attempts after a replica refused.",
                s.rerouted,
            ),
            (
                "wildcat_cluster_completed_total",
                "Responses received by awaiting callers.",
                s.completed,
            ),
            (
                "wildcat_cluster_deadline_exceeded_total",
                "Requests that hit their deadline before a response.",
                s.deadline_exceeded,
            ),
            (
                "wildcat_cluster_failovers_total",
                "In-flight requests failed over off a dead replica.",
                s.failovers,
            ),
            (
                "wildcat_cluster_retries_total",
                "Full-cluster retry rounds after a refusal.",
                s.retries,
            ),
            (
                "wildcat_cluster_restarts_total",
                "Replica workers respawned after a crash.",
                s.restarts,
            ),
        ];
        for (name, help, v) in totals {
            b.declare(name, "counter", help);
            b.sample(name, &[], v as f64);
        }
        b.declare(
            "wildcat_cluster_e2e_latency_ms",
            "gauge",
            "Cluster end-to-end latency quantiles in milliseconds.",
        );
        for (q, v) in [("0.5", s.p50_ms), ("0.95", s.p95_ms), ("0.99", s.p99_ms)] {
            b.sample("wildcat_cluster_e2e_latency_ms", &[("quantile", q)], v);
        }
        let now_us = self.cfg.clock.now_us();
        for i in 0..self.pool.len() {
            let c = self.pool.client(i);
            let label = i.to_string();
            let labels = [("replica", label.as_str())];
            c.metrics().prom_write(&mut b, &labels);
            c.pool_snapshot().prom_write(&mut b, &labels);
            b.declare("wildcat_queue_depth", "gauge", "Requests waiting in the replica queue.");
            b.sample("wildcat_queue_depth", &labels, c.queue_depth() as f64);
            b.declare(
                "wildcat_breaker_state",
                "gauge",
                "Replica circuit-breaker state (0 closed, 1 open, 2 half-open).",
            );
            b.sample(
                "wildcat_breaker_state",
                &labels,
                self.health[i].state(now_us, &self.breaker).code() as f64,
            );
            b.declare(
                "wildcat_replica_restarts_total",
                "counter",
                "Times this replica was respawned after a crash.",
            );
            b.sample("wildcat_replica_restarts_total", &labels, self.pool.restarts(i) as f64);
        }
        b.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::fault::{FaultConfig, FaultPlan};
    use crate::cluster::pool::ReplicaPool;
    use crate::coordinator::ServerConfig;
    use crate::kvcache::StreamingLlm;
    use crate::model::{ModelConfig, Transformer};
    use crate::rng::Rng;

    fn tiny_pool_cfg(n: usize, cfg: ServerConfig) -> Arc<ReplicaPool> {
        Arc::new(ReplicaPool::spawn(n, cfg, Arc::new(StreamingLlm), |i| {
            let cfg = ModelConfig {
                vocab: 16,
                d_model: 16,
                n_layers: 2,
                n_heads: 2,
                d_ff: 32,
                max_len: 256,
            };
            Transformer::random(cfg, &mut Rng::seed_from(50 + i as u64))
        }))
    }

    fn tiny_pool(n: usize) -> Arc<ReplicaPool> {
        tiny_pool_cfg(n, ServerConfig::default())
    }

    #[test]
    fn round_robin_spreads_requests() {
        let pool = tiny_pool(3);
        let router = Router::new(
            pool.clone(),
            RouterConfig { policy: RoutingPolicy::RoundRobin, ..Default::default() },
        );
        let mut pending = Vec::new();
        for _ in 0..9 {
            pending.push(router.submit(vec![1, 2, 3], 1, None).unwrap());
        }
        for p in pending {
            assert!(p.wait(Duration::from_secs(30)).is_some());
        }
        for i in 0..3 {
            assert_eq!(router.metrics().routed_to(i), 3, "replica {i} share");
        }
        let s = router.snapshot();
        assert_eq!(s.requests, 9);
        assert_eq!(s.completed, 9);
        assert_eq!(s.rejected, 0);
        assert_eq!(s.deadline_exceeded, 0);
        pool.shutdown();
    }

    #[test]
    fn affinity_pins_sessions() {
        let pool = tiny_pool(4);
        let router = Router::new(
            pool.clone(),
            RouterConfig { policy: RoutingPolicy::Affinity, ..Default::default() },
        );
        let mut homes = std::collections::BTreeMap::new();
        let mut pending = Vec::new();
        for turn in 0..3 {
            for session in 0..6u64 {
                let r = router.submit(vec![1, 2, 3, 4], 1, Some(session)).unwrap();
                let prev = homes.insert(session, r.replica);
                if turn > 0 {
                    assert_eq!(prev, Some(r.replica), "session {session} moved replicas");
                }
                pending.push(r);
            }
        }
        // 6 sessions over 4 replicas: at least two distinct homes
        let distinct: std::collections::BTreeSet<_> = homes.values().collect();
        assert!(distinct.len() >= 2, "all sessions hashed to one replica");
        for p in pending {
            assert!(p.wait(Duration::from_secs(30)).is_some());
        }
        pool.shutdown();
    }

    #[test]
    fn overlong_prompt_rejects_without_reroute_or_retry() {
        let pool = tiny_pool(2);
        let router = Router::new(pool.clone(), RouterConfig::default());
        let outcome = router.submit(vec![0; 5000], 1, None).unwrap_err();
        assert!(matches!(outcome, Outcome::Rejected(RejectReason::PromptTooLong { .. })));
        let s = router.snapshot();
        assert_eq!(s.requests, 1);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.rerouted, 0, "malformed requests must not be re-routed");
        assert_eq!(s.retries, 0, "malformed requests must not be retried");
        pool.shutdown();
    }

    #[test]
    fn injected_rejects_are_retried_to_completion() {
        // every 2nd submit to the single replica fails transiently; with
        // retry rounds every request still completes
        let plan =
            FaultPlan::new(FaultConfig { reject_every: 2, ..Default::default() }, 1).unwrap();
        let pool = tiny_pool_cfg(1, ServerConfig { faults: Some(plan), ..Default::default() });
        let router = Router::new(
            pool.clone(),
            RouterConfig { policy: RoutingPolicy::RoundRobin, max_retries: 3, ..Default::default() },
        );
        let mut pending = Vec::new();
        for _ in 0..6 {
            pending.push(router.submit(vec![1, 2, 3], 1, None).unwrap());
        }
        for p in pending {
            assert!(
                router.await_outcome(p, Duration::from_secs(60)).is_completed(),
                "transient injected rejects must be retried to completion"
            );
        }
        let s = router.snapshot();
        assert_eq!(s.requests, 6);
        assert_eq!(s.completed, 6);
        assert!(s.retries > 0, "injected failures must surface as retries: {s:?}");
        pool.shutdown();
    }

    #[test]
    fn breaker_opens_on_virtual_clock_and_reports_state() {
        // reject every submit; manual clock so the open window never
        // expires during the test
        let plan =
            FaultPlan::new(FaultConfig { reject_every: 1, ..Default::default() }, 1).unwrap();
        let pool = tiny_pool_cfg(1, ServerConfig { faults: Some(plan), ..Default::default() });
        let clock = Clock::manual();
        let router = Router::new(
            pool.clone(),
            RouterConfig {
                policy: RoutingPolicy::RoundRobin,
                max_retries: 0,
                clock,
                ..Default::default()
            },
        );
        let outcome = router.submit(vec![1, 2, 3], 1, None).unwrap_err();
        assert!(matches!(outcome, Outcome::Rejected(RejectReason::Injected)));
        let j = router.metrics_json();
        let rep = &j.get("replicas").unwrap().as_arr().unwrap()[0];
        assert_eq!(rep.get("breaker_state").and_then(Json::as_str), Some("open"));
        assert!(rep.get("breaker_transitions").and_then(Json::as_f64).unwrap() >= 1.0);
        let agg = j.get("aggregate").unwrap();
        let by_reason = agg.get("rejected_by_reason").expect("outcome-reason accounting");
        assert_eq!(by_reason.get("injected").and_then(Json::as_f64), Some(1.0));
        pool.shutdown();
    }

    #[test]
    fn deadline_exceeded_is_terminal_and_counted() {
        // stall every engine step far past the deadline budget
        let plan = FaultPlan::new(
            FaultConfig { stall_every: 1, stall_ms: 200, ..Default::default() },
            1,
        )
        .unwrap();
        let pool = tiny_pool_cfg(1, ServerConfig { faults: Some(plan), ..Default::default() });
        let router = Router::new(
            pool.clone(),
            RouterConfig {
                policy: RoutingPolicy::RoundRobin,
                request_timeout: Duration::from_millis(40),
                max_retries: 0,
                ..Default::default()
            },
        );
        let r = router.submit(vec![1, 2, 3], 4, None).unwrap();
        let outcome = router.await_outcome(r, Duration::from_secs(30));
        assert!(matches!(outcome, Outcome::DeadlineExceeded), "got {}", outcome.name());
        let s = router.snapshot();
        assert_eq!(s.requests, 1);
        assert_eq!(s.deadline_exceeded, 1);
        assert_eq!(s.completed + s.rejected + s.deadline_exceeded, s.requests);
        pool.shutdown();
    }

    #[test]
    fn audited_cluster_aggregates_quality_across_replicas() {
        use crate::obs::quality::QualityConfig;
        let mut cfg = ServerConfig::default();
        cfg.quality = QualityConfig { rate: 1, slo_abs_err: 0.0, seed: 7 };
        let pool = Arc::new(ReplicaPool::spawn(2, cfg, Arc::new(StreamingLlm), |i| {
            let mc = ModelConfig {
                vocab: 16,
                d_model: 16,
                n_layers: 2,
                n_heads: 2,
                d_ff: 32,
                max_len: 256,
            };
            Transformer::random(mc, &mut Rng::seed_from(90 + i as u64))
        }));
        let router = Router::new(
            pool.clone(),
            RouterConfig { policy: RoutingPolicy::RoundRobin, ..Default::default() },
        );
        let mut pending = Vec::new();
        for _ in 0..4 {
            pending.push(router.submit(vec![1, 2, 3, 4], 3, None).unwrap());
        }
        for p in pending {
            assert!(p.wait(Duration::from_secs(30)).is_some());
        }
        let s = router.snapshot();
        assert!(s.quality_audited_samples > 0, "rate-1 audit must sample decode steps");
        assert_eq!(s.quality_slo_degradations, 0, "SLO disabled: no degradations");
        assert_eq!(s.quality_degraded_replicas, 0);
        let j = router.metrics_json();
        assert_eq!(
            j.get("quality_audited_samples").and_then(Json::as_f64),
            Some(s.quality_audited_samples as f64)
        );
        assert_eq!(j.get("quality_degraded_replicas").and_then(Json::as_f64), Some(0.0));
        // the document still satisfies the obs --metrics validator: the
        // per-replica quality blocks are the only "quality" objects
        assert_eq!(crate::obs::validate_quality_json(&j), Ok(2));
        // per-replica blocks each carry their own quality snapshot, and
        // the cluster total is their sum
        let reps = j.get("replicas").unwrap().as_arr().unwrap();
        let per_replica: f64 = reps
            .iter()
            .map(|r| {
                r.get("quality")
                    .and_then(|q| q.get("audited_samples"))
                    .and_then(Json::as_f64)
                    .unwrap_or(0.0)
            })
            .sum();
        assert_eq!(per_replica, s.quality_audited_samples as f64);
        // the scrape surface carries the quality families per replica
        let prom = router.to_prometheus();
        assert!(prom.contains("wildcat_quality_audited_samples_total"), "prom:\n{prom}");
        pool.shutdown();
    }

    #[test]
    fn policy_parsing() {
        assert_eq!(RoutingPolicy::parse("rr").unwrap(), RoutingPolicy::RoundRobin);
        assert_eq!(
            RoutingPolicy::parse("join_shortest_queue").unwrap(),
            RoutingPolicy::JoinShortestQueue
        );
        assert_eq!(RoutingPolicy::parse("affinity").unwrap(), RoutingPolicy::Affinity);
        assert!(RoutingPolicy::parse("random").is_err());
        for p in RoutingPolicy::ALL {
            assert_eq!(RoutingPolicy::parse(p.name()).unwrap(), p);
        }
    }

    #[test]
    fn metrics_json_has_aggregate_and_replicas() {
        let pool = tiny_pool(2);
        let router = Router::new(pool.clone(), RouterConfig::default());
        let r = router.submit(vec![1, 2, 3], 1, None).unwrap();
        assert!(r.wait(Duration::from_secs(30)).is_some());
        let j = router.metrics_json();
        assert_eq!(j.get("policy").and_then(Json::as_str), Some("join_shortest_queue"));
        assert_eq!(j.get("n_replicas").and_then(Json::as_f64), Some(2.0));
        assert_eq!(j.get("restarts").and_then(Json::as_f64), Some(0.0));
        let agg = j.get("aggregate").unwrap();
        assert_eq!(agg.get("completed").and_then(Json::as_f64), Some(1.0));
        assert_eq!(agg.get("requests").and_then(Json::as_f64), Some(1.0));
        assert_eq!(agg.get("deadline_exceeded").and_then(Json::as_f64), Some(0.0));
        assert_eq!(agg.get("failovers").and_then(Json::as_f64), Some(0.0));
        assert_eq!(agg.get("retries").and_then(Json::as_f64), Some(0.0));
        let reps = j.get("replicas").unwrap().as_arr().unwrap();
        assert_eq!(reps.len(), 2);
        let routed_sum: f64 =
            reps.iter().map(|r| r.get("routed").and_then(Json::as_f64).unwrap()).sum();
        assert_eq!(routed_sum, 1.0);
        // every replica block reports a healthy breaker and no restarts
        for r in reps {
            assert_eq!(r.get("breaker_state").and_then(Json::as_str), Some("closed"));
            assert_eq!(r.get("restarts").and_then(Json::as_f64), Some(0.0));
        }
        // every replica block carries its pool gauges; the one request
        // landed on exactly one replica, whose pool saw KV bytes
        let peaks: Vec<f64> = reps
            .iter()
            .map(|r| {
                let kvp = r.get("kv_pool").expect("replica kv_pool block");
                kvp.get("peak_bytes").and_then(Json::as_f64).unwrap()
            })
            .collect();
        assert!(peaks.iter().any(|&p| p > 0.0), "no replica pool held KV state");
        // the cluster aggregate sums the per-replica pools
        let peak_sum: f64 = peaks.iter().sum();
        let kv = j.get("kv").expect("cluster kv aggregate");
        assert_eq!(kv.get("peak_bytes").and_then(Json::as_f64), Some(peak_sum));
        assert_eq!(router.snapshot().kv_bytes_peak as f64, peak_sum);
        // document parses back (fixed point)
        let text = j.to_string_compact();
        assert_eq!(crate::util::json::parse(&text).unwrap(), j);
        // cluster-wide prefix counters are present and consistent
        let hits = j.get("prefix_hits").and_then(Json::as_f64).unwrap();
        let misses = j.get("prefix_misses").and_then(Json::as_f64).unwrap();
        assert_eq!(hits + misses, 1.0, "one admission must be a hit or a miss");
        // default config audits nothing: no cluster quality keys, zero totals
        assert!(
            j.get("quality_audited_samples").is_none(),
            "quality totals must be absent at audit rate 0"
        );
        assert_eq!(router.snapshot().quality_audited_samples, 0);
        // Prometheus exposition carries the router counters per replica
        let prom = router.to_prometheus();
        assert!(prom.contains("wildcat_cluster_completed_total 1\n"), "prom:\n{prom}");
        assert!(prom.contains("wildcat_cluster_requests_total 1\n"), "prom:\n{prom}");
        assert!(prom.contains("wildcat_cluster_deadline_exceeded_total 0\n"), "prom:\n{prom}");
        assert!(prom.contains("wildcat_cluster_routed_total{replica=\"0\"}"), "prom:\n{prom}");
        assert!(prom.contains("wildcat_breaker_state{replica=\"0\"} 0\n"), "prom:\n{prom}");
        assert!(prom.contains("wildcat_kv_pool_bytes{replica=\"1\",state=\"peak\"}"));
        pool.shutdown();
    }
}
