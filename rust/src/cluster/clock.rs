//! Virtual/wall clock abstraction for the routing tier.
//!
//! Deadlines, backoff sleeps and circuit-breaker windows all need a notion
//! of "now". Coupling them to [`Instant::now`] makes every breaker test a
//! wall-clock sleep and every chaos property test nondeterministic, so the
//! router reads time through a [`Clock`] instead: `Clock::wall()` for
//! production and `Clock::manual()` for tests, where time only moves when
//! the test (or a polling waiter) advances it. The `--fast` loadgen path
//! already replays arrivals in virtual time; this extends the same idea to
//! timeouts and health windows.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Microsecond clock: either the process monotonic clock or a manually
/// advanced counter (for deterministic tests).
#[derive(Debug)]
pub enum Clock {
    /// Monotonic wall time, measured from the clock's creation.
    Wall {
        /// Epoch all readings are relative to.
        epoch: Instant,
    },
    /// Virtual time in microseconds; moves only via [`Clock::advance_us`].
    Manual(AtomicU64),
}

impl Clock {
    /// A wall clock starting at 0 µs now.
    pub fn wall() -> Arc<Clock> {
        Arc::new(Clock::Wall { epoch: Instant::now() })
    }

    /// A virtual clock starting at 0 µs; time moves only on `advance_us`.
    pub fn manual() -> Arc<Clock> {
        Arc::new(Clock::Manual(AtomicU64::new(0)))
    }

    /// True for manually advanced (virtual) clocks.
    pub fn is_manual(&self) -> bool {
        matches!(self, Clock::Manual(_))
    }

    /// Current time in microseconds since the clock's epoch.
    pub fn now_us(&self) -> u64 {
        match self {
            Clock::Wall { epoch } => epoch.elapsed().as_micros() as u64,
            Clock::Manual(us) => us.load(Ordering::Acquire),
        }
    }

    /// Advance a manual clock by `us`. No-op on a wall clock (wall time
    /// advances on its own).
    pub fn advance_us(&self, us: u64) {
        if let Clock::Manual(now) = self {
            now.fetch_add(us, Ordering::AcqRel);
        }
    }

    /// Sleep for `us`: a real [`std::thread::sleep`] on a wall clock, a
    /// virtual advance plus a scheduler yield on a manual one (the yield
    /// lets worker threads make wall-time progress inside virtual sleeps).
    pub fn sleep_us(&self, us: u64) {
        match self {
            Clock::Wall { .. } => std::thread::sleep(Duration::from_micros(us)),
            Clock::Manual(_) => {
                self.advance_us(us);
                std::thread::yield_now();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manual_clock_only_moves_when_advanced() {
        let c = Clock::manual();
        assert!(c.is_manual());
        assert_eq!(c.now_us(), 0);
        c.advance_us(250);
        assert_eq!(c.now_us(), 250);
        c.sleep_us(50);
        assert_eq!(c.now_us(), 300);
    }

    #[test]
    fn wall_clock_moves_forward() {
        let c = Clock::wall();
        assert!(!c.is_manual());
        let t0 = c.now_us();
        c.sleep_us(2_000);
        assert!(c.now_us() >= t0 + 1_000);
        // advance is a no-op on wall clocks
        c.advance_us(1_000_000_000);
        assert!(c.now_us() < 1_000_000_000);
    }
}
