//! Deterministic fault injection for the serving cluster.
//!
//! A [`FaultPlan`] schedules three failure modes against a replica pool,
//! all seeded and reproducible:
//!
//! - **crashes** — the replica worker thread panics at a scheduled engine
//!   step (the supervisor in [`crate::cluster::ReplicaPool`] detects the
//!   dead worker, fails its in-flight requests back to the router, and
//!   respawns the replica);
//! - **stalls** — an injected per-decode-step latency, modelling a hung or
//!   slow decode;
//! - **transient admission failures** — every Nth submit to a replica is
//!   rejected with [`crate::coordinator::RejectReason::Injected`],
//!   exercising the router's retry/backoff path.
//!
//! The plan follows the same gate discipline as the tracer: servers hold
//! an `Option<Arc<FaultPlan>>`, and when it is `None` (the default, i.e.
//! no `--fault-*` flag was given) the entire plane is one branch per site
//! — nothing is counted, scheduled or allocated. The plan outlives the
//! server incarnations it kills: per-replica step counters keep running
//! across respawns, so crashes repeat every `crash_every` steps until the
//! plan is [`FaultPlan::disarm`]ed.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::rng::splitmix64;
use crate::util::json::Json;

/// Which faults a [`FaultPlan`] injects, and where. A field of 0 disables
/// that fault mode; a config with every mode disabled yields no plan at
/// all ([`FaultPlan::new`] returns `None`).
#[derive(Clone, Debug, Default)]
pub struct FaultConfig {
    /// Seed for the deterministic crash-point jitter.
    pub seed: u64,
    /// Crash each replica's worker roughly every N engine steps (the first
    /// crash lands at a seeded point in `[1, N]`, then every N after).
    pub crash_every: u64,
    /// Stall every Nth engine step per replica.
    pub stall_every: u64,
    /// Duration of each injected stall, in milliseconds.
    pub stall_ms: u64,
    /// Reject every Nth submit per replica with a transient
    /// [`crate::coordinator::RejectReason::Injected`] failure.
    pub reject_every: u64,
}

impl FaultConfig {
    /// True when at least one fault mode is enabled.
    pub fn any_active(&self) -> bool {
        self.crash_every > 0 || (self.stall_every > 0 && self.stall_ms > 0) || self.reject_every > 0
    }
}

/// Per-replica fault bookkeeping. Counters are plan-scoped, not
/// server-scoped: they survive replica respawns.
#[derive(Debug)]
struct ReplicaFaults {
    /// Engine steps observed on this replica (across incarnations).
    steps: AtomicU64,
    /// Step number of the next scheduled crash (advances by `crash_every`
    /// after each crash so the respawned worker dies again on schedule).
    next_crash: AtomicU64,
    /// Submits observed on this replica.
    submits: AtomicU64,
    /// Crashes injected into this replica.
    crashes: AtomicU64,
}

/// A seeded, deterministic schedule of injected faults. Shared (via `Arc`)
/// between the CLI/test driver, every server incarnation, and the metrics
/// exporter.
#[derive(Debug)]
pub struct FaultPlan {
    cfg: FaultConfig,
    armed: AtomicBool,
    replicas: Vec<ReplicaFaults>,
    crashes: AtomicU64,
    stalls: AtomicU64,
    injected_rejects: AtomicU64,
}

impl FaultPlan {
    /// Build a plan for `n_replicas`, or `None` when the config enables no
    /// fault mode (so the disabled path stays a bare `Option` check).
    pub fn new(cfg: FaultConfig, n_replicas: usize) -> Option<Arc<FaultPlan>> {
        if !cfg.any_active() {
            return None;
        }
        let replicas = (0..n_replicas.max(1))
            .map(|i| {
                // Seeded per-replica jitter: the first crash lands in
                // [1, crash_every] so short runs still observe crashes.
                let mut s = cfg.seed ^ (i as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15);
                let first = if cfg.crash_every > 0 {
                    1 + splitmix64(&mut s) % cfg.crash_every
                } else {
                    u64::MAX
                };
                ReplicaFaults {
                    steps: AtomicU64::new(0),
                    next_crash: AtomicU64::new(first),
                    submits: AtomicU64::new(0),
                    crashes: AtomicU64::new(0),
                }
            })
            .collect();
        Some(Arc::new(FaultPlan {
            cfg,
            armed: AtomicBool::new(true),
            replicas,
            crashes: AtomicU64::new(0),
            stalls: AtomicU64::new(0),
            injected_rejects: AtomicU64::new(0),
        }))
    }

    /// The config this plan was built from.
    pub fn config(&self) -> &FaultConfig {
        &self.cfg
    }

    /// Stop injecting faults (counters freeze; already-dead replicas still
    /// need supervision). Used by tests to end the chaos phase and verify
    /// the cluster recovers.
    pub fn disarm(&self) {
        self.armed.store(false, Ordering::Release);
    }

    /// True while the plan is still injecting faults.
    pub fn armed(&self) -> bool {
        self.armed.load(Ordering::Acquire)
    }

    /// Called by the server worker loop before each engine step. May sleep
    /// (stall) or panic (crash) according to the schedule; the panic is
    /// the injected fault — the pool supervisor turns it into a restart.
    ///
    /// # Panics
    /// Panics on purpose at scheduled crash points.
    pub fn before_step(&self, replica: usize) {
        if !self.armed() {
            return;
        }
        let Some(st) = self.replicas.get(replica) else { return };
        let step = st.steps.fetch_add(1, Ordering::Relaxed) + 1;
        if self.cfg.stall_every > 0 && self.cfg.stall_ms > 0 && step % self.cfg.stall_every == 0 {
            self.stalls.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(Duration::from_millis(self.cfg.stall_ms));
        }
        if self.cfg.crash_every > 0 && step >= st.next_crash.load(Ordering::Relaxed) {
            st.next_crash.fetch_add(self.cfg.crash_every, Ordering::Relaxed);
            st.crashes.fetch_add(1, Ordering::Relaxed);
            self.crashes.fetch_add(1, Ordering::Relaxed);
            panic!("fault injection: scheduled crash of replica {replica} at engine step {step}");
        }
    }

    /// Called by `ServerClient::submit`: true when this submit should fail
    /// with a transient injected rejection.
    pub fn inject_admission_failure(&self, replica: usize) -> bool {
        if !self.armed() || self.cfg.reject_every == 0 {
            return false;
        }
        let Some(st) = self.replicas.get(replica) else {
            return false;
        };
        let n = st.submits.fetch_add(1, Ordering::Relaxed) + 1;
        if n % self.cfg.reject_every == 0 {
            self.injected_rejects.fetch_add(1, Ordering::Relaxed);
            true
        } else {
            false
        }
    }

    /// Total crashes injected so far.
    pub fn crashes(&self) -> u64 {
        self.crashes.load(Ordering::Relaxed)
    }

    /// Total stalls injected so far.
    pub fn stalls(&self) -> u64 {
        self.stalls.load(Ordering::Relaxed)
    }

    /// Total transient admission failures injected so far.
    pub fn injected_rejects(&self) -> u64 {
        self.injected_rejects.load(Ordering::Relaxed)
    }

    /// JSON block for metrics dumps (`"faults"` in the cluster snapshot).
    pub fn to_json(&self) -> Json {
        let mut o = std::collections::BTreeMap::new();
        o.insert("seed".to_string(), Json::Num(self.cfg.seed as f64));
        o.insert("crash_every".to_string(), Json::Num(self.cfg.crash_every as f64));
        o.insert("stall_every".to_string(), Json::Num(self.cfg.stall_every as f64));
        o.insert("stall_ms".to_string(), Json::Num(self.cfg.stall_ms as f64));
        o.insert("reject_every".to_string(), Json::Num(self.cfg.reject_every as f64));
        o.insert("armed".to_string(), Json::Bool(self.armed()));
        o.insert("crashes".to_string(), Json::Num(self.crashes() as f64));
        o.insert("stalls".to_string(), Json::Num(self.stalls() as f64));
        o.insert("injected_rejects".to_string(), Json::Num(self.injected_rejects() as f64));
        o.insert(
            "crashes_per_replica".to_string(),
            Json::Arr(
                self.replicas
                    .iter()
                    .map(|r| Json::Num(r.crashes.load(Ordering::Relaxed) as f64))
                    .collect(),
            ),
        );
        Json::Obj(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inactive_config_yields_no_plan() {
        assert!(FaultPlan::new(FaultConfig::default(), 4).is_none());
        // stall_every without stall_ms is inert too
        let cfg = FaultConfig { stall_every: 8, ..Default::default() };
        assert!(FaultPlan::new(cfg, 4).is_none());
    }

    #[test]
    fn crash_schedule_is_deterministic_and_repeats() {
        let cfg = FaultConfig { seed: 42, crash_every: 5, ..Default::default() };
        let steps_to_first = |seed| {
            let plan =
                FaultPlan::new(FaultConfig { seed, ..cfg.clone() }, 2).expect("active plan");
            let mut n = 0u64;
            loop {
                n += 1;
                if std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| plan.before_step(0)))
                    .is_err()
                {
                    return (n, plan);
                }
                assert!(n < 100, "crash never fired");
            }
        };
        let (a, plan_a) = steps_to_first(42);
        let (b, _) = steps_to_first(42);
        assert_eq!(a, b, "same seed, same crash point");
        assert!((1..=5).contains(&a), "first crash in [1, crash_every], got {a}");
        assert_eq!(plan_a.crashes(), 1);
        // the next crash on the same plan comes crash_every steps later
        let mut n = 0u64;
        loop {
            n += 1;
            if std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| plan_a.before_step(0)))
                .is_err()
            {
                break;
            }
            assert!(n < 100);
        }
        assert_eq!(n, 5, "second crash exactly crash_every steps after the first");
        assert_eq!(plan_a.crashes(), 2);
    }

    #[test]
    fn injected_rejects_fire_every_nth_submit_per_replica() {
        let cfg = FaultConfig { reject_every: 3, ..Default::default() };
        let plan = FaultPlan::new(cfg, 2).expect("active plan");
        let fired: Vec<bool> = (0..6).map(|_| plan.inject_admission_failure(0)).collect();
        assert_eq!(fired, vec![false, false, true, false, false, true]);
        // replica 1 has its own counter
        assert!(!plan.inject_admission_failure(1));
        assert_eq!(plan.injected_rejects(), 2);
    }

    #[test]
    fn disarm_stops_all_injection() {
        let cfg = FaultConfig { crash_every: 1, reject_every: 1, ..Default::default() };
        let plan = FaultPlan::new(cfg, 1).expect("active plan");
        plan.disarm();
        for _ in 0..10 {
            plan.before_step(0); // would panic if armed
            assert!(!plan.inject_admission_failure(0));
        }
        assert_eq!(plan.crashes() + plan.injected_rejects(), 0);
        let j = plan.to_json();
        assert_eq!(j.get("armed"), Some(&Json::Bool(false)));
        assert_eq!(j.get("crashes").and_then(Json::as_f64), Some(0.0));
    }
}
