//! Trace-driven load generation against a [`Router`].
//!
//! Replays a [`crate::workload::trace`] arrival sequence either at
//! wall-clock rate (sleeping until each arrival's timestamp — the
//! realistic serving measurement) or in *virtual time* (submitting
//! back-to-back — the CI/`--fast` mode, which turns the same trace into
//! a saturation test that finishes in seconds).

use super::router::{Outcome, Router};
use crate::rng::Rng;
use crate::workload::trace::Arrival;
use std::time::{Duration, Instant};

/// How arrival timestamps are honoured during replay.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pacing {
    /// Sleep until each arrival's wall-clock offset.
    WallClock,
    /// Ignore timestamps; submit arrivals back-to-back (virtual time).
    Virtual,
}

/// Replay parameters.
#[derive(Clone, Debug)]
pub struct ReplayConfig {
    /// Wall-clock or virtual-time pacing.
    pub pacing: Pacing,
    /// Vocabulary size prompts are sampled from.
    pub vocab: u32,
    /// Arrivals are assigned round-robin to this many logical sessions
    /// (the `affinity` policy's key space).
    pub n_sessions: usize,
    /// Per-response wait budget during the drain phase.
    pub timeout: Duration,
}

impl Default for ReplayConfig {
    fn default() -> Self {
        ReplayConfig {
            pacing: Pacing::WallClock,
            vocab: 64,
            n_sessions: 8,
            timeout: Duration::from_secs(120),
        }
    }
}

/// Outcome of one trace replay.
#[derive(Clone, Debug)]
pub struct ReplayStats {
    /// Trace arrivals submitted to the router.
    pub submitted: usize,
    /// Arrivals rejected after every replica refused (terminal).
    pub rejected: usize,
    /// Responses received within the drain-phase timeout.
    pub completed: usize,
    /// Arrivals that hit their deadline (router `request_timeout` or the
    /// drain-phase wait cap) before a response — a terminal outcome.
    pub deadline_exceeded: usize,
    /// Legacy alias bucket: always 0 since PR 9 — the router's
    /// deadline/failover machinery guarantees a terminal outcome instead
    /// of an indeterminate timeout. Kept so downstream report schemas
    /// stay stable.
    pub timed_out: usize,
    /// Decode tokens across completed responses.
    pub tokens_generated: usize,
    /// Submission of the first arrival → last awaited response.
    pub elapsed: Duration,
    /// Completed requests per second of replay.
    pub throughput_rps: f64,
    /// Generated tokens per second of replay.
    pub tokens_per_s: f64,
    /// Fraction of arrivals rejected.
    pub reject_rate: f64,
    /// Router-measured end-to-end latency median, in milliseconds.
    pub p50_ms: f64,
    /// Router-measured end-to-end latency 95th percentile, in milliseconds.
    pub p95_ms: f64,
    /// Router-measured end-to-end latency 99th percentile, in milliseconds.
    pub p99_ms: f64,
}

/// Replay `trace` against `router`, wait for every accepted request, and
/// summarise. Rejections are counted (the router only rejects after every
/// replica refused); prompts are seeded from `rng`, so a fixed seed and
/// trace make the workload — though not the timing — deterministic.
pub fn replay(
    router: &Router,
    trace: &[Arrival],
    cfg: &ReplayConfig,
    rng: &mut Rng,
) -> ReplayStats {
    assert!(cfg.vocab >= 2 && cfg.n_sessions >= 1);
    let start = Instant::now();
    let mut pending = Vec::new();
    let mut rejected = 0usize;
    let mut deadline_exceeded = 0usize;
    for (idx, a) in trace.iter().enumerate() {
        if cfg.pacing == Pacing::WallClock {
            let now = start.elapsed();
            if a.at > now {
                std::thread::sleep(a.at - now);
            }
        }
        let prompt: Vec<u32> =
            (0..a.prompt_len).map(|_| rng.below(cfg.vocab as usize) as u32).collect();
        let session = (idx % cfg.n_sessions) as u64;
        match router.submit(prompt, a.max_new, Some(session)) {
            Ok(r) => pending.push(r),
            Err(Outcome::DeadlineExceeded) => deadline_exceeded += 1,
            Err(_) => rejected += 1,
        }
    }
    let mut completed = 0usize;
    let mut tokens = 0usize;
    for r in pending {
        match router.await_outcome(r, cfg.timeout) {
            Outcome::Completed(resp) => {
                completed += 1;
                tokens += resp.tokens.len();
            }
            Outcome::Rejected(_) => rejected += 1,
            Outcome::DeadlineExceeded => deadline_exceeded += 1,
        }
    }
    let elapsed = start.elapsed();
    let secs = elapsed.as_secs_f64().max(1e-9);
    let snap = router.snapshot();
    ReplayStats {
        submitted: trace.len(),
        rejected,
        completed,
        deadline_exceeded,
        timed_out: 0,
        tokens_generated: tokens,
        elapsed,
        throughput_rps: completed as f64 / secs,
        tokens_per_s: tokens as f64 / secs,
        reject_rate: if trace.is_empty() { 0.0 } else { rejected as f64 / trace.len() as f64 },
        p50_ms: snap.p50_ms,
        p95_ms: snap.p95_ms,
        p99_ms: snap.p99_ms,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::pool::ReplicaPool;
    use crate::cluster::router::RouterConfig;
    use crate::coordinator::ServerConfig;
    use crate::kvcache::StreamingLlm;
    use crate::model::{ModelConfig, Transformer};
    use crate::workload::poisson_trace;
    use std::sync::Arc;

    #[test]
    fn virtual_replay_accounts_for_every_arrival() {
        let pool =
            Arc::new(ReplicaPool::spawn(2, ServerConfig::default(), Arc::new(StreamingLlm), |i| {
                let cfg = ModelConfig {
                    vocab: 16,
                    d_model: 16,
                    n_layers: 2,
                    n_heads: 2,
                    d_ff: 32,
                    max_len: 256,
                };
                Transformer::random(cfg, &mut Rng::seed_from(i as u64))
            }));
        let router = Router::new(pool.clone(), RouterConfig::default());
        let mut rng = Rng::seed_from(3);
        let trace = poisson_trace(&mut rng, 40.0, Duration::from_secs(1), 4, 16, 3);
        assert!(!trace.is_empty());
        let cfg = ReplayConfig { pacing: Pacing::Virtual, vocab: 16, ..Default::default() };
        let stats = replay(&router, &trace, &cfg, &mut rng);
        assert_eq!(stats.submitted, trace.len());
        assert_eq!(
            stats.completed + stats.rejected + stats.deadline_exceeded,
            stats.submitted,
            "arrivals must reach exactly one terminal outcome — never lost"
        );
        assert_eq!(stats.timed_out, 0);
        assert!(stats.completed > 0);
        assert!(stats.throughput_rps > 0.0);
        assert!(stats.p50_ms > 0.0 || stats.completed == 0);
        pool.shutdown();
    }
}
