//! The replica pool: N independent [`Server`] workers, each owning its
//! own backend (constructed on its own worker thread — `!Send` backends
//! like PJRT work unchanged) and seeded deterministically from a base
//! seed, so a fixed-seed cluster run is reproducible replica-by-replica.

use crate::coordinator::{Server, ServerClient, ServerConfig, ServerHandle, ServingMetrics};
use crate::kvcache::KvCompressor;
use crate::kvpool::PoolSnapshot;
use crate::model::ModelBackend;
use std::sync::Arc;

/// A pool of identical serving replicas. Owns shutdown; clients go
/// through [`ReplicaPool::clients`] (and usually a
/// [`crate::cluster::Router`] on top).
pub struct ReplicaPool {
    handles: Vec<ServerHandle>,
}

impl ReplicaPool {
    /// Spawn `n_replicas` servers. Replica `i` runs `cfg` with seed
    /// `cfg.seed + i` (independent deterministic streams) and a backend
    /// built by `make_backend(i)` on the replica's worker thread.
    pub fn spawn<B, F>(
        n_replicas: usize,
        cfg: ServerConfig,
        compressor: Arc<dyn KvCompressor>,
        make_backend: F,
    ) -> Self
    where
        B: ModelBackend,
        F: Fn(usize) -> B + Send + Sync + 'static,
    {
        let factory = Arc::new(make_backend);
        let handles = (0..n_replicas.max(1))
            .map(|i| {
                let mut rcfg = cfg.clone();
                rcfg.seed = cfg.seed.wrapping_add(i as u64);
                rcfg.replica = i as u32;
                let f = factory.clone();
                Server::spawn(rcfg, compressor.clone(), move || (*f)(i))
            })
            .collect();
        ReplicaPool { handles }
    }

    /// Number of replicas in the pool.
    pub fn len(&self) -> usize {
        self.handles.len()
    }

    /// Whether the pool holds no replicas (never true after `spawn`).
    pub fn is_empty(&self) -> bool {
        self.handles.is_empty()
    }

    /// One clone-able submit-side client per replica, in replica order.
    pub fn clients(&self) -> Vec<ServerClient> {
        self.handles.iter().map(|h| h.client()).collect()
    }

    /// One replica's serving metrics.
    pub fn metrics(&self, replica: usize) -> &ServingMetrics {
        self.handles[replica].metrics()
    }

    /// Per-replica KV pool gauges, in replica order. Every replica owns
    /// a *private* pool sized by `ServerConfig::pool` (prefix sharing is
    /// within-replica; cross-replica dedup is a ROADMAP follow-up).
    pub fn pool_snapshots(&self) -> Vec<PoolSnapshot> {
        self.handles.iter().map(|h| h.client().pool_snapshot()).collect()
    }

    /// Graceful shutdown: each replica stops admissions, finishes its
    /// in-flight work, and joins.
    pub fn shutdown(self) {
        for h in self.handles {
            h.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::StreamingLlm;
    use crate::model::{ModelConfig, Transformer};
    use crate::rng::Rng;
    use std::time::Duration;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig { vocab: 16, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 32, max_len: 256 }
    }

    #[test]
    fn replicas_serve_independently() {
        let pool = ReplicaPool::spawn(3, ServerConfig::default(), Arc::new(StreamingLlm), |i| {
            Transformer::random(tiny_cfg(), &mut Rng::seed_from(100 + i as u64))
        });
        assert_eq!(pool.len(), 3);
        let clients = pool.clients();
        let mut rxs = Vec::new();
        for (i, c) in clients.iter().enumerate() {
            let (_, rx) = c.submit(vec![1, 2, 3, (i % 16) as u32], 2).unwrap();
            rxs.push(rx);
        }
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert_eq!(resp.tokens.len(), 2);
        }
        for i in 0..3 {
            assert_eq!(pool.metrics(i).counters().completed, 1);
        }
        // each replica served from its own private KV pool
        let snaps = pool.pool_snapshots();
        assert_eq!(snaps.len(), 3);
        for s in &snaps {
            assert!(s.peak_bytes() > 0, "replica pool never held KV state");
            assert_eq!(s.sequences, 0, "sequences must be retired after completion");
        }
        pool.shutdown();
    }

    #[test]
    fn zero_replicas_clamps_to_one() {
        let pool = ReplicaPool::spawn(0, ServerConfig::default(), Arc::new(StreamingLlm), |_| {
            Transformer::random(tiny_cfg(), &mut Rng::seed_from(1))
        });
        assert_eq!(pool.len(), 1);
        pool.shutdown();
    }
}
