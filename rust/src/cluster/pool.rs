//! The replica pool: N independent [`Server`] workers, each owning its
//! own backend (constructed on its own worker thread — `!Send` backends
//! like PJRT work unchanged) and seeded deterministically from a base
//! seed, so a fixed-seed cluster run is reproducible replica-by-replica.
//!
//! Since PR 9 the pool is also a **supervisor**: it keeps the spawn
//! recipe for every replica, detects a dead/panicked worker
//! ([`crate::coordinator::ServerHandle::worker_died`]), fails that
//! replica's in-flight requests back to the router (their response
//! channels disconnect, which the router turns into failovers), and
//! respawns the replica with its original deterministic seed and a fresh
//! KV pool. Restarts are counted per replica and traced as
//! [`crate::obs::trace::SpanKind::Restart`] spans.

use super::clock::Clock;
use crate::coordinator::{Server, ServerClient, ServerConfig, ServerHandle, ServingMetrics};
use crate::kvcache::KvCompressor;
use crate::kvpool::PoolSnapshot;
use crate::model::ModelBackend;
use crate::obs::trace::{self, SpanKind, NO_REQ};
use crate::util::sync::lock_recover;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Each restart incarnation gets its own request-id range so a respawned
/// replica never reuses ids from its previous life (waiter keys and trace
/// lanes stay unique; well below the 2^32 packing limit of the Chrome
/// exporter's router lanes).
const ID_EPOCH: u64 = 10_000_000;

/// One supervised replica slot. The cached client stays valid after its
/// server dies (submits then fail with `ShuttingDown`), so routing never
/// observes a torn slot.
struct Slot {
    /// `None` only after [`ReplicaPool::shutdown`].
    handle: Option<ServerHandle>,
    client: ServerClient,
    restarts: u64,
}

/// A pool of identical serving replicas with crash supervision. Owns
/// shutdown; clients go through [`ReplicaPool::client`] (and usually a
/// [`crate::cluster::Router`] on top).
pub struct ReplicaPool {
    slots: Vec<Mutex<Slot>>,
    respawn: Box<dyn Fn(usize, u64) -> ServerHandle + Send + Sync>,
    restarts_total: AtomicU64,
}

impl ReplicaPool {
    /// Spawn `n_replicas` servers. Replica `i` runs `cfg` with seed
    /// `cfg.seed + i` (independent deterministic streams) and a backend
    /// built by `make_backend(i)` on the replica's worker thread. The
    /// same recipe is kept for respawning crashed replicas.
    pub fn spawn<B, F>(
        n_replicas: usize,
        cfg: ServerConfig,
        compressor: Arc<dyn KvCompressor>,
        make_backend: F,
    ) -> Self
    where
        B: ModelBackend,
        F: Fn(usize) -> B + Send + Sync + 'static,
    {
        let factory = Arc::new(make_backend);
        let base = cfg.clone();
        let respawn = Box::new(move |i: usize, incarnation: u64| {
            let mut rcfg = base.clone();
            rcfg.seed = base.seed.wrapping_add(i as u64);
            rcfg.replica = i as u32;
            rcfg.first_request_id = 1 + incarnation * ID_EPOCH;
            let f = factory.clone();
            Server::spawn(rcfg, compressor.clone(), move || (*f)(i))
        });
        let slots = (0..n_replicas.max(1))
            .map(|i| {
                let h = respawn(i, 0);
                Mutex::new(Slot { client: h.client(), handle: Some(h), restarts: 0 })
            })
            .collect();
        ReplicaPool { slots, respawn, restarts_total: AtomicU64::new(0) }
    }

    /// Number of replicas in the pool.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// Whether the pool holds no replicas (never true after `spawn`).
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// The current submit-side client of one replica. Fetched per use —
    /// never cache it across calls, a respawn replaces it.
    pub fn client(&self, replica: usize) -> ServerClient {
        lock_recover(&self.slots[replica]).client.clone()
    }

    /// One clone-able submit-side client per replica, in replica order.
    /// Snapshot of the *current* incarnations; prefer
    /// [`ReplicaPool::client`] per submission under supervision.
    pub fn clients(&self) -> Vec<ServerClient> {
        (0..self.len()).map(|i| self.client(i)).collect()
    }

    /// One replica's serving metrics (current incarnation — a respawn
    /// starts fresh; cumulative truth lives in the router's
    /// [`crate::cluster::ClusterMetrics`]).
    pub fn metrics(&self, replica: usize) -> Arc<ServingMetrics> {
        lock_recover(&self.slots[replica]).client.metrics_arc()
    }

    /// Per-replica KV pool gauges, in replica order. Every replica owns
    /// a *private* pool sized by `ServerConfig::pool` (prefix sharing is
    /// within-replica; cross-replica dedup is a ROADMAP follow-up).
    pub fn pool_snapshots(&self) -> Vec<PoolSnapshot> {
        (0..self.len()).map(|i| self.client(i).pool_snapshot()).collect()
    }

    /// True when the replica's worker thread has panicked (and the slot
    /// has not been respawned yet).
    pub fn worker_died(&self, replica: usize) -> bool {
        lock_recover(&self.slots[replica])
            .handle
            .as_ref()
            .is_some_and(ServerHandle::worker_died)
    }

    /// Supervision step for one replica: if its worker died, fail all
    /// in-flight requests back to their waiters (the router observes
    /// disconnects and fails them over) and respawn the replica with its
    /// original seed and a fresh KV pool. Returns `true` when a restart
    /// happened. Safe to call concurrently — the slot lock serializes,
    /// and losers see a healthy respawned worker.
    pub fn restart_if_dead(&self, replica: usize) -> bool {
        let mut slot = lock_recover(&self.slots[replica]);
        let died = slot.handle.as_ref().is_some_and(ServerHandle::worker_died);
        if !died {
            return false;
        }
        let t0 = Instant::now();
        let old = slot.handle.take();
        // fail in-flight work first: dropping the senders disconnects the
        // waiters, which the router counts as failovers
        let failed_over = slot.client.fail_pending();
        drop(old); // joins the panicked thread (Drop tolerates the panic)
        slot.restarts += 1;
        let incarnation = slot.restarts;
        let h = (self.respawn)(replica, incarnation);
        slot.client = h.client();
        slot.handle = Some(h);
        self.restarts_total.fetch_add(1, Ordering::Relaxed);
        if trace::enabled() {
            trace::span_on(
                replica as u32,
                SpanKind::Restart,
                t0,
                Instant::now(),
                NO_REQ,
                incarnation,
                failed_over as u64,
            );
        }
        true
    }

    /// Run [`ReplicaPool::restart_if_dead`] across every replica;
    /// returns how many were restarted.
    pub fn supervise(&self) -> usize {
        (0..self.len()).filter(|&i| self.restart_if_dead(i)).count()
    }

    /// Times this replica has been respawned after a crash.
    pub fn restarts(&self, replica: usize) -> u64 {
        lock_recover(&self.slots[replica]).restarts
    }

    /// Total replica restarts across the pool.
    pub fn restarts_total(&self) -> u64 {
        self.restarts_total.load(Ordering::Relaxed)
    }

    /// Graceful shutdown: each replica stops admissions, finishes its
    /// in-flight work, and joins. Idempotent; slots stay readable (their
    /// cached clients answer `ShuttingDown`).
    pub fn shutdown(&self) {
        for slot in &self.slots {
            let handle = lock_recover(slot).handle.take();
            if let Some(h) = handle {
                h.shutdown();
            }
        }
    }
}

/// How often the supervisor thread polls for a crossed tick boundary.
/// Short enough that a crashed replica is respawned within about a
/// millisecond of the tick, long enough that an idle supervisor costs
/// nothing measurable.
const SUPERVISOR_SLICE_US: u64 = 500;

/// A dedicated supervision thread: ticks [`ReplicaPool::supervise`] once
/// per `interval` of *clock* time, so crashed replicas are respawned even
/// when no request traffic reaches them (the router only supervises the
/// replicas it happens to touch). Driven by a [`Clock`] — under a manual
/// clock, ticks fire as the test (or the virtual-time replay driver)
/// advances time, which keeps supervision deterministic in chaos tests.
///
/// Stopped and joined by [`Supervisor::stop`] (or drop).
pub struct Supervisor {
    stop: Arc<AtomicBool>,
    ticks: Arc<AtomicU64>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl Supervisor {
    /// Spawn the supervision thread over `pool`, ticking once per
    /// `interval` of `clock` time.
    pub fn start(pool: Arc<ReplicaPool>, clock: Arc<Clock>, interval: Duration) -> Supervisor {
        let stop = Arc::new(AtomicBool::new(false));
        let ticks = Arc::new(AtomicU64::new(0));
        let interval_us = (interval.as_micros() as u64).max(1);
        let worker = {
            let stop = stop.clone();
            let ticks = ticks.clone();
            std::thread::Builder::new()
                .name("wildcat-supervisor".into())
                .spawn(move || {
                    let mut next = clock.now_us().saturating_add(interval_us);
                    while !stop.load(Ordering::Relaxed) {
                        if clock.now_us() >= next {
                            pool.supervise();
                            ticks.fetch_add(1, Ordering::Relaxed);
                            next = clock.now_us().saturating_add(interval_us);
                        } else {
                            // Poll in short wall-time slices rather than
                            // `clock.sleep_us`: on a manual clock a sleep
                            // *advances* virtual time, and time is owned
                            // by the replay driver — the supervisor must
                            // only ever observe it.
                            std::thread::sleep(Duration::from_micros(SUPERVISOR_SLICE_US));
                        }
                    }
                })
                .expect("spawning the supervisor thread")
        };
        Supervisor { stop, ticks, worker: Some(worker) }
    }

    /// Completed supervision ticks so far.
    pub fn ticks(&self) -> u64 {
        self.ticks.load(Ordering::Relaxed)
    }

    /// Stop and join the supervision thread.
    pub fn stop(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Supervisor {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::fault::{FaultConfig, FaultPlan};
    use crate::kvcache::StreamingLlm;
    use crate::model::{ModelConfig, Transformer};
    use crate::rng::Rng;
    use std::time::Duration;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig { vocab: 16, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 32, max_len: 256 }
    }

    #[test]
    fn replicas_serve_independently() {
        let pool = ReplicaPool::spawn(3, ServerConfig::default(), Arc::new(StreamingLlm), |i| {
            Transformer::random(tiny_cfg(), &mut Rng::seed_from(100 + i as u64))
        });
        assert_eq!(pool.len(), 3);
        let clients = pool.clients();
        let mut rxs = Vec::new();
        for (i, c) in clients.iter().enumerate() {
            let (_, rx) = c.submit(vec![1, 2, 3, (i % 16) as u32], 2).unwrap();
            rxs.push(rx);
        }
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert_eq!(resp.tokens.len(), 2);
        }
        for i in 0..3 {
            assert_eq!(pool.metrics(i).counters().completed, 1);
        }
        // each replica served from its own private KV pool
        let snaps = pool.pool_snapshots();
        assert_eq!(snaps.len(), 3);
        for s in &snaps {
            assert!(s.peak_bytes() > 0, "replica pool never held KV state");
            assert_eq!(s.sequences, 0, "sequences must be retired after completion");
        }
        pool.shutdown();
    }

    #[test]
    fn zero_replicas_clamps_to_one() {
        let pool = ReplicaPool::spawn(0, ServerConfig::default(), Arc::new(StreamingLlm), |_| {
            Transformer::random(tiny_cfg(), &mut Rng::seed_from(1))
        });
        assert_eq!(pool.len(), 1);
        pool.shutdown();
    }

    #[test]
    fn crashed_replica_is_respawned_and_serves_again() {
        // crash replica 0 on its very first engine step
        let plan = FaultPlan::new(FaultConfig { seed: 9, crash_every: 1, ..Default::default() }, 1)
            .expect("active plan");
        let cfg = ServerConfig { faults: Some(plan.clone()), ..Default::default() };
        let pool = ReplicaPool::spawn(1, cfg, Arc::new(StreamingLlm), |_| {
            Transformer::random(tiny_cfg(), &mut Rng::seed_from(7))
        });
        let (_, rx) = pool.client(0).submit(vec![1, 2, 3], 2).unwrap();
        // wait for the injected crash to kill the worker
        let mut died = false;
        for _ in 0..1000 {
            if pool.worker_died(0) {
                died = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(died, "injected crash never killed the worker");
        plan.disarm();
        assert!(pool.restart_if_dead(0), "supervisor must restart the dead replica");
        assert!(!pool.restart_if_dead(0), "respawned worker is healthy");
        assert_eq!(pool.restarts(0), 1);
        assert_eq!(pool.restarts_total(), 1);
        // the in-flight request was failed back (sender dropped)
        assert!(matches!(
            rx.recv_timeout(Duration::from_secs(5)),
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected)
        ));
        // and the fresh incarnation serves; ids come from a new epoch
        let (id, rx2) = pool.client(0).submit(vec![4, 5, 6], 2).unwrap();
        assert!(id >= super::ID_EPOCH, "respawn must not reuse the old id space");
        let resp = rx2.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(resp.tokens.len(), 2);
        pool.shutdown();
    }

    #[test]
    fn supervisor_thread_respawns_crashed_replica_on_clock_tick() {
        // crash replica 0 on its first engine step; nobody calls
        // supervise() by hand — the dedicated thread must catch it
        let plan = FaultPlan::new(FaultConfig { seed: 9, crash_every: 1, ..Default::default() }, 1)
            .expect("active plan");
        let cfg = ServerConfig { faults: Some(plan.clone()), ..Default::default() };
        let pool = Arc::new(ReplicaPool::spawn(1, cfg, Arc::new(StreamingLlm), |_| {
            Transformer::random(tiny_cfg(), &mut Rng::seed_from(7))
        }));
        let clock = Clock::manual();
        let sup = Supervisor::start(pool.clone(), clock.clone(), Duration::from_millis(1));
        let (_, _rx) = pool.client(0).submit(vec![1, 2, 3], 2).unwrap();
        let mut died = false;
        for _ in 0..1000 {
            if pool.worker_died(0) {
                died = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(died, "injected crash never killed the worker");
        plan.disarm();
        // virtual time has not moved: the supervisor must not have ticked
        assert_eq!(pool.restarts_total(), 0, "supervisor ticked before its interval elapsed");
        // cross one tick boundary and give the thread wall time to see it
        clock.advance_us(1_500);
        let mut restarted = false;
        for _ in 0..1000 {
            if pool.restarts_total() == 1 {
                restarted = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(restarted, "supervisor thread never respawned the dead replica");
        assert!(sup.ticks() >= 1);
        sup.stop();
        // the respawned incarnation serves
        let (_, rx) = pool.client(0).submit(vec![4, 5], 1).unwrap();
        assert_eq!(rx.recv_timeout(Duration::from_secs(30)).unwrap().tokens.len(), 1);
        pool.shutdown();
    }
}
