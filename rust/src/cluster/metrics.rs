//! Cluster-level serving metrics: routing counters plus end-to-end
//! latency measured at the router (submission → response receipt), the
//! number a client of the whole cluster actually experiences. Per-replica
//! [`crate::coordinator::ServingMetrics`] snapshots are aggregated next
//! to it in one JSON document by [`crate::cluster::Router::metrics_json`].

use crate::coordinator::admission::RejectReason;
use crate::util::json::Json;
use crate::util::stats::LogHistogram;
use crate::util::sync::lock_recover;
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Plain-number snapshot for benches and tests.
#[derive(Clone, Debug)]
pub struct ClusterSnapshot {
    /// Requests submitted to the router. Terminal-outcome invariant:
    /// `completed + rejected + deadline_exceeded == requests` once every
    /// submission has been driven to its outcome.
    pub requests: u64,
    /// Requests accepted by some replica (failover resubmissions land
    /// here again, so `routed` can exceed `requests` under faults).
    pub routed: u64,
    /// Requests rejected by *every* replica (surface to the caller).
    pub rejected: u64,
    /// Extra submission attempts after a replica refused (re-routes).
    pub rerouted: u64,
    /// Responses received by awaiting callers.
    pub completed: u64,
    /// Requests whose deadline expired before a response (terminal).
    pub deadline_exceeded: u64,
    /// In-flight requests failed over off a dead replica and resubmitted.
    pub failovers: u64,
    /// Full-cluster retry rounds after every replica refused.
    pub retries: u64,
    /// Replica workers respawned after a crash — filled in by
    /// [`crate::cluster::Router::snapshot`] from the pool supervisor; 0
    /// for a bare `ClusterMetrics` snapshot.
    pub restarts: u64,
    /// Cluster-wide rejections keyed by [`RejectReason::name`].
    pub rejected_by_reason: BTreeMap<&'static str, u64>,
    /// Decode tokens across completed responses.
    pub tokens_generated: u64,
    /// Cluster end-to-end latency median, in milliseconds.
    pub p50_ms: f64,
    /// Cluster end-to-end latency 95th percentile, in milliseconds.
    pub p95_ms: f64,
    /// Cluster end-to-end latency 99th percentile, in milliseconds.
    pub p99_ms: f64,
    /// KV pool bytes summed over the replicas' (disjoint) pools —
    /// filled in by [`crate::cluster::Router::snapshot`], which can see
    /// the per-replica clients; 0 for a bare `ClusterMetrics` snapshot.
    pub kv_bytes_used: usize,
    /// Peak KV pool bytes summed over replicas (same provenance as
    /// `kv_bytes_used`).
    pub kv_bytes_peak: usize,
    /// Prompt tokens actually computed at prefill, summed over replicas —
    /// filled in by [`crate::cluster::Router::snapshot`] from the
    /// per-replica serving counters; 0 for a bare `ClusterMetrics`
    /// snapshot.
    pub prefill_tokens_computed: u64,
    /// Prompt tokens skipped via KV-pool prefix hits, summed over
    /// replicas (see `prefill_tokens_computed` for provenance).
    pub prefill_tokens_skipped: u64,
    /// Admissions that resumed from a prefix hit, summed over replicas
    /// (request-level counterpart of the token counters; same
    /// provenance as `prefill_tokens_computed`).
    pub prefix_hits: u64,
    /// Admissions that prefilled cold, summed over replicas (same
    /// provenance as `prefill_tokens_computed`).
    pub prefix_misses: u64,
    /// Approximation-quality audit samples (decode steps + compression
    /// folds) summed over replicas — filled in by
    /// [`crate::cluster::Router::snapshot`] from the per-replica quality
    /// auditors; 0 for a bare `ClusterMetrics` snapshot and when
    /// auditing is disabled (`--audit-rate 0`).
    pub quality_audited_samples: u64,
    /// Error-SLO degradation transitions summed over replicas (same
    /// provenance as `quality_audited_samples`).
    pub quality_slo_degradations: u64,
    /// Replicas currently in the degraded state (same provenance as
    /// `quality_audited_samples`).
    pub quality_degraded_replicas: u64,
}

impl ClusterSnapshot {
    /// Total submission attempts (routed + rejected).
    pub fn submitted(&self) -> u64 {
        self.routed + self.rejected
    }

    /// Requests that reached a terminal outcome so far. Equals
    /// `requests` once every submission has been driven to completion,
    /// under any fault schedule.
    pub fn terminal(&self) -> u64 {
        self.completed + self.rejected + self.deadline_exceeded
    }

    /// Fraction of submissions rejected cluster-wide.
    pub fn reject_rate(&self) -> f64 {
        if self.submitted() == 0 {
            0.0
        } else {
            self.rejected as f64 / self.submitted() as f64
        }
    }
}

struct Inner {
    requests: u64,
    routed_per_replica: Vec<u64>,
    rerouted: u64,
    rejected: u64,
    rejected_by_reason: BTreeMap<&'static str, u64>,
    deadline_exceeded: u64,
    failovers: u64,
    retries: u64,
    completed: u64,
    tokens_generated: u64,
    e2e_us: LogHistogram,
    started: Instant,
}

/// Thread-safe cluster metrics sink (same locking story as
/// `ServingMetrics`: recording is ns-scale against ms-scale model steps).
pub struct ClusterMetrics {
    inner: Mutex<Inner>,
}

impl ClusterMetrics {
    /// A fresh sink tracking `n_replicas` routing targets, started now.
    pub fn new(n_replicas: usize) -> Self {
        ClusterMetrics {
            inner: Mutex::new(Inner {
                requests: 0,
                routed_per_replica: vec![0; n_replicas],
                rerouted: 0,
                rejected: 0,
                rejected_by_reason: BTreeMap::new(),
                deadline_exceeded: 0,
                failovers: 0,
                retries: 0,
                completed: 0,
                tokens_generated: 0,
                e2e_us: LogHistogram::latency_us(),
                started: Instant::now(),
            }),
        }
    }

    /// Record a request entering the router (before routing).
    pub fn on_request(&self) {
        lock_recover(&self.inner).requests += 1;
    }

    /// Record an accepted submission landing on `replica`.
    pub fn on_routed(&self, replica: usize) {
        lock_recover(&self.inner).routed_per_replica[replica] += 1;
    }

    /// Record a re-route attempt on another replica after a refusal.
    pub fn on_reroute(&self) {
        lock_recover(&self.inner).rerouted += 1;
    }

    /// Record a full-cluster retry round (every replica refused once;
    /// the router backs off and sweeps them again).
    pub fn on_retry(&self) {
        lock_recover(&self.inner).retries += 1;
    }

    /// Record an in-flight request failed over off a dead replica.
    pub fn on_failover(&self) {
        lock_recover(&self.inner).failovers += 1;
    }

    /// Record a terminal cluster-wide rejection, keyed by reason.
    pub fn on_reject(&self, reason: RejectReason) {
        let mut g = lock_recover(&self.inner);
        g.rejected += 1;
        *g.rejected_by_reason.entry(reason.name()).or_insert(0) += 1;
    }

    /// Record a terminal deadline expiry.
    pub fn on_deadline_exceeded(&self) {
        lock_recover(&self.inner).deadline_exceeded += 1;
    }

    /// Record a response receipt with its end-to-end latency.
    pub fn on_complete(&self, e2e: Duration, tokens: usize) {
        let mut g = lock_recover(&self.inner);
        g.completed += 1;
        g.tokens_generated += tokens as u64;
        g.e2e_us.record(e2e.as_secs_f64() * 1e6);
    }

    /// Requests routed to one replica so far.
    pub fn routed_to(&self, replica: usize) -> u64 {
        lock_recover(&self.inner).routed_per_replica[replica]
    }

    /// Plain-number snapshot of the router-side counters. The KV and
    /// prefill-skipping fields are zero here — [`crate::cluster::Router::snapshot`]
    /// fills them from the per-replica clients.
    pub fn snapshot(&self) -> ClusterSnapshot {
        let g = lock_recover(&self.inner);
        ClusterSnapshot {
            requests: g.requests,
            routed: g.routed_per_replica.iter().sum(),
            rejected: g.rejected,
            rerouted: g.rerouted,
            completed: g.completed,
            deadline_exceeded: g.deadline_exceeded,
            failovers: g.failovers,
            retries: g.retries,
            restarts: 0,
            rejected_by_reason: g.rejected_by_reason.clone(),
            tokens_generated: g.tokens_generated,
            p50_ms: g.e2e_us.quantile(0.5) / 1e3,
            p95_ms: g.e2e_us.quantile(0.95) / 1e3,
            p99_ms: g.e2e_us.quantile(0.99) / 1e3,
            kv_bytes_used: 0,
            kv_bytes_peak: 0,
            prefill_tokens_computed: 0,
            prefill_tokens_skipped: 0,
            prefix_hits: 0,
            prefix_misses: 0,
            quality_audited_samples: 0,
            quality_slo_degradations: 0,
            quality_degraded_replicas: 0,
        }
    }

    /// The aggregate block of the cluster JSON snapshot.
    pub fn to_json(&self) -> Json {
        let g = lock_recover(&self.inner);
        let num = |x: f64| Json::Num(if x.is_finite() { x } else { 0.0 });
        let routed: u64 = g.routed_per_replica.iter().sum();
        let submitted = routed + g.rejected;
        let mut o = BTreeMap::new();
        o.insert("requests".to_string(), Json::Num(g.requests as f64));
        o.insert("submitted".to_string(), Json::Num(submitted as f64));
        o.insert("routed".to_string(), Json::Num(routed as f64));
        o.insert("rejected".to_string(), Json::Num(g.rejected as f64));
        o.insert(
            "rejected_by_reason".to_string(),
            Json::Obj(
                g.rejected_by_reason
                    .iter()
                    .map(|(k, v)| (k.to_string(), Json::Num(*v as f64)))
                    .collect(),
            ),
        );
        o.insert("deadline_exceeded".to_string(), Json::Num(g.deadline_exceeded as f64));
        o.insert("failovers".to_string(), Json::Num(g.failovers as f64));
        o.insert("retries".to_string(), Json::Num(g.retries as f64));
        o.insert("rerouted".to_string(), Json::Num(g.rerouted as f64));
        o.insert("completed".to_string(), Json::Num(g.completed as f64));
        o.insert("tokens_generated".to_string(), Json::Num(g.tokens_generated as f64));
        o.insert(
            "reject_rate".to_string(),
            num(if submitted == 0 { 0.0 } else { g.rejected as f64 / submitted as f64 }),
        );
        o.insert("e2e_ms_p50".to_string(), num(g.e2e_us.quantile(0.5) / 1e3));
        o.insert("e2e_ms_p95".to_string(), num(g.e2e_us.quantile(0.95) / 1e3));
        o.insert("e2e_ms_p99".to_string(), num(g.e2e_us.quantile(0.99) / 1e3));
        o.insert("uptime_s".to_string(), num(g.started.elapsed().as_secs_f64()));
        Json::Obj(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_snapshot() {
        let m = ClusterMetrics::new(2);
        for _ in 0..4 {
            m.on_request();
        }
        m.on_routed(0);
        m.on_routed(1);
        m.on_routed(1);
        m.on_reroute();
        m.on_retry();
        m.on_failover();
        m.on_reject(RejectReason::QueueFull);
        m.on_complete(Duration::from_millis(12), 4);
        m.on_complete(Duration::from_millis(30), 2);
        m.on_deadline_exceeded();
        let s = m.snapshot();
        assert_eq!(s.requests, 4);
        assert_eq!(s.routed, 3);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.rejected_by_reason.get("queue_full"), Some(&1));
        assert_eq!(s.rerouted, 1);
        assert_eq!(s.retries, 1);
        assert_eq!(s.failovers, 1);
        assert_eq!(s.completed, 2);
        assert_eq!(s.deadline_exceeded, 1);
        assert_eq!(s.terminal(), 4, "every request reached one terminal outcome");
        assert_eq!(s.tokens_generated, 6);
        assert_eq!(s.submitted(), 4);
        assert!((s.reject_rate() - 0.25).abs() < 1e-12);
        assert!(s.p50_ms > 0.0 && s.p99_ms >= s.p50_ms);
        assert_eq!(m.routed_to(1), 2);
    }

    #[test]
    fn json_parses_and_is_finite() {
        let m = ClusterMetrics::new(1);
        // empty metrics must still serialise with finite fields
        let j0 = m.to_json();
        assert_eq!(j0.get("completed").and_then(Json::as_f64), Some(0.0));
        assert_eq!(j0.get("deadline_exceeded").and_then(Json::as_f64), Some(0.0));
        m.on_request();
        m.on_routed(0);
        m.on_complete(Duration::from_millis(5), 3);
        m.on_reject(RejectReason::Injected);
        let j = m.to_json();
        let text = j.to_string_compact();
        assert_eq!(crate::util::json::parse(&text).unwrap(), j);
        assert_eq!(j.get("requests").and_then(Json::as_f64), Some(1.0));
        assert_eq!(j.get("routed").and_then(Json::as_f64), Some(1.0));
        assert_eq!(
            j.get("rejected_by_reason").and_then(|r| r.get("injected")).and_then(Json::as_f64),
            Some(1.0)
        );
        assert!(j.get("e2e_ms_p50").and_then(Json::as_f64).unwrap() > 0.0);
    }
}
