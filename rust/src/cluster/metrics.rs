//! Cluster-level serving metrics: routing counters plus end-to-end
//! latency measured at the router (submission → response receipt), the
//! number a client of the whole cluster actually experiences. Per-replica
//! [`crate::coordinator::ServingMetrics`] snapshots are aggregated next
//! to it in one JSON document by [`crate::cluster::Router::metrics_json`].

use crate::util::json::Json;
use crate::util::stats::LogHistogram;
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Plain-number snapshot for benches and tests.
#[derive(Clone, Debug)]
pub struct ClusterSnapshot {
    /// Requests accepted by some replica.
    pub routed: u64,
    /// Requests rejected by *every* replica (surface to the caller).
    pub rejected: u64,
    /// Extra submission attempts after a replica refused (re-routes).
    pub rerouted: u64,
    /// Responses received by awaiting callers.
    pub completed: u64,
    /// Decode tokens across completed responses.
    pub tokens_generated: u64,
    /// Cluster end-to-end latency median, in milliseconds.
    pub p50_ms: f64,
    /// Cluster end-to-end latency 95th percentile, in milliseconds.
    pub p95_ms: f64,
    /// Cluster end-to-end latency 99th percentile, in milliseconds.
    pub p99_ms: f64,
    /// KV pool bytes summed over the replicas' (disjoint) pools —
    /// filled in by [`crate::cluster::Router::snapshot`], which can see
    /// the per-replica clients; 0 for a bare `ClusterMetrics` snapshot.
    pub kv_bytes_used: usize,
    /// Peak KV pool bytes summed over replicas (same provenance as
    /// `kv_bytes_used`).
    pub kv_bytes_peak: usize,
    /// Prompt tokens actually computed at prefill, summed over replicas —
    /// filled in by [`crate::cluster::Router::snapshot`] from the
    /// per-replica serving counters; 0 for a bare `ClusterMetrics`
    /// snapshot.
    pub prefill_tokens_computed: u64,
    /// Prompt tokens skipped via KV-pool prefix hits, summed over
    /// replicas (see `prefill_tokens_computed` for provenance).
    pub prefill_tokens_skipped: u64,
    /// Admissions that resumed from a prefix hit, summed over replicas
    /// (request-level counterpart of the token counters; same
    /// provenance as `prefill_tokens_computed`).
    pub prefix_hits: u64,
    /// Admissions that prefilled cold, summed over replicas (same
    /// provenance as `prefill_tokens_computed`).
    pub prefix_misses: u64,
    /// Approximation-quality audit samples (decode steps + compression
    /// folds) summed over replicas — filled in by
    /// [`crate::cluster::Router::snapshot`] from the per-replica quality
    /// auditors; 0 for a bare `ClusterMetrics` snapshot and when
    /// auditing is disabled (`--audit-rate 0`).
    pub quality_audited_samples: u64,
    /// Error-SLO degradation transitions summed over replicas (same
    /// provenance as `quality_audited_samples`).
    pub quality_slo_degradations: u64,
    /// Replicas currently in the degraded state (same provenance as
    /// `quality_audited_samples`).
    pub quality_degraded_replicas: u64,
}

impl ClusterSnapshot {
    /// Total submission attempts (routed + rejected).
    pub fn submitted(&self) -> u64 {
        self.routed + self.rejected
    }

    /// Fraction of submissions rejected cluster-wide.
    pub fn reject_rate(&self) -> f64 {
        if self.submitted() == 0 {
            0.0
        } else {
            self.rejected as f64 / self.submitted() as f64
        }
    }
}

struct Inner {
    routed_per_replica: Vec<u64>,
    rerouted: u64,
    rejected: u64,
    completed: u64,
    tokens_generated: u64,
    e2e_us: LogHistogram,
    started: Instant,
}

/// Thread-safe cluster metrics sink (same locking story as
/// `ServingMetrics`: recording is ns-scale against ms-scale model steps).
pub struct ClusterMetrics {
    inner: Mutex<Inner>,
}

impl ClusterMetrics {
    /// A fresh sink tracking `n_replicas` routing targets, started now.
    pub fn new(n_replicas: usize) -> Self {
        ClusterMetrics {
            inner: Mutex::new(Inner {
                routed_per_replica: vec![0; n_replicas],
                rerouted: 0,
                rejected: 0,
                completed: 0,
                tokens_generated: 0,
                e2e_us: LogHistogram::latency_us(),
                started: Instant::now(),
            }),
        }
    }

    /// Record an accepted submission landing on `replica`.
    pub fn on_routed(&self, replica: usize) {
        self.inner.lock().unwrap().routed_per_replica[replica] += 1;
    }

    /// Record a retry on another replica after a refusal.
    pub fn on_reroute(&self) {
        self.inner.lock().unwrap().rerouted += 1;
    }

    /// Record a cluster-wide rejection (every replica refused).
    pub fn on_reject(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    /// Record a response receipt with its end-to-end latency.
    pub fn on_complete(&self, e2e: Duration, tokens: usize) {
        let mut g = self.inner.lock().unwrap();
        g.completed += 1;
        g.tokens_generated += tokens as u64;
        g.e2e_us.record(e2e.as_secs_f64() * 1e6);
    }

    /// Requests routed to one replica so far.
    pub fn routed_to(&self, replica: usize) -> u64 {
        self.inner.lock().unwrap().routed_per_replica[replica]
    }

    /// Plain-number snapshot of the router-side counters. The KV and
    /// prefill-skipping fields are zero here — [`crate::cluster::Router::snapshot`]
    /// fills them from the per-replica clients.
    pub fn snapshot(&self) -> ClusterSnapshot {
        let g = self.inner.lock().unwrap();
        ClusterSnapshot {
            routed: g.routed_per_replica.iter().sum(),
            rejected: g.rejected,
            rerouted: g.rerouted,
            completed: g.completed,
            tokens_generated: g.tokens_generated,
            p50_ms: g.e2e_us.quantile(0.5) / 1e3,
            p95_ms: g.e2e_us.quantile(0.95) / 1e3,
            p99_ms: g.e2e_us.quantile(0.99) / 1e3,
            kv_bytes_used: 0,
            kv_bytes_peak: 0,
            prefill_tokens_computed: 0,
            prefill_tokens_skipped: 0,
            prefix_hits: 0,
            prefix_misses: 0,
            quality_audited_samples: 0,
            quality_slo_degradations: 0,
            quality_degraded_replicas: 0,
        }
    }

    /// The aggregate block of the cluster JSON snapshot.
    pub fn to_json(&self) -> Json {
        let g = self.inner.lock().unwrap();
        let num = |x: f64| Json::Num(if x.is_finite() { x } else { 0.0 });
        let routed: u64 = g.routed_per_replica.iter().sum();
        let submitted = routed + g.rejected;
        let mut o = BTreeMap::new();
        o.insert("submitted".to_string(), Json::Num(submitted as f64));
        o.insert("routed".to_string(), Json::Num(routed as f64));
        o.insert("rejected".to_string(), Json::Num(g.rejected as f64));
        o.insert("rerouted".to_string(), Json::Num(g.rerouted as f64));
        o.insert("completed".to_string(), Json::Num(g.completed as f64));
        o.insert("tokens_generated".to_string(), Json::Num(g.tokens_generated as f64));
        o.insert(
            "reject_rate".to_string(),
            num(if submitted == 0 { 0.0 } else { g.rejected as f64 / submitted as f64 }),
        );
        o.insert("e2e_ms_p50".to_string(), num(g.e2e_us.quantile(0.5) / 1e3));
        o.insert("e2e_ms_p95".to_string(), num(g.e2e_us.quantile(0.95) / 1e3));
        o.insert("e2e_ms_p99".to_string(), num(g.e2e_us.quantile(0.99) / 1e3));
        o.insert("uptime_s".to_string(), num(g.started.elapsed().as_secs_f64()));
        Json::Obj(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_snapshot() {
        let m = ClusterMetrics::new(2);
        m.on_routed(0);
        m.on_routed(1);
        m.on_routed(1);
        m.on_reroute();
        m.on_reject();
        m.on_complete(Duration::from_millis(12), 4);
        m.on_complete(Duration::from_millis(30), 2);
        let s = m.snapshot();
        assert_eq!(s.routed, 3);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.rerouted, 1);
        assert_eq!(s.completed, 2);
        assert_eq!(s.tokens_generated, 6);
        assert_eq!(s.submitted(), 4);
        assert!((s.reject_rate() - 0.25).abs() < 1e-12);
        assert!(s.p50_ms > 0.0 && s.p99_ms >= s.p50_ms);
        assert_eq!(m.routed_to(1), 2);
    }

    #[test]
    fn json_parses_and_is_finite() {
        let m = ClusterMetrics::new(1);
        // empty metrics must still serialise with finite fields
        let j0 = m.to_json();
        assert_eq!(j0.get("completed").and_then(Json::as_f64), Some(0.0));
        m.on_routed(0);
        m.on_complete(Duration::from_millis(5), 3);
        let j = m.to_json();
        let text = j.to_string_compact();
        assert_eq!(crate::util::json::parse(&text).unwrap(), j);
        assert_eq!(j.get("routed").and_then(Json::as_f64), Some(1.0));
        assert!(j.get("e2e_ms_p50").and_then(Json::as_f64).unwrap() > 0.0);
    }
}
