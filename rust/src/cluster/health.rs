//! Per-replica health / backpressure state.
//!
//! A replica whose admission queue rejects is *cooled down*: the router
//! stops preferring it for a short window so queued work drains, and
//! re-routes traffic to its siblings. Cooled replicas are still tried as
//! a last resort — a request is only ever rejected when every replica
//! has refused it, never dropped silently.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Health/backpressure bookkeeping for one replica.
pub struct ReplicaHealth {
    cooled_until: Mutex<Option<Instant>>,
    rejects: AtomicU64,
    cooldowns: AtomicU64,
}

impl Default for ReplicaHealth {
    fn default() -> Self {
        Self::new()
    }
}

impl ReplicaHealth {
    /// Healthy (not cooled) state with zeroed counters.
    pub fn new() -> Self {
        ReplicaHealth {
            cooled_until: Mutex::new(None),
            rejects: AtomicU64::new(0),
            cooldowns: AtomicU64::new(0),
        }
    }

    /// Is this replica inside a cooldown window?
    pub fn is_cooled(&self, now: Instant) -> bool {
        match *self.cooled_until.lock().unwrap() {
            Some(until) => now < until,
            None => false,
        }
    }

    /// Record a backpressure rejection and start (or extend) a cooldown.
    pub fn on_reject(&self, now: Instant, cooldown: Duration) {
        self.rejects.fetch_add(1, Ordering::Relaxed);
        let mut g = self.cooled_until.lock().unwrap();
        let was_cooled = g.map(|u| now < u).unwrap_or(false);
        if !was_cooled {
            self.cooldowns.fetch_add(1, Ordering::Relaxed);
        }
        let until = now + cooldown;
        if g.map(|u| u < until).unwrap_or(true) {
            *g = Some(until);
        }
    }

    /// A successful submission ends any cooldown early: the queue
    /// evidently has room again.
    pub fn on_accept(&self) {
        *self.cooled_until.lock().unwrap() = None;
    }

    /// Total backpressure rejections observed at this replica.
    pub fn rejects(&self) -> u64 {
        self.rejects.load(Ordering::Relaxed)
    }

    /// Distinct cooldown windows entered.
    pub fn cooldowns(&self) -> u64 {
        self.cooldowns.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cooldown_lifecycle() {
        let h = ReplicaHealth::new();
        let t0 = Instant::now();
        assert!(!h.is_cooled(t0));
        h.on_reject(t0, Duration::from_millis(50));
        assert!(h.is_cooled(t0));
        assert!(h.is_cooled(t0 + Duration::from_millis(49)));
        assert!(!h.is_cooled(t0 + Duration::from_millis(51)));
        assert_eq!(h.rejects(), 1);
        assert_eq!(h.cooldowns(), 1);
    }

    #[test]
    fn accept_clears_cooldown() {
        let h = ReplicaHealth::new();
        let t0 = Instant::now();
        h.on_reject(t0, Duration::from_secs(60));
        assert!(h.is_cooled(t0));
        h.on_accept();
        assert!(!h.is_cooled(t0));
    }

    #[test]
    fn repeated_rejects_extend_one_window() {
        let h = ReplicaHealth::new();
        let t0 = Instant::now();
        h.on_reject(t0, Duration::from_millis(50));
        h.on_reject(t0 + Duration::from_millis(10), Duration::from_millis(50));
        assert_eq!(h.rejects(), 2);
        assert_eq!(h.cooldowns(), 1, "second reject extends the same window");
        assert!(h.is_cooled(t0 + Duration::from_millis(55)));
    }
}
