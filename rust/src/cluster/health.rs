//! Per-replica health: a closed → open → half-open circuit breaker.
//!
//! PR 2's single cooldown window generalizes into a standard circuit
//! breaker driven by the router's [`crate::cluster::Clock`] (so tests run
//! on virtual time):
//!
//! - **Closed** — healthy; the replica is routed to normally.
//! - **Open** — `failure_threshold` consecutive failures tripped the
//!   breaker; the router deprioritizes the replica for `open_for_us`
//!   (it is still tried as a last resort — a request is only rejected
//!   when every replica has refused it, never dropped silently).
//! - **HalfOpen** — the open window expired; the next request routed here
//!   is a *probe*. Success closes the breaker, failure re-opens it, and
//!   concurrent submitters treat a replica whose probe is already in
//!   flight as still open so a recovering worker is not flooded.
//!
//! With the default `failure_threshold = 1` the closed→open→half-open
//! cycle degenerates to exactly the old cooldown behaviour: one reject
//! demotes the replica for one window.
//!
//! Locks here are poison-recovering ([`crate::util::sync::lock_recover`]):
//! a crashed sibling must never wedge routing for the survivors.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::sync::lock_recover;

/// Breaker tuning, derived from `RouterConfig` (`cooldown` is the open
/// window; `failure_threshold` the consecutive-failure trip point).
#[derive(Clone, Copy, Debug)]
pub struct BreakerConfig {
    /// Consecutive failures that trip the breaker open.
    pub failure_threshold: u32,
    /// How long the breaker stays open before allowing a probe, in µs.
    pub open_for_us: u64,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        // threshold 1 ≈ the original cooldown semantics; 50ms window
        // matches the old RouterConfig::default().cooldown.
        BreakerConfig { failure_threshold: 1, open_for_us: 50_000 }
    }
}

/// Circuit-breaker state of one replica.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    /// Healthy — route normally.
    Closed,
    /// Tripped — deprioritize until the open window expires.
    Open,
    /// Window expired — admit one probe request.
    HalfOpen,
}

impl BreakerState {
    /// Stable snake_case name (metrics/JSON).
    pub fn name(&self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half_open",
        }
    }

    /// Numeric code for Prometheus gauges and trace payloads
    /// (0 closed, 1 open, 2 half-open).
    pub fn code(&self) -> u64 {
        match self {
            BreakerState::Closed => 0,
            BreakerState::Open => 1,
            BreakerState::HalfOpen => 2,
        }
    }
}

#[derive(Debug)]
struct Inner {
    state: BreakerState,
    consecutive_failures: u32,
    opened_at_us: u64,
    probing: bool,
}

/// Health/backpressure bookkeeping for one replica: breaker state plus
/// monotone counters for metrics.
#[derive(Debug)]
pub struct ReplicaHealth {
    inner: Mutex<Inner>,
    rejects: AtomicU64,
    opens: AtomicU64,
    transitions: AtomicU64,
}

impl Default for ReplicaHealth {
    fn default() -> Self {
        Self::new()
    }
}

impl ReplicaHealth {
    /// Healthy (closed) breaker with zeroed counters.
    pub fn new() -> Self {
        ReplicaHealth {
            inner: Mutex::new(Inner {
                state: BreakerState::Closed,
                consecutive_failures: 0,
                opened_at_us: 0,
                probing: false,
            }),
            rejects: AtomicU64::new(0),
            opens: AtomicU64::new(0),
            transitions: AtomicU64::new(0),
        }
    }

    /// Lazily move Open → HalfOpen once the open window has expired.
    fn refresh(&self, inner: &mut Inner, now_us: u64, cfg: &BreakerConfig) {
        if inner.state == BreakerState::Open
            && now_us >= inner.opened_at_us.saturating_add(cfg.open_for_us)
        {
            inner.state = BreakerState::HalfOpen;
            inner.probing = false;
            self.transitions.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Current breaker state at `now_us`.
    pub fn state(&self, now_us: u64, cfg: &BreakerConfig) -> BreakerState {
        let mut g = lock_recover(&self.inner);
        self.refresh(&mut g, now_us, cfg);
        g.state
    }

    /// Routing preference rank: closed (0) before half-open with a free
    /// probe slot (1) before open / probe-in-flight (2). Lower is better;
    /// the router sorts candidates by this but still tries every replica
    /// before rejecting a request.
    pub fn rank(&self, now_us: u64, cfg: &BreakerConfig) -> u8 {
        let mut g = lock_recover(&self.inner);
        self.refresh(&mut g, now_us, cfg);
        match (g.state, g.probing) {
            (BreakerState::Closed, _) => 0,
            (BreakerState::HalfOpen, false) => 1,
            _ => 2,
        }
    }

    /// Mark that a request is being sent to this replica; a half-open
    /// breaker records it as the in-flight probe.
    pub fn begin_probe(&self, now_us: u64, cfg: &BreakerConfig) {
        let mut g = lock_recover(&self.inner);
        self.refresh(&mut g, now_us, cfg);
        if g.state == BreakerState::HalfOpen {
            g.probing = true;
        }
    }

    /// Record a failed interaction (admission reject, injected fault, or a
    /// failover off a dead worker). Returns `true` when this failure
    /// tripped the breaker open (callers trace/count the transition).
    pub fn on_failure(&self, now_us: u64, cfg: &BreakerConfig) -> bool {
        self.rejects.fetch_add(1, Ordering::Relaxed);
        let mut g = lock_recover(&self.inner);
        self.refresh(&mut g, now_us, cfg);
        g.consecutive_failures = g.consecutive_failures.saturating_add(1);
        g.probing = false;
        let trip = match g.state {
            // a failed probe re-opens immediately
            BreakerState::HalfOpen => true,
            BreakerState::Closed => g.consecutive_failures >= cfg.failure_threshold.max(1),
            // already open: refresh the window so a failing last-resort
            // attempt keeps the replica demoted
            BreakerState::Open => {
                g.opened_at_us = now_us;
                false
            }
        };
        if trip {
            g.state = BreakerState::Open;
            g.opened_at_us = now_us;
            self.opens.fetch_add(1, Ordering::Relaxed);
            self.transitions.fetch_add(1, Ordering::Relaxed);
        }
        trip
    }

    /// Record a successful interaction: resets the failure streak and
    /// closes the breaker from any state. Returns `true` when this closed
    /// a non-closed breaker (a successful probe).
    pub fn on_success(&self) -> bool {
        let mut g = lock_recover(&self.inner);
        g.consecutive_failures = 0;
        g.probing = false;
        if g.state != BreakerState::Closed {
            g.state = BreakerState::Closed;
            self.transitions.fetch_add(1, Ordering::Relaxed);
            true
        } else {
            false
        }
    }

    /// Total failed interactions observed at this replica.
    pub fn rejects(&self) -> u64 {
        self.rejects.load(Ordering::Relaxed)
    }

    /// Distinct times the breaker tripped open (the metric PR 2 called
    /// "cooldowns" — the JSON key is kept for continuity).
    pub fn opens(&self) -> u64 {
        self.opens.load(Ordering::Relaxed)
    }

    /// Total breaker state transitions (open, half-open, close).
    pub fn transitions(&self) -> u64 {
        self.transitions.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const CFG: BreakerConfig = BreakerConfig { failure_threshold: 1, open_for_us: 50_000 };

    #[test]
    fn breaker_lifecycle_closed_open_halfopen_closed() {
        let h = ReplicaHealth::new();
        assert_eq!(h.state(0, &CFG), BreakerState::Closed);
        assert!(h.on_failure(0, &CFG), "threshold 1: first failure trips");
        assert_eq!(h.state(0, &CFG), BreakerState::Open);
        assert_eq!(h.state(49_999, &CFG), BreakerState::Open);
        assert_eq!(h.state(50_000, &CFG), BreakerState::HalfOpen);
        assert!(h.on_success(), "successful probe closes");
        assert_eq!(h.state(50_000, &CFG), BreakerState::Closed);
        assert_eq!(h.rejects(), 1);
        assert_eq!(h.opens(), 1);
        // open, half-open, closed
        assert_eq!(h.transitions(), 3);
    }

    #[test]
    fn failed_probe_reopens() {
        let h = ReplicaHealth::new();
        h.on_failure(0, &CFG);
        assert_eq!(h.state(60_000, &CFG), BreakerState::HalfOpen);
        assert!(h.on_failure(60_000, &CFG), "failed probe re-opens");
        assert_eq!(h.state(60_000, &CFG), BreakerState::Open);
        assert_eq!(h.state(110_000, &CFG), BreakerState::HalfOpen);
        assert_eq!(h.opens(), 2);
    }

    #[test]
    fn threshold_requires_consecutive_failures() {
        let cfg = BreakerConfig { failure_threshold: 3, open_for_us: 50_000 };
        let h = ReplicaHealth::new();
        assert!(!h.on_failure(0, &cfg));
        assert!(!h.on_failure(1, &cfg));
        h.on_success(); // streak reset
        assert!(!h.on_failure(2, &cfg));
        assert!(!h.on_failure(3, &cfg));
        assert!(h.on_failure(4, &cfg), "third consecutive failure trips");
        assert_eq!(h.state(4, &cfg), BreakerState::Open);
    }

    #[test]
    fn probe_slot_limits_concurrency() {
        let h = ReplicaHealth::new();
        h.on_failure(0, &CFG);
        assert_eq!(h.rank(50_000, &CFG), 1, "half-open with free probe slot");
        h.begin_probe(50_000, &CFG);
        assert_eq!(h.rank(50_000, &CFG), 2, "probe in flight: treated as open");
        assert!(h.on_success());
        assert_eq!(h.rank(50_000, &CFG), 0);
    }

    #[test]
    fn open_failure_extends_window() {
        let h = ReplicaHealth::new();
        h.on_failure(0, &CFG);
        // a failing last-resort attempt at t=40ms re-bases the window
        assert!(!h.on_failure(40_000, &CFG));
        assert_eq!(h.state(60_000, &CFG), BreakerState::Open, "window extended");
        assert_eq!(h.state(90_000, &CFG), BreakerState::HalfOpen);
        assert_eq!(h.opens(), 1, "extension is not a new open");
    }
}
