//! The multi-replica serving tier — scaling the Layer-3 coordinator out.
//!
//! One [`crate::coordinator::Server`] is a single-replica engine; this
//! module shards load across N of them:
//!
//! * [`pool`] — [`ReplicaPool`]: N independent servers, each owning its
//!   backend on its own worker thread, seeded deterministically; since
//!   PR 9 also the **supervisor** that detects crashed workers, fails
//!   their in-flight requests back to the router, and respawns them.
//!   [`Supervisor`] runs that sweep on a dedicated clock-driven thread
//!   so crashes are caught even on idle replicas.
//! * [`router`] — [`Router`] with pluggable [`RoutingPolicy`]s
//!   (`round_robin`, `join_shortest_queue` over the per-replica
//!   in-flight/queue-depth gauges, `affinity` session hashing for warm
//!   KV-cache reuse), hardened with per-request deadlines, bounded
//!   retries with backoff, and failover off dead replicas.
//! * [`health`] — [`ReplicaHealth`]: per-replica closed → open →
//!   half-open circuit breaker; tripped replicas are demoted to
//!   last-resort candidates and probed after the open window.
//! * [`fault`] — [`FaultPlan`]: seeded deterministic fault injection
//!   (crashes, stalls, transient rejects) for chaos testing; `None` on
//!   every hot path when unconfigured.
//! * [`clock`] — [`Clock`]: wall or manual virtual time, so deadline /
//!   backoff / breaker tests run instant and deterministic.
//! * [`metrics`] — [`ClusterMetrics`]: router-side counters (terminal
//!   outcomes, retries, failovers) and end-to-end latency, aggregated
//!   with per-replica [`crate::coordinator::ServingMetrics`] into one
//!   JSON snapshot.
//! * [`loadgen`] — trace-driven load generator: replays
//!   [`crate::workload::trace`] arrivals at wall-clock rate, or in
//!   virtual time (`--fast`) for CI.
//!
//! Invariants (property-tested in `rust/tests/coordinator_props.rs` and
//! `rust/tests/chaos_props.rs`): every request submitted to the router
//! reaches **exactly one terminal outcome** — completed, rejected with a
//! reason, or deadline exceeded — for any replica count, policy, and
//! fault schedule; a rejection implies every replica refused (or the
//! request was malformed / out of failover budget). See
//! `docs/ROBUSTNESS.md` for the failure model.

#![warn(missing_docs)]

pub mod clock;
pub mod fault;
pub mod health;
pub mod loadgen;
pub mod metrics;
pub mod pool;
pub mod router;

pub use clock::Clock;
pub use fault::{FaultConfig, FaultPlan};
pub use health::{BreakerConfig, BreakerState, ReplicaHealth};
pub use loadgen::{replay, Pacing, ReplayConfig, ReplayStats};
pub use metrics::{ClusterMetrics, ClusterSnapshot};
pub use pool::{ReplicaPool, Supervisor};
pub use router::{Outcome, RoutedRequest, Router, RouterConfig, RoutingPolicy};
