//! The multi-replica serving tier — scaling the Layer-3 coordinator out.
//!
//! One [`crate::coordinator::Server`] is a single-replica engine; this
//! module shards load across N of them:
//!
//! * [`pool`] — [`ReplicaPool`]: N independent servers, each owning its
//!   backend on its own worker thread, seeded deterministically.
//! * [`router`] — [`Router`] with pluggable [`RoutingPolicy`]s
//!   (`round_robin`, `join_shortest_queue` over the per-replica
//!   in-flight/queue-depth gauges, `affinity` session hashing for warm
//!   KV-cache reuse).
//! * [`health`] — per-replica cooldown on backpressure; refused traffic
//!   is re-routed, and only rejected once every replica has refused.
//! * [`metrics`] — [`ClusterMetrics`]: router-side counters and
//!   end-to-end latency, aggregated with per-replica
//!   [`crate::coordinator::ServingMetrics`] into one JSON snapshot.
//! * [`loadgen`] — trace-driven load generator: replays
//!   [`crate::workload::trace`] arrivals at wall-clock rate, or in
//!   virtual time (`--fast`) for CI.
//!
//! Invariants (property-tested in `rust/tests/coordinator_props.rs`):
//! every request submitted to the router is answered or rejected exactly
//! once across replicas, for any replica count and policy; a rejection
//! implies every replica refused.

#![warn(missing_docs)]

pub mod health;
pub mod loadgen;
pub mod metrics;
pub mod pool;
pub mod router;

pub use health::ReplicaHealth;
pub use loadgen::{replay, Pacing, ReplayConfig, ReplayStats};
pub use metrics::{ClusterMetrics, ClusterSnapshot};
pub use pool::ReplicaPool;
pub use router::{RoutedRequest, Router, RouterConfig, RoutingPolicy};
