//! Radix (prefix) index over fixed-size token chunks.
//!
//! Every node covers exactly `block_tokens` consecutive token ids and
//! owns one sealed [`super::block::Block`]; a root-to-node path therefore
//! spells out a prompt prefix in whole blocks. Because all chunks have
//! the same length the radix tree degenerates into a trie keyed by the
//! chunk's token ids — lookups walk full-chunk matches only, which is
//! exactly the granularity at which KV rows can be shared (a partial
//! chunk lives in the requesting sequence's private tail instead).
//!
//! The index never owns reference counts: a node just names a block. The
//! eviction tier asks for *leaves* whose block has zero active mappings
//! and prunes them LRU-first, which frees deeper (colder) prefixes before
//! shallower (hotter) ones by construction.

use super::block::BlockId;
use std::collections::HashMap;

struct Node {
    chunk: Vec<u32>,
    block: BlockId,
    parent: Option<usize>,
    children: HashMap<Vec<u32>, usize>,
}

/// The prefix index: a trie over `block_tokens`-sized token chunks.
pub struct RadixIndex {
    nodes: Vec<Option<Node>>,
    free: Vec<usize>,
    root_children: HashMap<Vec<u32>, usize>,
    len: usize,
}

impl RadixIndex {
    /// An empty index.
    pub fn new() -> Self {
        RadixIndex { nodes: Vec::new(), free: Vec::new(), root_children: HashMap::new(), len: 0 }
    }

    /// Number of indexed blocks.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether no blocks are indexed.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn node(&self, idx: usize) -> &Node {
        self.nodes[idx].as_ref().expect("use of freed radix node")
    }

    /// Longest full-chunk prefix match of `tokens`: the `(node, block)`
    /// path from the root, in order. Stops at the first chunk with no
    /// child (or when fewer than `chunk_len` tokens remain).
    pub fn lookup(&self, tokens: &[u32], chunk_len: usize) -> Vec<(usize, BlockId)> {
        let mut path = Vec::new();
        if chunk_len == 0 {
            return path;
        }
        let mut pos = 0;
        let mut children = &self.root_children;
        while pos + chunk_len <= tokens.len() {
            let chunk = &tokens[pos..pos + chunk_len];
            match children.get(chunk) {
                Some(&idx) => {
                    let n = self.node(idx);
                    path.push((idx, n.block));
                    children = &n.children;
                    pos += chunk_len;
                }
                None => break,
            }
        }
        path
    }

    /// Insert `chunk → block` under `parent` (`None` = root). The chunk
    /// must not already exist at that position (lookups stop exactly at
    /// the first missing child, so callers can't race themselves).
    pub fn insert(&mut self, parent: Option<usize>, chunk: Vec<u32>, block: BlockId) -> usize {
        let idx = match self.free.pop() {
            Some(i) => i,
            None => {
                self.nodes.push(None);
                self.nodes.len() - 1
            }
        };
        let node = Node { chunk: chunk.clone(), block, parent, children: HashMap::new() };
        self.nodes[idx] = Some(node);
        let children = match parent {
            None => &mut self.root_children,
            Some(p) => &mut self.nodes[p].as_mut().expect("freed parent").children,
        };
        let prev = children.insert(chunk, idx);
        assert!(prev.is_none(), "duplicate radix chunk insertion");
        self.len += 1;
        idx
    }

    /// Block owned by a node.
    pub fn node_block(&self, idx: usize) -> BlockId {
        self.node(idx).block
    }

    /// Direct child of `parent` (`None` = root) keyed by exactly `chunk`,
    /// if one exists. Lets a seal that happens *after* a separate lookup
    /// (the resumed-prefill path) detect chunks another registration
    /// indexed in between, and reuse them instead of inserting duplicates.
    pub fn child(&self, parent: Option<usize>, chunk: &[u32]) -> Option<usize> {
        let children = match parent {
            None => &self.root_children,
            Some(p) => &self.node(p).children,
        };
        children.get(chunk).copied()
    }

    /// The full root-to-node token prefix a node spells out — the spill
    /// tier's cold-index key, read *before* the node is unlinked.
    pub fn path_tokens(&self, idx: usize) -> Vec<u32> {
        let mut chunks: Vec<&[u32]> = Vec::new();
        let mut cur = Some(idx);
        while let Some(i) = cur {
            let n = self.node(i);
            chunks.push(&n.chunk);
            cur = n.parent;
        }
        let mut out = Vec::with_capacity(chunks.iter().map(|c| c.len()).sum());
        for c in chunks.iter().rev() {
            out.extend_from_slice(c);
        }
        out
    }

    /// Indices of all leaf nodes (no children) — the only evictable ones.
    pub fn leaves(&self) -> Vec<usize> {
        self.nodes
            .iter()
            .enumerate()
            .filter_map(|(i, n)| match n {
                Some(node) if node.children.is_empty() => Some(i),
                _ => None,
            })
            .collect()
    }

    /// Remove a leaf node, returning its block id. Panics on non-leaves
    /// (evicting an interior node would orphan deeper cached prefixes).
    pub fn remove_leaf(&mut self, idx: usize) -> BlockId {
        let node = self.nodes[idx].take().expect("remove of freed radix node");
        assert!(node.children.is_empty(), "remove_leaf on interior node");
        let children = match node.parent {
            None => &mut self.root_children,
            Some(p) => &mut self.nodes[p].as_mut().expect("freed parent").children,
        };
        children.remove(&node.chunk);
        self.free.push(idx);
        self.len -= 1;
        node.block
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_walks_full_chunks_only() {
        let mut r = RadixIndex::new();
        let a = r.insert(None, vec![1, 2], 10);
        let b = r.insert(Some(a), vec![3, 4], 11);
        r.insert(Some(b), vec![5, 6], 12);
        assert_eq!(r.len(), 3);
        // full three-chunk match
        let p = r.lookup(&[1, 2, 3, 4, 5, 6], 2);
        assert_eq!(p.iter().map(|&(_, b)| b).collect::<Vec<_>>(), vec![10, 11, 12]);
        // divergence after one chunk
        let p = r.lookup(&[1, 2, 9, 9, 5, 6], 2);
        assert_eq!(p.len(), 1);
        assert_eq!(p[0].1, 10);
        // partial final chunk never matches
        let p = r.lookup(&[1, 2, 3], 2);
        assert_eq!(p.len(), 1);
        // no match at root
        assert!(r.lookup(&[7, 7, 7, 7], 2).is_empty());
        assert!(r.lookup(&[1], 2).is_empty());
    }

    #[test]
    fn branches_share_a_parent() {
        let mut r = RadixIndex::new();
        let a = r.insert(None, vec![1, 2], 1);
        r.insert(Some(a), vec![3, 4], 2);
        r.insert(Some(a), vec![8, 8], 3);
        assert_eq!(r.lookup(&[1, 2, 8, 8], 2).last().unwrap().1, 3);
        assert_eq!(r.lookup(&[1, 2, 3, 4], 2).last().unwrap().1, 2);
        // only the two branch tips are leaves
        let mut leaves: Vec<BlockId> = r.leaves().iter().map(|&i| r.node_block(i)).collect();
        leaves.sort_unstable();
        assert_eq!(leaves, vec![2, 3]);
    }

    #[test]
    fn remove_leaf_exposes_parent() {
        let mut r = RadixIndex::new();
        let a = r.insert(None, vec![1, 2], 1);
        let b = r.insert(Some(a), vec![3, 4], 2);
        assert_eq!(r.leaves(), vec![b]);
        assert_eq!(r.remove_leaf(b), 2);
        assert_eq!(r.len(), 1);
        assert_eq!(r.leaves(), vec![a]);
        assert!(r.lookup(&[1, 2, 3, 4], 2).len() == 1);
        assert_eq!(r.remove_leaf(a), 1);
        assert!(r.is_empty());
        // freed slots are reused
        let c = r.insert(None, vec![9, 9], 7);
        assert!(c == a || c == b);
        assert_eq!(r.lookup(&[9, 9], 2)[0].1, 7);
    }
}
