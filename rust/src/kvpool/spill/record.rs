//! The checksummed, versioned on-disk block record.
//!
//! One file per spilled block, little-endian throughout:
//!
//! ```text
//! magic    b"WCSP"                      4 bytes
//! version  u32                          4 bytes (= 1)
//! n_tokens u32 | n_lh u32 | d_k u32 | d_v u32
//! tokens   n_tokens x u32
//! layers   n_lh x { keys n_tokens*d_k f32, values n_tokens*d_v f32 }
//! check    u64 — integrity word over every preceding byte
//! ```
//!
//! The integrity word is a splitmix64-fed xxhash-style fold: the byte
//! stream is consumed as 8-byte words (zero-padded tail), each XORed
//! into a running state that is re-mixed through the splitmix64
//! finaliser. Not cryptographic — it exists to catch torn writes,
//! truncation, and bit rot, any of which must read as a *miss* (cold
//! prefill recomputes the rows) rather than ever serving corrupt KV.
//!
//! [`decode`] is therefore total: any structural defect — short buffer,
//! wrong magic/version, inconsistent dims, trailing garbage, checksum
//! mismatch — returns `None`.

use crate::kvpool::block::{Block, BlockLayer};
use crate::linalg::Matrix;

/// File magic: "WCSP" (WildCat SPill).
pub const MAGIC: [u8; 4] = *b"WCSP";

/// Current record version. Decoders reject anything else.
pub const VERSION: u32 = 1;

const HEADER_LEN: usize = 4 + 4 + 4 * 4;
const CHECK_LEN: usize = 8;

/// Exact encoded size of a record with the given shape — lets the cold
/// index account for a record's disk footprint before the background
/// write lands.
pub fn encoded_len(n_tokens: usize, n_lh: usize, d_k: usize, d_v: usize) -> usize {
    HEADER_LEN + n_tokens * 4 + n_lh * n_tokens * (d_k + d_v) * 4 + CHECK_LEN
}

/// Integrity word: fold the byte stream as zero-padded 8-byte words
/// through the splitmix64 finaliser.
fn integrity_word(bytes: &[u8]) -> u64 {
    let mut h = 0x57_43_53_50_u64 ^ (bytes.len() as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    for chunk in bytes.chunks(8) {
        let mut word = [0u8; 8];
        word[..chunk.len()].copy_from_slice(chunk);
        h ^= u64::from_le_bytes(word);
        // splitmix64 finaliser
        h = (h ^ (h >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        h = (h ^ (h >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        h ^= h >> 31;
    }
    h
}

/// Serialise a block into a self-contained record.
pub fn encode(block: &Block) -> Vec<u8> {
    let n_tokens = block.tokens.len();
    let n_lh = block.layers.len();
    let (d_k, d_v) = block
        .layers
        .first()
        .map(|l| (l.keys.cols(), l.values.cols()))
        .unwrap_or((0, 0));
    let mut out = Vec::with_capacity(encoded_len(n_tokens, n_lh, d_k, d_v));
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    for dim in [n_tokens, n_lh, d_k, d_v] {
        out.extend_from_slice(&(dim as u32).to_le_bytes());
    }
    for &t in &block.tokens {
        out.extend_from_slice(&t.to_le_bytes());
    }
    for layer in &block.layers {
        for &x in layer.keys.as_slice() {
            out.extend_from_slice(&x.to_le_bytes());
        }
        for &x in layer.values.as_slice() {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }
    let check = integrity_word(&out);
    out.extend_from_slice(&check.to_le_bytes());
    out
}

fn read_u32(bytes: &[u8], at: usize) -> u32 {
    u32::from_le_bytes(bytes[at..at + 4].try_into().unwrap())
}

/// Deserialise a record back into a block (`refs = 0`, `in_tree = false`,
/// `last_touch = 0` — the page-in path re-links it). Returns `None` on
/// *any* defect; a corrupt record is a cache miss, never served KV.
pub fn decode(bytes: &[u8]) -> Option<Block> {
    if bytes.len() < HEADER_LEN + CHECK_LEN {
        return None;
    }
    if bytes[..4] != MAGIC || read_u32(bytes, 4) != VERSION {
        return None;
    }
    let n_tokens = read_u32(bytes, 8) as usize;
    let n_lh = read_u32(bytes, 12) as usize;
    let d_k = read_u32(bytes, 16) as usize;
    let d_v = read_u32(bytes, 20) as usize;
    if bytes.len() != encoded_len(n_tokens, n_lh, d_k, d_v) {
        return None;
    }
    let payload_end = bytes.len() - CHECK_LEN;
    let stored = u64::from_le_bytes(bytes[payload_end..].try_into().unwrap());
    if integrity_word(&bytes[..payload_end]) != stored {
        return None;
    }
    let mut at = HEADER_LEN;
    let mut tokens = Vec::with_capacity(n_tokens);
    for _ in 0..n_tokens {
        tokens.push(read_u32(bytes, at));
        at += 4;
    }
    let read_mat = |at: &mut usize, rows: usize, cols: usize| {
        let mut data = Vec::with_capacity(rows * cols);
        for _ in 0..rows * cols {
            data.push(f32::from_le_bytes(bytes[*at..*at + 4].try_into().unwrap()));
            *at += 4;
        }
        Matrix::from_vec(data, rows, cols)
    };
    let mut layers = Vec::with_capacity(n_lh);
    for _ in 0..n_lh {
        let keys = read_mat(&mut at, n_tokens, d_k);
        let values = read_mat(&mut at, n_tokens, d_v);
        layers.push(BlockLayer { keys, values });
    }
    Some(Block { tokens, layers, refs: 0, in_tree: false, last_touch: 0 })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn block(n: usize, n_lh: usize, d: usize) -> Block {
        Block {
            tokens: (0..n as u32).map(|t| t * 7 + 3).collect(),
            layers: (0..n_lh)
                .map(|lh| BlockLayer {
                    keys: Matrix::from_fn(n, d, |i, j| (lh * 100 + i * 10 + j) as f32 * 0.5),
                    values: Matrix::from_fn(n, d, |i, j| -((lh * 100 + i * 10 + j) as f32)),
                })
                .collect(),
            refs: 2,
            in_tree: true,
            last_touch: 99,
        }
    }

    #[test]
    fn roundtrip_preserves_tokens_and_rows() {
        let b = block(16, 3, 4);
        let bytes = encode(&b);
        assert_eq!(bytes.len(), encoded_len(16, 3, 4, 4));
        let d = decode(&bytes).expect("clean record must decode");
        assert_eq!(d.tokens, b.tokens);
        assert_eq!(d.layers.len(), 3);
        for lh in 0..3 {
            assert_eq!(d.layers[lh].keys, b.layers[lh].keys);
            assert_eq!(d.layers[lh].values, b.layers[lh].values);
        }
        // bookkeeping fields reset for re-linking
        assert_eq!((d.refs, d.in_tree, d.last_touch), (0, false, 0));
    }

    #[test]
    fn corruption_truncation_and_garbage_all_miss() {
        let bytes = encode(&block(8, 2, 4));
        // flip one payload bit
        for &at in &[0usize, 5, HEADER_LEN + 2, bytes.len() - 1] {
            let mut bad = bytes.clone();
            bad[at] ^= 0x40;
            assert!(decode(&bad).is_none(), "flipped byte {at} must not decode");
        }
        // torn write: every strict prefix misses
        for cut in [0, 3, HEADER_LEN, bytes.len() / 2, bytes.len() - 1] {
            assert!(decode(&bytes[..cut]).is_none(), "truncation at {cut} must miss");
        }
        // trailing garbage
        let mut long = bytes.clone();
        long.push(0);
        assert!(decode(&long).is_none());
        // version bump
        let mut vers = bytes.clone();
        vers[4] = 2;
        assert!(decode(&vers).is_none());
    }

    #[test]
    fn integrity_word_is_stable_and_length_sensitive() {
        // checksum must distinguish zero-padded tails from real zeros
        let a = integrity_word(&[1, 2, 3]);
        let b = integrity_word(&[1, 2, 3, 0]);
        assert_ne!(a, b);
        assert_eq!(a, integrity_word(&[1, 2, 3]));
    }
}
