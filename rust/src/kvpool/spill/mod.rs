//! Spill-to-disk tier: the rung between *evict* and *reject* on the
//! pressure ladder.
//!
//! When the eviction tier reclaims an unreferenced cached-prefix block,
//! the pool used to destroy it — the rows were "pure cache", recomputable
//! by a future prefill. That recompute is exactly the quadratic work the
//! serving stack exists to avoid, so with a [`SpillStore`] configured the
//! evicted block is *offered* to a byte-budgeted cold index instead and
//! written to disk off the decode path; a later prefix lookup that runs
//! past the radix index consults the cold index and pages the block back
//! into the pool ([`super::KvPool::lookup_prefix`]), so admission resumes
//! prefill past it.
//!
//! Design points:
//!
//! * **Writeback is asynchronous.** [`SpillStore::offer`] moves the
//!   evicted block into a `Pending` cold-index entry and enqueues the
//!   serialisation + file write onto a dedicated background thread, so
//!   the decode path never waits on disk. A page-in that arrives before
//!   the write lands is served from the pending in-memory block —
//!   deterministically identical to reading the file back.
//! * **Budgeted, LRU.** The index tracks the exact encoded byte size of
//!   every entry against `--spill-budget-mb`; inserting past the budget
//!   drops least-recently-touched entries (their files are deleted by
//!   the writeback thread, ordered after any pending write).
//! * **Integrity over availability.** Records are checksummed
//!   ([`record`]); a torn, truncated, or bit-rotted record decodes to
//!   `None` and is treated as a miss — the pool falls back to cold
//!   prefill and the bad entry/file is dropped. Corrupt KV is never
//!   served.
//!
//! The cold index is keyed by the *full root-to-block token prefix*, so
//! a hit can be re-linked into the radix tree at exactly the position it
//! was evicted from. The index lives in memory only: spill files are
//! per-run scratch, not a persistence layer.

use super::block::Block;
use crate::obs::trace::{self, SpanKind, NO_REQ};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

pub mod record;

/// Spill-tier configuration (CLI surface: `--spill-budget-mb`,
/// `--spill-dir`). Present on [`super::KvPoolConfig::spill`] only when
/// the tier is enabled — `None` is bit-identical to a spill-less build.
#[derive(Clone, Debug)]
pub struct SpillParams {
    /// Directory the block records are written under. Each replica must
    /// use its own directory (replicas serve distinct model instances).
    pub dir: PathBuf,
    /// Cold-index byte budget; entries past it are dropped LRU-first.
    pub budget_bytes: usize,
    /// Replica tag the writeback thread stamps on its trace spans.
    pub replica: u32,
}

/// Convert a `--spill-budget-mb` operator value to bytes.
pub fn spill_budget_bytes_from_mb(mb: f64) -> usize {
    if mb <= 0.0 {
        0
    } else {
        (mb * 1024.0 * 1024.0).round() as usize
    }
}

/// What [`SpillStore::offer`] did with an evicted block.
#[derive(Clone, Copy, Debug)]
pub struct OfferOutcome {
    /// Encoded record size now charged to the cold index.
    pub bytes: u64,
    /// Cold entries dropped (LRU) to make room.
    pub evicted: u64,
}

/// Result of a cold-index probe.
pub enum Fetch {
    /// The block was rematerialised (from the pending in-memory copy or
    /// a verified on-disk record).
    Hit(Block),
    /// The entry existed but its record failed verification; the entry
    /// and file have been dropped. Callers count `spill_corrupt` and
    /// fall back to cold prefill.
    Corrupt,
    /// No entry under this key.
    Miss,
}

enum EntryState {
    /// Write still queued/in-flight; page-ins serve this copy.
    Pending(Arc<Block>),
    /// The record landed on disk; page-ins read and verify it.
    OnDisk,
}

struct Entry {
    state: EntryState,
    bytes: usize,
    last_touch: u64,
    file: PathBuf,
}

struct Index {
    map: HashMap<Vec<u32>, Entry>,
    bytes: usize,
    tick: u64,
    next_file: u64,
}

enum Job {
    Write { key: Vec<u32>, path: PathBuf, block: Arc<Block> },
    Remove { path: PathBuf },
    Flush(Sender<()>),
}

/// The byte-budgeted cold store: an in-memory LRU index over
/// checksummed per-block record files, written by a dedicated
/// background thread. Metrics-free by design — callers (the kvpool
/// eviction and page-in paths) count outcomes on [`super::PoolMetrics`].
pub struct SpillStore {
    dir: PathBuf,
    budget_bytes: usize,
    index: Arc<Mutex<Index>>,
    tx: Option<Sender<Job>>,
    worker: Option<JoinHandle<()>>,
}

impl SpillStore {
    /// Create the spill directory and start the writeback thread.
    pub fn new(params: &SpillParams) -> std::io::Result<SpillStore> {
        std::fs::create_dir_all(&params.dir)?;
        let index = Arc::new(Mutex::new(Index {
            map: HashMap::new(),
            bytes: 0,
            tick: 0,
            next_file: 0,
        }));
        let (tx, rx) = mpsc::channel();
        let worker_index = Arc::clone(&index);
        let replica = params.replica;
        let worker = std::thread::Builder::new()
            .name("wildcat-spill-writeback".to_string())
            .spawn(move || run_writeback(rx, worker_index, replica))?;
        Ok(SpillStore {
            dir: params.dir.clone(),
            budget_bytes: params.budget_bytes,
            index,
            tx: Some(tx),
            worker: Some(worker),
        })
    }

    fn send(&self, job: Job) -> bool {
        self.tx.as_ref().map(|t| t.send(job).is_ok()).unwrap_or(false)
    }

    /// Offer an evicted block to the cold tier, keyed by its full
    /// root-to-block token prefix. Takes ownership (zero-copy off the
    /// eviction path); the disk write happens on the writeback thread.
    /// Returns `None` when the key is already indexed (touch only — the
    /// existing record still serves) or the record cannot fit the budget
    /// at all; `Some` reports the bytes newly charged and how many LRU
    /// entries were dropped to make room.
    pub fn offer(&self, key: Vec<u32>, block: Block) -> Option<OfferOutcome> {
        let (d_k, d_v) = block
            .layers
            .first()
            .map(|l| (l.keys.cols(), l.values.cols()))
            .unwrap_or((0, 0));
        let bytes = record::encoded_len(block.tokens.len(), block.layers.len(), d_k, d_v);
        if bytes > self.budget_bytes {
            return None;
        }
        let mut g = self.index.lock().unwrap();
        g.tick += 1;
        let tick = g.tick;
        if let Some(e) = g.map.get_mut(&key) {
            e.last_touch = tick;
            return None;
        }
        let mut evicted = 0u64;
        while g.bytes + bytes > self.budget_bytes {
            let victim = g
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_touch)
                .map(|(k, _)| k.clone());
            let Some(vk) = victim else { break };
            let e = g.map.remove(&vk).expect("victim vanished under lock");
            g.bytes -= e.bytes;
            evicted += 1;
            self.send(Job::Remove { path: e.file });
        }
        // Files are named by a monotonic id, not the key: a re-spill
        // after an LRU drop gets a fresh file, so a stale queued Remove
        // can never delete a newer record.
        let file = self.dir.join(format!("rec-{:08}.wcsp", g.next_file));
        g.next_file += 1;
        let block = Arc::new(block);
        g.map.insert(
            key.clone(),
            Entry {
                state: EntryState::Pending(Arc::clone(&block)),
                bytes,
                last_touch: tick,
                file: file.clone(),
            },
        );
        g.bytes += bytes;
        self.send(Job::Write { key, path: file, block });
        Some(OfferOutcome { bytes: bytes as u64, evicted })
    }

    /// Probe the cold index for a spilled block. A hit stays indexed
    /// (page-in is a read, not a move), so re-evicting the same prefix
    /// later is a free touch instead of a rewrite.
    pub fn fetch(&self, key: &[u32]) -> Fetch {
        let mut g = self.index.lock().unwrap();
        g.tick += 1;
        let tick = g.tick;
        let Some(e) = g.map.get_mut(key) else { return Fetch::Miss };
        e.last_touch = tick;
        match &e.state {
            EntryState::Pending(b) => Fetch::Hit(Block::clone(b)),
            EntryState::OnDisk => {
                let path = e.file.clone();
                let decoded = std::fs::read(&path).ok().and_then(|bytes| record::decode(&bytes));
                match decoded {
                    // the record must spell the key's own tail chunk —
                    // anything else (however it got there) is corruption
                    Some(block) if key.ends_with(&block.tokens) => Fetch::Hit(block),
                    _ => {
                        let e = g.map.remove(key).expect("entry vanished under lock");
                        g.bytes -= e.bytes;
                        self.send(Job::Remove { path: e.file });
                        Fetch::Corrupt
                    }
                }
            }
        }
    }

    /// Block until every queued write/remove has been applied. Tests and
    /// benches use this to observe on-disk state; the serving path never
    /// calls it.
    pub fn flush(&self) {
        let (ack_tx, ack_rx) = mpsc::channel();
        if self.send(Job::Flush(ack_tx)) {
            let _ = ack_rx.recv();
        }
    }

    /// Configured cold-index byte budget.
    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    /// Bytes currently charged to the cold index.
    pub fn indexed_bytes(&self) -> usize {
        self.index.lock().unwrap().bytes
    }

    /// Entries currently in the cold index.
    pub fn entries(&self) -> usize {
        self.index.lock().unwrap().map.len()
    }

    /// On-disk path a key's record lives at, if the key is indexed —
    /// test hook for crash-consistency scenarios (truncating/corrupting
    /// a live record).
    pub fn record_path(&self, key: &[u32]) -> Option<PathBuf> {
        self.index.lock().unwrap().map.get(key).map(|e| e.file.clone())
    }
}

impl Drop for SpillStore {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn run_writeback(rx: Receiver<Job>, index: Arc<Mutex<Index>>, replica: u32) {
    trace::set_current_replica(replica);
    while let Ok(job) = rx.recv() {
        match job {
            Job::Write { key, path, block } => {
                let t0 = if trace::enabled() { Some(Instant::now()) } else { None };
                let bytes = record::encode(&block);
                let n = bytes.len() as u64;
                // write-then-rename so a crash mid-write leaves no
                // half-record under the live name (the checksum would
                // catch one anyway; this keeps the common case clean)
                let tmp = path.with_extension("wcsp.tmp");
                let ok = std::fs::write(&tmp, &bytes)
                    .and_then(|()| std::fs::rename(&tmp, &path))
                    .is_ok();
                let mut g = index.lock().unwrap();
                if let Some(e) = g.map.get_mut(&key) {
                    // flip only our own entry: a drop + re-offer in the
                    // meantime owns a different file
                    if e.file == path {
                        if ok {
                            e.state = EntryState::OnDisk;
                        } else {
                            let e = g.map.remove(&key).expect("entry vanished under lock");
                            g.bytes -= e.bytes;
                        }
                    }
                }
                drop(g);
                if let Some(t0) = t0 {
                    trace::span(SpanKind::Spill, t0, Instant::now(), NO_REQ, 1, n);
                }
            }
            Job::Remove { path } => {
                let _ = std::fs::remove_file(&path);
            }
            Job::Flush(ack) => {
                let _ = ack.send(());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvpool::block::BlockLayer;
    use crate::linalg::Matrix;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("wildcat_spill_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn store(dir: &Path, budget: usize) -> SpillStore {
        SpillStore::new(&SpillParams {
            dir: dir.to_path_buf(),
            budget_bytes: budget,
            replica: 0,
        })
        .unwrap()
    }

    fn block(tokens: &[u32]) -> Block {
        Block {
            tokens: tokens.to_vec(),
            layers: (0..2)
                .map(|lh| BlockLayer {
                    keys: Matrix::from_fn(tokens.len(), 4, |i, j| {
                        tokens[i] as f32 + (lh * 100 + j) as f32
                    }),
                    values: Matrix::from_fn(tokens.len(), 4, |i, j| {
                        -(tokens[i] as f32) - (lh * 100 + j) as f32
                    }),
                })
                .collect(),
            refs: 0,
            in_tree: false,
            last_touch: 0,
        }
    }

    #[test]
    fn offer_then_fetch_roundtrips_pending_and_on_disk() {
        let dir = tmp_dir("roundtrip");
        let s = store(&dir, 1 << 20);
        let key: Vec<u32> = (0..16).collect();
        let b = block(&key[8..]);
        let out = s.offer(key.clone(), b.clone()).expect("first offer indexes");
        assert!(out.bytes > 0);
        // before flush the pending copy serves; after flush the file does
        for stage in ["pending", "flushed"] {
            match s.fetch(&key) {
                Fetch::Hit(got) => {
                    assert_eq!(got.tokens, b.tokens, "{stage}");
                    assert_eq!(got.layers[1].keys, b.layers[1].keys, "{stage}");
                }
                _ => panic!("{stage}: expected a hit"),
            }
            s.flush();
        }
        assert_eq!(s.entries(), 1);
        assert_eq!(s.indexed_bytes(), out.bytes as usize);
        // re-offer of an indexed key is a touch, not a rewrite
        assert!(s.offer(key.clone(), b).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn budget_drops_lru_entries_and_their_files() {
        let dir = tmp_dir("budget");
        let one = {
            let b = block(&[0; 8]);
            let (d_k, d_v) = (4, 4);
            record::encoded_len(8, b.layers.len(), d_k, d_v)
        };
        let s = store(&dir, 2 * one + one / 2); // fits two records
        for i in 0..3u32 {
            let key: Vec<u32> = (i * 8..i * 8 + 8).collect();
            let out = s.offer(key, block(&[i; 8])).expect("offer indexes");
            if i == 2 {
                assert_eq!(out.evicted, 1, "third insert must drop the LRU entry");
            }
        }
        s.flush();
        assert_eq!(s.entries(), 2);
        // the oldest key is gone, the two newest serve
        assert!(matches!(s.fetch(&(0..8).collect::<Vec<_>>()), Fetch::Miss));
        assert!(matches!(s.fetch(&(8..16).collect::<Vec<_>>()), Fetch::Hit(_)));
        assert!(matches!(s.fetch(&(16..24).collect::<Vec<_>>()), Fetch::Hit(_)));
        // exactly two record files remain on disk
        let files = std::fs::read_dir(&dir).unwrap().count();
        assert_eq!(files, 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_record_is_a_dropped_miss_never_served() {
        let dir = tmp_dir("corrupt");
        let s = store(&dir, 1 << 20);
        let key: Vec<u32> = (0..8).collect();
        s.offer(key.clone(), block(&key)).unwrap();
        s.flush();
        let path = s.record_path(&key).unwrap();
        // truncate the record mid-payload (a torn write)
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(matches!(s.fetch(&key), Fetch::Corrupt));
        // the entry and file are gone; the next probe is a plain miss
        assert!(matches!(s.fetch(&key), Fetch::Miss));
        s.flush();
        assert!(!path.exists(), "corrupt record file must be deleted");
        assert_eq!(s.indexed_bytes(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn oversized_record_is_refused() {
        let dir = tmp_dir("oversize");
        let s = store(&dir, 64);
        assert!(s.offer((0..8).collect(), block(&[1; 8])).is_none());
        assert_eq!(s.entries(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }
}
