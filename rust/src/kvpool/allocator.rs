//! Slab allocator for blocks plus the pool's global float-budget ledger.
//!
//! Every stored float — block pages *and* per-sequence private tails —
//! is charged against one `used_floats` gauge, so the pressure ladder has
//! a single number to compare against the configured budget. `peak_floats`
//! tracks the high-water mark for capacity reporting (`bytes-per-token`
//! in the `kvpool` bench divides it by logical tokens served).

use super::block::{Block, BlockId};

/// Block slab + global accounting.
pub struct BlockStore {
    slots: Vec<Option<Block>>,
    free: Vec<BlockId>,
    n_blocks: usize,
    used_floats: usize,
    peak_floats: usize,
}

impl BlockStore {
    /// Empty store with a zeroed ledger.
    pub fn new() -> Self {
        BlockStore { slots: Vec::new(), free: Vec::new(), n_blocks: 0, used_floats: 0, peak_floats: 0 }
    }

    /// Insert a sealed block, charging its footprint. Returns its id.
    pub fn insert(&mut self, block: Block) -> BlockId {
        self.charge(block.footprint_floats());
        self.n_blocks += 1;
        match self.free.pop() {
            Some(id) => {
                self.slots[id] = Some(block);
                id
            }
            None => {
                self.slots.push(Some(block));
                self.slots.len() - 1
            }
        }
    }

    /// Remove a block, crediting its footprint back to the ledger.
    pub fn remove(&mut self, id: BlockId) -> Block {
        let block = self.slots[id].take().expect("remove of free block slot");
        self.credit(block.footprint_floats());
        self.n_blocks -= 1;
        self.free.push(id);
        block
    }

    /// Borrow a live block. Panics on a freed slot.
    pub fn get(&self, id: BlockId) -> &Block {
        self.slots[id].as_ref().expect("get of free block slot")
    }

    /// Mutably borrow a live block (refcount/LRU updates only — the KV
    /// payload is sealed). Panics on a freed slot.
    pub fn get_mut(&mut self, id: BlockId) -> &mut Block {
        self.slots[id].as_mut().expect("get_mut of free block slot")
    }

    /// Charge non-block storage (sequence tails) to the ledger.
    pub fn charge(&mut self, floats: usize) {
        self.used_floats += floats;
        self.peak_floats = self.peak_floats.max(self.used_floats);
    }

    /// Credit non-block storage back.
    pub fn credit(&mut self, floats: usize) {
        debug_assert!(self.used_floats >= floats, "ledger underflow");
        self.used_floats = self.used_floats.saturating_sub(floats);
    }

    /// Floats currently charged (blocks + tails).
    pub fn used_floats(&self) -> usize {
        self.used_floats
    }

    /// High-water mark of [`BlockStore::used_floats`].
    pub fn peak_floats(&self) -> usize {
        self.peak_floats
    }

    /// Live (non-freed) blocks in the slab.
    pub fn n_blocks(&self) -> usize {
        self.n_blocks
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvpool::block::BlockLayer;
    use crate::linalg::Matrix;

    fn blk(n: usize) -> Block {
        Block {
            tokens: (0..n as u32).collect(),
            layers: vec![BlockLayer { keys: Matrix::zeros(n, 2), values: Matrix::zeros(n, 2) }],
            refs: 0,
            in_tree: false,
            last_touch: 0,
        }
    }

    #[test]
    fn insert_remove_roundtrip_and_ledger() {
        let mut s = BlockStore::new();
        let a = s.insert(blk(4)); // 4*2 + 4*2 = 16 floats
        let b = s.insert(blk(2)); // 8 floats
        assert_eq!(s.used_floats(), 24);
        assert_eq!(s.peak_floats(), 24);
        assert_eq!(s.n_blocks(), 2);
        assert_eq!(s.get(a).n_tokens(), 4);
        s.remove(a);
        assert_eq!(s.used_floats(), 8);
        assert_eq!(s.peak_floats(), 24, "peak is sticky");
        // freed slot is reused
        let c = s.insert(blk(1));
        assert_eq!(c, a);
        assert_eq!(s.get(b).n_tokens(), 2);
    }

    #[test]
    fn tail_charges_share_the_ledger() {
        let mut s = BlockStore::new();
        s.charge(100);
        s.insert(blk(2));
        assert_eq!(s.used_floats(), 108);
        s.credit(100);
        assert_eq!(s.used_floats(), 8);
        assert_eq!(s.peak_floats(), 108);
    }
}
