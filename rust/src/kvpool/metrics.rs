//! Pool observability: lock-free counters plus a consistent snapshot of
//! the ledger gauges, serialisable into the serving-metrics JSON
//! documents (`wildcat serve --metrics-json`, `Router::metrics_json`).

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

/// Monotonic pool counters (updated under the pool lock, read lock-free).
#[derive(Default)]
pub struct PoolMetrics {
    pub(crate) prefix_queries: AtomicU64,
    pub(crate) prefix_hits: AtomicU64,
    pub(crate) shared_tokens: AtomicU64,
    pub(crate) tier_compressions: AtomicU64,
    pub(crate) evicted_blocks: AtomicU64,
    pub(crate) admission_rejects: AtomicU64,
}

impl PoolMetrics {
    pub(crate) fn add(counter: &AtomicU64, n: u64) {
        counter.fetch_add(n, Ordering::Relaxed);
    }
}

/// Point-in-time view of one pool, in plain numbers.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolSnapshot {
    /// Configured global budget in floats (0 = unbounded).
    pub budget_floats: usize,
    /// Floats currently charged to the ledger (blocks + tails).
    pub used_floats: usize,
    /// High-water mark of `used_floats` since pool creation.
    pub peak_floats: usize,
    /// Registered sequences.
    pub sequences: usize,
    /// Live blocks in the slab.
    pub blocks: usize,
    /// Blocks currently referenced by the radix prefix index.
    pub tree_blocks: usize,
    /// Prefix lookups performed (one per registration/lookup with sharing on).
    pub prefix_queries: u64,
    /// Lookups that matched at least one block.
    pub prefix_hits: u64,
    /// Prompt tokens served from shared blocks instead of new storage.
    pub shared_tokens: u64,
    /// Compression-tier firings of the pressure ladder.
    pub tier_compressions: u64,
    /// Cached prefix blocks reclaimed by the eviction tier.
    pub evicted_blocks: u64,
    /// Prefill registrations rejected after both reclaim tiers came up short.
    pub admission_rejects: u64,
}

impl PoolSnapshot {
    /// `used_floats` in bytes (4 bytes per float).
    pub fn used_bytes(&self) -> usize {
        self.used_floats * 4
    }

    /// `peak_floats` in bytes (4 bytes per float).
    pub fn peak_bytes(&self) -> usize {
        self.peak_floats * 4
    }

    /// Fraction of prefill registrations that reused at least one block.
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prefix_queries == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / self.prefix_queries as f64
        }
    }

    /// Write the pool gauges into a Prometheus text-exposition builder,
    /// attaching `labels` (e.g. `[("replica", "0")]`) to every sample.
    pub fn prom_write(&self, b: &mut crate::obs::PromBuilder, labels: &[(&str, &str)]) {
        b.declare("wildcat_kv_pool_bytes", "gauge", "KV pool ledger bytes (used and peak).");
        for (state, v) in [("used", self.used_bytes()), ("peak", self.peak_bytes())] {
            let mut ls = labels.to_vec();
            ls.push(("state", state));
            b.sample("wildcat_kv_pool_bytes", &ls, v as f64);
        }
        b.declare("wildcat_kv_pool_sequences", "gauge", "Sequences registered in the pool.");
        b.sample("wildcat_kv_pool_sequences", labels, self.sequences as f64);
        b.declare("wildcat_kv_pool_blocks", "gauge", "Live blocks in the pool slab.");
        b.sample("wildcat_kv_pool_blocks", labels, self.blocks as f64);
        b.declare("wildcat_kv_prefix_hit_rate", "gauge", "Prefix-sharing block hit rate.");
        b.sample("wildcat_kv_prefix_hit_rate", labels, self.prefix_hit_rate());
        let counters: [(&str, &str, u64); 3] = [
            (
                "wildcat_kv_tier_compressions_total",
                "Compression-tier firings of the pressure ladder.",
                self.tier_compressions,
            ),
            (
                "wildcat_kv_evicted_blocks_total",
                "Cached prefix blocks reclaimed by eviction.",
                self.evicted_blocks,
            ),
            (
                "wildcat_kv_admission_rejects_total",
                "Prefill registrations rejected under pressure.",
                self.admission_rejects,
            ),
        ];
        for (name, help, v) in counters {
            b.declare(name, "counter", help);
            b.sample(name, labels, v as f64);
        }
    }

    /// Serialise as the `"kv"` block of the serving metrics documents.
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("budget_bytes".into(), Json::Num((self.budget_floats * 4) as f64));
        o.insert("used_bytes".into(), Json::Num(self.used_bytes() as f64));
        o.insert("peak_bytes".into(), Json::Num(self.peak_bytes() as f64));
        o.insert("sequences".into(), Json::Num(self.sequences as f64));
        o.insert("blocks".into(), Json::Num(self.blocks as f64));
        o.insert("tree_blocks".into(), Json::Num(self.tree_blocks as f64));
        o.insert("prefix_queries".into(), Json::Num(self.prefix_queries as f64));
        o.insert("prefix_hits".into(), Json::Num(self.prefix_hits as f64));
        o.insert("prefix_hit_rate".into(), Json::Num(self.prefix_hit_rate()));
        o.insert("shared_tokens".into(), Json::Num(self.shared_tokens as f64));
        o.insert("tier_compressions".into(), Json::Num(self.tier_compressions as f64));
        o.insert("evicted_blocks".into(), Json::Num(self.evicted_blocks as f64));
        o.insert("admission_rejects".into(), Json::Num(self.admission_rejects as f64));
        Json::Obj(o)
    }
}

/// Sum per-replica pool snapshots into one cluster-level gauge block —
/// what `Router::metrics_json` reports as `"kv"` next to the routing
/// aggregate (peaks are summed too: replicas hold disjoint pools, so the
/// cluster's worst-case footprint is the sum of per-replica worst cases).
pub fn aggregate_snapshots(snaps: &[PoolSnapshot]) -> PoolSnapshot {
    let mut agg = PoolSnapshot::default();
    for s in snaps {
        agg.budget_floats += s.budget_floats;
        agg.used_floats += s.used_floats;
        agg.peak_floats += s.peak_floats;
        agg.sequences += s.sequences;
        agg.blocks += s.blocks;
        agg.tree_blocks += s.tree_blocks;
        agg.prefix_queries += s.prefix_queries;
        agg.prefix_hits += s.prefix_hits;
        agg.shared_tokens += s.shared_tokens;
        agg.tier_compressions += s.tier_compressions;
        agg.evicted_blocks += s.evicted_blocks;
        agg.admission_rejects += s.admission_rejects;
    }
    agg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_json_parses_back() {
        let s = PoolSnapshot {
            budget_floats: 1000,
            used_floats: 600,
            peak_floats: 900,
            sequences: 3,
            blocks: 5,
            tree_blocks: 4,
            prefix_queries: 10,
            prefix_hits: 4,
            shared_tokens: 128,
            tier_compressions: 2,
            evicted_blocks: 1,
            admission_rejects: 0,
        };
        assert_eq!(s.used_bytes(), 2400);
        assert!((s.prefix_hit_rate() - 0.4).abs() < 1e-12);
        let j = s.to_json();
        let text = j.to_string_compact();
        assert_eq!(crate::util::json::parse(&text).unwrap(), j);
        assert_eq!(j.get("peak_bytes").and_then(Json::as_f64), Some(3600.0));
    }

    #[test]
    fn aggregation_sums_gauges() {
        let a = PoolSnapshot { used_floats: 10, prefix_hits: 1, prefix_queries: 2, ..Default::default() };
        let b = PoolSnapshot { used_floats: 30, prefix_hits: 1, prefix_queries: 2, ..Default::default() };
        let agg = aggregate_snapshots(&[a, b]);
        assert_eq!(agg.used_floats, 40);
        assert_eq!(agg.prefix_queries, 4);
        assert!((agg.prefix_hit_rate() - 0.5).abs() < 1e-12);
        // zero-query aggregate divides safely
        assert_eq!(aggregate_snapshots(&[]).prefix_hit_rate(), 0.0);
    }
}
