//! Pool observability: lock-free counters plus a consistent snapshot of
//! the ledger gauges, serialisable into the serving-metrics JSON
//! documents (`wildcat serve --metrics-json`, `Router::metrics_json`).

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

/// Monotonic pool counters (updated under the pool lock, read lock-free).
#[derive(Default)]
pub struct PoolMetrics {
    pub(crate) prefix_queries: AtomicU64,
    pub(crate) prefix_hits: AtomicU64,
    pub(crate) shared_tokens: AtomicU64,
    pub(crate) tier_compressions: AtomicU64,
    pub(crate) evicted_blocks: AtomicU64,
    pub(crate) admission_rejects: AtomicU64,
    // spill-tier counters: only ever touched when the tier is configured
    pub(crate) spills: AtomicU64,
    pub(crate) spill_bytes: AtomicU64,
    pub(crate) spill_evictions: AtomicU64,
    pub(crate) page_ins: AtomicU64,
    pub(crate) pagein_tokens: AtomicU64,
    pub(crate) spill_corrupt: AtomicU64,
}

impl PoolMetrics {
    pub(crate) fn add(counter: &AtomicU64, n: u64) {
        counter.fetch_add(n, Ordering::Relaxed);
    }
}

/// Point-in-time view of one pool, in plain numbers.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolSnapshot {
    /// Configured global budget in floats (0 = unbounded).
    pub budget_floats: usize,
    /// Floats currently charged to the ledger (blocks + tails).
    pub used_floats: usize,
    /// High-water mark of `used_floats` since pool creation.
    pub peak_floats: usize,
    /// Registered sequences.
    pub sequences: usize,
    /// Live blocks in the slab.
    pub blocks: usize,
    /// Blocks currently referenced by the radix prefix index.
    pub tree_blocks: usize,
    /// Prefix lookups performed (one per registration/lookup with sharing on).
    pub prefix_queries: u64,
    /// Lookups that matched at least one block.
    pub prefix_hits: u64,
    /// Prompt tokens served from shared blocks instead of new storage.
    pub shared_tokens: u64,
    /// Compression-tier firings of the pressure ladder.
    pub tier_compressions: u64,
    /// Cached prefix blocks reclaimed by the eviction tier.
    pub evicted_blocks: u64,
    /// Prefill registrations rejected after both reclaim tiers came up short.
    pub admission_rejects: u64,
    /// Spill-tier gauges and counters; `None` when the tier is off, in
    /// which case no spill keys appear in any export (bit-identity with
    /// a spill-less build).
    pub spill: Option<SpillSnapshot>,
}

/// Point-in-time view of the spill tier of one pool.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpillSnapshot {
    /// Configured cold-index byte budget.
    pub budget_bytes: usize,
    /// Bytes currently charged to the cold index.
    pub used_bytes: usize,
    /// Entries currently in the cold index.
    pub entries: usize,
    /// Evicted blocks accepted by the cold store.
    pub spills: u64,
    /// Record bytes written (cumulative) by accepted spills.
    pub spill_bytes: u64,
    /// Cold-index entries dropped LRU-first to hold the byte budget.
    pub spill_evictions: u64,
    /// Spilled blocks rematerialised into the pool on prefix lookups.
    pub page_ins: u64,
    /// Prompt tokens those page-ins covered (prefill work saved).
    pub pagein_tokens: u64,
    /// Records that failed integrity verification (served as misses).
    pub spill_corrupt: u64,
}

impl PoolSnapshot {
    /// `used_floats` in bytes (4 bytes per float).
    pub fn used_bytes(&self) -> usize {
        self.used_floats * 4
    }

    /// `peak_floats` in bytes (4 bytes per float).
    pub fn peak_bytes(&self) -> usize {
        self.peak_floats * 4
    }

    /// Fraction of prefill registrations that reused at least one block.
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prefix_queries == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / self.prefix_queries as f64
        }
    }

    /// Write the pool gauges into a Prometheus text-exposition builder,
    /// attaching `labels` (e.g. `[("replica", "0")]`) to every sample.
    pub fn prom_write(&self, b: &mut crate::obs::PromBuilder, labels: &[(&str, &str)]) {
        b.declare("wildcat_kv_pool_bytes", "gauge", "KV pool ledger bytes (used and peak).");
        for (state, v) in [("used", self.used_bytes()), ("peak", self.peak_bytes())] {
            let mut ls = labels.to_vec();
            ls.push(("state", state));
            b.sample("wildcat_kv_pool_bytes", &ls, v as f64);
        }
        b.declare("wildcat_kv_pool_sequences", "gauge", "Sequences registered in the pool.");
        b.sample("wildcat_kv_pool_sequences", labels, self.sequences as f64);
        b.declare("wildcat_kv_pool_blocks", "gauge", "Live blocks in the pool slab.");
        b.sample("wildcat_kv_pool_blocks", labels, self.blocks as f64);
        b.declare("wildcat_kv_prefix_hit_rate", "gauge", "Prefix-sharing block hit rate.");
        b.sample("wildcat_kv_prefix_hit_rate", labels, self.prefix_hit_rate());
        let counters: [(&str, &str, u64); 3] = [
            (
                "wildcat_kv_tier_compressions_total",
                "Compression-tier firings of the pressure ladder.",
                self.tier_compressions,
            ),
            (
                "wildcat_kv_evicted_blocks_total",
                "Cached prefix blocks reclaimed by eviction.",
                self.evicted_blocks,
            ),
            (
                "wildcat_kv_admission_rejects_total",
                "Prefill registrations rejected under pressure.",
                self.admission_rejects,
            ),
        ];
        for (name, help, v) in counters {
            b.declare(name, "counter", help);
            b.sample(name, labels, v as f64);
        }
        // spill families exist only when the tier is configured, so a
        // spill-less run's exposition is byte-identical to pre-spill builds
        if let Some(sp) = &self.spill {
            b.declare("wildcat_spill_bytes", "gauge", "Spill cold-index bytes (used and budget).");
            for (state, v) in [("used", sp.used_bytes), ("budget", sp.budget_bytes)] {
                let mut ls = labels.to_vec();
                ls.push(("state", state));
                b.sample("wildcat_spill_bytes", &ls, v as f64);
            }
            b.declare("wildcat_spill_entries", "gauge", "Entries in the spill cold index.");
            b.sample("wildcat_spill_entries", labels, sp.entries as f64);
            let spill_counters: [(&str, &str, u64); 6] = [
                ("wildcat_spill_blocks_total", "Evicted blocks accepted by the spill tier.", sp.spills),
                ("wildcat_spill_written_bytes_total", "Record bytes written by the spill tier.", sp.spill_bytes),
                (
                    "wildcat_spill_evictions_total",
                    "Cold-index entries dropped to hold the spill budget.",
                    sp.spill_evictions,
                ),
                (
                    "wildcat_spill_page_ins_total",
                    "Spilled blocks rematerialised on prefix lookups.",
                    sp.page_ins,
                ),
                (
                    "wildcat_spill_pagein_tokens_total",
                    "Prompt tokens served from paged-in blocks.",
                    sp.pagein_tokens,
                ),
                (
                    "wildcat_spill_corrupt_total",
                    "Spill records that failed integrity verification.",
                    sp.spill_corrupt,
                ),
            ];
            for (name, help, v) in spill_counters {
                b.declare(name, "counter", help);
                b.sample(name, labels, v as f64);
            }
        }
    }

    /// Serialise as the `"kv"` block of the serving metrics documents.
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("budget_bytes".into(), Json::Num((self.budget_floats * 4) as f64));
        o.insert("used_bytes".into(), Json::Num(self.used_bytes() as f64));
        o.insert("peak_bytes".into(), Json::Num(self.peak_bytes() as f64));
        o.insert("sequences".into(), Json::Num(self.sequences as f64));
        o.insert("blocks".into(), Json::Num(self.blocks as f64));
        o.insert("tree_blocks".into(), Json::Num(self.tree_blocks as f64));
        o.insert("prefix_queries".into(), Json::Num(self.prefix_queries as f64));
        o.insert("prefix_hits".into(), Json::Num(self.prefix_hits as f64));
        o.insert("prefix_hit_rate".into(), Json::Num(self.prefix_hit_rate()));
        o.insert("shared_tokens".into(), Json::Num(self.shared_tokens as f64));
        o.insert("tier_compressions".into(), Json::Num(self.tier_compressions as f64));
        o.insert("evicted_blocks".into(), Json::Num(self.evicted_blocks as f64));
        o.insert("admission_rejects".into(), Json::Num(self.admission_rejects as f64));
        if let Some(sp) = &self.spill {
            let mut s = BTreeMap::new();
            s.insert("budget_bytes".into(), Json::Num(sp.budget_bytes as f64));
            s.insert("used_bytes".into(), Json::Num(sp.used_bytes as f64));
            s.insert("entries".into(), Json::Num(sp.entries as f64));
            s.insert("spills".into(), Json::Num(sp.spills as f64));
            s.insert("spill_bytes".into(), Json::Num(sp.spill_bytes as f64));
            s.insert("spill_evictions".into(), Json::Num(sp.spill_evictions as f64));
            s.insert("page_ins".into(), Json::Num(sp.page_ins as f64));
            s.insert("pagein_tokens".into(), Json::Num(sp.pagein_tokens as f64));
            s.insert("spill_corrupt".into(), Json::Num(sp.spill_corrupt as f64));
            o.insert("spill".into(), Json::Obj(s));
        }
        Json::Obj(o)
    }
}

/// Sum per-replica pool snapshots into one cluster-level gauge block —
/// what `Router::metrics_json` reports as `"kv"` next to the routing
/// aggregate (peaks are summed too: replicas hold disjoint pools, so the
/// cluster's worst-case footprint is the sum of per-replica worst cases).
pub fn aggregate_snapshots(snaps: &[PoolSnapshot]) -> PoolSnapshot {
    let mut agg = PoolSnapshot::default();
    for s in snaps {
        agg.budget_floats += s.budget_floats;
        agg.used_floats += s.used_floats;
        agg.peak_floats += s.peak_floats;
        agg.sequences += s.sequences;
        agg.blocks += s.blocks;
        agg.tree_blocks += s.tree_blocks;
        agg.prefix_queries += s.prefix_queries;
        agg.prefix_hits += s.prefix_hits;
        agg.shared_tokens += s.shared_tokens;
        agg.tier_compressions += s.tier_compressions;
        agg.evicted_blocks += s.evicted_blocks;
        agg.admission_rejects += s.admission_rejects;
        // the aggregate reports spill gauges iff any replica runs the tier
        if let Some(sp) = &s.spill {
            let a = agg.spill.get_or_insert_with(SpillSnapshot::default);
            a.budget_bytes += sp.budget_bytes;
            a.used_bytes += sp.used_bytes;
            a.entries += sp.entries;
            a.spills += sp.spills;
            a.spill_bytes += sp.spill_bytes;
            a.spill_evictions += sp.spill_evictions;
            a.page_ins += sp.page_ins;
            a.pagein_tokens += sp.pagein_tokens;
            a.spill_corrupt += sp.spill_corrupt;
        }
    }
    agg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_json_parses_back() {
        let s = PoolSnapshot {
            budget_floats: 1000,
            used_floats: 600,
            peak_floats: 900,
            sequences: 3,
            blocks: 5,
            tree_blocks: 4,
            prefix_queries: 10,
            prefix_hits: 4,
            shared_tokens: 128,
            tier_compressions: 2,
            evicted_blocks: 1,
            admission_rejects: 0,
            spill: None,
        };
        assert_eq!(s.used_bytes(), 2400);
        assert!((s.prefix_hit_rate() - 0.4).abs() < 1e-12);
        let j = s.to_json();
        let text = j.to_string_compact();
        assert_eq!(crate::util::json::parse(&text).unwrap(), j);
        assert_eq!(j.get("peak_bytes").and_then(Json::as_f64), Some(3600.0));
        assert!(j.get("spill").is_none(), "spill off must add no JSON keys");

        // spill on: a nested block appears and parses back
        let with = PoolSnapshot {
            spill: Some(SpillSnapshot {
                budget_bytes: 4096,
                used_bytes: 1024,
                entries: 2,
                spills: 5,
                spill_bytes: 2048,
                spill_evictions: 1,
                page_ins: 3,
                pagein_tokens: 48,
                spill_corrupt: 1,
            }),
            ..s
        };
        let j = with.to_json();
        assert_eq!(crate::util::json::parse(&j.to_string_compact()).unwrap(), j);
        let sp = j.get("spill").expect("spill block present when the tier is on");
        assert_eq!(sp.get("page_ins").and_then(Json::as_f64), Some(3.0));
        assert_eq!(sp.get("spill_corrupt").and_then(Json::as_f64), Some(1.0));
    }

    #[test]
    fn prom_spill_families_gated_on_tier() {
        let off = PoolSnapshot::default();
        let mut b = crate::obs::PromBuilder::new();
        off.prom_write(&mut b, &[("replica", "0")]);
        assert!(!b.finish().contains("wildcat_spill_"), "spill off must add no prom families");

        let on = PoolSnapshot {
            spill: Some(SpillSnapshot { spills: 7, ..Default::default() }),
            ..Default::default()
        };
        let mut b = crate::obs::PromBuilder::new();
        on.prom_write(&mut b, &[("replica", "0")]);
        let text = b.finish();
        assert!(text.contains("wildcat_spill_blocks_total{replica=\"0\"} 7"));
        assert!(text.contains("wildcat_spill_corrupt_total"));
    }

    #[test]
    fn aggregation_sums_gauges() {
        let a = PoolSnapshot { used_floats: 10, prefix_hits: 1, prefix_queries: 2, ..Default::default() };
        let b = PoolSnapshot { used_floats: 30, prefix_hits: 1, prefix_queries: 2, ..Default::default() };
        let agg = aggregate_snapshots(&[a, b]);
        assert_eq!(agg.used_floats, 40);
        assert_eq!(agg.prefix_queries, 4);
        assert!((agg.prefix_hit_rate() - 0.5).abs() < 1e-12);
        assert!(agg.spill.is_none(), "no replica spills => no aggregate spill block");
        // zero-query aggregate divides safely
        assert_eq!(aggregate_snapshots(&[]).prefix_hit_rate(), 0.0);

        // a mixed fleet still aggregates the spilling replicas
        let c = PoolSnapshot {
            spill: Some(SpillSnapshot { spills: 2, page_ins: 1, ..Default::default() }),
            ..Default::default()
        };
        let agg = aggregate_snapshots(&[a, c, c]);
        let sp = agg.spill.expect("any spilling replica => aggregate spill block");
        assert_eq!(sp.spills, 4);
        assert_eq!(sp.page_ins, 2);
    }
}
