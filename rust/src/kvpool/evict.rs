//! The pressure ladder: what the pool does when it runs out of budget,
//! ordered from information-free to lossy to fatal:
//!
//! 1. **Evict cached prefixes** — LRU leaf blocks of the radix index
//!    with no active sequence mapping are pure cache (their rows can be
//!    recomputed by a future prefill), so dropping them loses nothing.
//! 2. **Compress cold sequences** — the configured [`KvCompressor`]
//!    shrinks the least-recently-touched sequences in place to
//!    `compress_budget` physical entries per layer-head (folding their
//!    shared-block mappings into a private coreset, which in turn frees
//!    blocks for step 1 to reclaim).
//! 3. **Reject admission** — only [`super::KvPool::register_prefill`]
//!    can fail, and only after both tiers came up short; decode appends
//!    always succeed so accepted sequences always finish.

use super::metrics::PoolMetrics;
use super::{compress_seq_impl, KvPoolConfig, PoolInner};
use crate::kvcache::KvCompressor;
use crate::obs::trace::{self, SpanKind, NO_REQ};
use std::sync::atomic::Ordering;
use std::time::Instant;

/// Drive `used_floats` down toward `target_floats` (best effort).
pub(crate) fn reclaim(
    g: &mut PoolInner,
    cfg: &KvPoolConfig,
    compressor: &dyn KvCompressor,
    metrics: &PoolMetrics,
    target_floats: usize,
) {
    // traced as one `evict` span on the replica's maintenance lane,
    // carrying how much each ladder tier reclaimed
    let t0 = if trace::enabled() { Some(Instant::now()) } else { None };
    let evicted0 = metrics.evicted_blocks.load(Ordering::Relaxed);
    let tiers0 = metrics.tier_compressions.load(Ordering::Relaxed);
    reclaim_inner(g, cfg, compressor, metrics, target_floats);
    if let Some(t0) = t0 {
        let evicted = metrics.evicted_blocks.load(Ordering::Relaxed) - evicted0;
        let tiers = metrics.tier_compressions.load(Ordering::Relaxed) - tiers0;
        if evicted + tiers > 0 {
            trace::span(SpanKind::Evict, t0, Instant::now(), NO_REQ, evicted, tiers);
        }
    }
}

fn reclaim_inner(
    g: &mut PoolInner,
    cfg: &KvPoolConfig,
    compressor: &dyn KvCompressor,
    metrics: &PoolMetrics,
    target_floats: usize,
) {
    evict_blocks(g, metrics, target_floats);
    if g.store.used_floats() <= target_floats {
        return;
    }
    // Error-SLO degradation pauses the lossy rung: while the audited
    // windowed p99 is in breach, the ladder runs evict-only and the pool
    // rides closer to its budget rather than compounding approximation
    // error with further folds.
    if g.audit.as_deref().is_some_and(|a| a.is_degraded()) {
        return;
    }
    // Compression tier: coldest first, one attempt per sequence per
    // reclaim call (compressing can transiently raise usage while the
    // freed blocks wait for eviction, so interleave the two tiers).
    let mut cands: Vec<(u64, u64)> = g
        .seqs
        .iter()
        .filter(|(_, s)| s.phys_max(&g.store) > cfg.compress_budget)
        .map(|(&seq, s)| (s.last_touch, seq))
        .collect();
    cands.sort_unstable();
    let clock = g.clock;
    let mut rng = g.rng.fork(clock);
    for (_, seq) in cands {
        if g.store.used_floats() <= target_floats {
            break;
        }
        if compress_seq_impl(g, compressor, seq, cfg.compress_budget, None, &mut rng) > 0 {
            PoolMetrics::add(&metrics.tier_compressions, 1);
        }
        evict_blocks(g, metrics, target_floats);
    }
}

/// Evict LRU unreferenced leaf blocks until the target is met or nothing
/// evictable remains. Removing a leaf can expose its parent as the next
/// candidate, so the scan repeats until a pass finds nothing.
///
/// With a spill tier configured the evicted block is not destroyed: it
/// is *moved* (zero-copy) into the cold store keyed by its full
/// root-to-block token prefix, so a later lookup of the same prefix
/// pages it back instead of recomputing the rows. The disk write happens
/// on the spill store's background thread — this path only hands the
/// block over.
fn evict_blocks(g: &mut PoolInner, metrics: &PoolMetrics, target_floats: usize) {
    let spill = g.spill.clone();
    while g.store.used_floats() > target_floats {
        let victim = g
            .radix
            .leaves()
            .into_iter()
            .filter(|&idx| g.store.get(g.radix.node_block(idx)).refs == 0)
            .min_by_key(|&idx| g.store.get(g.radix.node_block(idx)).last_touch);
        match victim {
            Some(idx) => {
                // the key must be read before the leaf is unlinked
                let key = spill.as_ref().map(|_| g.radix.path_tokens(idx));
                let id = g.radix.remove_leaf(idx);
                let block = g.store.remove(id);
                PoolMetrics::add(&metrics.evicted_blocks, 1);
                if let (Some(s), Some(key)) = (spill.as_deref(), key) {
                    if let Some(out) = s.offer(key, block) {
                        PoolMetrics::add(&metrics.spills, 1);
                        PoolMetrics::add(&metrics.spill_bytes, out.bytes);
                        PoolMetrics::add(&metrics.spill_evictions, out.evicted);
                    }
                }
            }
            None => break,
        }
    }
}
