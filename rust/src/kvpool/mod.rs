//! `kvpool` — the block-paged KV memory manager.
//!
//! The serving stack's single authoritative store for KV-cache bytes:
//!
//! * **Paged storage** ([`block`], [`allocator`]) — prompt-prefix KV rows
//!   are sealed into immutable fixed-size blocks charged against one
//!   global float budget; everything else (divergent prompt tokens,
//!   decode appends, compressed coresets) lives in per-sequence private
//!   tails charged against the same ledger.
//! * **Prefix sharing** ([`radix`]) — a radix index over token chunks
//!   lets sequences whose prompts share a prefix map the *same* blocks
//!   (reference-counted), so the shared rows are stored once. Blocks are
//!   immutable, which makes copy-on-write trivial: the first divergent
//!   append simply lands in the appending sequence's private tail.
//! * **Pressure ladder** ([`evict`]) — when the pool crosses its
//!   high-water mark it first evicts LRU *unreferenced* cached prefix
//!   blocks (pure cache, information-free), then compresses cold
//!   sequences in place through the configured [`KvCompressor`] (coreset
//!   compression as an eviction *tier*, the paper's Sec. 4.3 policies
//!   reused unchanged — this also frees the sequences' blocks for the
//!   eviction rung), and only rejects admission when neither tier can
//!   reclaim enough.
//!
//! Decode-time appends never fail: only prefill registration
//! ([`KvPool::register_prefill`]) is subject to admission control, so an
//! accepted sequence always runs to completion.

#![warn(missing_docs)]

pub mod allocator;
pub mod block;
pub mod evict;
pub mod metrics;
pub mod radix;
pub mod spill;

pub use metrics::{aggregate_snapshots, PoolMetrics, PoolSnapshot, SpillSnapshot};
pub use spill::{spill_budget_bytes_from_mb, SpillParams, SpillStore};

use crate::kvcache::{CompressionCtx, KvCompressor};
use crate::linalg::Matrix;
use crate::model::CachedPrefix;
use crate::obs::quality::{self, QualityAudit};
use crate::obs::trace;
use crate::rng::Rng;
use allocator::BlockStore;
use block::{Block, BlockId, BlockLayer};
use radix::RadixIndex;
use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

/// Pool configuration (CLI surface: `--kv-budget-mb`, `--prefix-sharing`).
#[derive(Clone, Debug)]
pub struct KvPoolConfig {
    /// Global budget in f32-equivalents; 0 = unbounded (no ladder).
    pub budget_floats: usize,
    /// Tokens per sealed block (prefix-sharing granularity).
    pub block_tokens: usize,
    /// Fraction of the budget above which appends trigger the ladder
    /// opportunistically (admission always enforces the full budget).
    pub high_water: f64,
    /// Whether prompts are deduplicated through the radix index.
    pub prefix_sharing: bool,
    /// Per-layer physical entry target the compression tier shrinks cold
    /// sequences to.
    pub compress_budget: usize,
    /// Seed of the pool's private RNG (ladder compressions fork from it,
    /// keeping fixed-seed runs reproducible).
    pub seed: u64,
    /// Spill-to-disk tier between evict and reject (`--spill-budget-mb`,
    /// `--spill-dir`). `None` (the default) is bit-identical to a
    /// spill-less build: no threads, no counters, no extra branches
    /// taken on the serving path.
    pub spill: Option<SpillParams>,
}

impl Default for KvPoolConfig {
    fn default() -> Self {
        KvPoolConfig {
            budget_floats: 0,
            block_tokens: 16,
            high_water: 0.85,
            prefix_sharing: true,
            compress_budget: 64,
            seed: 0x9E3779B9,
            spill: None,
        }
    }
}

/// Convert a `--kv-budget-mb` operator value to a float budget.
pub fn budget_floats_from_mb(mb: f64) -> usize {
    if mb <= 0.0 {
        0
    } else {
        (mb * 1024.0 * 1024.0 / 4.0).round() as usize
    }
}

/// What the compression tier needs to know about the model: the layer-slot
/// count its [`CompressionCtx::n_layers`] reports (the serving stack uses
/// one slot per (layer, head)) and the attention scale β.
#[derive(Clone, Copy, Debug)]
pub struct CompressDims {
    /// Layer-slot count compressors see (one per (layer, head) here).
    pub n_layers: usize,
    /// Attention inverse-temperature the compressors score under.
    pub beta: f64,
}

/// Admission verdict when the ladder could not reclaim enough.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmitError {
    /// The prompt's new storage does not fit even after both reclaim tiers.
    PoolExhausted {
        /// Floats the registration needed for its unmatched tokens.
        need_floats: usize,
        /// The pool's configured global budget.
        budget_floats: usize,
    },
}

impl std::fmt::Display for AdmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmitError::PoolExhausted { need_floats, budget_floats } => write!(
                f,
                "kv pool exhausted: need {need_floats} floats against a budget of {budget_floats}"
            ),
        }
    }
}

impl std::error::Error for AdmitError {}

/// A prefix-cache hit held between [`KvPool::lookup_prefix`] and
/// [`KvPool::register_resumed`] — the matched block table plus the
/// materialised K/V rows the backend resumes attention from.
///
/// The matched blocks are reference-counted by the handle, so the
/// pressure ladder cannot evict them while the resumed prefill computes.
/// Every handle must be consumed exactly once, either by
/// [`KvPool::register_resumed`] or [`KvPool::release_prefix`].
pub struct PrefixHandle {
    pub(crate) blocks: Vec<BlockId>,
    /// Radix node of the last matched block — the parent new chunks are
    /// sealed under.
    pub(crate) parent: Option<usize>,
    /// The matched prefix's K/V rows, ready for
    /// [`crate::model::ModelBackend::prefill_from`]. `kv.len` is the
    /// matched token count (always a multiple of the pool's
    /// `block_tokens`, and always leaving at least one prompt token
    /// unmatched so the resumed prefill has a position to produce logits
    /// from).
    pub kv: CachedPrefix,
}

impl PrefixHandle {
    /// Prompt tokens covered by the matched blocks.
    pub fn matched_tokens(&self) -> usize {
        self.kv.len
    }

    /// Number of matched blocks.
    pub fn matched_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Whether the lookup matched anything.
    pub fn is_hit(&self) -> bool {
        !self.blocks.is_empty()
    }
}

/// What a prefill registration reused and created.
#[derive(Clone, Copy, Debug, Default)]
pub struct RegisterOutcome {
    /// Prompt tokens served from already-stored blocks.
    pub matched_tokens: usize,
    /// Shared blocks mapped (rather than sealed) by this registration.
    pub matched_blocks: usize,
    /// Full blocks sealed (and indexed) from this prompt.
    pub new_blocks: usize,
}

/// Per-sequence stats for cache accounting.
#[derive(Clone, Copy, Debug, Default)]
pub struct SeqStats {
    /// Max physical entries over the layer-head caches.
    pub physical_max: usize,
    /// Total physical entries across layer-heads.
    pub physical_total: usize,
    /// Total logical tokens represented across layer-heads.
    pub logical_total: usize,
    /// Floats attributable to this sequence (its tails plus every block
    /// it maps — shared blocks count once *per mapping sequence* here,
    /// while the pool ledger charges them once globally).
    pub footprint_floats: usize,
}

/// One layer-head's private storage: rows past the shared blocks —
/// divergent prompt tokens, decode appends, or a compressed coreset.
pub(crate) struct Tail {
    pub keys: Matrix,
    pub values: Matrix,
    pub weights: Vec<f64>,
    /// Logical tokens this tail represents (≥ physical rows once
    /// compressed; excludes tokens covered by the sequence's blocks).
    pub logical: usize,
}

impl Tail {
    fn new(d_k: usize, d_v: usize) -> Self {
        Tail { keys: Matrix::zeros(0, d_k), values: Matrix::zeros(0, d_v), weights: Vec::new(), logical: 0 }
    }

    fn floats(&self) -> usize {
        self.keys.rows() * self.keys.cols()
            + self.values.rows() * self.values.cols()
            + self.weights.len()
    }
}

/// A registered sequence: shared block mappings plus private tails.
pub(crate) struct SeqKv {
    pub n_lh: usize,
    pub d_k: usize,
    pub d_v: usize,
    pub blocks: Vec<BlockId>,
    pub tails: Vec<Tail>,
    pub last_touch: u64,
    /// Compression folds applied to this sequence so far — the fold
    /// index of the quality auditor's deterministic (seq, fold) sampler.
    pub folds: u64,
}

impl SeqKv {
    pub(crate) fn block_tokens(&self, store: &BlockStore) -> usize {
        self.blocks.iter().map(|&b| store.get(b).n_tokens()).sum()
    }

    pub(crate) fn phys_len(&self, store: &BlockStore, lh: usize) -> usize {
        self.block_tokens(store) + self.tails[lh].keys.rows()
    }

    pub(crate) fn phys_max(&self, store: &BlockStore) -> usize {
        let bt = self.block_tokens(store);
        bt + self.tails.iter().map(|t| t.keys.rows()).max().unwrap_or(0)
    }

    fn tail_floats(&self) -> usize {
        self.tails.iter().map(Tail::floats).sum()
    }
}

pub(crate) struct PoolInner {
    pub(crate) store: BlockStore,
    pub(crate) radix: RadixIndex,
    pub(crate) seqs: HashMap<u64, SeqKv>,
    pub(crate) clock: u64,
    pub(crate) dims: Option<CompressDims>,
    pub(crate) rng: Rng,
    pub(crate) audit: Option<Arc<QualityAudit>>,
    pub(crate) spill: Option<Arc<SpillStore>>,
}

/// The shared, thread-safe pool facade.
pub struct KvPool {
    cfg: KvPoolConfig,
    compressor: Arc<dyn KvCompressor>,
    metrics: PoolMetrics,
    inner: Mutex<PoolInner>,
}

impl KvPool {
    /// Create an empty pool with the given budget/sharing configuration
    /// and the compressor its pressure ladder will shrink sequences with.
    pub fn new(cfg: KvPoolConfig, compressor: Arc<dyn KvCompressor>) -> Self {
        let rng = Rng::seed_from(cfg.seed);
        let spill = cfg.spill.as_ref().map(|params| {
            Arc::new(SpillStore::new(params).expect("creating spill store directory"))
        });
        KvPool {
            cfg,
            compressor,
            metrics: PoolMetrics::default(),
            inner: Mutex::new(PoolInner {
                store: BlockStore::new(),
                radix: RadixIndex::new(),
                seqs: HashMap::new(),
                clock: 0,
                dims: None,
                rng,
                audit: None,
                spill,
            }),
        }
    }

    /// The pool's configuration, as constructed.
    pub fn config(&self) -> &KvPoolConfig {
        &self.cfg
    }

    /// Name of the compressor the pressure ladder runs.
    pub fn compressor_name(&self) -> &'static str {
        self.compressor.name()
    }

    /// Record the model dims the pressure ladder compresses under. Safe
    /// to call repeatedly (per-replica pools serve a single model).
    pub fn set_dims(&self, dims: CompressDims) {
        self.inner.lock().unwrap().dims = Some(dims);
    }

    /// Attach the replica's approximation-quality auditor: sampled
    /// compression folds recompute their ground-truth error here, and a
    /// degraded SLO pauses the pressure ladder's compression rung.
    pub fn set_quality_audit(&self, audit: Arc<QualityAudit>) {
        self.inner.lock().unwrap().audit = Some(audit);
    }

    /// Create (or reset) an empty sequence that will be fed by appends.
    pub fn create_sequence(&self, seq: u64, n_lh: usize, d_k: usize, d_v: usize) {
        let mut g = self.inner.lock().unwrap();
        g.clock += 1;
        let now = g.clock;
        drop_seq_inner(&mut g, seq);
        let tails = (0..n_lh).map(|_| Tail::new(d_k, d_v)).collect();
        g.seqs.insert(
            seq,
            SeqKv { n_lh, d_k, d_v, blocks: Vec::new(), tails, last_touch: now, folds: 0 },
        );
    }

    /// Register a prefilled sequence: map shared prefix blocks, seal new
    /// full blocks into the index, keep the remainder as a private tail.
    /// The only pool operation subject to admission control.
    pub fn register_prefill(
        &self,
        seq: u64,
        tokens: &[u32],
        k_cache: &[Matrix],
        v_cache: &[Matrix],
    ) -> Result<RegisterOutcome, AdmitError> {
        let n_lh = k_cache.len();
        assert!(n_lh > 0 && v_cache.len() == n_lh, "empty/mismatched caches");
        let n = tokens.len();
        assert!(
            k_cache.iter().chain(v_cache).all(|m| m.rows() == n),
            "cache rows must match token count"
        );
        let bt = self.cfg.block_tokens.max(1);

        let mut g = self.inner.lock().unwrap();
        g.clock += 1;
        let now = g.clock;
        drop_seq_inner(&mut g, seq);

        // 1. Longest-prefix match against the radix index (incref the
        //    matched blocks immediately so the ladder cannot evict them).
        let mut blocks: Vec<BlockId> = Vec::new();
        let mut matched_tokens = 0;
        let mut parent: Option<usize> = None;
        if self.cfg.prefix_sharing {
            PoolMetrics::add(&self.metrics.prefix_queries, 1);
            let path = g.radix.lookup(tokens, bt);
            for &(node, block) in &path {
                debug_assert_eq!(g.store.get(block).layers.len(), n_lh, "pool reused across models");
                let b = g.store.get_mut(block);
                b.refs += 1;
                b.last_touch = now;
                blocks.push(block);
                matched_tokens += bt;
                parent = Some(node);
            }
            if !blocks.is_empty() {
                PoolMetrics::add(&self.metrics.prefix_hits, 1);
                PoolMetrics::add(&self.metrics.shared_tokens, matched_tokens as u64);
            }
        }
        // 2-4. Admission, sealing, tail — shared with the resumed path;
        // `k_cache` rows are absolute, so the row of token
        // `matched_tokens` is `matched_tokens` itself.
        self.seal_and_register(
            &mut g,
            now,
            seq,
            tokens,
            blocks,
            parent,
            matched_tokens,
            k_cache,
            v_cache,
            matched_tokens,
        )
    }

    /// Token-level prefix match against the radix index, done *before*
    /// compute. The matched blocks are increfed (eviction-safe) and
    /// their K/V rows materialised so the backend can resume prefill
    /// over the unmatched tail ([`crate::model::ModelBackend::prefill_from`]).
    /// Returns an empty (miss) handle when sharing is disabled or nothing
    /// matched; the match is capped to leave at least one prompt token
    /// for the resumed prefill to compute logits from.
    pub fn lookup_prefix(&self, tokens: &[u32]) -> PrefixHandle {
        let bt = self.cfg.block_tokens.max(1);
        let mut g = self.inner.lock().unwrap();
        g.clock += 1;
        let now = g.clock;
        let mut handle =
            PrefixHandle { blocks: Vec::new(), parent: None, kv: CachedPrefix::empty() };
        if !self.cfg.prefix_sharing || tokens.is_empty() {
            return handle;
        }
        PoolMetrics::add(&self.metrics.prefix_queries, 1);
        let mut path = g.radix.lookup(tokens, bt);
        // Spill page-in: where the radix match runs out, consult the
        // cold index for the prompt's next chunks and rematerialise any
        // spilled blocks — re-charged to the ledger, re-linked into the
        // tree — so admission resumes prefill past them instead of
        // recomputing. Every paged chunk is prompt prefix this prompt
        // would otherwise store anyway (as tail or sealed blocks), so
        // paging in never increases the admission footprint.
        if let Some(spill) = g.spill.clone() {
            let t0 = if trace::enabled() { Some(std::time::Instant::now()) } else { None };
            let mut paged_blocks = 0u64;
            loop {
                let matched = path.len() * bt;
                if matched + bt > tokens.len() {
                    break;
                }
                match spill.fetch(&tokens[..matched + bt]) {
                    spill::Fetch::Hit(mut block) => {
                        block.last_touch = now;
                        block.in_tree = true;
                        let parent = path.last().map(|&(node, _)| node);
                        let id = g.store.insert(block);
                        let node =
                            g.radix.insert(parent, tokens[matched..matched + bt].to_vec(), id);
                        path.push((node, id));
                        paged_blocks += 1;
                    }
                    spill::Fetch::Corrupt => {
                        PoolMetrics::add(&self.metrics.spill_corrupt, 1);
                        break;
                    }
                    spill::Fetch::Miss => break,
                }
            }
            if paged_blocks > 0 {
                let paged_tokens = paged_blocks * bt as u64;
                PoolMetrics::add(&self.metrics.page_ins, paged_blocks);
                PoolMetrics::add(&self.metrics.pagein_tokens, paged_tokens);
                if let Some(t0) = t0 {
                    trace::span(
                        trace::SpanKind::PageIn,
                        t0,
                        std::time::Instant::now(),
                        trace::NO_REQ,
                        paged_blocks,
                        paged_tokens,
                    );
                }
            }
        }
        // always leave >= 1 unmatched token: prefill needs a position to
        // produce next-token logits from, so a whole-prompt match resumes
        // from all but its last block
        while !path.is_empty() && path.len() * bt >= tokens.len() {
            path.pop();
        }
        if path.is_empty() {
            return handle;
        }
        let n_lh = g.store.get(path[0].1).layers.len();
        for &(node, block) in &path {
            debug_assert_eq!(g.store.get(block).layers.len(), n_lh, "pool reused across models");
            let b = g.store.get_mut(block);
            b.refs += 1;
            b.last_touch = now;
            handle.blocks.push(block);
            handle.parent = Some(node);
        }
        let matched = path.len() * bt;
        PoolMetrics::add(&self.metrics.prefix_hits, 1);
        PoolMetrics::add(&self.metrics.shared_tokens, matched as u64);
        for lh in 0..n_lh {
            let ks: Vec<&Matrix> =
                handle.blocks.iter().map(|&b| &g.store.get(b).layers[lh].keys).collect();
            let vs: Vec<&Matrix> =
                handle.blocks.iter().map(|&b| &g.store.get(b).layers[lh].values).collect();
            handle.kv.keys.push(Matrix::vcat(&ks));
            handle.kv.values.push(Matrix::vcat(&vs));
        }
        handle.kv.len = matched;
        handle
    }

    /// Register a sequence prefilled *from* a prefix hit: the handle's
    /// blocks become the sequence's shared prefix mapping, and only the
    /// tail caches — rows for the unmatched tokens, as returned by a
    /// resumed prefill — are new storage. Consumes the handle (its
    /// references transfer to the sequence, or are released on
    /// rejection). Subject to the same admission control as
    /// [`KvPool::register_prefill`], charged for the tail only.
    pub fn register_resumed(
        &self,
        seq: u64,
        tokens: &[u32],
        handle: PrefixHandle,
        tail_k: &[Matrix],
        tail_v: &[Matrix],
    ) -> Result<RegisterOutcome, AdmitError> {
        let n_lh = tail_k.len();
        assert!(n_lh > 0 && tail_v.len() == n_lh, "empty/mismatched caches");
        let matched = handle.matched_tokens();
        let n = tokens.len();
        assert!(matched < n, "resume needs at least one tail token");
        assert!(
            tail_k.iter().chain(tail_v).all(|m| m.rows() == n - matched),
            "tail cache rows must cover exactly the unmatched tokens"
        );
        if handle.is_hit() {
            assert_eq!(handle.kv.keys.len(), n_lh, "handle/cache layer-head count mismatch");
        }
        let mut g = self.inner.lock().unwrap();
        g.clock += 1;
        let now = g.clock;
        drop_seq_inner(&mut g, seq);
        // tail rows start at token `matched`, so its row index is 0
        self.seal_and_register(
            &mut g, now, seq, tokens, handle.blocks, handle.parent, matched, tail_k, tail_v, 0,
        )
    }

    /// Release a handle without registering a sequence (a lookup whose
    /// admission was abandoned). Handles must come from this pool.
    pub fn release_prefix(&self, handle: PrefixHandle) {
        let mut g = self.inner.lock().unwrap();
        for id in handle.blocks {
            release_block(&mut g.store, id);
        }
    }

    /// Admission + sealing + tail install, shared by the cold and
    /// resumed registration paths. `k_rows`/`v_rows` hold the computed
    /// cache rows, with token index `matched_tokens` living at row
    /// `base` (cold prefill passes absolute rows with
    /// `base = matched_tokens`; resumed prefill passes tail-only rows
    /// with `base = 0`).
    #[allow(clippy::too_many_arguments)]
    fn seal_and_register(
        &self,
        g: &mut PoolInner,
        now: u64,
        seq: u64,
        tokens: &[u32],
        mut blocks: Vec<BlockId>,
        mut parent: Option<usize>,
        matched_tokens: usize,
        k_rows: &[Matrix],
        v_rows: &[Matrix],
        base: usize,
    ) -> Result<RegisterOutcome, AdmitError> {
        let n = tokens.len();
        let n_lh = k_rows.len();
        let (d_k, d_v) = (k_rows[0].cols(), v_rows[0].cols());
        let bt = self.cfg.block_tokens.max(1);
        let row = |pos: usize| pos - matched_tokens + base;
        let mut matched_tokens = matched_tokens;
        let mut matched_blocks = blocks.len();

        // Admission: everything past the matched prefix is new storage.
        let need = (n - matched_tokens) * n_lh * (d_k + d_v + 1);
        if self.cfg.budget_floats > 0 && g.store.used_floats() + need > self.cfg.budget_floats {
            // a prompt that can never fit (need alone exceeds the whole
            // budget) is rejected up front — running the ladder for it
            // would wipe the prefix cache and lossily compress every
            // live sequence without making the admission possible
            if need <= self.cfg.budget_floats {
                let target = self.cfg.budget_floats - need;
                evict::reclaim(g, &self.cfg, self.compressor.as_ref(), &self.metrics, target);
            }
            if g.store.used_floats() + need > self.cfg.budget_floats {
                for id in blocks {
                    release_block(&mut g.store, id);
                }
                PoolMetrics::add(&self.metrics.admission_rejects, 1);
                return Err(AdmitError::PoolExhausted {
                    need_floats: need,
                    budget_floats: self.cfg.budget_floats,
                });
            }
        }

        // Seal the new full chunks as shared blocks under the matched
        // path, so the *next* request with this prefix hits them.
        let mut pos = matched_tokens;
        let mut new_blocks = 0;
        if self.cfg.prefix_sharing {
            while pos + bt <= n {
                let chunk = &tokens[pos..pos + bt];
                if let Some(idx) = g.radix.child(parent, chunk) {
                    // another registration sealed this chunk between a
                    // lookup and this seal — map its block instead of
                    // inserting a duplicate
                    let id = g.radix.node_block(idx);
                    let b = g.store.get_mut(id);
                    b.refs += 1;
                    b.last_touch = now;
                    blocks.push(id);
                    parent = Some(idx);
                    matched_tokens += bt;
                    matched_blocks += 1;
                } else {
                    let layers = (0..n_lh)
                        .map(|lh| BlockLayer {
                            keys: k_rows[lh].slice_rows(row(pos), row(pos) + bt),
                            values: v_rows[lh].slice_rows(row(pos), row(pos) + bt),
                        })
                        .collect();
                    let id = g.store.insert(Block {
                        tokens: chunk.to_vec(),
                        layers,
                        refs: 1,
                        in_tree: true,
                        last_touch: now,
                    });
                    parent = Some(g.radix.insert(parent, chunk.to_vec(), id));
                    blocks.push(id);
                    new_blocks += 1;
                }
                pos += bt;
            }
        }

        // The partial remainder is the private tail.
        let tails: Vec<Tail> = (0..n_lh)
            .map(|lh| Tail {
                keys: k_rows[lh].slice_rows(row(pos), row(n)),
                values: v_rows[lh].slice_rows(row(pos), row(n)),
                weights: vec![1.0; n - pos],
                logical: n - pos,
            })
            .collect();
        let tail_floats: usize = tails.iter().map(Tail::floats).sum();
        g.store.charge(tail_floats);
        g.seqs.insert(seq, SeqKv { n_lh, d_k, d_v, blocks, tails, last_touch: now, folds: 0 });
        Ok(RegisterOutcome { matched_tokens, matched_blocks, new_blocks })
    }

    /// Append one decoded token's K/V row to a layer-head tail. Never
    /// fails; crossing the high-water mark triggers the ladder
    /// opportunistically (best effort, no rejection).
    pub fn append_row(&self, seq: u64, lh: usize, k_row: &[f32], v_row: &[f32]) {
        let mut g = self.inner.lock().unwrap();
        g.clock += 1;
        let now = g.clock;
        let s = g.seqs.get_mut(&seq).expect("append to unknown sequence");
        debug_assert_eq!(k_row.len(), s.d_k, "key row width mismatch");
        debug_assert_eq!(v_row.len(), s.d_v, "value row width mismatch");
        s.last_touch = now;
        let t = &mut s.tails[lh];
        t.keys.push_row(k_row);
        t.values.push_row(v_row);
        t.weights.push(1.0);
        t.logical += 1;
        g.store.charge(k_row.len() + v_row.len() + 1);
        if self.cfg.budget_floats > 0 {
            let hw = (self.cfg.high_water * self.cfg.budget_floats as f64) as usize;
            if g.store.used_floats() > hw {
                evict::reclaim(&mut g, &self.cfg, self.compressor.as_ref(), &self.metrics, hw);
            }
        }
    }

    /// Materialise one layer-head cache: `(keys, values, weights,
    /// logical_len)` — block rows (unit weights) then the tail.
    pub fn layer_view(&self, seq: u64, lh: usize) -> Option<(Matrix, Matrix, Vec<f64>, usize)> {
        let g = self.inner.lock().unwrap();
        let s = g.seqs.get(&seq)?;
        if lh >= s.n_lh {
            return None;
        }
        let (k, v, w) = gather_lh(&g.store, s, lh);
        let logical = s.block_tokens(&g.store) + s.tails[lh].logical;
        Some((k, v, w, logical))
    }

    /// Materialise every layer-head cache of a sequence (the decode path).
    pub fn gather(&self, seq: u64) -> Option<Vec<(Matrix, Matrix, Vec<f64>)>> {
        let mut g = self.inner.lock().unwrap();
        g.clock += 1;
        let now = g.clock;
        let s = g.seqs.get_mut(&seq)?;
        s.last_touch = now;
        let s = g.seqs.get(&seq)?;
        Some((0..s.n_lh).map(|lh| gather_lh(&g.store, s, lh)).collect())
    }

    /// Physical entries of one layer-head cache (blocks + tail rows).
    pub fn layer_len(&self, seq: u64, lh: usize) -> Option<usize> {
        let g = self.inner.lock().unwrap();
        g.seqs.get(&seq).map(|s| s.phys_len(&g.store, lh))
    }

    /// Compress a sequence in place so every layer-head holds at most
    /// `budget` physical entries. Folds its shared blocks into the
    /// private compressed tail (releasing the block references — the
    /// index keeps the blocks cached for other sequences). Returns the
    /// number of layer-heads compressed (0 = nothing exceeded budget).
    pub fn compress_sequence(
        &self,
        seq: u64,
        budget: usize,
        obs_queries: Option<&Matrix>,
        rng: &mut Rng,
    ) -> usize {
        let mut g = self.inner.lock().unwrap();
        g.clock += 1;
        compress_seq_impl(&mut g, self.compressor.as_ref(), seq, budget, obs_queries, rng)
    }

    /// Drop a sequence: free its tails, release its block references
    /// (indexed blocks stay cached for future prefix hits). Returns
    /// whether the sequence existed — callers retire sequences exactly
    /// once and should assert on this.
    pub fn drop_sequence(&self, seq: u64) -> bool {
        let mut g = self.inner.lock().unwrap();
        drop_seq_inner(&mut g, seq)
    }

    /// Whether a sequence is currently registered.
    pub fn has_sequence(&self, seq: u64) -> bool {
        self.inner.lock().unwrap().seqs.contains_key(&seq)
    }

    /// Physical/logical size accounting for one sequence.
    pub fn seq_stats(&self, seq: u64) -> Option<SeqStats> {
        let g = self.inner.lock().unwrap();
        let s = g.seqs.get(&seq)?;
        let bt = s.block_tokens(&g.store);
        let block_floats: usize = s.blocks.iter().map(|&b| g.store.get(b).footprint_floats()).sum();
        let mut st = SeqStats { footprint_floats: block_floats + s.tail_floats(), ..Default::default() };
        for t in &s.tails {
            let phys = bt + t.keys.rows();
            st.physical_max = st.physical_max.max(phys);
            st.physical_total += phys;
            st.logical_total += bt + t.logical;
        }
        Some(st)
    }

    /// The spill tier's cold store, when configured — test/bench hook
    /// for flushing the writeback queue and locating record files.
    pub fn spill_store(&self) -> Option<Arc<SpillStore>> {
        self.inner.lock().unwrap().spill.clone()
    }

    /// Consistent point-in-time view of the ledger gauges and counters.
    pub fn snapshot(&self) -> PoolSnapshot {
        let g = self.inner.lock().unwrap();
        let spill = g.spill.as_ref().map(|s| SpillSnapshot {
            budget_bytes: s.budget_bytes(),
            used_bytes: s.indexed_bytes(),
            entries: s.entries(),
            spills: self.metrics.spills.load(Ordering::Relaxed),
            spill_bytes: self.metrics.spill_bytes.load(Ordering::Relaxed),
            spill_evictions: self.metrics.spill_evictions.load(Ordering::Relaxed),
            page_ins: self.metrics.page_ins.load(Ordering::Relaxed),
            pagein_tokens: self.metrics.pagein_tokens.load(Ordering::Relaxed),
            spill_corrupt: self.metrics.spill_corrupt.load(Ordering::Relaxed),
        });
        PoolSnapshot {
            spill,
            budget_floats: self.cfg.budget_floats,
            used_floats: g.store.used_floats(),
            peak_floats: g.store.peak_floats(),
            sequences: g.seqs.len(),
            blocks: g.store.n_blocks(),
            tree_blocks: g.radix.len(),
            prefix_queries: self.metrics.prefix_queries.load(Ordering::Relaxed),
            prefix_hits: self.metrics.prefix_hits.load(Ordering::Relaxed),
            shared_tokens: self.metrics.shared_tokens.load(Ordering::Relaxed),
            tier_compressions: self.metrics.tier_compressions.load(Ordering::Relaxed),
            evicted_blocks: self.metrics.evicted_blocks.load(Ordering::Relaxed),
            admission_rejects: self.metrics.admission_rejects.load(Ordering::Relaxed),
        }
    }

    /// Bytes currently charged to the ledger (4 bytes per stored float).
    pub fn used_bytes(&self) -> usize {
        self.inner.lock().unwrap().store.used_floats() * 4
    }

    /// High-water mark of [`KvPool::used_bytes`] since creation.
    pub fn peak_bytes(&self) -> usize {
        self.inner.lock().unwrap().store.peak_floats() * 4
    }
}

/// Decrement a block's sequence refcount; free it unless the index still
/// caches it.
pub(crate) fn release_block(store: &mut BlockStore, id: BlockId) {
    let b = store.get_mut(id);
    debug_assert!(b.refs > 0, "double release of block {id}");
    b.refs -= 1;
    if b.refs == 0 && !b.in_tree {
        store.remove(id);
    }
}

pub(crate) fn drop_seq_inner(g: &mut PoolInner, seq: u64) -> bool {
    let Some(s) = g.seqs.remove(&seq) else { return false };
    g.store.credit(s.tail_floats());
    for id in s.blocks {
        release_block(&mut g.store, id);
    }
    true
}

/// Concatenate a sequence's block rows (unit weights) and tail for one
/// layer-head.
pub(crate) fn gather_lh(store: &BlockStore, s: &SeqKv, lh: usize) -> (Matrix, Matrix, Vec<f64>) {
    let t = &s.tails[lh];
    if s.blocks.is_empty() {
        return (t.keys.clone(), t.values.clone(), t.weights.clone());
    }
    let mut ks: Vec<&Matrix> = Vec::with_capacity(s.blocks.len() + 1);
    let mut vs: Vec<&Matrix> = Vec::with_capacity(s.blocks.len() + 1);
    let mut block_rows = 0;
    for &b in &s.blocks {
        let layer = &store.get(b).layers[lh];
        block_rows += layer.keys.rows();
        ks.push(&layer.keys);
        vs.push(&layer.values);
    }
    ks.push(&t.keys);
    vs.push(&t.values);
    let mut weights = vec![1.0f64; block_rows];
    weights.extend_from_slice(&t.weights);
    (Matrix::vcat(&ks), Matrix::vcat(&vs), weights)
}

/// Compress a sequence's layer-heads past `budget` in place: gather each
/// cache, run the compressor, and install the result as the new private
/// tail. Releases the sequence's block references (the rows now live in
/// the coreset). Under-budget layer-heads pass through unchanged.
///
/// Traced as a `compress` span on the sequence's request lane when any
/// layer-head actually compressed (admission, decode high-water, and
/// pressure-ladder compressions all funnel through here).
pub(crate) fn compress_seq_impl(
    g: &mut PoolInner,
    compressor: &dyn KvCompressor,
    seq: u64,
    budget: usize,
    obs_queries: Option<&Matrix>,
    rng: &mut Rng,
) -> usize {
    use crate::obs::trace::{self, SpanKind};
    let t0 = if trace::enabled() { Some(std::time::Instant::now()) } else { None };
    let compressed = compress_seq_inner(g, compressor, seq, budget, obs_queries, rng);
    if let Some(t0) = t0 {
        if compressed > 0 {
            let now = std::time::Instant::now();
            trace::span(SpanKind::Compress, t0, now, seq, compressed as u64, 0);
        }
    }
    compressed
}

fn compress_seq_inner(
    g: &mut PoolInner,
    compressor: &dyn KvCompressor,
    seq: u64,
    budget: usize,
    obs_queries: Option<&Matrix>,
    rng: &mut Rng,
) -> usize {
    let Some(mut s) = g.seqs.remove(&seq) else { return 0 };
    if s.phys_max(&g.store) <= budget {
        g.seqs.insert(seq, s);
        return 0;
    }
    let dims = g
        .dims
        .unwrap_or(CompressDims { n_layers: s.n_lh, beta: 0.35 });
    let block_tokens = s.block_tokens(&g.store);
    let audit = g.audit.clone();
    let mut compressed = 0;
    let mut new_tails = Vec::with_capacity(s.n_lh);
    for lh in 0..s.n_lh {
        let (k, v, w) = gather_lh(&g.store, &s, lh);
        let logical = block_tokens + s.tails[lh].logical;
        if k.rows() > budget {
            // Note: gathered rows may carry non-unit weights from an
            // earlier compression; the compressor treats them as
            // surrogate tokens (the paper's streaming re-compression
            // caveat, Sec. 5 limitations).
            let ctx = CompressionCtx {
                keys: &k,
                values: &v,
                budget,
                beta: dims.beta,
                layer: lh,
                n_layers: dims.n_layers,
                obs_queries,
            };
            let e = compressor.compress(&ctx, rng);
            // Fold audit: the only point where the pre-fold rows and
            // the compressed entry coexist. Off the served path (the
            // fold result is already decided).
            let fold = s.folds;
            s.folds += 1;
            if let Some(a) = audit.as_deref() {
                if a.audit_fold(seq, fold) {
                    let probe = quality::probe_queries(a.config().seed, seq, fold, s.d_k);
                    let (max_abs, rel) =
                        quality::fold_error(&probe, &k, &v, &w, &e, dims.beta as f32);
                    a.observe_fold(seq, lh, max_abs, rel);
                }
            }
            new_tails.push(Tail { keys: e.keys, values: e.values, weights: e.weights, logical });
            compressed += 1;
        } else {
            new_tails.push(Tail { keys: k, values: v, weights: w, logical });
        }
    }
    let old_tail_floats = s.tail_floats();
    g.store.credit(old_tail_floats);
    for id in s.blocks.drain(..) {
        release_block(&mut g.store, id);
    }
    s.tails = new_tails;
    g.store.charge(s.tail_floats());
    g.seqs.insert(seq, s);
    compressed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::StreamingLlm;

    fn pool(cfg: KvPoolConfig) -> KvPool {
        KvPool::new(cfg, Arc::new(StreamingLlm))
    }

    fn fake_prefill(seed: u64, n: usize, n_lh: usize, d: usize) -> (Vec<Matrix>, Vec<Matrix>) {
        let mut rng = Rng::seed_from(seed);
        let ks = (0..n_lh).map(|_| Matrix::randn(&mut rng, n, d)).collect();
        let vs = (0..n_lh).map(|_| Matrix::randn(&mut rng, n, d)).collect();
        (ks, vs)
    }

    /// Token stream whose KV rows are a deterministic function of the
    /// token id — lets tests check that shared blocks serve the *right*
    /// rows after divergence.
    fn tagged_prefill(tokens: &[u32], n_lh: usize, d: usize) -> (Vec<Matrix>, Vec<Matrix>) {
        let mk = |scale: f32| {
            (0..n_lh)
                .map(|lh| {
                    Matrix::from_fn(tokens.len(), d, |i, j| {
                        scale * (tokens[i] as f32 + lh as f32 * 1000.0 + j as f32 * 0.01)
                    })
                })
                .collect::<Vec<_>>()
        };
        (mk(1.0), mk(-1.0))
    }

    #[test]
    fn prefix_sharing_stores_shared_rows_once() {
        let p = pool(KvPoolConfig { block_tokens: 8, ..Default::default() });
        let prompt: Vec<u32> = (0..40).collect();
        let (ks, vs) = tagged_prefill(&prompt, 2, 4);
        let r1 = p.register_prefill(1, &prompt, &ks, &vs).unwrap();
        assert_eq!(r1.matched_tokens, 0);
        assert_eq!(r1.new_blocks, 5);
        let used_one = p.snapshot().used_floats;

        // identical prompt: the whole block-covered prefix is reused
        let r2 = p.register_prefill(2, &prompt, &ks, &vs).unwrap();
        assert_eq!(r2.matched_tokens, 40);
        assert_eq!(r2.matched_blocks, 5);
        assert_eq!(r2.new_blocks, 0);
        let used_two = p.snapshot().used_floats;
        assert!(
            used_two < used_one + used_one / 10,
            "second identical prompt nearly free: {used_one} -> {used_two}"
        );
        let snap = p.snapshot();
        assert_eq!(snap.prefix_queries, 2);
        assert_eq!(snap.prefix_hits, 1);
        assert_eq!(snap.shared_tokens, 40);
        assert!((snap.prefix_hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn divergent_suffix_gets_private_storage_with_correct_rows() {
        let p = pool(KvPoolConfig { block_tokens: 8, ..Default::default() });
        let a: Vec<u32> = (0..32).collect();
        let mut b = a.clone();
        for t in b[16..].iter_mut() {
            *t += 100; // diverge after two blocks
        }
        let (ka, va) = tagged_prefill(&a, 2, 4);
        let (kb, vb) = tagged_prefill(&b, 2, 4);
        p.register_prefill(1, &a, &ka, &va).unwrap();
        let r = p.register_prefill(2, &b, &kb, &vb).unwrap();
        assert_eq!(r.matched_tokens, 16);
        // gathers reproduce each sequence's own prefill exactly
        for (seq, kc) in [(1u64, &ka), (2u64, &kb)] {
            let g = p.gather(seq).unwrap();
            for lh in 0..2 {
                assert_eq!(g[lh].0, kc[lh], "seq {seq} lh {lh} keys corrupted");
                assert!(g[lh].2.iter().all(|&w| w == 1.0));
            }
        }
    }

    #[test]
    fn lookup_then_resume_maps_blocks_and_stores_tail() {
        let p = pool(KvPoolConfig { block_tokens: 8, ..Default::default() });
        let a: Vec<u32> = (0..32).collect();
        let (ka, va) = tagged_prefill(&a, 2, 4);
        p.register_prefill(1, &a, &ka, &va).unwrap();

        // b shares 20 tokens with a: only 2 full blocks (16 tokens)
        // match — the boundary is NOT a multiple of block_tokens
        let mut b = a.clone();
        for t in b[20..].iter_mut() {
            *t += 100;
        }
        let h = p.lookup_prefix(&b);
        assert!(h.is_hit());
        assert_eq!(h.matched_tokens(), 16);
        assert_eq!(h.matched_blocks(), 2);
        // materialised K/V equal the original prefill's rows
        assert_eq!(h.kv.keys[0], ka[0].slice_rows(0, 16));
        assert_eq!(h.kv.values[1], va[1].slice_rows(0, 16));

        let (kb, vb) = tagged_prefill(&b, 2, 4);
        let tail_k: Vec<Matrix> = kb.iter().map(|m| m.slice_rows(16, 32)).collect();
        let tail_v: Vec<Matrix> = vb.iter().map(|m| m.slice_rows(16, 32)).collect();
        let out = p.register_resumed(2, &b, h, &tail_k, &tail_v).unwrap();
        assert_eq!(out.matched_tokens, 16);
        assert_eq!(out.new_blocks, 2, "tokens 16..32 sealed as two new chunks");
        // the gather reproduces b's own full prefill exactly
        let g = p.gather(2).unwrap();
        assert_eq!(g[0].0, kb[0]);
        assert_eq!(g[1].1, vb[1]);
        assert!(g[0].2.iter().all(|&w| w == 1.0));
        let snap = p.snapshot();
        assert_eq!(snap.prefix_hits, 1);
        assert_eq!(snap.shared_tokens, 16);
    }

    #[test]
    fn full_prompt_match_leaves_a_tail_token() {
        let p = pool(KvPoolConfig { block_tokens: 8, ..Default::default() });
        let a: Vec<u32> = (0..32).collect();
        let (ka, va) = tagged_prefill(&a, 2, 4);
        p.register_prefill(1, &a, &ka, &va).unwrap();
        let h = p.lookup_prefix(&a);
        assert_eq!(h.matched_tokens(), 24, "whole-prompt match must drop the last block");
        let tail_k: Vec<Matrix> = ka.iter().map(|m| m.slice_rows(24, 32)).collect();
        let tail_v: Vec<Matrix> = va.iter().map(|m| m.slice_rows(24, 32)).collect();
        let out = p.register_resumed(2, &a, h, &tail_k, &tail_v).unwrap();
        // the dropped block is rediscovered at seal time, not duplicated
        assert_eq!(out.matched_tokens, 32);
        assert_eq!(out.new_blocks, 0);
        assert_eq!(p.snapshot().tree_blocks, 4);
        let g = p.gather(2).unwrap();
        assert_eq!(g[0].0, ka[0]);
    }

    #[test]
    fn release_prefix_returns_block_references() {
        let p = pool(KvPoolConfig { block_tokens: 8, ..Default::default() });
        let a: Vec<u32> = (0..24).collect();
        let (ka, va) = tagged_prefill(&a, 2, 4);
        p.register_prefill(1, &a, &ka, &va).unwrap();
        assert!(p.drop_sequence(1));
        let mut b = a.clone();
        b.extend([99, 98, 97]);
        let h = p.lookup_prefix(&b);
        assert_eq!(h.matched_tokens(), 24);
        p.release_prefix(h);
        // the blocks stayed cached in the tree and can be matched again
        let h2 = p.lookup_prefix(&b);
        assert_eq!(h2.matched_tokens(), 24);
        p.release_prefix(h2);
        let snap = p.snapshot();
        assert_eq!(snap.tree_blocks, 3);
        assert_eq!(snap.sequences, 0);
    }

    #[test]
    fn lookup_miss_and_sharing_off_return_empty_handles() {
        let p = pool(KvPoolConfig { block_tokens: 8, ..Default::default() });
        let h = p.lookup_prefix(&[1, 2, 3, 4, 5, 6, 7, 8, 9]);
        assert!(!h.is_hit());
        assert_eq!(h.matched_tokens(), 0);
        // a miss handle still registers like a cold prefill
        let toks: Vec<u32> = (0..20).collect();
        let (ks, vs) = tagged_prefill(&toks, 2, 4);
        let out = p.register_resumed(1, &toks, h, &ks, &vs).unwrap();
        assert_eq!(out.matched_tokens, 0);
        assert_eq!(out.new_blocks, 2);
        assert_eq!(p.gather(1).unwrap()[0].0, ks[0]);

        let off = pool(KvPoolConfig { prefix_sharing: false, ..Default::default() });
        off.register_prefill(1, &toks, &ks, &vs).unwrap();
        let h = off.lookup_prefix(&toks);
        assert!(!h.is_hit());
        assert_eq!(off.snapshot().prefix_queries, 0, "sharing off: lookups are free");
    }

    #[test]
    fn sharing_disabled_stores_everything_privately() {
        let p = pool(KvPoolConfig { prefix_sharing: false, ..Default::default() });
        let prompt: Vec<u32> = (0..32).collect();
        let (ks, vs) = tagged_prefill(&prompt, 2, 4);
        let r1 = p.register_prefill(1, &prompt, &ks, &vs).unwrap();
        let used_one = p.snapshot().used_floats;
        let r2 = p.register_prefill(2, &prompt, &ks, &vs).unwrap();
        assert_eq!((r1.matched_tokens, r2.matched_tokens), (0, 0));
        assert_eq!(r1.new_blocks + r2.new_blocks, 0);
        assert_eq!(p.snapshot().used_floats, 2 * used_one);
        assert_eq!(p.snapshot().prefix_queries, 0);
    }

    #[test]
    fn drop_keeps_indexed_blocks_for_reuse() {
        let p = pool(KvPoolConfig { block_tokens: 8, ..Default::default() });
        let prompt: Vec<u32> = (0..24).collect();
        let (ks, vs) = tagged_prefill(&prompt, 2, 4);
        p.register_prefill(1, &prompt, &ks, &vs).unwrap();
        assert!(p.drop_sequence(1));
        assert!(!p.drop_sequence(1), "double drop must report false");
        let snap = p.snapshot();
        assert_eq!(snap.sequences, 0);
        assert_eq!(snap.tree_blocks, 3, "indexed blocks survive the drop");
        // a new request with the same prompt hits the cached prefix
        let r = p.register_prefill(2, &prompt, &ks, &vs).unwrap();
        assert_eq!(r.matched_tokens, 24);
    }

    #[test]
    fn appends_grow_tail_and_ledger() {
        let p = pool(KvPoolConfig::default());
        p.create_sequence(7, 2, 3, 5);
        for i in 0..6 {
            p.append_row(7, 1, &[i as f32; 3], &[0.0; 5]);
        }
        let st = p.seq_stats(7).unwrap();
        assert_eq!(st.physical_total, 6);
        assert_eq!(st.logical_total, 6);
        assert_eq!(st.footprint_floats, 6 * (3 + 5 + 1));
        assert_eq!(p.snapshot().used_floats, 54);
        let (k, _, w, logical) = p.layer_view(7, 1).unwrap();
        assert_eq!(k.rows(), 6);
        assert_eq!(k.get(3, 0), 3.0);
        assert_eq!(w.len(), 6);
        assert_eq!(logical, 6);
    }

    #[test]
    fn compress_folds_blocks_into_private_coreset() {
        let p = pool(KvPoolConfig { block_tokens: 8, ..Default::default() });
        let prompt: Vec<u32> = (0..64).collect();
        let (ks, vs) = fake_prefill(3, 64, 2, 4);
        p.register_prefill(1, &prompt, &ks, &vs).unwrap();
        p.register_prefill(2, &prompt, &ks, &vs).unwrap();
        let mut rng = Rng::seed_from(1);
        let n = p.compress_sequence(1, 16, None, &mut rng);
        assert_eq!(n, 2);
        let st = p.seq_stats(1).unwrap();
        assert_eq!(st.physical_max, 16);
        assert_eq!(st.logical_total, 128, "logical length survives compression");
        // seq 2 still maps the blocks and gathers the full context
        let g2 = p.gather(2).unwrap();
        assert_eq!(g2[0].0.rows(), 64);
        assert_eq!(g2[0].0, ks[0]);
        // under-budget sequences are left alone
        assert_eq!(p.compress_sequence(1, 64, None, &mut rng), 0);
    }

    #[test]
    fn admission_rejects_only_when_nothing_reclaimable() {
        // budget below one prompt's footprint and nothing to reclaim
        let cfg = KvPoolConfig { budget_floats: 100, ..Default::default() };
        let p = pool(cfg);
        let prompt: Vec<u32> = (0..32).collect();
        let (ks, vs) = fake_prefill(5, 32, 2, 4);
        let err = p.register_prefill(1, &prompt, &ks, &vs).unwrap_err();
        assert!(matches!(err, AdmitError::PoolExhausted { .. }));
        let snap = p.snapshot();
        assert_eq!(snap.admission_rejects, 1);
        assert_eq!(snap.used_floats, 0, "rejected admission must not leak storage");
        assert!(!p.has_sequence(1));
    }

    #[test]
    fn ladder_compresses_cold_sequences_to_admit_new_ones() {
        // Budget fits ~1.5 uncompressed sequences; the compression tier
        // must shrink the cold one so the next admission succeeds.
        let n = 64;
        let floats_per_seq = n * 2 * (4 + 4 + 1); // 1152
        let cfg = KvPoolConfig {
            budget_floats: floats_per_seq + floats_per_seq / 2,
            compress_budget: 8,
            prefix_sharing: false,
            ..Default::default()
        };
        let p = pool(cfg);
        for seq in 0..4u64 {
            let prompt: Vec<u32> = (0..n as u32).map(|t| t + 100 * seq as u32).collect();
            let (ks, vs) = fake_prefill(seq, n, 2, 4);
            p.register_prefill(seq, &prompt, &ks, &vs)
                .unwrap_or_else(|e| panic!("seq {seq} rejected: {e}"));
        }
        let snap = p.snapshot();
        assert!(snap.tier_compressions > 0, "compression tier never fired");
        assert_eq!(snap.admission_rejects, 0);
        assert_eq!(snap.sequences, 4);
        assert!(snap.used_floats <= cfg_budget(&p));
    }

    fn cfg_budget(p: &KvPool) -> usize {
        p.config().budget_floats
    }

    #[test]
    fn ladder_evicts_unreferenced_cached_prefixes() {
        // Fill the index with dead prefixes, then admit under pressure:
        // eviction (not compression) must make room.
        let n = 32;
        let floats_per_seq = n * 2 * (4 + 4 + 1);
        let cfg = KvPoolConfig {
            budget_floats: 2 * floats_per_seq,
            block_tokens: 8,
            ..Default::default()
        };
        let p = pool(cfg);
        for seq in 0..2u64 {
            let prompt: Vec<u32> = (0..n as u32).map(|t| t + 1000 * seq as u32).collect();
            let (ks, vs) = fake_prefill(10 + seq, n, 2, 4);
            p.register_prefill(seq, &prompt, &ks, &vs).unwrap();
            p.drop_sequence(seq);
        }
        assert_eq!(p.snapshot().tree_blocks, 8);
        let prompt: Vec<u32> = (0..n as u32).map(|t| t + 50_000).collect();
        let (ks, vs) = fake_prefill(99, n, 2, 4);
        p.register_prefill(9, &prompt, &ks, &vs).unwrap();
        let snap = p.snapshot();
        assert!(snap.evicted_blocks > 0, "eviction tier never fired");
        assert_eq!(snap.admission_rejects, 0);
    }

    fn spill_cfg(tag: &str, budget_floats: usize, spill_mb: f64) -> KvPoolConfig {
        let dir = std::env::temp_dir().join(format!("wildcat_pool_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        KvPoolConfig {
            budget_floats,
            block_tokens: 8,
            spill: Some(SpillParams {
                dir,
                budget_bytes: spill_budget_bytes_from_mb(spill_mb),
                replica: 0,
            }),
            ..Default::default()
        }
    }

    #[test]
    fn evicted_blocks_spill_and_page_back_with_identical_rows() {
        // Budget fits exactly one prompt's storage: admitting B evicts
        // (and spills) A's cached blocks; a new lookup of A pages them
        // back from disk.
        let n = 32;
        let floats_per_seq = n * 2 * (4 + 4 + 1);
        let cfg = spill_cfg("roundtrip", floats_per_seq, 4.0);
        let p = pool(cfg.clone());
        let a: Vec<u32> = (0..n as u32).collect();
        let b: Vec<u32> = (0..n as u32).map(|t| t + 10_000).collect();
        let (ka, va) = tagged_prefill(&a, 2, 4);
        let (kb, vb) = tagged_prefill(&b, 2, 4);
        p.register_prefill(1, &a, &ka, &va).unwrap();
        p.drop_sequence(1);
        p.register_prefill(2, &b, &kb, &vb).unwrap();
        p.drop_sequence(2);
        p.register_prefill(3, &b, &kb, &vb).unwrap(); // keep B hot
        let snap = p.snapshot();
        let sp = snap.spill.expect("spill tier configured");
        assert!(sp.spills > 0, "pressure must have spilled A's evicted blocks");
        assert_eq!(snap.admission_rejects, 0);

        // A's prefix now misses the radix but hits the cold index: the
        // lookup pages the blocks back with the exact original rows.
        let h = p.lookup_prefix(&a);
        assert!(h.is_hit(), "page-in must surface the spilled prefix");
        let matched = h.matched_tokens();
        assert!(matched >= 8);
        assert_eq!(h.kv.keys[0], ka[0].slice_rows(0, matched));
        assert_eq!(h.kv.values[1], va[1].slice_rows(0, matched));
        let sp = p.snapshot().spill.unwrap();
        assert!(sp.page_ins > 0);
        assert_eq!(sp.pagein_tokens % 8, 0);
        assert_eq!(sp.spill_corrupt, 0);
        p.release_prefix(h);
        if let Some(params) = &cfg.spill {
            std::fs::remove_dir_all(&params.dir).ok();
        }
    }

    #[test]
    fn spill_off_snapshot_has_no_spill_block() {
        let p = pool(KvPoolConfig::default());
        assert!(p.snapshot().spill.is_none());
        assert!(p.spill_store().is_none());
    }

    #[test]
    fn appends_never_fail_past_budget() {
        let cfg = KvPoolConfig {
            budget_floats: 64,
            prefix_sharing: false,
            compress_budget: 4,
            ..Default::default()
        };
        let p = pool(cfg);
        p.create_sequence(1, 1, 3, 3);
        for i in 0..100 {
            p.append_row(1, 0, &[i as f32; 3], &[0.0; 3]);
        }
        // the ladder kept shrinking the tail opportunistically
        let st = p.seq_stats(1).unwrap();
        assert!(st.physical_max < 100, "high-water ladder never compressed");
        assert_eq!(st.logical_total, 100);
    }
}
