//! The unit of pooled KV storage: an immutable page of up to
//! `block_tokens` consecutive context tokens, holding those tokens'
//! key/value rows for *every* (layer, head) cache of the model.
//!
//! Blocks are sealed at creation and never mutated afterwards — that is
//! what makes them safely shareable between sequences with a common
//! prompt prefix (copy-on-write degenerates to "divergent tokens always
//! land in the owning sequence's private tail"). Reference counts track
//! *active sequence* mappings; the radix prefix index holds blocks via
//! the separate `in_tree` mark so a cached-but-unmapped prefix survives
//! until the eviction tier reclaims it.

use crate::linalg::Matrix;

/// Index into the pool's block store.
pub type BlockId = usize;

/// One (layer, head) slice of a block: `n_tokens × d_k` keys and
/// `n_tokens × d_v` values. Weights are implicitly 1.0 — blocks only ever
/// hold verbatim (uncompressed) rows.
#[derive(Clone, Debug)]
pub struct BlockLayer {
    /// `n_tokens × d_k` key rows for this layer-head.
    pub keys: Matrix,
    /// `n_tokens × d_v` value rows for this layer-head.
    pub values: Matrix,
}

/// An immutable page of KV rows for a token span, across all layer-heads.
#[derive(Clone, Debug)]
pub struct Block {
    /// The token ids this block covers (defines prefix identity).
    pub tokens: Vec<u32>,
    /// Per-(layer, head) key/value rows, indexed like the model's caches.
    pub layers: Vec<BlockLayer>,
    /// Number of active sequences currently mapping this block.
    pub refs: usize,
    /// Whether the radix prefix index references this block.
    pub in_tree: bool,
    /// Pool logical clock of the last map/unmap (LRU eviction order).
    pub last_touch: u64,
}

impl Block {
    /// Context tokens this block covers.
    pub fn n_tokens(&self) -> usize {
        self.tokens.len()
    }

    /// f32-equivalent stored footprint. Blocks store no weights (they are
    /// synthesised as 1.0 at gather time), so only keys + values count.
    pub fn footprint_floats(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.keys.rows() * l.keys.cols() + l.values.rows() * l.values.cols())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn footprint_counts_all_layers() {
        let b = Block {
            tokens: vec![1, 2, 3],
            layers: (0..4)
                .map(|_| BlockLayer {
                    keys: Matrix::zeros(3, 8),
                    values: Matrix::zeros(3, 4),
                })
                .collect(),
            refs: 0,
            in_tree: false,
            last_touch: 0,
        };
        assert_eq!(b.n_tokens(), 3);
        assert_eq!(b.footprint_floats(), 4 * (3 * 8 + 3 * 4));
    }
}
