//! Dense linear-algebra substrate (no BLAS/LAPACK in the offline image).
//!
//! * [`Matrix`] — row-major `f32` matrix, the working type of the whole
//!   attention stack (activations, Q/K/V, caches).
//! * [`gemm`] — blocked, multi-threaded matrix multiplication kernels.
//! * [`cholesky`] — `f64` Cholesky factorisation + triangular solves used
//!   by the Nyström weight solve (`H_SS W = H_{S,:}`).
//! * [`norms`] — Frobenius / max / (2,∞) norms and a power-iteration
//!   operator-norm estimate (used to verify Thm. 1 empirically).

pub mod cholesky;
pub mod gemm;
pub mod matrix;
pub mod norms;

pub use cholesky::{cholesky_in_place, solve_lower, solve_lower_transpose, spd_solve};
pub use matrix::Matrix;
pub use norms::{frobenius, max_abs, max_abs_diff, norm_2inf, op_norm_sym_f64};
