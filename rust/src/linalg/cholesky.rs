//! f64 Cholesky factorisation and triangular solves.
//!
//! These back the Nyström weight solve `h(K_S,K_S) W = h(K_S,K)` (Alg. 1's
//! `W = M R` step in pseudo-inverse form) and the BalanceKV / baseline
//! machinery. Matrices here are small (r×r with r ≤ a few hundred), stored
//! as flat row-major `Vec<f64>`.

/// In-place lower-Cholesky of a row-major symmetric positive-definite
/// `n×n` matrix. Returns `Err(pivot)` at the first non-positive pivot.
/// Only the lower triangle of the output is meaningful.
pub fn cholesky_in_place(a: &mut [f64], n: usize) -> Result<(), usize> {
    assert_eq!(a.len(), n * n);
    for j in 0..n {
        let mut diag = a[j * n + j];
        for k in 0..j {
            diag -= a[j * n + k] * a[j * n + k];
        }
        if !(diag > 0.0) || !diag.is_finite() {
            return Err(j);
        }
        let ljj = diag.sqrt();
        a[j * n + j] = ljj;
        for i in j + 1..n {
            let mut v = a[i * n + j];
            for k in 0..j {
                v -= a[i * n + k] * a[j * n + k];
            }
            a[i * n + j] = v / ljj;
        }
    }
    Ok(())
}

/// Solve `L x = b` (forward substitution) for lower-triangular `L`,
/// overwriting `b` with `x`. `b` holds `nrhs` interleaved columns in
/// row-major layout (`n × nrhs`).
pub fn solve_lower(l: &[f64], n: usize, b: &mut [f64], nrhs: usize) {
    assert_eq!(l.len(), n * n);
    assert_eq!(b.len(), n * nrhs);
    for i in 0..n {
        let lii = l[i * n + i];
        for k in 0..i {
            let lik = l[i * n + k];
            if lik == 0.0 {
                continue;
            }
            // b[i,:] -= l[i,k] * b[k,:]  (split_at_mut keeps aliasing legal)
            let (head, tail) = b.split_at_mut(i * nrhs);
            let bi = &mut tail[..nrhs];
            let bk = &head[k * nrhs..(k + 1) * nrhs];
            for (x, &y) in bi.iter_mut().zip(bk) {
                *x -= lik * y;
            }
        }
        for x in b[i * nrhs..(i + 1) * nrhs].iter_mut() {
            *x /= lii;
        }
    }
}

/// Solve `Lᵀ x = b` (back substitution), overwriting `b` with `x`.
pub fn solve_lower_transpose(l: &[f64], n: usize, b: &mut [f64], nrhs: usize) {
    assert_eq!(l.len(), n * n);
    assert_eq!(b.len(), n * nrhs);
    for i in (0..n).rev() {
        let lii = l[i * n + i];
        for k in i + 1..n {
            let lki = l[k * n + i];
            if lki == 0.0 {
                continue;
            }
            let (head, tail) = b.split_at_mut(k * nrhs);
            let bi = &mut head[i * nrhs..(i + 1) * nrhs];
            let bk = &tail[..nrhs];
            for (x, &y) in bi.iter_mut().zip(bk) {
                *x -= lki * y;
            }
        }
        for x in b[i * nrhs..(i + 1) * nrhs].iter_mut() {
            *x /= lii;
        }
    }
}

/// Solve the SPD system `A X = B` with escalating jitter (pseudo-inverse
/// semantics for nearly-singular kernel matrices, per Alg. 1's `H⁺`).
///
/// `a` is `n×n` row-major (consumed), `b` is `n×nrhs` row-major
/// (overwritten with the solution). Returns the jitter that was needed.
pub fn spd_solve(mut a: Vec<f64>, n: usize, b: &mut [f64], nrhs: usize) -> f64 {
    let trace: f64 = (0..n).map(|i| a[i * n + i]).sum();
    let base = (trace / n.max(1) as f64).max(1e-300);
    let mut jitter = 0.0f64;
    let mut factor = a.clone();
    loop {
        if cholesky_in_place(&mut factor, n).is_ok() {
            solve_lower(&factor, n, b, nrhs);
            solve_lower_transpose(&factor, n, b, nrhs);
            return jitter;
        }
        // escalate jitter: 1e-10, 1e-8, ... of the mean diagonal
        jitter = if jitter == 0.0 { base * 1e-10 } else { jitter * 100.0 };
        assert!(
            jitter <= base * 10.0,
            "spd_solve: matrix is numerically indefinite even with jitter"
        );
        for i in 0..n {
            a[i * n + i] += jitter;
        }
        factor.copy_from_slice(&a);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::util::prop::Cases;

    fn random_spd(rng: &mut Rng, n: usize) -> Vec<f64> {
        // A = G Gᵀ + n * I
        let g: Vec<f64> = (0..n * n).map(|_| rng.gaussian()).collect();
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += g[i * n + k] * g[j * n + k];
                }
                a[i * n + j] = s + if i == j { n as f64 } else { 0.0 };
            }
        }
        a
    }

    fn matvec(a: &[f64], n: usize, x: &[f64]) -> Vec<f64> {
        (0..n)
            .map(|i| (0..n).map(|j| a[i * n + j] * x[j]).sum())
            .collect()
    }

    #[test]
    fn cholesky_reconstructs() {
        Cases::new(16).run(|rng| {
            let n = 1 + rng.below(20);
            let a = random_spd(rng, n);
            let mut l = a.clone();
            cholesky_in_place(&mut l, n).unwrap();
            for i in 0..n {
                for j in 0..=i {
                    let mut s = 0.0;
                    for k in 0..=j {
                        s += l[i * n + k] * l[j * n + k];
                    }
                    assert!(
                        (s - a[i * n + j]).abs() < 1e-8 * (1.0 + a[i * n + j].abs()),
                        "n={n} i={i} j={j}"
                    );
                }
            }
        });
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(cholesky_in_place(&mut a, 2).is_err());
    }

    #[test]
    fn spd_solve_solves() {
        Cases::new(16).run(|rng| {
            let n = 1 + rng.below(16);
            let nrhs = 1 + rng.below(5);
            let a = random_spd(rng, n);
            let x_true: Vec<f64> = (0..n * nrhs).map(|_| rng.gaussian()).collect();
            // b = A @ X (column-interleaved layout)
            let mut b = vec![0.0; n * nrhs];
            for c in 0..nrhs {
                let xc: Vec<f64> = (0..n).map(|i| x_true[i * nrhs + c]).collect();
                let bc = matvec(&a, n, &xc);
                for i in 0..n {
                    b[i * nrhs + c] = bc[i];
                }
            }
            let jit = spd_solve(a, n, &mut b, nrhs);
            assert!(jit < 1e-3);
            for (got, want) in b.iter().zip(&x_true) {
                assert!((got - want).abs() < 1e-6 * (1.0 + want.abs()), "{got} vs {want}");
            }
        });
    }

    #[test]
    fn spd_solve_handles_singular_with_jitter() {
        // rank-1 matrix: [[1,1],[1,1]] — needs jitter, must not panic
        let a = vec![1.0, 1.0, 1.0, 1.0];
        let mut b = vec![1.0, 1.0];
        let jit = spd_solve(a, 2, &mut b, 1);
        assert!(jit > 0.0);
        // solution of the jittered system is near [0.5, 0.5]
        assert!((b[0] - 0.5).abs() < 1e-3 && (b[1] - 0.5).abs() < 1e-3);
    }

    #[test]
    fn triangular_solves_roundtrip() {
        Cases::new(8).run(|rng| {
            let n = 1 + rng.below(12);
            let a = random_spd(rng, n);
            let mut l = a.clone();
            cholesky_in_place(&mut l, n).unwrap();
            let x: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            // b = L x
            let mut b = vec![0.0; n];
            for i in 0..n {
                for j in 0..=i {
                    b[i] += l[i * n + j] * x[j];
                }
            }
            solve_lower(&l, n, &mut b, 1);
            for (got, want) in b.iter().zip(&x) {
                assert!((got - want).abs() < 1e-9 * (1.0 + want.abs()));
            }
        });
    }
}
