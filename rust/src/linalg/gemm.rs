//! Blocked, multi-threaded GEMM kernels.
//!
//! Two primitives cover the stack:
//! * [`matmul`]       — `C = A · B`
//! * [`matmul_transb`] — `C = A · Bᵀ` (the attention-logits shape
//!   `Q · K_Sᵀ`; B is accessed row-wise so both primitives stream
//!   cache-friendly contiguous rows).
//!
//! Parallelism: output rows are split into contiguous chunks processed by
//! the [`crate::exec`] pool. The inner kernel accumulates in f32 with a
//! 4-way unrolled j-loop (auto-vectorises well on x86-64); reductions that
//! need f64 (softmax normalisers) live in the attention code, not here.

use super::matrix::Matrix;
use crate::exec;

/// Row-chunk size for parallel GEMM. Chosen so a chunk's A-panel plus the
/// B-panel stay inside L2 for typical d ≤ 256.
const ROW_CHUNK: usize = 64;

/// `C = A · B`.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "matmul: inner dim mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Matrix::zeros(m, n);
    let a_data = a.as_slice();
    let b_data = b.as_slice();
    exec::parallel_chunks_mut(c.as_mut_slice(), ROW_CHUNK * n.max(1), |chunk_idx, out| {
        let row0 = chunk_idx * ROW_CHUNK;
        let rows_here = out.len() / n.max(1);
        for r in 0..rows_here {
            let i = row0 + r;
            let a_row = &a_data[i * k..(i + 1) * k];
            let out_row = &mut out[r * n..(r + 1) * n];
            out_row.fill(0.0);
            for (p, &av) in a_row.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let b_row = &b_data[p * n..(p + 1) * n];
                axpy(av, b_row, out_row);
            }
        }
    });
    c
}

/// `C = A · Bᵀ` where A is m×k and B is n×k; result m×n.
pub fn matmul_transb(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.cols(), "matmul_transb: inner dim mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.rows());
    let mut c = Matrix::zeros(m, n);
    let a_data = a.as_slice();
    let b_data = b.as_slice();
    exec::parallel_chunks_mut(c.as_mut_slice(), ROW_CHUNK * n.max(1), |chunk_idx, out| {
        let row0 = chunk_idx * ROW_CHUNK;
        let rows_here = out.len() / n.max(1);
        for r in 0..rows_here {
            let i = row0 + r;
            let a_row = &a_data[i * k..(i + 1) * k];
            let out_row = &mut out[r * n..(r + 1) * n];
            for (j, o) in out_row.iter_mut().enumerate() {
                *o = dot(a_row, &b_data[j * k..(j + 1) * k]);
            }
        }
    });
    c
}

/// `y += alpha * x` with 4-way unrolling.
#[inline]
fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    let n = x.len();
    let chunks = n / 4;
    for c in 0..chunks {
        let o = c * 4;
        y[o] += alpha * x[o];
        y[o + 1] += alpha * x[o + 1];
        y[o + 2] += alpha * x[o + 2];
        y[o + 3] += alpha * x[o + 3];
    }
    for o in chunks * 4..n {
        y[o] += alpha * x[o];
    }
}

/// f32 dot product with a 16-lane accumulator array: with
/// `-C target-cpu=native` LLVM maps this to one AVX-512 (or two AVX2)
/// FMA lanes — ~4× over the previous 4-lane version (EXPERIMENTS.md
/// §Perf, iteration 2).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 16];
    let mut ca = a.chunks_exact(16);
    let mut cb = b.chunks_exact(16);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        for i in 0..16 {
            acc[i] += xa[i] * xb[i];
        }
    }
    let mut tail = 0.0f32;
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        tail += x * y;
    }
    let mut total = tail;
    for v in acc {
        total += v;
    }
    total
}

/// `C = A · B` computed serially (reference for testing the parallel path).
pub fn matmul_naive(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows());
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        for p in 0..k {
            let av = a.get(i, p);
            for j in 0..n {
                let cur = c.get(i, j);
                c.set(i, j, cur + av * b.get(p, j));
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::util::prop::Cases;

    fn assert_close(a: &Matrix, b: &Matrix, tol: f32) {
        assert_eq!(a.rows(), b.rows());
        assert_eq!(a.cols(), b.cols());
        for (x, y) in a.as_slice().iter().zip(b.as_slice()) {
            assert!((x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())), "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_matches_naive() {
        Cases::new(20).run(|rng| {
            let m = 1 + rng.below(50);
            let k = 1 + rng.below(40);
            let n = 1 + rng.below(70);
            let a = Matrix::randn(rng, m, k);
            let b = Matrix::randn(rng, k, n);
            assert_close(&matmul(&a, &b), &matmul_naive(&a, &b), 1e-4);
        });
    }

    #[test]
    fn transb_matches_explicit_transpose() {
        Cases::new(20).run(|rng| {
            let m = 1 + rng.below(60);
            let k = 1 + rng.below(33);
            let n = 1 + rng.below(60);
            let a = Matrix::randn(rng, m, k);
            let b = Matrix::randn(rng, n, k);
            assert_close(&matmul_transb(&a, &b), &matmul(&a, &b.transpose()), 1e-4);
        });
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::seed_from(4);
        let a = Matrix::randn(&mut rng, 9, 9);
        let eye = Matrix::from_fn(9, 9, |i, j| if i == j { 1.0 } else { 0.0 });
        assert_close(&matmul(&a, &eye), &a, 1e-6);
        assert_close(&matmul(&eye, &a), &a, 1e-6);
    }

    #[test]
    fn large_parallel_consistent() {
        let mut rng = Rng::seed_from(5);
        let a = Matrix::randn(&mut rng, 300, 64);
        let b = Matrix::randn(&mut rng, 64, 200);
        assert_close(&matmul(&a, &b), &matmul_naive(&a, &b), 1e-3);
    }

    #[test]
    fn dot_accuracy() {
        let a: Vec<f32> = (0..103).map(|i| (i as f32) * 0.01).collect();
        let b: Vec<f32> = (0..103).map(|i| 1.0 - (i as f32) * 0.005).collect();
        let want: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
        assert!((dot(&a, &b) as f64 - want).abs() < 1e-3);
    }
}
