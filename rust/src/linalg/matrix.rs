//! Row-major `f32` matrix.

use crate::rng::Rng;

/// Dense row-major `f32` matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    data: Vec<f32>,
    rows: usize,
    cols: usize,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { data: vec![0.0; rows * cols], rows, cols }
    }

    pub fn from_vec(data: Vec<f32>, rows: usize, cols: usize) -> Self {
        assert_eq!(data.len(), rows * cols, "data length mismatch");
        Matrix { data, rows, cols }
    }

    pub fn from_fn<F: FnMut(usize, usize) -> f32>(rows: usize, cols: usize, mut f: F) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { data, rows, cols }
    }

    /// i.i.d. standard normal entries.
    pub fn randn(rng: &mut Rng, rows: usize, cols: usize) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        rng.fill_gaussian_f32(&mut m.data);
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Append a row (grows the matrix; used by the KV caches).
    pub fn push_row(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.cols, "push_row: width mismatch");
        self.data.extend_from_slice(row);
        self.rows += 1;
    }

    /// Select rows by index (gather).
    pub fn select_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (oi, &i) in idx.iter().enumerate() {
            out.row_mut(oi).copy_from_slice(self.row(i));
        }
        out
    }

    /// Contiguous row slice `[start, end)` as a new matrix.
    pub fn slice_rows(&self, start: usize, end: usize) -> Matrix {
        assert!(start <= end && end <= self.rows);
        Matrix::from_vec(
            self.data[start * self.cols..end * self.cols].to_vec(),
            end - start,
            self.cols,
        )
    }

    /// Vertical concatenation.
    pub fn vcat(mats: &[&Matrix]) -> Matrix {
        assert!(!mats.is_empty());
        let cols = mats[0].cols;
        assert!(mats.iter().all(|m| m.cols == cols), "vcat: column mismatch");
        let rows = mats.iter().map(|m| m.rows).sum();
        let mut data = Vec::with_capacity(rows * cols);
        for m in mats {
            data.extend_from_slice(&m.data);
        }
        Matrix { data, rows, cols }
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// Column-wise mean as a row vector.
    pub fn col_mean(&self) -> Vec<f32> {
        let mut acc = vec![0.0f64; self.cols];
        for i in 0..self.rows {
            for (a, &x) in acc.iter_mut().zip(self.row(i)) {
                *a += x as f64;
            }
        }
        acc.iter().map(|&a| (a / self.rows.max(1) as f64) as f32).collect()
    }

    /// Subtract a row vector from every row (returns a new matrix).
    pub fn sub_row_vector(&self, v: &[f32]) -> Matrix {
        assert_eq!(v.len(), self.cols);
        let mut out = self.clone();
        for i in 0..out.rows {
            for (x, &s) in out.row_mut(i).iter_mut().zip(v) {
                *x -= s;
            }
        }
        out
    }

    /// Add a row vector to every row in place.
    pub fn add_row_vector_mut(&mut self, v: &[f32]) {
        assert_eq!(v.len(), self.cols);
        for i in 0..self.rows {
            for (x, &s) in self.row_mut(i).iter_mut().zip(v) {
                *x += s;
            }
        }
    }

    /// Scale all entries.
    pub fn scale(&self, s: f32) -> Matrix {
        let mut out = self.clone();
        for x in &mut out.data {
            *x *= s;
        }
        out
    }

    /// Max row L2 norm, i.e. `‖A‖_{2,∞}`.
    pub fn max_row_norm(&self) -> f64 {
        (0..self.rows)
            .map(|i| self.row(i).iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>())
            .fold(0.0f64, f64::max)
            .sqrt()
    }

    /// Per-column min and max (the clip range of Lem. 1 / Alg. 4).
    pub fn col_min_max(&self) -> (Vec<f32>, Vec<f32>) {
        let mut mn = vec![f32::INFINITY; self.cols];
        let mut mx = vec![f32::NEG_INFINITY; self.cols];
        for i in 0..self.rows {
            for (j, &x) in self.row(i).iter().enumerate() {
                if x < mn[j] {
                    mn[j] = x;
                }
                if x > mx[j] {
                    mx[j] = x;
                }
            }
        }
        (mn, mx)
    }

    /// Dot product of two rows of (possibly different) matrices.
    #[inline]
    pub fn row_dot(a: &Matrix, i: usize, b: &Matrix, j: usize) -> f64 {
        debug_assert_eq!(a.cols, b.cols);
        let ra = a.row(i);
        let rb = b.row(j);
        let mut acc = 0.0f64;
        for (x, y) in ra.iter().zip(rb) {
            acc += (*x as f64) * (*y as f64);
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let m = Matrix::from_fn(3, 2, |i, j| (i * 10 + j) as f32);
        assert_eq!(m.get(2, 1), 21.0);
        assert_eq!(m.row(1), &[10.0, 11.0]);
        assert_eq!(m.rows(), 3);
        assert_eq!(m.cols(), 2);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::seed_from(1);
        let m = Matrix::randn(&mut rng, 7, 5);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn select_and_slice() {
        let m = Matrix::from_fn(5, 3, |i, _| i as f32);
        let s = m.select_rows(&[4, 0, 2]);
        assert_eq!(s.row(0)[0], 4.0);
        assert_eq!(s.row(1)[0], 0.0);
        assert_eq!(s.row(2)[0], 2.0);
        let sl = m.slice_rows(1, 3);
        assert_eq!(sl.rows(), 2);
        assert_eq!(sl.row(0)[0], 1.0);
    }

    #[test]
    fn vcat_roundtrip() {
        let m = Matrix::from_fn(6, 2, |i, j| (i + j) as f32);
        let a = m.slice_rows(0, 2);
        let b = m.slice_rows(2, 6);
        assert_eq!(Matrix::vcat(&[&a, &b]), m);
    }

    #[test]
    fn recentring_zeroes_mean() {
        let mut rng = Rng::seed_from(3);
        let m = Matrix::randn(&mut rng, 100, 4);
        let mean = m.col_mean();
        let c = m.sub_row_vector(&mean);
        for v in c.col_mean() {
            assert!(v.abs() < 1e-5);
        }
        // add back restores
        let mut c2 = c.clone();
        c2.add_row_vector_mut(&mean);
        for (a, b) in c2.as_slice().iter().zip(m.as_slice()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn col_min_max_and_row_norm() {
        let m = Matrix::from_vec(vec![1.0, -2.0, 3.0, 4.0], 2, 2);
        let (mn, mx) = m.col_min_max();
        assert_eq!(mn, vec![1.0, -2.0]);
        assert_eq!(mx, vec![3.0, 4.0]);
        assert!((m.max_row_norm() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn row_dot_matches_manual() {
        let a = Matrix::from_vec(vec![1.0, 2.0, 3.0], 1, 3);
        let b = Matrix::from_vec(vec![4.0, 5.0, 6.0], 1, 3);
        assert!((Matrix::row_dot(&a, 0, &b, 0) - 32.0).abs() < 1e-12);
    }
}
