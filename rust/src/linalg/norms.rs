//! Matrix norms used throughout the paper's analysis:
//! `‖·‖_max` (entrywise), `‖·‖_F`, `‖·‖_{2,∞}` (max row L2), and a
//! power-iteration estimate of `‖·‖_op` for symmetric f64 matrices
//! (used by tests that verify the Thm. 1 / Lem. 2 error chains).

use super::matrix::Matrix;

/// Entrywise max norm `‖A‖_max`.
pub fn max_abs(a: &Matrix) -> f64 {
    a.as_slice().iter().fold(0.0f64, |m, &x| m.max((x as f64).abs()))
}

/// `‖A − B‖_max` — the paper's headline error metric.
pub fn max_abs_diff(a: &Matrix, b: &Matrix) -> f64 {
    assert_eq!(a.rows(), b.rows());
    assert_eq!(a.cols(), b.cols());
    a.as_slice()
        .iter()
        .zip(b.as_slice())
        .fold(0.0f64, |m, (&x, &y)| m.max(((x as f64) - (y as f64)).abs()))
}

/// Frobenius norm.
pub fn frobenius(a: &Matrix) -> f64 {
    a.as_slice()
        .iter()
        .map(|&x| (x as f64) * (x as f64))
        .sum::<f64>()
        .sqrt()
}

/// Relative Frobenius error `‖A − B‖_F / ‖B‖_F`.
pub fn rel_frobenius_err(approx: &Matrix, exact: &Matrix) -> f64 {
    assert_eq!(approx.rows(), exact.rows());
    assert_eq!(approx.cols(), exact.cols());
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (&x, &y) in approx.as_slice().iter().zip(exact.as_slice()) {
        let d = x as f64 - y as f64;
        num += d * d;
        den += (y as f64) * (y as f64);
    }
    (num / den.max(1e-300)).sqrt()
}

/// `‖A‖_{2,∞}` — max row L2 norm.
pub fn norm_2inf(a: &Matrix) -> f64 {
    a.max_row_norm()
}

/// Operator norm of a symmetric `n×n` f64 matrix by power iteration.
/// Deterministic start vector; `iters` ≈ 100 is ample for test tolerances.
pub fn op_norm_sym_f64(a: &[f64], n: usize, iters: usize) -> f64 {
    assert_eq!(a.len(), n * n);
    if n == 0 {
        return 0.0;
    }
    let mut v: Vec<f64> = (0..n).map(|i| 1.0 + (i as f64 * 0.7).sin()).collect();
    let mut lambda = 0.0f64;
    for _ in 0..iters {
        let mut w = vec![0.0f64; n];
        for i in 0..n {
            let row = &a[i * n..(i + 1) * n];
            w[i] = row.iter().zip(&v).map(|(&x, &y)| x * y).sum();
        }
        let norm = w.iter().map(|x| x * x).sum::<f64>().sqrt();
        if norm == 0.0 {
            return 0.0;
        }
        lambda = norm;
        for (x, y) in v.iter_mut().zip(&w) {
            *x = y / norm;
        }
    }
    lambda
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn norms_basic() {
        let a = Matrix::from_vec(vec![3.0, -4.0, 0.0, 0.0], 2, 2);
        assert_eq!(max_abs(&a), 4.0);
        assert!((frobenius(&a) - 5.0).abs() < 1e-9);
        assert!((norm_2inf(&a) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn max_abs_diff_zero_on_equal() {
        let mut rng = Rng::seed_from(1);
        let a = Matrix::randn(&mut rng, 5, 7);
        assert_eq!(max_abs_diff(&a, &a), 0.0);
    }

    #[test]
    fn rel_frobenius_scaling() {
        let a = Matrix::from_vec(vec![1.0; 16], 4, 4);
        let b = a.scale(1.1);
        assert!((rel_frobenius_err(&b, &a) - 0.1).abs() < 1e-6);
    }

    #[test]
    fn op_norm_diagonal() {
        let n = 6;
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            a[i * n + i] = (i + 1) as f64;
        }
        let l = op_norm_sym_f64(&a, n, 200);
        assert!((l - 6.0).abs() < 1e-6, "l={l}");
    }

    #[test]
    fn op_norm_rank_one() {
        // vvᵀ has operator norm ‖v‖².
        let v = [1.0, 2.0, 3.0];
        let n = 3;
        let mut a = vec![0.0; 9];
        for i in 0..n {
            for j in 0..n {
                a[i * n + j] = v[i] * v[j];
            }
        }
        let l = op_norm_sym_f64(&a, n, 100);
        assert!((l - 14.0).abs() < 1e-8, "l={l}");
    }

    #[test]
    fn op_norm_zero() {
        assert_eq!(op_norm_sym_f64(&[0.0; 9], 3, 10), 0.0);
        assert_eq!(op_norm_sym_f64(&[], 0, 10), 0.0);
    }
}
