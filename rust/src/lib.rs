//! # WildCat — near-linear attention in theory and practice
//!
//! A full-stack reproduction of *"WildCat: Near-Linear Attention in Theory
//! and Practice"* (Schröder & Mackey, ICML 2026) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the serving coordinator: request routing,
//!   dynamic batching, prefill/decode scheduling and KV-cache management
//!   with six compression policies over the block-paged [`kvpool`] memory
//!   manager (global float budget, radix prefix sharing, compression-tier
//!   eviction), scaled out by the [`cluster`] tier (replica pool +
//!   pluggable routing), observed end-to-end by the [`obs`] subsystem
//!   (lifecycle span tracing, time-series telemetry, Prometheus
//!   exposition), plus the complete numeric substrate (linear algebra,
//!   RPNYS, attention algorithms, baselines).
//! * **Layer 2 (`python/compile/model.py`)** — the JAX compute graph of the
//!   WildCat pipeline and a small transformer LM, AOT-lowered once to HLO
//!   text artifacts.
//! * **Layer 1 (`python/compile/kernels/`)** — Pallas kernels for the
//!   weighted-attention hot spot, validated against a pure-jnp oracle.
//!
//! Python never runs on the request path: the [`runtime`] module loads the
//! pre-compiled HLO artifacts through PJRT and executes them natively.
//!
//! ## Quick tour
//!
//! ```
//! use wildcat::attention::{wildcat_attention, WildcatParams};
//! use wildcat::linalg::Matrix;
//! use wildcat::rng::Rng;
//!
//! let mut rng = Rng::seed_from(7);
//! let n = 1024;
//! let d = 64;
//! let q = Matrix::randn(&mut rng, n, d);
//! let k = Matrix::randn(&mut rng, n, d);
//! let v = Matrix::randn(&mut rng, n, d);
//! let params = WildcatParams { rank: 64, bins: 8, ..Default::default() };
//! let o_hat = wildcat_attention(&q, &k, &v, &params, &mut rng);
//! assert_eq!(o_hat.rows(), n);
//! ```

pub mod bench;
pub mod util;
pub mod rng;
pub mod exec;
pub mod lambertw;
pub mod linalg;
pub mod kernels;
pub mod rpnys;
pub mod attention;
pub mod baselines;
pub mod kvcache;
pub mod kvpool;
pub mod model;
pub mod runtime;
pub mod coordinator;
pub mod cluster;
pub mod obs;
pub mod workload;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
