//! SnapKV (Li et al. 2024b): score each context key by the attention mass
//! it receives from an observation window of the most recent queries,
//! smooth the scores with 1-D max pooling (to keep local context blocks
//! together), and retain the top-k middle tokens.

use super::{assemble_selection, shrink_to_budget, split_protected, CompressionCtx, KvCompressor, KvEntry};
use crate::kernels::safe_exp;
use crate::linalg::gemm::dot;
use crate::linalg::Matrix;
use crate::rng::Rng;

pub struct SnapKv {
    /// 1-D max-pool kernel width over key positions (paper default 7).
    pub pool: usize,
}

impl Default for SnapKv {
    fn default() -> Self {
        SnapKv { pool: 7 }
    }
}

impl SnapKv {
    /// Attention-mass score of every key from the observation queries,
    /// softmax-normalised per query then summed (the SnapKV voting rule).
    pub fn scores(keys: &Matrix, obs: &Matrix, beta: f64) -> Vec<f64> {
        let n = keys.rows();
        let mut score = vec![0.0f64; n];
        for i in 0..obs.rows() {
            let qi = obs.row(i);
            let logits: Vec<f64> =
                (0..n).map(|j| beta * dot(qi, keys.row(j)) as f64).collect();
            let mx = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let ps: Vec<f64> = logits.iter().map(|&l| safe_exp(l - mx)).collect();
            let total: f64 = ps.iter().sum();
            if total > 0.0 {
                for (s, p) in score.iter_mut().zip(&ps) {
                    *s += p / total;
                }
            }
        }
        score
    }

    /// 1-D max pooling with window `pool` (same-length output).
    pub fn max_pool(scores: &[f64], pool: usize) -> Vec<f64> {
        if pool <= 1 || scores.is_empty() {
            return scores.to_vec();
        }
        let half = pool / 2;
        let n = scores.len();
        (0..n)
            .map(|i| {
                let lo = i.saturating_sub(half);
                let hi = (i + half + 1).min(n);
                scores[lo..hi].iter().cloned().fold(f64::NEG_INFINITY, f64::max)
            })
            .collect()
    }

    /// Indices of the `k` largest scores (ties by position), sorted.
    pub fn top_k(scores: &[f64], k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..scores.len()).collect();
        idx.sort_by(|&a, &b| {
            scores[b].partial_cmp(&scores[a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
        });
        idx.truncate(k);
        idx.sort_unstable();
        idx
    }
}

impl KvCompressor for SnapKv {
    fn name(&self) -> &'static str {
        "SnapKV"
    }

    fn compress(&self, ctx: &CompressionCtx, _rng: &mut Rng) -> KvEntry {
        let n = ctx.keys.rows();
        let Some((head, mid, tail)) = split_protected(n, ctx.budget) else {
            return shrink_to_budget(ctx.keys, ctx.values, ctx.budget);
        };
        let take = ctx.budget.saturating_sub(head + tail).min(mid.len());
        // Observation window: supplied recent queries, else the last
        // PROTECTED keys double as query proxies (K/Q share geometry in
        // trained models).
        let owned_obs;
        let obs: &Matrix = match ctx.obs_queries {
            Some(o) => o,
            None => {
                owned_obs = ctx.keys.slice_rows(n - tail, n);
                &owned_obs
            }
        };
        let mid_keys = ctx.keys.slice_rows(mid.start, mid.end);
        let raw = Self::scores(&mid_keys, obs, ctx.beta);
        let pooled = Self::max_pool(&raw, self.pool);
        let chosen: Vec<usize> = Self::top_k(&pooled, take)
            .into_iter()
            .map(|i| i + mid.start)
            .collect();
        assemble_selection(ctx.keys, ctx.values, &chosen, head)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_k_basic() {
        let s = [0.1, 5.0, 0.2, 4.0, 0.3];
        assert_eq!(SnapKv::top_k(&s, 2), vec![1, 3]);
        assert_eq!(SnapKv::top_k(&s, 0), Vec::<usize>::new());
        assert_eq!(SnapKv::top_k(&s, 10).len(), 5);
    }

    #[test]
    fn max_pool_window() {
        let s = [0.0, 1.0, 0.0, 0.0, 3.0];
        let p = SnapKv::max_pool(&s, 3);
        assert_eq!(p, vec![1.0, 1.0, 1.0, 3.0, 3.0]);
        assert_eq!(SnapKv::max_pool(&s, 1), s.to_vec());
    }

    #[test]
    fn retains_keys_the_observation_window_attends_to() {
        // Construct keys where middle position P strongly matches the
        // observation queries: SnapKV must keep it.
        let n = 300;
        let d = 8;
        let mut rng = Rng::seed_from(1);
        let mut k = Matrix::randn(&mut rng, n, d).scale(0.1);
        let hot = 150usize;
        for j in 0..d {
            k.set(hot, j, 2.0);
        }
        let v = Matrix::randn(&mut rng, n, 4);
        let obs = Matrix::from_fn(8, d, |_, _| 1.0); // aligned with hot key
        let ctx = CompressionCtx {
            keys: &k,
            values: &v,
            budget: 96,
            beta: 1.0,
            layer: 0,
            n_layers: 1,
            obs_queries: Some(&obs),
        };
        let e = SnapKv::default().compress(&ctx, &mut rng);
        assert_eq!(e.len(), 96);
        // the hot key must appear among the retained keys
        let found = (0..e.len()).any(|i| (e.keys.get(i, 0) - 2.0).abs() < 1e-6);
        assert!(found, "hot key was evicted");
    }

    #[test]
    fn scores_sum_to_query_count() {
        // per-query softmax scores sum to 1 ⇒ total mass = #queries
        let mut rng = Rng::seed_from(2);
        let k = Matrix::randn(&mut rng, 40, 4);
        let obs = Matrix::randn(&mut rng, 6, 4);
        let s = SnapKv::scores(&k, &obs, 0.5);
        let total: f64 = s.iter().sum();
        assert!((total - 6.0).abs() < 1e-9, "total={total}");
    }
}
