//! The serving-side KV-cache manager, backed by the block-paged
//! [`KvPool`].
//!
//! The manager keeps the per-sequence *policy* — a per-layer entry budget
//! with a high-water mark that triggers [`KvCompressor`] re-compression —
//! while the pool owns the actual bytes: shared prefix blocks plus
//! private tails, charged against one global float budget. Several
//! managers (or a manager and the scheduler) can share one pool, which is
//! how per-replica global budgets and cross-request prefix sharing reach
//! the serving stack.
//!
//! [`LayerCache`] is the *materialised view* of one layer-head cache —
//! block rows (unit weights) concatenated with the sequence's tail —
//! handed out by value; the storage behind it is pool block handles.

use super::{KvCompressor, KvEntry};
use crate::kvpool::{AdmitError, CompressDims, KvPool, KvPoolConfig, PrefixHandle, RegisterOutcome};
use crate::linalg::Matrix;
use crate::rng::Rng;
use std::collections::BTreeSet;
use std::sync::Arc;

/// One layer's cache view for one sequence: weighted key/value rows.
#[derive(Clone, Debug)]
pub struct LayerCache {
    pub keys: Matrix,
    pub values: Matrix,
    pub weights: Vec<f64>,
    /// Logical context length represented (≥ physical entries after
    /// compression).
    pub logical_len: usize,
}

impl LayerCache {
    pub fn new(d_k: usize, d_v: usize) -> Self {
        LayerCache {
            keys: Matrix::zeros(0, d_k),
            values: Matrix::zeros(0, d_v),
            weights: Vec::new(),
            logical_len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.keys.rows()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Append one decoded token's key/value (unit weight).
    pub fn append(&mut self, k_row: &[f32], v_row: &[f32]) {
        self.keys.push_row(k_row);
        self.values.push_row(v_row);
        self.weights.push(1.0);
        self.logical_len += 1;
    }

    /// Replace contents with a compressed entry.
    pub fn install(&mut self, entry: KvEntry, logical_len: usize) {
        self.keys = entry.keys;
        self.values = entry.values;
        self.weights = entry.weights;
        self.logical_len = logical_len;
    }

    /// f32-equivalent memory footprint.
    pub fn footprint_floats(&self) -> usize {
        self.keys.rows() * self.keys.cols()
            + self.values.rows() * self.values.cols()
            + self.weights.len()
    }
}

/// Aggregate cache statistics (reported by the coordinator).
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    pub sequences: usize,
    pub physical_entries: usize,
    pub logical_tokens: usize,
    /// Per-sequence float attribution (shared blocks counted once per
    /// mapping sequence — the "what would this cost unshared" view).
    pub footprint_floats: usize,
    pub compressions: u64,
    /// Pool-ledger bytes (shared blocks counted once): the real memory.
    pub kv_bytes_current: usize,
    pub kv_bytes_peak: usize,
}

/// Per-sequence KV caches with budget-triggered compression, stored in a
/// (possibly shared) [`KvPool`].
pub struct CacheManager {
    /// Physical entries allowed per (layer, head) per sequence.
    pub budget: usize,
    /// Entries past which compression triggers (hysteresis avoids
    /// re-compressing every decode step). Defaults to `budget`.
    pub high_water: usize,
    pub beta: f64,
    pub n_layers: usize,
    pool: Arc<KvPool>,
    /// Sequence ids this manager created (a shared pool may hold others).
    seqs: BTreeSet<u64>,
    compressions: u64,
}

impl CacheManager {
    /// Stand-alone manager over a private, unbounded pool.
    pub fn new(
        budget: usize,
        n_layers: usize,
        beta: f64,
        compressor: Arc<dyn KvCompressor>,
    ) -> Self {
        let pool = Arc::new(KvPool::new(KvPoolConfig::default(), compressor));
        Self::with_pool(budget, n_layers, beta, pool)
    }

    /// Manager over a shared pool (the serving path: one pool per
    /// replica, threaded through scheduler and server).
    pub fn with_pool(budget: usize, n_layers: usize, beta: f64, pool: Arc<KvPool>) -> Self {
        pool.set_dims(CompressDims { n_layers, beta });
        CacheManager {
            budget,
            high_water: budget,
            beta,
            n_layers,
            pool,
            seqs: BTreeSet::new(),
            compressions: 0,
        }
    }

    pub fn pool(&self) -> &Arc<KvPool> {
        &self.pool
    }

    pub fn compressor_name(&self) -> &'static str {
        self.pool.compressor_name()
    }

    pub fn compressions(&self) -> u64 {
        self.compressions
    }

    /// Create (or reset) the caches for a sequence.
    pub fn create_sequence(&mut self, seq: u64, d_k: usize, d_v: usize) {
        self.pool.create_sequence(seq, self.n_layers, d_k, d_v);
        self.seqs.insert(seq);
    }

    /// Register a prefilled sequence through the pool: shared prefix
    /// blocks are mapped (not copied), new full blocks are sealed for
    /// future requests, the remainder becomes the private tail. The only
    /// admission-controlled entry point — a `PoolExhausted` error means
    /// the pressure ladder could not reclaim enough for this prompt.
    pub fn ingest_prefill(
        &mut self,
        seq: u64,
        tokens: &[u32],
        k_cache: &[Matrix],
        v_cache: &[Matrix],
    ) -> Result<RegisterOutcome, AdmitError> {
        assert_eq!(k_cache.len(), self.n_layers, "layer-cache count mismatch");
        let out = self.pool.register_prefill(seq, tokens, k_cache, v_cache)?;
        self.seqs.insert(seq);
        Ok(out)
    }

    /// Token-level prefix lookup *before* compute — the first half of a
    /// resumed prefill. See [`KvPool::lookup_prefix`]; the handle must be
    /// consumed by [`CacheManager::ingest_resumed`] (or released through
    /// the pool).
    pub fn lookup_prefix(&self, tokens: &[u32]) -> PrefixHandle {
        self.pool.lookup_prefix(tokens)
    }

    /// Register a sequence prefilled from a prefix hit: the handle's
    /// blocks are mapped as the sequence's shared prefix, and only the
    /// tail caches (rows for the unmatched tokens) are new storage.
    /// Same admission control as [`CacheManager::ingest_prefill`],
    /// charged for the tail only.
    pub fn ingest_resumed(
        &mut self,
        seq: u64,
        tokens: &[u32],
        handle: PrefixHandle,
        tail_k: &[Matrix],
        tail_v: &[Matrix],
    ) -> Result<RegisterOutcome, AdmitError> {
        assert_eq!(tail_k.len(), self.n_layers, "layer-cache count mismatch");
        let out = self.pool.register_resumed(seq, tokens, handle, tail_k, tail_v)?;
        self.seqs.insert(seq);
        Ok(out)
    }

    /// Drop a sequence's caches. Returns whether it existed — retire
    /// paths assert on this so leaked/double-freed sequences fail loudly
    /// instead of silently growing the pool.
    #[must_use]
    pub fn drop_sequence(&mut self, seq: u64) -> bool {
        let tracked = self.seqs.remove(&seq);
        let existed = self.pool.drop_sequence(seq);
        debug_assert_eq!(tracked, existed, "manager/pool sequence tracking diverged");
        existed
    }

    pub fn has_sequence(&self, seq: u64) -> bool {
        self.pool.has_sequence(seq)
    }

    /// Materialised view of one layer-head cache.
    pub fn layer(&self, seq: u64, layer: usize) -> Option<LayerCache> {
        let (keys, values, weights, logical_len) = self.pool.layer_view(seq, layer)?;
        Some(LayerCache { keys, values, weights, logical_len })
    }

    /// Materialise every layer-head cache (the decode hot path).
    pub fn gather(&self, seq: u64) -> Option<Vec<(Matrix, Matrix, Vec<f64>)>> {
        self.pool.gather(seq)
    }

    /// Raw append without the budget check (prefill ingestion in tests).
    pub fn append_row(&mut self, seq: u64, layer: usize, k_row: &[f32], v_row: &[f32]) {
        self.pool.append_row(seq, layer, k_row, v_row);
    }

    /// Append a token's K/V to a layer cache; when the layer crosses the
    /// high-water mark the *sequence* is compressed back to budget (every
    /// layer-head past budget — they cross together on the decode path).
    /// Returns whether a compression ran.
    pub fn append_and_maybe_compress(
        &mut self,
        seq: u64,
        layer: usize,
        k_row: &[f32],
        v_row: &[f32],
        obs_queries: Option<&Matrix>,
        rng: &mut Rng,
    ) -> bool {
        self.pool.append_row(seq, layer, k_row, v_row);
        let high_water = self.high_water.max(self.budget);
        let len = self.pool.layer_len(seq, layer).expect("unknown sequence/layer");
        if len <= high_water {
            return false;
        }
        let n = self.pool.compress_sequence(seq, self.budget, obs_queries, rng);
        self.compressions += n as u64;
        n > 0
    }

    /// Compress every layer of a sequence past budget now (prefill
    /// compression).
    pub fn compress_sequence(
        &mut self,
        seq: u64,
        obs_queries: Option<&Matrix>,
        rng: &mut Rng,
    ) {
        let n = self.pool.compress_sequence(seq, self.budget, obs_queries, rng);
        self.compressions += n as u64;
    }

    pub fn stats(&self) -> CacheStats {
        let mut s = CacheStats { sequences: self.seqs.len(), ..Default::default() };
        for &seq in &self.seqs {
            if let Some(st) = self.pool.seq_stats(seq) {
                s.physical_entries += st.physical_total;
                s.logical_tokens += st.logical_total;
                s.footprint_floats += st.footprint_floats;
            }
        }
        s.compressions = self.compressions;
        s.kv_bytes_current = self.pool.used_bytes();
        s.kv_bytes_peak = self.pool.peak_bytes();
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::{StreamingLlm, UniformKv};

    fn mk(budget: usize) -> CacheManager {
        CacheManager::new(budget, 2, 0.35, Arc::new(StreamingLlm))
    }

    #[test]
    fn append_grows_and_tracks_logical() {
        let mut m = mk(1000);
        m.create_sequence(7, 4, 4);
        let mut rng = Rng::seed_from(1);
        for i in 0..10 {
            let k = vec![i as f32; 4];
            let v = vec![-(i as f32); 4];
            let compressed = m.append_and_maybe_compress(7, 0, &k, &v, None, &mut rng);
            assert!(!compressed);
        }
        let l = m.layer(7, 0).unwrap();
        assert_eq!(l.len(), 10);
        assert_eq!(l.logical_len, 10);
        assert_eq!(l.keys.get(3, 0), 3.0);
    }

    #[test]
    fn budget_enforced_with_compression() {
        let mut m = mk(128);
        m.create_sequence(1, 4, 4);
        let mut rng = Rng::seed_from(2);
        let mut any_compressed = false;
        for i in 0..300 {
            let k = vec![(i as f32).sin(); 4];
            let v = vec![(i as f32).cos(); 4];
            any_compressed |= m.append_and_maybe_compress(1, 0, &k, &v, None, &mut rng);
            let l = m.layer(1, 0).unwrap();
            assert!(l.len() <= 129, "cache overflow: {}", l.len());
        }
        assert!(any_compressed);
        let l = m.layer(1, 0).unwrap();
        assert_eq!(l.logical_len, 300);
        assert!(m.stats().compressions > 0);
    }

    #[test]
    fn prefill_compression_all_layers() {
        let mut m = CacheManager::new(100, 2, 0.35, Arc::new(UniformKv));
        m.create_sequence(5, 4, 4);
        let mut rng = Rng::seed_from(3);
        for layer in 0..2 {
            for i in 0..400 {
                // append directly without triggering (budget honoured later)
                m.append_row(5, layer, &[i as f32; 4], &[i as f32; 4]);
            }
        }
        m.compress_sequence(5, None, &mut rng);
        for layer in 0..2 {
            assert_eq!(m.layer(5, layer).unwrap().len(), 100);
            assert_eq!(m.layer(5, layer).unwrap().logical_len, 400);
        }
    }

    #[test]
    fn sequence_lifecycle() {
        let mut m = mk(64);
        m.create_sequence(9, 2, 2);
        assert!(m.has_sequence(9));
        assert_eq!(m.stats().sequences, 1);
        assert!(m.drop_sequence(9), "live sequence must report existed");
        assert!(!m.has_sequence(9));
        assert_eq!(m.stats().sequences, 0);
        assert!(!m.drop_sequence(9), "double drop must report false");
    }

    #[test]
    fn footprint_accounting() {
        let mut m = mk(1000);
        m.create_sequence(1, 3, 5);
        let mut rng = Rng::seed_from(4);
        for _ in 0..7 {
            m.append_and_maybe_compress(1, 1, &[0.0; 3], &[0.0; 5], None, &mut rng);
        }
        let s = m.stats();
        assert_eq!(s.physical_entries, 7);
        assert_eq!(s.footprint_floats, 7 * 3 + 7 * 5 + 7);
        assert_eq!(s.kv_bytes_current, (7 * 3 + 7 * 5 + 7) * 4);
        assert!(s.kv_bytes_peak >= s.kv_bytes_current);
    }

    #[test]
    fn shared_pool_dedups_across_managers() {
        // two managers over one pool: identical prompts stored once
        let pool = Arc::new(KvPool::new(
            KvPoolConfig { block_tokens: 8, ..Default::default() },
            Arc::new(StreamingLlm) as Arc<dyn KvCompressor>,
        ));
        let mut a = CacheManager::with_pool(1000, 2, 0.35, pool.clone());
        let mut b = CacheManager::with_pool(1000, 2, 0.35, pool.clone());
        let tokens: Vec<u32> = (0..32).collect();
        let mut rng = Rng::seed_from(9);
        let ks: Vec<Matrix> = (0..2).map(|_| Matrix::randn(&mut rng, 32, 4)).collect();
        let vs: Vec<Matrix> = (0..2).map(|_| Matrix::randn(&mut rng, 32, 4)).collect();
        let r1 = a.ingest_prefill(1, &tokens, &ks, &vs).unwrap();
        let r2 = b.ingest_prefill(2, &tokens, &ks, &vs).unwrap();
        assert_eq!(r1.matched_tokens, 0);
        assert_eq!(r2.matched_tokens, 32);
        // both managers see the same (deduplicated) pool bytes
        assert_eq!(a.stats().kv_bytes_current, b.stats().kv_bytes_current);
        // but per-sequence attribution counts each mapping
        assert_eq!(a.stats().footprint_floats, b.stats().footprint_floats);
        assert_eq!(a.layer(1, 0).unwrap().keys, b.layer(2, 0).unwrap().keys);
    }
}
