//! The serving-side KV-cache manager.
//!
//! Owns per-sequence, per-layer caches; enforces a per-layer entry budget
//! by invoking the configured [`KvCompressor`] when a cache grows past its
//! high-water mark (prefill compression and mid-stream re-compression);
//! tracks memory/compression statistics for the coordinator's metrics.

use super::{CompressionCtx, KvCompressor, KvEntry};
use crate::linalg::Matrix;
use crate::rng::Rng;
use std::collections::HashMap;

/// One layer's cache for one sequence: weighted key/value rows.
#[derive(Clone, Debug)]
pub struct LayerCache {
    pub keys: Matrix,
    pub values: Matrix,
    pub weights: Vec<f64>,
    /// Logical context length represented (≥ physical entries after
    /// compression).
    pub logical_len: usize,
}

impl LayerCache {
    pub fn new(d_k: usize, d_v: usize) -> Self {
        LayerCache {
            keys: Matrix::zeros(0, d_k),
            values: Matrix::zeros(0, d_v),
            weights: Vec::new(),
            logical_len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.keys.rows()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Append one decoded token's key/value (unit weight).
    pub fn append(&mut self, k_row: &[f32], v_row: &[f32]) {
        self.keys.push_row(k_row);
        self.values.push_row(v_row);
        self.weights.push(1.0);
        self.logical_len += 1;
    }

    /// Replace contents with a compressed entry.
    pub fn install(&mut self, entry: KvEntry, logical_len: usize) {
        self.keys = entry.keys;
        self.values = entry.values;
        self.weights = entry.weights;
        self.logical_len = logical_len;
    }

    /// f32-equivalent memory footprint.
    pub fn footprint_floats(&self) -> usize {
        self.keys.rows() * self.keys.cols()
            + self.values.rows() * self.values.cols()
            + self.weights.len()
    }
}

/// Aggregate cache statistics (reported by the coordinator).
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    pub sequences: usize,
    pub physical_entries: usize,
    pub logical_tokens: usize,
    pub footprint_floats: usize,
    pub compressions: u64,
}

/// Per-sequence KV caches with budget-triggered compression.
pub struct CacheManager {
    /// Physical entries allowed per layer per sequence.
    pub budget: usize,
    /// Entries past which compression triggers (hysteresis avoids
    /// re-compressing every decode step). Defaults to `budget`.
    pub high_water: usize,
    pub beta: f64,
    pub n_layers: usize,
    compressor: Box<dyn KvCompressor>,
    seqs: HashMap<u64, Vec<LayerCache>>,
    compressions: u64,
}

impl CacheManager {
    pub fn new(
        budget: usize,
        n_layers: usize,
        beta: f64,
        compressor: Box<dyn KvCompressor>,
    ) -> Self {
        CacheManager {
            budget,
            high_water: budget,
            beta,
            n_layers,
            compressor,
            seqs: HashMap::new(),
            compressions: 0,
        }
    }

    pub fn compressor_name(&self) -> &'static str {
        self.compressor.name()
    }

    /// Create (or reset) the caches for a sequence.
    pub fn create_sequence(&mut self, seq: u64, d_k: usize, d_v: usize) {
        let layers = (0..self.n_layers).map(|_| LayerCache::new(d_k, d_v)).collect();
        self.seqs.insert(seq, layers);
    }

    pub fn drop_sequence(&mut self, seq: u64) {
        self.seqs.remove(&seq);
    }

    pub fn has_sequence(&self, seq: u64) -> bool {
        self.seqs.contains_key(&seq)
    }

    pub fn layer(&self, seq: u64, layer: usize) -> Option<&LayerCache> {
        self.seqs.get(&seq).and_then(|l| l.get(layer))
    }

    pub fn layer_mut(&mut self, seq: u64, layer: usize) -> Option<&mut LayerCache> {
        self.seqs.get_mut(&seq).and_then(|l| l.get_mut(layer))
    }

    /// Append a token's K/V to a layer cache; compress if past the
    /// high-water mark. Returns whether a compression ran.
    pub fn append_and_maybe_compress(
        &mut self,
        seq: u64,
        layer: usize,
        k_row: &[f32],
        v_row: &[f32],
        obs_queries: Option<&Matrix>,
        rng: &mut Rng,
    ) -> bool {
        let beta = self.beta;
        let n_layers = self.n_layers;
        let budget = self.budget;
        let high_water = self.high_water.max(budget);
        let cache = self
            .seqs
            .get_mut(&seq)
            .and_then(|l| l.get_mut(layer))
            .expect("unknown sequence/layer");
        cache.append(k_row, v_row);
        if cache.len() <= high_water {
            return false;
        }
        // Note: after a compression the weights of the *current* cache are
        // not all 1.0; the compressor treats stored entries as surrogate
        // tokens. This is the paper's streaming re-compression caveat
        // (Sec. 5 limitations) — acceptable because entries were built to
        // reproduce attention behaviour of the originals.
        let ctx = CompressionCtx {
            keys: &cache.keys,
            values: &cache.values,
            budget,
            beta,
            layer,
            n_layers,
            obs_queries,
        };
        let entry = self.compressor.compress(&ctx, rng);
        let logical = cache.logical_len;
        cache.install(entry, logical);
        self.compressions += 1;
        true
    }

    /// Compress every layer of a sequence now (prefill compression).
    pub fn compress_sequence(
        &mut self,
        seq: u64,
        obs_queries: Option<&Matrix>,
        rng: &mut Rng,
    ) {
        let beta = self.beta;
        let n_layers = self.n_layers;
        let budget = self.budget;
        let Some(layers) = self.seqs.get_mut(&seq) else { return };
        for (li, cache) in layers.iter_mut().enumerate() {
            if cache.len() <= budget {
                continue;
            }
            let ctx = CompressionCtx {
                keys: &cache.keys,
                values: &cache.values,
                budget,
                beta,
                layer: li,
                n_layers,
                obs_queries,
            };
            let entry = self.compressor.compress(&ctx, rng);
            let logical = cache.logical_len;
            cache.install(entry, logical);
            self.compressions += 1;
        }
    }

    pub fn stats(&self) -> CacheStats {
        let mut s = CacheStats { sequences: self.seqs.len(), ..Default::default() };
        for layers in self.seqs.values() {
            for l in layers {
                s.physical_entries += l.len();
                s.logical_tokens += l.logical_len;
                s.footprint_floats += l.footprint_floats();
            }
        }
        s.compressions = self.compressions;
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::{StreamingLlm, UniformKv};

    fn mk(budget: usize) -> CacheManager {
        CacheManager::new(budget, 2, 0.35, Box::new(StreamingLlm))
    }

    #[test]
    fn append_grows_and_tracks_logical() {
        let mut m = mk(1000);
        m.create_sequence(7, 4, 4);
        let mut rng = Rng::seed_from(1);
        for i in 0..10 {
            let k = vec![i as f32; 4];
            let v = vec![-(i as f32); 4];
            let compressed = m.append_and_maybe_compress(7, 0, &k, &v, None, &mut rng);
            assert!(!compressed);
        }
        let l = m.layer(7, 0).unwrap();
        assert_eq!(l.len(), 10);
        assert_eq!(l.logical_len, 10);
        assert_eq!(l.keys.get(3, 0), 3.0);
    }

    #[test]
    fn budget_enforced_with_compression() {
        let mut m = mk(128);
        m.create_sequence(1, 4, 4);
        let mut rng = Rng::seed_from(2);
        let mut any_compressed = false;
        for i in 0..300 {
            let k = vec![(i as f32).sin(); 4];
            let v = vec![(i as f32).cos(); 4];
            any_compressed |= m.append_and_maybe_compress(1, 0, &k, &v, None, &mut rng);
            let l = m.layer(1, 0).unwrap();
            assert!(l.len() <= 129, "cache overflow: {}", l.len());
        }
        assert!(any_compressed);
        let l = m.layer(1, 0).unwrap();
        assert_eq!(l.logical_len, 300);
        assert!(m.stats().compressions > 0);
    }

    #[test]
    fn prefill_compression_all_layers() {
        let mut m = CacheManager::new(100, 2, 0.35, Box::new(UniformKv));
        m.create_sequence(5, 4, 4);
        let mut rng = Rng::seed_from(3);
        for layer in 0..2 {
            for i in 0..400 {
                // append directly without triggering (budget honoured later)
                let cache = m.layer_mut(5, layer).unwrap();
                cache.append(&[i as f32; 4], &[i as f32; 4]);
            }
        }
        m.compress_sequence(5, None, &mut rng);
        for layer in 0..2 {
            assert_eq!(m.layer(5, layer).unwrap().len(), 100);
            assert_eq!(m.layer(5, layer).unwrap().logical_len, 400);
        }
    }

    #[test]
    fn sequence_lifecycle() {
        let mut m = mk(64);
        m.create_sequence(9, 2, 2);
        assert!(m.has_sequence(9));
        assert_eq!(m.stats().sequences, 1);
        m.drop_sequence(9);
        assert!(!m.has_sequence(9));
        assert_eq!(m.stats().sequences, 0);
    }

    #[test]
    fn footprint_accounting() {
        let mut m = mk(1000);
        m.create_sequence(1, 3, 5);
        let mut rng = Rng::seed_from(4);
        for _ in 0..7 {
            m.append_and_maybe_compress(1, 1, &[0.0; 3], &[0.0; 5], None, &mut rng);
        }
        let s = m.stats();
        assert_eq!(s.physical_entries, 7);
        assert_eq!(s.footprint_floats, 7 * 3 + 7 * 5 + 7);
    }
}
