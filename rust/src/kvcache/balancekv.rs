//! BalanceKV (Han et al. 2025): KV-cache compression via discrepancy
//! theory. The middle tokens are repeatedly *halved* by a self-balancing
//! signed vector walk over concatenated key/value features, which keeps
//! the retained half's attention contribution balanced against the
//! discarded half's (the streaming-attention discrepancy guarantee).
//!
//! Simplification: Han et al. run the Banaszczyk-style walk per batch with
//! randomised thresholds; we use the deterministic greedy sign rule on a
//! shuffled pairing (same discrepancy order, seed-stable), and trim any
//! overshoot uniformly.

use super::{assemble_selection, shrink_to_budget, split_protected, CompressionCtx, KvCompressor, KvEntry};
use crate::linalg::Matrix;
use crate::rng::Rng;

pub struct BalanceKv;

impl BalanceKv {
    /// One self-balancing halving round over `idx` (absolute indices into
    /// `feat`), returning the survivors.
    fn halve(feat: &Matrix, idx: &[usize], rng: &mut Rng) -> Vec<usize> {
        let f = feat.cols();
        let mut order = idx.to_vec();
        rng.shuffle(&mut order);
        let mut sigma = vec![0.0f64; f];
        let mut keep = Vec::with_capacity(order.len().div_ceil(2));
        let mut t = 0;
        while t + 1 < order.len() {
            let (a, b) = (order[t], order[t + 1]);
            let fa = feat.row(a);
            let fb = feat.row(b);
            let mut ip = 0.0f64;
            for ((&x, &y), &s) in fa.iter().zip(fb).zip(sigma.iter()) {
                ip += s * (x as f64 - y as f64);
            }
            let keep_a = ip <= 0.0;
            let sign = if keep_a { 1.0 } else { -1.0 };
            for ((s, &x), &y) in sigma.iter_mut().zip(fa).zip(fb) {
                *s += sign * (x as f64 - y as f64);
            }
            keep.push(if keep_a { a } else { b });
            t += 2;
        }
        if t < order.len() {
            keep.push(order[t]);
        }
        keep
    }

    /// Balance features: unit-normalised `[k_j ; v_j]` per token (the walk
    /// balances both the attention logits and the value payload).
    fn features(keys: &Matrix, values: &Matrix) -> Matrix {
        let n = keys.rows();
        let d = keys.cols() + values.cols();
        Matrix::from_fn(n, d, |i, j| {
            let raw = if j < keys.cols() {
                keys.get(i, j)
            } else {
                values.get(i, j - keys.cols())
            };
            raw
        })
        .normalised_rows()
    }
}

impl KvCompressor for BalanceKv {
    fn name(&self) -> &'static str {
        "BalanceKV"
    }

    fn compress(&self, ctx: &CompressionCtx, rng: &mut Rng) -> KvEntry {
        let n = ctx.keys.rows();
        let Some((head, mid, tail)) = split_protected(n, ctx.budget) else {
            return shrink_to_budget(ctx.keys, ctx.values, ctx.budget);
        };
        let take = ctx.budget.saturating_sub(head + tail).min(mid.len());
        let feat = Self::features(ctx.keys, ctx.values);
        let mut survivors: Vec<usize> = mid.clone().collect();
        while survivors.len() > take.max(1) * 2 {
            survivors = Self::halve(&feat, &survivors, rng);
        }
        // final partial round / uniform trim to the exact budget
        while survivors.len() > take {
            if survivors.len() >= 2 * take.max(1) {
                survivors = Self::halve(&feat, &survivors, rng);
            } else {
                let keep_idx = rng.sample_without_replacement(survivors.len(), take);
                survivors = keep_idx.into_iter().map(|i| survivors[i]).collect();
            }
        }
        survivors.sort_unstable();
        assemble_selection(ctx.keys, ctx.values, &survivors, head)
    }
}

/// Row-normalisation helper used by the balance walk.
trait NormalisedRows {
    fn normalised_rows(self) -> Matrix;
}

impl NormalisedRows for Matrix {
    fn normalised_rows(mut self) -> Matrix {
        for i in 0..self.rows() {
            let norm: f64 = self
                .row(i)
                .iter()
                .map(|&x| (x as f64) * (x as f64))
                .sum::<f64>()
                .sqrt();
            if norm > 0.0 {
                for x in self.row_mut(i) {
                    *x = (*x as f64 / norm) as f32;
                }
            }
        }
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meets_budget_exactly() {
        let mut rng = Rng::seed_from(1);
        let k = Matrix::randn(&mut rng, 512, 8);
        let v = Matrix::randn(&mut rng, 512, 8);
        for budget in [96usize, 128, 200] {
            let ctx = CompressionCtx {
                keys: &k,
                values: &v,
                budget,
                beta: 0.35,
                layer: 0,
                n_layers: 1,
                obs_queries: None,
            };
            let e = BalanceKv.compress(&ctx, &mut rng);
            assert_eq!(e.len(), budget, "budget={budget}");
        }
    }

    #[test]
    fn balanced_half_tracks_attention_better_than_worst_case() {
        // discrepancy selection should track full attention at least as
        // well as an adversarial contiguous half (which drops a whole
        // region of the context).
        let mut rng = Rng::seed_from(2);
        let n = 512;
        let k = Matrix::randn(&mut rng, n, 8);
        let v = Matrix::randn(&mut rng, n, 4);
        let q = Matrix::randn(&mut rng, 32, 8);
        let beta = 0.35f32;
        let exact = crate::attention::exact_attention(&q, &k, &v, beta);
        let ctx = CompressionCtx {
            keys: &k,
            values: &v,
            budget: 256 + 64,
            beta: beta as f64,
            layer: 0,
            n_layers: 1,
            obs_queries: None,
        };
        let e = BalanceKv.compress(&ctx, &mut rng);
        let o = crate::attention::exact_attention(&q, &e.keys, &e.values, beta);
        let bal_err = crate::linalg::norms::max_abs_diff(&o, &exact);
        // contiguous half baseline
        let half_k = k.slice_rows(0, 256 + 64);
        let half_v = v.slice_rows(0, 256 + 64);
        let o2 = crate::attention::exact_attention(&q, &half_k, &half_v, beta);
        let contig_err = crate::linalg::norms::max_abs_diff(&o2, &exact);
        assert!(
            bal_err <= contig_err * 1.5,
            "balanced={bal_err} contiguous={contig_err}"
        );
    }

    #[test]
    fn halve_keeps_one_per_pair() {
        let mut rng = Rng::seed_from(3);
        let feat = Matrix::randn(&mut rng, 64, 6);
        let idx: Vec<usize> = (0..64).collect();
        let kept = BalanceKv::halve(&feat, &idx, &mut rng);
        assert_eq!(kept.len(), 32);
    }
}
