//! Uniform baseline (Han et al. 2025): keep the protected ends and a
//! uniformly random subset of the middle. The control arm of Tab. 4.

use super::{assemble_selection, shrink_to_budget, split_protected, CompressionCtx, KvCompressor, KvEntry};
use crate::rng::Rng;

pub struct UniformKv;

impl KvCompressor for UniformKv {
    fn name(&self) -> &'static str {
        "Uniform"
    }

    fn compress(&self, ctx: &CompressionCtx, rng: &mut Rng) -> KvEntry {
        let n = ctx.keys.rows();
        let Some((head, mid, tail)) = split_protected(n, ctx.budget) else {
            return shrink_to_budget(ctx.keys, ctx.values, ctx.budget);
        };
        let take = ctx.budget.saturating_sub(head + tail);
        let mid_len = mid.len();
        let chosen: Vec<usize> = rng
            .sample_without_replacement(mid_len, take.min(mid_len))
            .into_iter()
            .map(|i| i + mid.start)
            .collect();
        assemble_selection(ctx.keys, ctx.values, &chosen, head)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    #[test]
    fn meets_budget_and_sorted_middle() {
        let mut rng = Rng::seed_from(1);
        let k = Matrix::randn(&mut rng, 400, 4);
        let v = Matrix::randn(&mut rng, 400, 4);
        let ctx = CompressionCtx {
            keys: &k,
            values: &v,
            budget: 100,
            beta: 0.5,
            layer: 0,
            n_layers: 1,
            obs_queries: None,
        };
        let e = UniformKv.compress(&ctx, &mut rng);
        assert_eq!(e.len(), 100);
        assert_eq!(e.source_len, 400);
        assert!(e.weights.iter().all(|&w| w == 1.0));
    }

    #[test]
    fn deterministic_per_seed() {
        let k = Matrix::randn(&mut Rng::seed_from(2), 300, 4);
        let v = Matrix::randn(&mut Rng::seed_from(3), 300, 4);
        let ctx = CompressionCtx {
            keys: &k,
            values: &v,
            budget: 96,
            beta: 0.5,
            layer: 0,
            n_layers: 1,
            obs_queries: None,
        };
        let e1 = UniformKv.compress(&ctx, &mut Rng::seed_from(9));
        let e2 = UniformKv.compress(&ctx, &mut Rng::seed_from(9));
        assert_eq!(e1.keys, e2.keys);
    }
}
