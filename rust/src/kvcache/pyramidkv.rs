//! PyramidKV (Cai et al. 2025): SnapKV-style observation-window scoring
//! with *pyramidal* per-layer budgets — early layers (which funnel broad
//! information) keep more tokens, late layers fewer, while the average
//! budget across layers matches the requested one.

use super::snapkv::SnapKv;
use super::{assemble_selection, shrink_to_budget, split_protected, CompressionCtx, KvCompressor, KvEntry};
use crate::linalg::Matrix;
use crate::rng::Rng;

pub struct PyramidKv {
    /// Ratio between the first layer's budget and the mean budget
    /// (the last layer gets `2 − shape` of the mean); 1.0 = flat = SnapKV.
    pub shape: f64,
    pub pool: usize,
}

impl Default for PyramidKv {
    fn default() -> Self {
        PyramidKv { shape: 1.5, pool: 7 }
    }
}

impl PyramidKv {
    /// Per-layer budget: linear pyramid through the mean.
    pub fn layer_budget(&self, mean_budget: usize, layer: usize, n_layers: usize) -> usize {
        if n_layers <= 1 {
            return mean_budget;
        }
        let top = self.shape;
        let bottom = 2.0 - self.shape;
        let t = layer as f64 / (n_layers - 1) as f64;
        let factor = top * (1.0 - t) + bottom * t;
        // floor keeps the protected ends + at least one middle token while
        // never exceeding the caller's budget intent (the earlier clamp of
        // 2*PROTECTED+1 silently inflated aggressive budgets)
        let floor = 2 * super::protected_for(mean_budget) + 1;
        ((mean_budget as f64 * factor).round() as usize).max(floor)
    }
}

impl KvCompressor for PyramidKv {
    fn name(&self) -> &'static str {
        "PyramidKV"
    }

    fn compress(&self, ctx: &CompressionCtx, _rng: &mut Rng) -> KvEntry {
        let n = ctx.keys.rows();
        let budget = self.layer_budget(ctx.budget, ctx.layer, ctx.n_layers);
        let Some((head, mid, tail)) = split_protected(n, budget) else {
            return shrink_to_budget(ctx.keys, ctx.values, budget);
        };
        let take = budget.saturating_sub(head + tail).min(mid.len());
        let owned_obs;
        let obs: &Matrix = match ctx.obs_queries {
            Some(o) => o,
            None => {
                owned_obs = ctx.keys.slice_rows(n - tail, n);
                &owned_obs
            }
        };
        let mid_keys = ctx.keys.slice_rows(mid.start, mid.end);
        let raw = SnapKv::scores(&mid_keys, obs, ctx.beta);
        let pooled = SnapKv::max_pool(&raw, self.pool);
        let chosen: Vec<usize> = SnapKv::top_k(&pooled, take)
            .into_iter()
            .map(|i| i + mid.start)
            .collect();
        assemble_selection(ctx.keys, ctx.values, &chosen, head)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pyramid_budgets_average_to_mean() {
        let p = PyramidKv::default();
        let n_layers = 8;
        let mean = 256;
        let total: usize = (0..n_layers).map(|l| p.layer_budget(mean, l, n_layers)).sum();
        let avg = total as f64 / n_layers as f64;
        assert!((avg - mean as f64).abs() < mean as f64 * 0.02, "avg={avg}");
        // monotone decreasing over depth
        for l in 1..n_layers {
            assert!(p.layer_budget(mean, l, n_layers) <= p.layer_budget(mean, l - 1, n_layers));
        }
    }

    #[test]
    fn early_layers_keep_more() {
        let mut rng = Rng::seed_from(1);
        let k = Matrix::randn(&mut rng, 600, 4);
        let v = Matrix::randn(&mut rng, 600, 4);
        let entry_at = |layer: usize| {
            let ctx = CompressionCtx {
                keys: &k,
                values: &v,
                budget: 128,
                beta: 0.5,
                layer,
                n_layers: 4,
                obs_queries: None,
            };
            PyramidKv::default().compress(&ctx, &mut Rng::seed_from(2)).len()
        };
        assert!(entry_at(0) > entry_at(3), "layer0={} layer3={}", entry_at(0), entry_at(3));
    }

    #[test]
    fn single_layer_is_flat() {
        let p = PyramidKv::default();
        assert_eq!(p.layer_budget(100, 0, 1), 100);
    }
}
