//! KV-cache compression (Sec. 4.3): the cache manager plus the six
//! compression policies benchmarked in Tab. 4.
//!
//! * [`compress_kv_policy`] — COMPRESSKV (ours, Alg. 2 wrapped for caches)
//! * [`streaming_llm`] — StreamingLLM (Xiao et al. 2024): sinks + recency
//! * [`snapkv`] — SnapKV (Li et al. 2024b): observation-window scoring
//! * [`pyramidkv`] — PyramidKV (Cai et al. 2025): pyramidal layer budgets
//! * [`balancekv`] — BalanceKV (Han et al. 2025): discrepancy halving
//! * [`uniform`] — Uniform (Han et al. 2025): random subset
//!
//! Protocol (matching Han et al. 2025 / the paper's Sec. 4.3): every
//! policy retains the first and last [`PROTECTED`] tokens verbatim and
//! compresses only the middle of the context to meet the overall budget.

pub mod balancekv;
pub mod cache;
pub mod compress_kv_policy;
pub mod pyramidkv;
pub mod snapkv;
pub mod streaming_llm;
pub mod uniform;

pub use balancekv::BalanceKv;
pub use cache::{CacheManager, CacheStats, LayerCache};
pub use compress_kv_policy::CompressKvPolicy;
pub use pyramidkv::PyramidKv;
pub use snapkv::SnapKv;
pub use streaming_llm::StreamingLlm;
pub use uniform::UniformKv;

use crate::linalg::Matrix;
use crate::rng::Rng;
use std::sync::Arc;

/// All policy names accepted by [`compressor_by_name`], in Tab. 4 order.
pub const COMPRESSOR_NAMES: [&str; 6] =
    ["compresskv", "streaming", "snapkv", "pyramidkv", "balancekv", "uniform"];

/// Resolve a compression policy by its CLI name (`wildcat serve/tasks/
/// cluster --compressor ...`). Errors on unknown names so operator typos
/// surface with the full roster instead of a panic.
pub fn compressor_by_name(name: &str) -> anyhow::Result<Arc<dyn KvCompressor>> {
    Ok(match name {
        "compresskv" => Arc::new(CompressKvPolicy::default()) as Arc<dyn KvCompressor>,
        "streaming" => Arc::new(StreamingLlm),
        "snapkv" => Arc::new(SnapKv::default()),
        "pyramidkv" => Arc::new(PyramidKv::default()),
        "balancekv" => Arc::new(BalanceKv),
        "uniform" => Arc::new(UniformKv),
        other => anyhow::bail!("unknown compressor {other:?} (try {})", COMPRESSOR_NAMES.join("/")),
    })
}

/// Tokens protected verbatim at each end of the context (paper Sec. 4.3:
/// "retain the first and last 32 context tokens").
pub const PROTECTED: usize = 32;

/// A compressed per-layer cache entry: weighted coreset keys/values.
/// Selection-only policies use unit weights; COMPRESSKV uses Nyström
/// weights for its compressed middle.
#[derive(Clone, Debug)]
pub struct KvEntry {
    pub keys: Matrix,
    pub values: Matrix,
    pub weights: Vec<f64>,
    /// Original context length this entry summarises.
    pub source_len: usize,
}

impl KvEntry {
    pub fn len(&self) -> usize {
        self.keys.rows()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Uncompressed passthrough entry.
    pub fn exact(keys: Matrix, values: Matrix) -> Self {
        let n = keys.rows();
        KvEntry { keys, values, weights: vec![1.0; n], source_len: n }
    }
}

/// Everything a compression policy may consult.
pub struct CompressionCtx<'a> {
    /// Full per-layer keys (n×d) and values (n×d_v).
    pub keys: &'a Matrix,
    pub values: &'a Matrix,
    /// Total retained-entry budget (including protected tokens).
    pub budget: usize,
    /// Attention scale β of the layer.
    pub beta: f64,
    /// Layer index and total layer count (for pyramidal policies).
    pub layer: usize,
    pub n_layers: usize,
    /// Recent-window queries (w×d) for attention-score-based policies.
    pub obs_queries: Option<&'a Matrix>,
}

/// A KV-cache compression policy.
pub trait KvCompressor: Send + Sync {
    fn name(&self) -> &'static str;

    /// Compress one layer's `(K, V)` to roughly `ctx.budget` entries.
    fn compress(&self, ctx: &CompressionCtx, rng: &mut Rng) -> KvEntry;
}

/// Number of protected tokens per end for a given budget: the paper's 32
/// when the budget affords it, scaled down (≥ 1) for aggressive budgets
/// so the 93.75%-compression level of Tab. 4 stays meaningful on short
/// contexts (DESIGN.md §3).
pub fn protected_for(budget: usize) -> usize {
    PROTECTED.min((budget / 4).max(1))
}

/// Split `0..n` into (protected head, middle range, protected tail) under
/// the first/last-protected protocol. Returns `None` when the budget or
/// context is too small to compress — callers keep everything when the
/// budget allows it, and otherwise fall back to [`shrink_to_budget`] so
/// the budget contract (`entry.len() <= budget`) holds even at budgets
/// of 0/1/2 entries.
pub fn split_protected(n: usize, budget: usize) -> Option<(usize, std::ops::Range<usize>, usize)> {
    let p = protected_for(budget);
    if budget >= n || n <= 2 * p || budget <= 2 * p {
        return None;
    }
    Some((p, p..n - p, p))
}

/// Last-resort shrink shared by every policy for budgets too small for
/// the protected-ends protocol: keep the attention sinks (head) and the
/// most recent tokens, exactly `budget` entries (`budget == 0` keeps
/// nothing; `budget >= n` keeps everything verbatim). This is what makes
/// `entry.len() <= budget` a hard invariant the pool's capacity ladder
/// can rely on.
pub fn shrink_to_budget(keys: &Matrix, values: &Matrix, budget: usize) -> KvEntry {
    let n = keys.rows();
    if budget >= n {
        return KvEntry::exact(keys.clone(), values.clone());
    }
    let head = budget / 2;
    let tail = budget - head;
    let k = Matrix::vcat(&[&keys.slice_rows(0, head), &keys.slice_rows(n - tail, n)]);
    let v = Matrix::vcat(&[&values.slice_rows(0, head), &values.slice_rows(n - tail, n)]);
    KvEntry { keys: k, values: v, weights: vec![1.0; budget], source_len: n }
}

/// Assemble a [`KvEntry`] from protected head/tail plus selected middle
/// indices with per-index weights. `middle` indices are absolute.
pub fn assemble_entry(
    keys: &Matrix,
    values: &Matrix,
    middle_keys: Matrix,
    middle_values: Matrix,
    middle_weights: Vec<f64>,
    protected: usize,
) -> KvEntry {
    let n = keys.rows();
    let head_k = keys.slice_rows(0, protected);
    let head_v = values.slice_rows(0, protected);
    let tail_k = keys.slice_rows(n - protected, n);
    let tail_v = values.slice_rows(n - protected, n);
    let mut weights = vec![1.0f64; protected];
    weights.extend_from_slice(&middle_weights);
    weights.extend(std::iter::repeat(1.0).take(protected));
    let keys = Matrix::vcat(&[&head_k, &middle_keys, &tail_k]);
    let values = Matrix::vcat(&[&head_v, &middle_values, &tail_v]);
    KvEntry { keys, values, weights, source_len: n }
}

/// Selection-based assembly: keep `selected` absolute middle indices with
/// unit weights.
pub fn assemble_selection(
    keys: &Matrix,
    values: &Matrix,
    selected: &[usize],
    protected: usize,
) -> KvEntry {
    let mk = keys.select_rows(selected);
    let mv = values.select_rows(selected);
    let w = vec![1.0f64; selected.len()];
    assemble_entry(keys, values, mk, mv, w, protected)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_protocol() {
        assert!(split_protected(100, 100).is_none()); // budget >= n
        // short context with a moderate budget still compresses with a
        // scaled-down protected count (p = 12 here)
        let (h3, mid3, t3) = split_protected(60, 50).unwrap();
        assert_eq!((h3, t3), (12, 12));
        assert_eq!(mid3, 12..48);
        assert!(split_protected(12, 24).is_none()); // n <= 2p
        // aggressive budgets scale the protected count down
        let (h2, mid2, t2) = split_protected(1000, 64).unwrap();
        assert_eq!((h2, t2), (16, 16));
        assert_eq!(mid2, 16..984);
        assert_eq!(protected_for(256), 32);
        assert_eq!(protected_for(64), 16);
        assert_eq!(protected_for(2), 1);
        let (h, mid, t) = split_protected(1000, 128).unwrap();
        assert_eq!(h, 32);
        assert_eq!(t, 32);
        assert_eq!(mid, 32..968);
    }

    #[test]
    fn assemble_selection_layout() {
        let mut rng = Rng::seed_from(1);
        let k = Matrix::randn(&mut rng, 100, 4);
        let v = Matrix::randn(&mut rng, 100, 3);
        let e = assemble_selection(&k, &v, &[40, 50, 60], 32);
        assert_eq!(e.len(), 32 + 3 + 32);
        assert_eq!(e.weights.len(), 67);
        assert!(e.weights.iter().all(|&w| w == 1.0));
        // head is rows 0..32, middle at 32..35, tail 35..67
        for j in 0..4 {
            assert_eq!(e.keys.get(0, j), k.get(0, j));
            assert_eq!(e.keys.get(32, j), k.get(40, j));
            assert_eq!(e.keys.get(34, j), k.get(60, j));
            assert_eq!(e.keys.get(35, j), k.get(68, j));
            assert_eq!(e.keys.get(66, j), k.get(99, j));
        }
        assert_eq!(e.source_len, 100);
    }

    #[test]
    fn compressor_roster_resolves() {
        for name in COMPRESSOR_NAMES {
            assert!(!compressor_by_name(name).unwrap().name().is_empty());
        }
        let err = compressor_by_name("nope").unwrap_err().to_string();
        assert!(err.contains("unknown compressor"), "{err}");
        assert!(err.contains("compresskv"), "roster missing from error: {err}");
    }

    #[test]
    fn shrink_to_budget_is_exact_sized() {
        let mut rng = Rng::seed_from(3);
        let k = Matrix::randn(&mut rng, 20, 4);
        let v = Matrix::randn(&mut rng, 20, 3);
        for budget in [0usize, 1, 2, 5, 19] {
            let e = shrink_to_budget(&k, &v, budget);
            assert_eq!(e.len(), budget, "budget={budget}");
            assert_eq!(e.weights.len(), budget);
            assert_eq!(e.source_len, 20);
        }
        // budget 1 keeps the newest token (recency over sinks on ties)
        let e = shrink_to_budget(&k, &v, 1);
        assert_eq!(e.keys.row(0), k.row(19));
        // budget >= n is verbatim
        assert_eq!(shrink_to_budget(&k, &v, 25).keys, k);
    }

    #[test]
    fn exact_entry_passthrough() {
        let mut rng = Rng::seed_from(2);
        let k = Matrix::randn(&mut rng, 10, 4);
        let v = Matrix::randn(&mut rng, 10, 3);
        let e = KvEntry::exact(k.clone(), v.clone());
        assert_eq!(e.len(), 10);
        assert_eq!(e.keys, k);
        assert!(e.weights.iter().all(|&w| w == 1.0));
    }
}
