//! COMPRESSKV as a cache policy — the paper's method (Alg. 2) under the
//! Tab. 4 protocol: first/last-32 tokens retained verbatim, the middle
//! distilled into a *weighted* Nyström coreset with `B = r/12` bins
//! (Sec. 4.3), so unlike the selection baselines *every* middle token
//! contributes to the compressed values `V_S = W V`.

use super::{assemble_entry, shrink_to_budget, split_protected, CompressionCtx, KvCompressor, KvEntry};
use crate::attention::{compress_kv, CompressOpts};
use crate::rng::Rng;

pub struct CompressKvPolicy {
    /// Bin divisor: `B = max(1, r / bin_div)`; the paper uses `r/12`.
    pub bin_div: usize,
    /// Query radius estimate for the temperature rule. When the serving
    /// stack knows recent queries it passes their radius via the ctx
    /// observation window; otherwise the key radius is used as a proxy
    /// (Q and K share scale in trained attention layers).
    pub fallback_rq: Option<f64>,
}

impl Default for CompressKvPolicy {
    fn default() -> Self {
        CompressKvPolicy { bin_div: 12, fallback_rq: None }
    }
}

impl KvCompressor for CompressKvPolicy {
    fn name(&self) -> &'static str {
        "CompressKV"
    }

    fn compress(&self, ctx: &CompressionCtx, rng: &mut Rng) -> KvEntry {
        let n = ctx.keys.rows();
        let Some((head, mid, tail)) = split_protected(n, ctx.budget) else {
            return shrink_to_budget(ctx.keys, ctx.values, ctx.budget);
        };
        let take = ctx.budget.saturating_sub(head + tail).min(mid.len());
        // Round the rank down to a multiple of the bin count: RPNYS
        // splits the rank per bin with a ceiling, so a ragged rank could
        // overshoot `take` by up to `bins − 1` entries and break the hard
        // budget contract the kvpool capacity ladder relies on.
        let bins = (take / self.bin_div).max(1);
        let rank = (take / bins) * bins;
        let mid_keys = ctx.keys.slice_rows(mid.start, mid.end);
        let mid_vals = ctx.values.slice_rows(mid.start, mid.end);
        let r_q = match (ctx.obs_queries, self.fallback_rq) {
            (Some(obs), _) => obs.max_row_norm(),
            (None, Some(rq)) => rq,
            (None, None) => mid_keys.max_row_norm(),
        };
        let opts = CompressOpts { rank, bins, beta: ctx.beta, r_q };
        let c = compress_kv(&mid_keys, &mid_vals, &opts, rng);
        assemble_entry(ctx.keys, ctx.values, c.keys, c.values, c.weights, head)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{exact_attention, wtd_attention, ClipRange};
    use crate::linalg::norms::max_abs_diff;
    use crate::linalg::Matrix;

    fn ctx<'a>(k: &'a Matrix, v: &'a Matrix, budget: usize) -> CompressionCtx<'a> {
        CompressionCtx {
            keys: k,
            values: v,
            budget,
            beta: 0.35,
            layer: 0,
            n_layers: 1,
            obs_queries: None,
        }
    }

    #[test]
    fn budget_and_weighted_middle() {
        let mut rng = Rng::seed_from(1);
        let k = Matrix::randn(&mut rng, 512, 8);
        let v = Matrix::randn(&mut rng, 512, 4);
        let e = CompressKvPolicy::default().compress(&ctx(&k, &v, 128), &mut rng);
        assert!(e.len() <= 128, "len={}", e.len()); // hard budget contract
        assert_eq!(e.weights.len(), e.len());
        // protected ends have unit weights; middle generally not
        assert!(e.weights[..32].iter().all(|&w| w == 1.0));
        assert!(e.weights[e.len() - 32..].iter().all(|&w| w == 1.0));
        let mid = &e.weights[32..e.len() - 32];
        assert!(mid.iter().any(|&w| (w - 1.0).abs() > 1e-9), "middle not weighted");
    }

    #[test]
    fn beats_uniform_on_attention_fidelity() {
        // The headline Tab. 4 mechanism: weighted Nyström coreset should
        // approximate attention better than a uniform subset at the same
        // budget, averaged over seeds.
        let mut data_rng = Rng::seed_from(2);
        let n = 512;
        let k = Matrix::randn(&mut data_rng, n, 8);
        let v = Matrix::randn(&mut data_rng, n, 4);
        let q = Matrix::randn(&mut data_rng, 24, 8);
        let beta = 0.35f32;
        let exact = exact_attention(&q, &k, &v, beta);
        let clip = ClipRange::from_values(&v);
        let run = |comp: &dyn KvCompressor, seed: u64| {
            let mut rng = Rng::seed_from(seed);
            let e = comp.compress(&ctx(&k, &v, 160), &mut rng);
            let o = wtd_attention(&q, &e.keys, &e.values, &e.weights, &clip, beta);
            max_abs_diff(&o, &exact)
        };
        let mut ours = 0.0;
        let mut unif = 0.0;
        for s in 0..6 {
            ours += run(&CompressKvPolicy::default(), 100 + s);
            unif += run(&super::super::UniformKv, 100 + s);
        }
        assert!(
            ours < unif,
            "CompressKV ({ours}) should beat Uniform ({unif}) on fidelity"
        );
    }

    #[test]
    fn small_context_scaled_protection() {
        let mut rng = Rng::seed_from(3);
        let k = Matrix::randn(&mut rng, 50, 4);
        let v = Matrix::randn(&mut rng, 50, 4);
        // budget 40 on n=50: protected scales to 10 per end; compresses
        let e = CompressKvPolicy::default().compress(&ctx(&k, &v, 40), &mut rng);
        assert!(e.len() <= 42 && e.len() >= 20, "len={}", e.len());
        // and a budget >= n keeps everything verbatim
        let e2 = CompressKvPolicy::default().compress(&ctx(&k, &v, 64), &mut rng);
        assert_eq!(e2.len(), 50);
    }
}
