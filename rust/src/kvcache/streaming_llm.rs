//! StreamingLLM (Xiao et al. 2024): keep the attention-sink tokens at the
//! start of the context plus a sliding window of the most recent tokens.
//! No middle tokens survive — the cheapest and lossiest policy in Tab. 4.

use super::{protected_for, shrink_to_budget, CompressionCtx, KvCompressor, KvEntry};
use crate::linalg::Matrix;
use crate::rng::Rng;

pub struct StreamingLlm;

impl KvCompressor for StreamingLlm {
    fn name(&self) -> &'static str {
        "StreamingLLM"
    }

    fn compress(&self, ctx: &CompressionCtx, _rng: &mut Rng) -> KvEntry {
        let n = ctx.keys.rows();
        if ctx.budget >= n || ctx.budget < 2 {
            // budget >= n keeps everything; budgets of 0/1 still honour
            // the budget through the shared tiny-budget fallback
            return shrink_to_budget(ctx.keys, ctx.values, ctx.budget.min(n));
        }
        // sinks = protected head, recency = the rest of the budget
        let sink = protected_for(ctx.budget).min(ctx.budget / 2);
        let recent = ctx.budget - sink;
        let head_k = ctx.keys.slice_rows(0, sink);
        let head_v = ctx.values.slice_rows(0, sink);
        let tail_k = ctx.keys.slice_rows(n - recent, n);
        let tail_v = ctx.values.slice_rows(n - recent, n);
        let keys = Matrix::vcat(&[&head_k, &tail_k]);
        let values = Matrix::vcat(&[&head_v, &tail_v]);
        let total = keys.rows();
        KvEntry { keys, values, weights: vec![1.0; total], source_len: n }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_sinks_and_recency() {
        let mut rng = Rng::seed_from(1);
        let k = Matrix::from_fn(500, 2, |i, _| i as f32);
        let v = Matrix::randn(&mut rng, 500, 2);
        let ctx = CompressionCtx {
            keys: &k,
            values: &v,
            budget: 128,
            beta: 0.5,
            layer: 0,
            n_layers: 2,
            obs_queries: None,
        };
        let e = StreamingLlm.compress(&ctx, &mut rng);
        assert_eq!(e.len(), 128);
        assert_eq!(e.keys.get(0, 0), 0.0); // first sink token
        assert_eq!(e.keys.get(31, 0), 31.0); // last sink token
        assert_eq!(e.keys.get(32, 0), 404.0); // recency window start
        assert_eq!(e.keys.get(127, 0), 499.0); // newest token
    }

    #[test]
    fn passthrough_when_budget_sufficient() {
        let mut rng = Rng::seed_from(2);
        let k = Matrix::randn(&mut rng, 50, 2);
        let v = Matrix::randn(&mut rng, 50, 2);
        let ctx = CompressionCtx {
            keys: &k,
            values: &v,
            budget: 100,
            beta: 0.5,
            layer: 0,
            n_layers: 1,
            obs_queries: None,
        };
        let e = StreamingLlm.compress(&ctx, &mut rng);
        assert_eq!(e.len(), 50);
    }
}
