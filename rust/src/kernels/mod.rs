//! The exponential attention kernel `h(x, y) = exp(β⟨x, y⟩)` and the
//! paper's pre-conditioning steps: key recentring (Sec. 2.4) and the
//! closed-form temperature rule (Eq. 4).
//!
//! Kernel matrices are evaluated in f64 (the Cholesky recursions of RPNYS
//! amplify round-off in f32) with exponents clamped to the f64-safe range.

use crate::lambertw::{lambert_w0, rho0};
use crate::linalg::Matrix;

/// Clamp for exponents so `exp` stays finite in f64.
const EXP_CLAMP: f64 = 700.0;

/// `exp(c)` with overflow clamping.
#[inline]
pub fn safe_exp(c: f64) -> f64 {
    c.clamp(-EXP_CLAMP, EXP_CLAMP).exp()
}

/// Effective kernel scale used by RPNYS: `β / τ²`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct KernelScale {
    pub beta: f64,
    pub tau: f64,
}

impl KernelScale {
    #[inline]
    pub fn effective(&self) -> f64 {
        self.beta / (self.tau * self.tau)
    }
}

/// `h_τ(x, y) = exp(β⟨x, y⟩ / τ²)` for f32 rows.
///
/// The inner product runs through the SIMD f32 kernel (§Perf iteration 3:
/// the scalar f64 loop dominated RPNYS); only the exponent is f64. For
/// the d ≤ 256 head dims of this stack the f32 dot's relative error
/// (~1e-6) is far below the Nyström jitter floor.
#[inline]
pub fn exp_kernel(scale_eff: f64, x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    safe_exp(scale_eff * crate::linalg::gemm::dot(x, y) as f64)
}

/// Kernel diagonal `h_τ(k_l, k_l)` for all rows of `K` (same SIMD path as
/// [`exp_kernel`] so diagonal and cross entries agree bit-for-bit).
pub fn kernel_diag(k: &Matrix, scale_eff: f64) -> Vec<f64> {
    (0..k.rows())
        .map(|i| exp_kernel(scale_eff, k.row(i), k.row(i)))
        .collect()
}

/// Dense Gram matrix `h_τ(A, B)` as row-major f64 (`A.rows × B.rows`).
/// Only used on small blocks (coresets, bins); O(|A||B|d).
pub fn kernel_cross(a: &Matrix, b: &Matrix, scale_eff: f64) -> Vec<f64> {
    assert_eq!(a.cols(), b.cols());
    let (m, n) = (a.rows(), b.rows());
    let mut out = vec![0.0f64; m * n];
    for i in 0..m {
        let ra = a.row(i);
        for j in 0..n {
            out[i * n + j] = exp_kernel(scale_eff, ra, b.row(j));
        }
    }
    out
}

/// Kernel column `h_τ(K, k_s)` for a single pivot row `s` of `K`.
pub fn kernel_column(k: &Matrix, s: usize, scale_eff: f64) -> Vec<f64> {
    let rs = k.row(s);
    (0..k.rows()).map(|i| exp_kernel(scale_eff, k.row(i), rs)).collect()
}

/// The paper's temperature rule (Eq. 4):
///
/// `τ = sqrt( (R_K / R_Q) · b₀ / (2 W₀(b₀ / (2ρ₀))) )` with
/// `b₀ = log(n) / (β R_Q R_K) + 2`.
///
/// Degenerate inputs (zero radii, n ≤ 1) fall back to `τ = 1` (identity
/// rescaling), which keeps WTDATTN exact in those trivial cases.
pub fn temperature(beta: f64, r_q: f64, r_k: f64, n: usize) -> f64 {
    if !(beta > 0.0) || !(r_q > 0.0) || !(r_k > 0.0) || n <= 1 {
        return 1.0;
    }
    let b0 = (n as f64).ln() / (beta * r_q * r_k) + 2.0;
    let w = lambert_w0(b0 / (2.0 * rho0()));
    if !(w > 0.0) {
        return 1.0;
    }
    let tau2 = (r_k / r_q) * b0 / (2.0 * w);
    tau2.max(1e-12).sqrt()
}

/// Entry growth factor `γ(n) = β R_Q R_K / log(n)` (Cor. 2, Tab. 5).
pub fn gamma_growth(beta: f64, r_q: f64, r_k: f64, n: usize) -> f64 {
    if n <= 1 {
        return f64::INFINITY;
    }
    beta * r_q * r_k / (n as f64).ln()
}

/// Recentred keys plus the mean that was removed (Sec. 2.4).
pub struct Recentred {
    pub keys: Matrix,
    pub mean: Vec<f32>,
}

/// Subtract the column mean from the keys; attention output is invariant
/// to this shift (Sec. 2.4), while low-rank approximability improves.
pub fn recenter_keys(k: &Matrix) -> Recentred {
    let mean = k.col_mean();
    Recentred { keys: k.sub_row_vector(&mean), mean }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::util::prop::Cases;

    #[test]
    fn kernel_symmetry_and_positivity() {
        Cases::new(16).run(|rng| {
            let n = 2 + rng.below(10);
            let d = 1 + rng.below(8);
            let k = Matrix::randn(rng, n, d);
            let h = kernel_cross(&k, &k, 0.3);
            for i in 0..n {
                for j in 0..n {
                    assert!(h[i * n + j] > 0.0);
                    assert!((h[i * n + j] - h[j * n + i]).abs() < 1e-12);
                }
            }
        });
    }

    #[test]
    fn kernel_diag_matches_cross() {
        let mut rng = Rng::seed_from(2);
        let k = Matrix::randn(&mut rng, 6, 4);
        let h = kernel_cross(&k, &k, 0.5);
        let d = kernel_diag(&k, 0.5);
        for i in 0..6 {
            assert!((h[i * 6 + i] - d[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn kernel_cauchy_schwarz() {
        // h(x,y) <= sqrt(h(x,x) h(y,y)) — h is a PSD kernel.
        Cases::new(32).run(|rng| {
            let d = 1 + rng.below(6);
            let x = Matrix::randn(rng, 1, d);
            let y = Matrix::randn(rng, 1, d);
            let hxy = exp_kernel(0.7, x.row(0), y.row(0));
            let hxx = exp_kernel(0.7, x.row(0), x.row(0));
            let hyy = exp_kernel(0.7, y.row(0), y.row(0));
            assert!(hxy <= (hxx * hyy).sqrt() * (1.0 + 1e-12));
        });
    }

    #[test]
    fn safe_exp_clamps() {
        assert!(safe_exp(1e6).is_finite());
        assert!(safe_exp(-1e6) >= 0.0);
        assert!((safe_exp(1.0) - 1.0f64.exp()).abs() < 1e-12);
    }

    #[test]
    fn temperature_positive_and_scales() {
        // τ grows as entries shrink relative to log n (more aggressive
        // rescaling is safe when the kernel matrix is already flat).
        let t1 = temperature(0.125, 8.0, 8.0, 1024);
        let t2 = temperature(0.125, 2.0, 2.0, 1024);
        assert!(t1 > 0.0 && t2 > 0.0);
        assert!(t2 > t1, "t1={t1} t2={t2}");
    }

    #[test]
    fn temperature_degenerate_inputs() {
        assert_eq!(temperature(0.0, 1.0, 1.0, 100), 1.0);
        assert_eq!(temperature(0.5, 0.0, 1.0, 100), 1.0);
        assert_eq!(temperature(0.5, 1.0, 1.0, 1), 1.0);
    }

    #[test]
    fn temperature_matches_formula() {
        // hand-evaluate Eq. 4 once
        let (beta, rq, rk, n) = (0.125f64, 4.0f64, 3.0f64, 4096usize);
        let b0 = (n as f64).ln() / (beta * rq * rk) + 2.0;
        let want = ((rk / rq) * b0 / (2.0 * lambert_w0(b0 / (2.0 * rho0())))).sqrt();
        assert!((temperature(beta, rq, rk, n) - want).abs() < 1e-12);
    }

    #[test]
    fn gamma_decreasing_in_n_for_fixed_radii() {
        let g1 = gamma_growth(0.125, 5.0, 5.0, 64);
        let g2 = gamma_growth(0.125, 5.0, 5.0, 4096);
        assert!(g2 < g1);
    }

    #[test]
    fn recenter_zero_mean() {
        let mut rng = Rng::seed_from(7);
        let k = Matrix::randn(&mut rng, 50, 3);
        let rc = recenter_keys(&k);
        for m in rc.keys.col_mean() {
            assert!(m.abs() < 1e-5);
        }
        // restoring the mean recovers the input
        let mut restored = rc.keys.clone();
        restored.add_row_vector_mut(&rc.mean);
        for (a, b) in restored.as_slice().iter().zip(k.as_slice()) {
            assert!((a - b).abs() < 1e-5);
        }
    }
}
