//! Poison-recovering synchronization helpers.
//!
//! A panicking thread poisons every `Mutex` it holds, and the default
//! `.lock().unwrap()` idiom then cascades that panic into every sibling
//! that touches the same state. In a supervised cluster a replica worker
//! is *allowed* to die (fault injection crashes them on purpose); the
//! shared health/router/metrics state it may have been touching must stay
//! usable for the survivors. These helpers recover the guard from a
//! poisoned lock instead of propagating the panic — safe here because all
//! protected state in this crate is counters, maps and ring buffers whose
//! invariants hold after every individual store.

use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};
use std::time::Duration;

/// Lock a mutex, recovering the guard if a previous holder panicked.
#[inline]
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// `Condvar::wait_timeout` that recovers from poisoning and discards the
/// timeout flag (callers re-check their predicate and the clock anyway).
#[inline]
pub fn wait_timeout_recover<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    timeout: Duration,
) -> MutexGuard<'a, T> {
    match cv.wait_timeout(guard, timeout) {
        Ok((g, _)) => g,
        Err(poisoned) => poisoned.into_inner().0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn lock_recover_survives_poison() {
        let m = Arc::new(Mutex::new(0u64));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.is_poisoned());
        *lock_recover(&m) += 1;
        assert_eq!(*lock_recover(&m), 1);
    }

    #[test]
    fn wait_timeout_recover_returns_guard() {
        let m = Mutex::new(3u32);
        let cv = Condvar::new();
        let g = lock_recover(&m);
        let g = wait_timeout_recover(&cv, g, Duration::from_millis(1));
        assert_eq!(*g, 3);
    }
}
