//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, and `--key=value` forms plus free
//! positional arguments. Every experiment binary and the coordinator's
//! `wildcat` CLI parse through this.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Parse from an iterator of argument strings.
    pub fn parse<I, S>(args: I) -> Self
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let toks: Vec<String> = args.into_iter().map(Into::into).collect();
        let mut out = Args::default();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if let Some(body) = t.strip_prefix("--") {
                if let Some(eq) = body.find('=') {
                    out.opts
                        .insert(body[..eq].to_string(), body[eq + 1..].to_string());
                } else if i + 1 < toks.len() && !toks[i + 1].starts_with("--") {
                    out.opts.insert(body.to_string(), toks[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(t.clone());
            }
            i += 1;
        }
        out
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// Typed getter with default; panics with a clear message on parse
    /// failure (these are operator-facing binaries).
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.get(name) {
            None => default,
            Some(s) => s
                .parse::<T>()
                .unwrap_or_else(|_| panic!("--{name}: cannot parse {s:?}")),
        }
    }

    /// Comma-separated list getter, e.g. `--ranks 64,128,256`.
    pub fn get_list<T: std::str::FromStr>(&self, name: &str, default: &[T]) -> Vec<T>
    where
        T: Clone,
    {
        match self.get(name) {
            None => default.to_vec(),
            Some(s) => s
                .split(',')
                .filter(|p| !p.is_empty())
                .map(|p| {
                    p.trim()
                        .parse::<T>()
                        .unwrap_or_else(|_| panic!("--{name}: cannot parse element {p:?}"))
                })
                .collect(),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_forms() {
        let a = Args::parse(["--seed", "42", "--fast", "--out=/tmp/x", "pos1", "pos2"]);
        assert_eq!(a.get("seed"), Some("42"));
        assert!(a.flag("fast"));
        assert!(!a.flag("slow"));
        assert_eq!(a.get("out"), Some("/tmp/x"));
        assert_eq!(a.positional(), &["pos1".to_string(), "pos2".to_string()]);
    }

    #[test]
    fn typed_getters() {
        let a = Args::parse(["--n", "1024", "--tau", "2.5"]);
        assert_eq!(a.get_parse::<usize>("n", 0), 1024);
        assert!((a.get_parse::<f64>("tau", 0.0) - 2.5).abs() < 1e-12);
        assert_eq!(a.get_parse::<usize>("missing", 7), 7);
    }

    #[test]
    fn list_getter() {
        let a = Args::parse(["--ranks", "64,128,256"]);
        assert_eq!(a.get_list::<usize>("ranks", &[]), vec![64, 128, 256]);
        assert_eq!(a.get_list::<usize>("bins", &[2, 4]), vec![2, 4]);
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse(["--verbose"]);
        assert!(a.flag("verbose"));
    }

    #[test]
    #[should_panic]
    fn bad_parse_panics() {
        let a = Args::parse(["--n", "abc"]);
        a.get_parse::<usize>("n", 0);
    }
}
