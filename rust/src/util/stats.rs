//! Summary statistics over samples: mean / stddev / median / percentiles.
//! Shared by the bench harness and the coordinator's latency metrics.

/// Summary of a sample of f64 observations.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p90: f64,
    pub p99: f64,
}

/// Percentile by linear interpolation on the sorted sample, `q ∈ [0,1]`.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of empty sample");
    let q = q.clamp(0.0, 1.0);
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Compute a [`Summary`] of the sample. Panics on an empty sample.
pub fn summarize(samples: &[f64]) -> Summary {
    assert!(!samples.is_empty(), "summarize of empty sample");
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        count: n,
        mean,
        stddev: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        median: percentile(&sorted, 0.5),
        p90: percentile(&sorted, 0.9),
        p99: percentile(&sorted, 0.99),
    }
}

/// Online (streaming) mean/variance via Welford's algorithm; used by the
/// coordinator metrics where storing every observation is undesirable.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n > 1 {
            self.m2 / (self.n - 1) as f64
        } else {
            0.0
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Fixed-bucket latency histogram (log-spaced), cheap enough for the
/// serving hot path. Buckets are `[base * growth^i, base * growth^{i+1})`.
#[derive(Clone, Debug)]
pub struct LogHistogram {
    base: f64,
    growth: f64,
    counts: Vec<u64>,
    underflow: u64,
    total: u64,
    sum: f64,
}

impl LogHistogram {
    /// Typical latency histogram: 1 µs base, ×1.5 growth, 64 buckets spans
    /// ~1 µs … ~10^11 µs.
    pub fn latency_us() -> Self {
        Self::new(1.0, 1.5, 64)
    }

    pub fn new(base: f64, growth: f64, buckets: usize) -> Self {
        assert!(base > 0.0 && growth > 1.0 && buckets > 0);
        LogHistogram { base, growth, counts: vec![0; buckets], underflow: 0, total: 0, sum: 0.0 }
    }

    pub fn record(&mut self, x: f64) {
        self.total += 1;
        self.sum += x;
        if x < self.base {
            self.underflow += 1;
            return;
        }
        let idx = ((x / self.base).ln() / self.growth.ln()).floor() as usize;
        let idx = idx.min(self.counts.len() - 1);
        self.counts[idx] += 1;
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    /// Sum of every recorded value (Prometheus histogram `_sum`).
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Cumulative buckets as `(upper_edge, count_le)` pairs, suitable for
    /// a Prometheus histogram exposition: the first bucket's upper edge
    /// is `base` and absorbs underflow, each subsequent edge multiplies
    /// by `growth`, and the final count equals [`LogHistogram::total`]
    /// (the last bucket is clamped open-ended on record, so its edge
    /// behaves as `+Inf` for counting purposes).
    pub fn cumulative_buckets(&self) -> Vec<(f64, u64)> {
        let mut out = Vec::with_capacity(self.counts.len() + 1);
        let mut acc = self.underflow;
        out.push((self.base, acc));
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            out.push((self.base * self.growth.powi(i as i32 + 1), acc));
        }
        out
    }

    /// Approximate quantile from bucket boundaries (upper edge).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64;
        let mut acc = self.underflow;
        if acc >= target {
            return self.base;
        }
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return self.base * self.growth.powi(i as i32 + 1);
            }
        }
        self.base * self.growth.powi(self.counts.len() as i32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.count, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.stddev - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_single() {
        let s = summarize(&[7.0]);
        assert_eq!(s.median, 7.0);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.p99, 7.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert!((percentile(&v, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&v, 0.0), 0.0);
        assert_eq!(percentile(&v, 1.0), 10.0);
    }

    #[test]
    fn welford_matches_batch() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 3.0 + 1.0).collect();
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let s = summarize(&xs);
        assert!((w.mean() - s.mean).abs() < 1e-10);
        assert!((w.stddev() - s.stddev).abs() < 1e-10);
        assert_eq!(w.count(), 100);
        assert_eq!(w.min(), s.min);
        assert_eq!(w.max(), s.max);
    }

    #[test]
    fn histogram_quantiles_reasonable() {
        let mut h = LogHistogram::latency_us();
        for i in 1..=1000 {
            h.record(i as f64);
        }
        let p50 = h.quantile(0.5);
        // bucket edges are coarse (×1.5) — allow one bucket of slack
        assert!(p50 >= 300.0 && p50 <= 1200.0, "p50={p50}");
        assert!(h.quantile(1.0) >= 1000.0);
        assert_eq!(h.total(), 1000);
    }

    #[test]
    fn histogram_quantiles_within_one_bucket_of_ground_truth() {
        // The serving benches' p50/p99 numbers come straight from this
        // estimator: feed distributions with known quantiles and assert
        // the estimate lands within one log-bucket of the truth. The
        // estimator returns the upper edge of the bucket containing the
        // target rank, so truth ≤ estimate < truth · growth²; one extra
        // growth factor of slack covers the edge-straddling case.
        let check = |h: &LogHistogram, growth: f64, q: f64, truth: f64| {
            let est = h.quantile(q);
            assert!(
                est >= truth && est <= truth * growth * growth,
                "q={q}: estimate {est} not within one ×{growth} bucket of {truth}"
            );
        };

        // uniform 1..=100_000: p50 = 50_000, p90 = 90_000, p99 = 99_000
        let mut h = LogHistogram::latency_us();
        for i in 1..=100_000 {
            h.record(i as f64);
        }
        check(&h, 1.5, 0.5, 50_000.0);
        check(&h, 1.5, 0.9, 90_000.0);
        check(&h, 1.5, 0.99, 99_000.0);

        // exponential via inverse CDF on a deterministic grid: the p-th
        // quantile of Exp(λ) is −ln(1−p)/λ (λ = 1e−3 → mean 1000)
        let mut h = LogHistogram::latency_us();
        let n = 100_000;
        for i in 0..n {
            let u = (i as f64 + 0.5) / n as f64;
            h.record(-(1.0 - u).ln() * 1000.0);
        }
        check(&h, 1.5, 0.5, -(0.5f64).ln() * 1000.0);
        check(&h, 1.5, 0.99, -(0.01f64).ln() * 1000.0);

        // a finer histogram tightens the bound correspondingly
        let mut h = LogHistogram::new(1.0, 1.1, 200);
        for i in 1..=100_000 {
            h.record(i as f64);
        }
        check(&h, 1.1, 0.5, 50_000.0);
        check(&h, 1.1, 0.99, 99_000.0);
    }

    #[test]
    fn histogram_underflow() {
        let mut h = LogHistogram::new(10.0, 2.0, 8);
        h.record(0.5);
        h.record(1e9);
        assert_eq!(h.total(), 2);
        assert!(h.quantile(0.25) <= 10.0);
    }

    #[test]
    fn histogram_sum_and_cumulative_buckets() {
        let mut h = LogHistogram::new(10.0, 2.0, 4);
        for x in [0.5, 15.0, 25.0, 1e9] {
            h.record(x);
        }
        assert!((h.sum() - (0.5 + 15.0 + 25.0 + 1e9)).abs() < 1e-3);
        let b = h.cumulative_buckets();
        assert_eq!(b.len(), 5);
        // edges: 10, 20, 40, 80, 160; underflow folds into the first
        assert_eq!(b[0], (10.0, 1));
        assert_eq!(b[1], (20.0, 2));
        assert_eq!(b[2], (40.0, 3));
        // the clamped overflow value lands in the last bucket
        assert_eq!(b[4].1, h.total());
        // cumulative counts are monotone non-decreasing
        for w in b.windows(2) {
            assert!(w[1].1 >= w[0].1);
            assert!(w[1].0 > w[0].0);
        }
    }
}
