//! ASCII table renderer used by every bench to print paper-style tables
//! (Tab. 2, Tab. 3, Tab. 4, Tab. 5 and the Fig. 3 / Fig. M.1 series).

/// A simple column-aligned table with a header row.
#[derive(Clone, Debug, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn add_row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render to a string with column alignment and a rule under the header.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncols {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<width$}", cells[i], width = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Render as markdown (for EXPERIMENTS.md inclusion).
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("**{}**\n\n", self.title));
        }
        out.push_str(&format!("| {} |\n", self.header.join(" | ")));
        out.push_str(&format!(
            "|{}\n",
            "---|".repeat(self.header.len())
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format helpers shared by benches.
pub fn fmt_speedup(x: f64) -> String {
    format!("{x:.2}x")
}

pub fn fmt_pct(x: f64) -> String {
    format!("{x:.2}%")
}

pub fn fmt_sci(x: f64) -> String {
    format!("{x:.3e}")
}

pub fn fmt_ms(seconds: f64) -> String {
    format!("{:.3} ms", seconds * 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["method", "speedup"]);
        t.add_row(vec!["exact".into(), "1.00x".into()]);
        t.add_row(vec!["wildcat-long-name".into(), "4.33x".into()]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        let lines: Vec<&str> = r.lines().collect();
        // header + rule + two rows
        assert_eq!(lines.len(), 5);
        assert!(lines[1].starts_with("method"));
        assert!(lines[2].chars().all(|c| c == '-'));
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.add_row(vec!["only-one".into()]);
    }

    #[test]
    fn markdown_shape() {
        let mut t = Table::new("", &["a", "b"]);
        t.add_row(vec!["1".into(), "2".into()]);
        let md = t.render_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("|---|---|"));
        assert!(md.contains("| 1 | 2 |"));
    }

    #[test]
    fn format_helpers() {
        assert_eq!(fmt_speedup(4.331), "4.33x");
        assert_eq!(fmt_pct(1.216), "1.22%");
        assert_eq!(fmt_ms(0.001234), "1.234 ms");
        assert!(fmt_sci(0.000123).contains('e'));
    }
}
