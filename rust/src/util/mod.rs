//! General utilities: statistics, table rendering, CLI parsing, JSON,
//! property-test helpers. These replace criterion/clap/serde, which are
//! unavailable in the offline build.

pub mod cli;
pub mod json;
pub mod prop;
pub mod stats;
pub mod sync;
pub mod table;
