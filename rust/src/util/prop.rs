//! Tiny property-testing helper (proptest is unavailable offline).
//!
//! `Cases` drives a closure over many seeded random cases and reports the
//! first failing seed so a failure reproduces deterministically:
//!
//! ```
//! use wildcat::util::prop::Cases;
//! Cases::new(64).run(|rng| {
//!     let n = 1 + rng.below(100);
//!     assert!(n >= 1 && n <= 100);
//! });
//! ```

use crate::rng::Rng;

/// Runs a property over `n` seeded cases.
pub struct Cases {
    n: usize,
    base_seed: u64,
}

impl Cases {
    pub fn new(n: usize) -> Self {
        // Honour WILDCAT_PROP_SEED for reproducing CI failures.
        let base_seed = std::env::var("WILDCAT_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        Cases { n, base_seed }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.base_seed = seed;
        self
    }

    /// Run the property; panics (with the case seed) on the first failure.
    pub fn run<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(&self, f: F) {
        for case in 0..self.n {
            let seed = self.base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
            let result = std::panic::catch_unwind(|| {
                let mut rng = Rng::seed_from(seed);
                f(&mut rng);
            });
            if let Err(e) = result {
                let msg = e
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<non-string panic>".into());
                panic!(
                    "property failed on case {case} (WILDCAT_PROP_SEED={}) : {msg}",
                    self.base_seed
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        Cases::new(32).run(|rng| {
            let x = rng.uniform();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    fn reports_failures() {
        let res = std::panic::catch_unwind(|| {
            Cases::new(8).with_seed(1).run(|_| panic!("boom"));
        });
        assert!(res.is_err());
    }

    #[test]
    fn cases_are_deterministic() {
        let mut seen1 = Vec::new();
        Cases::new(4).with_seed(9).run(|rng| {
            // no assertion; just record
            let _ = rng;
        });
        // determinism by construction: same seed -> same streams; check via values
        for case in 0..4u64 {
            let seed = 9u64.wrapping_add(case).wrapping_mul(0x9E3779B97F4A7C15);
            seen1.push(Rng::seed_from(seed).next_u64());
        }
        let seen2: Vec<u64> = (0..4u64)
            .map(|case| {
                let seed = 9u64.wrapping_add(case).wrapping_mul(0x9E3779B97F4A7C15);
                Rng::seed_from(seed).next_u64()
            })
            .collect();
        assert_eq!(seen1, seen2);
    }
}
