//! Minimal JSON reader/writer (serde is unavailable offline).
//!
//! Used for the artifact manifest (`artifacts/manifest.json`, written by the
//! python AOT step) and for metrics dumps from the coordinator/benches.
//! Supports the full JSON value model; numbers are f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Serialise compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Returns an error string with byte position on
/// malformed input.
pub fn parse(input: &str) -> Result<Json, String> {
    let bytes = input.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != bytes.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy one UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..]).map_err(|e| e.to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let text = r#"{
            "artifacts": [
                {"name": "wtd_attn_1024x96x64", "file": "wtd_attn.hlo.txt",
                 "inputs": [[1024, 64], [96, 64]], "beta": 0.125, "tuple": true}
            ],
            "version": 1
        }"#;
        let v = parse(text).unwrap();
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 1);
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("wtd_attn_1024x96x64"));
        assert_eq!(v.get("version").unwrap().as_usize(), Some(1));
        let beta = arts[0].get("beta").unwrap().as_f64().unwrap();
        assert!((beta - 0.125).abs() < 1e-12);
        // serialise + reparse = fixed point
        let s = v.to_string_compact();
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn escapes() {
        let v = Json::Str("a\"b\\c\nd".into());
        let s = v.to_string_compact();
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn numbers() {
        for (s, want) in [("0", 0.0), ("-1.5", -1.5), ("1e3", 1000.0), ("2.5E-2", 0.025)] {
            assert_eq!(parse(s).unwrap(), Json::Num(want));
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"abc").is_err());
    }

    #[test]
    fn nested() {
        let v = parse("[[1,2],[3,[4]]]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[1].as_arr().unwrap()[1].as_arr().unwrap()[0], Json::Num(4.0));
    }

    #[test]
    fn unicode_escape() {
        let v = parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }
}
