//! Thinformer (Carrell et al. 2025): attention over a *thinned* coreset
//! produced by low-rank kernel halving.
//!
//! Kernel thinning repeatedly halves the key set: keys are paired and a
//! self-balancing signed walk decides which element of each pair survives,
//! keeping the running feature-space discrepancy small. After `rounds`
//! halvings, `n/2^rounds` keys remain whose empirical kernel distribution
//! tracks the full set's to `O(√log n / n_out)` discrepancy; attention is
//! then computed exactly over the surviving coreset (uniform weights
//! cancel in the softmax ratio).
//!
//! Simplification: the discrepancy walk runs on FAVOR+ random features of
//! the attention kernel (Carrell et al.'s "low-rank thinning") with a
//! deterministic greedy sign rule instead of the probabilistic one — the
//! greedy rule has the same discrepancy guarantee up to constants
//! (Dwivedi & Mackey 2024) and is seed-stable for benches.

use super::AttentionApprox;
use crate::attention::exact_attention;
use crate::linalg::gemm;
use crate::linalg::Matrix;
use crate::rng::Rng;

pub struct Thinformer {
    /// Number of halving rounds: coreset size is `n / 2^rounds`.
    pub rounds: usize,
    /// Random-feature dimension for the discrepancy walk.
    pub n_features: usize,
}

impl Thinformer {
    pub fn new(rounds: usize) -> Self {
        Thinformer { rounds, n_features: 64 }
    }

    /// One halving round over `idx`, returning the survivors.
    fn halve(feat: &Matrix, idx: &[usize], rng: &mut Rng) -> Vec<usize> {
        let f = feat.cols();
        let mut order = idx.to_vec();
        rng.shuffle(&mut order);
        let mut sigma = vec![0.0f64; f];
        let mut keep = Vec::with_capacity(order.len().div_ceil(2));
        let mut t = 0;
        while t + 1 < order.len() {
            let (a, b) = (order[t], order[t + 1]);
            let fa = feat.row(a);
            let fb = feat.row(b);
            // δ = ψ_a − ψ_b ; sign s = −sign⟨σ, δ⟩ keeps ‖σ‖ small
            let mut ip = 0.0f64;
            for ((&x, &y), &s) in fa.iter().zip(fb).zip(sigma.iter()) {
                ip += s * (x as f64 - y as f64);
            }
            let keep_a = ip <= 0.0;
            let sign = if keep_a { 1.0 } else { -1.0 };
            for ((s, &x), &y) in sigma.iter_mut().zip(fa).zip(fb) {
                *s += sign * (x as f64 - y as f64);
            }
            keep.push(if keep_a { a } else { b });
            t += 2;
        }
        if t < order.len() {
            keep.push(order[t]); // odd element survives
        }
        keep
    }
}

impl AttentionApprox for Thinformer {
    fn name(&self) -> &'static str {
        "Thinformer"
    }

    fn attend(&self, q: &Matrix, k: &Matrix, v: &Matrix, beta: f32, rng: &mut Rng) -> Matrix {
        let n = k.rows();
        if n <= 2 || self.rounds == 0 {
            return exact_attention(q, k, v, beta);
        }
        // FAVOR+ positive features of the keys (shared global stabiliser).
        let d = k.cols();
        let omega = Matrix::randn(rng, self.n_features, d);
        let sqrt_beta = (beta as f64).sqrt() as f32;
        let proj = gemm::matmul_transb(&k.scale(sqrt_beta), &omega);
        let mut expo = proj;
        for j in 0..n {
            let sq: f64 = k.row(j).iter().map(|&x| (x as f64) * (x as f64)).sum();
            let shift = (beta as f64 * sq / 2.0) as f32;
            for e in expo.row_mut(j) {
                *e -= shift;
            }
        }
        let gmax = expo.as_slice().iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let feat = Matrix::from_fn(n, self.n_features, |j, f| {
            ((expo.get(j, f) - gmax) as f64).exp() as f32
        });

        let mut survivors: Vec<usize> = (0..n).collect();
        for _ in 0..self.rounds {
            if survivors.len() <= 2 {
                break;
            }
            survivors = Self::halve(&feat, &survivors, rng);
        }
        survivors.sort_unstable();
        let ks = k.select_rows(&survivors);
        let vs = v.select_rows(&survivors);
        exact_attention(q, &ks, &vs, beta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norms::rel_frobenius_err;

    #[test]
    fn zero_rounds_is_exact() {
        let mut rng = Rng::seed_from(1);
        let q = Matrix::randn(&mut rng, 10, 4);
        let k = Matrix::randn(&mut rng, 20, 4);
        let v = Matrix::randn(&mut rng, 20, 3);
        let t = Thinformer::new(0);
        let o = t.attend(&q, &k, &v, 0.4, &mut rng);
        let e = exact_attention(&q, &k, &v, 0.4);
        assert_eq!(o, e);
    }

    #[test]
    fn halving_reduces_key_count_correctly() {
        let mut rng = Rng::seed_from(2);
        let feat = Matrix::randn(&mut rng, 33, 8);
        let idx: Vec<usize> = (0..33).collect();
        let kept = Thinformer::halve(&feat, &idx, &mut rng);
        assert_eq!(kept.len(), 17); // ceil(33/2)
        let mut sorted = kept.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), kept.len());
        assert!(sorted.iter().all(|&i| i < 33));
    }

    #[test]
    fn one_round_beats_random_half_on_average() {
        // Kernel-halving coreset should track the full attention better
        // than a uniform random half, averaged over seeds.
        let mut data_rng = Rng::seed_from(3);
        let q = Matrix::randn(&mut data_rng, 48, 8);
        let k = Matrix::randn(&mut data_rng, 256, 8);
        let v = Matrix::randn(&mut data_rng, 256, 4);
        let beta = 0.35f32;
        let exact = exact_attention(&q, &k, &v, beta);
        let mut thin_err = 0.0;
        let mut rand_err = 0.0;
        let trials = 8;
        for s in 0..trials {
            let mut rng = Rng::seed_from(200 + s);
            let t = Thinformer::new(1);
            thin_err += rel_frobenius_err(&t.attend(&q, &k, &v, beta, &mut rng), &exact);
            let idx = rng.sample_without_replacement(256, 128);
            let o = exact_attention(&q, &k.select_rows(&idx), &v.select_rows(&idx), beta);
            rand_err += rel_frobenius_err(&o, &exact);
        }
        assert!(
            thin_err < rand_err * 1.05,
            "thinning ({thin_err}) should not lose to random halving ({rand_err})"
        );
    }

    #[test]
    fn multi_round_output_valid() {
        let mut rng = Rng::seed_from(4);
        let q = Matrix::randn(&mut rng, 16, 6);
        let k = Matrix::randn(&mut rng, 100, 6);
        let v = Matrix::randn(&mut rng, 100, 3);
        let t = Thinformer::new(3); // 100 -> 13 keys
        let o = t.attend(&q, &k, &v, 0.3, &mut rng);
        assert_eq!((o.rows(), o.cols()), (16, 3));
        let (mn, mx) = v.col_min_max();
        for i in 0..o.rows() {
            for j in 0..o.cols() {
                assert!(o.get(i, j) >= mn[j] - 1e-5 && o.get(i, j) <= mx[j] + 1e-5);
            }
        }
    }
}
