//! Performer (Choromanski et al. 2021): FAVOR+ positive random features.
//!
//! The softmax kernel factorises as
//! `exp(β q·k) = E_{ω∼N(0,I)}[ exp(√β ω·q − β‖q‖²/2) · exp(√β ω·k − β‖k‖²/2) ]`,
//! so with `M` sampled feature vectors the attention matrix is approximated
//! by the rank-`M` product `φ(Q) φ(K)ᵀ`, and the full softmax output costs
//! `O((m+n) M d)`.
//!
//! Simplification vs. the reference implementation: features are i.i.d.
//! Gaussian rather than block-orthogonal (orthogonality reduces variance
//! by a constant factor; the asymptotics and the benchmark role are
//! unchanged — documented in DESIGN.md §Algorithms).
//!
//! Stabilisation: a per-row max is subtracted from the query feature
//! exponents (cancels in the softmax ratio) and a global max from the key
//! feature exponents (a constant scale on numerator and denominator).

use super::AttentionApprox;
use crate::linalg::{gemm, Matrix};
use crate::rng::Rng;

/// Performer with `M` random features.
pub struct Performer {
    pub n_features: usize,
}

impl Performer {
    pub fn with_features(n_features: usize) -> Self {
        assert!(n_features > 0);
        Performer { n_features }
    }

    /// Feature exponents `√β ω_i · x − β‖x‖²/2` for all rows of `x`.
    fn feature_exponents(x: &Matrix, omega: &Matrix, beta: f32) -> Matrix {
        let sqrt_beta = (beta as f64).sqrt() as f32;
        let proj = gemm::matmul_transb(&x.scale(sqrt_beta), omega); // rows × M
        let mut out = proj;
        for i in 0..x.rows() {
            let sq: f64 = x.row(i).iter().map(|&v| (v as f64) * (v as f64)).sum();
            let shift = (beta as f64 * sq / 2.0) as f32;
            for e in out.row_mut(i) {
                *e -= shift;
            }
        }
        out
    }
}

impl AttentionApprox for Performer {
    fn name(&self) -> &'static str {
        "Performer"
    }

    fn attend(&self, q: &Matrix, k: &Matrix, v: &Matrix, beta: f32, rng: &mut Rng) -> Matrix {
        let d = q.cols();
        let m_feat = self.n_features;
        let omega = Matrix::randn(rng, m_feat, d);

        let q_exp = Self::feature_exponents(q, &omega, beta);
        let k_exp = Self::feature_exponents(k, &omega, beta);

        // Global max over key exponents: uniform scale, cancels in ratio.
        let k_max = k_exp.as_slice().iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut phi_k = Matrix::zeros(k.rows(), m_feat);
        for i in 0..k.rows() {
            for (o, &e) in phi_k.row_mut(i).iter_mut().zip(k_exp.row(i)) {
                *o = ((e - k_max) as f64).exp() as f32;
            }
        }
        // Σ_j φ(k_j) v_j  and  Σ_j φ(k_j): one pass, O(n M (d_v+1)).
        let kv = gemm::matmul(&phi_k.transpose(), v); // M × d_v
        let mut k_ones = vec![0.0f32; m_feat];
        for i in 0..k.rows() {
            for (s, &p) in k_ones.iter_mut().zip(phi_k.row(i)) {
                *s += p;
            }
        }

        let dv = v.cols();
        let mut out = Matrix::zeros(q.rows(), dv);
        for i in 0..q.rows() {
            // per-query max: cancels in ratio
            let row = q_exp.row(i);
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let phi_q: Vec<f64> = row.iter().map(|&e| ((e - mx) as f64).exp()).collect();
            let mut denom = 0.0f64;
            for (p, &s) in phi_q.iter().zip(&k_ones) {
                denom += p * s as f64;
            }
            let out_row = out.row_mut(i);
            for jd in 0..dv {
                let mut num = 0.0f64;
                for (f, p) in phi_q.iter().enumerate() {
                    num += p * kv.get(f, jd) as f64;
                }
                out_row[jd] = if denom > 0.0 { (num / denom) as f32 } else { 0.0 };
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::exact_attention;
    use crate::linalg::norms::rel_frobenius_err;

    #[test]
    fn moderate_feature_budget_tracks_exact() {
        // FAVOR+ is heavy-tailed (log-normal feature summands), so we test
        // the paper-relevant property: at a moderate budget the *absolute*
        // ‖·‖_max error (the paper's metric, Lem. 1) is a small fraction of
        // ‖V‖_max, averaged over seeds.
        let mut data_rng = Rng::seed_from(1);
        let q = Matrix::randn(&mut data_rng, 32, 8).scale(0.7);
        let k = Matrix::randn(&mut data_rng, 64, 8).scale(0.7);
        let v = Matrix::randn(&mut data_rng, 64, 4);
        let exact = exact_attention(&q, &k, &v, 0.35);
        let v_max = crate::linalg::norms::max_abs(&v);
        let mut tot = 0.0;
        for seed in 0..8 {
            let mut rng = Rng::seed_from(50 + seed);
            let p = Performer::with_features(128);
            tot += crate::linalg::norms::max_abs_diff(&p.attend(&q, &k, &v, 0.35, &mut rng), &exact);
        }
        let err = tot / 8.0;
        assert!(err < 0.25 * v_max, "err={err} vmax={v_max}");
        // rel_frobenius_err stays referenced for API stability of the test
        let _ = rel_frobenius_err(&exact, &exact);
    }

    #[test]
    fn kernel_estimate_unbiasedness_sanity() {
        // E[φ(q)·φ(k)] = exp(β q·k); check monte-carlo mean over features
        // lands near the kernel value for a fixed pair.
        let q = Matrix::from_vec(vec![0.5, -0.3, 0.8], 1, 3);
        let k = Matrix::from_vec(vec![-0.1, 0.4, 0.2], 1, 3);
        let beta = 0.5f32;
        let mut rng = Rng::seed_from(9);
        let m_feat = 200_000;
        let omega = Matrix::randn(&mut rng, m_feat, 3);
        let qe = Performer::feature_exponents(&q, &omega, beta);
        let ke = Performer::feature_exponents(&k, &omega, beta);
        let mut acc = 0.0f64;
        for f in 0..m_feat {
            acc += ((qe.get(0, f) + ke.get(0, f)) as f64).exp();
        }
        let est = acc / m_feat as f64;
        let want = (beta as f64 * crate::linalg::Matrix::row_dot(&q, 0, &k, 0)).exp();
        assert!((est - want).abs() < 0.02 * want, "est={est} want={want}");
    }

    #[test]
    fn stable_under_large_inputs() {
        let mut rng = Rng::seed_from(3);
        let q = Matrix::randn(&mut rng, 8, 4).scale(20.0);
        let k = Matrix::randn(&mut rng, 16, 4).scale(20.0);
        let v = Matrix::randn(&mut rng, 16, 2);
        let p = Performer::with_features(64);
        let o = p.attend(&q, &k, &v, 1.0, &mut rng);
        assert!(o.as_slice().iter().all(|x| x.is_finite()));
    }
}
