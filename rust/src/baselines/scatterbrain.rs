//! ScatterBrain (Chen et al. 2021a): unified sparse + low-rank attention.
//!
//! Decomposes `A ≈ Φ_Q Φ_Kᵀ + S` where the low-rank part is a Performer
//! (FAVOR+) estimate and the sparse part `S` corrects the low-rank
//! estimate exactly on LSH collision pairs:
//! `S_ij = exp(β q_i·k_j) − φ(q_i)·φ(k_j)` for colliding `(i, j)`.
//! The softmax output then uses numerator `Φ_Q (Φ_Kᵀ V) + S V` and
//! normaliser `Φ_Q (Φ_Kᵀ 1) + S 1`, each in `O((m+n)Md + nnz(S))`.
//!
//! Simplification: the LSH used to find collisions is the same spherical
//! argmax hash as our Reformer baseline (the original uses tied
//! Reformer-style hashing too).

use super::performer::Performer;
use super::reformer::Reformer;
use super::AttentionApprox;
use crate::linalg::gemm::{self, dot};
use crate::linalg::Matrix;
use crate::rng::Rng;

pub struct ScatterBrain {
    /// Random-feature count for the low-rank part.
    pub n_features: usize,
    /// LSH buckets for the sparse correction.
    pub n_buckets: usize,
}

impl ScatterBrain {
    pub fn new(n_features: usize, n_buckets: usize) -> Self {
        assert!(n_features > 0 && n_buckets >= 2);
        ScatterBrain { n_features, n_buckets }
    }
}

impl AttentionApprox for ScatterBrain {
    fn name(&self) -> &'static str {
        "ScatterBrain"
    }

    fn attend(&self, q: &Matrix, k: &Matrix, v: &Matrix, beta: f32, rng: &mut Rng) -> Matrix {
        let (m, n, d, dv) = (q.rows(), k.rows(), q.cols(), v.cols());
        let m_feat = self.n_features;
        let omega = Matrix::randn(rng, m_feat, d);

        // ---- low-rank part: unnormalised positive features -------------
        // exponent matrices: e_q[i,f] = √β ω_f·q_i − β‖q_i‖²/2
        let sqrt_beta = (beta as f64).sqrt() as f32;
        let proj_q = gemm::matmul_transb(&q.scale(sqrt_beta), &omega);
        let proj_k = gemm::matmul_transb(&k.scale(sqrt_beta), &omega);
        let sq_shift = |x: &Matrix, i: usize| -> f32 {
            let sq: f64 = x.row(i).iter().map(|&a| (a as f64) * (a as f64)).sum();
            (beta as f64 * sq / 2.0) as f32
        };
        // A single global shift keeps everything positive & finite; it is a
        // uniform scale on numerator and denominator, so it cancels.
        let mut max_expo = f32::NEG_INFINITY;
        for i in 0..m {
            let s = sq_shift(q, i);
            for &p in proj_q.row(i) {
                max_expo = max_expo.max(p - s);
            }
        }
        let mut kmax_expo = f32::NEG_INFINITY;
        for j in 0..n {
            let s = sq_shift(k, j);
            for &p in proj_k.row(j) {
                kmax_expo = kmax_expo.max(p - s);
            }
        }
        let phi = |proj: &Matrix, x: &Matrix, i: usize, shift: f32| -> Vec<f64> {
            let s = sq_shift(x, i);
            proj.row(i)
                .iter()
                .map(|&p| ((p - s - shift) as f64).exp())
                .collect()
        };
        let mut phi_q: Vec<Vec<f64>> = Vec::with_capacity(m);
        for i in 0..m {
            phi_q.push(phi(&proj_q, q, i, max_expo));
        }
        let mut phi_k: Vec<Vec<f64>> = Vec::with_capacity(n);
        for j in 0..n {
            phi_k.push(phi(&proj_k, k, j, kmax_expo));
        }
        // feature-space summaries of keys: Σ φ(k_j) v_j  and  Σ φ(k_j)
        let mut kv = vec![0.0f64; m_feat * dv];
        let mut k1 = vec![0.0f64; m_feat];
        for j in 0..n {
            let pk = &phi_k[j];
            let vr = v.row(j);
            for f in 0..m_feat {
                let p = pk[f];
                if p == 0.0 {
                    continue;
                }
                k1[f] += p;
                for (c, &x) in kv[f * dv..(f + 1) * dv].iter_mut().zip(vr) {
                    *c += p * x as f64;
                }
            }
        }

        // ---- sparse part: LSH collision pairs --------------------------
        let half = self.n_buckets.div_ceil(2);
        let r_mat = Matrix::randn(rng, half, d);
        let mut buckets: Vec<Vec<u32>> = vec![Vec::new(); 2 * half];
        for j in 0..n {
            buckets[bucket_of(k.row(j), &r_mat)].push(j as u32);
        }

        // the low-rank estimate is scaled by exp(−max_expo − kmax_expo)
        // relative to the true kernel; the sparse correction must live on
        // the same scale (computed in log space for overflow safety).
        let lr_log_scale = (max_expo + kmax_expo) as f64;

        let mut out = Matrix::zeros(m, dv);
        for i in 0..m {
            let pq = &phi_q[i];
            let mut denom = 0.0f64;
            let mut acc = vec![0.0f64; dv];
            for f in 0..m_feat {
                let p = pq[f];
                if p == 0.0 {
                    continue;
                }
                denom += p * k1[f];
                for (a, &c) in acc.iter_mut().zip(&kv[f * dv..(f + 1) * dv]) {
                    *a += p * c;
                }
            }
            // sparse correction on this query's bucket
            let b = bucket_of(q.row(i), &r_mat);
            for &j in &buckets[b] {
                let j = j as usize;
                let true_a =
                    crate::kernels::safe_exp(beta as f64 * dot(q.row(i), k.row(j)) as f64 - lr_log_scale);
                let lowrank_a: f64 = pq.iter().zip(&phi_k[j]).map(|(a, b)| a * b).sum();
                let s = true_a - lowrank_a;
                denom += s;
                for (a, &x) in acc.iter_mut().zip(v.row(j)) {
                    *a += s * x as f64;
                }
            }
            for (o, a) in out.row_mut(i).iter_mut().zip(&acc) {
                *o = if denom > 0.0 { (*a / denom) as f32 } else { 0.0 };
            }
        }
        out
    }
}

fn bucket_of(x: &[f32], r_mat: &Matrix) -> usize {
    let half = r_mat.rows();
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for j in 0..half {
        let p = dot(x, r_mat.row(j));
        if p > best_v {
            best_v = p;
            best = j;
        }
        if -p > best_v {
            best_v = -p;
            best = half + j;
        }
    }
    best
}

/// The combination components are reused by tests; keep them nameable.
pub type LowRankPart = Performer;
pub type SparsePart = Reformer;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::exact_attention;
    use crate::linalg::norms::rel_frobenius_err;

    #[test]
    fn improves_on_pure_performer_for_clustered_data() {
        // Clustered keys ⇒ concentrated attention the sparse part captures.
        let mut rng = Rng::seed_from(11);
        let k = Matrix::randn(&mut rng, 96, 8);
        let q = k.slice_rows(0, 48).scale(1.0); // queries near keys
        let v = Matrix::randn(&mut rng, 96, 4);
        let beta = 2.0f32;
        let exact = exact_attention(&q, &k, &v, beta);
        let avg_err = |f: &dyn Fn(&mut Rng) -> Matrix| {
            let mut tot = 0.0;
            for s in 0..4 {
                let mut r = Rng::seed_from(100 + s);
                tot += rel_frobenius_err(&f(&mut r), &exact);
            }
            tot / 4.0
        };
        let perf = Performer::with_features(64);
        let sb = ScatterBrain::new(64, 8);
        let e_perf = avg_err(&|r: &mut Rng| perf.attend(&q, &k, &v, beta, r));
        let e_sb = avg_err(&|r: &mut Rng| sb.attend(&q, &k, &v, beta, r));
        assert!(
            e_sb < e_perf,
            "scatterbrain ({e_sb}) should beat performer ({e_perf}) on concentrated attention"
        );
    }

    #[test]
    fn finite_and_shaped() {
        let mut rng = Rng::seed_from(2);
        let q = Matrix::randn(&mut rng, 17, 5);
        let k = Matrix::randn(&mut rng, 33, 5);
        let v = Matrix::randn(&mut rng, 33, 3);
        let sb = ScatterBrain::new(32, 4);
        let o = sb.attend(&q, &k, &v, 0.5, &mut rng);
        assert_eq!((o.rows(), o.cols()), (17, 3));
        assert!(o.as_slice().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn stable_under_scale() {
        let mut rng = Rng::seed_from(3);
        let q = Matrix::randn(&mut rng, 8, 4).scale(10.0);
        let k = Matrix::randn(&mut rng, 16, 4).scale(10.0);
        let v = Matrix::randn(&mut rng, 16, 2);
        let sb = ScatterBrain::new(32, 4);
        let o = sb.attend(&q, &k, &v, 1.0, &mut rng);
        assert!(o.as_slice().iter().all(|x| x.is_finite()));
    }
}
