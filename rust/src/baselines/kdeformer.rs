//! KDEformer (Zandieh et al. 2023): attention via kernel-density
//! importance sampling.
//!
//! The softmax numerator `Σ_j exp(β q·k_j) v_j` is an expectation that can
//! be estimated unbiasedly by sampling keys from any proposal `p_j > 0`
//! and reweighting by `1/(r p_j)`. KDEformer's insight is to use a fast
//! kernel-density estimate of each key's total attention mass as the
//! proposal, concentrating samples on the keys that matter.
//!
//! Simplification: the original builds its KDE with hashing-based
//! estimators (HBE); here the proposal is the exact column mass computed
//! on a small uniform subsample of queries (`n_probe` of them) — the same
//! "sample ∝ estimated column mass" mechanism with a simpler estimator,
//! per DESIGN.md §Algorithms.

use super::AttentionApprox;
use crate::kernels::safe_exp;
use crate::linalg::gemm::dot;
use crate::linalg::Matrix;
use crate::rng::Rng;

pub struct KdeFormer {
    /// Number of keys sampled per forward pass.
    pub n_samples: usize,
    /// Number of probe queries used to estimate column masses.
    pub n_probe: usize,
}

impl KdeFormer {
    pub fn new(n_samples: usize, n_probe: usize) -> Self {
        assert!(n_samples > 0 && n_probe > 0);
        KdeFormer { n_samples, n_probe }
    }
}

impl AttentionApprox for KdeFormer {
    fn name(&self) -> &'static str {
        "KDEformer"
    }

    fn attend(&self, q: &Matrix, k: &Matrix, v: &Matrix, beta: f32, rng: &mut Rng) -> Matrix {
        let (m, n, dv) = (q.rows(), k.rows(), v.cols());
        let r = self.n_samples.min(n);

        // --- proposal: estimated column masses on probe queries ---------
        let probes = rng.sample_without_replacement(m, self.n_probe.min(m));
        let mut col_mass = vec![0.0f64; n];
        // per-probe max subtraction keeps the mass estimate stable
        for &pi in &probes {
            let qrow = q.row(pi);
            let logits: Vec<f64> = (0..n)
                .map(|j| beta as f64 * dot(qrow, k.row(j)) as f64)
                .collect();
            let mx = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            for (c, &l) in col_mass.iter_mut().zip(&logits) {
                *c += safe_exp(l - mx);
            }
        }
        let total: f64 = col_mass.iter().sum();
        // guard: degenerate probes ⇒ uniform proposal
        let probs: Vec<f64> = if total > 0.0 {
            // mix with uniform to keep the estimator's variance bounded
            col_mass
                .iter()
                .map(|&c| 0.9 * c / total + 0.1 / n as f64)
                .collect()
        } else {
            vec![1.0 / n as f64; n]
        };

        // --- sample r keys with replacement from the proposal ------------
        let mut sampled: Vec<(usize, f64)> = Vec::with_capacity(r);
        for _ in 0..r {
            let j = rng.categorical(&probs).unwrap_or(0);
            sampled.push((j, probs[j]));
        }

        // --- unbiased softmax estimate over sampled keys ----------------
        let mut out = Matrix::zeros(m, dv);
        for i in 0..m {
            let qi = q.row(i);
            let mut mx = f64::NEG_INFINITY;
            let logits: Vec<f64> = sampled
                .iter()
                .map(|&(j, _)| {
                    let l = beta as f64 * dot(qi, k.row(j)) as f64;
                    if l > mx {
                        mx = l;
                    }
                    l
                })
                .collect();
            let mut denom = 0.0f64;
            let mut acc = vec![0.0f64; dv];
            for ((&(j, pj), &l) , _) in sampled.iter().zip(&logits).zip(0..) {
                let w = safe_exp(l - mx) / pj; // importance weight (1/r cancels)
                denom += w;
                for (a, &x) in acc.iter_mut().zip(v.row(j)) {
                    *a += w * x as f64;
                }
            }
            for (o, a) in out.row_mut(i).iter_mut().zip(&acc) {
                *o = if denom > 0.0 { (*a / denom) as f32 } else { 0.0 };
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::exact_attention;
    use crate::linalg::norms::rel_frobenius_err;

    #[test]
    fn error_decreases_with_sample_budget() {
        // Paper metric: absolute ‖·‖_max error (Lem. 1), averaged over seeds.
        let mut data_rng = Rng::seed_from(1);
        let q = Matrix::randn(&mut data_rng, 32, 8);
        let k = Matrix::randn(&mut data_rng, 128, 8);
        let v = Matrix::randn(&mut data_rng, 128, 4);
        let exact = exact_attention(&q, &k, &v, 0.35);
        let err_at = |r: usize| {
            let mut tot = 0.0;
            for s in 0..4 {
                let mut rng = Rng::seed_from(40 + s);
                let kf = KdeFormer::new(r, 8);
                tot += crate::linalg::norms::max_abs_diff(
                    &kf.attend(&q, &k, &v, 0.35, &mut rng),
                    &exact,
                );
            }
            tot / 4.0
        };
        let small = err_at(8);
        let large = err_at(120);
        assert!(large < small, "small={small} large={large}");
        let v_max = crate::linalg::norms::max_abs(&v);
        assert!(large < 0.15 * v_max, "large-budget error too high: {large}");
    }

    #[test]
    fn importance_sampling_beats_uniform_on_sharp_attention() {
        // KDEformer's contribution over naive subsampling: sampling ∝
        // estimated column mass concentrates on the keys that matter.
        let mut rng = Rng::seed_from(2);
        let k = Matrix::randn(&mut rng, 64, 6);
        let q = k.slice_rows(0, 16); // queries collide with keys: sharp mass
        let v = Matrix::randn(&mut rng, 64, 3);
        let exact = exact_attention(&q, &k, &v, 4.0);
        let trials = 8;
        let mut kde_err = 0.0;
        let mut unif_err = 0.0;
        for s in 0..trials {
            let mut r1 = Rng::seed_from(60 + s);
            let kf = KdeFormer::new(32, 16);
            kde_err += rel_frobenius_err(&kf.attend(&q, &k, &v, 4.0, &mut r1), &exact);
            let idx = r1.sample_without_replacement(64, 32);
            let o = exact_attention(&q, &k.select_rows(&idx), &v.select_rows(&idx), 4.0);
            unif_err += rel_frobenius_err(&o, &exact);
        }
        assert!(
            kde_err < unif_err,
            "kde ({kde_err}) should beat uniform subsampling ({unif_err})"
        );
    }

    #[test]
    fn finite_on_degenerate_input() {
        // all-zero queries/keys: uniform attention; sampler must not panic
        let q = Matrix::zeros(4, 3);
        let k = Matrix::zeros(10, 3);
        let v = Matrix::from_fn(10, 2, |i, j| (i + j) as f32);
        let kf = KdeFormer::new(5, 2);
        let mut rng = Rng::seed_from(3);
        let o = kf.attend(&q, &k, &v, 0.5, &mut rng);
        assert!(o.as_slice().iter().all(|x| x.is_finite()));
    }
}
