//! Reformer (Kitaev et al. 2020): LSH-bucketed sparse attention.
//!
//! Keys and queries are hashed with random-rotation LSH
//! (`h(x) = argmax([xR; −xR])`, Andoni et al. spherical LSH as in the
//! paper); each query attends exactly over the keys that share one of its
//! hashes across `n_rounds` independent rounds. Queries whose buckets are
//! empty fall back to a small uniform key sample so the output is always a
//! proper convex combination.
//!
//! Simplification vs. the original: Reformer shares Q=K tied weights and
//! sorts into fixed-capacity chunks for TPU batching; here Q≠K and buckets
//! are exact membership lists, which preserves the method's accuracy
//! characteristics (sparse exact attention over collision sets) without
//! the chunking machinery.

use super::AttentionApprox;
use crate::kernels::safe_exp;
use crate::linalg::gemm::dot;
use crate::linalg::Matrix;
use crate::rng::Rng;

/// Reformer with `2^?`-ish bucket granularity: `n_buckets` hyperplane
/// buckets per round, `n_rounds` independent hash rounds.
pub struct Reformer {
    pub n_buckets: usize,
    pub n_rounds: usize,
}

impl Reformer {
    pub fn new(n_buckets: usize, n_rounds: usize) -> Self {
        assert!(n_buckets >= 2 && n_rounds >= 1);
        Reformer { n_buckets, n_rounds }
    }

    /// Spherical LSH bucket id: argmax over `[xR; −xR]` columns.
    fn bucket(x: &[f32], r_mat: &Matrix) -> usize {
        let half = r_mat.rows();
        let mut best = 0usize;
        let mut best_v = f32::NEG_INFINITY;
        for j in 0..half {
            let p = dot(x, r_mat.row(j));
            if p > best_v {
                best_v = p;
                best = j;
            }
            if -p > best_v {
                best_v = -p;
                best = half + j;
            }
        }
        best
    }
}

impl AttentionApprox for Reformer {
    fn name(&self) -> &'static str {
        "Reformer"
    }

    fn attend(&self, q: &Matrix, k: &Matrix, v: &Matrix, beta: f32, rng: &mut Rng) -> Matrix {
        let (m, n, d, dv) = (q.rows(), k.rows(), q.cols(), v.cols());
        let half = self.n_buckets.div_ceil(2);

        // candidate key sets per query, unioned over rounds
        let mut cand: Vec<Vec<u32>> = vec![Vec::new(); m];
        for _round in 0..self.n_rounds {
            let r_mat = Matrix::randn(rng, half, d);
            let mut buckets: Vec<Vec<u32>> = vec![Vec::new(); 2 * half];
            for j in 0..n {
                buckets[Self::bucket(k.row(j), &r_mat)].push(j as u32);
            }
            for (i, c) in cand.iter_mut().enumerate() {
                let b = Self::bucket(q.row(i), &r_mat);
                c.extend_from_slice(&buckets[b]);
            }
        }

        // fallback sample for empty buckets
        let fallback: Vec<u32> = rng
            .sample_without_replacement(n, n.min(8))
            .into_iter()
            .map(|x| x as u32)
            .collect();

        let mut out = Matrix::zeros(m, dv);
        for i in 0..m {
            let mut keys = std::mem::take(&mut cand[i]);
            keys.sort_unstable();
            keys.dedup();
            if keys.is_empty() {
                keys = fallback.clone();
            }
            let qi = q.row(i);
            let mut mx = f64::NEG_INFINITY;
            let logits: Vec<f64> = keys
                .iter()
                .map(|&j| {
                    let l = beta as f64 * dot(qi, k.row(j as usize)) as f64;
                    if l > mx {
                        mx = l;
                    }
                    l
                })
                .collect();
            let mut denom = 0.0f64;
            let mut acc = vec![0.0f64; dv];
            for (&j, &l) in keys.iter().zip(&logits) {
                let p = safe_exp(l - mx);
                denom += p;
                for (a, &x) in acc.iter_mut().zip(v.row(j as usize)) {
                    *a += p * x as f64;
                }
            }
            for (o, a) in out.row_mut(i).iter_mut().zip(&acc) {
                *o = (*a / denom.max(f64::MIN_POSITIVE)) as f32;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::exact_attention;
    use crate::linalg::norms::rel_frobenius_err;

    #[test]
    fn output_in_value_hull() {
        let mut rng = Rng::seed_from(1);
        let q = Matrix::randn(&mut rng, 30, 6);
        let k = Matrix::randn(&mut rng, 60, 6);
        let v = Matrix::randn(&mut rng, 60, 3);
        let r = Reformer::new(8, 2);
        let o = r.attend(&q, &k, &v, 0.4, &mut rng);
        let (mn, mx) = v.col_min_max();
        for i in 0..o.rows() {
            for j in 0..o.cols() {
                assert!(o.get(i, j) >= mn[j] - 1e-5 && o.get(i, j) <= mx[j] + 1e-5);
            }
        }
    }

    #[test]
    fn single_bucket_equals_exact() {
        // With 2 buckets and clustered data on one side, most mass
        // collides; with enough rounds of a trivial 2-bucket hash every
        // query sees the keys in its halfspace. Stronger: n_buckets=2,
        // data all in one cluster -> all collide -> exact.
        let mut rng = Rng::seed_from(2);
        let centre = vec![3.0f32; 4];
        let mut q = Matrix::randn(&mut rng, 10, 4).scale(0.05);
        let mut k = Matrix::randn(&mut rng, 20, 4).scale(0.05);
        q.add_row_vector_mut(&centre);
        k.add_row_vector_mut(&centre);
        let v = Matrix::randn(&mut rng, 20, 3);
        let r = Reformer::new(2, 1);
        let o = r.attend(&q, &k, &v, 0.3, &mut rng);
        let e = exact_attention(&q, &k, &v, 0.3);
        // all points hash to the same bucket with a clustered input
        assert!(rel_frobenius_err(&o, &e) < 1e-4);
    }

    #[test]
    fn captures_concentrated_attention() {
        // When attention is concentrated on nearest keys (high beta,
        // clustered structure) LSH recovers most of the mass.
        let mut rng = Rng::seed_from(3);
        let k = Matrix::randn(&mut rng, 128, 8);
        let q = k.slice_rows(0, 64); // queries equal to some keys
        let v = Matrix::randn(&mut rng, 128, 4);
        let e = exact_attention(&q, &k, &v, 3.0);
        let r = Reformer::new(8, 4);
        let o = r.attend(&q, &k, &v, 3.0, &mut rng);
        let err = rel_frobenius_err(&o, &e);
        assert!(err < 0.35, "err={err}");
    }

    #[test]
    fn deterministic_given_rng() {
        let q = Matrix::randn(&mut Rng::seed_from(4), 10, 4);
        let k = Matrix::randn(&mut Rng::seed_from(5), 20, 4);
        let v = Matrix::randn(&mut Rng::seed_from(6), 20, 2);
        let r = Reformer::new(4, 2);
        let o1 = r.attend(&q, &k, &v, 0.3, &mut Rng::seed_from(7));
        let o2 = r.attend(&q, &k, &v, 0.3, &mut Rng::seed_from(7));
        assert_eq!(o1, o2);
    }
}
