//! The five comparison attention approximations of Tab. 2 / Tab. 3:
//! Performer, Reformer, ScatterBrain, KDEformer and Thinformer, behind a
//! common [`AttentionApprox`] trait together with WildCat and the exact
//! baselines.
//!
//! Each implementation follows the published method's core mechanism;
//! engineering simplifications relative to the original codebases are
//! documented at the top of each file (and in DESIGN.md §Algorithms).

pub mod kdeformer;
pub mod performer;
pub mod reformer;
pub mod scatterbrain;
pub mod thinformer;

use crate::linalg::Matrix;
use crate::rng::Rng;

pub use kdeformer::KdeFormer;
pub use performer::Performer;
pub use reformer::Reformer;
pub use scatterbrain::ScatterBrain;
pub use thinformer::Thinformer;

/// A drop-in (approximate) attention mechanism: estimates
/// `softmax(β Q Kᵀ) V`.
pub trait AttentionApprox: Send + Sync {
    /// Display name used in paper-style tables.
    fn name(&self) -> &'static str;

    /// Approximate the softmax matrix–value product.
    fn attend(&self, q: &Matrix, k: &Matrix, v: &Matrix, beta: f32, rng: &mut Rng) -> Matrix;
}

/// Exact attention as an [`AttentionApprox`] (the Tab. 2/3 "Exact" row).
pub struct ExactBaseline;

impl AttentionApprox for ExactBaseline {
    fn name(&self) -> &'static str {
        "Exact"
    }

    fn attend(&self, q: &Matrix, k: &Matrix, v: &Matrix, beta: f32, _rng: &mut Rng) -> Matrix {
        crate::attention::flash_attention(q, k, v, beta)
    }
}

/// WildCat as an [`AttentionApprox`].
pub struct WildcatBaseline {
    pub params: crate::attention::WildcatParams,
}

impl AttentionApprox for WildcatBaseline {
    fn name(&self) -> &'static str {
        "WILDCAT"
    }

    fn attend(&self, q: &Matrix, k: &Matrix, v: &Matrix, beta: f32, rng: &mut Rng) -> Matrix {
        let mut p = self.params;
        p.beta = Some(beta as f64);
        crate::attention::wildcat_attention(q, k, v, &p, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::exact_attention;
    use crate::linalg::norms::max_abs_diff;

    /// Shared smoke contract for every approximator: finite output of the
    /// right shape, and (at a generous budget) meaningfully better than a
    /// zero predictor on a moderately concentrated attention problem.
    fn contract(approx: &dyn AttentionApprox, tol_vs_zero: f64) {
        let mut rng = Rng::seed_from(2024);
        let (m, n, d, dv) = (48, 96, 8, 6);
        let q = Matrix::randn(&mut rng, m, d);
        let k = Matrix::randn(&mut rng, n, d);
        let v = Matrix::randn(&mut rng, n, dv);
        let beta = 0.35f32;
        let exact = exact_attention(&q, &k, &v, beta);
        let got = approx.attend(&q, &k, &v, beta, &mut rng);
        assert_eq!(got.rows(), m);
        assert_eq!(got.cols(), dv);
        assert!(got.as_slice().iter().all(|x| x.is_finite()), "{}", approx.name());
        let err = max_abs_diff(&got, &exact);
        let zero_err = crate::linalg::norms::max_abs(&exact);
        assert!(
            err < tol_vs_zero * zero_err,
            "{}: err={err} vs zero-baseline={zero_err}",
            approx.name()
        );
    }

    #[test]
    fn exact_baseline_is_exact() {
        contract(&ExactBaseline, 0.01);
    }

    #[test]
    fn wildcat_contract() {
        contract(
            &WildcatBaseline {
                params: crate::attention::WildcatParams { rank: 48, bins: 2, beta: None },
            },
            0.9,
        );
    }

    #[test]
    fn performer_contract() {
        contract(&Performer::with_features(256), 1.5);
    }

    #[test]
    fn reformer_contract() {
        contract(&Reformer::new(8, 2), 2.0);
    }

    #[test]
    fn scatterbrain_contract() {
        contract(&ScatterBrain::new(256, 8), 1.5);
    }

    #[test]
    fn kdeformer_contract() {
        contract(&KdeFormer::new(48, 16), 1.5);
    }

    #[test]
    fn thinformer_contract() {
        contract(&Thinformer::new(1), 1.5);
    }
}
