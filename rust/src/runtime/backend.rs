//! [`crate::model::ModelBackend`] implementation over the PJRT runtime —
//! the production path: the serving model's prefill/decode are the
//! AOT-compiled HLO artifacts; this adapter handles fixed-shape padding.
//!
//! Padding contracts (pinned by python/tests):
//! * prefill: tokens padded with PAD to the artifact length; `length`
//!   carries the true token count; caches are sliced to `length`.
//! * decode: the cache is padded to the artifact capacity `R` with
//!   arbitrary keys, **zero values** and **zero weights** (inert rows).
//!
//! Gated behind the `pjrt` cargo feature (see [`super`] module docs);
//! without it a stub with the same API reports the missing feature.

#[cfg(feature = "pjrt")]
use super::{LiteralArg, PjrtRuntime};
use crate::linalg::Matrix;
use crate::model::{ModelBackend, ModelConfig, PrefillOutput};
#[cfg(feature = "pjrt")]
use anyhow::{anyhow, Result};

/// PJRT-backed serving model.
#[cfg(feature = "pjrt")]
pub struct PjrtBackend {
    rt: PjrtRuntime,
    cfg: ModelConfig,
    /// Available prefill artifact lengths, ascending.
    prefill_lens: Vec<usize>,
    /// Available decode cache capacities, ascending.
    decode_caps: Vec<usize>,
}

#[cfg(feature = "pjrt")]
impl PjrtBackend {
    pub fn open(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let rt = PjrtRuntime::open(dir)?;
        let cfg = ModelConfig::from_spec(&rt.manifest.model);
        let mut prefill_lens: Vec<usize> = rt
            .manifest
            .artifacts_with_prefix("model_prefill_n")
            .iter()
            .filter_map(|a| a.name.trim_start_matches("model_prefill_n").parse().ok())
            .collect();
        prefill_lens.sort_unstable();
        let mut decode_caps: Vec<usize> = rt
            .manifest
            .artifacts_with_prefix("model_decode_r")
            .iter()
            .filter_map(|a| a.name.trim_start_matches("model_decode_r").parse().ok())
            .collect();
        decode_caps.sort_unstable();
        if prefill_lens.is_empty() || decode_caps.is_empty() {
            return Err(anyhow!(
                "artifacts missing model_prefill_n*/model_decode_r* (run `make artifacts`)"
            ));
        }
        Ok(PjrtBackend { rt, cfg, prefill_lens, decode_caps })
    }

    pub fn platform(&self) -> String {
        self.rt.platform()
    }

    pub fn max_prefill(&self) -> usize {
        *self.prefill_lens.last().unwrap()
    }

    pub fn max_decode_cache(&self) -> usize {
        *self.decode_caps.last().unwrap() - 1 // one slot reserved implicitly
    }

    fn pick_prefill(&self, n: usize) -> Result<usize> {
        self.prefill_lens
            .iter()
            .copied()
            .find(|&l| l >= n)
            .ok_or_else(|| anyhow!("prompt of {n} exceeds largest prefill artifact"))
    }

    fn pick_decode(&self, cache_len: usize) -> Result<usize> {
        self.decode_caps
            .iter()
            .copied()
            .find(|&c| c >= cache_len)
            .ok_or_else(|| anyhow!("cache of {cache_len} exceeds largest decode artifact"))
    }
}

#[cfg(feature = "pjrt")]
impl ModelBackend for PjrtBackend {
    fn config(&self) -> ModelConfig {
        self.cfg
    }

    fn prefill(&mut self, tokens: &[u32]) -> PrefillOutput {
        let n = tokens.len();
        let cap = self.pick_prefill(n).expect("prefill capacity");
        let mut padded: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        padded.resize(cap, super::super::workload::tasks::PAD as i32);
        let name = format!("model_prefill_n{cap}");
        let outs = self
            .rt
            .execute_f32(
                &name,
                &[LiteralArg::I32Vec(&padded), LiteralArg::I32Scalar(n as i32)],
            )
            .expect("prefill execution");
        let (l, h, dh) = (self.cfg.n_layers, self.cfg.n_heads, self.cfg.d_head());
        let logits = outs[0].clone();
        // caches come back as (L, H, cap, dh); slice to n rows
        let mut k_cache = Vec::with_capacity(l * h);
        let mut v_cache = Vec::with_capacity(l * h);
        for (out_idx, dst) in [(1usize, &mut k_cache), (2usize, &mut v_cache)] {
            let flat = &outs[out_idx];
            assert_eq!(flat.len(), l * h * cap * dh);
            for li in 0..l {
                for hi in 0..h {
                    let base = (li * h + hi) * cap * dh;
                    let mut m = Matrix::zeros(n, dh);
                    for row in 0..n {
                        m.row_mut(row)
                            .copy_from_slice(&flat[base + row * dh..base + (row + 1) * dh]);
                    }
                    dst.push(m);
                }
            }
        }
        PrefillOutput { logits, k_cache, v_cache }
    }

    fn decode(
        &mut self,
        token: u32,
        pos: usize,
        caches: &[(&Matrix, &Matrix, &[f64])],
    ) -> (Vec<f32>, Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let (l, h, dh) = (self.cfg.n_layers, self.cfg.n_heads, self.cfg.d_head());
        assert_eq!(caches.len(), l * h);
        let longest = caches.iter().map(|(k, _, _)| k.rows()).max().unwrap_or(0);
        let cap = self.pick_decode(longest).expect("decode capacity");
        let name = format!("model_decode_r{cap}");
        // pack padded (L, H, cap, dh) tensors; pad rows: k arbitrary(0),
        // v = 0, w = 0 (inert per the WTDATTN padding contract)
        let mut kbuf = vec![0.0f32; l * h * cap * dh];
        let mut vbuf = vec![0.0f32; l * h * cap * dh];
        let mut wbuf = vec![0.0f32; l * h * cap];
        for (lh, (k, v, w)) in caches.iter().enumerate() {
            let base = lh * cap * dh;
            for row in 0..k.rows() {
                kbuf[base + row * dh..base + (row + 1) * dh].copy_from_slice(k.row(row));
                vbuf[base + row * dh..base + (row + 1) * dh].copy_from_slice(v.row(row));
            }
            for (row, &wv) in w.iter().enumerate() {
                wbuf[lh * cap + row] = wv as f32;
            }
        }
        let dims4 = vec![l as i64, h as i64, cap as i64, dh as i64];
        let dims3 = vec![l as i64, h as i64, cap as i64];
        let outs = self
            .rt
            .execute_f32(
                &name,
                &[
                    LiteralArg::I32Scalar(token as i32),
                    LiteralArg::I32Scalar(pos as i32),
                    LiteralArg::F32(&kbuf, dims4.clone()),
                    LiteralArg::F32(&vbuf, dims4),
                    LiteralArg::F32(&wbuf, dims3),
                ],
            )
            .expect("decode execution");
        let logits = outs[0].clone();
        let unpack = |flat: &Vec<f32>| -> Vec<Vec<f32>> {
            assert_eq!(flat.len(), l * h * dh);
            (0..l * h)
                .map(|lh| flat[lh * dh..(lh + 1) * dh].to_vec())
                .collect()
        };
        (logits, unpack(&outs[1]), unpack(&outs[2]))
    }
}

/// Stub backend for builds without the `pjrt` feature. [`PjrtBackend::open`]
/// always errors, so the `ModelBackend` methods below are unreachable; they
/// exist so `Server::spawn(cfg, comp, || PjrtBackend::open(dir).unwrap())`
/// still typechecks in the CLI and examples.
#[cfg(not(feature = "pjrt"))]
pub struct PjrtBackend {
    #[allow(dead_code)] // never constructed: open() always errors
    cfg: ModelConfig,
}

#[cfg(not(feature = "pjrt"))]
impl PjrtBackend {
    pub fn open(dir: impl AsRef<std::path::Path>) -> anyhow::Result<Self> {
        let _ = dir;
        anyhow::bail!(
            "this build of wildcat has no PJRT support (built without the \
             `pjrt` feature); use the native backend instead"
        )
    }

    pub fn platform(&self) -> String {
        "unavailable (built without the `pjrt` feature)".to_string()
    }
}

#[cfg(not(feature = "pjrt"))]
impl ModelBackend for PjrtBackend {
    fn config(&self) -> ModelConfig {
        self.cfg
    }

    fn prefill(&mut self, _tokens: &[u32]) -> PrefillOutput {
        unreachable!("PjrtBackend cannot be constructed without the `pjrt` feature")
    }

    fn decode(
        &mut self,
        _token: u32,
        _pos: usize,
        _caches: &[(&Matrix, &Matrix, &[f64])],
    ) -> (Vec<f32>, Vec<Vec<f32>>, Vec<Vec<f32>>) {
        unreachable!("PjrtBackend cannot be constructed without the `pjrt` feature")
    }
}
