//! Artifact manifest: the JSON index written by python/compile/aot.py
//! (`artifacts/manifest.json`), parsed with the in-tree JSON substrate.

use crate::util::json::{parse, Json};

/// Tensor dtype+shape as declared by the exporter.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    fn from_json(j: &Json) -> Result<Self, String> {
        let dtype = j
            .get("dtype")
            .and_then(|d| d.as_str())
            .ok_or("tensor missing dtype")?
            .to_string();
        let shape = j
            .get("shape")
            .and_then(|s| s.as_arr())
            .ok_or("tensor missing shape")?
            .iter()
            .map(|v| v.as_usize().ok_or("bad dim"))
            .collect::<Result<Vec<_>, _>>()?;
        Ok(TensorSpec { dtype, shape })
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One exported HLO computation.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Model hyper-parameters baked into the manifest.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelSpec {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_len: usize,
    pub beta: f64,
}

impl ModelSpec {
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }
}

/// Parsed manifest.json.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub version: usize,
    pub model: ModelSpec,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self, String> {
        let j = parse(text)?;
        let version = j.get("version").and_then(|v| v.as_usize()).ok_or("missing version")?;
        let m = j.get("model").ok_or("missing model")?;
        let get = |k: &str| -> Result<usize, String> {
            m.get(k).and_then(|v| v.as_usize()).ok_or(format!("model missing {k}"))
        };
        let model = ModelSpec {
            vocab: get("vocab")?,
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            d_ff: get("d_ff")?,
            max_len: get("max_len")?,
            beta: m.get("beta").and_then(|v| v.as_f64()).ok_or("model missing beta")?,
        };
        let artifacts = j
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or("missing artifacts")?
            .iter()
            .map(|a| {
                Ok(ArtifactSpec {
                    name: a
                        .get("name")
                        .and_then(|n| n.as_str())
                        .ok_or("artifact missing name")?
                        .to_string(),
                    file: a
                        .get("file")
                        .and_then(|n| n.as_str())
                        .ok_or("artifact missing file")?
                        .to_string(),
                    inputs: a
                        .get("inputs")
                        .and_then(|i| i.as_arr())
                        .ok_or("artifact missing inputs")?
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect::<Result<Vec<_>, _>>()?,
                    outputs: a
                        .get("outputs")
                        .and_then(|i| i.as_arr())
                        .ok_or("artifact missing outputs")?
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect::<Result<Vec<_>, _>>()?,
                })
            })
            .collect::<Result<Vec<_>, String>>()?;
        Ok(Manifest { version, model, artifacts })
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Artifact names with a given prefix (e.g. all `model_decode_r*`).
    pub fn artifacts_with_prefix(&self, prefix: &str) -> Vec<&ArtifactSpec> {
        self.artifacts.iter().filter(|a| a.name.starts_with(prefix)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "model": {"vocab": 64, "d_model": 64, "n_layers": 2, "n_heads": 2,
                "d_ff": 128, "max_len": 1024, "beta": 0.17677},
      "artifacts": [
        {"name": "model_decode_r64", "file": "model_decode_r64.hlo.txt",
         "inputs": [{"dtype": "i32", "shape": []},
                    {"dtype": "i32", "shape": []},
                    {"dtype": "f32", "shape": [2, 2, 64, 32]},
                    {"dtype": "f32", "shape": [2, 2, 64, 32]},
                    {"dtype": "f32", "shape": [2, 2, 64]}],
         "outputs": [{"dtype": "f32", "shape": [64]},
                     {"dtype": "f32", "shape": [2, 2, 32]},
                     {"dtype": "f32", "shape": [2, 2, 32]}]}
      ]
    }"#;

    #[test]
    fn parses_manifest() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.version, 1);
        assert_eq!(m.model.vocab, 64);
        assert_eq!(m.model.d_head(), 32);
        assert!((m.model.beta - 0.17677).abs() < 1e-9);
        let a = m.artifact("model_decode_r64").unwrap();
        assert_eq!(a.inputs.len(), 5);
        assert_eq!(a.inputs[2].shape, vec![2, 2, 64, 32]);
        assert_eq!(a.inputs[2].numel(), 2 * 2 * 64 * 32);
        assert_eq!(a.outputs[0].shape, vec![64]);
        assert_eq!(m.artifacts_with_prefix("model_decode").len(), 1);
        assert!(m.artifact("nope").is_none());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("not json").is_err());
    }
}
