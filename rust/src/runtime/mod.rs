//! PJRT runtime: load the AOT-compiled HLO artifacts and execute them from
//! the Rust hot path. Python never runs here — `make artifacts` produced
//! HLO *text* (see python/compile/aot.py for why text, not serialized
//! protos) which this module parses, compiles once per process through
//! the PJRT CPU client, and caches.
//!
//! The PJRT client comes from the external `xla` bindings, which are not
//! available in the offline build. The execution path is therefore gated
//! behind the `pjrt` cargo feature: without it, [`PjrtRuntime::open`] and
//! [`PjrtBackend::open`](backend::PjrtBackend::open) return a descriptive
//! error and everything else in the crate (native model, benches,
//! coordinator) works unchanged. The manifest parser ([`artifacts`]) is
//! pure Rust and always available.
//!
//! `xla::PjRtClient` is `Rc`-backed (not `Send`), so a [`PjrtRuntime`] is
//! owned by a single thread — the coordinator dedicates a model-worker
//! thread to it and communicates over channels.

pub mod artifacts;
pub mod backend;

pub use artifacts::{ArtifactSpec, Manifest, ModelSpec, TensorSpec};
pub use backend::PjrtBackend;

#[cfg(feature = "pjrt")]
use crate::linalg::Matrix;
#[cfg(feature = "pjrt")]
use anyhow::{anyhow, Context, Result};
#[cfg(feature = "pjrt")]
use std::collections::HashMap;
#[cfg(feature = "pjrt")]
use std::path::{Path, PathBuf};

/// A loaded-and-compiled artifact registry over one PJRT client.
#[cfg(feature = "pjrt")]
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

#[cfg(feature = "pjrt")]
impl PjrtRuntime {
    /// Open the artifact directory (reads `manifest.json`) and create the
    /// PJRT CPU client. Compilation is lazy per artifact.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts`"))?;
        let manifest = Manifest::parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(PjrtRuntime { client, dir, manifest, executables: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (once) and return the executable for a named artifact.
    pub fn load(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.executables.contains_key(name) {
            let spec = self
                .manifest
                .artifact(name)
                .ok_or_else(|| anyhow!("unknown artifact {name:?}"))?;
            let path = self.dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.executables.insert(name.to_string(), exe);
        }
        Ok(&self.executables[name])
    }

    /// Execute an artifact on literal inputs; returns the flattened tuple
    /// outputs (aot.py lowers with `return_tuple=True`).
    pub fn execute(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let spec = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?}"))?;
        if inputs.len() != spec.inputs.len() {
            return Err(anyhow!(
                "{name}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            ));
        }
        let exe = self.load(name)?;
        let result = exe.execute::<xla::Literal>(inputs)?;
        let lit = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| anyhow!("{name}: empty execution result"))?
            .to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Convenience: run on f32 matrices / vectors (the common case).
    pub fn execute_f32(&mut self, name: &str, inputs: &[LiteralArg]) -> Result<Vec<Vec<f32>>> {
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|a| a.to_literal())
            .collect::<Result<_>>()?;
        let outs = self.execute(name, &lits)?;
        outs.iter().map(|l| Ok(l.to_vec::<f32>()?)).collect()
    }
}

/// Typed argument helper for [`PjrtRuntime::execute_f32`].
#[cfg(feature = "pjrt")]
pub enum LiteralArg<'a> {
    /// Flat f32 data with an explicit shape.
    F32(&'a [f32], Vec<i64>),
    /// A 2-D matrix.
    MatrixRef(&'a Matrix),
    /// An i32 scalar (token ids, lengths, positions).
    I32Scalar(i32),
    /// An i32 vector (token buffers).
    I32Vec(&'a [i32]),
}

#[cfg(feature = "pjrt")]
impl LiteralArg<'_> {
    pub fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            LiteralArg::F32(data, dims) => {
                let flat = xla::Literal::vec1(data);
                if dims.len() == 1 && dims[0] as usize == data.len() {
                    flat
                } else {
                    flat.reshape(dims)?
                }
            }
            LiteralArg::MatrixRef(m) => xla::Literal::vec1(m.as_slice())
                .reshape(&[m.rows() as i64, m.cols() as i64])?,
            LiteralArg::I32Scalar(v) => xla::Literal::scalar(*v),
            LiteralArg::I32Vec(v) => xla::Literal::vec1(v),
        })
    }
}

/// Stub runtime used when the crate is built without the `pjrt` feature:
/// [`PjrtRuntime::open`] always fails with a clear message, so every
/// downstream caller (the `wildcat info` / `wildcat serve --pjrt` paths)
/// reports the build configuration instead of a missing-symbol error.
#[cfg(not(feature = "pjrt"))]
pub struct PjrtRuntime {
    /// Parsed artifact manifest (kept so callers can typecheck; a stub
    /// runtime is never actually constructed).
    pub manifest: Manifest,
}

#[cfg(not(feature = "pjrt"))]
impl PjrtRuntime {
    pub fn open(dir: impl AsRef<std::path::Path>) -> anyhow::Result<Self> {
        let _ = dir;
        anyhow::bail!(
            "this build of wildcat has no PJRT support (the `xla` bindings are \
             not available offline); rebuild with `--features pjrt` in an \
             environment that provides the xla crate, or use the native backend"
        )
    }

    pub fn platform(&self) -> String {
        "unavailable (built without the `pjrt` feature)".to_string()
    }
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;

    #[test]
    fn literal_arg_shapes() {
        let m = Matrix::from_fn(2, 3, |i, j| (i * 3 + j) as f32);
        let lit = LiteralArg::MatrixRef(&m).to_literal().unwrap();
        assert_eq!(lit.element_count(), 6);
        let lit2 = LiteralArg::I32Scalar(7).to_literal().unwrap();
        assert_eq!(lit2.element_count(), 1);
        let v = vec![1.0f32, 2.0, 3.0, 4.0];
        let lit3 = LiteralArg::F32(&v, vec![2, 2]).to_literal().unwrap();
        assert_eq!(lit3.element_count(), 4);
        let toks = vec![1i32, 2, 3];
        assert_eq!(LiteralArg::I32Vec(&toks).to_literal().unwrap().element_count(), 3);
    }

    // PJRT client construction + artifact execution are covered by the
    // integration tests in rust/tests/pjrt_roundtrip.rs (they need the
    // artifacts directory built by `make artifacts`).
}
