//! Lambert-W function substrate (principal branch `W0`).
//!
//! The WildCat temperature rule (Eq. 4) and the theoretical rank bounds
//! (Thm. 2, Lem. 3) evaluate `W0`. SciPy is not on the request path, so we
//! implement the guaranteed-precision iteration of Lóczi (2022), quoted in
//! the paper as Thm. L.1:
//!
//! * start `β0 = ln z − ln ln z` for `z > e`, `β0 = exp(ln z − 1) = z/e`
//!   for `0 < z < e`;
//! * iterate `β_{n+1} = β_n/(1+β_n) · (1 + ln z − ln β_n)`;
//! * after `n` steps the error is `< max(0.32^(2^n), 0.633^(2^n)/3)` —
//!   quadratic convergence, so 6 iterations give far below f64 ulp for the
//!   argument ranges the temperature rule produces.
//!
//! Negative arguments in `(−1/e, 0)` (not needed by Eq. 4 but exercised in
//! tests and by the Tab. 1 machinery) use a Halley fallback.

/// `ρ0 = sqrt(1 + e^{W0(2/e²)+2})` — the paper's Eq. (16) constant (≈ 3.19).
pub fn rho0() -> f64 {
    (1.0 + (lambert_w0(2.0 / (std::f64::consts::E * std::f64::consts::E)) + 2.0).exp()).sqrt()
}

/// Principal branch `W0(z)` for `z ≥ −1/e`.
///
/// Uses the Lóczi (2022) iteration for `z > 0` and a Halley iteration from
/// a series seed for `z ∈ [−1/e, 0]`.
pub fn lambert_w0(z: f64) -> f64 {
    assert!(z.is_finite(), "lambert_w0: non-finite argument {z}");
    let inv_e = (-1.0f64).exp();
    assert!(
        z >= -inv_e - 1e-12,
        "lambert_w0: argument {z} below -1/e (outside domain)"
    );
    if z == 0.0 {
        return 0.0;
    }
    if z > 0.0 {
        let e = std::f64::consts::E;
        let mut b = if z > e {
            let lz = z.ln();
            lz - lz.ln()
        } else {
            // exp(ln z − 1) = z / e; always a valid positive seed for z<e.
            z / e
        };
        // Guard: the iteration needs b > 0.
        if !(b > 0.0) {
            b = z / e;
        }
        let lnz = z.ln();
        for _ in 0..8 {
            let next = b / (1.0 + b) * (1.0 + lnz - b.ln());
            if !next.is_finite() {
                break;
            }
            if (next - b).abs() <= 1e-16 * b.abs().max(1e-300) {
                b = next;
                break;
            }
            b = next;
        }
        return b;
    }
    // z in [−1/e, 0): Halley from the branch-point series seed.
    let p = (2.0 * (1.0 + std::f64::consts::E * z)).max(0.0).sqrt();
    let mut w = -1.0 + p - p * p / 3.0 + 11.0 * p * p * p / 72.0;
    for _ in 0..40 {
        let ew = w.exp();
        let f = w * ew - z;
        if f == 0.0 {
            break;
        }
        let denom = ew * (w + 1.0) - (w + 2.0) * f / (2.0 * w + 2.0);
        if denom == 0.0 || !denom.is_finite() {
            break;
        }
        let step = f / denom;
        w -= step;
        if step.abs() < 1e-15 * w.abs().max(1e-10) {
            break;
        }
    }
    w
}

/// Convenience: `exp(W0(z)) = z / W0(z)` for `z ≠ 0` (Lem. L.1).
pub fn exp_w0(z: f64) -> f64 {
    if z == 0.0 {
        return 1.0;
    }
    let w = lambert_w0(z);
    if w == 0.0 {
        1.0
    } else {
        z / w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_inverse(z: f64, tol: f64) {
        let w = lambert_w0(z);
        let back = w * w.exp();
        assert!(
            (back - z).abs() <= tol * z.abs().max(1.0),
            "z={z} w={w} back={back}"
        );
    }

    #[test]
    fn known_values() {
        assert!((lambert_w0(0.0)).abs() < 1e-15);
        // W0(e) = 1
        assert!((lambert_w0(std::f64::consts::E) - 1.0).abs() < 1e-12);
        // W0(1) = Ω ≈ 0.5671432904097838
        assert!((lambert_w0(1.0) - 0.567_143_290_409_783_8).abs() < 1e-12);
        // W0(-1/e) = -1
        assert!((lambert_w0(-(-1.0f64).exp()) + 1.0).abs() < 1e-5);
    }

    #[test]
    fn inverse_identity_positive_range() {
        for &z in &[1e-8, 1e-3, 0.1, 0.5, 1.0, 2.0, 2.6, 3.0, 10.0, 1e3, 1e6, 1e12] {
            check_inverse(z, 1e-10);
        }
    }

    #[test]
    fn inverse_identity_negative_range() {
        for &z in &[-0.05, -0.1, -0.2, -0.3, -0.35] {
            check_inverse(z, 1e-9);
        }
    }

    #[test]
    fn monotone_increasing() {
        let mut prev = lambert_w0(-0.3);
        for i in 1..2000 {
            let z = -0.3 + i as f64 * 0.01;
            let w = lambert_w0(z);
            assert!(w >= prev - 1e-12, "not monotone at z={z}");
            prev = w;
        }
    }

    #[test]
    fn orabona_lower_bound() {
        // Lem. L.4 (Orabona 2019, Thm C.3): W0(z) >= 0.6321 log(1+z), z >= 0.
        for i in 0..500 {
            let z = i as f64 * 0.37;
            assert!(
                lambert_w0(z) >= 0.6321 * (1.0 + z).ln() - 1e-9,
                "bound fails at z={z}"
            );
        }
    }

    #[test]
    fn rho0_matches_paper() {
        // Paper: ρ0 ≈ 3.19 and 2/(ρ0² + 1) ≤ 1/5 (Cor. G.1 proof).
        let r = rho0();
        assert!((r - 3.19).abs() < 0.02, "rho0={r}");
        assert!(2.0 / (r * r + 1.0) <= 0.2 + 1e-9);
    }

    #[test]
    fn exp_w0_identity() {
        for &z in &[0.5, 1.0, 7.0, 100.0] {
            let w = lambert_w0(z);
            assert!((exp_w0(z) - w.exp()).abs() < 1e-9 * w.exp());
        }
        assert_eq!(exp_w0(0.0), 1.0);
    }

    #[test]
    #[should_panic]
    fn below_domain_panics() {
        lambert_w0(-1.0);
    }
}
