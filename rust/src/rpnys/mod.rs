//! Randomly pivoted Nyström (RPNYS, Alg. 1) — the paper's coreset
//! selection + optimal weighting engine.
//!
//! Two implementations are provided and cross-validated:
//!
//! * [`rpnys`] — factor form (randomly pivoted Cholesky, Chen et al. 2022):
//!   maintains `F ∈ R^{n×t}` with `H ≈ F Fᵀ` and the residual diagonal;
//!   numerically stabler and `O(nr² + nrd)` like the paper's Alg. 1.
//! * [`rpnys_paper_update`] — the paper's literal `g gᵀ` rank-one inverse
//!   update (Prop. K.1), kept as a fidelity oracle for tests.
//!
//! After pivot selection, the Nyström weights
//! `W = h(K_S, K_S)⁺ h(K_S, K)` are solved once with jittered Cholesky
//! (pseudo-inverse semantics), `O(r³ + r²n)`.

use crate::kernels::{kernel_column, kernel_cross, kernel_diag};
use crate::linalg::{spd_solve, Matrix};
use crate::rng::Rng;

/// Output of RPNYS: coreset indices and optimal Nyström weights.
#[derive(Clone, Debug)]
pub struct NystromApprox {
    /// Selected pivot indices into the input key matrix, in selection order.
    pub indices: Vec<usize>,
    /// `W ∈ R^{r×n}` row-major: optimal weights such that
    /// `h(·, K) ≈ h(·, K_S) W`.
    pub weights: Vec<f64>,
    /// Number of input keys `n`.
    pub n: usize,
}

impl NystromApprox {
    pub fn rank(&self) -> usize {
        self.indices.len()
    }

    /// `w = W 1_n` — the softmax re-normalisation vector of COMPRESSKV.
    pub fn weight_row_sums(&self) -> Vec<f64> {
        let r = self.rank();
        let mut out = vec![0.0; r];
        for (i, o) in out.iter_mut().enumerate() {
            *o = self.weights[i * self.n..(i + 1) * self.n].iter().sum();
        }
        out
    }

    /// `V_S = W V` — compressed values (f64 accumulation, f32 output).
    pub fn compress_values(&self, v: &Matrix) -> Matrix {
        assert_eq!(v.rows(), self.n, "value count must match key count");
        let r = self.rank();
        let d = v.cols();
        let mut out = Matrix::zeros(r, d);
        for i in 0..r {
            let wrow = &self.weights[i * self.n..(i + 1) * self.n];
            let mut acc = vec![0.0f64; d];
            for (l, &w) in wrow.iter().enumerate() {
                if w == 0.0 {
                    continue;
                }
                for (a, &x) in acc.iter_mut().zip(v.row(l)) {
                    *a += w * x as f64;
                }
            }
            for (o, a) in out.row_mut(i).iter_mut().zip(&acc) {
                *o = *a as f32;
            }
        }
        out
    }
}

/// Floor under which a residual diagonal entry is treated as exhausted.
const RESIDUAL_FLOOR: f64 = 1e-12;

/// Factor-form randomly pivoted Nyström. `scale_eff = β/τ²` is the
/// effective kernel scale; `rank` the requested coreset size (may stop
/// early if the kernel matrix is numerically exhausted).
pub fn rpnys(k: &Matrix, scale_eff: f64, rank: usize, rng: &mut Rng) -> NystromApprox {
    let n = k.rows();
    let rank = rank.min(n);
    let mut res = kernel_diag(k, scale_eff);
    let total0: f64 = res.iter().sum();
    let floor = RESIDUAL_FLOOR * total0.max(1e-300) / n.max(1) as f64;

    // F stored column-major as r vectors of length n (each column built once).
    let mut cols: Vec<Vec<f64>> = Vec::with_capacity(rank);
    let mut pivots: Vec<usize> = Vec::with_capacity(rank);

    for _t in 0..rank {
        let s = match rng.categorical(&res) {
            Some(s) => s,
            None => break, // fully approximated
        };
        let mut c = kernel_column(k, s, scale_eff);
        // c -= F[:, :t] * F[s, :t]
        for col in &cols {
            let fs = col[s];
            if fs == 0.0 {
                continue;
            }
            for (ci, fi) in c.iter_mut().zip(col) {
                *ci -= fs * fi;
            }
        }
        let rho = c[s].min(res[s]).max(0.0);
        if rho <= floor {
            res[s] = 0.0;
            continue; // numerically exhausted pivot; try another
        }
        let inv_sqrt = 1.0 / rho.sqrt();
        for ci in c.iter_mut() {
            *ci *= inv_sqrt;
        }
        for (r_i, f_i) in res.iter_mut().zip(&c) {
            *r_i = (*r_i - f_i * f_i).max(0.0);
        }
        res[s] = 0.0;
        cols.push(c);
        pivots.push(s);
    }

    let weights = solve_weights(k, &pivots, scale_eff);
    NystromApprox { indices: pivots, weights, n }
}

/// Solve `h(K_S, K_S) W = h(K_S, K)` for the optimal Nyström weights.
fn solve_weights(k: &Matrix, pivots: &[usize], scale_eff: f64) -> Vec<f64> {
    let n = k.rows();
    let r = pivots.len();
    if r == 0 {
        return Vec::new();
    }
    let ks = k.select_rows(pivots);
    let h_ss = kernel_cross(&ks, &ks, scale_eff);
    let mut rhs = kernel_cross(&ks, k, scale_eff); // r×n
    spd_solve(h_ss, r, &mut rhs, n);
    rhs
}

/// The paper's literal Alg. 1 with the `g gᵀ` inverse update (Prop. K.1).
/// O(nr²) like the factor form but with explicit inverse maintenance.
/// Kept as a test oracle: with the same RNG stream it must select the same
/// pivots as [`rpnys`] and produce consistent weights (up to round-off).
pub fn rpnys_paper_update(k: &Matrix, scale_eff: f64, rank: usize, rng: &mut Rng) -> NystromApprox {
    let n = k.rows();
    let rank = rank.min(n);
    let mut res = kernel_diag(k, scale_eff);
    let total0: f64 = res.iter().sum();
    let floor = RESIDUAL_FLOOR * total0.max(1e-300) / n.max(1) as f64;

    let mut pivots: Vec<usize> = Vec::new();
    // inv = h(K_S, K_S)^{-1}, row-major r×r, grown per pivot.
    let mut inv: Vec<f64> = Vec::new();
    // rows = h(K_S, K), r×n row-major.
    let mut rows: Vec<f64> = Vec::new();

    for _t in 0..rank {
        let s = match rng.categorical(&res) {
            Some(s) => s,
            None => break,
        };
        let r = pivots.len();
        let col_s = kernel_column(k, s, scale_eff); // h(K, k_s), length n
        // residual at pivot: h(k_s,k_s) − h(k_s,K_S) inv h(K_S,k_s)
        let hs: Vec<f64> = pivots.iter().map(|&p| col_s[p]).collect();
        let mut m_hs = vec![0.0f64; r]; // inv * hs
        for i in 0..r {
            m_hs[i] = (0..r).map(|j| inv[i * r + j] * hs[j]).sum();
        }
        let res_s = col_s[s] - hs.iter().zip(&m_hs).map(|(a, b)| a * b).sum::<f64>();
        let res_s = res_s.min(res[s]).max(0.0);
        if res_s <= floor {
            res[s] = 0.0;
            continue;
        }
        // g = (m_hs, -1)/sqrt(res_s); inv' = [[inv,0],[0,0]] + g gᵀ
        let inv_sqrt = 1.0 / res_s.sqrt();
        let g: Vec<f64> = m_hs
            .iter()
            .map(|&x| x * inv_sqrt)
            .chain(std::iter::once(-inv_sqrt))
            .collect();
        let r1 = r + 1;
        let mut new_inv = vec![0.0f64; r1 * r1];
        for i in 0..r {
            for j in 0..r {
                new_inv[i * r1 + j] = inv[i * r + j];
            }
        }
        for i in 0..r1 {
            for j in 0..r1 {
                new_inv[i * r1 + j] += g[i] * g[j];
            }
        }
        inv = new_inv;
        rows.extend_from_slice(&col_s); // h(K_S', K) gains row h(k_s, K)
        pivots.push(s);
        // residual diag update: res_l -= (gᵀ h(K_S', k_l))²
        for l in 0..n {
            let mut dot = 0.0f64;
            for (i, gi) in g.iter().enumerate() {
                dot += gi * rows[i * n + l];
            }
            res[l] = (res[l] - dot * dot).max(0.0);
        }
        res[s] = 0.0;
    }

    // W = inv · rows (the paper's `M R` product)
    let r = pivots.len();
    let mut weights = vec![0.0f64; r * n];
    for i in 0..r {
        for l in 0..n {
            let mut acc = 0.0f64;
            for j in 0..r {
                acc += inv[i * r + j] * rows[j * n + l];
            }
            weights[i * n + l] = acc;
        }
    }
    NystromApprox { indices: pivots, weights, n }
}

/// `‖H − h(K, K_S) W‖_op` for a [`NystromApprox`] — the Thm. 1 error
/// metric. O(n²) — test/diagnostic use only.
pub fn residual_op_norm(k: &Matrix, approx: &NystromApprox, scale_eff: f64) -> f64 {
    let n = k.rows();
    let r = approx.rank();
    let h = kernel_cross(k, k, scale_eff);
    let mut resid = h;
    if r > 0 {
        let ks = k.select_rows(&approx.indices);
        let h_ns = kernel_cross(k, &ks, scale_eff); // n×r
        for i in 0..n {
            for l in 0..n {
                let mut acc = 0.0f64;
                for j in 0..r {
                    acc += h_ns[i * r + j] * approx.weights[j * n + l];
                }
                resid[i * n + l] -= acc;
            }
        }
    }
    // symmetrise against round-off before power iteration
    for i in 0..n {
        for l in 0..i {
            let v = 0.5 * (resid[i * n + l] + resid[l * n + i]);
            resid[i * n + l] = v;
            resid[l * n + i] = v;
        }
    }
    crate::linalg::op_norm_sym_f64(&resid, n, 200)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Cases;

    #[test]
    fn selects_requested_rank_distinct_pivots() {
        Cases::new(16).run(|rng| {
            let n = 8 + rng.below(40);
            let d = 1 + rng.below(6);
            let k = Matrix::randn(rng, n, d);
            let r = 1 + rng.below(n.min(12));
            let a = rpnys(&k, 0.25, r, rng);
            assert!(a.rank() <= r);
            let mut seen = a.indices.clone();
            seen.sort_unstable();
            seen.dedup();
            assert_eq!(seen.len(), a.indices.len(), "duplicate pivot");
            assert_eq!(a.weights.len(), a.rank() * n);
        });
    }

    #[test]
    fn error_decreases_with_rank() {
        let mut rng = Rng::seed_from(42);
        let n = 48;
        let k = Matrix::randn(&mut rng, n, 4);
        let scale = 0.3;
        let mut last = f64::INFINITY;
        for r in [2usize, 8, 24, 48] {
            let mut r_rng = Rng::seed_from(7);
            let a = rpnys(&k, scale, r, &mut r_rng);
            let err = residual_op_norm(&k, &a, scale);
            assert!(
                err <= last * 1.5 + 1e-9,
                "r={r}: err={err} last={last} (should broadly decrease)"
            );
            if err < last {
                last = err;
            }
        }
        // full rank ⇒ (near-)exact reconstruction
        let mut r_rng = Rng::seed_from(7);
        let a = rpnys(&k, scale, n, &mut r_rng);
        let h = kernel_cross(&k, &k, scale);
        let h_norm = crate::linalg::op_norm_sym_f64(&h, n, 100);
        let err = residual_op_norm(&k, &a, scale);
        assert!(err <= 1e-5 * h_norm.max(1.0), "full-rank err={err}");
    }

    #[test]
    fn weights_interpolate_at_pivots() {
        // Nyström is a projection: at coreset points it reproduces the
        // kernel row exactly, so W restricted to pivot columns is identity.
        Cases::new(8).run(|rng| {
            let n = 10 + rng.below(20);
            let k = Matrix::randn(rng, n, 3);
            let a = rpnys(&k, 0.4, 6, rng);
            for (i, _) in a.indices.iter().enumerate() {
                for (j, &pj) in a.indices.iter().enumerate() {
                    let w = a.weights[i * n + pj];
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!(
                        (w - want).abs() < 1e-4,
                        "W[{i},{pj}]={w}, want {want}"
                    );
                }
            }
        });
    }

    #[test]
    fn paper_update_matches_factor_form() {
        Cases::new(8).run(|rng| {
            let n = 12 + rng.below(20);
            let k = Matrix::randn(rng, n, 3);
            let r = 5;
            let mut rng_a = Rng::seed_from(99);
            let mut rng_b = Rng::seed_from(99);
            let a = rpnys(&k, 0.35, r, &mut rng_a);
            let b = rpnys_paper_update(&k, 0.35, r, &mut rng_b);
            assert_eq!(a.indices, b.indices, "pivot sequences differ");
            for (x, y) in a.weights.iter().zip(&b.weights) {
                assert!((x - y).abs() < 1e-5 * (1.0 + x.abs()), "{x} vs {y}");
            }
        });
    }

    #[test]
    fn compress_values_and_row_sums() {
        let mut rng = Rng::seed_from(3);
        let n = 30;
        let k = Matrix::randn(&mut rng, n, 4);
        let v = Matrix::randn(&mut rng, n, 5);
        let a = rpnys(&k, 0.3, 8, &mut rng);
        let vs = a.compress_values(&v);
        assert_eq!(vs.rows(), a.rank());
        assert_eq!(vs.cols(), 5);
        // check one entry against the definition
        let want: f64 = (0..n)
            .map(|l| a.weights[l] * v.get(l, 2) as f64)
            .sum();
        assert!((vs.get(0, 2) as f64 - want).abs() < 1e-4 * (1.0 + want.abs()));
        let ws = a.weight_row_sums();
        assert_eq!(ws.len(), a.rank());
    }

    #[test]
    fn handles_duplicate_keys() {
        // Rank-deficient kernel matrix (duplicated rows): must not panic
        // and must stop early or pick distinct pivots.
        let mut rng = Rng::seed_from(5);
        let base = Matrix::randn(&mut rng, 4, 3);
        let k = Matrix::vcat(&[&base, &base, &base]);
        let a = rpnys(&k, 0.5, 10, &mut rng);
        assert!(a.rank() >= 1);
        let err = residual_op_norm(&k, &a, 0.5);
        let h = kernel_cross(&k, &k, 0.5);
        let h_norm = crate::linalg::op_norm_sym_f64(&h, 12, 100);
        assert!(err < 1e-3 * h_norm, "err={err} vs ‖H‖={h_norm}");
    }

    #[test]
    fn zero_rank_is_empty() {
        let mut rng = Rng::seed_from(6);
        let k = Matrix::randn(&mut rng, 10, 2);
        let a = rpnys(&k, 0.3, 0, &mut rng);
        assert_eq!(a.rank(), 0);
        assert!(a.weights.is_empty());
    }
}
