//! Thread-pool / parallel-for substrate.
//!
//! Neither `rayon` nor `tokio` is available in the offline build, so the
//! stack parallelises through this module: a global lazily-initialised pool
//! of worker threads plus scoped `parallel_for` helpers. The RPNYS binning
//! (Sec. 2.5), the blocked GEMM, the flash-attention baseline and the
//! coordinator's compression workers all run on top of it.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Number of worker threads used for data-parallel sections.
pub fn num_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        std::env::var("WILDCAT_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(4)
            })
    })
}

/// Run `f(chunk_index)` for every index in `0..n_chunks`, spread over the
/// pool. Work is distributed by an atomic cursor so uneven chunks balance.
///
/// `f` must be `Sync`: it may be called concurrently from several threads.
pub fn parallel_for<F>(n_chunks: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n_chunks == 0 {
        return;
    }
    let workers = num_threads().min(n_chunks);
    if workers <= 1 {
        for i in 0..n_chunks {
            f(i);
        }
        return;
    }
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n_chunks {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Partition `0..len` into roughly equal contiguous ranges, one per task,
/// and run `f(task_index, range)` in parallel. `n_tasks` is clamped to
/// `[1, len]`.
pub fn parallel_ranges<F>(len: usize, n_tasks: usize, f: F)
where
    F: Fn(usize, std::ops::Range<usize>) + Sync,
{
    if len == 0 {
        return;
    }
    let n_tasks = n_tasks.clamp(1, len);
    let base = len / n_tasks;
    let rem = len % n_tasks;
    parallel_for(n_tasks, |t| {
        let start = t * base + t.min(rem);
        let end = start + base + usize::from(t < rem);
        f(t, start..end);
    });
}

/// Map `f` over `0..n` in parallel, collecting results in order.
pub fn parallel_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let slots: Vec<std::sync::Mutex<&mut T>> =
            out.iter_mut().map(std::sync::Mutex::new).collect();
        parallel_for(n, |i| {
            let mut slot = slots[i].lock().unwrap();
            **slot = f(i);
        });
    }
    out
}

/// Split a mutable slice into disjoint row-chunks and process each chunk on
/// the pool. Used by GEMM and attention kernels to write output rows
/// without locking.
pub fn parallel_chunks_mut<T, F>(data: &mut [T], chunk: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if data.is_empty() {
        return;
    }
    let chunk = chunk.max(1);
    let chunks: Vec<&mut [T]> = data.chunks_mut(chunk).collect();
    let n = chunks.len();
    let slots: Vec<std::sync::Mutex<&mut [T]>> =
        chunks.into_iter().map(std::sync::Mutex::new).collect();
    parallel_for(n, |i| {
        let mut slot = slots[i].lock().unwrap();
        f(i, &mut slot);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_visits_all_once() {
        let n = 1000;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(n, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_zero_is_noop() {
        parallel_for(0, |_| panic!("should not run"));
    }

    #[test]
    fn parallel_ranges_cover_exactly() {
        for len in [1usize, 7, 64, 1000] {
            for tasks in [1usize, 3, 8, 2000] {
                let covered: Vec<AtomicUsize> = (0..len).map(|_| AtomicUsize::new(0)).collect();
                parallel_ranges(len, tasks, |_, r| {
                    for i in r {
                        covered[i].fetch_add(1, Ordering::Relaxed);
                    }
                });
                assert!(
                    covered.iter().all(|c| c.load(Ordering::Relaxed) == 1),
                    "len={len} tasks={tasks}"
                );
            }
        }
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map(256, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn parallel_chunks_mut_disjoint_writes() {
        let mut data = vec![0u64; 1003];
        parallel_chunks_mut(&mut data, 100, |ci, chunk| {
            for x in chunk.iter_mut() {
                *x = ci as u64 + 1;
            }
        });
        assert!(data.iter().all(|&x| x > 0));
        assert_eq!(data[0], 1);
        assert_eq!(data[1002], 11);
    }

    #[test]
    fn parallel_sum_matches_serial() {
        let n = 4096usize;
        let total = AtomicU64::new(0);
        parallel_for(n, |i| {
            total.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), (n as u64 - 1) * n as u64 / 2);
    }
}
