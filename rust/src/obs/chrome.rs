//! Chrome trace-event JSON export and validation.
//!
//! [`chrome_trace`] converts a drained [`TraceBuffer`] into the Chrome
//! trace-event format (the JSON flavour loaded by Perfetto and
//! `chrome://tracing`): one process (`pid`) per replica plus a
//! synthetic router process, one thread lane (`tid`) per request, and
//! paired `B`/`E` duration events with microsecond timestamps.
//!
//! Lanes are emitted well-formed *by construction*: within a lane,
//! spans are sorted by start time (ties broken longest-first so
//! enclosing spans open before the zero-duration spans they contain),
//! then replayed through a stack that closes every span before a
//! later non-overlapping one opens and clamps children to their
//! parent's end. The result always satisfies what
//! [`validate_chrome_trace`] checks: monotone timestamps per lane and
//! a matching `E` for every `B`.

use std::collections::BTreeMap;

use super::trace::{Event, SpanKind, TraceBuffer, NO_REQ, ROUTE_REJECTED};
use crate::util::json::Json;

/// Synthetic `pid` for the router process (real replicas use their
/// index, so any value far above a plausible replica count works).
pub const ROUTER_PID: u64 = 1_000_000;

/// `tid` of the per-replica maintenance lane carrying `evict` spans and
/// request-less `compress` spans. Request lanes use `req + 1`, so 0 is
/// free.
pub const MAINT_TID: u64 = 0;

/// `tid` of the router lane that collects rejected submissions (they
/// have no request id, hence no per-request lane).
pub const REJECT_TID: u64 = 1;

/// `tid` of the per-replica counter ("C" phase) lane. Request lanes use
/// `req + 1`, so a far-out sentinel keeps gauges clear of any plausible
/// request id (a small constant like 2 would collide with request 1).
pub const GAUGE_TID: u64 = 9_999_999;

/// `(pid, tid)` lane for an event, per the mapping above.
fn lane(ev: &Event) -> (u64, u64) {
    match ev.kind {
        SpanKind::Route => {
            if ev.req == NO_REQ {
                (ROUTER_PID, REJECT_TID)
            } else {
                // Router lanes are per (replica, request): ids are
                // assigned per replica, so the pair is what is unique.
                (ROUTER_PID, ((ev.replica as u64 + 1) << 32) | ev.req)
            }
        }
        _ => {
            if ev.req == NO_REQ {
                (ev.replica as u64, MAINT_TID)
            } else {
                (ev.replica as u64, ev.req + 1)
            }
        }
    }
}

/// Kind-specific `args` payload for one event.
fn args_of(ev: &Event) -> Json {
    let mut o = BTreeMap::new();
    if ev.req != NO_REQ {
        o.insert("req".to_string(), Json::Num(ev.req as f64));
    }
    match ev.kind {
        SpanKind::Queue => {
            o.insert("prompt_tokens".to_string(), Json::Num(ev.a as f64));
        }
        SpanKind::PrefixLookup => {
            o.insert("matched_tokens".to_string(), Json::Num(ev.a as f64));
            o.insert("hit".to_string(), Json::Bool(ev.b == 1));
        }
        SpanKind::Prefill => {
            o.insert("computed_tokens".to_string(), Json::Num(ev.a as f64));
            o.insert("skipped_tokens".to_string(), Json::Num(ev.b as f64));
        }
        SpanKind::DecodeStep => {
            o.insert("token_index".to_string(), Json::Num(ev.a as f64));
        }
        SpanKind::Compress => {
            o.insert("entries_compressed".to_string(), Json::Num(ev.a as f64));
        }
        SpanKind::Evict => {
            o.insert("evicted_blocks".to_string(), Json::Num(ev.a as f64));
            o.insert("tier_compressions".to_string(), Json::Num(ev.b as f64));
        }
        SpanKind::Route => {
            o.insert("attempts".to_string(), Json::Num(ev.a as f64));
            if ev.b == ROUTE_REJECTED {
                o.insert("outcome".to_string(), Json::Str("rejected".to_string()));
            } else {
                o.insert("replica".to_string(), Json::Num(ev.b as f64));
            }
        }
        SpanKind::Retire => {
            o.insert("tokens_generated".to_string(), Json::Num(ev.a as f64));
            o.insert("e2e_us".to_string(), Json::Num(ev.b as f64));
        }
        SpanKind::Quality => {
            o.insert("max_abs_err".to_string(), Json::Num(ev.a as f64 * 1e-9));
            let kind = if ev.b >> 32 == 0 { "decode" } else { "fold" };
            o.insert("sample".to_string(), Json::Str(kind.to_string()));
            o.insert("lh".to_string(), Json::Num((ev.b & 0xffff_ffff) as f64));
        }
        SpanKind::SloTransition => {
            let dir = if ev.a == 1 { "degrade" } else { "recover" };
            o.insert("transition".to_string(), Json::Str(dir.to_string()));
            o.insert("window_p99_err".to_string(), Json::Num(ev.b as f64 * 1e-9));
        }
        SpanKind::Gauge => {
            o.insert("value".to_string(), Json::Num(ev.a as f64));
        }
        SpanKind::Failover => {
            o.insert("failover".to_string(), Json::Num(ev.a as f64));
            o.insert("lost_replica".to_string(), Json::Num(ev.b as f64));
        }
        SpanKind::Restart => {
            o.insert("incarnation".to_string(), Json::Num(ev.a as f64));
            o.insert("failed_over_requests".to_string(), Json::Num(ev.b as f64));
        }
        SpanKind::Breaker => {
            let state = match ev.a {
                0 => "closed",
                1 => "open",
                _ => "half_open",
            };
            o.insert("state".to_string(), Json::Str(state.to_string()));
            o.insert("failures".to_string(), Json::Num(ev.b as f64));
        }
        SpanKind::Spill => {
            o.insert("spilled_blocks".to_string(), Json::Num(ev.a as f64));
            o.insert("record_bytes".to_string(), Json::Num(ev.b as f64));
        }
        SpanKind::PageIn => {
            o.insert("paged_blocks".to_string(), Json::Num(ev.a as f64));
            o.insert("paged_tokens".to_string(), Json::Num(ev.b as f64));
        }
    }
    Json::Obj(o)
}

/// A Chrome counter ("C" phase) event for one [`SpanKind::Gauge`]
/// sample: named after the gauge id, on the replica's dedicated
/// [`GAUGE_TID`] lane, with the sampled value under `args.value`.
fn counter_event(ev: &Event) -> Json {
    let mut args = BTreeMap::new();
    args.insert("value".to_string(), Json::Num(ev.a as f64));
    let mut o = BTreeMap::new();
    o.insert("name".to_string(), Json::Str(SpanKind::gauge_name(ev.b).to_string()));
    o.insert("cat".to_string(), Json::Str("wildcat".to_string()));
    o.insert("ph".to_string(), Json::Str("C".to_string()));
    o.insert("ts".to_string(), Json::Num(ev.ts_us as f64));
    o.insert("pid".to_string(), Json::Num(ev.replica as f64));
    o.insert("tid".to_string(), Json::Num(GAUGE_TID as f64));
    o.insert("args".to_string(), Json::Obj(args));
    Json::Obj(o)
}

fn meta_event(pid: u64, name: &str, key: &str, value: &str) -> Json {
    let mut args = BTreeMap::new();
    args.insert(key.to_string(), Json::Str(value.to_string()));
    let mut o = BTreeMap::new();
    o.insert("name".to_string(), Json::Str(name.to_string()));
    o.insert("ph".to_string(), Json::Str("M".to_string()));
    o.insert("pid".to_string(), Json::Num(pid as f64));
    o.insert("tid".to_string(), Json::Num(0.0));
    o.insert("args".to_string(), Json::Obj(args));
    Json::Obj(o)
}

fn phase_event(ev: &Event, ph: &str, ts: u64, pid: u64, tid: u64, with_args: bool) -> Json {
    let mut o = BTreeMap::new();
    o.insert("name".to_string(), Json::Str(ev.kind.name().to_string()));
    o.insert("cat".to_string(), Json::Str("wildcat".to_string()));
    o.insert("ph".to_string(), Json::Str(ph.to_string()));
    o.insert("ts".to_string(), Json::Num(ts as f64));
    o.insert("pid".to_string(), Json::Num(pid as f64));
    o.insert("tid".to_string(), Json::Num(tid as f64));
    if with_args {
        o.insert("args".to_string(), args_of(ev));
    }
    Json::Obj(o)
}

/// Convert a drained trace into a Chrome trace-event JSON document:
/// `{"traceEvents": [...], "displayTimeUnit": "ms", "otherData": {...}}`
/// with `dropped_events`/`recorded_events` under `otherData`.
pub fn chrome_trace(buf: &TraceBuffer) -> Json {
    // Group spans by lane; counter samples bypass the B/E machinery and
    // get their own per-replica lane below.
    let mut lanes: BTreeMap<(u64, u64), Vec<&Event>> = BTreeMap::new();
    let mut gauges: Vec<&Event> = Vec::new();
    for ev in &buf.events {
        if ev.kind == SpanKind::Gauge {
            gauges.push(ev);
        } else {
            lanes.entry(lane(ev)).or_default().push(ev);
        }
    }

    let mut out: Vec<Json> = Vec::with_capacity(buf.events.len() * 2 + 8);

    // Process/thread naming metadata.
    let mut named_pid = u64::MAX;
    for &(pid, tid) in lanes.keys() {
        if pid != named_pid {
            named_pid = pid;
            let pname =
                if pid == ROUTER_PID { "router".to_string() } else { format!("replica {pid}") };
            out.push(meta_event(pid, "process_name", "name", &pname));
        }
        if pid != ROUTER_PID && tid == MAINT_TID {
            out.push(meta_event(pid, "thread_name", "name", "kv maintenance"));
        }
    }

    // Per-lane stack-based B/E emission.
    for spans in lanes.values_mut() {
        spans.sort_by(|x, y| x.ts_us.cmp(&y.ts_us).then(y.dur_us.cmp(&x.dur_us)));
        let (pid, tid) = lane(spans[0]);
        // (event, clamped end) of currently-open spans, outermost first.
        let mut open: Vec<(&Event, u64)> = Vec::new();
        for &s in spans.iter() {
            let start = s.ts_us;
            while let Some(&(top, end)) = open.last() {
                if end <= start {
                    out.push(phase_event(top, "E", end, pid, tid, false));
                    open.pop();
                } else {
                    break;
                }
            }
            // Clamp to the enclosing span so lanes always nest cleanly
            // even if instrumentation produced a straddling overlap.
            let mut end = start.saturating_add(s.dur_us);
            if let Some(&(_, parent_end)) = open.last() {
                end = end.min(parent_end);
            }
            out.push(phase_event(s, "B", start, pid, tid, true));
            open.push((s, end.max(start)));
        }
        while let Some((top, end)) = open.pop() {
            out.push(phase_event(top, "E", end, pid, tid, false));
        }
    }

    // Counter ("C") samples, monotone per replica lane.
    gauges.sort_by_key(|e| (e.replica, e.ts_us));
    for ev in gauges {
        out.push(counter_event(ev));
    }

    let mut other = BTreeMap::new();
    other.insert("dropped_events".to_string(), Json::Num(buf.dropped as f64));
    other.insert("recorded_events".to_string(), Json::Num(buf.recorded as f64));

    let mut doc = BTreeMap::new();
    doc.insert("traceEvents".to_string(), Json::Arr(out));
    doc.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
    doc.insert("otherData".to_string(), Json::Obj(other));
    Json::Obj(doc)
}

/// Summary returned by [`validate_chrome_trace`].
#[derive(Clone, Copy, Debug, Default)]
pub struct TraceSummary {
    /// Total trace events (including metadata events).
    pub events: usize,
    /// Completed B/E span pairs.
    pub spans: usize,
    /// Counter ("C" phase) samples.
    pub counters: usize,
    /// Distinct `(pid, tid)` lanes.
    pub lanes: usize,
    /// Request lanes that carried a `retire` span.
    pub retired: usize,
    /// `otherData.dropped_events` from the document.
    pub dropped: u64,
    /// Worst relative error of `queue + prefill + Σ decode_step +
    /// retire` against the retire span's recorded e2e, over completed
    /// requests (0 when no request qualified or events were dropped).
    pub max_account_err: f64,
}

/// Span-accounting tolerance: per completed request, the lane's
/// lifecycle spans must sum to the recorded e2e within 5% (with a small
/// absolute floor so microsecond jitter on sub-millisecond requests
/// does not trip the relative check).
pub const ACCOUNT_REL_TOL: f64 = 0.05;
const ACCOUNT_ABS_FLOOR_US: f64 = 1000.0;

#[derive(Default)]
struct LaneCheck {
    last_ts: f64,
    // open span names, for B/E matching
    stack: Vec<String>,
    // summed durations per lifecycle kind (queue/prefill/decode/retire)
    queue_us: f64,
    prefill_us: f64,
    decode_us: f64,
    retire_us: f64,
    retire_e2e_us: f64,
    retire_tokens: f64,
    retired: bool,
    // ts of the currently open span per name (for duration on E)
    open_ts: Vec<f64>,
}

fn num_field(ev: &BTreeMap<String, Json>, key: &str) -> Result<f64, String> {
    ev.get(key)
        .and_then(|v| v.as_f64())
        .ok_or_else(|| format!("event missing numeric {key:?}: {:?}", ev.get("name")))
}

/// Structurally validate a Chrome trace document produced by
/// [`chrome_trace`] (or any conforming tool): every event has
/// `name`/`ph`/`ts`/`pid`/`tid`, per-lane timestamps are monotone
/// non-decreasing, every `B` has a matching `E` (LIFO per lane), and —
/// when no events were dropped — each completed request's lifecycle
/// spans account for its recorded e2e latency within
/// [`ACCOUNT_REL_TOL`].
pub fn validate_chrome_trace(doc: &Json) -> Result<TraceSummary, String> {
    let events = doc
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .ok_or("missing traceEvents array")?;
    let dropped = doc
        .get("otherData")
        .and_then(|o| o.get("dropped_events"))
        .and_then(|v| v.as_f64())
        .unwrap_or(0.0) as u64;

    let mut lanes: BTreeMap<(u64, u64), LaneCheck> = BTreeMap::new();
    let mut spans = 0usize;
    let mut counters = 0usize;

    for (i, ev) in events.iter().enumerate() {
        let o = ev.as_obj().ok_or_else(|| format!("event {i} is not an object"))?;
        let name = o
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or_else(|| format!("event {i} missing name"))?
            .to_string();
        let ph = o
            .get("ph")
            .and_then(|v| v.as_str())
            .ok_or_else(|| format!("event {i} ({name}) missing ph"))?;
        if ph == "M" {
            continue;
        }
        let ts = num_field(o, "ts")?;
        let pid = num_field(o, "pid")? as u64;
        let tid = num_field(o, "tid")? as u64;
        if ts < 0.0 {
            return Err(format!("event {i} ({name}) has negative ts"));
        }
        let lane = lanes.entry((pid, tid)).or_default();
        if ts < lane.last_ts {
            return Err(format!(
                "lane ({pid},{tid}): ts not monotone at event {i} ({name}): {ts} < {}",
                lane.last_ts
            ));
        }
        lane.last_ts = ts;
        match ph {
            "B" => {
                lane.stack.push(name.clone());
                lane.open_ts.push(ts);
            }
            "E" => {
                let open = lane
                    .stack
                    .pop()
                    .ok_or_else(|| format!("lane ({pid},{tid}): E without open B at event {i}"))?;
                if open != name {
                    return Err(format!(
                        "lane ({pid},{tid}): E {name:?} closes open span {open:?} at event {i}"
                    ));
                }
                let b_ts = lane.open_ts.pop().unwrap_or(ts);
                let dur = ts - b_ts;
                spans += 1;
                match name.as_str() {
                    "queue" => lane.queue_us += dur,
                    "prefill" => lane.prefill_us += dur,
                    "decode_step" => lane.decode_us += dur,
                    "retire" => {
                        lane.retire_us += dur;
                        lane.retired = true;
                    }
                    _ => {}
                }
            }
            "C" => {
                // Counter samples: no stack effect, no span accounting;
                // the value must be present and numeric.
                let args = o
                    .get("args")
                    .and_then(|v| v.as_obj())
                    .ok_or_else(|| format!("counter event {i} ({name}) missing args"))?;
                if args.get("value").and_then(|v| v.as_f64()).is_none() {
                    return Err(format!("counter event {i} ({name}) missing numeric value"));
                }
                counters += 1;
            }
            other => {
                return Err(format!("event {i} ({name}) has unsupported ph {other:?}"));
            }
        }
        // Retire payload rides on the B event's args.
        if ph == "B" && name == "retire" {
            if let Some(args) = o.get("args") {
                lane.retire_e2e_us = args.get("e2e_us").and_then(|v| v.as_f64()).unwrap_or(0.0);
                lane.retire_tokens =
                    args.get("tokens_generated").and_then(|v| v.as_f64()).unwrap_or(0.0);
            }
        }
    }

    let mut retired = 0usize;
    let mut max_account_err = 0.0f64;
    for ((pid, tid), lane) in &lanes {
        if !lane.stack.is_empty() {
            return Err(format!(
                "lane ({pid},{tid}): {} B event(s) without matching E: {:?}",
                lane.stack.len(),
                lane.stack
            ));
        }
        if !lane.retired {
            continue;
        }
        retired += 1;
        // Span accounting, only for completed (token-bearing) requests
        // and only when the ring dropped nothing (a partial window
        // cannot account for full lifecycles).
        if dropped == 0 && lane.retire_tokens > 0.0 && lane.retire_e2e_us > 0.0 {
            let sum = lane.queue_us + lane.prefill_us + lane.decode_us + lane.retire_us;
            let err = (sum - lane.retire_e2e_us).abs();
            if err > ACCOUNT_ABS_FLOOR_US.max(ACCOUNT_REL_TOL * lane.retire_e2e_us) {
                return Err(format!(
                    "lane ({pid},{tid}): lifecycle spans sum to {sum} us but retire recorded \
                     e2e {} us (err {err:.0} us)",
                    lane.retire_e2e_us
                ));
            }
            if lane.retire_e2e_us > 0.0 {
                max_account_err = max_account_err.max(err / lane.retire_e2e_us);
            }
        }
    }

    Ok(TraceSummary {
        events: events.len(),
        spans,
        counters,
        lanes: lanes.len(),
        retired,
        dropped,
        max_account_err,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn span(kind: SpanKind, ts: u64, dur: u64, replica: u32, req: u64, a: u64, b: u64) -> Event {
        Event { ts_us: ts, dur_us: dur, kind, replica, req, a, b }
    }

    fn buf(events: Vec<Event>) -> TraceBuffer {
        let n = events.len() as u64;
        TraceBuffer { events, dropped: 0, recorded: n }
    }

    #[test]
    fn export_roundtrips_through_parser_and_validates() {
        let b = buf(vec![
            span(SpanKind::Queue, 0, 100, 0, 1, 16, 0),
            span(SpanKind::PrefixLookup, 100, 5, 0, 1, 8, 1),
            span(SpanKind::Prefill, 100, 900, 0, 1, 8, 8),
            span(SpanKind::DecodeStep, 1000, 500, 0, 1, 1, 0),
            span(SpanKind::DecodeStep, 1500, 450, 0, 1, 2, 0),
            span(SpanKind::Retire, 1950, 50, 0, 1, 2, 2000),
            span(SpanKind::Evict, 300, 40, 0, NO_REQ, 3, 1),
            span(SpanKind::Route, 0, 30, 0, 1, 1, 0),
        ]);
        let doc = chrome_trace(&b);
        let text = doc.to_string_compact();
        let parsed = json::parse(&text).expect("chrome trace must parse with util::json");
        let s = validate_chrome_trace(&parsed).expect("trace must validate");
        assert_eq!(s.spans, 8);
        assert_eq!(s.retired, 1);
        assert_eq!(s.dropped, 0);
        // queue 100 + prefill 900 + decode 950 + retire 50 == e2e 2000
        assert!(s.max_account_err < 1e-9, "err={}", s.max_account_err);
        // lanes: replica0 maintenance, replica0 req1, router (replica0,req1)
        assert_eq!(s.lanes, 3);
    }

    #[test]
    fn nested_and_zero_duration_spans_stay_well_formed() {
        // prefix_lookup nested in prefill, zero-duration retire at the
        // exact end of the last decode step.
        let b = buf(vec![
            span(SpanKind::Prefill, 100, 900, 0, 7, 10, 0),
            span(SpanKind::PrefixLookup, 100, 0, 0, 7, 0, 0),
            span(SpanKind::Compress, 500, 100, 0, 7, 4, 0),
            span(SpanKind::DecodeStep, 1000, 200, 0, 7, 1, 0),
            span(SpanKind::Retire, 1200, 0, 0, 7, 1, 0),
        ]);
        let doc = chrome_trace(&b);
        let s = validate_chrome_trace(&doc).expect("nested spans must validate");
        assert_eq!(s.spans, 5);
    }

    #[test]
    fn straddling_overlap_is_clamped_not_broken() {
        // A child that extends past its parent's end must be clamped.
        let b = buf(vec![
            span(SpanKind::Prefill, 0, 100, 0, 3, 1, 0),
            span(SpanKind::Compress, 50, 500, 0, 3, 1, 0),
        ]);
        let doc = chrome_trace(&b);
        validate_chrome_trace(&doc).expect("clamped overlap must validate");
    }

    #[test]
    fn validator_rejects_tampered_traces() {
        let b = buf(vec![
            span(SpanKind::Queue, 0, 100, 0, 1, 4, 0),
            span(SpanKind::Prefill, 100, 100, 0, 1, 4, 0),
        ]);
        let good = chrome_trace(&b).to_string_compact();
        // drop one E event -> unbalanced stack
        let tampered = good.replacen("\"ph\":\"E\"", "\"ph\":\"M\"", 1);
        let doc = json::parse(&tampered).unwrap();
        assert!(validate_chrome_trace(&doc).is_err(), "unbalanced B/E must be rejected");
        // non-monotone ts
        let b2 = json::parse(&good.replacen("\"ts\":100", "\"ts\":99999999", 1)).unwrap();
        assert!(validate_chrome_trace(&b2).is_err(), "non-monotone ts must be rejected");
    }

    #[test]
    fn accounting_mismatch_is_rejected() {
        let b = buf(vec![
            span(SpanKind::Queue, 0, 100, 0, 1, 4, 0),
            span(SpanKind::Prefill, 100, 100, 0, 1, 4, 0),
            span(SpanKind::DecodeStep, 200, 100, 0, 1, 1, 0),
            // claims 100 ms e2e but spans only cover ~300 us
            span(SpanKind::Retire, 300, 10, 0, 1, 1, 100_000),
        ]);
        let doc = chrome_trace(&b);
        assert!(validate_chrome_trace(&doc).is_err());
        // the same trace with dropped events is exempt (partial window)
        let mut lossy = buf(b.events.clone());
        lossy.dropped = 5;
        let doc2 = chrome_trace(&lossy);
        validate_chrome_trace(&doc2).expect("lossy traces skip accounting");
    }

    #[test]
    fn counter_events_export_as_c_phase_and_validate() {
        let b = buf(vec![
            span(SpanKind::Queue, 0, 100, 0, 1, 16, 0),
            span(SpanKind::Prefill, 100, 900, 0, 1, 16, 0),
            span(SpanKind::Gauge, 200, 0, 0, NO_REQ, 5, SpanKind::GAUGE_BLOCKS_IN_USE),
            span(SpanKind::Gauge, 200, 0, 0, NO_REQ, 2, SpanKind::GAUGE_IN_FLIGHT),
            span(SpanKind::Gauge, 900, 0, 0, NO_REQ, 7, SpanKind::GAUGE_BLOCKS_IN_USE),
        ]);
        let doc = chrome_trace(&b);
        let text = doc.to_string_compact();
        assert!(text.contains("\"ph\":\"C\""));
        assert!(text.contains("kvpool_blocks_in_use"));
        assert!(text.contains("in_flight_requests"));
        let parsed = json::parse(&text).unwrap();
        let s = validate_chrome_trace(&parsed).expect("counters must validate");
        assert_eq!(s.counters, 3);
        assert_eq!(s.spans, 2);
        // counters live on their own sentinel lane, clear of request ids
        assert!(text.contains(&format!("\"tid\":{GAUGE_TID}")));
    }

    #[test]
    fn quality_and_slo_spans_carry_error_payloads() {
        let b = buf(vec![
            span(SpanKind::Quality, 10, 0, 0, 3, 1_500_000, (1 << 32) | 2),
            span(SpanKind::SloTransition, 20, 0, 0, NO_REQ, 1, 2_000_000),
        ]);
        let doc = chrome_trace(&b);
        let text = doc.to_string_compact();
        assert!(text.contains("\"sample\":\"fold\""));
        assert!(text.contains("\"transition\":\"degrade\""));
        validate_chrome_trace(&doc).expect("quality spans must validate");
    }

    #[test]
    fn rejected_route_goes_to_reject_lane() {
        let b = buf(vec![span(SpanKind::Route, 10, 20, 0, NO_REQ, 2, ROUTE_REJECTED)]);
        let doc = chrome_trace(&b);
        let text = doc.to_string_compact();
        assert!(text.contains("\"outcome\":\"rejected\""));
        let s = validate_chrome_trace(&doc).unwrap();
        assert_eq!(s.lanes, 1);
        assert_eq!(s.retired, 0);
    }
}
