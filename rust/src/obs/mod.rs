//! Observability for the serving stack: request-lifecycle tracing,
//! time-series telemetry, and Prometheus-style exposition.
//!
//! Always compiled, near-free when off: every instrumentation site in
//! the coordinator/kvpool/cluster layers guards on
//! [`trace::enabled`] (one relaxed atomic load) before taking a single
//! timestamp, and the disabled overhead is pinned by the
//! `tracer_record_off` record in the `micro` bench.
//!
//! Three coordinated pieces (formats documented in
//! `docs/OBSERVABILITY.md`):
//!
//! * [`trace`] — a bounded-ring span tracer recording typed lifecycle
//!   spans (`queue`, `prefix_lookup`, `prefill`, `decode_step`,
//!   `compress`, `evict`, `route`, `retire`, `quality`,
//!   `slo_transition`, plus `gauge` counter samples), enabled by
//!   `--trace-json PATH` on `serve`/`cluster`.
//! * [`chrome`] — export of a drained ring to Chrome trace-event JSON
//!   (Perfetto-loadable; pid=replica, tid=request lane, counter samples
//!   as "C" events), plus the [`validate_chrome_trace`]
//!   schema/monotonicity/span-accounting checker used by tests, CI, and
//!   `wildcat obs`.
//! * [`series`] — a periodic sampler writing cumulative
//!   counters/gauges as JSONL (`--metrics-series PATH`,
//!   `--metrics-interval-ms N`), with [`validate_series`]; and
//!   [`prom`], the Prometheus text builder behind
//!   `ServingMetrics::to_prometheus` / `Router::to_prometheus`
//!   (`--prom PATH`).
//! * [`quality`] — the online approximation-quality auditor: seeded
//!   1-in-N sampling of decode steps and compression folds, exact
//!   reference recomputation, error histograms on every export surface,
//!   and an error SLO with adaptive degradation
//!   (`--audit-rate N`, `--audit-slo-abs-err E`).

#![warn(missing_docs)]

pub mod chrome;
pub mod prom;
pub mod quality;
pub mod series;
pub mod trace;

pub use chrome::{chrome_trace, validate_chrome_trace, TraceSummary};
pub use prom::PromBuilder;
pub use quality::{validate_quality_json, QualityAudit, QualityConfig, QualitySnapshot};
pub use series::{validate_series, MetricsSampler, SeriesSummary};
pub use trace::{SpanKind, TraceBuffer, Tracer};

use crate::util::json::Json;
use std::collections::BTreeMap;

/// Self-describing run metadata stamped into the top of
/// `--metrics-json` dumps and the JSONL series header: the command,
/// seed, crate version, wall-clock start, and an echo of the
/// performance-relevant config (`replicas`, `policy`, KV budget,
/// prefill-skip, ...), so dumps are diffable across runs without the
/// invoking command line.
pub fn run_meta(command: &str, seed: u64, config: Vec<(&str, Json)>) -> Json {
    let started_unix_s = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0);
    let mut cfg = BTreeMap::new();
    for (k, v) in config {
        cfg.insert(k.to_string(), v);
    }
    let mut o = BTreeMap::new();
    o.insert("command".to_string(), Json::Str(command.to_string()));
    o.insert("seed".to_string(), Json::Num(seed as f64));
    o.insert(
        "crate_version".to_string(),
        Json::Str(env!("CARGO_PKG_VERSION").to_string()),
    );
    o.insert("started_unix_s".to_string(), Json::Num(started_unix_s));
    o.insert("config".to_string(), Json::Obj(cfg));
    Json::Obj(o)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_meta_is_self_describing() {
        let m = run_meta(
            "cluster",
            7,
            vec![
                ("replicas", Json::Num(4.0)),
                ("policy", Json::Str("jsq".to_string())),
            ],
        );
        assert_eq!(m.get("command").and_then(|v| v.as_str()), Some("cluster"));
        assert_eq!(m.get("seed").and_then(|v| v.as_f64()), Some(7.0));
        assert!(!m.get("crate_version").and_then(|v| v.as_str()).unwrap().is_empty());
        assert!(m.get("started_unix_s").and_then(|v| v.as_f64()).unwrap() > 0.0);
        let cfg = m.get("config").unwrap();
        assert_eq!(cfg.get("replicas").and_then(|v| v.as_f64()), Some(4.0));
        // fixed point through our own parser
        let text = m.to_string_compact();
        assert_eq!(crate::util::json::parse(&text).unwrap(), m);
    }
}
