//! The span/event tracer: a lock-cheap, bounded ring buffer of typed
//! request-lifecycle spans.
//!
//! Recording is guarded by an atomic flag: when tracing is disabled
//! (the default), [`Tracer::record`] is a single relaxed load and a
//! branch, and every instrumentation site in the serving stack checks
//! [`enabled`] *before* taking timestamps — the serving hot path pays
//! one predictable branch per site. When enabled, recording takes a
//! short mutex critical section (a copy into a preallocated ring); the
//! model step it sits next to is milliseconds, so contention is
//! negligible (same locking story as
//! [`crate::coordinator::ServingMetrics`]).
//!
//! The ring is bounded: past capacity the oldest events are dropped
//! first and counted, so a runaway trace degrades to "most recent
//! window" instead of unbounded memory. Export to Chrome trace-event
//! JSON lives in [`crate::obs::chrome`].

use std::cell::Cell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Sentinel request id for events that belong to no request (pool
/// maintenance, router-wide rejections). Mapped to a dedicated lane by
/// the Chrome exporter.
pub const NO_REQ: u64 = u64::MAX;

/// Sentinel for [`SpanKind::Route`] events whose payload `b` (the
/// accepting replica) has no value because every replica refused.
pub const ROUTE_REJECTED: u64 = u64::MAX;

/// The typed request-lifecycle span taxonomy (see
/// `docs/OBSERVABILITY.md` for payload semantics per kind).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// Admission-queue wait: request arrival → prefill start.
    Queue,
    /// Radix prefix-tree match against the KV pool at admission.
    PrefixLookup,
    /// Prompt prefill (including admission-time cache compression).
    Prefill,
    /// One decode token for one sequence: previous token (or prefill
    /// end) → this token emitted, i.e. inter-token latency inclusive of
    /// scheduling interference from batch-mates.
    DecodeStep,
    /// A KV-cache compression of one sequence (admission, decode
    /// high-water, or the pool pressure ladder's compression tier).
    Compress,
    /// One pass of the pool pressure ladder (`kvpool::evict::reclaim`).
    Evict,
    /// Router submission: candidate selection → a replica accepted (or
    /// all refused).
    Route,
    /// Sequence retirement: last decode step → response handed back.
    Retire,
    /// One approximation-quality audit sample (`a` = audited
    /// `max_abs_err` in 1e-9 fixed point, `b` = `kind << 32 | lh` where
    /// kind 0 = decode step, 1 = compression fold).
    Quality,
    /// An error-SLO state transition (`a` = 1 for degrade, 0 for
    /// recover; `b` = the windowed p99 error in 1e-9 fixed point that
    /// triggered it).
    SloTransition,
    /// An instant gauge sample, exported as a Chrome counter ("C")
    /// event (`a` = gauge value, `b` = gauge id: 0 = kvpool blocks in
    /// use, 1 = in-flight requests).
    Gauge,
    /// A request failed over off a dead replica: disconnect observed →
    /// resubmission attempted (`a` = the request's failover ordinal,
    /// `b` = the replica that died; `replica` is the dead replica).
    Failover,
    /// A crashed replica respawned by the pool supervisor (`a` = the
    /// replica's restart ordinal, `b` = in-flight requests failed back
    /// to their waiters).
    Restart,
    /// A circuit-breaker transition on one replica (`a` = the new
    /// state's code: 0 closed, 1 open, 2 half-open; `b` = total failures
    /// observed at that replica so far).
    Breaker,
    /// One evicted block written to the spill tier's cold store by the
    /// writeback thread (`a` = blocks written, `b` = record bytes).
    Spill,
    /// Spilled blocks rematerialised into the pool on a prefix lookup
    /// (`a` = blocks paged in, `b` = tokens they cover).
    PageIn,
}

impl SpanKind {
    /// Every kind, in lifecycle order.
    pub const ALL: [SpanKind; 16] = [
        SpanKind::Queue,
        SpanKind::PrefixLookup,
        SpanKind::Prefill,
        SpanKind::DecodeStep,
        SpanKind::Compress,
        SpanKind::Evict,
        SpanKind::Route,
        SpanKind::Retire,
        SpanKind::Quality,
        SpanKind::SloTransition,
        SpanKind::Gauge,
        SpanKind::Failover,
        SpanKind::Restart,
        SpanKind::Breaker,
        SpanKind::Spill,
        SpanKind::PageIn,
    ];

    /// The canonical snake_case span name used in trace exports.
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Queue => "queue",
            SpanKind::PrefixLookup => "prefix_lookup",
            SpanKind::Prefill => "prefill",
            SpanKind::DecodeStep => "decode_step",
            SpanKind::Compress => "compress",
            SpanKind::Evict => "evict",
            SpanKind::Route => "route",
            SpanKind::Retire => "retire",
            SpanKind::Quality => "quality",
            SpanKind::SloTransition => "slo_transition",
            SpanKind::Gauge => "gauge",
            SpanKind::Failover => "failover",
            SpanKind::Restart => "restart",
            SpanKind::Breaker => "breaker",
            SpanKind::Spill => "spill",
            SpanKind::PageIn => "pagein",
        }
    }

    /// Gauge id for [`SpanKind::Gauge`] events: KV-pool blocks in use.
    pub const GAUGE_BLOCKS_IN_USE: u64 = 0;
    /// Gauge id for [`SpanKind::Gauge`] events: in-flight requests.
    pub const GAUGE_IN_FLIGHT: u64 = 1;

    /// The exported counter name for a gauge id (see
    /// [`SpanKind::Gauge`]).
    pub fn gauge_name(id: u64) -> &'static str {
        match id {
            Self::GAUGE_BLOCKS_IN_USE => "kvpool_blocks_in_use",
            Self::GAUGE_IN_FLIGHT => "in_flight_requests",
            _ => "gauge",
        }
    }
}

/// One recorded span: a fixed-size, `Copy` record so the ring buffer is
/// a flat copy-in/copy-out structure with no per-event allocation.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// Start timestamp, microseconds since the tracer's epoch.
    pub ts_us: u64,
    /// Duration in microseconds (0 for instant events).
    pub dur_us: u64,
    /// The span's lifecycle kind.
    pub kind: SpanKind,
    /// Replica the event was recorded on (thread-local, see
    /// [`set_current_replica`]); for [`SpanKind::Route`] the replica the
    /// request was routed *to*.
    pub replica: u32,
    /// Request/sequence id, or [`NO_REQ`] for maintenance events.
    pub req: u64,
    /// Kind-specific payload (e.g. computed tokens for `prefill`,
    /// matched tokens for `prefix_lookup`, attempts for `route`).
    pub a: u64,
    /// Second kind-specific payload (e.g. skipped tokens for `prefill`,
    /// hit flag for `prefix_lookup`, e2e µs for `retire`).
    pub b: u64,
}

struct Ring {
    buf: VecDeque<Event>,
    cap: usize,
    dropped: u64,
    recorded: u64,
}

/// A drained copy of the ring: events oldest-first plus the loss/volume
/// counters needed to interpret them.
#[derive(Clone, Debug)]
pub struct TraceBuffer {
    /// Retained events, oldest first.
    pub events: Vec<Event>,
    /// Events overwritten because the ring was at capacity (oldest
    /// dropped first).
    pub dropped: u64,
    /// Total events recorded while enabled (`events.len() + dropped`).
    pub recorded: u64,
}

/// The tracer: an enable flag, a shared time epoch, and the ring.
pub struct Tracer {
    enabled: AtomicBool,
    epoch: Instant,
    inner: Mutex<Ring>,
}

/// Default ring capacity (events) for [`global`] and the CLI
/// `--trace-capacity` flag.
pub const DEFAULT_CAPACITY: usize = 65_536;

impl Tracer {
    /// A fresh, *disabled* tracer with the given ring capacity and an
    /// epoch of "now".
    pub fn new(capacity: usize) -> Self {
        Tracer {
            enabled: AtomicBool::new(false),
            epoch: Instant::now(),
            inner: Mutex::new(Ring {
                buf: VecDeque::with_capacity(capacity.max(1)),
                cap: capacity.max(1),
                dropped: 0,
                recorded: 0,
            }),
        }
    }

    /// Whether recording is on. The disabled fast path of every
    /// instrumentation site.
    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Turn recording on or off without touching the ring contents.
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Clear the ring, set its capacity, and enable recording.
    pub fn enable_with_capacity(&self, capacity: usize) {
        {
            let mut g = crate::util::sync::lock_recover(&self.inner);
            g.buf.clear();
            g.cap = capacity.max(1);
            g.dropped = 0;
            g.recorded = 0;
        }
        self.enabled.store(true, Ordering::Relaxed);
    }

    /// Microseconds between the tracer epoch and `t` (0 if `t` predates
    /// the epoch, which only happens for timestamps taken before the
    /// tracer was created).
    pub fn us_of(&self, t: Instant) -> u64 {
        t.checked_duration_since(self.epoch)
            .map(|d| d.as_micros() as u64)
            .unwrap_or(0)
    }

    /// Microseconds since the tracer epoch, now.
    pub fn now_us(&self) -> u64 {
        self.us_of(Instant::now())
    }

    /// Record one event. When disabled this is a relaxed load and a
    /// branch; when enabled, a short lock + ring push (oldest event
    /// dropped and counted at capacity).
    #[inline]
    pub fn record(&self, ev: Event) {
        if !self.enabled.load(Ordering::Relaxed) {
            return;
        }
        let mut g = crate::util::sync::lock_recover(&self.inner);
        if g.buf.len() >= g.cap {
            g.buf.pop_front();
            g.dropped += 1;
        }
        g.buf.push_back(ev);
        g.recorded += 1;
    }

    /// Record a span from a start/end [`Instant`] pair (clamped to the
    /// epoch; `end < start` records a zero-duration span).
    pub fn record_span(
        &self,
        kind: SpanKind,
        start: Instant,
        end: Instant,
        replica: u32,
        req: u64,
        a: u64,
        b: u64,
    ) {
        if !self.is_enabled() {
            return;
        }
        let ts_us = self.us_of(start);
        let dur_us = self.us_of(end).saturating_sub(ts_us);
        self.record(Event { ts_us, dur_us, kind, replica, req, a, b });
    }

    /// `(recorded, dropped)` totals since the last
    /// [`Tracer::enable_with_capacity`]/[`Tracer::drain`].
    pub fn counts(&self) -> (u64, u64) {
        let g = crate::util::sync::lock_recover(&self.inner);
        (g.recorded, g.dropped)
    }

    /// Take every retained event out of the ring (oldest first),
    /// resetting the counters. Recording may continue afterwards.
    pub fn drain(&self) -> TraceBuffer {
        let mut g = crate::util::sync::lock_recover(&self.inner);
        let events: Vec<Event> = g.buf.drain(..).collect();
        let out = TraceBuffer { dropped: g.dropped, recorded: g.recorded, events };
        g.dropped = 0;
        g.recorded = 0;
        out
    }
}

thread_local! {
    static CURRENT_REPLICA: Cell<u32> = const { Cell::new(0) };
}

/// Tag this thread with a replica index: every span recorded through
/// [`span`] from this thread carries it. Called by each replica's server
/// worker at startup.
pub fn set_current_replica(replica: u32) {
    CURRENT_REPLICA.with(|c| c.set(replica));
}

/// The replica index this thread records spans under (0 if never set).
pub fn current_replica() -> u32 {
    CURRENT_REPLICA.with(|c| c.get())
}

static GLOBAL: OnceLock<Tracer> = OnceLock::new();

/// The process-wide tracer every instrumentation site records into.
/// Created disabled with [`DEFAULT_CAPACITY`]; the serving CLIs enable
/// it when `--trace-json` is given.
pub fn global() -> &'static Tracer {
    GLOBAL.get_or_init(|| Tracer::new(DEFAULT_CAPACITY))
}

/// Whether the global tracer is recording. Instrumentation sites check
/// this before taking timestamps so the disabled path never calls
/// `Instant::now()`.
#[inline]
pub fn enabled() -> bool {
    global().is_enabled()
}

/// Record a span on the global tracer under this thread's replica tag.
pub fn span(kind: SpanKind, start: Instant, end: Instant, req: u64, a: u64, b: u64) {
    global().record_span(kind, start, end, current_replica(), req, a, b);
}

/// Record an instant [`SpanKind::Gauge`] sample on the global tracer
/// under this thread's replica tag (`id` is one of the
/// `SpanKind::GAUGE_*` constants, `value` the sampled gauge value).
pub fn gauge(id: u64, value: u64) {
    let t = global();
    if !t.is_enabled() {
        return;
    }
    t.record(Event {
        ts_us: t.now_us(),
        dur_us: 0,
        kind: SpanKind::Gauge,
        replica: current_replica(),
        req: NO_REQ,
        a: value,
        b: id,
    });
}

/// Record a span on the global tracer with an explicit replica (the
/// router runs on caller threads, so its thread-local tag is wrong).
pub fn span_on(
    replica: u32,
    kind: SpanKind,
    start: Instant,
    end: Instant,
    req: u64,
    a: u64,
    b: u64,
) {
    global().record_span(kind, start, end, replica, req, a, b);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn ev(ts: u64) -> Event {
        Event {
            ts_us: ts,
            dur_us: 1,
            kind: SpanKind::DecodeStep,
            replica: 0,
            req: 1,
            a: 0,
            b: 0,
        }
    }

    #[test]
    fn disabled_by_default_records_nothing() {
        let t = Tracer::new(16);
        t.record(ev(1));
        let buf = t.drain();
        assert!(buf.events.is_empty());
        assert_eq!(buf.recorded, 0);
        assert_eq!(buf.dropped, 0);
    }

    #[test]
    fn wraparound_drops_oldest_first() {
        let t = Tracer::new(4);
        t.set_enabled(true);
        for i in 0..10 {
            t.record(ev(i));
        }
        let buf = t.drain();
        assert_eq!(buf.events.len(), 4);
        assert_eq!(buf.dropped, 6);
        assert_eq!(buf.recorded, 10);
        let ts: Vec<u64> = buf.events.iter().map(|e| e.ts_us).collect();
        assert_eq!(ts, vec![6, 7, 8, 9], "oldest events must go first");
    }

    #[test]
    fn span_timestamps_use_epoch_and_clamp() {
        let t = Tracer::new(16);
        t.set_enabled(true);
        let a = Instant::now();
        let b = a + Duration::from_micros(1500);
        t.record_span(SpanKind::Prefill, a, b, 2, 7, 10, 3);
        // end < start clamps to zero duration instead of panicking
        t.record_span(SpanKind::Retire, b, a, 2, 7, 0, 0);
        let buf = t.drain();
        assert_eq!(buf.events.len(), 2);
        let e = &buf.events[0];
        assert_eq!(e.kind, SpanKind::Prefill);
        assert_eq!(e.replica, 2);
        assert_eq!(e.req, 7);
        assert!(e.dur_us >= 1400 && e.dur_us <= 1600, "dur={}", e.dur_us);
        assert_eq!(buf.events[1].dur_us, 0);
    }

    #[test]
    fn enable_with_capacity_resets() {
        let t = Tracer::new(2);
        t.set_enabled(true);
        for i in 0..5 {
            t.record(ev(i));
        }
        t.enable_with_capacity(8);
        assert!(t.is_enabled());
        t.record(ev(42));
        let buf = t.drain();
        assert_eq!(buf.events.len(), 1);
        assert_eq!(buf.dropped, 0, "enable_with_capacity must reset drop counts");
        assert_eq!(buf.events[0].ts_us, 42);
    }

    #[test]
    fn replica_tag_is_thread_local() {
        set_current_replica(3);
        assert_eq!(current_replica(), 3);
        let h = std::thread::spawn(|| current_replica());
        assert_eq!(h.join().unwrap(), 0, "fresh threads default to replica 0");
        assert_eq!(current_replica(), 3);
        set_current_replica(0);
    }
}
