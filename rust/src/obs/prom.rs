//! Prometheus text-exposition builder (format version 0.0.4): the
//! scrape-style counterpart of the JSON snapshots, so metrics dumps can
//! be pointed at any Prometheus-compatible collector or diffed as
//! plain text.
//!
//! [`PromBuilder`] accumulates `# HELP`/`# TYPE` headers (emitted once
//! per metric, on first use) and labeled samples;
//! [`crate::coordinator::ServingMetrics::prom_write`] and
//! [`crate::cluster::Router::to_prometheus`] drive it.

use std::collections::BTreeSet;
use std::fmt::Write as _;

/// Incremental builder for one Prometheus text exposition document.
#[derive(Default)]
pub struct PromBuilder {
    out: String,
    declared: BTreeSet<String>,
}

fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

impl PromBuilder {
    /// An empty document.
    pub fn new() -> Self {
        PromBuilder::default()
    }

    /// Declare a metric's `# HELP` and `# TYPE` lines. Idempotent per
    /// metric name, so per-replica loops can declare unconditionally.
    pub fn declare(&mut self, name: &str, mtype: &str, help: &str) {
        if self.declared.insert(name.to_string()) {
            let _ = writeln!(self.out, "# HELP {name} {help}");
            let _ = writeln!(self.out, "# TYPE {name} {mtype}");
        }
    }

    /// Append one sample line: `name{labels} value`. Non-finite values
    /// are clamped to 0 (empty-histogram quantiles), integral values
    /// print without a fraction.
    pub fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.out.push_str(name);
        if !labels.is_empty() {
            self.out.push('{');
            for (i, (k, v)) in labels.iter().enumerate() {
                if i > 0 {
                    self.out.push(',');
                }
                let _ = write!(self.out, "{k}=\"{}\"", escape_label(v));
            }
            self.out.push('}');
        }
        let v = if value.is_finite() { value } else { 0.0 };
        if v.fract() == 0.0 && v.abs() < 1e15 {
            let _ = writeln!(self.out, " {}", v as i64);
        } else {
            let _ = writeln!(self.out, " {v}");
        }
    }

    /// Emit one full Prometheus `histogram` family from cumulative
    /// buckets: `name_bucket{le="..."}` lines (including the mandatory
    /// `le="+Inf"` terminal bucket equal to the total count), `name_sum`
    /// and `name_count`. `buckets` are `(upper_edge, cumulative_count)`
    /// pairs as produced by
    /// [`crate::util::stats::LogHistogram::cumulative_buckets`];
    /// `scale` converts edges and the sum into the exported unit (e.g.
    /// `1e-3` for µs → ms).
    pub fn histogram(
        &mut self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        buckets: &[(f64, u64)],
        sum: f64,
        count: u64,
        scale: f64,
    ) {
        self.declare(name, "histogram", help);
        let bucket_name = format!("{name}_bucket");
        for &(edge, c) in buckets {
            let mut ls = labels.to_vec();
            let le = format!("{}", edge * scale);
            ls.push(("le", &le));
            self.sample(&bucket_name, &ls, c as f64);
        }
        let mut ls = labels.to_vec();
        ls.push(("le", "+Inf"));
        self.sample(&bucket_name, &ls, count as f64);
        self.sample(&format!("{name}_sum"), labels, sum * scale);
        self.sample(&format!("{name}_count"), labels, count as f64);
    }

    /// The finished exposition text.
    pub fn finish(self) -> String {
        self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn declares_once_and_formats_samples() {
        let mut b = PromBuilder::new();
        b.declare("wildcat_requests_total", "counter", "Requests routed.");
        b.sample("wildcat_requests_total", &[("replica", "0")], 42.0);
        b.declare("wildcat_requests_total", "counter", "Requests routed.");
        b.sample("wildcat_requests_total", &[("replica", "1")], 7.0);
        b.declare("wildcat_up", "gauge", "Liveness.");
        b.sample("wildcat_up", &[], 1.5);
        let text = b.finish();
        assert_eq!(text.matches("# HELP wildcat_requests_total").count(), 1);
        assert_eq!(text.matches("# TYPE wildcat_requests_total counter").count(), 1);
        assert!(text.contains("wildcat_requests_total{replica=\"0\"} 42\n"));
        assert!(text.contains("wildcat_requests_total{replica=\"1\"} 7\n"));
        assert!(text.contains("wildcat_up 1.5\n"));
    }

    #[test]
    fn histogram_family_is_cumulative_with_inf_bucket() {
        let mut b = PromBuilder::new();
        let buckets = [(1000.0, 1), (2000.0, 3), (4000.0, 4)];
        b.histogram("wildcat_lat_ms", "Latency.", &[], &buckets, 7000.0, 4, 1e-3);
        let text = b.finish();
        assert!(text.contains("# TYPE wildcat_lat_ms histogram"));
        assert!(text.contains("wildcat_lat_ms_bucket{le=\"1\"} 1\n"));
        assert!(text.contains("wildcat_lat_ms_bucket{le=\"2\"} 3\n"));
        assert!(text.contains("wildcat_lat_ms_bucket{le=\"+Inf\"} 4\n"));
        assert!(text.contains("wildcat_lat_ms_sum 7\n"));
        assert!(text.contains("wildcat_lat_ms_count 4\n"));
    }

    #[test]
    fn escapes_and_clamps() {
        let mut b = PromBuilder::new();
        b.sample("m", &[("k", "a\"b\\c\nd")], f64::NAN);
        let text = b.finish();
        assert_eq!(text, "m{k=\"a\\\"b\\\\c\\nd\"} 0\n");
    }
}
