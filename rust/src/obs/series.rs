//! Periodic time-series sampler: snapshots a metrics closure onto JSONL
//! at a fixed interval, replacing the dump-once-at-exit model.
//!
//! File format (`wildcat.series.v1`): the first line is a header object
//! carrying `schema`, `interval_ms`, and the self-describing `run`
//! metadata from [`crate::obs::run_meta`]; every following line is one
//! sample — `{"i": <index>, "t_s": <seconds since start>, ...}` merged
//! with whatever object the snapshot closure returned (cumulative
//! counters, KV gauges, queue depths). A final sample is always written
//! at [`MetricsSampler::stop`], so the last line's cumulative counters
//! equal the end-of-run `--metrics-json` snapshot.

use std::fs::File;
use std::io::{BufWriter, Write as _};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// Schema tag written into every series header.
pub const SERIES_SCHEMA: &str = "wildcat.series.v1";

/// Handle to a running sampler thread; call [`MetricsSampler::stop`] to
/// flush the final sample and join.
pub struct MetricsSampler {
    stop: Arc<AtomicBool>,
    worker: Option<JoinHandle<std::io::Result<u64>>>,
}

impl MetricsSampler {
    /// Write the header line and start sampling `snap()` onto `path`
    /// every `interval` until [`MetricsSampler::stop`]. The closure runs
    /// on the sampler thread, so it must only touch shared handles
    /// (metric structs are internally synchronized).
    pub fn start<P, F>(path: P, run: Json, interval: Duration, snap: F) -> Result<MetricsSampler>
    where
        P: AsRef<Path>,
        F: Fn() -> Json + Send + 'static,
    {
        let path = path.as_ref();
        let file = File::create(path)
            .with_context(|| format!("creating metrics series {}", path.display()))?;
        let mut out = BufWriter::new(file);

        let interval = interval.max(Duration::from_millis(1));
        let mut header = std::collections::BTreeMap::new();
        header.insert("schema".to_string(), Json::Str(SERIES_SCHEMA.to_string()));
        header.insert("interval_ms".to_string(), Json::Num(interval.as_secs_f64() * 1e3));
        header.insert("run".to_string(), run);
        writeln!(out, "{}", Json::Obj(header).to_string_compact())
            .with_context(|| format!("writing series header to {}", path.display()))?;

        let stop = Arc::new(AtomicBool::new(false));
        let stop_w = Arc::clone(&stop);
        let worker = std::thread::Builder::new()
            .name("wildcat-metrics-sampler".to_string())
            .spawn(move || -> std::io::Result<u64> {
                let epoch = Instant::now();
                // Sleep in short slices so stop() returns promptly even
                // with long sampling intervals.
                let slice = Duration::from_millis(20).min(interval);
                let mut i = 0u64;
                loop {
                    let mut waited = Duration::ZERO;
                    while waited < interval && !stop_w.load(Ordering::Relaxed) {
                        let nap = slice.min(interval - waited);
                        std::thread::sleep(nap);
                        waited += nap;
                    }
                    // On stop this is the final, end-of-run sample.
                    let mut line = std::collections::BTreeMap::new();
                    line.insert("i".to_string(), Json::Num(i as f64));
                    line.insert(
                        "t_s".to_string(),
                        Json::Num(epoch.elapsed().as_secs_f64()),
                    );
                    match snap() {
                        Json::Obj(o) => {
                            for (k, v) in o {
                                line.entry(k).or_insert(v);
                            }
                        }
                        other => {
                            line.insert("metrics".to_string(), other);
                        }
                    }
                    writeln!(out, "{}", Json::Obj(line).to_string_compact())?;
                    i += 1;
                    if stop_w.load(Ordering::Relaxed) {
                        break;
                    }
                }
                out.flush()?;
                Ok(i)
            })
            .context("spawning metrics sampler thread")?;

        Ok(MetricsSampler { stop, worker: Some(worker) })
    }

    /// Signal the sampler, wait for it to write the final sample, and
    /// return how many samples were written.
    pub fn stop(mut self) -> Result<u64> {
        self.stop.store(true, Ordering::Relaxed);
        let worker = self.worker.take().expect("stop called once");
        let n = worker
            .join()
            .map_err(|_| anyhow!("metrics sampler thread panicked"))?
            .context("writing metrics series")?;
        Ok(n)
    }
}

impl Drop for MetricsSampler {
    fn drop(&mut self) {
        // If stop() was never called, still shut the thread down.
        self.stop.store(true, Ordering::Relaxed);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Summary returned by [`validate_series`].
#[derive(Clone, Copy, Debug)]
pub struct SeriesSummary {
    /// Number of sample lines (excluding the header).
    pub samples: usize,
    /// `interval_ms` from the header.
    pub interval_ms: f64,
}

/// Validate a JSONL metrics series: a `wildcat.series.v1` header with
/// `run` metadata, then ≥ 1 sample line, each a JSON object with a
/// consecutive `i` index and non-decreasing `t_s`.
pub fn validate_series(text: &str) -> Result<SeriesSummary, String> {
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let header_line = lines.next().ok_or("series is empty")?;
    let header = crate::util::json::parse(header_line).map_err(|e| format!("header: {e}"))?;
    let schema = header.get("schema").and_then(|v| v.as_str()).unwrap_or("");
    if schema != SERIES_SCHEMA {
        return Err(format!("header schema {schema:?}, want {SERIES_SCHEMA:?}"));
    }
    let interval_ms = header
        .get("interval_ms")
        .and_then(|v| v.as_f64())
        .ok_or("header missing interval_ms")?;
    let run = header.get("run").and_then(|v| v.as_obj()).ok_or("header missing run metadata")?;
    for key in ["command", "seed", "crate_version", "started_unix_s", "config"] {
        if !run.contains_key(key) {
            return Err(format!("run metadata missing {key:?}"));
        }
    }

    let mut samples = 0usize;
    let mut last_t = f64::NEG_INFINITY;
    for (n, line) in lines.enumerate() {
        let v = crate::util::json::parse(line).map_err(|e| format!("sample {n}: {e}"))?;
        let i = v
            .get("i")
            .and_then(|x| x.as_f64())
            .ok_or_else(|| format!("sample {n} missing i"))?;
        if i as usize != n {
            return Err(format!("sample {n} has index {i}, want {n}"));
        }
        let t = v
            .get("t_s")
            .and_then(|x| x.as_f64())
            .ok_or_else(|| format!("sample {n} missing t_s"))?;
        if t < last_t {
            return Err(format!("sample {n}: t_s {t} decreased (prev {last_t})"));
        }
        last_t = t;
        samples += 1;
    }
    if samples == 0 {
        return Err("series has a header but no samples".to_string());
    }
    Ok(SeriesSummary { samples, interval_ms })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::run_meta;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn sampler_writes_header_and_final_sample() {
        let dir = std::env::temp_dir().join("wildcat_obs_series_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("s.jsonl");
        let counter = Arc::new(AtomicU64::new(0));
        let c = Arc::clone(&counter);
        let run = run_meta("test", 42, vec![("replicas", Json::Num(1.0))]);
        let sampler = MetricsSampler::start(&path, run, Duration::from_millis(10), move || {
            let mut o = std::collections::BTreeMap::new();
            o.insert(
                "completed".to_string(),
                Json::Num(c.fetch_add(1, Ordering::Relaxed) as f64),
            );
            Json::Obj(o)
        })
        .unwrap();
        std::thread::sleep(Duration::from_millis(60));
        let n = sampler.stop().unwrap();
        assert!(n >= 1, "at least the final sample must be written");

        let text = std::fs::read_to_string(&path).unwrap();
        let summary = validate_series(&text).expect("series must validate");
        assert_eq!(summary.samples as u64, n);
        assert!((summary.interval_ms - 10.0).abs() < 1e-9);

        // final line carries the last snapshot value
        let last = text.lines().filter(|l| !l.trim().is_empty()).last().unwrap();
        let v = crate::util::json::parse(last).unwrap();
        assert_eq!(v.get("completed").and_then(|x| x.as_f64()), Some((n - 1) as f64));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn validator_rejects_bad_series() {
        assert!(validate_series("").is_err());
        assert!(validate_series("{\"schema\":\"nope\"}\n").is_err());
        let hdr = format!(
            "{}\n",
            {
                let mut h = std::collections::BTreeMap::new();
                h.insert("schema".to_string(), Json::Str(SERIES_SCHEMA.to_string()));
                h.insert("interval_ms".to_string(), Json::Num(50.0));
                h.insert("run".to_string(), run_meta("t", 1, vec![]));
                Json::Obj(h).to_string_compact()
            }
        );
        // header but no samples
        assert!(validate_series(&hdr).is_err());
        // good single sample
        let good = format!("{hdr}{{\"i\":0,\"t_s\":0.5}}\n");
        assert!(validate_series(&good).is_ok());
        // index gap
        let gap = format!("{hdr}{{\"i\":1,\"t_s\":0.5}}\n");
        assert!(validate_series(&gap).is_err());
        // time going backwards
        let back = format!("{hdr}{{\"i\":0,\"t_s\":2.0}}\n{{\"i\":1,\"t_s\":1.0}}\n");
        assert!(validate_series(&back).is_err());
    }
}
