//! The error-SLO state machine: a rolling window of audited errors,
//! a degrade threshold on the windowed p99, and hysteresis on the way
//! back (recovery requires the window p99 to fall well below the
//! threshold, so the ladder does not flap at the boundary).

use std::collections::VecDeque;

/// Rolling window length (audited samples) the SLO p99 is computed over.
pub const WINDOW: usize = 64;

/// Minimum audited samples in the window before the SLO can trip —
/// a p99 over a handful of samples is just the max.
pub const MIN_SAMPLES: usize = 16;

/// Recovery hysteresis: the windowed p99 must fall below
/// `threshold * RECOVER_FRACTION` before the degraded state clears.
pub const RECOVER_FRACTION: f64 = 0.5;

/// A state transition decided by [`SloState::observe`], carrying the
/// windowed p99 error that triggered it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Transition {
    /// The windowed p99 breached the SLO: degrade approximation depth.
    Degrade(f64),
    /// The windowed p99 fell below the hysteresis band: recover.
    Recover(f64),
}

/// Rolling-window SLO evaluator. Pure state machine — the caller owns
/// the degraded flag and the observable side effects (tracer span,
/// counters, ladder gating).
#[derive(Debug)]
pub struct SloState {
    threshold: f64,
    window: VecDeque<f64>,
}

impl SloState {
    /// A fresh evaluator; `threshold <= 0` disables the SLO entirely.
    pub fn new(threshold: f64) -> Self {
        SloState { threshold, window: VecDeque::with_capacity(WINDOW) }
    }

    /// Whether an SLO threshold is configured.
    pub fn active(&self) -> bool {
        self.threshold > 0.0
    }

    /// The windowed p99 (nearest-rank over the rolling window), or 0
    /// when empty.
    pub fn window_p99(&self) -> f64 {
        if self.window.is_empty() {
            return 0.0;
        }
        let mut v: Vec<f64> = self.window.iter().copied().collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let rank = ((0.99 * v.len() as f64).ceil() as usize).clamp(1, v.len());
        v[rank - 1]
    }

    /// Feed one audited error; decide whether the caller must transition
    /// given its current `degraded` state.
    pub fn observe(&mut self, err: f64, degraded: bool) -> Option<Transition> {
        if !self.active() {
            return None;
        }
        if self.window.len() >= WINDOW {
            self.window.pop_front();
        }
        self.window.push_back(err);
        if self.window.len() < MIN_SAMPLES {
            return None;
        }
        let p99 = self.window_p99();
        if !degraded && p99 > self.threshold {
            Some(Transition::Degrade(p99))
        } else if degraded && p99 < self.threshold * RECOVER_FRACTION {
            Some(Transition::Recover(p99))
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inactive_threshold_never_transitions() {
        let mut s = SloState::new(0.0);
        for _ in 0..200 {
            assert_eq!(s.observe(1e9, false), None);
        }
    }

    #[test]
    fn degrades_once_then_recovers_with_hysteresis() {
        let mut s = SloState::new(1e-3);
        let mut degraded = false;
        let mut degrades = 0;
        let mut recovers = 0;
        // high errors: exactly one degrade despite many breaching samples
        for _ in 0..100 {
            match s.observe(5e-3, degraded) {
                Some(Transition::Degrade(p)) => {
                    assert!(p > 1e-3);
                    degraded = true;
                    degrades += 1;
                }
                Some(Transition::Recover(_)) => recovers += 1,
                None => {}
            }
        }
        assert_eq!((degrades, recovers), (1, 0));
        // errors just below the threshold: hysteresis holds the degraded
        // state (p99 must fall below threshold/2)
        for _ in 0..WINDOW {
            assert_eq!(s.observe(0.9e-3, degraded), None);
        }
        // genuinely low errors: one recovery once the window drains
        for _ in 0..WINDOW {
            if let Some(Transition::Recover(p)) = s.observe(1e-5, degraded) {
                assert!(p < 0.5e-3);
                degraded = false;
                recovers += 1;
            }
        }
        assert_eq!((degrades, recovers), (1, 1));
    }

    #[test]
    fn needs_min_samples_before_tripping() {
        let mut s = SloState::new(1e-6);
        for i in 0..MIN_SAMPLES - 1 {
            assert_eq!(s.observe(1.0, false), None, "tripped at sample {i}");
        }
        assert!(matches!(s.observe(1.0, false), Some(Transition::Degrade(_))));
    }
}
