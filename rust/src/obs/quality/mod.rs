//! Online approximation-quality auditing: seeded sampling of live work,
//! exact-reference recomputation, error histograms, and an error SLO
//! with adaptive degradation.
//!
//! WildCat's claim is *bounded* error, not just speed — this module
//! makes the bound observable in production. A [`QualityAudit`] is
//! shared (one per replica) between the scheduler, the KV pool, and the
//! metrics sink:
//!
//! * **Sampling** is deterministic: a splitmix hash of `(seed, site)`
//!   modulo `--audit-rate` picks 1-in-N requests (whose decode steps
//!   are then audited against a shadow uncompressed KV cache) and
//!   1-in-N compression folds (audited at fold time, where the
//!   pre-fold rows still exist). Same seed ⇒ same sites ⇒ same errors.
//! * **Errors** (`max_abs_err`, relative Frobenius) feed per-layer/head
//!   and global [`LogHistogram`]s, exported through the Prometheus,
//!   JSON-series, metrics-JSON, and Chrome-trace surfaces.
//! * **The SLO** (`--audit-slo-abs-err`) watches the windowed p99 in
//!   [`slo`]: on breach the serving stack degrades gracefully (the
//!   scheduler raises its coreset budget, the kvpool pressure ladder
//!   pauses its compression rung) and recovers with hysteresis; every
//!   transition is a tracer span and a counter.
//!
//! All audit computation happens off the request's critical result path:
//! sampled sites recompute references *after* the served output is
//! already decided, so audits never perturb served tokens.

pub mod slo;

use crate::attention::{wtd_attention, ClipRange};
use crate::kvcache::KvEntry;
use crate::linalg::Matrix;
use crate::rng::Rng;
use crate::util::json::Json;
use crate::util::stats::LogHistogram;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use super::trace::{self, Event, SpanKind, NO_REQ};

/// Audit configuration (CLI surface: `--audit-rate`,
/// `--audit-slo-abs-err`, and the run seed).
#[derive(Clone, Debug, Default)]
pub struct QualityConfig {
    /// Sample 1-in-`rate` requests and compression folds; 0 disables
    /// auditing entirely (no shadow state, no metrics).
    pub rate: u32,
    /// Degrade when the windowed p99 audited `max_abs_err` exceeds this;
    /// `<= 0` disables the SLO (auditing still measures).
    pub slo_abs_err: f64,
    /// Seed for the deterministic site sampler and probe queries.
    pub seed: u64,
}

/// Sample-site kind tag carried in [`SpanKind::Quality`] payloads.
pub const SAMPLE_DECODE: u64 = 0;
/// Sample-site kind tag for compression-fold audits.
pub const SAMPLE_FOLD: u64 = 1;

/// Number of deterministic probe queries a fold audit attends with.
pub const FOLD_PROBES: usize = 4;

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

fn err_fixed(err: f64) -> u64 {
    let f = (err * 1e9).round();
    if f >= u64::MAX as f64 {
        u64::MAX
    } else {
        f.max(0.0) as u64
    }
}

struct State {
    audited_decode: u64,
    audited_folds: u64,
    degradations: u64,
    recoveries: u64,
    max_err_seen: f64,
    err: LogHistogram,
    rel: LogHistogram,
    per_lh: BTreeMap<usize, LogHistogram>,
    slo: slo::SloState,
}

fn err_histogram() -> LogHistogram {
    // 1e-9 … ~1e10 in ×2 buckets: audited attention errors live well
    // inside this span, and sub-nanoscale errors fold into underflow.
    LogHistogram::new(1e-9, 2.0, 64)
}

/// The per-replica audit sink: deterministic samplers, error
/// histograms, and the SLO state machine. Shared by the scheduler, the
/// KV pool (fold audits + ladder gating), and the metrics sink
/// (export).
pub struct QualityAudit {
    cfg: QualityConfig,
    degraded: AtomicBool,
    inner: Mutex<State>,
}

impl QualityAudit {
    /// A fresh audit sink for one replica.
    pub fn new(cfg: QualityConfig) -> Self {
        let slo = slo::SloState::new(cfg.slo_abs_err);
        QualityAudit {
            cfg,
            degraded: AtomicBool::new(false),
            inner: Mutex::new(State {
                audited_decode: 0,
                audited_folds: 0,
                degradations: 0,
                recoveries: 0,
                max_err_seen: 0.0,
                err: err_histogram(),
                rel: err_histogram(),
                per_lh: BTreeMap::new(),
                slo,
            }),
        }
    }

    /// The configuration this sink was built with.
    pub fn config(&self) -> &QualityConfig {
        &self.cfg
    }

    /// Whether auditing is on at all (`rate > 0`).
    pub fn enabled(&self) -> bool {
        self.cfg.rate > 0
    }

    /// Deterministic request sampler: `true` for 1-in-`rate` request
    /// ids (every decode step of a sampled request is audited).
    pub fn audit_request(&self, req: u64) -> bool {
        self.cfg.rate > 0
            && splitmix64(self.cfg.seed ^ req.wrapping_mul(0x9E37_79B9_7F4A_7C15))
                % self.cfg.rate as u64
                == 0
    }

    /// Deterministic fold sampler: `true` for 1-in-`rate`
    /// (sequence, fold-index) compression sites.
    pub fn audit_fold(&self, seq: u64, fold: u64) -> bool {
        self.cfg.rate > 0
            && splitmix64(
                self.cfg.seed
                    ^ seq.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                    ^ fold.wrapping_mul(0xBF58_476D_1CE4_E5B9),
            ) % self.cfg.rate as u64
                == 0
    }

    /// Whether the SLO state machine currently holds the stack degraded
    /// (scheduler: raised coreset budget; kvpool ladder: compression
    /// rung paused). A relaxed load — polled from hot paths.
    #[inline]
    pub fn is_degraded(&self) -> bool {
        self.degraded.load(Ordering::Relaxed)
    }

    /// Record one audited decode step: per-(layer, head) error pairs
    /// `(lh, max_abs_err, rel_fro_err)` against the shadow exact cache.
    pub fn observe_decode(&self, req: u64, errs: &[(usize, f64, f64)]) {
        let mut g = self.inner.lock().unwrap();
        g.audited_decode += 1;
        let (site_err, site_lh) = Self::record_errs(&mut g, errs);
        drop(g);
        self.emit_quality_span(req, site_err, SAMPLE_DECODE, site_lh);
        self.run_slo(site_err);
    }

    /// Record one audited compression fold's error against the
    /// uncompressed rows it replaced.
    pub fn observe_fold(&self, seq: u64, lh: usize, max_abs: f64, rel: f64) {
        let mut g = self.inner.lock().unwrap();
        g.audited_folds += 1;
        Self::record_errs(&mut g, &[(lh, max_abs, rel)]);
        drop(g);
        self.emit_quality_span(seq, max_abs, SAMPLE_FOLD, lh);
        self.run_slo(max_abs);
    }

    /// Record error pairs into the histograms; returns the site-level
    /// (max error, argmax layer-head).
    fn record_errs(g: &mut State, errs: &[(usize, f64, f64)]) -> (f64, usize) {
        let mut site_err = 0.0f64;
        let mut site_rel = 0.0f64;
        let mut site_lh = 0usize;
        for &(lh, max_abs, rel) in errs {
            g.per_lh.entry(lh).or_insert_with(err_histogram).record(max_abs);
            if max_abs >= site_err {
                site_err = max_abs;
                site_lh = lh;
            }
            site_rel = site_rel.max(rel);
        }
        g.err.record(site_err);
        g.rel.record(site_rel);
        g.max_err_seen = g.max_err_seen.max(site_err);
        (site_err, site_lh)
    }

    fn emit_quality_span(&self, req: u64, err: f64, kind_id: u64, lh: usize) {
        let t = trace::global();
        if !t.is_enabled() {
            return;
        }
        t.record(Event {
            ts_us: t.now_us(),
            dur_us: 0,
            kind: SpanKind::Quality,
            replica: trace::current_replica(),
            req,
            a: err_fixed(err),
            b: (kind_id << 32) | lh as u64,
        });
    }

    /// Feed the SLO state machine and apply/record any transition.
    fn run_slo(&self, err: f64) {
        let mut g = self.inner.lock().unwrap();
        let degraded = self.is_degraded();
        let Some(t) = g.slo.observe(err, degraded) else { return };
        let (to_degraded, p99) = match t {
            slo::Transition::Degrade(p) => (true, p),
            slo::Transition::Recover(p) => (false, p),
        };
        self.degraded.store(to_degraded, Ordering::Relaxed);
        if to_degraded {
            g.degradations += 1;
        } else {
            g.recoveries += 1;
        }
        drop(g);
        let t = trace::global();
        if t.is_enabled() {
            t.record(Event {
                ts_us: t.now_us(),
                dur_us: 0,
                kind: SpanKind::SloTransition,
                replica: trace::current_replica(),
                req: NO_REQ,
                a: u64::from(to_degraded),
                b: err_fixed(p99),
            });
        }
    }

    /// A consistent point-in-time copy of every exported audit statistic.
    pub fn snapshot(&self) -> QualitySnapshot {
        let g = self.inner.lock().unwrap();
        let quantile = |h: &LogHistogram, q: f64| if h.total() == 0 { 0.0 } else { h.quantile(q) };
        QualitySnapshot {
            rate: self.cfg.rate,
            slo_abs_err: self.cfg.slo_abs_err,
            audited_decode: g.audited_decode,
            audited_folds: g.audited_folds,
            err_p50: if g.max_err_seen == 0.0 { 0.0 } else { quantile(&g.err, 0.5) },
            err_p99: if g.max_err_seen == 0.0 { 0.0 } else { quantile(&g.err, 0.99) },
            err_max: g.max_err_seen,
            rel_p99: quantile(&g.rel, 0.99),
            degraded: self.is_degraded(),
            degradations: g.degradations,
            recoveries: g.recoveries,
            err_buckets: g.err.cumulative_buckets(),
            err_sum: g.err.sum(),
            err_count: g.err.total(),
            per_lh_p99: g
                .per_lh
                .iter()
                .map(|(&lh, h)| (lh, quantile(h, 0.99), h.total()))
                .collect(),
        }
    }
}

/// Plain-number snapshot of a [`QualityAudit`], the unit every export
/// surface (JSON, Prometheus, report text) renders from — so all
/// surfaces show the same values.
#[derive(Clone, Debug)]
pub struct QualitySnapshot {
    /// Configured 1-in-N sample rate.
    pub rate: u32,
    /// Configured SLO threshold (0 = off).
    pub slo_abs_err: f64,
    /// Audited decode-step samples.
    pub audited_decode: u64,
    /// Audited compression folds.
    pub audited_folds: u64,
    /// p50 of audited `max_abs_err` (0 when every sample was exact).
    pub err_p50: f64,
    /// p99 of audited `max_abs_err` (0 when every sample was exact).
    pub err_p99: f64,
    /// Largest audited `max_abs_err` seen (exact, not bucketed —
    /// identically 0.0 on the exact path).
    pub err_max: f64,
    /// p99 of audited relative Frobenius error.
    pub rel_p99: f64,
    /// Whether the SLO currently holds the stack degraded.
    pub degraded: bool,
    /// SLO degrade transitions since start.
    pub degradations: u64,
    /// SLO recover transitions since start.
    pub recoveries: u64,
    /// Cumulative histogram buckets of audited `max_abs_err`.
    pub err_buckets: Vec<(f64, u64)>,
    /// Sum of audited `max_abs_err` (Prometheus histogram `_sum`).
    pub err_sum: f64,
    /// Audited sample count (Prometheus histogram `_count`).
    pub err_count: u64,
    /// Per-(layer, head) `(lh, p99 max_abs_err, samples)` rows.
    pub per_lh_p99: Vec<(usize, f64, u64)>,
}

impl QualitySnapshot {
    /// Total audited samples across site kinds.
    pub fn audited_total(&self) -> u64 {
        self.audited_decode + self.audited_folds
    }

    /// The JSON block exported under `"quality"` in metrics snapshots
    /// (and therefore in every `--metrics-series` sample).
    pub fn to_json(&self) -> Json {
        let num = |x: f64| Json::Num(if x.is_finite() { x } else { 0.0 });
        let mut o = BTreeMap::new();
        o.insert("audit_rate".to_string(), Json::Num(self.rate as f64));
        o.insert("slo_abs_err".to_string(), num(self.slo_abs_err));
        o.insert("audited_decode_samples".to_string(), Json::Num(self.audited_decode as f64));
        o.insert("audited_folds".to_string(), Json::Num(self.audited_folds as f64));
        o.insert("audited_samples".to_string(), Json::Num(self.audited_total() as f64));
        o.insert("max_abs_err_p50".to_string(), num(self.err_p50));
        o.insert("max_abs_err_p99".to_string(), num(self.err_p99));
        o.insert("max_abs_err_max".to_string(), num(self.err_max));
        o.insert("rel_fro_err_p99".to_string(), num(self.rel_p99));
        o.insert("degraded".to_string(), Json::Bool(self.degraded));
        o.insert("slo_degradations".to_string(), Json::Num(self.degradations as f64));
        o.insert("slo_recoveries".to_string(), Json::Num(self.recoveries as f64));
        let mut lh = BTreeMap::new();
        for &(i, p99, n) in &self.per_lh_p99 {
            let mut row = BTreeMap::new();
            row.insert("max_abs_err_p99".to_string(), num(p99));
            row.insert("samples".to_string(), Json::Num(n as f64));
            lh.insert(format!("lh{i}"), Json::Obj(row));
        }
        o.insert("per_lh".to_string(), Json::Obj(lh));
        Json::Obj(o)
    }

    /// Write the Prometheus samples for this snapshot (the quality slice
    /// of `ServingMetrics::prom_write`).
    pub fn prom_write(&self, b: &mut super::PromBuilder, labels: &[(&str, &str)]) {
        b.declare(
            "wildcat_quality_audited_samples_total",
            "counter",
            "Approximation-quality audit samples by site kind.",
        );
        for (kind, v) in [("decode", self.audited_decode), ("fold", self.audited_folds)] {
            let mut ls = labels.to_vec();
            ls.push(("kind", kind));
            b.sample("wildcat_quality_audited_samples_total", &ls, v as f64);
        }
        b.declare(
            "wildcat_quality_max_abs_err",
            "gauge",
            "Audited max-abs attention error quantiles (vs exact reference).",
        );
        for (q, v) in [("0.5", self.err_p50), ("0.99", self.err_p99), ("max", self.err_max)] {
            let mut ls = labels.to_vec();
            ls.push(("quantile", q));
            b.sample("wildcat_quality_max_abs_err", &ls, v);
        }
        b.declare(
            "wildcat_quality_rel_fro_err",
            "gauge",
            "Audited relative Frobenius error quantiles (vs exact reference).",
        );
        {
            let mut ls = labels.to_vec();
            ls.push(("quantile", "0.99"));
            b.sample("wildcat_quality_rel_fro_err", &ls, self.rel_p99);
        }
        b.histogram(
            "wildcat_quality_max_abs_err_hist",
            "Distribution of audited max-abs attention error.",
            labels,
            &self.err_buckets,
            self.err_sum,
            self.err_count,
            1.0,
        );
        b.declare(
            "wildcat_quality_slo_transitions_total",
            "counter",
            "Error-SLO state transitions.",
        );
        for (t, v) in [("degrade", self.degradations), ("recover", self.recoveries)] {
            let mut ls = labels.to_vec();
            ls.push(("transition", t));
            b.sample("wildcat_quality_slo_transitions_total", &ls, v as f64);
        }
        b.declare(
            "wildcat_quality_degraded",
            "gauge",
            "1 while the error SLO holds the stack degraded.",
        );
        b.sample("wildcat_quality_degraded", labels, f64::from(self.degraded));
        b.declare(
            "wildcat_quality_lh_max_abs_err_p99",
            "gauge",
            "Per-layer-head p99 of audited max-abs attention error.",
        );
        for &(lh, p99, _) in &self.per_lh_p99 {
            let mut ls = labels.to_vec();
            let lh = lh.to_string();
            ls.push(("lh", &lh));
            b.sample("wildcat_quality_lh_max_abs_err_p99", &ls, p99);
        }
    }
}

/// Deterministic probe queries for one fold-audit site: same
/// `(seed, seq, fold)` ⇒ bit-identical probes ⇒ identical audited
/// errors across runs.
pub fn probe_queries(seed: u64, seq: u64, fold: u64, d_k: usize) -> Matrix {
    let mut rng = Rng::seed_from(splitmix64(
        seed ^ seq.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ fold.wrapping_mul(0xBF58_476D_1CE4_E5B9),
    ));
    Matrix::randn(&mut rng, FOLD_PROBES, d_k)
}

/// Ground-truth error of one compression fold: weighted attention from
/// `probe` queries over the pre-fold rows versus over the compressed
/// entry. Returns `(max_abs_err, rel_frobenius_err)`.
pub fn fold_error(
    probe: &Matrix,
    pre_k: &Matrix,
    pre_v: &Matrix,
    pre_w: &[f64],
    entry: &KvEntry,
    beta: f32,
) -> (f64, f64) {
    let clip_ref = ClipRange::from_values(pre_v);
    let clip_apx = ClipRange::from_values(&entry.values);
    let reference = wtd_attention(probe, pre_k, pre_v, pre_w, &clip_ref, beta);
    let approx = wtd_attention(probe, &entry.keys, &entry.values, &entry.weights, &clip_apx, beta);
    matrix_error(reference.as_slice(), approx.as_slice())
}

/// `(max_abs_err, rel_frobenius_err)` of `approx` against `reference`
/// over flat row-major slices of equal length.
pub fn matrix_error(reference: &[f32], approx: &[f32]) -> (f64, f64) {
    debug_assert_eq!(reference.len(), approx.len());
    let mut max_abs = 0.0f64;
    let mut diff_sq = 0.0f64;
    let mut ref_sq = 0.0f64;
    for (&r, &a) in reference.iter().zip(approx) {
        let d = (r as f64 - a as f64).abs();
        max_abs = max_abs.max(d);
        diff_sq += d * d;
        ref_sq += (r as f64) * (r as f64);
    }
    (max_abs, diff_sq.sqrt() / ref_sq.sqrt().max(1e-12))
}

/// Validate the quality block(s) of a metrics-JSON document (the
/// `wildcat obs --metrics` check): every `"quality"` object found —
/// top-level or per-replica — must satisfy the audit invariants.
/// Returns the number of quality blocks checked (0 when auditing was
/// off; that is not an error).
pub fn validate_quality_json(doc: &Json) -> Result<usize, String> {
    let mut checked = 0;
    validate_quality_inner(doc, &mut checked)?;
    Ok(checked)
}

fn validate_quality_inner(doc: &Json, checked: &mut usize) -> Result<(), String> {
    if let Some(o) = doc.as_obj() {
        for (k, v) in o {
            if k == "quality" {
                validate_quality_block(v)?;
                *checked += 1;
            } else {
                validate_quality_inner(v, checked)?;
            }
        }
    } else if let Some(a) = doc.as_arr() {
        for v in a {
            validate_quality_inner(v, checked)?;
        }
    }
    Ok(())
}

fn validate_quality_block(q: &Json) -> Result<(), String> {
    let num = |key: &str| {
        q.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("quality block missing numeric {key:?}"))
    };
    let rate = num("audit_rate")?;
    let samples = num("audited_samples")?;
    let decode = num("audited_decode_samples")?;
    let folds = num("audited_folds")?;
    if decode + folds != samples {
        return Err(format!("quality sample counts disagree: {decode} + {folds} != {samples}"));
    }
    if rate == 0.0 && samples > 0.0 {
        return Err("quality block reports samples with auditing off".to_string());
    }
    let p50 = num("max_abs_err_p50")?;
    let p99 = num("max_abs_err_p99")?;
    let max = num("max_abs_err_max")?;
    if p50 < 0.0 || p99 < p50 {
        return Err(format!("quality quantiles not ordered: p50={p50} p99={p99}"));
    }
    if max < 0.0 {
        return Err(format!("negative max_abs_err_max: {max}"));
    }
    let degr = num("slo_degradations")?;
    let reco = num("slo_recoveries")?;
    if reco > degr {
        return Err(format!("more SLO recoveries ({reco}) than degradations ({degr})"));
    }
    match q.get("degraded") {
        Some(Json::Bool(d)) => {
            let expected = degr > reco;
            if *d != expected {
                return Err(format!(
                    "degraded flag {d} inconsistent with transitions ({degr} degrade / {reco} recover)"
                ));
            }
        }
        _ => return Err("quality block missing boolean \"degraded\"".to_string()),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampling_is_deterministic_and_roughly_one_in_n() {
        let a = QualityAudit::new(QualityConfig { rate: 8, slo_abs_err: 0.0, seed: 42 });
        let b = QualityAudit::new(QualityConfig { rate: 8, slo_abs_err: 0.0, seed: 42 });
        let hits: Vec<u64> = (0..10_000).filter(|&r| a.audit_request(r)).collect();
        let hits_b: Vec<u64> = (0..10_000).filter(|&r| b.audit_request(r)).collect();
        assert_eq!(hits, hits_b, "same seed must pick the same sites");
        // 1-in-8 of 10k: generous 3-sigma-ish band
        assert!(hits.len() > 900 && hits.len() < 1600, "hits={}", hits.len());
        let c = QualityAudit::new(QualityConfig { rate: 8, slo_abs_err: 0.0, seed: 43 });
        let hits_c: Vec<u64> = (0..10_000).filter(|&r| c.audit_request(r)).collect();
        assert_ne!(hits, hits_c, "different seeds should pick different sites");
        // rate 1 audits everything, rate 0 nothing
        let all = QualityAudit::new(QualityConfig { rate: 1, slo_abs_err: 0.0, seed: 1 });
        assert!((0..100).all(|r| all.audit_request(r) && all.audit_fold(r, 3)));
        let off = QualityAudit::new(QualityConfig::default());
        assert!(!off.enabled());
        assert!((0..100).all(|r| !off.audit_request(r) && !off.audit_fold(r, 0)));
    }

    #[test]
    fn observe_feeds_histograms_and_snapshot() {
        let a = QualityAudit::new(QualityConfig { rate: 1, slo_abs_err: 0.0, seed: 7 });
        a.observe_decode(3, &[(0, 1e-4, 1e-3), (1, 5e-4, 2e-3)]);
        a.observe_fold(9, 1, 2e-3, 4e-3);
        let s = a.snapshot();
        assert_eq!(s.audited_decode, 1);
        assert_eq!(s.audited_folds, 1);
        assert_eq!(s.audited_total(), 2);
        assert!((s.err_max - 2e-3).abs() < 1e-12, "max tracked exactly");
        assert!(s.err_p99 >= s.err_p50 && s.err_p50 > 0.0);
        assert!(s.rel_p99 > 0.0);
        assert_eq!(s.per_lh_p99.len(), 2);
        // lh 1 saw both the 5e-4 decode and the 2e-3 fold
        let lh1 = s.per_lh_p99.iter().find(|r| r.0 == 1).unwrap();
        assert_eq!(lh1.2, 2);
        // json + prometheus render without panicking and agree on p99
        let j = s.to_json();
        assert_eq!(j.get("audited_samples").and_then(Json::as_f64), Some(2.0));
        let mut b = crate::obs::PromBuilder::new();
        s.prom_write(&mut b, &[]);
        let text = b.finish();
        assert!(text.contains("wildcat_quality_audited_samples_total{kind=\"fold\"} 1\n"));
        assert!(text.contains("wildcat_quality_max_abs_err_hist_count 2\n"));
        // the validator only counts blocks nested under a "quality" key
        assert_eq!(validate_quality_json(&j).unwrap(), 0);
        let mut wrap = BTreeMap::new();
        wrap.insert("quality".to_string(), j);
        assert_eq!(validate_quality_json(&Json::Obj(wrap)).unwrap(), 1);
    }

    #[test]
    fn exact_samples_keep_err_identically_zero() {
        let a = QualityAudit::new(QualityConfig { rate: 1, slo_abs_err: 0.0, seed: 7 });
        for i in 0..50 {
            a.observe_decode(i, &[(0, 0.0, 0.0), (1, 0.0, 0.0)]);
        }
        let s = a.snapshot();
        assert_eq!(s.err_max, 0.0);
        assert_eq!(s.err_p99, 0.0);
        assert_eq!(s.err_p50, 0.0);
    }

    #[test]
    fn slo_degrades_and_recovers_exactly_once() {
        let a = QualityAudit::new(QualityConfig { rate: 1, slo_abs_err: 1e-3, seed: 7 });
        assert!(!a.is_degraded());
        for i in 0..slo::WINDOW as u64 {
            a.observe_decode(i, &[(0, 5e-3, 1e-2)]);
        }
        assert!(a.is_degraded(), "windowed p99 breach must degrade");
        for i in 0..2 * slo::WINDOW as u64 {
            a.observe_decode(1000 + i, &[(0, 1e-6, 1e-5)]);
        }
        assert!(!a.is_degraded(), "low errors must recover with hysteresis");
        let s = a.snapshot();
        assert_eq!(s.degradations, 1, "exactly one degrade transition");
        assert_eq!(s.recoveries, 1, "exactly one recovery");
    }

    #[test]
    fn fold_error_is_deterministic_and_zero_for_identity() {
        let mut rng = Rng::seed_from(5);
        let k = Matrix::randn(&mut rng, 20, 8);
        let v = Matrix::randn(&mut rng, 20, 8);
        let w = vec![1.0f64; 20];
        let probe = probe_queries(42, 3, 0, 8);
        let probe2 = probe_queries(42, 3, 0, 8);
        assert_eq!(probe.as_slice(), probe2.as_slice(), "probes must be deterministic");
        // identity "fold": entry == original rows ⇒ error identically 0
        let entry = KvEntry { keys: k.clone(), values: v.clone(), weights: w.clone(), source_len: 20 };
        let (max_abs, rel) = fold_error(&probe, &k, &v, &w, &entry, 0.35);
        assert_eq!(max_abs, 0.0);
        assert_eq!(rel, 0.0);
        // a genuinely lossy entry has nonzero, reproducible error
        let lossy = KvEntry {
            keys: Matrix::from_fn(4, 8, |i, j| k.get(i, j)),
            values: Matrix::from_fn(4, 8, |i, j| v.get(i, j)),
            weights: vec![5.0; 4],
            source_len: 20,
        };
        let e1 = fold_error(&probe, &k, &v, &w, &lossy, 0.35);
        let e2 = fold_error(&probe, &k, &v, &w, &lossy, 0.35);
        assert_eq!(e1, e2);
        assert!(e1.0 > 0.0 && e1.1 > 0.0);
    }
}
