//! Timing harness: warmup + repeated measurement + robust summary, the
//! moral equivalent of a small criterion. Every `rust/benches/bench_*.rs`
//! binary builds its paper table through this.

use crate::util::stats::{summarize, Summary};
use std::time::Instant;

/// Result of benchmarking one closure.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time in seconds.
    pub seconds: Summary,
}

impl BenchResult {
    pub fn median(&self) -> f64 {
        self.seconds.median
    }
}

/// Benchmark configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchOpts {
    pub warmup_iters: usize,
    pub measure_iters: usize,
    /// Hard cap on total measured time; iterations stop early past this.
    pub max_seconds: f64,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts { warmup_iters: 3, measure_iters: 10, max_seconds: 30.0 }
    }
}

impl BenchOpts {
    /// Honour `WILDCAT_BENCH_FAST=1` for CI smoke runs.
    pub fn from_env() -> Self {
        if std::env::var("WILDCAT_BENCH_FAST").as_deref() == Ok("1") {
            BenchOpts { warmup_iters: 1, measure_iters: 3, max_seconds: 5.0 }
        } else {
            Self::default()
        }
    }

    /// Seconds-scale smoke preset used by `wildcat bench --smoke`: one
    /// warmup, three measured iterations, hard 2 s cap per closure.
    pub fn smoke() -> Self {
        BenchOpts { warmup_iters: 1, measure_iters: 3, max_seconds: 2.0 }
    }
}

/// Time `f` under `opts`; the closure's return value is black-boxed so the
/// optimiser cannot elide the work.
pub fn bench<T, F: FnMut() -> T>(name: &str, opts: BenchOpts, mut f: F) -> BenchResult {
    for _ in 0..opts.warmup_iters {
        black_box(f());
    }
    let mut samples = Vec::with_capacity(opts.measure_iters);
    let start_all = Instant::now();
    for _ in 0..opts.measure_iters {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
        if start_all.elapsed().as_secs_f64() > opts.max_seconds && !samples.is_empty() {
            break;
        }
    }
    BenchResult { name: name.to_string(), seconds: summarize(&samples) }
}

/// Opaque value sink (std::hint::black_box wrapper, kept local so benches
/// don't depend on unstable features).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Speed-up of `baseline` over `candidate` using median times, the paper's
/// "Speed-up over Exact" convention (>1 means candidate is faster).
pub fn speedup(baseline: &BenchResult, candidate: &BenchResult) -> f64 {
    baseline.median() / candidate.median()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_positive_time() {
        let r = bench(
            "spin",
            BenchOpts { warmup_iters: 1, measure_iters: 5, max_seconds: 5.0 },
            || {
                let mut acc = 0u64;
                for i in 0..10_000u64 {
                    acc = acc.wrapping_add(i * i);
                }
                acc
            },
        );
        assert!(r.seconds.median > 0.0);
        assert_eq!(r.name, "spin");
        assert!(r.seconds.count >= 1);
    }

    #[test]
    fn speedup_direction() {
        let slow = bench(
            "slow",
            BenchOpts { warmup_iters: 0, measure_iters: 3, max_seconds: 5.0 },
            || std::thread::sleep(std::time::Duration::from_millis(4)),
        );
        let fast = bench(
            "fast",
            BenchOpts { warmup_iters: 0, measure_iters: 3, max_seconds: 5.0 },
            || std::thread::sleep(std::time::Duration::from_micros(200)),
        );
        assert!(speedup(&slow, &fast) > 2.0);
    }

    #[test]
    fn respects_time_cap() {
        let t0 = Instant::now();
        let r = bench(
            "capped",
            BenchOpts { warmup_iters: 0, measure_iters: 1_000_000, max_seconds: 0.05 },
            || std::thread::sleep(std::time::Duration::from_millis(2)),
        );
        assert!(t0.elapsed().as_secs_f64() < 2.0);
        assert!(r.seconds.count < 1_000_000);
    }
}
