//! Shared runners for the seven paper benches plus the `serve` cluster
//! serving bench, the `kvpool` memory-manager bench, the `prefill`
//! prefix-resume bench and the `spill` cold-tier bench.
//!
//! Every `rust/benches/bench_*.rs` binary is a thin wrapper around one of
//! the `run_*` functions here, and `wildcat bench` drives the same
//! functions in-process. Each runner prints the paper-style table(s) it
//! always printed *and* returns a [`BenchReport`] of machine-readable
//! records; `wildcat bench --smoke` writes those as `BENCH_*.json` at the
//! repo root (the perf-trajectory contract checked by CI).
//!
//! Smoke mode shrinks shapes and iteration counts so the full suite
//! completes in seconds on a laptop; paper-scale settings remain the
//! default for the standalone bench binaries.

use crate::attention::{
    causal_wildcat_attention, compress_kv, exact_attention, flash_attention, wildcat_attention,
    wtd_attention, ClipRange, CompressOpts, WildcatParams,
};
use crate::bench::harness::{bench, speedup, BenchOpts, BenchResult};
use crate::bench::paperbench::{roster, run_roster, MethodResult};
use crate::bench::report::{BenchRecord, BenchReport};
use crate::cluster::{
    replay, Pacing, ReplayConfig, ReplicaPool, Router, RouterConfig, RoutingPolicy,
};
use crate::coordinator::{
    Batcher, BatcherConfig, Request, Scheduler, SchedulerConfig, ServerConfig, ServingMetrics,
};
use crate::kernels::gamma_growth;
use crate::kvcache::{
    compressor_by_name, BalanceKv, CompressKvPolicy, CompressionCtx, KvCompressor, PyramidKv,
    SnapKv, StreamingLlm, UniformKv,
};
use crate::kvpool::{spill_budget_bytes_from_mb, KvPool, KvPoolConfig, PoolSnapshot, SpillParams};
use crate::linalg::gemm;
use crate::linalg::norms::max_abs_diff;
use crate::linalg::Matrix;
use crate::model::{generate::greedy_decode_with_query, ModelConfig, Transformer, WeightFile};
use crate::rng::Rng;
use crate::rpnys::rpnys;
use crate::util::cli::Args;
use crate::util::stats::{percentile, summarize};
use crate::util::table::{fmt_pct, fmt_speedup, Table};
use crate::workload::gaussian::{activation_qkv, biggan_shapes};
use crate::workload::gaussian_qkv;
use crate::workload::tasks::{score, task_suite, TaskKind};
use crate::workload::trace::{shaped_trace, TraceShape};
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Configuration shared by every runner.
pub struct RunCfg<'a> {
    pub args: &'a Args,
    /// Seconds-scale shapes + smoke BenchOpts; reports tagged "smoke".
    pub smoke: bool,
    pub seed: u64,
}

impl<'a> RunCfg<'a> {
    pub fn from_args(args: &'a Args) -> Self {
        RunCfg { smoke: args.flag("smoke"), seed: args.get_parse::<u64>("seed", 0), args }
    }

    /// Timing options: smoke preset in smoke mode, else the env-sensitive
    /// default (`WILDCAT_BENCH_FAST=1` shrinks full runs for CI).
    pub fn opts(&self) -> BenchOpts {
        if self.smoke {
            BenchOpts::smoke()
        } else {
            BenchOpts::from_env()
        }
    }

    fn fast_env(&self) -> bool {
        std::env::var("WILDCAT_BENCH_FAST").as_deref() == Ok("1")
    }
}

/// Write the report next to `--json DIR` when the flag is given (the
/// standalone binaries call this; `wildcat bench` writes unconditionally).
pub fn maybe_write_json(report: &BenchReport, args: &Args) -> Result<()> {
    if let Some(dir) = args.get("json") {
        let path = report.write(Path::new(dir))?;
        println!("[bench] wrote {}", path.display());
    }
    Ok(())
}

/// Try `artifacts/weights.bin` under `--artifacts`. `Ok(None)` means the
/// caller should fall back to a seeded random model — allowed only when
/// `allow_fallback` (smoke benches, the cluster CLI); otherwise the load
/// error propagates. The single copy of the fallback policy shared by
/// `load_model`, the `serve` bench, and `wildcat cluster`.
pub fn load_weights(
    args: &Args,
    allow_fallback: bool,
    who: &str,
) -> Result<Option<Arc<WeightFile>>> {
    let dir = args.get_or("artifacts", "artifacts");
    match WeightFile::load(format!("{dir}/weights.bin")) {
        Ok(w) => Ok(Some(Arc::new(w))),
        Err(e) if allow_fallback => {
            println!(
                "[{who}] weights.bin unavailable ({e:#}); falling back to a seeded random model"
            );
            Ok(None)
        }
        Err(e) => Err(e).context("weights.bin missing — run `make artifacts` first"),
    }
}

/// Per-replica backend factory implementing the weights-or-seeded-random
/// policy resolved by [`load_weights`]: every replica loads the trained
/// weights when present, else builds a random model with a deterministic
/// per-replica seed (`seed + i`). Shared by the `serve` bench and the
/// `wildcat cluster` CLI so the two paths can never drift.
pub fn replica_backend_factory(
    weights: Option<Arc<WeightFile>>,
    model_cfg: ModelConfig,
    seed: u64,
) -> impl Fn(usize) -> Transformer + Send + Sync + 'static {
    move |i| match &weights {
        Some(w) => Transformer::from_weights(w.as_ref(), model_cfg).expect("model load"),
        None => Transformer::random(
            model_cfg,
            &mut Rng::seed_from(seed.wrapping_add(0x5E52).wrapping_add(i as u64)),
        ),
    }
}

/// The model used by the Tab. 4 / Tab. 5 benches: the build-time-trained
/// LM when `artifacts/weights.bin` exists; in smoke mode a seeded random
/// model of the same architecture stands in so `wildcat bench --smoke`
/// needs no artifacts.
fn load_model(cfg: &RunCfg) -> Result<Transformer> {
    match load_weights(cfg.args, cfg.smoke, "bench")? {
        Some(w) => Transformer::from_weights(w.as_ref(), ModelConfig::default()),
        None => Ok(Transformer::random(
            ModelConfig::default(),
            &mut Rng::seed_from(cfg.seed.wrapping_add(0x517C)),
        )),
    }
}

// ---------------------------------------------------------------------
// Fig. 3 — WildCat vs exact blocked attention over sequence length
// ---------------------------------------------------------------------

pub fn run_fig3(cfg: &RunCfg) -> Result<BenchReport> {
    let args = cfg.args;
    let seed = cfg.seed;
    let (def_min, def_max, def_err_seeds) = if cfg.smoke {
        (9u32, 11u32, 2u64)
    } else {
        (10, if cfg.fast_env() { 12 } else { 14 }, 3)
    };
    let min_exp = args.get_parse::<u32>("min-exp", def_min);
    let max_exp = args.get_parse::<u32>("max-exp", def_max);
    let rank = args.get_parse::<usize>("rank", 64);
    let bins = args.get_parse::<usize>("bins", 16);
    let d = args.get_parse::<usize>("d", 64);
    // clamp: 0 would record a false "zero error" into the JSON contract
    let err_seeds = args.get_parse::<u64>("err-seeds", def_err_seeds).max(1);

    let opts = cfg.opts();
    let title =
        format!("Fig. 3 — WildCat (r={rank}, B={bins}) vs exact blocked attention, d={d}");
    let mut report = BenchReport::new("fig3", &title, cfg.smoke, seed);
    let mut table =
        Table::new(&title, &["n", "exact (ms)", "wildcat (ms)", "speed-up", "err_max"]);

    let mut errs = Vec::new();
    let mut speedups = Vec::new();
    for exp in min_exp..=max_exp {
        let n = 1usize << exp;
        let mut rng = Rng::seed_from(seed + exp as u64);
        let w = gaussian_qkv(&mut rng, n, n, d, d);
        let t_exact = bench(&format!("exact n={n}"), opts, || {
            flash_attention(&w.q, &w.k, &w.v, w.beta)
        });
        let exact_out = flash_attention(&w.q, &w.k, &w.v, w.beta);
        let params = WildcatParams { rank, bins, beta: Some(w.beta as f64) };
        let t_wc = bench(&format!("wildcat n={n}"), opts, || {
            let mut r = Rng::seed_from(seed);
            wildcat_attention(&w.q, &w.k, &w.v, &params, &mut r)
        });
        let mut err = 0.0;
        for s in 0..err_seeds {
            let mut r = Rng::seed_from(seed + 10 + s);
            let approx = wildcat_attention(&w.q, &w.k, &w.v, &params, &mut r);
            err += max_abs_diff(&approx, &exact_out);
        }
        let err = err / err_seeds.max(1) as f64;
        let sp = t_exact.median() / t_wc.median();
        errs.push(err);
        speedups.push(sp);
        table.add_row(vec![
            format!("2^{exp}"),
            format!("{:.1}", t_exact.median() * 1e3),
            format!("{:.1}", t_wc.median() * 1e3),
            format!("{sp:.2}x"),
            format!("{err:.3e}"),
        ]);
        report.push(BenchRecord::new(format!("exact n={n}"), t_exact.median()).err(0.0));
        report.push(
            BenchRecord::new(format!("wildcat n={n}"), t_wc.median())
                .err(err)
                .coreset(rank)
                .extra("speedup", sp),
        );
    }
    table.print();
    println!("\n(markdown)\n{}", table.render_markdown());

    // paper-shape checks: speed-up increasing, error non-increasing in n
    let sp_up = speedups.windows(2).all(|w| w[1] >= w[0] * 0.85);
    let err_down = errs.first().zip(errs.last()).map(|(a, b)| *b <= a * 1.1).unwrap_or(true);
    println!(
        "[fig3] speed-up increasing with n: {}   error decreasing with n: {}",
        if sp_up { "YES" } else { "NO" },
        if err_down { "YES" } else { "NO" }
    );
    Ok(report)
}

// ---------------------------------------------------------------------
// Tab. 2 — BigGAN-shape roster comparison
// ---------------------------------------------------------------------

/// Push one roster comparison into a report: Exact row + every method.
fn push_roster_records(
    report: &mut BenchReport,
    suffix: &str,
    exact_t: &BenchResult,
    results: &[MethodResult],
    wildcat_rank: usize,
) {
    report.push(BenchRecord::new(format!("Exact{suffix}"), exact_t.median()).err(0.0));
    for r in results {
        let mut rec = BenchRecord::new(format!("{}{suffix}", r.name), r.timing.median())
            .err(r.quality.err_max_abs)
            .extra("speedup", speedup(exact_t, &r.timing))
            .extra("rel_frob", r.quality.rel_frob)
            .extra("top1_agree", r.quality.top1_agree);
        if r.name == "WILDCAT" {
            rec = rec.coreset(wildcat_rank);
        }
        report.push(rec);
    }
}

pub fn run_table2(cfg: &RunCfg) -> Result<BenchReport> {
    let args = cfg.args;
    let seed = cfg.seed;
    let seeds = args.get_parse::<u64>("quality-seeds", if cfg.smoke { 2 } else { 3 });
    let (m, n, d, dv, rank, bins) = if cfg.smoke {
        // quarter-scale BigGAN shapes: same aspect ratios, seconds-scale
        (1024usize, 256usize, 64usize, 64usize, 48usize, 4usize)
    } else {
        let (m, n, d, dv) = biggan_shapes();
        (m, n, d, dv, 96, 8)
    };
    let mut rng = Rng::seed_from(seed);
    let w = activation_qkv(&mut rng, m, n, d, dv, 4, 2.0);
    println!(
        "[table2] BigGAN{} shapes: Q {m}x{d}, K {n}x{d}, V {n}x{dv} (beta={:.4})",
        if cfg.smoke { " (smoke, quarter-scale)" } else { "" },
        w.beta
    );

    let opts = cfg.opts();
    let methods = roster(rank, bins, n);
    let (exact_t, results) = run_roster(&w, methods, opts, seeds, seed);

    let title = "Table 2 — BigGAN attention: speed-up and quality degradation";
    let mut report = BenchReport::new("table2", title, cfg.smoke, seed);
    push_roster_records(&mut report, "", &exact_t, &results, rank);

    let mut table = Table::new(
        title,
        &[
            "Attention Algorithm",
            "Speed-up over Exact",
            "MeanErr/Vmax (IS-proxy)",
            "RelFrob (FID-proxy)",
            "ErrMax/Vmax",
        ],
    );
    table.add_row(vec![
        "Exact".into(),
        "1.00x".into(),
        fmt_pct(0.0),
        fmt_pct(0.0),
        fmt_pct(0.0),
    ]);
    for r in &results {
        table.add_row(vec![
            r.name.into(),
            fmt_speedup(speedup(&exact_t, &r.timing)),
            fmt_pct(100.0 * r.quality.err_mean_rel),
            fmt_pct(100.0 * r.quality.rel_frob),
            fmt_pct(100.0 * r.quality.err_max_rel),
        ]);
    }
    table.print();
    println!("\n(markdown for EXPERIMENTS.md)\n{}", table.render_markdown());

    // sanity: the paper's headline — WildCat is the fastest approximation
    // with the smallest degradation — should reproduce in *shape*.
    if let Some(wc) = results.iter().find(|r| r.name == "WILDCAT") {
        println!(
            "[table2] WildCat: {:.2}x speed-up, {:.2}% rel-frob degradation",
            speedup(&exact_t, &wc.timing),
            100.0 * wc.quality.rel_frob
        );
    }
    Ok(report)
}

// ---------------------------------------------------------------------
// Tab. 3 — T2T-ViT per-layer roster comparison
// ---------------------------------------------------------------------

pub fn run_table3(cfg: &RunCfg) -> Result<BenchReport> {
    let args = cfg.args;
    let seed = cfg.seed;
    let seeds = args.get_parse::<u64>("quality-seeds", if cfg.smoke { 2 } else { 3 });
    let opts = cfg.opts();

    // (n, d, r, B) per layer, from Sec. 4.2 (smoke: quarter-scale shapes)
    let layers: Vec<(usize, usize, usize, usize)> = if cfg.smoke {
        vec![(784, 64, 96, 96), (392, 64, 48, 48)]
    } else {
        vec![(3136, 64, 224, 224), (784, 64, 196, 196)]
    };
    let title = "Table 3 — T2T-ViT attention: top-1 agreement and per-layer speed-ups";
    let mut report = BenchReport::new("table3", title, cfg.smoke, seed);
    let mut per_layer: Vec<(BenchResult, Vec<MethodResult>)> = Vec::new();
    for (li, &(n, d, r, b)) in layers.iter().enumerate() {
        let mut rng = Rng::seed_from(seed + li as u64);
        let w = activation_qkv(&mut rng, n, n, d, d, 4, 2.0);
        println!("[table3] layer {} shapes: n={n}, d={d}, r={r}, B={b}", li + 1);
        let (exact_t, results) = run_roster(&w, roster(r, b, n), opts, seeds, seed);
        push_roster_records(&mut report, &format!(" L{}", li + 1), &exact_t, &results, r);
        per_layer.push((exact_t, results));
    }

    let mut table = Table::new(
        title,
        &["Attention Algorithm", "Top-1 Agreement (%)", "Layer 1 Speed-up", "Layer 2 Speed-up"],
    );
    table.add_row(vec!["Exact".into(), "100.00%".into(), "1.00x".into(), "1.00x".into()]);
    let (e1, r1) = &per_layer[0];
    let (e2, r2) = &per_layer[1];
    for (m1, m2) in r1.iter().zip(r2.iter()) {
        assert_eq!(m1.name, m2.name);
        // accuracy dominated by the (larger) layer 1; report its agreement
        table.add_row(vec![
            m1.name.into(),
            fmt_pct(100.0 * m1.quality.top1_agree),
            fmt_speedup(speedup(e1, &m1.timing)),
            fmt_speedup(speedup(e2, &m2.timing)),
        ]);
    }
    table.print();
    println!("\n(markdown for EXPERIMENTS.md)\n{}", table.render_markdown());
    Ok(report)
}

// ---------------------------------------------------------------------
// Tab. 4 — KV-cache compression on the 13-task suite
// ---------------------------------------------------------------------

fn table4_methods() -> Vec<Box<dyn KvCompressor>> {
    vec![
        Box::new(StreamingLlm),
        Box::new(PyramidKv::default()),
        Box::new(BalanceKv),
        Box::new(UniformKv),
        Box::new(SnapKv::default()),
        Box::new(CompressKvPolicy::default()),
    ]
}

/// Tiny deterministic string hash for per-task seeds.
fn fxhash(s: &str) -> u64 {
    s.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    })
}

/// Attention-fidelity probe for a cache compressor: compress a fixed-seed
/// Gaussian (K, V) to `budget` entries and measure ‖O − Ô‖_max of the
/// weighted forward pass against exact attention.
fn kv_fidelity(comp: &dyn KvCompressor, budget: usize, seed: u64) -> f64 {
    let mut data_rng = Rng::seed_from(seed ^ 0xF1DE);
    let n = 384;
    let k = Matrix::randn(&mut data_rng, n, 8);
    let v = Matrix::randn(&mut data_rng, n, 4);
    let q = Matrix::randn(&mut data_rng, 24, 8);
    let beta = 0.35f32;
    let exact = exact_attention(&q, &k, &v, beta);
    let clip = ClipRange::from_values(&v);
    let ctx = CompressionCtx {
        keys: &k,
        values: &v,
        budget: budget.min(n),
        beta: beta as f64,
        layer: 0,
        n_layers: 1,
        obs_queries: None,
    };
    let mut rng = Rng::seed_from(seed ^ 0xF2DE);
    let e = comp.compress(&ctx, &mut rng);
    let o = wtd_attention(&q, &e.keys, &e.values, &e.weights, &clip, beta);
    max_abs_diff(&o, &exact)
}

/// Evaluate one method over the whole suite at one budget. Returns the
/// printed row, the per-episode wall times (seconds) and the average
/// score percentage.
#[allow(clippy::too_many_arguments)]
fn table4_row(
    model: &Transformer,
    comp: Option<&dyn KvCompressor>,
    name: &str,
    context: usize,
    budget: usize,
    trials: usize,
    seed: u64,
) -> (Vec<String>, Vec<f64>, f64) {
    let suite = task_suite();
    let mut row = vec![name.to_string()];
    let mut episode_secs = Vec::new();
    let mut total = 0.0;
    for task in &suite {
        let mut task_rng = Rng::seed_from(seed ^ fxhash(task.name));
        let mut s = 0.0;
        for _ in 0..trials {
            let inst = task.kind.generate(&mut task_rng, context, model.cfg.vocab as u32);
            let mut decode_rng = Rng::seed_from(seed + 1);
            let t0 = Instant::now();
            let out = match comp {
                None => greedy_decode_with_query(
                    model,
                    &inst.context,
                    &inst.query,
                    inst.expected.len(),
                    usize::MAX,
                    &UniformKv,
                    &mut decode_rng,
                ),
                Some(c) => greedy_decode_with_query(
                    model,
                    &inst.context,
                    &inst.query,
                    inst.expected.len(),
                    budget,
                    c,
                    &mut decode_rng,
                ),
            };
            episode_secs.push(t0.elapsed().as_secs_f64());
            s += score(&inst.expected, &out.tokens);
        }
        let pct = 100.0 * s / trials.max(1) as f64;
        total += pct;
        row.push(format!("{pct:.1}"));
    }
    let avg = total / suite.len() as f64;
    row.push(format!("{avg:.1}"));
    (row, episode_secs, avg)
}

pub fn run_table4(cfg: &RunCfg) -> Result<BenchReport> {
    let args = cfg.args;
    let seed = cfg.seed;
    let context = args.get_parse::<usize>("context", if cfg.smoke { 128 } else { 256 });
    let default_trials = if cfg.smoke {
        1
    } else if cfg.fast_env() {
        3
    } else {
        10
    };
    // clamp: 0 trials would leave summarize() with an empty sample
    let trials = args.get_parse::<usize>("trials", default_trials).max(1);
    let model = load_model(cfg)?;
    let suite = task_suite();

    let title = "Table 4 — KV-cache compression on the 13-task suite";
    let mut report = BenchReport::new("table4", title, cfg.smoke, seed);

    if args.flag("overhead") {
        for rec in table4_overhead(&model, context, seed)? {
            report.push(rec);
        }
        return Ok(report);
    }

    // compression levels of Tab. 4 (budget = context * (1 - level));
    // smoke mode runs the 75% level only
    let levels: &[(&str, f64)] = if cfg.smoke {
        &[("75.0%", 0.25)]
    } else {
        &[("75.0%", 0.25), ("87.5%", 0.125), ("93.75%", 0.0625)]
    };
    for &(level_name, keep_frac) in levels {
        let budget = ((context as f64) * keep_frac).round() as usize;
        let mut header: Vec<String> = vec!["Method".into()];
        header.extend(suite.iter().map(|t| t.name.to_string()));
        header.push("average".into());
        let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let mut table = Table::new(
            &format!(
                "Table 4 — {level_name} compression (context {context}, budget {budget}, {trials} trials)"
            ),
            &header_refs,
        );

        let (row, secs, avg) = table4_row(&model, None, "Exact", context, budget, trials, seed);
        table.add_row(row);
        report.push(
            BenchRecord::new(format!("Exact@{level_name}"), summarize(&secs).median)
                .err(0.0)
                .extra("score_pct", avg),
        );
        for comp in table4_methods() {
            let (row, secs, avg) =
                table4_row(&model, Some(comp.as_ref()), comp.name(), context, budget, trials, seed);
            table.add_row(row);
            report.push(
                BenchRecord::new(format!("{}@{level_name}", comp.name()), summarize(&secs).median)
                    .err(kv_fidelity(comp.as_ref(), budget, seed))
                    .coreset(budget)
                    .extra("score_pct", avg),
            );
        }
        table.print();
        println!("\n(markdown)\n{}", table.render_markdown());
    }
    Ok(report)
}

/// §M.3: prefill + compression wall time, CompressKV vs SnapKV.
fn table4_overhead(model: &Transformer, context: usize, seed: u64) -> Result<Vec<BenchRecord>> {
    let mut rng = Rng::seed_from(seed);
    let inst = TaskKind::Passkey.generate(&mut rng, context, model.cfg.vocab as u32);
    let budget = context / 4;
    let mut table = Table::new(
        &format!("§M.3 prefill overhead at {context} tokens, 75% compression"),
        &["Method", "prefill+compress", "overhead vs SnapKV"],
    );
    let mut records = Vec::new();
    let mut t_snap = 0.0;
    for comp in [
        Box::new(SnapKv::default()) as Box<dyn KvCompressor>,
        Box::new(CompressKvPolicy::default()),
    ] {
        let t0 = Instant::now();
        for _ in 0..5 {
            let out = model.prefill(&inst.context);
            for lh in 0..model.cfg.n_layers * model.cfg.n_heads {
                let ctx = CompressionCtx {
                    keys: &out.k_cache[lh],
                    values: &out.v_cache[lh],
                    budget,
                    beta: model.cfg.beta() as f64,
                    layer: lh / model.cfg.n_heads,
                    n_layers: model.cfg.n_layers,
                    obs_queries: None,
                };
                let _ = comp.compress(&ctx, &mut rng);
            }
        }
        let dt = t0.elapsed().as_secs_f64() / 5.0;
        if comp.name() == "SnapKV" {
            t_snap = dt;
        }
        table.add_row(vec![
            comp.name().into(),
            format!("{:.2} ms", dt * 1e3),
            if t_snap > 0.0 {
                format!("{:+.1}%", 100.0 * (dt - t_snap) / t_snap)
            } else {
                "-".into()
            },
        ]);
        records.push(
            BenchRecord::new(format!("overhead:{}", comp.name()), dt).coreset(budget),
        );
    }
    table.print();
    Ok(records)
}

// ---------------------------------------------------------------------
// Tab. 5 — entry growth factor γ(n)
// ---------------------------------------------------------------------

pub fn run_table5(cfg: &RunCfg) -> Result<BenchReport> {
    let args = cfg.args;
    let seed = cfg.seed;
    let trials = args.get_parse::<usize>("trials", if cfg.smoke { 2 } else { 5 }).max(1);
    let model = load_model(cfg)?;
    let beta = model.cfg.beta() as f64;
    let n_lh = model.cfg.n_layers * model.cfg.n_heads;
    let opts = cfg.opts();

    // paper sweeps n = 4 … 16384; our model's max_len caps the range
    let all_lens: &[usize] = if cfg.smoke { &[16, 64, 128] } else { &[4, 16, 64, 128, 256, 512] };
    let lens: Vec<usize> = all_lens.iter().copied().filter(|&n| n <= model.cfg.max_len).collect();

    let title = "Table 5 — entry growth factor γ(n) = β·R_Q·R_K / log(n)";
    let mut report = BenchReport::new("table5", title, cfg.smoke, seed);
    let mut table = Table::new(title, &["n", "R_K (mean)", "gamma(n)"]);
    let mut gammas = Vec::new();
    for &n in &lens {
        let mut rng = Rng::seed_from(seed);
        let mut g_acc = 0.0;
        let mut rk_acc = 0.0;
        let mut timing: Option<BenchResult> = None;
        for _ in 0..trials {
            let inst = TaskKind::Passkey.generate(&mut rng, n.max(16), model.cfg.vocab as u32);
            let toks: Vec<u32> = inst.context[..n.min(inst.context.len())].to_vec();
            if timing.is_none() {
                timing = Some(bench(&format!("prefill n={n}"), opts, || model.prefill(&toks)));
            }
            let out = model.prefill(&toks);
            // R_K per (layer, head); R_Q proxied by R_K of the same head
            // (queries and keys share scale in trained layers; the paper
            // measures both from activations — we average over heads)
            let mut g = 0.0;
            let mut rk_mean = 0.0;
            for lh in 0..n_lh {
                let r_k = out.k_cache[lh].max_row_norm();
                rk_mean += r_k / n_lh as f64;
                g += gamma_growth(beta, r_k, r_k, toks.len().max(2)) / n_lh as f64;
            }
            g_acc += g;
            rk_acc += rk_mean;
        }
        let g = g_acc / trials.max(1) as f64;
        let rk = rk_acc / trials.max(1) as f64;
        gammas.push(g);
        table.add_row(vec![n.to_string(), format!("{rk:.3}"), format!("{g:.3}")]);
        let prefill_median = timing.map(|t| t.median()).unwrap_or(0.0);
        report.push(
            BenchRecord::new(format!("gamma n={n}"), prefill_median)
                .extra("gamma", g)
                .extra("r_k_mean", rk),
        );
    }
    table.print();
    println!("\n(markdown)\n{}", table.render_markdown());

    // headline check: γ decreasing in n (Tab. 5's finding)
    let decreasing = gammas.windows(2).all(|w| w[1] <= w[0] * 1.05);
    println!(
        "[table5] gamma(n) decreasing: {} ({:?})",
        if decreasing { "YES (matches paper)" } else { "NO" },
        gammas.iter().map(|g| (g * 1000.0).round() / 1000.0).collect::<Vec<_>>()
    );
    Ok(report)
}

// ---------------------------------------------------------------------
// Fig. M.1 — rank/bin time-accuracy trade-off
// ---------------------------------------------------------------------

pub fn run_figm1(cfg: &RunCfg) -> Result<BenchReport> {
    let args = cfg.args;
    let seed = cfg.seed;
    let fast = cfg.fast_env();
    let def_n = if cfg.smoke { 1024 } else if fast { 4096 } else { 8192 };
    let n = args.get_parse::<usize>("n", def_n);
    let d = args.get_parse::<usize>("d", 64);
    let def_ranks: &[usize] = if cfg.smoke { &[32, 64, 128] } else { &[64, 128, 256, 512] };
    let def_bins: &[usize] = if cfg.smoke { &[2, 8] } else { &[2, 16, 64] };
    let ranks: Vec<usize> = args.get_list("ranks", def_ranks);
    let bins: Vec<usize> = args.get_list("bins", def_bins);
    let err_seeds =
        args.get_parse::<u64>("err-seeds", if cfg.smoke || fast { 2 } else { 5 }).max(1);

    let mut rng = Rng::seed_from(seed);
    let w = gaussian_qkv(&mut rng, n, n, d, d);
    let exact = flash_attention(&w.q, &w.k, &w.v, w.beta);
    let opts = cfg.opts();
    let t_exact = bench("exact", opts, || flash_attention(&w.q, &w.k, &w.v, w.beta));
    println!(
        "[figM1] n={n}, d={d}; exact attention median {:.1} ms",
        t_exact.median() * 1e3
    );

    let title = "Fig. M.1 — WildCat time-accuracy trade-off";
    let mut report = BenchReport::new("figm1", title, cfg.smoke, seed);
    report.push(BenchRecord::new(format!("exact n={n}"), t_exact.median()).err(0.0));
    let mut table = Table::new(title, &["B", "r", "time (ms)", "speed-up", "err_max"]);
    for &b in &bins {
        for &r in &ranks {
            if b > r {
                continue;
            }
            let params = WildcatParams { rank: r, bins: b, beta: Some(w.beta as f64) };
            let t = bench(&format!("r={r} B={b}"), opts, || {
                let mut run_rng = Rng::seed_from(seed);
                wildcat_attention(&w.q, &w.k, &w.v, &params, &mut run_rng)
            });
            let mut err = 0.0;
            for s in 0..err_seeds {
                let mut run_rng = Rng::seed_from(seed + 20 + s);
                err += max_abs_diff(
                    &wildcat_attention(&w.q, &w.k, &w.v, &params, &mut run_rng),
                    &exact,
                );
            }
            let err = err / err_seeds.max(1) as f64;
            table.add_row(vec![
                b.to_string(),
                r.to_string(),
                format!("{:.1}", t.median() * 1e3),
                format!("{:.2}x", t_exact.median() / t.median()),
                format!("{err:.3e}"),
            ]);
            report.push(
                BenchRecord::new(format!("wildcat r={r} B={b}"), t.median())
                    .err(err)
                    .coreset(r)
                    .extra("speedup", t_exact.median() / t.median())
                    .extra("bins", b as f64),
            );
        }
    }
    table.print();
    println!("\n(markdown)\n{}", table.render_markdown());
    Ok(report)
}

// ---------------------------------------------------------------------
// Micro-benchmarks of the hot-path primitives
// ---------------------------------------------------------------------

pub fn run_micro(cfg: &RunCfg) -> Result<BenchReport> {
    let opts = cfg.opts();
    let seed = cfg.seed;
    // smoke: half-scale shapes; full: the §Perf profiling shapes
    let n_attn = if cfg.smoke { 1024 } else { 4096 };
    let n_causal = if cfg.smoke { 256 } else { 512 };
    let comp_keys = if cfg.smoke { 512 } else { 1024 };
    let comp_budget = if cfg.smoke { 128 } else { 256 };
    let prefill_len = if cfg.smoke { 128 } else { 256 };

    let title = "micro-benchmarks";
    let mut report = BenchReport::new("micro", title, cfg.smoke, seed);
    let mut rng = Rng::seed_from(seed);
    let mut table = Table::new(title, &["op", "median", "notes"]);

    // GEMM
    let a = Matrix::randn(&mut rng, 1024, 64);
    let b = Matrix::randn(&mut rng, 64, 1024);
    let bt = Matrix::randn(&mut rng, 1024, 64);
    let r = bench("matmul 1024x64x1024", opts, || gemm::matmul(&a, &b));
    let flops = 2.0 * 1024.0 * 64.0 * 1024.0;
    table.add_row(vec![
        "matmul 1024x64x1024".into(),
        format!("{:.3} ms", r.median() * 1e3),
        format!("{:.2} GFLOP/s", flops / r.median() / 1e9),
    ]);
    report.push(BenchRecord::new("matmul 1024x64x1024", r.median()));
    let r = bench("matmul_transb", opts, || gemm::matmul_transb(&a, &bt));
    table.add_row(vec![
        "matmul_transb 1024x64x1024".into(),
        format!("{:.3} ms", r.median() * 1e3),
        format!("{:.2} GFLOP/s", flops / r.median() / 1e9),
    ]);
    report.push(BenchRecord::new("matmul_transb 1024x64x1024", r.median()));

    // attention kernels
    let q = Matrix::randn(&mut rng, n_attn, 64);
    let k = Matrix::randn(&mut rng, n_attn, 64);
    let v = Matrix::randn(&mut rng, n_attn, 64);
    let r = bench("exact_attention", opts, || exact_attention(&q, &k, &v, 0.125));
    table.add_row(vec![
        format!("exact_attention n={n_attn}"),
        format!("{:.3} ms", r.median() * 1e3),
        String::new(),
    ]);
    report.push(BenchRecord::new(format!("exact_attention n={n_attn}"), r.median()));
    let r = bench("flash_attention", opts, || flash_attention(&q, &k, &v, 0.125));
    table.add_row(vec![
        format!("flash_attention n={n_attn}"),
        format!("{:.3} ms", r.median() * 1e3),
        String::new(),
    ]);
    report.push(BenchRecord::new(format!("flash_attention n={n_attn}"), r.median()));

    // WTDATTN over a 96-point coreset
    let ks = k.slice_rows(0, 96);
    let vs = v.slice_rows(0, 96);
    let wts = vec![1.0f64; 96];
    let clip = ClipRange::from_values(&vs);
    let r = bench("wtd_attention", opts, || {
        wtd_attention(&q, &ks, &vs, &wts, &clip, 0.125)
    });
    table.add_row(vec![
        format!("wtd_attention m={n_attn} r=96"),
        format!("{:.3} ms", r.median() * 1e3),
        String::new(),
    ]);
    report.push(
        BenchRecord::new(format!("wtd_attention m={n_attn} r=96"), r.median()).coreset(96),
    );

    // RPNYS: unbinned vs binned (Sec. 2.5 speed-up)
    let rpnys_rank = if cfg.smoke { 48 } else { 96 };
    let r1 = bench("rpnys B=1", opts, || {
        let mut r = Rng::seed_from(1);
        rpnys(&k, 0.125, rpnys_rank, &mut r)
    });
    table.add_row(vec![
        format!("rpnys n={n_attn} r={rpnys_rank} (B=1)"),
        format!("{:.3} ms", r1.median() * 1e3),
        String::new(),
    ]);
    report.push(
        BenchRecord::new(format!("rpnys n={n_attn} r={rpnys_rank} B=1"), r1.median())
            .coreset(rpnys_rank),
    );
    let copts = CompressOpts { rank: rpnys_rank, bins: 8, beta: 0.125, r_q: q.max_row_norm() };
    let r8 = bench("compress_kv B=8", opts, || {
        let mut r = Rng::seed_from(1);
        compress_kv(&k, &v, &copts, &mut r)
    });
    table.add_row(vec![
        format!("compress_kv n={n_attn} r={rpnys_rank} B=8"),
        format!("{:.3} ms", r8.median() * 1e3),
        format!("{:.2}x vs B=1", r1.median() / r8.median()),
    ]);
    report.push(
        BenchRecord::new(format!("compress_kv n={n_attn} r={rpnys_rank} B=8"), r8.median())
            .coreset(rpnys_rank)
            .extra("speedup_vs_unbinned", r1.median() / r8.median()),
    );

    // compressors at serving shapes
    let keys = Matrix::randn(&mut rng, comp_keys, 32);
    let vals = Matrix::randn(&mut rng, comp_keys, 32);
    for comp in [
        Box::new(SnapKv::default()) as Box<dyn KvCompressor>,
        Box::new(CompressKvPolicy::default()),
    ] {
        let r = bench(comp.name(), opts, || {
            let mut rr = Rng::seed_from(2);
            let ctx = CompressionCtx {
                keys: &keys,
                values: &vals,
                budget: comp_budget,
                beta: 0.176,
                layer: 0,
                n_layers: 2,
                obs_queries: None,
            };
            comp.compress(&ctx, &mut rr)
        });
        table.add_row(vec![
            format!("compress[{}] {comp_keys}->{comp_budget}", comp.name()),
            format!("{:.3} ms", r.median() * 1e3),
            String::new(),
        ]);
        report.push(
            BenchRecord::new(
                format!("compress[{}] {comp_keys}->{comp_budget}", comp.name()),
                r.median(),
            )
            .coreset(comp_budget),
        );
    }

    // native model steps
    let mcfg = ModelConfig::default();
    let model = Transformer::random(mcfg, &mut rng);
    let toks: Vec<u32> = (0..prefill_len).map(|i| (i % 60 + 2) as u32).collect();
    let r = bench("prefill", opts, || model.prefill(&toks));
    table.add_row(vec![
        format!("model prefill n={prefill_len}"),
        format!("{:.3} ms", r.median() * 1e3),
        String::new(),
    ]);
    report.push(BenchRecord::new(format!("model prefill n={prefill_len}"), r.median()));
    let out = model.prefill(&toks);
    let caches: Vec<(Matrix, Matrix, Vec<f64>)> = out
        .k_cache
        .iter()
        .zip(&out.v_cache)
        .map(|(kc, vc)| (kc.clone(), vc.clone(), vec![1.0f64; kc.rows()]))
        .collect();
    let r = bench("decode", opts, || {
        let refs: Vec<(&Matrix, &Matrix, &[f64])> =
            caches.iter().map(|(kc, vc, wc)| (kc, vc, wc.as_slice())).collect();
        model.decode(5, prefill_len, &refs)
    });
    table.add_row(vec![
        format!("model decode @ {prefill_len} ctx"),
        format!("{:.3} ms", r.median() * 1e3),
        String::new(),
    ]);
    report.push(BenchRecord::new(format!("model decode @ {prefill_len} ctx"), r.median()));

    // streaming/causal extension (§5 future work): per-token attend cost
    // over a compressed stream vs exact causal attention
    let kcs = Matrix::randn(&mut rng, n_causal, 32);
    let vcs = Matrix::randn(&mut rng, n_causal, 32);
    let qcs = Matrix::randn(&mut rng, n_causal, 32);
    let r = bench("causal wildcat", opts, || {
        causal_wildcat_attention(&qcs, &kcs, &vcs, 64, 16, 1, 0.176, 3)
    });
    table.add_row(vec![
        format!("causal wildcat n={n_causal} (c=64,r=16)"),
        format!("{:.3} ms", r.median() * 1e3),
        String::new(),
    ]);
    report.push(
        BenchRecord::new(format!("causal wildcat n={n_causal} (c=64,r=16)"), r.median())
            .coreset(16),
    );
    let r = bench("causal exact", opts, || {
        let mut out = Matrix::zeros(n_causal, 32);
        for i in 0..n_causal {
            let qi = Matrix::from_vec(qcs.row(i).to_vec(), 1, 32);
            let o = exact_attention(
                &qi,
                &kcs.slice_rows(0, i + 1),
                &vcs.slice_rows(0, i + 1),
                0.176,
            );
            out.row_mut(i).copy_from_slice(o.row(0));
        }
        out
    });
    table.add_row(vec![
        format!("causal exact n={n_causal}"),
        format!("{:.3} ms", r.median() * 1e3),
        String::new(),
    ]);
    report.push(BenchRecord::new(format!("causal exact n={n_causal}"), r.median()));

    // metrics overhead (coordinator lock contention sanity)
    let metrics = Arc::new(ServingMetrics::new());
    let r = bench("metrics record", opts, || {
        for _ in 0..1000 {
            metrics.on_submit();
        }
    });
    table.add_row(vec![
        "metrics 1000 submits".into(),
        format!("{:.3} ms", r.median() * 1e3),
        String::new(),
    ]);
    report.push(BenchRecord::new("metrics 1000 submits", r.median()));

    // tracer overhead: the always-compiled disabled path (one relaxed
    // load + branch per site) vs enabled recording into the ring;
    // median_ns is per event, not per batch
    let tracer = crate::obs::trace::Tracer::new(crate::obs::trace::DEFAULT_CAPACITY);
    let ev = crate::obs::trace::Event {
        ts_us: 1,
        dur_us: 2,
        kind: crate::obs::SpanKind::DecodeStep,
        replica: 0,
        req: 7,
        a: 1,
        b: 0,
    };
    let batch = 1024u32;
    let r_off = bench("tracer record (off)", opts, || {
        for _ in 0..batch {
            tracer.record(ev);
        }
    });
    tracer.enable_with_capacity(crate::obs::trace::DEFAULT_CAPACITY);
    let r_on = bench("tracer record (on)", opts, || {
        for _ in 0..batch {
            tracer.record(ev);
        }
    });
    for (name, r) in [("tracer_record_off", &r_off), ("tracer_record_on", &r_on)] {
        let per_event = r.median() / batch as f64;
        table.add_row(vec![
            format!("{name} x{batch}"),
            format!("{:.3} ms", r.median() * 1e3),
            format!("{:.1} ns/event", per_event * 1e9),
        ]);
        report.push(BenchRecord::new(name, per_event).extra("events_per_s", 1.0 / per_event));
    }

    // quality-audit overhead per decode-step site: rate 0 is the
    // always-compiled gate alone (what every unaudited site pays), the
    // sampled rates add the splitmix hash plus — on 1-in-N sites — the
    // error-histogram observation. The reference recompute is excluded:
    // it runs off the hot path and scales with the sampled KV, not with
    // the per-site gate this record pins.
    for (name, audit_rate) in [("audit_off", 0u32), ("audit_1in64", 64), ("audit_1in8", 8)] {
        let audit = crate::obs::QualityAudit::new(crate::obs::QualityConfig {
            rate: audit_rate,
            slo_abs_err: 0.0,
            seed,
        });
        let r = bench(name, opts, || {
            for req in 0..batch as u64 {
                if audit.audit_request(req) {
                    audit.observe_decode(req, &[(0, 1.0e-6, 1.0e-6)]);
                }
            }
        });
        let per_event = r.median() / batch as f64;
        table.add_row(vec![
            format!("{name} x{batch}"),
            format!("{:.3} ms", r.median() * 1e3),
            format!("{:.1} ns/site", per_event * 1e9),
        ]);
        report.push(BenchRecord::new(name, per_event).extra("events_per_s", 1.0 / per_event));
    }

    // fault-plane overhead per injection site: `off` is what every
    // fault-free run pays (one Option discriminant check per engine
    // step / admission), `armed` adds the per-replica atomics of an
    // active plan whose thresholds never fire. CI gates `off` against
    // the tracer's disabled gate so the fault plane stays free when
    // chaos is not requested.
    {
        use crate::cluster::fault::{FaultConfig, FaultPlan};
        let none: Option<std::sync::Arc<FaultPlan>> = None;
        let r_off = bench("fault plane (off)", opts, || {
            let mut hits = 0u64;
            for _ in 0..batch {
                // black_box: keep the discriminant check from being
                // const-folded away (the real site reads a runtime field)
                if let Some(f) = std::hint::black_box(&none) {
                    if f.inject_admission_failure(0) {
                        hits += 1;
                    }
                }
            }
            hits
        });
        // thresholds far above the loop count: the armed gate runs, no
        // fault ever fires (isolates bookkeeping from injection)
        let plan = FaultPlan::new(
            FaultConfig { seed, reject_every: u64::MAX, ..Default::default() },
            1,
        )
        .expect("armed plan");
        let armed = Some(plan);
        let r_armed = bench("fault plane (armed)", opts, || {
            let mut hits = 0u64;
            for _ in 0..batch {
                if let Some(f) = std::hint::black_box(&armed) {
                    if f.inject_admission_failure(0) {
                        hits += 1;
                    }
                }
            }
            hits
        });
        for (name, r) in [("fault_plane_off", &r_off), ("fault_plane_armed", &r_armed)] {
            let per_event = r.median() / batch as f64;
            table.add_row(vec![
                format!("{name} x{batch}"),
                format!("{:.3} ms", r.median() * 1e3),
                format!("{:.1} ns/site", per_event * 1e9),
            ]);
            report.push(BenchRecord::new(name, per_event).extra("events_per_s", 1.0 / per_event));
        }
    }

    table.print();
    Ok(report)
}

// ---------------------------------------------------------------------
// serve — the cluster serving bench (trace-driven, per routing policy)
// ---------------------------------------------------------------------

/// Compare the routing policies at 1 vs N replicas on one fixed-seed
/// bursty trace. Smoke mode replays in virtual time (saturation test,
/// seconds-scale, needs no artifacts); full mode replays at wall-clock
/// rate against the trained model. Writes `BENCH_serve.json`: per config
/// `median_ns` is the p50 end-to-end latency, with throughput (req/s,
/// tok/s), p95/p99, and the cluster reject rate as extra fields.
pub fn run_serve(cfg: &RunCfg) -> Result<BenchReport> {
    let args = cfg.args;
    let seed = cfg.seed;
    let virtual_time = cfg.smoke || args.flag("fast");
    let replica_counts: Vec<usize> = args.get_list("replicas", &[1usize, 4]);
    let max_replicas = replica_counts.iter().copied().max().unwrap_or(1);
    let rate = args.get_parse::<f64>("rate", if cfg.smoke { 400.0 } else { 30.0 });
    let secs = args.get_parse::<f64>("duration", if cfg.smoke { 0.25 } else { 10.0 });
    let queue_cap = args.get_parse::<usize>("queue-cap", if cfg.smoke { 16 } else { 64 });
    let budget = args.get_parse::<usize>("budget", 96);
    // bursty by default (satellite: non-uniform traffic), short periods
    // in smoke so several on/off cycles fit the compressed trace
    let shape = match args.get("shape") {
        Some(name) => TraceShape::parse(name)?,
        None => TraceShape::OnOff {
            period: Duration::from_millis(if cfg.smoke { 100 } else { 2000 }),
            duty: 0.3,
            burst: 3.0,
        },
    };
    let model_cfg = ModelConfig::default();
    let weights = load_weights(args, cfg.smoke, "serve")?;

    let title = "serve — multi-replica serving: throughput & latency per routing policy";
    let mut report = BenchReport::new("serve", title, cfg.smoke, seed);
    let mut table = Table::new(
        title,
        &["policy", "replicas", "req/s", "tok/s", "p50 (ms)", "p95 (ms)", "p99 (ms)", "reject %"],
    );
    println!(
        "[serve] trace: rate {rate}/s x {secs}s, shape {}, {} pacing, queue cap {queue_cap}",
        shape.name(),
        if virtual_time { "virtual-time" } else { "wall-clock" }
    );
    let mut jsq_by_replicas: Vec<(usize, f64, f64)> = Vec::new();
    for &n in &replica_counts {
        for policy in RoutingPolicy::ALL {
            let mut scfg = ServerConfig::default();
            scfg.queue_capacity = queue_cap;
            scfg.scheduler.cache_budget = budget;
            scfg.seed = seed;
            let pool = Arc::new(ReplicaPool::spawn(
                n,
                scfg,
                Arc::new(StreamingLlm),
                replica_backend_factory(weights.clone(), model_cfg, seed),
            ));
            let router =
                Router::new(pool.clone(), RouterConfig { policy, ..Default::default() });
            // same fixed-seed trace and prompts for every configuration
            let mut trace_rng = Rng::seed_from(seed.wrapping_add(0xACE));
            let trace = shaped_trace(
                &mut trace_rng,
                rate,
                Duration::from_secs_f64(secs),
                &shape,
                8,
                48,
                4,
            );
            let rcfg = ReplayConfig {
                pacing: if virtual_time { Pacing::Virtual } else { Pacing::WallClock },
                vocab: model_cfg.vocab as u32,
                ..Default::default()
            };
            let mut prompt_rng = Rng::seed_from(seed.wrapping_add(0xBEE));
            let stats = replay(&router, &trace, &rcfg, &mut prompt_rng);
            pool.shutdown();
            if policy == RoutingPolicy::JoinShortestQueue {
                jsq_by_replicas.push((n, stats.throughput_rps, stats.reject_rate));
            }
            table.add_row(vec![
                policy.name().into(),
                n.to_string(),
                format!("{:.1}", stats.throughput_rps),
                format!("{:.1}", stats.tokens_per_s),
                format!("{:.2}", stats.p50_ms),
                format!("{:.2}", stats.p95_ms),
                format!("{:.2}", stats.p99_ms),
                fmt_pct(100.0 * stats.reject_rate),
            ]);
            report.push(
                BenchRecord::new(format!("{} x{n}", policy.name()), stats.p50_ms / 1e3)
                    .extra("replicas", n as f64)
                    .extra("throughput_rps", stats.throughput_rps)
                    .extra("tokens_per_s", stats.tokens_per_s)
                    .extra("p95_ms", stats.p95_ms)
                    .extra("p99_ms", stats.p99_ms)
                    .extra("reject_rate", stats.reject_rate)
                    .extra("completed", stats.completed as f64)
                    .extra("rejected", stats.rejected as f64),
            );
        }
    }
    table.print();
    println!("\n(markdown)\n{}", table.render_markdown());

    // headline check: scaling out under join_shortest_queue raises
    // throughput and lowers the reject rate (the PR-2 acceptance shape)
    let one = jsq_by_replicas.iter().find(|(n, _, _)| *n == 1);
    let many = jsq_by_replicas.iter().find(|(n, _, _)| *n == max_replicas && *n > 1);
    if let (Some(one), Some(many)) = (one, many) {
        println!(
            "[serve] jsq x{} vs x1: throughput {:.1} vs {:.1} req/s ({}), reject rate {:.1}% vs {:.1}% ({})",
            max_replicas,
            many.1,
            one.1,
            if many.1 > one.1 { "YES scales" } else { "NO" },
            100.0 * many.2,
            100.0 * one.2,
            if many.2 <= one.2 { "YES drops" } else { "NO" },
        );
    }
    Ok(report)
}

// ---------------------------------------------------------------------
// kvpool — paged KV memory manager: prefix sharing + pressure ladder
// ---------------------------------------------------------------------

/// Outcome of one `kvpool` bench configuration.
struct KvPoolRunStats {
    snap: PoolSnapshot,
    logical_tokens: usize,
    completed: usize,
    rejected_responses: usize,
    p50_decode_s: f64,
    p99_decode_s: f64,
    /// Prompt tokens the backend actually computed at admission
    /// (prefill skipping resumes from prefix hits, so under sharing this
    /// is less than the logical prompt-token total).
    prefill_tokens_computed: u64,
    /// Prompt tokens seeded from cached prefix KV rows instead.
    prefill_tokens_skipped: u64,
    /// Summed prefill wall time across completed responses.
    prefill_s_total: f64,
}

impl KvPoolRunStats {
    fn bytes_per_token(&self) -> f64 {
        self.snap.peak_bytes() as f64 / self.logical_tokens.max(1) as f64
    }
}

/// Replay one fixed-seed shared-prefix-tree trace through a scheduler
/// over a fresh pool with the given pool configuration. `max_active`
/// bounds batching concurrency: `prompts.len()` replays the whole set
/// concurrently (shared prefixes coexist in memory — the `kvpool` /
/// `prefill` shape), `1` replays sequentially (each request retires
/// before the next admits, so cached prefixes face eviction pressure
/// between reuses — the `spill` shape).
#[allow(clippy::too_many_arguments)]
fn kvpool_run(
    weights: &Option<Arc<WeightFile>>,
    model_cfg: ModelConfig,
    compressor: &Arc<dyn KvCompressor>,
    prompts: &[Vec<u32>],
    max_new: usize,
    pool_cfg: KvPoolConfig,
    prefill_skip: bool,
    max_active: usize,
    seed: u64,
) -> KvPoolRunStats {
    let pool = Arc::new(KvPool::new(pool_cfg, compressor.clone()));
    let backend = replica_backend_factory(weights.clone(), model_cfg, seed)(0);
    let metrics = Arc::new(ServingMetrics::new());
    let mut sched = Scheduler::with_pool(
        backend,
        // loose per-sequence budget: memory pressure is exercised
        // globally through the pool ladder, not per-sequence
        SchedulerConfig { cache_budget: 100_000, slack: 32, prefill_skip },
        metrics.clone(),
        seed,
        pool.clone(),
    );
    let n = max_active.max(1);
    let batcher = Batcher::new(BatcherConfig {
        max_active: n,
        max_admit_per_step: n,
        max_wait: Duration::ZERO,
        soft_active: n,
    });
    let reqs: Vec<Request> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| Request::new(i as u64, p.clone(), max_new))
        .collect();
    let responses = sched.run_to_completion(reqs, &batcher);
    let mut decode_s: Vec<f64> = Vec::new();
    let mut logical_tokens = 0;
    let mut completed = 0;
    let mut rejected_responses = 0;
    let mut prefill_s_total = 0.0;
    for r in &responses {
        if r.tokens.is_empty() {
            rejected_responses += 1;
            continue;
        }
        completed += 1;
        logical_tokens += r.context_len + r.tokens.len();
        decode_s.push(r.timing.decode.as_secs_f64());
        prefill_s_total += r.timing.prefill.as_secs_f64();
    }
    decode_s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |q| if decode_s.is_empty() { 0.0 } else { percentile(&decode_s, q) };
    let counters = metrics.counters();
    KvPoolRunStats {
        snap: pool.snapshot(),
        logical_tokens,
        completed,
        rejected_responses,
        p50_decode_s: pct(0.5),
        p99_decode_s: pct(0.99),
        prefill_tokens_computed: counters.prefill_tokens_computed,
        prefill_tokens_skipped: counters.prefill_tokens_skipped,
        prefill_s_total,
    }
}

/// The `kvpool` bench: a fixed-seed trace of prompts drawn from a
/// shared-prefix tree, replayed with prefix sharing on/off at a loose
/// (unbounded) and a tight pool budget. Reports bytes-per-token (pool
/// peak / logical tokens served), prefix-hit rate, compression-tier
/// activations, eviction count and p50/p99 decode latency per
/// configuration; `max_abs_err` is the attention-fidelity probe of the
/// tier's compressor at its budget when the tier fired (0 otherwise).
///
/// Acceptance shape (pinned by `rust/tests/kvpool_serve.rs`): sharing
/// cuts bytes-per-token by ≥ 30% on this trace, and the tight-budget run
/// completes with zero admission rejections — the ladder absorbs the
/// pressure by degrading accuracy, not availability.
pub fn run_kvpool(cfg: &RunCfg) -> Result<BenchReport> {
    let args = cfg.args;
    let seed = cfg.seed;
    let (n_roots, root_len, suffix_len, n_req, max_new, compress_budget) =
        if cfg.smoke { (4, 64, 24, 24, 6, 16) } else { (4, 96, 48, 64, 8, 24) };
    let n_req = args.get_parse::<usize>("requests", n_req);
    let compressor = compressor_by_name(&args.get_or("compressor", "streaming"))?;
    let model_cfg = ModelConfig::default();
    let weights = load_weights(args, true, "kvpool")?;

    // the shared-prefix tree: n_roots system prompts, each request is
    // root ++ unique suffix (fixed seed => identical trace per config)
    let mut trace_rng = Rng::seed_from(seed ^ 0x5EED);
    let vocab = model_cfg.vocab as u32;
    let roots: Vec<Vec<u32>> = (0..n_roots)
        .map(|_| (0..root_len).map(|_| trace_rng.below(vocab as usize) as u32).collect())
        .collect();
    let prompts: Vec<Vec<u32>> = (0..n_req)
        .map(|i| {
            let mut p = roots[i % n_roots].clone();
            p.extend((0..suffix_len).map(|_| trace_rng.below(vocab as usize) as u32));
            p
        })
        .collect();

    let title = "kvpool — paged KV pool: prefix sharing & compression-tier eviction";
    let mut report = BenchReport::new("kvpool", title, cfg.smoke, seed);
    let mut table = Table::new(
        title,
        &[
            "config",
            "bytes/token",
            "peak (MiB)",
            "hit rate",
            "tier compr",
            "evicted",
            "rejects",
            "p50 dec (ms)",
            "p99 dec (ms)",
        ],
    );

    let run = |sharing: bool, budget: usize| {
        let pool_cfg = KvPoolConfig {
            budget_floats: budget,
            prefix_sharing: sharing,
            compress_budget,
            block_tokens: 16,
            ..Default::default()
        };
        // whole set concurrent: shared prefixes coexist in memory
        kvpool_run(&weights, model_cfg, &compressor, &prompts, max_new, pool_cfg, true, prompts.len(), seed)
    };
    let loose_on = run(true, 0);
    let loose_off = run(false, 0);
    // tight: 60% of the sharing-on peak — compression/eviction must
    // absorb what no longer fits
    let tight_budget = (loose_on.snap.peak_floats * 3) / 5;
    let tight_on = run(true, tight_budget);
    let tight_off = run(false, tight_budget);

    let fidelity = kv_fidelity(compressor.as_ref(), compress_budget, seed);
    let configs: [(&str, &KvPoolRunStats); 4] = [
        ("sharing=on budget=loose", &loose_on),
        ("sharing=off budget=loose", &loose_off),
        ("sharing=on budget=tight", &tight_on),
        ("sharing=off budget=tight", &tight_off),
    ];
    for (name, s) in configs {
        table.add_row(vec![
            name.into(),
            format!("{:.1}", s.bytes_per_token()),
            format!("{:.2}", s.snap.peak_bytes() as f64 / (1024.0 * 1024.0)),
            fmt_pct(100.0 * s.snap.prefix_hit_rate()),
            s.snap.tier_compressions.to_string(),
            s.snap.evicted_blocks.to_string(),
            // pool rejections only: every one also surfaces as a
            // zero-token response, so summing the two would double-count
            s.snap.admission_rejects.to_string(),
            format!("{:.2}", s.p50_decode_s * 1e3),
            format!("{:.2}", s.p99_decode_s * 1e3),
        ]);
        let err = if s.snap.tier_compressions > 0 { fidelity } else { 0.0 };
        report.push(
            BenchRecord::new(name, s.p50_decode_s)
                .err(err)
                .coreset(compress_budget)
                .extra("bytes_per_token", s.bytes_per_token())
                .extra("peak_bytes", s.snap.peak_bytes() as f64)
                .extra("prefix_hit_rate", s.snap.prefix_hit_rate())
                .extra("shared_tokens", s.snap.shared_tokens as f64)
                .extra("tier_compressions", s.snap.tier_compressions as f64)
                .extra("evicted_blocks", s.snap.evicted_blocks as f64)
                .extra("admission_rejects", s.snap.admission_rejects as f64)
                .extra("rejected_responses", s.rejected_responses as f64)
                .extra("completed", s.completed as f64)
                .extra("logical_tokens", s.logical_tokens as f64)
                .extra("prefill_tokens_computed", s.prefill_tokens_computed as f64)
                .extra("prefill_tokens_skipped", s.prefill_tokens_skipped as f64)
                .extra("prefill_s_total", s.prefill_s_total)
                .extra("p99_decode_ms", s.p99_decode_s * 1e3),
        );
    }
    table.print();
    println!("\n(markdown)\n{}", table.render_markdown());

    // headline checks — the PR-3 acceptance shape
    let reduction = 1.0 - loose_on.bytes_per_token() / loose_off.bytes_per_token();
    println!(
        "[kvpool] prefix sharing cuts bytes-per-token by {:.1}% (target >= 30%): {}",
        100.0 * reduction,
        if reduction >= 0.30 { "YES" } else { "NO" }
    );
    let computed_cut =
        1.0 - loose_on.prefill_tokens_computed as f64 / loose_off.prefill_tokens_computed as f64;
    println!(
        "[kvpool] prefill skipping cuts computed prefill tokens by {:.1}% (target >= 30%): {}",
        100.0 * computed_cut,
        if computed_cut >= 0.30 { "YES" } else { "NO" }
    );
    let absorbed = tight_on.snap.admission_rejects == 0
        && tight_on.rejected_responses == 0
        && tight_on.completed == n_req;
    println!(
        "[kvpool] tight budget ({:.2} MiB) absorbed by the ladder ({} compressions, {} evictions, 0 rejects): {}",
        (tight_budget * 4) as f64 / (1024.0 * 1024.0),
        tight_on.snap.tier_compressions,
        tight_on.snap.evicted_blocks,
        if absorbed { "YES" } else { "NO" }
    );
    Ok(report)
}

// ---------------------------------------------------------------------
// prefill — resumed prefill on prefix hits vs cold recompute
// ---------------------------------------------------------------------

/// The `prefill` bench: the kvpool shared-prefix trace with `max_new = 1`
/// so admission-time prefill dominates the run, replayed at three
/// settings — resume=on (prefix sharing + prefill skipping), resume=off
/// (sharing on but every prompt recomputed cold), and sharing=off (no
/// pool index at all). Reports total prefill wall time, prompt tokens
/// computed vs skipped, and the resume-on speedup over resume-off.
///
/// Acceptance shape (pinned by `rust/tests/kvpool_serve.rs` and
/// `rust/tests/prefill_resume.rs`): resume=on computes ≥ 30% fewer
/// prompt tokens than resume=off on this trace, with logits equivalent
/// to cold prefill.
pub fn run_prefill(cfg: &RunCfg) -> Result<BenchReport> {
    let args = cfg.args;
    let seed = cfg.seed;
    let (n_roots, root_len, suffix_len, n_req) =
        if cfg.smoke { (4, 64, 24, 24) } else { (4, 96, 48, 64) };
    let n_req = args.get_parse::<usize>("requests", n_req);
    let compressor = compressor_by_name(&args.get_or("compressor", "streaming"))?;
    let model_cfg = ModelConfig::default();
    let weights = load_weights(args, true, "prefill")?;

    // identical trace construction to run_kvpool (same seed derivation)
    let mut trace_rng = Rng::seed_from(seed ^ 0x5EED);
    let vocab = model_cfg.vocab as u32;
    let roots: Vec<Vec<u32>> = (0..n_roots)
        .map(|_| (0..root_len).map(|_| trace_rng.below(vocab as usize) as u32).collect())
        .collect();
    let prompts: Vec<Vec<u32>> = (0..n_req)
        .map(|i| {
            let mut p = roots[i % n_roots].clone();
            p.extend((0..suffix_len).map(|_| trace_rng.below(vocab as usize) as u32));
            p
        })
        .collect();

    let title = "prefill — resumed prefill on radix prefix hits";
    let mut report = BenchReport::new("prefill", title, cfg.smoke, seed);
    let mut table = Table::new(
        title,
        &["config", "prefill (ms)", "computed", "skipped", "hit rate", "completed"],
    );

    let run = |sharing: bool, skip: bool| {
        let pool_cfg = KvPoolConfig {
            budget_floats: 0,
            prefix_sharing: sharing,
            compress_budget: 16,
            block_tokens: 16,
            ..Default::default()
        };
        kvpool_run(&weights, model_cfg, &compressor, &prompts, 1, pool_cfg, skip, prompts.len(), seed)
    };
    let resume_on = run(true, true);
    let resume_off = run(true, false);
    let sharing_off = run(false, false);

    let configs: [(&str, &KvPoolRunStats); 3] = [
        ("resume=on", &resume_on),
        ("resume=off", &resume_off),
        ("sharing=off", &sharing_off),
    ];
    for (name, s) in configs {
        table.add_row(vec![
            name.into(),
            format!("{:.2}", s.prefill_s_total * 1e3),
            s.prefill_tokens_computed.to_string(),
            s.prefill_tokens_skipped.to_string(),
            fmt_pct(100.0 * s.snap.prefix_hit_rate()),
            s.completed.to_string(),
        ]);
        report.push(
            BenchRecord::new(name, s.prefill_s_total)
                .extra("prefill_tokens_computed", s.prefill_tokens_computed as f64)
                .extra("prefill_tokens_skipped", s.prefill_tokens_skipped as f64)
                .extra("prefix_hit_rate", s.snap.prefix_hit_rate())
                .extra("completed", s.completed as f64),
        );
    }
    table.print();
    println!("\n(markdown)\n{}", table.render_markdown());

    // headline checks — the PR-6 acceptance shape
    let computed_cut = 1.0
        - resume_on.prefill_tokens_computed as f64 / resume_off.prefill_tokens_computed as f64;
    println!(
        "[prefill] resume computes {:.1}% fewer prompt tokens than cold (target >= 30%): {}",
        100.0 * computed_cut,
        if computed_cut >= 0.30 { "YES" } else { "NO" }
    );
    println!(
        "[prefill] wall-time speedup over cold: {:.2}x ({:.2} -> {:.2} ms)",
        resume_off.prefill_s_total / resume_on.prefill_s_total.max(1e-12),
        resume_off.prefill_s_total * 1e3,
        resume_on.prefill_s_total * 1e3,
    );
    Ok(report)
}

// ---------------------------------------------------------------------
// spill — the spill-to-disk tier: page-back vs recompute under pressure
// ---------------------------------------------------------------------

/// The `spill` bench: the kvpool shared-prefix trace replayed
/// *sequentially* (`max_active = 1`, `max_new = 1`) under a KV budget
/// tight enough that cached root prefixes are evicted between reuses.
/// Without the spill tier every root reuse recomputes the root's prefill
/// from scratch; with it the evicted blocks are paged back from the cold
/// store and prefill resumes past them. Reports computed/skipped prompt
/// tokens, spills, page-ins and rejects per configuration.
///
/// Acceptance shape (pinned by `rust/tests/kvpool_spill.rs`): spill-on
/// computes ≥ 30% fewer prompt tokens than spill-off on this trace, with
/// zero admission rejections and `page_ins > 0`.
pub fn run_spill(cfg: &RunCfg) -> Result<BenchReport> {
    let args = cfg.args;
    let seed = cfg.seed;
    let (n_roots, root_len, suffix_len, n_req) =
        if cfg.smoke { (4, 64, 24, 24) } else { (4, 96, 48, 64) };
    let n_req = args.get_parse::<usize>("requests", n_req);
    let compressor = compressor_by_name(&args.get_or("compressor", "streaming"))?;
    let model_cfg = ModelConfig::default();
    let weights = load_weights(args, true, "spill")?;

    // identical trace construction to run_kvpool (same seed derivation)
    let mut trace_rng = Rng::seed_from(seed ^ 0x5EED);
    let vocab = model_cfg.vocab as u32;
    let roots: Vec<Vec<u32>> = (0..n_roots)
        .map(|_| (0..root_len).map(|_| trace_rng.below(vocab as usize) as u32).collect())
        .collect();
    let prompts: Vec<Vec<u32>> = (0..n_req)
        .map(|i| {
            let mut p = roots[i % n_roots].clone();
            p.extend((0..suffix_len).map(|_| trace_rng.below(vocab as usize) as u32));
            p
        })
        .collect();

    let spill_dir =
        std::env::temp_dir().join(format!("wildcat_bench_spill_{}", std::process::id()));
    let run = |budget: usize, spill: bool| {
        // fresh cold store per configuration
        let _ = std::fs::remove_dir_all(&spill_dir);
        let pool_cfg = KvPoolConfig {
            budget_floats: budget,
            prefix_sharing: true,
            compress_budget: 16,
            block_tokens: 16,
            spill: spill.then(|| SpillParams {
                dir: spill_dir.clone(),
                budget_bytes: spill_budget_bytes_from_mb(64.0),
                replica: 0,
            }),
            ..Default::default()
        };
        // sequential replay: each request retires before the next admits,
        // so the tight budget evicts cached roots between reuses
        kvpool_run(&weights, model_cfg, &compressor, &prompts, 1, pool_cfg, true, 1, seed)
    };

    // Measure the fully-cached working set, then squeeze to a quarter of
    // it: comfortably above one active sequence (the ladder never has to
    // reject) but well below the root set (roots cannot all stay cached).
    let loose = run(0, false);
    let tight_budget = loose.snap.peak_floats / 4;
    let tight_off = run(tight_budget, false);
    let tight_on = run(tight_budget, true);
    let _ = std::fs::remove_dir_all(&spill_dir);

    let title = "spill — spill-to-disk tier: page-back vs recompute under pressure";
    let mut report = BenchReport::new("spill", title, cfg.smoke, seed);
    let mut table = Table::new(
        title,
        &["config", "computed", "skipped", "spills", "page-ins", "evicted", "rejects"],
    );
    let configs: [(&str, &KvPoolRunStats); 3] = [
        ("spill=off budget=loose", &loose),
        ("spill=off budget=tight", &tight_off),
        ("spill=on budget=tight", &tight_on),
    ];
    for (name, s) in configs {
        let sp = s.snap.spill.unwrap_or_default();
        table.add_row(vec![
            name.into(),
            s.prefill_tokens_computed.to_string(),
            s.prefill_tokens_skipped.to_string(),
            sp.spills.to_string(),
            sp.page_ins.to_string(),
            s.snap.evicted_blocks.to_string(),
            s.snap.admission_rejects.to_string(),
        ]);
        report.push(
            BenchRecord::new(name, s.prefill_s_total)
                .extra("prefill_tokens_computed", s.prefill_tokens_computed as f64)
                .extra("prefill_tokens_skipped", s.prefill_tokens_skipped as f64)
                .extra("evicted_blocks", s.snap.evicted_blocks as f64)
                .extra("admission_rejects", s.snap.admission_rejects as f64)
                .extra("rejected_responses", s.rejected_responses as f64)
                .extra("completed", s.completed as f64)
                .extra("spills", sp.spills as f64)
                .extra("spill_bytes", sp.spill_bytes as f64)
                .extra("spill_evictions", sp.spill_evictions as f64)
                .extra("page_ins", sp.page_ins as f64)
                .extra("pagein_tokens", sp.pagein_tokens as f64)
                .extra("spill_corrupt", sp.spill_corrupt as f64),
        );
    }
    table.print();
    println!("\n(markdown)\n{}", table.render_markdown());

    // headline checks — the spill-tier acceptance shape
    let computed_cut = 1.0
        - tight_on.prefill_tokens_computed as f64 / tight_off.prefill_tokens_computed.max(1) as f64;
    println!(
        "[spill] page-back cuts computed prefill tokens by {:.1}% vs spill-off (target >= 30%): {}",
        100.0 * computed_cut,
        if computed_cut >= 0.30 { "YES" } else { "NO" }
    );
    let sp = tight_on.snap.spill.unwrap_or_default();
    let absorbed = tight_on.snap.admission_rejects == 0
        && tight_on.rejected_responses == 0
        && tight_on.completed == n_req
        && sp.spills > 0
        && sp.page_ins > 0;
    println!(
        "[spill] tight budget ({:.2} MiB) absorbed with the cold tier ({} spills, {} page-ins, {} rejects): {}",
        (tight_budget * 4) as f64 / (1024.0 * 1024.0),
        sp.spills,
        sp.page_ins,
        tight_on.snap.admission_rejects,
        if absorbed { "YES" } else { "NO" }
    );
    Ok(report)
}

// ---------------------------------------------------------------------
// The unified entry point behind `wildcat bench`
// ---------------------------------------------------------------------

/// All bench ids in canonical order.
pub const BENCH_IDS: [&str; 11] = [
    "fig3", "table2", "table3", "table4", "table5", "figm1", "micro", "serve", "kvpool", "prefill",
    "spill",
];

/// Run the selected benches (all by default, or a comma-separated subset
/// via `only`) and write one `BENCH_<id>.json` per bench into `out_dir`.
/// Returns the written paths.
pub fn run_all(cfg: &RunCfg, out_dir: &Path, only: Option<&str>) -> Result<Vec<PathBuf>> {
    let wanted = |id: &str| -> bool {
        match only {
            None => true,
            Some(list) => list.split(',').any(|s| s.trim() == id),
        }
    };
    if let Some(list) = only {
        for id in list.split(',') {
            let id = id.trim();
            if !id.is_empty() && !BENCH_IDS.contains(&id) {
                anyhow::bail!("unknown bench {id:?} (available: {})", BENCH_IDS.join(","));
            }
        }
    }
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("creating output dir {out_dir:?}"))?;
    let mut written = Vec::new();
    let suite_t0 = Instant::now();
    for id in BENCH_IDS {
        if !wanted(id) {
            continue;
        }
        let t0 = Instant::now();
        println!("\n=== bench {id} ({}) ===", if cfg.smoke { "smoke" } else { "full" });
        let report = match id {
            "fig3" => run_fig3(cfg)?,
            "table2" => run_table2(cfg)?,
            "table3" => run_table3(cfg)?,
            "table4" => run_table4(cfg)?,
            "table5" => run_table5(cfg)?,
            "figm1" => run_figm1(cfg)?,
            "micro" => run_micro(cfg)?,
            "serve" => run_serve(cfg)?,
            "kvpool" => run_kvpool(cfg)?,
            "prefill" => run_prefill(cfg)?,
            "spill" => run_spill(cfg)?,
            _ => unreachable!(),
        };
        let path = report.write(out_dir)?;
        println!(
            "[bench] {id}: {} records -> {} ({:.1}s)",
            report.records.len(),
            path.display(),
            t0.elapsed().as_secs_f64()
        );
        written.push(path);
    }
    println!(
        "\n[bench] suite complete: {} report(s) in {:.1}s",
        written.len(),
        suite_t0.elapsed().as_secs_f64()
    );
    Ok(written)
}
