//! Benchmark harness substrate (criterion is unavailable offline) and
//! shared paper-benchmark plumbing.
//!
//! * [`harness`] — warmup/measure/summarise timing loop
//! * [`paperbench`] — method rosters + speed/quality measurement
//! * [`runners`] — one runner per paper bench, shared by the
//!   `rust/benches/bench_*` binaries and the `wildcat bench` subcommand
//! * [`report`] — the machine-readable `BENCH_*.json` schema

pub mod harness;
pub mod paperbench;
pub mod report;
pub mod runners;

pub use report::{BenchRecord, BenchReport};
pub use runners::{run_all, BENCH_IDS, RunCfg};
