//! Benchmark harness substrate (criterion is unavailable offline) and
//! shared paper-benchmark plumbing.

pub mod harness;
pub mod paperbench;
