//! Machine-readable bench reports — the `BENCH_*.json` perf-trajectory
//! contract every PR is measured against.
//!
//! One report per paper bench (`fig3`, `table2`, … `micro`), written at
//! the repo root by `wildcat bench --smoke`. The schema is deliberately
//! small and stable:
//!
//! ```json
//! {
//!   "schema_version": 1,
//!   "bench": "fig3",
//!   "title": "Fig. 3 — WildCat vs exact blocked attention",
//!   "mode": "smoke",
//!   "seed": 0,
//!   "unit": "ns",
//!   "records": [
//!     {"name": "wildcat n=1024", "median_ns": 1234567.0,
//!      "max_abs_err": 0.031, "coreset_size": 64, "speedup": 3.2}
//!   ]
//! }
//! ```
//!
//! Per record, `median_ns` is the median wall time per operation;
//! `max_abs_err` is ‖O − Ô‖_max against exact attention (`null` when the
//! record has no attention-error semantics, e.g. a GEMM micro-bench);
//! `coreset_size` is the coreset/budget the method ran at (`null` for
//! exact baselines). Extra numeric fields (speed-ups, scores, γ values)
//! may appear per record; consumers must ignore unknown keys.

use crate::util::json::{parse, Json};
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Schema version stamped into every report.
pub const SCHEMA_VERSION: f64 = 1.0;

/// One measured row of a bench report.
#[derive(Clone, Debug)]
pub struct BenchRecord {
    pub name: String,
    /// Median wall time per operation, nanoseconds.
    pub median_ns: f64,
    /// ‖O − Ô‖_max vs exact attention; `None` when not applicable.
    pub max_abs_err: Option<f64>,
    /// Coreset size / retained-entry budget; `None` when not applicable.
    pub coreset_size: Option<usize>,
    /// Additional numeric readouts (speed-up, score, gamma, ...).
    pub extra: BTreeMap<String, f64>,
}

impl BenchRecord {
    pub fn new(name: impl Into<String>, median_seconds: f64) -> Self {
        BenchRecord {
            name: name.into(),
            median_ns: median_seconds * 1e9,
            max_abs_err: None,
            coreset_size: None,
            extra: BTreeMap::new(),
        }
    }

    pub fn err(mut self, max_abs_err: f64) -> Self {
        self.max_abs_err = Some(max_abs_err);
        self
    }

    pub fn coreset(mut self, size: usize) -> Self {
        self.coreset_size = Some(size);
        self
    }

    pub fn extra(mut self, key: &str, value: f64) -> Self {
        self.extra.insert(key.to_string(), value);
        self
    }

    fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("name".to_string(), Json::Str(self.name.clone()));
        o.insert("median_ns".to_string(), finite_num(self.median_ns));
        o.insert(
            "max_abs_err".to_string(),
            match self.max_abs_err {
                Some(e) => finite_num(e),
                None => Json::Null,
            },
        );
        o.insert(
            "coreset_size".to_string(),
            match self.coreset_size {
                Some(r) => Json::Num(r as f64),
                None => Json::Null,
            },
        );
        for (k, v) in &self.extra {
            o.insert(k.clone(), finite_num(*v));
        }
        Json::Obj(o)
    }
}

/// Non-finite floats have no JSON encoding; map them to null so a NaN
/// measurement can never corrupt the report file.
fn finite_num(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else {
        Json::Null
    }
}

/// A full per-bench report.
#[derive(Clone, Debug)]
pub struct BenchReport {
    /// Short bench id: `fig3`, `table2`, `table3`, `table4`, `table5`,
    /// `figm1`, `micro`. Also the file stem (`BENCH_<bench>.json`).
    pub bench: String,
    pub title: String,
    /// `"smoke"` or `"full"`.
    pub mode: String,
    pub seed: u64,
    pub records: Vec<BenchRecord>,
}

impl BenchReport {
    pub fn new(bench: &str, title: &str, smoke: bool, seed: u64) -> Self {
        BenchReport {
            bench: bench.to_string(),
            title: title.to_string(),
            mode: if smoke { "smoke" } else { "full" }.to_string(),
            seed,
            records: Vec::new(),
        }
    }

    pub fn push(&mut self, record: BenchRecord) {
        self.records.push(record);
    }

    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("schema_version".to_string(), Json::Num(SCHEMA_VERSION));
        o.insert("bench".to_string(), Json::Str(self.bench.clone()));
        o.insert("title".to_string(), Json::Str(self.title.clone()));
        o.insert("mode".to_string(), Json::Str(self.mode.clone()));
        o.insert("seed".to_string(), Json::Num(self.seed as f64));
        o.insert("unit".to_string(), Json::Str("ns".to_string()));
        o.insert(
            "records".to_string(),
            Json::Arr(self.records.iter().map(BenchRecord::to_json).collect()),
        );
        Json::Obj(o)
    }

    /// File name this report is written under.
    pub fn file_name(&self) -> String {
        format!("BENCH_{}.json", self.bench)
    }

    /// Validate, serialise and write `BENCH_<bench>.json` into `dir`.
    pub fn write(&self, dir: &Path) -> Result<PathBuf> {
        let j = self.to_json();
        validate(&j).map_err(|e| anyhow::anyhow!("internal: invalid report for {}: {e}", self.bench))?;
        let path = dir.join(self.file_name());
        std::fs::write(&path, j.to_string_compact())
            .with_context(|| format!("writing {path:?}"))?;
        Ok(path)
    }
}

/// Validate a parsed report against the schema described in the module
/// docs. Returns the first violation as an error string.
pub fn validate(j: &Json) -> std::result::Result<(), String> {
    let obj = j.as_obj().ok_or("report is not a JSON object")?;
    let version = j
        .get("schema_version")
        .and_then(Json::as_f64)
        .ok_or("missing numeric schema_version")?;
    if version != SCHEMA_VERSION {
        return Err(format!("unsupported schema_version {version}"));
    }
    for key in ["bench", "title", "mode", "unit"] {
        let s = j
            .get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| format!("missing string field {key:?}"))?;
        if s.is_empty() {
            return Err(format!("empty field {key:?}"));
        }
    }
    match j.get("mode").and_then(Json::as_str) {
        Some("smoke") | Some("full") => {}
        other => return Err(format!("mode must be smoke|full, got {other:?}")),
    }
    j.get("seed").and_then(Json::as_f64).ok_or("missing numeric seed")?;
    let records = j
        .get("records")
        .and_then(Json::as_arr)
        .ok_or("missing records array")?;
    if records.is_empty() {
        return Err("records array is empty".to_string());
    }
    for (i, r) in records.iter().enumerate() {
        let name = r
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("record {i}: missing string name"))?;
        let ns = r
            .get("median_ns")
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("record {i} ({name}): missing numeric median_ns"))?;
        if !(ns.is_finite() && ns >= 0.0) {
            return Err(format!("record {i} ({name}): median_ns {ns} not a finite non-negative number"));
        }
        match r.get("max_abs_err") {
            None | Some(Json::Null) => {}
            Some(Json::Num(e)) if e.is_finite() && *e >= 0.0 => {}
            Some(other) => {
                return Err(format!("record {i} ({name}): bad max_abs_err {other:?}"))
            }
        }
        match r.get("coreset_size") {
            None | Some(Json::Null) => {}
            Some(Json::Num(c)) if c.is_finite() && *c >= 0.0 && c.fract() == 0.0 => {}
            Some(other) => {
                return Err(format!("record {i} ({name}): bad coreset_size {other:?}"))
            }
        }
    }
    let _ = obj;
    Ok(())
}

/// Parse + validate a report file's text; returns the parsed JSON.
pub fn validate_str(text: &str) -> std::result::Result<Json, String> {
    let j = parse(text)?;
    validate(&j)?;
    Ok(j)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> BenchReport {
        let mut rep = BenchReport::new("fig3", "Fig. 3 smoke", true, 7);
        rep.push(
            BenchRecord::new("exact n=512", 0.0123)
                .err(0.0),
        );
        rep.push(
            BenchRecord::new("wildcat n=512", 0.0034)
                .err(0.021)
                .coreset(64)
                .extra("speedup", 3.61),
        );
        rep
    }

    #[test]
    fn roundtrips_through_schema() {
        let rep = sample();
        let j = rep.to_json();
        validate(&j).unwrap();
        let text = j.to_string_compact();
        let back = validate_str(&text).unwrap();
        assert_eq!(back, j);
        assert_eq!(back.get("bench").unwrap().as_str(), Some("fig3"));
        let recs = back.get("records").unwrap().as_arr().unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[1].get("coreset_size").unwrap().as_usize(), Some(64));
        let ns = recs[1].get("median_ns").unwrap().as_f64().unwrap();
        assert!((ns - 0.0034e9).abs() < 1.0, "ns={ns}");
        assert!((recs[1].get("speedup").unwrap().as_f64().unwrap() - 3.61).abs() < 1e-9);
    }

    #[test]
    fn rejects_schema_violations() {
        // not an object
        assert!(validate(&Json::Arr(vec![])).is_err());
        // empty records
        let mut rep = sample();
        rep.records.clear();
        assert!(validate(&rep.to_json()).is_err());
        // bad mode
        let mut rep = sample();
        rep.mode = "warp".to_string();
        assert!(validate(&rep.to_json()).is_err());
        // record with negative time
        let mut rep = sample();
        rep.records[0].median_ns = -5.0;
        assert!(validate(&rep.to_json()).is_err());
        // malformed text
        assert!(validate_str("{not json").is_err());
    }

    #[test]
    fn nan_measurements_become_null() {
        let mut rep = sample();
        rep.records[0].max_abs_err = Some(f64::NAN);
        let j = rep.to_json();
        // NaN err serialises as null, which the schema accepts
        validate(&j).unwrap();
        let recs = j.get("records").unwrap().as_arr().unwrap();
        assert_eq!(recs[0].get("max_abs_err"), Some(&Json::Null));
    }

    #[test]
    fn write_creates_named_file() {
        let dir = std::env::temp_dir().join(format!("wildcat_report_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = sample().write(&dir).unwrap();
        assert!(path.ends_with("BENCH_fig3.json"));
        let text = std::fs::read_to_string(&path).unwrap();
        validate_str(&text).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }
}
