//! Shared plumbing for the paper-table benches (`rust/benches/bench_*`):
//! method rosters, quality metrics and the speed/quality measurement loop.

use crate::attention::WildcatParams;
use crate::baselines::{
    AttentionApprox, ExactBaseline, KdeFormer, Performer, Reformer, ScatterBrain, Thinformer,
    WildcatBaseline,
};
use crate::bench::harness::{bench, BenchOpts, BenchResult};
use crate::linalg::norms::{max_abs, max_abs_diff, rel_frobenius_err};
use crate::linalg::Matrix;
use crate::rng::Rng;
use crate::workload::AttentionWorkload;

/// Attention-quality readouts standing in for the paper's IS/FID/top-1
/// metrics (DESIGN.md §3): the downstream metrics are monotone readouts
/// of attention-output error, which we report directly.
#[derive(Clone, Copy, Debug, Default)]
pub struct Quality {
    /// ‖O − Ô‖_max — absolute worst-entry error vs exact attention (the
    /// raw value behind `err_max_rel`; reported in BENCH_*.json).
    pub err_max_abs: f64,
    /// ‖O − Ô‖_max / ‖V‖_max — the paper's theoretical metric (Lem. 1).
    pub err_max_rel: f64,
    /// Mean |O − Ô| / ‖V‖_max — average-entry degradation (IS-proxy:
    /// Inception Score responds to typical, not worst-case, distortion).
    pub err_mean_rel: f64,
    /// Relative Frobenius error (FID-degradation proxy).
    pub rel_frob: f64,
    /// Top-1 agreement with exact under a fixed random readout head
    /// (classification-accuracy proxy for Tab. 3).
    pub top1_agree: f64,
}

/// Compare an approximate output against the exact one.
pub fn quality(approx: &Matrix, exact: &Matrix, v: &Matrix, readout: &Matrix) -> Quality {
    let v_max = max_abs(v).max(1e-12);
    let classes = readout.rows();
    let mut agree = 0usize;
    for i in 0..exact.rows() {
        let cls = |m: &Matrix| -> usize {
            let mut best = 0;
            let mut best_v = f64::NEG_INFINITY;
            for c in 0..classes {
                let s: f64 = readout
                    .row(c)
                    .iter()
                    .zip(m.row(i))
                    .map(|(&a, &b)| a as f64 * b as f64)
                    .sum();
                if s > best_v {
                    best_v = s;
                    best = c;
                }
            }
            best
        };
        if cls(approx) == cls(exact) {
            agree += 1;
        }
    }
    let mut mean_err = 0.0f64;
    for (&a, &b) in approx.as_slice().iter().zip(exact.as_slice()) {
        mean_err += ((a as f64) - (b as f64)).abs();
    }
    mean_err /= exact.as_slice().len().max(1) as f64;
    let err_max_abs = max_abs_diff(approx, exact);
    Quality {
        err_max_abs,
        err_max_rel: err_max_abs / v_max,
        err_mean_rel: mean_err / v_max,
        rel_frob: rel_frobenius_err(approx, exact),
        top1_agree: agree as f64 / exact.rows().max(1) as f64,
    }
}

/// One method's Tab. 2/3-style result row.
pub struct MethodResult {
    pub name: &'static str,
    pub timing: BenchResult,
    pub quality: Quality,
}

/// The Tab. 2/3 roster with budgets scaled to the workload.
///
/// Budget convention: every approximation gets roughly the same "points
/// kept" budget `r` so the comparison is fair (the paper calibrates each
/// method's settings similarly; exact settings documented per bench).
pub fn roster(rank: usize, bins: usize, n: usize) -> Vec<Box<dyn AttentionApprox>>
{
    let halvings = if n > 2 * rank.max(1) {
        ((n as f64) / rank.max(1) as f64).log2().round() as usize
    } else {
        1
    };
    vec![
        Box::new(Reformer::new(16, 2)),
        Box::new(ScatterBrain::new(rank.max(32), 16)),
        Box::new(Performer::with_features(rank.max(32))),
        Box::new(KdeFormer::new(rank * 2, 16)),
        Box::new(Thinformer::new(halvings.max(1))),
        Box::new(WildcatBaseline {
            params: WildcatParams { rank, bins, beta: None },
        }),
    ]
}

/// Measure speed + quality of every roster method on a workload.
/// `seeds` controls the quality averaging (timing uses the harness opts).
pub fn run_roster(
    w: &AttentionWorkload,
    methods: Vec<Box<dyn AttentionApprox>>,
    opts: BenchOpts,
    seeds: u64,
    seed0: u64,
) -> (BenchResult, Vec<MethodResult>) {
    let exact_method = ExactBaseline;
    let mut rng = Rng::seed_from(seed0);
    let exact_out = exact_method.attend(&w.q, &w.k, &w.v, w.beta, &mut rng);
    let exact_timing = bench("Exact", opts, || {
        let mut r = Rng::seed_from(seed0);
        exact_method.attend(&w.q, &w.k, &w.v, w.beta, &mut r)
    });
    // fixed readout head for the top-1 proxy
    let mut readout_rng = Rng::seed_from(9999);
    let readout = Matrix::randn(&mut readout_rng, 10, w.v.cols());

    let mut results = Vec::new();
    for m in methods {
        let timing = bench(m.name(), opts, || {
            let mut r = Rng::seed_from(seed0);
            m.attend(&w.q, &w.k, &w.v, w.beta, &mut r)
        });
        // quality averaged over seeds
        let mut q_acc = Quality::default();
        for s in 0..seeds {
            let mut r = Rng::seed_from(seed0 + 1 + s);
            let out = m.attend(&w.q, &w.k, &w.v, w.beta, &mut r);
            let q = quality(&out, &exact_out, &w.v, &readout);
            q_acc.err_max_abs += q.err_max_abs;
            q_acc.err_max_rel += q.err_max_rel;
            q_acc.err_mean_rel += q.err_mean_rel;
            q_acc.rel_frob += q.rel_frob;
            q_acc.top1_agree += q.top1_agree;
        }
        let inv = 1.0 / seeds.max(1) as f64;
        results.push(MethodResult {
            name: m.name(),
            timing,
            quality: Quality {
                err_max_abs: q_acc.err_max_abs * inv,
                err_max_rel: q_acc.err_max_rel * inv,
                err_mean_rel: q_acc.err_mean_rel * inv,
                rel_frob: q_acc.rel_frob * inv,
                top1_agree: q_acc.top1_agree * inv,
            },
        });
    }
    (exact_timing, results)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::gaussian_qkv;

    #[test]
    fn quality_zero_for_identical() {
        let mut rng = Rng::seed_from(1);
        let m = Matrix::randn(&mut rng, 10, 4);
        let v = Matrix::randn(&mut rng, 8, 4);
        let readout = Matrix::randn(&mut rng, 5, 4);
        let q = quality(&m, &m, &v, &readout);
        assert_eq!(q.err_max_rel, 0.0);
        assert_eq!(q.rel_frob, 0.0);
        assert_eq!(q.top1_agree, 1.0);
    }

    #[test]
    fn run_roster_smoke() {
        let mut rng = Rng::seed_from(2);
        let w = gaussian_qkv(&mut rng, 32, 48, 8, 4);
        let opts = BenchOpts { warmup_iters: 0, measure_iters: 1, max_seconds: 10.0 };
        let (exact_t, results) = run_roster(&w, roster(16, 2, 48), opts, 1, 7);
        assert!(exact_t.median() > 0.0);
        assert_eq!(results.len(), 6);
        for r in &results {
            assert!(r.quality.err_max_rel.is_finite(), "{}", r.name);
            assert!(r.quality.top1_agree >= 0.0 && r.quality.top1_agree <= 1.0);
        }
    }
}
