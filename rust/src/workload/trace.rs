//! Arrival traces — the synthetic stand-in for production request traces
//! (DESIGN.md §3). Used by the serving demo, the cluster serving bench,
//! and §M.3-style overhead measurements.
//!
//! The base process is Poisson; [`TraceShape`] modulates it into bursty
//! (on/off) or heavy-tailed (Gamma-modulated) traffic via thinning of a
//! dominating homogeneous process, so the long-run mean rate stays at the
//! requested base rate.

use crate::rng::Rng;
use std::time::Duration;

/// One request arrival.
#[derive(Clone, Debug)]
pub struct Arrival {
    /// Offset from trace start.
    pub at: Duration,
    pub prompt_len: usize,
    pub max_new: usize,
}

/// Arrival-process shape: stationary Poisson or bursty modulations of it.
#[derive(Clone, Debug)]
pub enum TraceShape {
    /// Homogeneous Poisson at the base rate.
    Stationary,
    /// On/off (interrupted Poisson): within each `period`, the first
    /// `duty` fraction runs at `burst` × base rate and the remainder at
    /// the complementary rate, so the long-run mean stays at the base
    /// rate (the off-rate clamps at zero when `burst > 1/duty`).
    OnOff { period: Duration, duty: f64, burst: f64 },
    /// Gamma-modulated Poisson: each `period` draws an independent
    /// Gamma(shape, 1/shape) rate multiplier (mean 1). Smaller `shape`
    /// means burstier, heavier-tailed traffic than on/off.
    GammaModulated { period: Duration, shape: u32 },
}

impl TraceShape {
    /// Parse a CLI name: `stationary`, `onoff`, or `gamma` (with the
    /// defaults used by the serving bench).
    pub fn parse(name: &str) -> anyhow::Result<TraceShape> {
        Ok(match name {
            "stationary" | "poisson" => TraceShape::Stationary,
            "onoff" => TraceShape::OnOff {
                period: Duration::from_secs(2),
                duty: 0.3,
                burst: 3.0,
            },
            "gamma" => TraceShape::GammaModulated { period: Duration::from_secs(1), shape: 2 },
            other => anyhow::bail!("unknown trace shape {other:?} (try stationary/onoff/gamma)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            TraceShape::Stationary => "stationary",
            TraceShape::OnOff { .. } => "onoff",
            TraceShape::GammaModulated { .. } => "gamma",
        }
    }
}

/// Arrivals at mean `rate` req/s for `duration` under the given shape,
/// with prompt lengths log-uniform in `[min_prompt, max_prompt]` and
/// decode lengths uniform in `[1, max_new]`.
pub fn shaped_trace(
    rng: &mut Rng,
    rate: f64,
    duration: Duration,
    shape: &TraceShape,
    min_prompt: usize,
    max_prompt: usize,
    max_new: usize,
) -> Vec<Arrival> {
    assert!(rate > 0.0 && min_prompt >= 1 && max_prompt >= min_prompt && max_new >= 1);
    let horizon = duration.as_secs_f64();
    // Piecewise-constant rate multiplier and its supremum, for thinning.
    let (mult, mmax): (Box<dyn Fn(f64) -> f64>, f64) = match shape {
        TraceShape::Stationary => (Box::new(|_| 1.0), 1.0),
        TraceShape::OnOff { period, duty, burst } => {
            let p = period.as_secs_f64();
            assert!(p > 0.0 && *duty > 0.0 && *duty < 1.0 && *burst >= 1.0);
            let (duty, on) = (*duty, *burst);
            let off = ((1.0 - duty * on) / (1.0 - duty)).max(0.0);
            (Box::new(move |t: f64| if (t / p).fract() < duty { on } else { off }), on)
        }
        TraceShape::GammaModulated { period, shape } => {
            let p = period.as_secs_f64();
            assert!(p > 0.0);
            let k = (*shape).max(1);
            let n_periods = (horizon / p).ceil() as usize + 1;
            let mults: Vec<f64> = (0..n_periods)
                .map(|_| (0..k).map(|_| rng.exponential(1.0)).sum::<f64>() / k as f64)
                .collect();
            let mmax = mults.iter().copied().fold(f64::MIN_POSITIVE, f64::max);
            (Box::new(move |t: f64| mults[((t / p) as usize).min(mults.len() - 1)]), mmax)
        }
    };
    let stationary = matches!(shape, TraceShape::Stationary);
    let mut t = 0.0f64;
    let mut out = Vec::new();
    loop {
        t += rng.exponential(rate * mmax);
        if t >= horizon {
            break;
        }
        // Thinning: keep a dominating-process point with prob mult(t)/mmax
        // (skipped when stationary so the base process is drawn directly).
        if !stationary && rng.uniform() * mmax > mult(t) {
            continue;
        }
        let lo = (min_prompt as f64).ln();
        let hi = (max_prompt as f64).ln();
        let prompt_len = rng.uniform_in(lo, hi).exp().round() as usize;
        out.push(Arrival {
            at: Duration::from_secs_f64(t),
            prompt_len: prompt_len.clamp(min_prompt, max_prompt),
            max_new: 1 + rng.below(max_new),
        });
    }
    out
}

/// Stationary Poisson arrivals at `rate` req/s for `duration` — the
/// original trace generator, kept as the common case.
pub fn poisson_trace(
    rng: &mut Rng,
    rate: f64,
    duration: Duration,
    min_prompt: usize,
    max_prompt: usize,
    max_new: usize,
) -> Vec<Arrival> {
    shaped_trace(rng, rate, duration, &TraceShape::Stationary, min_prompt, max_prompt, max_new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrival_count_near_expectation() {
        let mut rng = Rng::seed_from(1);
        let trace = poisson_trace(&mut rng, 100.0, Duration::from_secs(10), 8, 64, 4);
        // E = 1000; Poisson sd ≈ 32
        assert!((850..1150).contains(&trace.len()), "n={}", trace.len());
        // sorted in time
        for w in trace.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
    }

    #[test]
    fn bounds_respected() {
        let mut rng = Rng::seed_from(2);
        for a in poisson_trace(&mut rng, 50.0, Duration::from_secs(5), 16, 128, 8) {
            assert!((16..=128).contains(&a.prompt_len));
            assert!((1..=8).contains(&a.max_new));
            assert!(a.at < Duration::from_secs(5));
        }
    }

    #[test]
    fn empty_for_tiny_duration() {
        let mut rng = Rng::seed_from(3);
        let trace = poisson_trace(&mut rng, 0.0001, Duration::from_millis(1), 8, 16, 2);
        assert!(trace.is_empty());
    }

    #[test]
    fn onoff_concentrates_arrivals_in_bursts() {
        let mut rng = Rng::seed_from(4);
        let shape = TraceShape::OnOff { period: Duration::from_secs(1), duty: 0.25, burst: 3.0 };
        let trace = shaped_trace(&mut rng, 200.0, Duration::from_secs(20), &shape, 8, 64, 4);
        // mean preserved: E = 4000 (duty·burst + (1−duty)·off = 1)
        assert!((3500..4500).contains(&trace.len()), "n={}", trace.len());
        let on_count = trace
            .iter()
            .filter(|a| a.at.as_secs_f64().fract() < 0.25)
            .count();
        // the on-quarter runs 3× the base rate → 75% of arrivals
        let on_frac = on_count as f64 / trace.len() as f64;
        assert!(
            (0.68..0.82).contains(&on_frac),
            "on-window fraction {on_frac} not bursty"
        );
        for w in trace.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
    }

    #[test]
    fn gamma_modulation_is_overdispersed() {
        // Per-period counts of a Gamma-modulated trace have variance well
        // above the mean (index of dispersion > 1); stationary ≈ 1.
        let dispersion = |trace: &[Arrival]| {
            let mut counts = vec![0f64; 40];
            for a in trace {
                counts[(a.at.as_secs_f64() as usize).min(39)] += 1.0;
            }
            let mean = counts.iter().sum::<f64>() / counts.len() as f64;
            let var = counts.iter().map(|c| (c - mean) * (c - mean)).sum::<f64>()
                / counts.len() as f64;
            var / mean.max(1e-9)
        };
        let mut rng = Rng::seed_from(5);
        let shape = TraceShape::GammaModulated { period: Duration::from_secs(1), shape: 1 };
        let bursty = shaped_trace(&mut rng, 50.0, Duration::from_secs(40), &shape, 8, 64, 4);
        let stationary = poisson_trace(&mut rng, 50.0, Duration::from_secs(40), 8, 64, 4);
        let (d_b, d_s) = (dispersion(&bursty), dispersion(&stationary));
        assert!(d_b > 2.0 * d_s, "gamma dispersion {d_b} vs stationary {d_s}");
        for a in &bursty {
            assert!((8..=64).contains(&a.prompt_len) && (1..=4).contains(&a.max_new));
        }
    }

    #[test]
    fn shape_parsing() {
        assert!(matches!(TraceShape::parse("stationary").unwrap(), TraceShape::Stationary));
        assert!(matches!(TraceShape::parse("onoff").unwrap(), TraceShape::OnOff { .. }));
        assert!(matches!(
            TraceShape::parse("gamma").unwrap(),
            TraceShape::GammaModulated { .. }
        ));
        assert!(TraceShape::parse("warp").is_err());
        assert_eq!(TraceShape::parse("onoff").unwrap().name(), "onoff");
    }
}
