//! Poisson arrival traces — the synthetic stand-in for production request
//! traces (DESIGN.md §3). Used by the serving demo and §M.3-style
//! overhead measurements.

use crate::rng::Rng;
use std::time::Duration;

/// One request arrival.
#[derive(Clone, Debug)]
pub struct Arrival {
    /// Offset from trace start.
    pub at: Duration,
    pub prompt_len: usize,
    pub max_new: usize,
}

/// Poisson arrivals at `rate` req/s for `duration`, with prompt lengths
/// log-uniform in `[min_prompt, max_prompt]` and decode lengths uniform
/// in `[1, max_new]`.
pub fn poisson_trace(
    rng: &mut Rng,
    rate: f64,
    duration: Duration,
    min_prompt: usize,
    max_prompt: usize,
    max_new: usize,
) -> Vec<Arrival> {
    assert!(rate > 0.0 && min_prompt >= 1 && max_prompt >= min_prompt && max_new >= 1);
    let mut t = 0.0f64;
    let horizon = duration.as_secs_f64();
    let mut out = Vec::new();
    loop {
        t += rng.exponential(rate);
        if t >= horizon {
            break;
        }
        let lo = (min_prompt as f64).ln();
        let hi = (max_prompt as f64).ln();
        let prompt_len = rng.uniform_in(lo, hi).exp().round() as usize;
        out.push(Arrival {
            at: Duration::from_secs_f64(t),
            prompt_len: prompt_len.clamp(min_prompt, max_prompt),
            max_new: 1 + rng.below(max_new),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrival_count_near_expectation() {
        let mut rng = Rng::seed_from(1);
        let trace = poisson_trace(&mut rng, 100.0, Duration::from_secs(10), 8, 64, 4);
        // E = 1000; Poisson sd ≈ 32
        assert!((850..1150).contains(&trace.len()), "n={}", trace.len());
        // sorted in time
        for w in trace.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
    }

    #[test]
    fn bounds_respected() {
        let mut rng = Rng::seed_from(2);
        for a in poisson_trace(&mut rng, 50.0, Duration::from_secs(5), 16, 128, 8) {
            assert!((16..=128).contains(&a.prompt_len));
            assert!((1..=8).contains(&a.max_new));
            assert!(a.at < Duration::from_secs(5));
        }
    }

    #[test]
    fn empty_for_tiny_duration() {
        let mut rng = Rng::seed_from(3);
        let trace = poisson_trace(&mut rng, 0.0001, Duration::from_millis(1), 8, 16, 2);
        assert!(trace.is_empty());
    }
}
