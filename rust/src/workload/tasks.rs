//! The 13-task long-context suite — the Tab. 4 stand-in for LongBench-E
//! (see DESIGN.md §3 for the substitution argument). Every task is built
//! from the two skills the build-time LM was trained on (key→value
//! retrieval and induction copying) with held-out parameterisations:
//! pair placement depth, distractor density, query multiplicity, and
//! copy periods. Token conventions mirror `python/compile/tasks.py`:
//!
//! ```text
//! PAD=0  BOS=1  KEY=2  VAL=3  QUERY=4  SEP=5  content: 6..vocab-1
//! ```

use crate::rng::Rng;

pub const PAD: u32 = 0;
pub const BOS: u32 = 1;
pub const KEY: u32 = 2;
pub const VAL: u32 = 3;
pub const QUERY: u32 = 4;
pub const SEP: u32 = 5;
pub const CONTENT_START: u32 = 6;
/// Disjoint token sub-ranges (mirror of python/compile/tasks.py): keys
/// never collide with filler, keeping retrieval unambiguous.
pub const KEY_LO: u32 = 6;
pub const KEY_HI: u32 = 20;
pub const VAL_LO: u32 = 20;
pub const VAL_HI: u32 = 34;
pub const FILLER_LO: u32 = 34;

/// Task family (names map to the LongBench-E columns of Tab. 4).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TaskKind {
    /// Retrieval with the pair placed in a context-depth band.
    KvDepth { lo_pct: u8, hi_pct: u8 },
    /// Retrieval among `distractors` additional pairs.
    KvDistractors { distractors: u8 },
    /// Retrieval scored over two consecutive queries.
    KvTwoQueries,
    /// Retrieval where the target pair is stated twice (consistency).
    KvRepeated,
    /// Pure single-pair passkey retrieval.
    Passkey,
    /// Induction copying with the given period.
    Induction { period: u16 },
}

/// One benchmark task: a name (LongBench analogue) + generator kind.
#[derive(Clone, Debug)]
pub struct LongContextTask {
    pub name: &'static str,
    pub kind: TaskKind,
}

/// The 13-task suite in Tab. 4 column order.
pub fn task_suite() -> Vec<LongContextTask> {
    use TaskKind::*;
    vec![
        LongContextTask { name: "qasper", kind: KvDepth { lo_pct: 5, hi_pct: 25 } },
        LongContextTask { name: "multifield", kind: KvDepth { lo_pct: 30, hi_pct: 55 } },
        LongContextTask { name: "hotpot", kind: KvDepth { lo_pct: 60, hi_pct: 85 } },
        LongContextTask { name: "2wiki", kind: KvDistractors { distractors: 2 } },
        LongContextTask { name: "gov", kind: KvDistractors { distractors: 5 } },
        LongContextTask { name: "multinews", kind: KvDistractors { distractors: 9 } },
        LongContextTask { name: "trec", kind: KvTwoQueries },
        LongContextTask { name: "trivia", kind: Induction { period: 16 } },
        LongContextTask { name: "samsum", kind: Induction { period: 48 } },
        LongContextTask { name: "p.count", kind: KvRepeated },
        LongContextTask { name: "p.ret", kind: Passkey },
        LongContextTask { name: "lcc", kind: Induction { period: 24 } },
        LongContextTask { name: "repo-p", kind: Induction { period: 32 } },
    ]
}

/// One evaluation episode under the serving protocol: prefill `context`
/// (the document), compress the cache, then feed `query` tokens through
/// *decode* (they arrive after compression, like a user turn), and
/// greedily decode `expected.len()` answer tokens; score = fraction
/// matching `expected`.
#[derive(Clone, Debug)]
pub struct TaskInstance {
    pub context: Vec<u32>,
    pub query: Vec<u32>,
    pub expected: Vec<u32>,
}

fn content(rng: &mut Rng, vocab: u32) -> u32 {
    CONTENT_START + rng.below((vocab - CONTENT_START) as usize) as u32
}

fn filler(rng: &mut Rng, vocab: u32) -> u32 {
    FILLER_LO + rng.below((vocab - FILLER_LO) as usize) as u32
}

fn key_token(rng: &mut Rng) -> u32 {
    KEY_LO + rng.below((KEY_HI - KEY_LO) as usize) as u32
}

fn val_token(rng: &mut Rng) -> u32 {
    VAL_LO + rng.below((VAL_HI - VAL_LO) as usize) as u32
}

/// Place `pairs` [KEY k v] triplets at depths within `[lo, hi)` (absolute
/// positions) of a filler sequence; returns (keys, vals).
fn place_pairs(
    toks: &mut [u32],
    rng: &mut Rng,
    _vocab: u32,
    n_pairs: usize,
    lo: usize,
    hi: usize,
) -> (Vec<u32>, Vec<u32>) {
    assert!(hi <= toks.len() && lo < hi);
    let slots_avail = (hi - lo) / 3;
    assert!(slots_avail >= n_pairs, "band too narrow for {n_pairs} pairs");
    let chosen = rng.sample_without_replacement(slots_avail, n_pairs);
    let mut keys = Vec::with_capacity(n_pairs);
    let mut vals = Vec::with_capacity(n_pairs);
    for &slot in &chosen {
        let s = lo + slot * 3;
        let mut k = key_token(rng);
        while keys.contains(&k) {
            k = key_token(rng);
        }
        let v = val_token(rng);
        toks[s] = KEY;
        toks[s + 1] = k;
        toks[s + 2] = v;
        keys.push(k);
        vals.push(v);
    }
    (keys, vals)
}

impl TaskKind {
    /// Generate one instance with context length `n`.
    pub fn generate(&self, rng: &mut Rng, n: usize, vocab: u32) -> TaskInstance {
        let mut toks: Vec<u32> = (0..n).map(|_| filler(rng, vocab)).collect();
        toks[0] = BOS;
        match *self {
            TaskKind::KvDepth { lo_pct, hi_pct } => {
                let lo = (n * lo_pct as usize / 100).max(1);
                let hi = (n * hi_pct as usize / 100).min(n - 3).max(lo + 9);
                let (keys, vals) = place_pairs(&mut toks, rng, vocab, 3, lo, hi);
                let t = rng.below(3);
                toks.truncate(n - 2);
                TaskInstance {
                    context: toks,
                    query: vec![KEY, keys[t]],
                    expected: vec![vals[t]],
                }
            }
            TaskKind::KvDistractors { distractors } => {
                let n_pairs = 1 + distractors as usize;
                let (keys, vals) = place_pairs(&mut toks, rng, vocab, n_pairs, 1, n - 3);
                let t = rng.below(n_pairs);
                toks.truncate(n - 2);
                TaskInstance {
                    context: toks,
                    query: vec![KEY, keys[t]],
                    expected: vec![vals[t]],
                }
            }
            TaskKind::KvTwoQueries => {
                let (keys, vals) = place_pairs(&mut toks, rng, vocab, 4, 1, n - 6);
                let t1 = rng.below(4);
                // first query is fully in-context; second ends the context
                toks[n - 5] = KEY;
                toks[n - 4] = keys[t1];
                toks[n - 3] = vals[t1];
                let t2 = rng.below(4);
                toks.truncate(n - 2);
                TaskInstance {
                    context: toks,
                    query: vec![KEY, keys[t2]],
                    expected: vec![vals[t2]],
                }
            }
            TaskKind::KvRepeated => {
                let (keys, vals) = place_pairs(&mut toks, rng, vocab, 2, 1, n / 2);
                // restate pair 0 in the second half
                let s = n / 2 + rng.below((n - 3) - n / 2 - 2);
                toks[s] = KEY;
                toks[s + 1] = keys[0];
                toks[s + 2] = vals[0];
                toks.truncate(n - 2);
                TaskInstance {
                    context: toks,
                    query: vec![KEY, keys[0]],
                    expected: vec![vals[0]],
                }
            }
            TaskKind::Passkey => {
                let (keys, vals) = place_pairs(&mut toks, rng, vocab, 1, 1, n - 3);
                toks.truncate(n - 2);
                TaskInstance {
                    context: toks,
                    query: vec![KEY, keys[0]],
                    expected: vec![vals[0]],
                }
            }
            TaskKind::Induction { period } => {
                let p = (period as usize).min(n / 3).max(4);
                let seg: Vec<u32> = (0..p).map(|_| content(rng, vocab)).collect();
                for i in 0..n {
                    toks[i] = seg[i % p];
                }
                toks[0] = BOS;
                // the document stops 4 tokens early; the first 2 held-out
                // tokens arrive as the post-compression "query", the model
                // must continue the copy for 2 more
                let cut = n - 4;
                let query = vec![seg[cut % p], seg[(cut + 1) % p]];
                let expected = vec![seg[(cut + 2) % p], seg[(cut + 3) % p]];
                TaskInstance { context: toks[..cut].to_vec(), query, expected }
            }
        }
    }
}

/// Score one decoded continuation against the expected tokens.
pub fn score(expected: &[u32], got: &[u32]) -> f64 {
    if expected.is_empty() {
        return 1.0;
    }
    let hits = expected
        .iter()
        .zip(got)
        .filter(|(a, b)| a == b)
        .count();
    hits as f64 / expected.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_13_named_tasks() {
        let s = task_suite();
        assert_eq!(s.len(), 13);
        let names: std::collections::HashSet<&str> = s.iter().map(|t| t.name).collect();
        assert_eq!(names.len(), 13);
        assert!(names.contains("p.ret"));
    }

    #[test]
    fn instances_well_formed() {
        let mut rng = Rng::seed_from(1);
        for task in task_suite() {
            for _ in 0..5 {
                let inst = task.kind.generate(&mut rng, 256, 64);
                assert!(!inst.context.is_empty(), "{}", task.name);
                assert!(!inst.query.is_empty(), "{}", task.name);
                assert!(!inst.expected.is_empty());
                assert!(inst.context.iter().all(|&t| t < 64), "{}", task.name);
                assert!(inst.query.iter().all(|&t| t < 64));
                assert!(inst.expected.iter().all(|&t| (6..64).contains(&t)));
                assert_eq!(inst.context[0], BOS);
            }
        }
    }

    #[test]
    fn retrieval_answer_is_recoverable_from_context() {
        // the [KEY k v] pair for the queried key must exist in context
        let mut rng = Rng::seed_from(2);
        let inst = TaskKind::Passkey.generate(&mut rng, 200, 64);
        let n = inst.context.len();
        assert_eq!(inst.query[0], KEY);
        let qk = inst.query[1];
        let found = (0..n - 2).any(|i| {
            inst.context[i] == KEY
                && inst.context[i + 1] == qk
                && inst.context[i + 2] == inst.expected[0]
        });
        assert!(found);
    }

    #[test]
    fn depth_band_respected() {
        let mut rng = Rng::seed_from(3);
        let kind = TaskKind::KvDepth { lo_pct: 60, hi_pct: 85 };
        let inst = kind.generate(&mut rng, 300, 64);
        // all KEY markers in the body sit within [60%, 85%) of the context
        let n = inst.context.len();
        for i in 1..n - 2 {
            if inst.context[i] == KEY {
                let pct = i * 100 / n;
                assert!((60..88).contains(&pct), "KEY at {pct}%");
            }
        }
    }

    #[test]
    fn induction_expectation_is_continuation() {
        let mut rng = Rng::seed_from(4);
        let inst = TaskKind::Induction { period: 16 }.generate(&mut rng, 256, 64);
        let cut = inst.context.len();
        // query + expected continue the periodic pattern
        assert_eq!(inst.query[0], inst.context[cut - 16]);
        assert_eq!(inst.query[1], inst.context[cut - 15]);
        assert_eq!(inst.expected[0], inst.context[cut - 14]);
        assert_eq!(inst.expected[1], inst.context[cut - 13]);
    }

    #[test]
    fn score_fraction() {
        assert_eq!(score(&[1, 2], &[1, 2]), 1.0);
        assert_eq!(score(&[1, 2], &[1, 3]), 0.5);
        assert_eq!(score(&[1, 2], &[0, 0]), 0.0);
        assert_eq!(score(&[1, 2], &[1]), 0.5); // short output
    }

    #[test]
    fn deterministic_per_seed() {
        let a = TaskKind::Passkey.generate(&mut Rng::seed_from(9), 128, 64);
        let b = TaskKind::Passkey.generate(&mut Rng::seed_from(9), 128, 64);
        assert_eq!(a.context, b.context);
        assert_eq!(a.query, b.query);
        assert_eq!(a.expected, b.expected);
    }
}
