//! Synthetic attention workloads at the paper's benchmark shapes.
//!
//! The BigGAN / T2T-ViT tensors themselves are not available offline
//! (DESIGN.md §3); we reproduce the *shapes* exactly and approximate the
//! activation statistics: vision attention activations are near-Gaussian
//! with mild anisotropy and a non-zero mean direction, which we model by
//! a low-rank colouring plus mean offset (the anisotropy is what makes
//! coreset methods interesting — pure isotropy is their best case, so we
//! avoid it).

use crate::linalg::{gemm, Matrix};
use crate::rng::Rng;

/// A (Q, K, V) attention problem plus its softmax scale.
pub struct AttentionWorkload {
    pub q: Matrix,
    pub k: Matrix,
    pub v: Matrix,
    pub beta: f32,
    pub label: String,
}

/// i.i.d. Gaussian QKV (the Fig. 3 setting: "independent standard
/// Gaussian entries", β = 1/√d).
pub fn gaussian_qkv(rng: &mut Rng, m: usize, n: usize, d: usize, dv: usize) -> AttentionWorkload {
    AttentionWorkload {
        q: Matrix::randn(rng, m, d),
        k: Matrix::randn(rng, n, d),
        v: Matrix::randn(rng, n, dv),
        beta: 1.0 / (d as f32).sqrt(),
        label: format!("gaussian m={m} n={n} d={d}"),
    }
}

/// Anisotropic "activation-like" QKV: low-rank colouring + mean offset.
/// `aniso_rank` directions carry `aniso_gain`× the variance.
pub fn activation_qkv(
    rng: &mut Rng,
    m: usize,
    n: usize,
    d: usize,
    dv: usize,
    aniso_rank: usize,
    aniso_gain: f32,
) -> AttentionWorkload {
    let colour = |x: Matrix, rng: &mut Rng| -> Matrix {
        let r = aniso_rank.min(d);
        if r == 0 {
            return x;
        }
        let dirs = Matrix::randn(rng, r, d);
        // x + gain * (x dirsᵀ) dirs / d  — boost variance along `dirs`
        let proj = gemm::matmul_transb(&x, &dirs); // m×r
        let boost = gemm::matmul(&proj, &dirs); // m×d
        let mut out = x;
        for (o, b) in out.as_mut_slice().iter_mut().zip(boost.as_slice()) {
            *o += aniso_gain * b / d as f32;
        }
        out
    };
    let mean: Vec<f32> = (0..d).map(|i| 0.3 * ((i as f32) * 0.7).sin()).collect();
    let mut q = colour(Matrix::randn(rng, m, d), rng);
    let mut k = colour(Matrix::randn(rng, n, d), rng);
    q.add_row_vector_mut(&mean);
    k.add_row_vector_mut(&mean);
    AttentionWorkload {
        q,
        k,
        v: Matrix::randn(rng, n, dv),
        beta: 1.0 / (d as f32).sqrt(),
        label: format!("activation m={m} n={n} d={d}"),
    }
}

/// The BigGAN-512 attention-layer shapes (Sec. 4.1): Q 4096×64,
/// K 1024×64, V 1024×256.
pub fn biggan_shapes() -> (usize, usize, usize, usize) {
    (4096, 1024, 64, 256)
}

/// T2T-ViT layer shapes (Sec. 4.2): layer 1 (3136, 64), layer 2 (784, 64)
/// with self-attention (m = n) and d_v = d.
pub fn t2t_vit_shapes() -> [(usize, usize, usize, usize); 2] {
    [(3136, 3136, 64, 64), (784, 784, 64, 64)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_paper() {
        assert_eq!(biggan_shapes(), (4096, 1024, 64, 256));
        assert_eq!(t2t_vit_shapes()[0], (3136, 3136, 64, 64));
        assert_eq!(t2t_vit_shapes()[1], (784, 784, 64, 64));
    }

    #[test]
    fn gaussian_workload_statistics() {
        let mut rng = Rng::seed_from(1);
        let w = gaussian_qkv(&mut rng, 64, 128, 16, 8);
        assert_eq!((w.q.rows(), w.q.cols()), (64, 16));
        assert_eq!((w.k.rows(), w.k.cols()), (128, 16));
        assert_eq!((w.v.rows(), w.v.cols()), (128, 8));
        assert!((w.beta - 0.25).abs() < 1e-6);
        let mean: f64 = w.k.as_slice().iter().map(|&x| x as f64).sum::<f64>()
            / w.k.as_slice().len() as f64;
        assert!(mean.abs() < 0.1);
    }

    #[test]
    fn activation_workload_is_anisotropic() {
        let mut rng = Rng::seed_from(2);
        let iso = gaussian_qkv(&mut rng, 256, 256, 16, 8);
        let ani = activation_qkv(&mut rng, 256, 256, 16, 8, 2, 4.0);
        // anisotropic keys have a larger top singular direction than iso:
        // compare ‖KᵀK‖_op via power iteration on the f64 gram
        let gram = |k: &Matrix| {
            let d = k.cols();
            let mut g = vec![0.0f64; d * d];
            for i in 0..k.rows() {
                let r = k.row(i);
                for a in 0..d {
                    for b in 0..d {
                        g[a * d + b] += r[a] as f64 * r[b] as f64;
                    }
                }
            }
            crate::linalg::op_norm_sym_f64(&g, d, 100)
        };
        assert!(gram(&ani.k) > gram(&iso.k) * 1.3);
    }
}
