//! Workload generators: synthetic attention inputs (Tab. 2/3, Fig. 3),
//! the 13-task long-context suite standing in for LongBench-E (Tab. 4),
//! and Poisson arrival traces for the serving benches.

pub mod gaussian;
pub mod tasks;
pub mod trace;

pub use gaussian::{biggan_shapes, gaussian_qkv, t2t_vit_shapes, AttentionWorkload};
pub use tasks::{task_suite, LongContextTask, TaskInstance, TaskKind};
pub use trace::{poisson_trace, shaped_trace, Arrival, TraceShape};
