//! Seeded pseudo-random number generation substrate.
//!
//! The offline build has no `rand` crate, so this module provides the
//! generators the stack needs: [`Rng`] is Xoshiro256++ (Blackman & Vigna)
//! seeded through SplitMix64, with helpers for uniforms, Gaussians
//! (Box–Muller), categorical sampling (used by the RPNYS pivot rule, Eq. 3),
//! permutations and subset sampling.
//!
//! Determinism is part of the contract: every experiment binary takes a
//! `--seed` and all results in EXPERIMENTS.md are reproducible bit-for-bit
//! on the same target.

/// SplitMix64 step — used for seeding and as a cheap standalone generator.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Xoshiro256++ generator. Not cryptographic; fast, high-quality for
/// simulation workloads.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller Gaussian.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Construct from a 64-bit seed (expanded through SplitMix64).
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent child stream (for per-bin / per-thread use).
    pub fn fork(&mut self, stream: u64) -> Rng {
        let mut sm = self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` (Lemire rejection-free bounded draw).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 128-bit multiply method; bias negligible for n << 2^64.
        let x = self.next_u64();
        (((x as u128) * (n as u128)) >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        loop {
            let u1 = self.uniform();
            let u2 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Exponential with given rate (inverse-CDF).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        let mut u = self.uniform();
        if u <= 0.0 {
            u = f64::MIN_POSITIVE;
        }
        -u.ln() / rate
    }

    /// Sample an index from unnormalised non-negative weights.
    ///
    /// This is the RPNYS pivot rule (Eq. 3): `p_l ∝ h_res(k_l, k_l)`.
    /// Returns `None` if the total mass is not strictly positive.
    pub fn categorical(&mut self, weights: &[f64]) -> Option<usize> {
        let total: f64 = weights.iter().filter(|w| w.is_finite() && **w > 0.0).sum();
        if !(total > 0.0) {
            return None;
        }
        let mut u = self.uniform() * total;
        let mut last_pos = None;
        for (i, &w) in weights.iter().enumerate() {
            if !(w > 0.0) || !w.is_finite() {
                continue;
            }
            last_pos = Some(i);
            if u < w {
                return Some(i);
            }
            u -= w;
        }
        last_pos // float round-off: fall back to the last positive entry
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// `k` distinct indices sampled uniformly from `[0, n)`, sorted.
    pub fn sample_without_replacement(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        // Partial Fisher–Yates over an index map (sparse for small k).
        if k * 4 < n {
            use std::collections::HashMap;
            let mut swapped: HashMap<usize, usize> = HashMap::new();
            let mut out = Vec::with_capacity(k);
            for i in 0..k {
                let j = i + self.below(n - i);
                let vi = *swapped.get(&i).unwrap_or(&i);
                let vj = *swapped.get(&j).unwrap_or(&j);
                out.push(vj);
                swapped.insert(j, vi);
            }
            out.sort_unstable();
            out
        } else {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx.sort_unstable();
            idx
        }
    }

    /// Fill a slice with standard normals (f32).
    pub fn fill_gaussian_f32(&mut self, out: &mut [f32]) {
        for x in out.iter_mut() {
            *x = self.gaussian() as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_construction() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut rng = Rng::seed_from(7);
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut rng = Rng::seed_from(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Rng::seed_from(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut rng = Rng::seed_from(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let i = rng.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn categorical_respects_weights() {
        let mut rng = Rng::seed_from(5);
        let w = [0.0, 1.0, 3.0, 0.0];
        let mut counts = [0usize; 4];
        for _ in 0..40_000 {
            counts[rng.categorical(&w).unwrap()] += 1;
        }
        assert_eq!(counts[0], 0);
        assert_eq!(counts[3], 0);
        let ratio = counts[2] as f64 / counts[1] as f64;
        assert!((ratio - 3.0).abs() < 0.25, "ratio={ratio}");
    }

    #[test]
    fn categorical_empty_and_zero() {
        let mut rng = Rng::seed_from(6);
        assert_eq!(rng.categorical(&[]), None);
        assert_eq!(rng.categorical(&[0.0, 0.0]), None);
        assert_eq!(rng.categorical(&[f64::NAN, 0.0]), None);
    }

    #[test]
    fn sample_without_replacement_distinct_sorted() {
        let mut rng = Rng::seed_from(8);
        for &(n, k) in &[(100usize, 5usize), (100, 90), (16, 16), (1, 1), (50, 0)] {
            let s = rng.sample_without_replacement(n, k);
            assert_eq!(s.len(), k);
            for w in s.windows(2) {
                assert!(w[0] < w[1], "not strictly increasing: {s:?}");
            }
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Rng::seed_from(10);
        let n = 100_000;
        let mean = (0..n).map(|_| rng.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::seed_from(1234);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::seed_from(2);
        let mut xs: Vec<usize> = (0..37).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..37).collect::<Vec<_>>());
    }
}
