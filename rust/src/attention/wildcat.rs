//! WILDCAT (Alg. 4): the drop-in attention module.
//!
//! Computes the per-column value range, the query radius `R_Q`, compresses
//! `(K, V)` with COMPRESSKV, and runs the weighted forward pass WTDATTN.
//! Runtime `O(nr²/B² + nrd/B + mrd)` — near-linear for `r ∈ n^{o(1)}`.

use super::compress::{compress_kv, CompressOpts};
use super::wtd::{wtd_attention, ClipRange};
use crate::linalg::Matrix;
use crate::rng::Rng;

/// WildCat hyper-parameters (Alg. 4 inputs).
#[derive(Clone, Copy, Debug)]
pub struct WildcatParams {
    /// Coreset size `r`.
    pub rank: usize,
    /// Bin count `B` (Sec. 2.5). `1` = unbinned.
    pub bins: usize,
    /// Attention scale `β`; `None` selects `1/√d` at call time.
    pub beta: Option<f64>,
}

impl Default for WildcatParams {
    fn default() -> Self {
        WildcatParams { rank: 64, bins: 1, beta: None }
    }
}

impl WildcatParams {
    pub fn beta_for(&self, d: usize) -> f64 {
        self.beta.unwrap_or(1.0 / (d.max(1) as f64).sqrt())
    }
}

/// WILDCAT attention (Alg. 4): approximate `softmax(β Q Kᵀ) V`.
pub fn wildcat_attention(
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    params: &WildcatParams,
    rng: &mut Rng,
) -> Matrix {
    assert_eq!(q.cols(), k.cols(), "q/k head dim mismatch");
    assert_eq!(k.rows(), v.rows(), "k/v length mismatch");
    let beta = params.beta_for(q.cols());
    let clip = ClipRange::from_values(v);
    let r_q = q.max_row_norm();
    let opts = CompressOpts { rank: params.rank, bins: params.bins, beta, r_q };
    let c = compress_kv(k, v, &opts, rng);
    wtd_attention(q, &c.keys, &c.values, &c.weights, &clip, beta as f32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::exact_attention;
    use crate::linalg::norms::max_abs_diff;

    #[test]
    fn full_rank_recovers_exact() {
        let mut rng = Rng::seed_from(1);
        let q = Matrix::randn(&mut rng, 20, 6);
        let k = Matrix::randn(&mut rng, 30, 6);
        let v = Matrix::randn(&mut rng, 30, 4);
        let params = WildcatParams { rank: 30, bins: 1, beta: None };
        let o = wildcat_attention(&q, &k, &v, &params, &mut rng);
        let e = exact_attention(&q, &k, &v, params.beta_for(6) as f32);
        assert!(max_abs_diff(&o, &e) < 2e-4, "err={}", max_abs_diff(&o, &e));
    }

    #[test]
    fn error_decreases_with_rank() {
        let mut data_rng = Rng::seed_from(2);
        let n = 256;
        let q = Matrix::randn(&mut data_rng, 128, 16);
        let k = Matrix::randn(&mut data_rng, n, 16);
        let v = Matrix::randn(&mut data_rng, n, 8);
        let e = exact_attention(&q, &k, &v, 0.25);
        let mut errs = Vec::new();
        for rank in [4usize, 32, 128] {
            // average over seeds (RPNYS is randomised)
            let mut tot = 0.0;
            for seed in 0..3 {
                let mut rng = Rng::seed_from(100 + seed);
                let params = WildcatParams { rank, bins: 1, beta: Some(0.25) };
                let o = wildcat_attention(&q, &k, &v, &params, &mut rng);
                tot += max_abs_diff(&o, &e);
            }
            errs.push(tot / 3.0);
        }
        assert!(
            errs[2] < errs[0],
            "error should decrease from r=4 to r=128: {errs:?}"
        );
        // and at r = n/2 the approximation should be decent
        assert!(errs[2] < 0.5, "errs={errs:?}");
    }

    #[test]
    fn output_within_value_hull() {
        let mut rng = Rng::seed_from(3);
        let q = Matrix::randn(&mut rng, 40, 8);
        let k = Matrix::randn(&mut rng, 100, 8);
        let v = Matrix::randn(&mut rng, 100, 4);
        let params = WildcatParams { rank: 12, bins: 2, beta: None };
        let o = wildcat_attention(&q, &k, &v, &params, &mut rng);
        let (mn, mx) = v.col_min_max();
        for i in 0..o.rows() {
            for j in 0..o.cols() {
                assert!(o.get(i, j) >= mn[j] - 1e-6 && o.get(i, j) <= mx[j] + 1e-6);
            }
        }
    }

    #[test]
    fn binned_matches_unbinned_quality_ballpark() {
        let mut data_rng = Rng::seed_from(4);
        let q = Matrix::randn(&mut data_rng, 64, 8);
        let k = Matrix::randn(&mut data_rng, 256, 8);
        let v = Matrix::randn(&mut data_rng, 256, 4);
        let e = exact_attention(&q, &k, &v, 0.35);
        let err_of = |bins: usize| {
            let mut tot = 0.0;
            for seed in 0..3 {
                let mut rng = Rng::seed_from(10 + seed);
                let params = WildcatParams { rank: 64, bins, beta: Some(0.35) };
                tot += max_abs_diff(&wildcat_attention(&q, &k, &v, &params, &mut rng), &e);
            }
            tot / 3.0
        };
        let e1 = err_of(1);
        let e4 = err_of(4);
        // binning trades some accuracy for speed but stays the same order
        assert!(e4 < e1 * 4.0 + 0.2, "e1={e1} e4={e4}");
    }

    #[test]
    fn beta_default_is_inv_sqrt_d() {
        let p = WildcatParams::default();
        assert!((p.beta_for(64) - 0.125).abs() < 1e-12);
        assert!((p.beta_for(0) - 1.0).abs() < 1e-12);
    }
}
