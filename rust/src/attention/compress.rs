//! COMPRESSKV (Alg. 2): distil `(K, V)` into a weighted coreset
//! `(K_S, V_S, w)` of size `r`.
//!
//! Pipeline per the paper: recentre keys (Sec. 2.4) → split into `B`
//! contiguous bins → per bin, compute the key radius, the temperature
//! (Eq. 4) and run RPNYS at rank `r/B` with kernel
//! `h_τ = exp(β⟨·,·⟩/τ²)` → concatenate, re-add the key mean, and form
//! `V_S = W V`, `w = W 1_n` with the block-diagonal weights.
//!
//! Bins run in parallel on the [`crate::exec`] pool with independent
//! forked RNG streams (deterministic given the input seed).

use crate::exec;
use crate::kernels::{recenter_keys, temperature};
use crate::linalg::Matrix;
use crate::rng::Rng;
use crate::rpnys::rpnys;

/// Options for COMPRESSKV.
#[derive(Clone, Copy, Debug)]
pub struct CompressOpts {
    /// Total coreset size `r` (split evenly across bins).
    pub rank: usize,
    /// Number of parallel bins `B` (Sec. 2.5).
    pub bins: usize,
    /// Attention scale `β` (typically `1/√d`).
    pub beta: f64,
    /// Query radius `R_Q = ‖Q‖_{2,∞}`; used only by the temperature rule.
    pub r_q: f64,
}

/// The compressed cache: coreset keys (original coordinates), compressed
/// values, normalisation weights and the global indices of the coreset.
#[derive(Clone, Debug)]
pub struct CompressedKV {
    /// `K_S ∈ R^{r×d}` — selected keys with the mean re-added.
    pub keys: Matrix,
    /// `V_S = W V ∈ R^{r×d_v}` — every value row participates.
    pub values: Matrix,
    /// `w = W 1_n` — softmax normalisation weights.
    pub weights: Vec<f64>,
    /// Global indices of the selected keys (into the original `K`).
    pub indices: Vec<usize>,
    /// Original sequence length this coreset summarises.
    pub source_len: usize,
}

impl CompressedKV {
    pub fn rank(&self) -> usize {
        self.keys.rows()
    }

    /// Memory footprint in f32-equivalents (keys + values + weights) —
    /// the Tab. 4 compression accounting.
    pub fn footprint_floats(&self) -> usize {
        self.keys.rows() * self.keys.cols()
            + self.values.rows() * self.values.cols()
            + self.weights.len()
    }
}

/// Result of compressing one bin (local to the bin's row range).
struct BinResult {
    indices: Vec<usize>, // global
    keys: Matrix,
    values: Matrix,
    weights: Vec<f64>,
}

impl Default for BinResult {
    fn default() -> Self {
        BinResult {
            indices: Vec::new(),
            keys: Matrix::zeros(0, 0),
            values: Matrix::zeros(0, 0),
            weights: Vec::new(),
        }
    }
}

impl Clone for BinResult {
    fn clone(&self) -> Self {
        BinResult {
            indices: self.indices.clone(),
            keys: self.keys.clone(),
            values: self.values.clone(),
            weights: self.weights.clone(),
        }
    }
}

/// COMPRESSKV (Alg. 2). `k` is n×d, `v` is n×d_v. Returns a coreset of at
/// most `opts.rank` weighted key/value pairs.
pub fn compress_kv(k: &Matrix, v: &Matrix, opts: &CompressOpts, rng: &mut Rng) -> CompressedKV {
    assert_eq!(k.rows(), v.rows(), "key/value length mismatch");
    let n = k.rows();
    if n == 0 || opts.rank == 0 {
        return CompressedKV {
            keys: Matrix::zeros(0, k.cols()),
            values: Matrix::zeros(0, v.cols()),
            weights: Vec::new(),
            indices: Vec::new(),
            source_len: n,
        };
    }
    // Degenerate: coreset at least as large as the input — keep everything
    // with unit weights (exact).
    if opts.rank >= n {
        return CompressedKV {
            keys: k.clone(),
            values: v.clone(),
            weights: vec![1.0; n],
            indices: (0..n).collect(),
            source_len: n,
        };
    }

    let bins = opts.bins.clamp(1, opts.rank.min(n));
    let rank_per_bin = opts.rank.div_ceil(bins);
    let recentred = recenter_keys(k);

    // Contiguous binning (Alg. 2 "evenly divide rows").
    let base = n / bins;
    let rem = n % bins;
    let bin_range = |b: usize| {
        let start = b * base + b.min(rem);
        let end = start + base + usize::from(b < rem);
        start..end
    };

    // Independent RNG stream per bin: deterministic and order-free.
    let seeds: Vec<Rng> = (0..bins).map(|b| rng.fork(b as u64)).collect();
    let seed_cells: Vec<std::sync::Mutex<Rng>> =
        seeds.into_iter().map(std::sync::Mutex::new).collect();

    let results: Vec<BinResult> = exec::parallel_map(bins, |b| {
        let range = bin_range(b);
        let start = range.start;
        let kb = recentred.keys.slice_rows(range.start, range.end);
        let vb = v.slice_rows(range.start, range.end);
        let n_b = kb.rows();
        let r_k = kb.max_row_norm();
        let tau = temperature(opts.beta, opts.r_q, r_k, n_b);
        let scale_eff = opts.beta / (tau * tau);
        let mut bin_rng = seed_cells[b].lock().unwrap().clone();
        let approx = rpnys(&kb, scale_eff, rank_per_bin.min(n_b), &mut bin_rng);
        let values = approx.compress_values(&vb);
        let weights = approx.weight_row_sums();
        let keys = kb.select_rows(&approx.indices);
        BinResult {
            indices: approx.indices.iter().map(|&i| i + start).collect(),
            keys,
            values,
            weights,
        }
    });

    // Concatenate bins and re-add the key mean.
    let mut indices = Vec::new();
    let mut weights = Vec::new();
    let key_parts: Vec<&Matrix> = results.iter().filter(|r| r.keys.rows() > 0).map(|r| &r.keys).collect();
    let val_parts: Vec<&Matrix> =
        results.iter().filter(|r| r.values.rows() > 0).map(|r| &r.values).collect();
    for r in &results {
        indices.extend_from_slice(&r.indices);
        weights.extend_from_slice(&r.weights);
    }
    let mut keys = if key_parts.is_empty() {
        Matrix::zeros(0, k.cols())
    } else {
        Matrix::vcat(&key_parts)
    };
    keys.add_row_vector_mut(&recentred.mean);
    let values = if val_parts.is_empty() {
        Matrix::zeros(0, v.cols())
    } else {
        Matrix::vcat(&val_parts)
    };
    CompressedKV { keys, values, weights, indices, source_len: n }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Cases;

    fn opts(rank: usize, bins: usize) -> CompressOpts {
        CompressOpts { rank, bins, beta: 0.25, r_q: 3.0 }
    }

    #[test]
    fn shapes_and_indices() {
        Cases::new(12).run(|rng| {
            let n = 16 + rng.below(60);
            let d = 2 + rng.below(6);
            let dv = 1 + rng.below(5);
            let k = Matrix::randn(rng, n, d);
            let v = Matrix::randn(rng, n, dv);
            let bins = 1 + rng.below(4);
            let rank = (4 + rng.below(12)).min(n - 1);
            let c = compress_kv(&k, &v, &opts(rank, bins), rng);
            assert!(c.rank() <= rank + bins); // ceil split may add < bins
            assert_eq!(c.keys.rows(), c.values.rows());
            assert_eq!(c.keys.rows(), c.weights.len());
            assert_eq!(c.keys.cols(), d);
            assert_eq!(c.values.cols(), dv);
            assert_eq!(c.source_len, n);
            // indices valid and unique
            let mut idx = c.indices.clone();
            idx.sort_unstable();
            let len0 = idx.len();
            idx.dedup();
            assert_eq!(idx.len(), len0);
            assert!(idx.iter().all(|&i| i < n));
        });
    }

    #[test]
    fn coreset_keys_are_original_rows() {
        let mut rng = Rng::seed_from(2);
        let k = Matrix::randn(&mut rng, 40, 4);
        let v = Matrix::randn(&mut rng, 40, 3);
        let c = compress_kv(&k, &v, &opts(8, 2), &mut rng);
        for (row, &gi) in c.indices.iter().enumerate() {
            for j in 0..4 {
                assert!(
                    (c.keys.get(row, j) - k.get(gi, j)).abs() < 1e-4,
                    "coreset key {row} != original row {gi}"
                );
            }
        }
    }

    #[test]
    fn full_rank_is_identity_compression() {
        let mut rng = Rng::seed_from(3);
        let k = Matrix::randn(&mut rng, 10, 3);
        let v = Matrix::randn(&mut rng, 10, 2);
        let c = compress_kv(&k, &v, &opts(10, 1), &mut rng);
        assert_eq!(c.rank(), 10);
        assert_eq!(c.keys, k);
        assert_eq!(c.values, v);
        assert!(c.weights.iter().all(|&w| (w - 1.0).abs() < 1e-12));
    }

    #[test]
    fn empty_and_zero_rank() {
        let mut rng = Rng::seed_from(4);
        let k = Matrix::zeros(0, 3);
        let v = Matrix::zeros(0, 2);
        let c = compress_kv(&k, &v, &opts(5, 2), &mut rng);
        assert_eq!(c.rank(), 0);
        let k2 = Matrix::randn(&mut rng, 8, 3);
        let v2 = Matrix::randn(&mut rng, 8, 2);
        let c2 = compress_kv(&k2, &v2, &opts(0, 2), &mut rng);
        assert_eq!(c2.rank(), 0);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng1 = Rng::seed_from(77);
        let mut rng2 = Rng::seed_from(77);
        let mut data_rng = Rng::seed_from(5);
        let k = Matrix::randn(&mut data_rng, 64, 4);
        let v = Matrix::randn(&mut data_rng, 64, 4);
        let c1 = compress_kv(&k, &v, &opts(16, 4), &mut rng1);
        let c2 = compress_kv(&k, &v, &opts(16, 4), &mut rng2);
        assert_eq!(c1.indices, c2.indices);
        assert_eq!(c1.weights, c2.weights);
    }

    #[test]
    fn binning_covers_all_bins() {
        // with B bins, the coreset should draw from every bin's range
        let mut rng = Rng::seed_from(6);
        let k = Matrix::randn(&mut rng, 80, 4);
        let v = Matrix::randn(&mut rng, 80, 2);
        let bins = 4;
        let c = compress_kv(&k, &v, &opts(16, bins), &mut rng);
        for b in 0..bins {
            let lo = b * 20;
            let hi = lo + 20;
            assert!(
                c.indices.iter().any(|&i| i >= lo && i < hi),
                "bin {b} contributed no pivots: {:?}",
                c.indices
            );
        }
    }

    #[test]
    fn footprint_accounting() {
        let mut rng = Rng::seed_from(7);
        let k = Matrix::randn(&mut rng, 50, 4);
        let v = Matrix::randn(&mut rng, 50, 6);
        let c = compress_kv(&k, &v, &opts(10, 1), &mut rng);
        let r = c.rank();
        assert_eq!(c.footprint_floats(), r * 4 + r * 6 + r);
    }
}
