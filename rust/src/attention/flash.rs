//! Blocked online-softmax exact attention — the FlashAttention-2 stand-in
//! used as the "Exact" timing baseline in Fig. 3 / Tab. 2 / Tab. 3.
//!
//! The algorithm tiles keys/values into cache-sized blocks and maintains a
//! running (max, normaliser, output) triple per query, exactly as FA2 does
//! on GPU (Dao 2024), parallelised here across query blocks on the
//! [`crate::exec`] pool. The result is bitwise *not* identical to
//! [`super::exact_attention`] (different summation order) but agrees to
//! f32 round-off; tests pin that.

use crate::exec;
use crate::linalg::gemm::dot;
use crate::linalg::Matrix;

/// Key-block size: 64 keys × (d + d_v) floats stays inside L1/L2 for the
/// paper's head dims.
const KEY_BLOCK: usize = 64;
/// Query-block size per parallel task.
const QUERY_BLOCK: usize = 32;

/// Exact attention via blocked online softmax.
pub fn flash_attention(q: &Matrix, k: &Matrix, v: &Matrix, beta: f32) -> Matrix {
    assert_eq!(q.cols(), k.cols(), "q/k head dim mismatch");
    assert_eq!(k.rows(), v.rows(), "k/v length mismatch");
    let (m, n, dv) = (q.rows(), k.rows(), v.cols());
    let mut out = Matrix::zeros(m, dv);
    exec::parallel_chunks_mut(out.as_mut_slice(), QUERY_BLOCK * dv.max(1), |chunk_idx, rows| {
        let row0 = chunk_idx * QUERY_BLOCK;
        let rows_here = rows.len() / dv.max(1);
        // per-query state: running max, running denom, accumulated numerator
        let mut mx = vec![f32::NEG_INFINITY; rows_here];
        let mut denom = vec![0.0f64; rows_here];
        let mut acc = vec![0.0f64; rows_here * dv];
        let mut logits = vec![0.0f32; KEY_BLOCK];
        let mut kb = 0;
        while kb < n {
            let kend = (kb + KEY_BLOCK).min(n);
            for r in 0..rows_here {
                let qi = q.row(row0 + r);
                // block logits + block max
                let mut block_max = f32::NEG_INFINITY;
                for (jj, j) in (kb..kend).enumerate() {
                    let l = beta * dot(qi, k.row(j));
                    logits[jj] = l;
                    if l > block_max {
                        block_max = l;
                    }
                }
                let new_max = mx[r].max(block_max);
                let correction = if mx[r] == f32::NEG_INFINITY {
                    0.0
                } else {
                    ((mx[r] - new_max) as f64).exp()
                };
                denom[r] *= correction;
                for a in acc[r * dv..(r + 1) * dv].iter_mut() {
                    *a *= correction;
                }
                for (jj, j) in (kb..kend).enumerate() {
                    let p = ((logits[jj] - new_max) as f64).exp();
                    denom[r] += p;
                    let vr = v.row(j);
                    let ar = &mut acc[r * dv..(r + 1) * dv];
                    for (a, &x) in ar.iter_mut().zip(vr) {
                        *a += p * x as f64;
                    }
                }
                mx[r] = new_max;
            }
            kb = kend;
        }
        for r in 0..rows_here {
            let d = denom[r].max(f64::MIN_POSITIVE);
            for (o, a) in rows[r * dv..(r + 1) * dv]
                .iter_mut()
                .zip(&acc[r * dv..(r + 1) * dv])
            {
                *o = (*a / d) as f32;
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::exact_attention;
    use crate::util::prop::Cases;

    #[test]
    fn matches_exact() {
        Cases::new(16).run(|rng| {
            let m = 1 + rng.below(70);
            let n = 1 + rng.below(200); // crosses several key blocks
            let d = 1 + rng.below(16);
            let dv = 1 + rng.below(8);
            let q = Matrix::randn(rng, m, d);
            let k = Matrix::randn(rng, n, d);
            let v = Matrix::randn(rng, n, dv);
            let a = flash_attention(&q, &k, &v, 0.25);
            let b = exact_attention(&q, &k, &v, 0.25);
            for (x, y) in a.as_slice().iter().zip(b.as_slice()) {
                assert!((x - y).abs() < 2e-4, "{x} vs {y}");
            }
        });
    }

    #[test]
    fn single_block_case() {
        let mut rng = crate::rng::Rng::seed_from(4);
        let q = Matrix::randn(&mut rng, 3, 4);
        let k = Matrix::randn(&mut rng, 5, 4);
        let v = Matrix::randn(&mut rng, 5, 2);
        let a = flash_attention(&q, &k, &v, 0.5);
        let b = exact_attention(&q, &k, &v, 0.5);
        for (x, y) in a.as_slice().iter().zip(b.as_slice()) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn exact_block_boundary() {
        // n exactly a multiple of the key block
        let mut rng = crate::rng::Rng::seed_from(5);
        let q = Matrix::randn(&mut rng, 9, 4);
        let k = Matrix::randn(&mut rng, 128, 4);
        let v = Matrix::randn(&mut rng, 128, 3);
        let a = flash_attention(&q, &k, &v, 0.3);
        let b = exact_attention(&q, &k, &v, 0.3);
        for (x, y) in a.as_slice().iter().zip(b.as_slice()) {
            assert!((x - y).abs() < 2e-4);
        }
    }

    #[test]
    fn huge_logit_range_stable() {
        let q = Matrix::from_vec(vec![50.0, 0.0], 1, 2);
        let mut kdata = vec![0.0f32; 2 * 200];
        for j in 0..200 {
            kdata[2 * j] = (j as f32 - 100.0) * 0.5; // logits span ±2500
        }
        let k = Matrix::from_vec(kdata, 200, 2);
        let v = Matrix::from_fn(200, 1, |j, _| j as f32);
        let o = flash_attention(&q, &k, &v, 1.0);
        assert!(o.get(0, 0).is_finite());
        // fully attends the largest-logit key (index 199)
        assert!((o.get(0, 0) - 199.0).abs() < 1e-3);
    }
}
