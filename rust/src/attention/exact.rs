//! Exact softmax attention (Eq. 1), numerically stabilised with per-query
//! max subtraction. `O(m n d)` time, `O(n)` extra memory per thread.

use crate::exec;
use crate::linalg::gemm::dot;
use crate::linalg::Matrix;

/// `O = softmax(β Q Kᵀ) V` — the reference the whole paper approximates.
///
/// Parallel over query rows; logits for one query are materialised at a
/// time (O(n) scratch), so this scales to the Fig. 3 sequence lengths
/// without O(mn) memory.
pub fn exact_attention(q: &Matrix, k: &Matrix, v: &Matrix, beta: f32) -> Matrix {
    assert_eq!(q.cols(), k.cols(), "q/k head dim mismatch");
    assert_eq!(k.rows(), v.rows(), "k/v length mismatch");
    let (m, n, dv) = (q.rows(), k.rows(), v.cols());
    let mut out = Matrix::zeros(m, dv);
    exec::parallel_chunks_mut(out.as_mut_slice(), 16 * dv.max(1), |chunk_idx, rows| {
        let row0 = chunk_idx * 16;
        let mut logits = vec![0.0f32; n];
        let rows_here = rows.len() / dv.max(1);
        for r in 0..rows_here {
            let i = row0 + r;
            let qi = q.row(i);
            let mut mx = f32::NEG_INFINITY;
            for (j, l) in logits.iter_mut().enumerate() {
                *l = beta * dot(qi, k.row(j));
                if *l > mx {
                    mx = *l;
                }
            }
            let mut denom = 0.0f64;
            let out_row = &mut rows[r * dv..(r + 1) * dv];
            let mut acc = vec![0.0f64; dv];
            for (j, &l) in logits.iter().enumerate() {
                let p = ((l - mx) as f64).exp();
                denom += p;
                for (a, &x) in acc.iter_mut().zip(v.row(j)) {
                    *a += p * x as f64;
                }
            }
            for (o, a) in out_row.iter_mut().zip(&acc) {
                *o = (*a / denom) as f32;
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::util::prop::Cases;

    /// Brute force oracle in f64.
    pub(crate) fn attention_oracle(q: &Matrix, k: &Matrix, v: &Matrix, beta: f32) -> Matrix {
        let (m, n, dv) = (q.rows(), k.rows(), v.cols());
        let mut out = Matrix::zeros(m, dv);
        for i in 0..m {
            let logits: Vec<f64> = (0..n)
                .map(|j| beta as f64 * Matrix::row_dot(q, i, k, j))
                .collect();
            let mx = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let ps: Vec<f64> = logits.iter().map(|l| (l - mx).exp()).collect();
            let denom: f64 = ps.iter().sum();
            for jd in 0..dv {
                let num: f64 = (0..n).map(|j| ps[j] * v.get(j, jd) as f64).sum();
                out.set(i, jd, (num / denom) as f32);
            }
        }
        out
    }

    #[test]
    fn matches_oracle() {
        Cases::new(16).run(|rng| {
            let m = 1 + rng.below(40);
            let n = 1 + rng.below(50);
            let d = 1 + rng.below(16);
            let dv = 1 + rng.below(12);
            let q = Matrix::randn(rng, m, d);
            let k = Matrix::randn(rng, n, d);
            let v = Matrix::randn(rng, n, dv);
            let got = exact_attention(&q, &k, &v, 0.3);
            let want = attention_oracle(&q, &k, &v, 0.3);
            for (a, b) in got.as_slice().iter().zip(want.as_slice()) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        });
    }

    #[test]
    fn rows_are_convex_combinations() {
        // each output row lies in the convex hull of value rows
        let mut rng = Rng::seed_from(1);
        let q = Matrix::randn(&mut rng, 20, 8);
        let k = Matrix::randn(&mut rng, 30, 8);
        let v = Matrix::randn(&mut rng, 30, 4);
        let o = exact_attention(&q, &k, &v, 0.125);
        let (mn, mx) = v.col_min_max();
        for i in 0..o.rows() {
            for j in 0..o.cols() {
                let x = o.get(i, j);
                assert!(x >= mn[j] - 1e-4 && x <= mx[j] + 1e-4);
            }
        }
    }

    #[test]
    fn shift_invariance_of_keys() {
        // Sec 2.4: output invariant under global key recentring.
        let mut rng = Rng::seed_from(2);
        let q = Matrix::randn(&mut rng, 10, 6);
        let k = Matrix::randn(&mut rng, 25, 6);
        let v = Matrix::randn(&mut rng, 25, 3);
        let shift: Vec<f32> = (0..6).map(|i| i as f32 * 0.5 - 1.0).collect();
        let k_shift = k.sub_row_vector(&shift);
        let a = exact_attention(&q, &k, &v, 0.2);
        let b = exact_attention(&q, &k_shift, &v, 0.2);
        for (x, y) in a.as_slice().iter().zip(b.as_slice()) {
            assert!((x - y).abs() < 2e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn scale_invariance_of_qk() {
        // A invariant under Q→τQ, K→K/τ.
        let mut rng = Rng::seed_from(3);
        let q = Matrix::randn(&mut rng, 8, 5);
        let k = Matrix::randn(&mut rng, 12, 5);
        let v = Matrix::randn(&mut rng, 12, 4);
        let tau = 2.5f32;
        let a = exact_attention(&q, &k, &v, 0.3);
        let b = exact_attention(&q.scale(tau), &k.scale(1.0 / tau), &v, 0.3);
        for (x, y) in a.as_slice().iter().zip(b.as_slice()) {
            assert!((x - y).abs() < 2e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn extreme_logits_stay_finite() {
        let q = Matrix::from_vec(vec![100.0, 100.0], 1, 2);
        let k = Matrix::from_vec(vec![100.0, 100.0, -100.0, -100.0], 2, 2);
        let v = Matrix::from_vec(vec![1.0, 2.0], 2, 1);
        let o = exact_attention(&q, &k, &v, 1.0);
        assert!(o.get(0, 0).is_finite());
        assert!((o.get(0, 0) - 1.0).abs() < 1e-5); // fully attends first key
    }
}
