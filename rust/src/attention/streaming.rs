//! Streaming / causal WildCat — the paper's §5 future-work extension,
//! built with the divide-and-conquer evaluation it suggests (à la
//! HyperAttention's causal recursion, here in its simplest chunked form).
//!
//! Keys are consumed in arrival order and grouped into chunks of size
//! `chunk`. Completed chunks are frozen into COMPRESSKV coresets
//! (`rank_per_chunk` weighted points each); the *current* chunk stays
//! exact. A query at position `i` then attends over
//!
//! `coresets(chunks fully before i)  ∪  exact keys of i's own chunk ≤ i`,
//!
//! which respects causality exactly at the chunk granularity and
//! approximately (via the coreset) for the past. Cost per token:
//! `O((n/c)·r·d + c·d)` — near-linear overall for `r, c ∈ n^{o(1)}`-ish
//! choices, versus `O(n·d)` per token for exact causal attention.

use super::compress::{compress_kv, CompressOpts};
use super::wtd::{wtd_attention, ClipRange};
use crate::linalg::Matrix;
use crate::rng::Rng;

/// Streaming attention state: frozen coresets + the live tail chunk.
pub struct StreamingWildcat {
    pub chunk: usize,
    pub rank_per_chunk: usize,
    pub bins: usize,
    beta: f64,
    d_k: usize,
    d_v: usize,
    // frozen summary of all completed chunks
    frozen_keys: Matrix,
    frozen_values: Matrix,
    frozen_weights: Vec<f64>,
    // live (uncompressed) tail
    tail_keys: Matrix,
    tail_values: Matrix,
    /// total keys consumed
    len: usize,
    rng: Rng,
}

impl StreamingWildcat {
    pub fn new(
        chunk: usize,
        rank_per_chunk: usize,
        bins: usize,
        beta: f64,
        d_k: usize,
        d_v: usize,
        seed: u64,
    ) -> Self {
        assert!(chunk >= 1 && rank_per_chunk >= 1);
        StreamingWildcat {
            chunk,
            rank_per_chunk,
            bins: bins.max(1),
            beta,
            d_k,
            d_v,
            frozen_keys: Matrix::zeros(0, d_k),
            frozen_values: Matrix::zeros(0, d_v),
            frozen_weights: Vec::new(),
            tail_keys: Matrix::zeros(0, d_k),
            tail_values: Matrix::zeros(0, d_v),
            len: 0,
            rng: Rng::seed_from(seed),
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Physical summary size (frozen coreset points + live tail).
    pub fn state_size(&self) -> usize {
        self.frozen_keys.rows() + self.tail_keys.rows()
    }

    /// Ingest one (key, value); freezes the tail into a coreset when the
    /// chunk completes.
    pub fn push(&mut self, key: &[f32], value: &[f32]) {
        assert_eq!(key.len(), self.d_k);
        assert_eq!(value.len(), self.d_v);
        self.tail_keys.push_row(key);
        self.tail_values.push_row(value);
        self.len += 1;
        if self.tail_keys.rows() >= self.chunk {
            self.freeze_tail();
        }
    }

    fn freeze_tail(&mut self) {
        let n_tail = self.tail_keys.rows();
        if n_tail == 0 {
            return;
        }
        let opts = CompressOpts {
            rank: self.rank_per_chunk.min(n_tail),
            bins: self.bins,
            beta: self.beta,
            // query radius proxy: keys of the same stream share scale
            r_q: self.tail_keys.max_row_norm().max(1e-9),
        };
        let c = compress_kv(&self.tail_keys, &self.tail_values, &opts, &mut self.rng);
        self.frozen_keys = Matrix::vcat(&[&self.frozen_keys, &c.keys]);
        self.frozen_values = Matrix::vcat(&[&self.frozen_values, &c.values]);
        self.frozen_weights.extend_from_slice(&c.weights);
        self.tail_keys = Matrix::zeros(0, self.d_k);
        self.tail_values = Matrix::zeros(0, self.d_v);
    }

    /// Causal attention of `q` (1×d or m×d, all at the *current* position)
    /// over everything ingested so far.
    pub fn attend(&self, q: &Matrix) -> Matrix {
        assert_eq!(q.cols(), self.d_k);
        assert!(self.len > 0, "attend on empty stream");
        // assemble frozen ∪ tail (tail carries unit weights)
        let keys = Matrix::vcat(&[&self.frozen_keys, &self.tail_keys]);
        let values = Matrix::vcat(&[&self.frozen_values, &self.tail_values]);
        let mut weights = self.frozen_weights.clone();
        weights.extend(std::iter::repeat(1.0).take(self.tail_keys.rows()));
        let clip = ClipRange::from_values(&values);
        wtd_attention(q, &keys, &values, &weights, &clip, self.beta as f32)
    }
}

/// Full causal WildCat over a (Q, K, V) batch: the offline equivalent of
/// feeding the stream token by token and attending at every position.
/// Returns the m×d_v causal outputs (row i attends over keys 0..=i).
pub fn causal_wildcat_attention(
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    chunk: usize,
    rank_per_chunk: usize,
    bins: usize,
    beta: f64,
    seed: u64,
) -> Matrix {
    assert_eq!(q.rows(), k.rows(), "causal attention needs m == n");
    let mut state =
        StreamingWildcat::new(chunk, rank_per_chunk, bins, beta, k.cols(), v.cols(), seed);
    let mut out = Matrix::zeros(q.rows(), v.cols());
    for i in 0..q.rows() {
        state.push(k.row(i), v.row(i));
        let qi = Matrix::from_vec(q.row(i).to_vec(), 1, q.cols());
        let o = state.attend(&qi);
        out.row_mut(i).copy_from_slice(o.row(0));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norms::{max_abs, max_abs_diff};

    /// Exact causal attention oracle.
    fn causal_exact(q: &Matrix, k: &Matrix, v: &Matrix, beta: f32) -> Matrix {
        let mut out = Matrix::zeros(q.rows(), v.cols());
        for i in 0..q.rows() {
            let qi = Matrix::from_vec(q.row(i).to_vec(), 1, q.cols());
            let ki = k.slice_rows(0, i + 1);
            let vi = v.slice_rows(0, i + 1);
            let o = crate::attention::exact_attention(&qi, &ki, &vi, beta);
            out.row_mut(i).copy_from_slice(o.row(0));
        }
        out
    }

    #[test]
    fn huge_chunk_is_exact_causal() {
        // chunk larger than the stream ⇒ tail never freezes ⇒ exact
        let mut rng = Rng::seed_from(1);
        let n = 24;
        let q = Matrix::randn(&mut rng, n, 6);
        let k = Matrix::randn(&mut rng, n, 6);
        let v = Matrix::randn(&mut rng, n, 4);
        let got = causal_wildcat_attention(&q, &k, &v, 1000, 8, 1, 0.3, 7);
        let want = causal_exact(&q, &k, &v, 0.3);
        assert!(max_abs_diff(&got, &want) < 1e-4);
    }

    #[test]
    fn compressed_stream_tracks_exact() {
        let mut rng = Rng::seed_from(2);
        let n = 160;
        let q = Matrix::randn(&mut rng, n, 8);
        let k = Matrix::randn(&mut rng, n, 8);
        let v = Matrix::randn(&mut rng, n, 4);
        let want = causal_exact(&q, &k, &v, 0.35);
        let got = causal_wildcat_attention(&q, &k, &v, 32, 16, 1, 0.35, 7);
        let err = max_abs_diff(&got, &want) / max_abs(&v);
        assert!(err < 0.5, "relative causal error too high: {err}");
        // and better than dropping the past entirely (StreamingLLM-style)
        let mut drop_err = 0.0f64;
        for i in 0..n {
            let lo = i.saturating_sub(31);
            let qi = Matrix::from_vec(q.row(i).to_vec(), 1, 8);
            let o = crate::attention::exact_attention(
                &qi,
                &k.slice_rows(lo, i + 1),
                &v.slice_rows(lo, i + 1),
                0.35,
            );
            for (a, b) in o.row(0).iter().zip(want.row(i)) {
                drop_err = drop_err.max((a - b).abs() as f64 / max_abs(&v));
            }
        }
        assert!(
            err < drop_err,
            "coreset past ({err}) should beat dropped past ({drop_err})"
        );
    }

    #[test]
    fn state_size_near_constant_per_chunk() {
        let mut rng = Rng::seed_from(3);
        let mut s = StreamingWildcat::new(32, 8, 1, 0.3, 4, 4, 9);
        for i in 0..320 {
            let kr: Vec<f32> = (0..4).map(|_| rng.gaussian() as f32).collect();
            let vr: Vec<f32> = (0..4).map(|_| rng.gaussian() as f32).collect();
            s.push(&kr, &vr);
            let _ = i;
        }
        assert_eq!(s.len(), 320);
        // 10 frozen chunks × ≤8 points + empty tail
        assert!(s.state_size() <= 10 * 8, "state={}", s.state_size());
        // compression ratio ≥ 4x
        assert!(s.state_size() * 4 <= 320);
    }

    #[test]
    fn causality_future_keys_ignored() {
        // output at position i must not change when future keys change
        let mut rng = Rng::seed_from(4);
        let n = 64;
        let q = Matrix::randn(&mut rng, n, 4);
        let k = Matrix::randn(&mut rng, n, 4);
        let v = Matrix::randn(&mut rng, n, 4);
        let a = causal_wildcat_attention(&q, &k, &v, 16, 8, 1, 0.3, 5);
        // perturb the future half
        let mut k2 = k.clone();
        let mut v2 = v.clone();
        for i in 48..n {
            for j in 0..4 {
                k2.set(i, j, -k.get(i, j) + 1.0);
                v2.set(i, j, 3.0 * v.get(i, j));
            }
        }
        let b = causal_wildcat_attention(&q, &k2, &v2, 16, 8, 1, 0.3, 5);
        for i in 0..48 {
            for j in 0..4 {
                assert!(
                    (a.get(i, j) - b.get(i, j)).abs() < 1e-5,
                    "future leak at ({i},{j})"
                );
            }
        }
    }
}
