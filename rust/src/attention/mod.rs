//! Attention algorithms: the paper's WildCat pipeline and the exact
//! baselines it is measured against.
//!
//! * [`exact`] — textbook softmax attention (numerically stabilised).
//! * [`flash`] — blocked online-softmax exact attention, the repo's
//!   FlashAttention-2 stand-in for Fig. 3 (multi-threaded over query
//!   blocks, streaming key/value tiles).
//! * [`wtd`] — WTDATTN (Alg. 3): weighted attention over a compressed
//!   coreset `(K_S, V_S, w)` with per-column clipping (Lem. 1).
//! * [`compress`] — COMPRESSKV (Alg. 2): recentring, per-bin temperature
//!   (Eq. 4), binned RPNYS and block-diagonal Nyström weighting.
//! * [`wildcat`] — WILDCAT (Alg. 4): the drop-in attention module.

pub mod compress;
pub mod exact;
pub mod flash;
pub mod streaming;
pub mod wildcat;
pub mod wtd;

pub use compress::{compress_kv, CompressedKV, CompressOpts};
pub use exact::exact_attention;
pub use flash::flash_attention;
pub use streaming::{causal_wildcat_attention, StreamingWildcat};
pub use wildcat::{wildcat_attention, WildcatParams};
pub use wtd::{wtd_attention, ClipRange};
