//! WTDATTN (Alg. 3): weighted attention over a compressed coreset.
//!
//! Given the coreset `(K_S, V_S, w)` produced by COMPRESSKV, each query
//! attends only over the `r` coreset keys:
//!
//! `Ô_i = clip( Σ_j exp(β⟨q_i, k_j⟩) V_S[j,:] / Σ_j exp(β⟨q_i, k_j⟩) w_j )`
//!
//! The ratio is invariant to subtracting the per-query max logit, which we
//! do for overflow safety (the paper's Alg. 3 exponentiates raw logits;
//! see DESIGN.md §Algorithms). Rows with a non-positive normaliser are
//! zeroed before clipping, exactly per Alg. 3.

use crate::exec;
use crate::linalg::gemm::dot;
use crate::linalg::Matrix;

/// Per-column clip range `(v_min, v_max)` of Lem. 1 / Alg. 4.
#[derive(Clone, Debug)]
pub struct ClipRange {
    pub lo: Vec<f32>,
    pub hi: Vec<f32>,
}

impl ClipRange {
    /// Derive from a value matrix (per-column min/max).
    pub fn from_values(v: &Matrix) -> Self {
        let (lo, hi) = v.col_min_max();
        ClipRange { lo, hi }
    }

    /// Unbounded range (clipping disabled).
    pub fn unbounded(cols: usize) -> Self {
        ClipRange { lo: vec![f32::NEG_INFINITY; cols], hi: vec![f32::INFINITY; cols] }
    }
}

/// Weighted attention forward pass over the compressed cache.
///
/// * `q` — m×d queries,
/// * `k_s` — r×d coreset keys (original coordinates, mean re-added),
/// * `v_s` — r×d_v compressed values `W V`,
/// * `w` — length-r normalisation weights `W 1_n`,
/// * `clip` — per-column output range.
pub fn wtd_attention(
    q: &Matrix,
    k_s: &Matrix,
    v_s: &Matrix,
    w: &[f64],
    clip: &ClipRange,
    beta: f32,
) -> Matrix {
    assert_eq!(q.cols(), k_s.cols(), "q/k_s head dim mismatch");
    assert_eq!(k_s.rows(), v_s.rows(), "coreset key/value mismatch");
    assert_eq!(w.len(), k_s.rows(), "weight length mismatch");
    let (m, r, dv) = (q.rows(), k_s.rows(), v_s.cols());
    assert_eq!(clip.lo.len(), dv);
    let mut out = Matrix::zeros(m, dv);
    exec::parallel_chunks_mut(out.as_mut_slice(), 32 * dv.max(1), |chunk_idx, rows| {
        let row0 = chunk_idx * 32;
        let rows_here = rows.len() / dv.max(1);
        let mut logits = vec![0.0f32; r];
        for rr in 0..rows_here {
            let i = row0 + rr;
            let qi = q.row(i);
            let mut mx = f32::NEG_INFINITY;
            for (j, l) in logits.iter_mut().enumerate() {
                *l = beta * dot(qi, k_s.row(j));
                if *l > mx {
                    mx = *l;
                }
            }
            let mut denom = 0.0f64;
            let mut acc = vec![0.0f64; dv];
            for (j, &l) in logits.iter().enumerate() {
                let p = ((l - mx) as f64).exp();
                denom += p * w[j];
                for (a, &x) in acc.iter_mut().zip(v_s.row(j)) {
                    *a += p * x as f64;
                }
            }
            let out_row = &mut rows[rr * dv..(rr + 1) * dv];
            if denom > 0.0 {
                for ((o, a), (lo, hi)) in out_row
                    .iter_mut()
                    .zip(&acc)
                    .zip(clip.lo.iter().zip(&clip.hi))
                {
                    *o = ((*a / denom) as f32).clamp(*lo, *hi);
                }
            } else {
                // Alg. 3: Âw ≤ 0 ⇒ 0, then clip into the value range.
                for (o, (lo, hi)) in out_row.iter_mut().zip(clip.lo.iter().zip(&clip.hi)) {
                    *o = 0.0f32.clamp(*lo, *hi);
                }
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::exact_attention;
    use crate::rng::Rng;
    use crate::util::prop::Cases;

    #[test]
    fn unit_weights_full_coreset_equals_exact() {
        // With K_S = K, V_S = V, w = 1 the weighted pass is exact attention.
        Cases::new(12).run(|rng| {
            let m = 1 + rng.below(20);
            let n = 1 + rng.below(30);
            let d = 1 + rng.below(8);
            let q = Matrix::randn(rng, m, d);
            let k = Matrix::randn(rng, n, d);
            let v = Matrix::randn(rng, n, 3);
            let w = vec![1.0f64; n];
            let clip = ClipRange::from_values(&v);
            let a = wtd_attention(&q, &k, &v, &w, &clip, 0.4);
            let b = exact_attention(&q, &k, &v, 0.4);
            for (x, y) in a.as_slice().iter().zip(b.as_slice()) {
                assert!((x - y).abs() < 1e-4, "{x} vs {y}");
            }
        });
    }

    #[test]
    fn output_respects_clip() {
        let mut rng = Rng::seed_from(1);
        let q = Matrix::randn(&mut rng, 10, 4);
        let k = Matrix::randn(&mut rng, 6, 4);
        // adversarial V_S and negative weights can push the ratio outside
        // the hull; clip must bound it
        let v = Matrix::randn(&mut rng, 6, 2).scale(10.0);
        let w: Vec<f64> = (0..6).map(|i| if i % 2 == 0 { 1.0 } else { -0.8 }).collect();
        let clip = ClipRange { lo: vec![-1.0, -2.0], hi: vec![1.0, 2.0] };
        let o = wtd_attention(&q, &k, &v, &w, &clip, 1.0);
        for i in 0..o.rows() {
            assert!(o.get(i, 0) >= -1.0 && o.get(i, 0) <= 1.0);
            assert!(o.get(i, 1) >= -2.0 && o.get(i, 1) <= 2.0);
        }
    }

    #[test]
    fn zero_normaliser_falls_back_to_zero() {
        let q = Matrix::from_vec(vec![1.0, 0.0], 1, 2);
        let k = Matrix::from_vec(vec![1.0, 0.0], 1, 2);
        let v = Matrix::from_vec(vec![5.0], 1, 1);
        let clip = ClipRange { lo: vec![-10.0], hi: vec![10.0] };
        let o = wtd_attention(&q, &k, &v, &[0.0], &clip, 1.0);
        assert_eq!(o.get(0, 0), 0.0);
        // and when clip excludes zero, fallback is clipped
        let clip2 = ClipRange { lo: vec![2.0], hi: vec![10.0] };
        let o2 = wtd_attention(&q, &k, &v, &[0.0], &clip2, 1.0);
        assert_eq!(o2.get(0, 0), 2.0);
    }

    #[test]
    fn stable_at_extreme_scale() {
        let q = Matrix::from_vec(vec![80.0, 80.0], 1, 2);
        let k = Matrix::from_vec(vec![80.0, 80.0, -80.0, -80.0], 2, 2);
        let v = Matrix::from_vec(vec![1.0, -1.0], 2, 1);
        let clip = ClipRange::from_values(&v);
        let o = wtd_attention(&q, &k, &v, &[1.0, 1.0], &clip, 1.0);
        assert!(o.get(0, 0).is_finite());
        assert!((o.get(0, 0) - 1.0).abs() < 1e-5);
    }
}
