//! Greedy decoding over a compressed KV cache — glue between the native
//! [`Transformer`], the [`crate::kvcache::CacheManager`] and the task
//! evaluation harness (Tab. 4 analogue).

use super::transformer::Transformer;
use crate::kvcache::{CacheManager, CompressionCtx, KvCompressor, KvEntry};
use crate::linalg::Matrix;
use crate::rng::Rng;

/// Result of one generation episode.
#[derive(Clone, Debug)]
pub struct GenerateOutcome {
    pub tokens: Vec<u32>,
    /// Physical cache entries per (layer, head) after prefill compression.
    pub cache_entries: usize,
    /// Original context length.
    pub context_len: usize,
}

/// Prefill `context`, compress every (layer, head) cache to `budget`
/// entries with `compressor`, then greedily decode `n_new` tokens.
///
/// This is the Tab. 4 evaluation path: quality differences between
/// compressors show up directly in the decoded answers.
pub fn greedy_decode(
    model: &Transformer,
    context: &[u32],
    n_new: usize,
    budget: usize,
    compressor: &dyn KvCompressor,
    rng: &mut Rng,
) -> GenerateOutcome {
    greedy_decode_with_query(model, context, &[], n_new, budget, compressor, rng)
}

/// The serving protocol of the Tab. 4 bench: prefill the *document*,
/// compress the caches, then feed the `query` tokens through decode
/// (they arrive after compression, like a user turn) before greedily
/// generating `n_new` answer tokens. Without this split, one-token
/// answers would be produced by the uncompressed prefill logits and the
/// benchmark would not exercise compression at all.
pub fn greedy_decode_with_query(
    model: &Transformer,
    context: &[u32],
    query: &[u32],
    n_new: usize,
    budget: usize,
    compressor: &dyn KvCompressor,
    rng: &mut Rng,
) -> GenerateOutcome {
    let cfg = &model.cfg;
    let n_lh = cfg.n_layers * cfg.n_heads;
    let out = model.prefill(context);

    // Compress each (layer, head) cache.
    let mut caches: Vec<(Matrix, Matrix, Vec<f64>)> = Vec::with_capacity(n_lh);
    for lh in 0..n_lh {
        let keys = &out.k_cache[lh];
        let values = &out.v_cache[lh];
        let entry: KvEntry = if budget >= keys.rows() {
            KvEntry::exact(keys.clone(), values.clone())
        } else {
            let ctx = CompressionCtx {
                keys,
                values,
                budget,
                beta: cfg.beta() as f64,
                layer: lh / cfg.n_heads,
                n_layers: cfg.n_layers,
                obs_queries: None,
            };
            compressor.compress(&ctx, rng)
        };
        caches.push((entry.keys, entry.values, entry.weights));
    }
    let cache_entries = caches.iter().map(|(k, _, _)| k.rows()).max().unwrap_or(0);

    // Feed the post-compression query tokens (teacher-forced).
    let mut logits = out.logits;
    let mut pos = context.len();
    for &qt in query {
        let refs: Vec<(&Matrix, &Matrix, &[f64])> =
            caches.iter().map(|(k, v, w)| (k, v, w.as_slice())).collect();
        let (lg, new_k, new_v) = model.decode(qt, pos.min(cfg.max_len - 1), &refs);
        logits = lg;
        for (lh, (k, v, w)) in caches.iter_mut().enumerate() {
            k.push_row(&new_k[lh]);
            v.push_row(&new_v[lh]);
            w.push(1.0);
        }
        pos += 1;
    }

    // Greedy decode.
    let mut tokens = Vec::with_capacity(n_new);
    for _ in 0..n_new {
        let next = argmax(&logits) as u32;
        tokens.push(next);
        let refs: Vec<(&Matrix, &Matrix, &[f64])> =
            caches.iter().map(|(k, v, w)| (k, v, w.as_slice())).collect();
        let (lg, new_k, new_v) = model.decode(next, pos.min(cfg.max_len - 1), &refs);
        logits = lg;
        for (lh, (k, v, w)) in caches.iter_mut().enumerate() {
            k.push_row(&new_k[lh]);
            v.push_row(&new_v[lh]);
            w.push(1.0);
        }
        pos += 1;
    }
    GenerateOutcome { tokens, cache_entries, context_len: context.len() }
}

/// Index of the maximum logit.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > best_v {
            best_v = x;
            best = i;
        }
    }
    best
}

/// Uncompressed greedy decoding through the [`CacheManager`] API —
/// exercises the serving-side cache plumbing (pool registration, prefix
/// sharing, gather, budget-triggered re-compression) end to end; used by
/// the coordinator tests.
pub fn decode_with_manager(
    model: &Transformer,
    manager: &mut CacheManager,
    seq: u64,
    context: &[u32],
    n_new: usize,
    rng: &mut Rng,
) -> Vec<u32> {
    let cfg = &model.cfg;
    let n_lh = cfg.n_layers * cfg.n_heads;
    let out = model.prefill(context);
    manager
        .ingest_prefill(seq, context, &out.k_cache, &out.v_cache)
        .expect("pool admission (unbounded manager pools never reject)");
    manager.compress_sequence(seq, None, rng);
    let mut logits = out.logits;
    let mut tokens = Vec::with_capacity(n_new);
    let mut pos = context.len();
    for _ in 0..n_new {
        let next = argmax(&logits) as u32;
        tokens.push(next);
        let borrowed = manager.gather(seq).expect("sequence");
        let refs: Vec<(&Matrix, &Matrix, &[f64])> =
            borrowed.iter().map(|(k, v, w)| (k, v, w.as_slice())).collect();
        let (lg, new_k, new_v) = model.decode(next, pos.min(cfg.max_len - 1), &refs);
        logits = lg;
        for lh in 0..n_lh {
            manager.append_and_maybe_compress(seq, lh, &new_k[lh], &new_v[lh], None, rng);
        }
        pos += 1;
    }
    assert!(manager.drop_sequence(seq), "sequence retired twice");
    tokens
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::{StreamingLlm, UniformKv};
    use crate::model::transformer::ModelConfig;

    fn tiny_model() -> Transformer {
        let cfg = ModelConfig { vocab: 16, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 32, max_len: 256 };
        let mut rng = Rng::seed_from(5);
        Transformer::random(cfg, &mut rng)
    }

    #[test]
    fn uncompressed_budget_is_exact_path() {
        let m = tiny_model();
        let ctx: Vec<u32> = (0..20).map(|i| (i % 16) as u32).collect();
        let mut rng = Rng::seed_from(1);
        let a = greedy_decode(&m, &ctx, 5, 10_000, &UniformKv, &mut rng);
        let mut rng2 = Rng::seed_from(2);
        let b = greedy_decode(&m, &ctx, 5, 10_000, &StreamingLlm, &mut rng2);
        // with no compression both policies decode identically
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.cache_entries, 20);
        assert_eq!(a.context_len, 20);
    }

    #[test]
    fn compressed_budget_respected() {
        let m = tiny_model();
        let ctx: Vec<u32> = (0..150).map(|i| (i % 16) as u32).collect();
        let mut rng = Rng::seed_from(3);
        let out = greedy_decode(&m, &ctx, 3, 100, &StreamingLlm, &mut rng);
        assert_eq!(out.tokens.len(), 3);
        assert!(out.cache_entries <= 100, "entries={}", out.cache_entries);
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }

    #[test]
    fn manager_path_matches_direct_path_uncompressed() {
        let m = tiny_model();
        let ctx: Vec<u32> = (0..12).map(|i| (i % 16) as u32).collect();
        let mut rng = Rng::seed_from(4);
        let direct = greedy_decode(&m, &ctx, 4, 10_000, &UniformKv, &mut rng);
        let mut manager =
            CacheManager::new(10_000, 4, m.cfg.beta() as f64, std::sync::Arc::new(UniformKv));
        let mut rng2 = Rng::seed_from(4);
        let via_manager = decode_with_manager(&m, &mut manager, 1, &ctx, 4, &mut rng2);
        assert_eq!(direct.tokens, via_manager);
    }
}
