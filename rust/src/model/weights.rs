//! Loader for `artifacts/weights.bin` (format defined by
//! python/compile/aot.py `dump_weights_bin`):
//!
//! ```text
//! magic "WCWT" | u32 version | u32 count |
//!   per tensor: u16 name_len | name | u8 ndim | u32 dims[ndim] | f32 data
//! ```
//! All integers and floats little-endian.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// A parsed weight file: tensor name → (shape, row-major f32 data).
#[derive(Clone, Debug, Default)]
pub struct WeightFile {
    tensors: HashMap<String, (Vec<usize>, Vec<f32>)>,
}

impl WeightFile {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let data = std::fs::read(path.as_ref())
            .with_context(|| format!("reading {:?} — run `make artifacts`", path.as_ref()))?;
        Self::parse(&data)
    }

    pub fn parse(data: &[u8]) -> Result<Self> {
        if data.len() < 12 || &data[..4] != b"WCWT" {
            bail!("bad magic in weights file");
        }
        let version = u32::from_le_bytes(data[4..8].try_into().unwrap());
        if version != 1 {
            bail!("unsupported weights version {version}");
        }
        let count = u32::from_le_bytes(data[8..12].try_into().unwrap()) as usize;
        let mut off = 12usize;
        let mut tensors = HashMap::with_capacity(count);
        let take = |off: &mut usize, n: usize| -> Result<&[u8]> {
            if *off + n > data.len() {
                bail!("truncated weights file at offset {off}");
            }
            let s = &data[*off..*off + n];
            *off += n;
            Ok(s)
        };
        for _ in 0..count {
            let nlen = u16::from_le_bytes(take(&mut off, 2)?.try_into().unwrap()) as usize;
            let name = String::from_utf8(take(&mut off, nlen)?.to_vec())
                .map_err(|_| anyhow!("non-utf8 tensor name"))?;
            let ndim = take(&mut off, 1)?[0] as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(u32::from_le_bytes(take(&mut off, 4)?.try_into().unwrap()) as usize);
            }
            let numel: usize = dims.iter().product::<usize>().max(1);
            let raw = take(&mut off, numel * 4)?;
            let mut vals = Vec::with_capacity(numel);
            for c in raw.chunks_exact(4) {
                vals.push(f32::from_le_bytes(c.try_into().unwrap()));
            }
            tensors.insert(name, (dims, vals));
        }
        if off != data.len() {
            bail!("trailing bytes in weights file");
        }
        Ok(WeightFile { tensors })
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.tensors.keys()
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    pub fn get(&self, name: &str) -> Result<(&[usize], &[f32])> {
        self.tensors
            .get(name)
            .map(|(s, d)| (s.as_slice(), d.as_slice()))
            .ok_or_else(|| anyhow!("missing tensor {name:?}"))
    }

    /// Fetch a 2-D tensor as a [`crate::linalg::Matrix`].
    pub fn matrix(&self, name: &str) -> Result<crate::linalg::Matrix> {
        let (shape, data) = self.get(name)?;
        if shape.len() != 2 {
            bail!("{name}: expected 2-D, got {shape:?}");
        }
        Ok(crate::linalg::Matrix::from_vec(data.to_vec(), shape[0], shape[1]))
    }

    /// Fetch a 1-D tensor.
    pub fn vector(&self, name: &str) -> Result<Vec<f32>> {
        let (shape, data) = self.get(name)?;
        if shape.len() != 1 {
            bail!("{name}: expected 1-D, got {shape:?}");
        }
        Ok(data.to_vec())
    }

    /// Insert (test/builder use).
    pub fn insert(&mut self, name: &str, shape: Vec<usize>, data: Vec<f32>) {
        assert_eq!(shape.iter().product::<usize>().max(1), data.len());
        self.tensors.insert(name.to_string(), (shape, data));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_bytes() -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(b"WCWT");
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&2u32.to_le_bytes());
        // tensor "ab": shape (2,2), data 1..4
        out.extend_from_slice(&2u16.to_le_bytes());
        out.extend_from_slice(b"ab");
        out.push(2);
        out.extend_from_slice(&2u32.to_le_bytes());
        out.extend_from_slice(&2u32.to_le_bytes());
        for v in [1.0f32, 2.0, 3.0, 4.0] {
            out.extend_from_slice(&v.to_le_bytes());
        }
        // tensor "g": shape (3,)
        out.extend_from_slice(&1u16.to_le_bytes());
        out.extend_from_slice(b"g");
        out.push(1);
        out.extend_from_slice(&3u32.to_le_bytes());
        for v in [5.0f32, 6.0, 7.0] {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    #[test]
    fn parses_valid_file() {
        let w = WeightFile::parse(&sample_bytes()).unwrap();
        assert_eq!(w.len(), 2);
        let m = w.matrix("ab").unwrap();
        assert_eq!(m.get(1, 0), 3.0);
        assert_eq!(w.vector("g").unwrap(), vec![5.0, 6.0, 7.0]);
        assert!(w.get("missing").is_err());
        assert!(w.vector("ab").is_err()); // wrong rank
    }

    #[test]
    fn rejects_corruption() {
        assert!(WeightFile::parse(b"XXXX").is_err());
        let mut b = sample_bytes();
        b.truncate(b.len() - 3);
        assert!(WeightFile::parse(&b).is_err());
        let mut b2 = sample_bytes();
        b2.push(0); // trailing byte
        assert!(WeightFile::parse(&b2).is_err());
    }
}
