//! Native transformer forward passes (prefill + decode) mirroring
//! `python/compile/model.py` operation-for-operation. See module docs in
//! [`super`] for how this relates to the PJRT path.

use crate::attention::{wtd_attention, ClipRange};
use crate::linalg::{gemm, Matrix};
use crate::model::weights::WeightFile;
use anyhow::Result;

/// Model hyper-parameters (mirror of python `Config` / manifest `model`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ModelConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_len: usize,
}

impl Default for ModelConfig {
    fn default() -> Self {
        ModelConfig { vocab: 64, d_model: 64, n_layers: 2, n_heads: 2, d_ff: 128, max_len: 1024 }
    }
}

impl ModelConfig {
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn beta(&self) -> f32 {
        1.0 / (self.d_head() as f32).sqrt()
    }

    pub fn from_spec(s: &crate::runtime::ModelSpec) -> Self {
        ModelConfig {
            vocab: s.vocab,
            d_model: s.d_model,
            n_layers: s.n_layers,
            n_heads: s.n_heads,
            d_ff: s.d_ff,
            max_len: s.max_len,
        }
    }
}

/// Per-layer weights.
struct LayerWeights {
    wq: Matrix,
    wk: Matrix,
    wv: Matrix,
    wo: Matrix,
    w1: Matrix,
    w2: Matrix,
    ln1: Vec<f32>,
    ln2: Vec<f32>,
}

/// The native model.
pub struct Transformer {
    pub cfg: ModelConfig,
    embed: Matrix,
    unembed: Matrix,
    ln_f: Vec<f32>,
    layers: Vec<LayerWeights>,
    pos_enc: Matrix,
}

/// Output of a prefill pass.
pub struct PrefillOutput {
    /// Next-token logits at the last position.
    pub logits: Vec<f32>,
    /// Per (layer, head) key caches, each `n × d_head`, indexed
    /// `layer * n_heads + head`.
    pub k_cache: Vec<Matrix>,
    pub v_cache: Vec<Matrix>,
}

/// Per-(layer, head) K/V rows of an already-computed prompt prefix, the
/// compute-side view of a KV-pool prefix hit. Feeding one to
/// [`Transformer::prefill_from`] resumes prefill at position `len`
/// instead of recomputing positions `0..len`.
pub struct CachedPrefix {
    /// Prompt tokens covered (absolute positions `0..len`).
    pub len: usize,
    /// Per-(layer, head) keys, each `len × d_head`, indexed
    /// `layer * n_heads + head`.
    pub keys: Vec<Matrix>,
    /// Per-(layer, head) values, same indexing as `keys`.
    pub values: Vec<Matrix>,
}

impl CachedPrefix {
    /// The empty prefix — resuming from it is exactly a cold prefill.
    pub fn empty() -> Self {
        CachedPrefix { len: 0, keys: Vec::new(), values: Vec::new() }
    }
}

impl Transformer {
    /// Load from a weights file exported by `make artifacts`.
    pub fn from_weights(w: &WeightFile, cfg: ModelConfig) -> Result<Self> {
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            layers.push(LayerWeights {
                wq: w.matrix(&format!("l{l}.wq"))?,
                wk: w.matrix(&format!("l{l}.wk"))?,
                wv: w.matrix(&format!("l{l}.wv"))?,
                wo: w.matrix(&format!("l{l}.wo"))?,
                w1: w.matrix(&format!("l{l}.w1"))?,
                w2: w.matrix(&format!("l{l}.w2"))?,
                ln1: w.vector(&format!("l{l}.ln1"))?,
                ln2: w.vector(&format!("l{l}.ln2"))?,
            });
        }
        Ok(Transformer {
            embed: w.matrix("embed")?,
            unembed: w.matrix("unembed")?,
            ln_f: w.vector("ln_f")?,
            layers,
            pos_enc: positional_encoding(&cfg),
            cfg,
        })
    }

    /// Load the artifact-directory model (weights.bin + default config).
    pub fn load_artifacts(dir: impl AsRef<std::path::Path>, cfg: ModelConfig) -> Result<Self> {
        let w = WeightFile::load(dir.as_ref().join("weights.bin"))?;
        Self::from_weights(&w, cfg)
    }

    /// Random-weight model (tests and micro-benches).
    pub fn random(cfg: ModelConfig, rng: &mut crate::rng::Rng) -> Self {
        let scale = 1.0 / (cfg.d_model as f32).sqrt();
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for _ in 0..cfg.n_layers {
            layers.push(LayerWeights {
                wq: Matrix::randn(rng, cfg.d_model, cfg.d_model).scale(scale),
                wk: Matrix::randn(rng, cfg.d_model, cfg.d_model).scale(scale),
                wv: Matrix::randn(rng, cfg.d_model, cfg.d_model).scale(scale),
                wo: Matrix::randn(rng, cfg.d_model, cfg.d_model).scale(scale),
                w1: Matrix::randn(rng, cfg.d_model, cfg.d_ff).scale(scale),
                w2: Matrix::randn(rng, cfg.d_ff, cfg.d_model).scale(scale),
                ln1: vec![1.0; cfg.d_model],
                ln2: vec![1.0; cfg.d_model],
            });
        }
        Transformer {
            embed: Matrix::randn(rng, cfg.vocab, cfg.d_model).scale(0.05),
            unembed: Matrix::randn(rng, cfg.d_model, cfg.vocab).scale(0.05),
            ln_f: vec![1.0; cfg.d_model],
            layers,
            pos_enc: positional_encoding(&cfg),
            cfg,
        }
    }

    /// Causal prefill over `tokens`, producing logits at the last position
    /// and per-(layer, head) KV caches.
    pub fn prefill(&self, tokens: &[u32]) -> PrefillOutput {
        self.prefill_impl(None, tokens)
    }

    /// Resume a causal prefill past a prefix whose K/V rows are already
    /// known: embed only the `tail` (at absolute positions
    /// `cached.len..`), and let each tail query attend across the cached
    /// keys *and* the new ones. In a causal pass the tail rows depend on
    /// the prefix only through its K/V rows, so this produces the same
    /// logits as `prefill(prefix ++ tail)` while running attention over
    /// the tail positions only. The returned caches are tail-only (rows
    /// for positions `cached.len..cached.len + tail.len()`).
    pub fn prefill_from(&self, cached: &CachedPrefix, tail: &[u32]) -> PrefillOutput {
        if cached.len == 0 {
            return self.prefill_impl(None, tail);
        }
        self.prefill_impl(Some(cached), tail)
    }

    fn prefill_impl(&self, cached: Option<&CachedPrefix>, tail: &[u32]) -> PrefillOutput {
        let cfg = &self.cfg;
        let hist = cached.map_or(0, |c| c.len);
        let n = tail.len();
        assert!(n >= 1 && hist + n <= cfg.max_len, "prefill length {}", hist + n);
        if let Some(c) = cached {
            let n_lh = cfg.n_layers * cfg.n_heads;
            assert_eq!(c.keys.len(), n_lh, "cached prefix (layer, head) count");
            assert_eq!(c.values.len(), n_lh, "cached prefix (layer, head) count");
        }
        let mut x = Matrix::zeros(n, cfg.d_model);
        for (i, &t) in tail.iter().enumerate() {
            let e = self.embed.row(t as usize);
            let p = self.pos_enc.row(hist + i);
            for (o, (a, b)) in x.row_mut(i).iter_mut().zip(e.iter().zip(p)) {
                *o = a + b;
            }
        }
        let mut k_cache = Vec::with_capacity(cfg.n_layers * cfg.n_heads);
        let mut v_cache = Vec::with_capacity(cfg.n_layers * cfg.n_heads);
        let beta = cfg.beta();
        for (l, lw) in self.layers.iter().enumerate() {
            let h = rmsnorm_mat(&x, &lw.ln1);
            let q = gemm::matmul(&h, &lw.wq);
            let k = gemm::matmul(&h, &lw.wk);
            let v = gemm::matmul(&h, &lw.wv);
            let mut att = Matrix::zeros(n, cfg.d_model);
            for head in 0..cfg.n_heads {
                let qh = take_head(&q, head, cfg);
                let kh = take_head(&k, head, cfg);
                let vh = take_head(&v, head, cfg);
                let oh = match cached {
                    Some(c) => {
                        let lh = l * cfg.n_heads + head;
                        debug_assert_eq!(c.keys[lh].rows(), hist, "cached prefix row count");
                        let ks = Matrix::vcat(&[&c.keys[lh], &kh]);
                        let vs = Matrix::vcat(&[&c.values[lh], &vh]);
                        causal_attention(&qh, &ks, &vs, beta, hist)
                    }
                    None => causal_attention(&qh, &kh, &vh, beta, 0),
                };
                put_head(&mut att, &oh, head, cfg);
                k_cache.push(kh);
                v_cache.push(vh);
            }
            let proj = gemm::matmul(&att, &lw.wo);
            add_assign(&mut x, &proj);
            let h2 = rmsnorm_mat(&x, &lw.ln2);
            let ff = gemm::matmul(&gelu_mat(&gemm::matmul(&h2, &lw.w1)), &lw.w2);
            add_assign(&mut x, &ff);
        }
        let final_h = rmsnorm_row(x.row(n - 1), &self.ln_f);
        let logits = matvec_t(&self.unembed, &final_h);
        PrefillOutput { logits, k_cache, v_cache }
    }

    /// One decode step over weighted per-(layer, head) caches.
    ///
    /// `caches[layer * n_heads + head]` supplies `(keys, values, weights)`;
    /// the current token attends over `cache ∪ {self}` exactly like the
    /// JAX `decode_step`. Returns (logits, new_k, new_v) where the new
    /// entries are per (layer, head) rows for the caller to append.
    pub fn decode(
        &self,
        token: u32,
        pos: usize,
        caches: &[(&Matrix, &Matrix, &[f64])],
    ) -> (Vec<f32>, Vec<Vec<f32>>, Vec<Vec<f32>>) {
        self.decode_inner(token, pos, caches, None)
    }

    /// [`Transformer::decode`] that additionally captures each
    /// (layer, head)'s attention output row — the quantity the
    /// approximation-quality auditor compares against an exact-reference
    /// recompute. Identical logits/caches to `decode` (same code path).
    #[allow(clippy::type_complexity)]
    pub fn decode_captured(
        &self,
        token: u32,
        pos: usize,
        caches: &[(&Matrix, &Matrix, &[f64])],
    ) -> (Vec<f32>, Vec<Vec<f32>>, Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let mut attn = Vec::with_capacity(caches.len());
        let (logits, new_k, new_v) = self.decode_inner(token, pos, caches, Some(&mut attn));
        (logits, new_k, new_v, attn)
    }

    #[allow(clippy::type_complexity)]
    fn decode_inner(
        &self,
        token: u32,
        pos: usize,
        caches: &[(&Matrix, &Matrix, &[f64])],
        mut capture: Option<&mut Vec<Vec<f32>>>,
    ) -> (Vec<f32>, Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let cfg = &self.cfg;
        assert_eq!(caches.len(), cfg.n_layers * cfg.n_heads);
        assert!(pos < cfg.max_len);
        let beta = cfg.beta();
        let dh = cfg.d_head();
        let mut x: Vec<f32> = self
            .embed
            .row(token as usize)
            .iter()
            .zip(self.pos_enc.row(pos))
            .map(|(a, b)| a + b)
            .collect();
        let mut new_ks = Vec::with_capacity(caches.len());
        let mut new_vs = Vec::with_capacity(caches.len());
        for (l, lw) in self.layers.iter().enumerate() {
            let h = rmsnorm_row(&x, &lw.ln1);
            let q = matvec_t(&lw.wq, &h);
            let k_new = matvec_t(&lw.wk, &h);
            let v_new = matvec_t(&lw.wv, &h);
            let mut att = vec![0.0f32; cfg.d_model];
            for head in 0..cfg.n_heads {
                let (ck, cv, cw) = caches[l * cfg.n_heads + head];
                let qh = Matrix::from_vec(q[head * dh..(head + 1) * dh].to_vec(), 1, dh);
                // cache ∪ {self}
                let mut ks = ck.clone();
                ks.push_row(&k_new[head * dh..(head + 1) * dh]);
                let mut vs = cv.clone();
                vs.push_row(&v_new[head * dh..(head + 1) * dh]);
                let mut w: Vec<f64> = cw.to_vec();
                w.push(1.0);
                let clip = ClipRange::from_values(&vs);
                let o = wtd_attention(&qh, &ks, &vs, &w, &clip, beta);
                att[head * dh..(head + 1) * dh].copy_from_slice(o.row(0));
                if let Some(cap) = capture.as_deref_mut() {
                    cap.push(o.row(0).to_vec());
                }
            }
            let proj = matvec_t(&lw.wo, &att);
            for (xi, pi) in x.iter_mut().zip(&proj) {
                *xi += pi;
            }
            let h2 = rmsnorm_row(&x, &lw.ln2);
            let mut ff_in = matvec_t(&lw.w1, &h2);
            for v in ff_in.iter_mut() {
                *v = gelu(*v);
            }
            let ff = matvec_t(&lw.w2, &ff_in);
            for (xi, fi) in x.iter_mut().zip(&ff) {
                *xi += fi;
            }
            new_ks.push(
                (0..cfg.n_heads)
                    .map(|hh| k_new[hh * dh..(hh + 1) * dh].to_vec())
                    .collect::<Vec<_>>(),
            );
            new_vs.push(
                (0..cfg.n_heads)
                    .map(|hh| v_new[hh * dh..(hh + 1) * dh].to_vec())
                    .collect::<Vec<_>>(),
            );
        }
        let final_h = rmsnorm_row(&x, &self.ln_f);
        let logits = matvec_t(&self.unembed, &final_h);
        (
            logits,
            new_ks.into_iter().flatten().collect(),
            new_vs.into_iter().flatten().collect(),
        )
    }
}

// ---------------------------------------------------------------------
// primitive ops shared by prefill/decode (exact python mirrors)
// ---------------------------------------------------------------------

/// Sinusoidal positions, identical formula to `model.positional_encoding`.
pub fn positional_encoding(cfg: &ModelConfig) -> Matrix {
    let mut enc = Matrix::zeros(cfg.max_len, cfg.d_model);
    for pos in 0..cfg.max_len {
        for dim in 0..cfg.d_model / 2 {
            let angle =
                pos as f64 / 10000f64.powf(2.0 * dim as f64 / cfg.d_model as f64);
            enc.set(pos, 2 * dim, angle.sin() as f32);
            enc.set(pos, 2 * dim + 1, angle.cos() as f32);
        }
    }
    enc
}

fn rmsnorm_row(x: &[f32], g: &[f32]) -> Vec<f32> {
    let ms: f64 =
        x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / x.len() as f64;
    let inv = 1.0 / (ms + 1e-6).sqrt();
    x.iter().zip(g).map(|(&v, &gi)| (v as f64 * inv) as f32 * gi).collect()
}

fn rmsnorm_mat(x: &Matrix, g: &[f32]) -> Matrix {
    let mut out = x.clone();
    for i in 0..x.rows() {
        let r = rmsnorm_row(x.row(i), g);
        out.row_mut(i).copy_from_slice(&r);
    }
    out
}

fn gelu(x: f32) -> f32 {
    let x = x as f64;
    (0.5 * x * (1.0 + (0.7978845608028654 * (x + 0.044715 * x * x * x)).tanh())) as f32
}

fn gelu_mat(x: &Matrix) -> Matrix {
    let mut out = x.clone();
    for v in out.as_mut_slice() {
        *v = gelu(*v);
    }
    out
}

fn add_assign(x: &mut Matrix, y: &Matrix) {
    for (a, b) in x.as_mut_slice().iter_mut().zip(y.as_slice()) {
        *a += b;
    }
}

/// `Wᵀ · h` for row-vector h (i.e. `h @ W` in numpy convention).
fn matvec_t(w: &Matrix, h: &[f32]) -> Vec<f32> {
    assert_eq!(w.rows(), h.len());
    let mut out = vec![0.0f32; w.cols()];
    for (i, &hi) in h.iter().enumerate() {
        if hi == 0.0 {
            continue;
        }
        for (o, &wij) in out.iter_mut().zip(w.row(i)) {
            *o += hi * wij;
        }
    }
    out
}

/// Extract one head's columns as a contiguous matrix.
fn take_head(x: &Matrix, head: usize, cfg: &ModelConfig) -> Matrix {
    let dh = cfg.d_head();
    Matrix::from_fn(x.rows(), dh, |i, j| x.get(i, head * dh + j))
}

fn put_head(out: &mut Matrix, h: &Matrix, head: usize, cfg: &ModelConfig) {
    let dh = cfg.d_head();
    for i in 0..h.rows() {
        for j in 0..dh {
            out.set(i, head * dh + j, h.get(i, j));
        }
    }
}

/// Causal softmax attention (prefill path). Query row `i` sits at
/// absolute position `hist + i` and attends over key rows `0..=hist + i`
/// — `k`/`v` carry all `hist + q.rows()` rows (history first), while `q`
/// carries the tail only. `hist = 0` is the cold-prefill case.
fn causal_attention(q: &Matrix, k: &Matrix, v: &Matrix, beta: f32, hist: usize) -> Matrix {
    let n = q.rows();
    debug_assert_eq!(k.rows(), hist + n, "keys must cover history + tail");
    let dv = v.cols();
    let mut out = Matrix::zeros(n, dv);
    for i in 0..n {
        let qi = q.row(i);
        let mut mx = f32::NEG_INFINITY;
        let logits: Vec<f32> = (0..=hist + i)
            .map(|j| {
                let l = beta * gemm::dot(qi, k.row(j));
                if l > mx {
                    mx = l;
                }
                l
            })
            .collect();
        let mut denom = 0.0f64;
        let mut acc = vec![0.0f64; dv];
        for (j, &l) in logits.iter().enumerate() {
            let p = ((l - mx) as f64).exp();
            denom += p;
            for (a, &x) in acc.iter_mut().zip(v.row(j)) {
                *a += p * x as f64;
            }
        }
        for (o, a) in out.row_mut(i).iter_mut().zip(&acc) {
            *o = (*a / denom) as f32;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn tiny() -> (Transformer, ModelConfig) {
        let cfg = ModelConfig { vocab: 16, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 32, max_len: 64 };
        let mut rng = Rng::seed_from(1);
        (Transformer::random(cfg, &mut rng), cfg)
    }

    #[test]
    fn prefill_shapes() {
        let (t, cfg) = tiny();
        let toks: Vec<u32> = (0..10).map(|i| (i % 16) as u32).collect();
        let out = t.prefill(&toks);
        assert_eq!(out.logits.len(), cfg.vocab);
        assert_eq!(out.k_cache.len(), cfg.n_layers * cfg.n_heads);
        assert_eq!(out.k_cache[0].rows(), 10);
        assert_eq!(out.k_cache[0].cols(), cfg.d_head());
        assert!(out.logits.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn decode_with_full_cache_matches_prefill() {
        // prefill(n) logits must equal prefill(n-1) caches + decode(token n-1)
        let (t, _cfg) = tiny();
        let toks: Vec<u32> = vec![1, 5, 3, 7, 2, 9, 4, 11, 6, 13];
        let full = t.prefill(&toks);
        let part = t.prefill(&toks[..toks.len() - 1]);
        let caches: Vec<(&Matrix, &Matrix, Vec<f64>)> = part
            .k_cache
            .iter()
            .zip(&part.v_cache)
            .map(|(k, v)| (k, v, vec![1.0f64; k.rows()]))
            .collect();
        let cache_refs: Vec<(&Matrix, &Matrix, &[f64])> =
            caches.iter().map(|(k, v, w)| (*k, *v, w.as_slice())).collect();
        let (logits, new_k, new_v) =
            t.decode(toks[toks.len() - 1], toks.len() - 1, &cache_refs);
        for (a, b) in logits.iter().zip(&full.logits) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
        assert_eq!(new_k.len(), 4); // L*H
        assert_eq!(new_k[0].len(), 8); // d_head
        // the decode-produced k/v rows match the full prefill's last row
        for lh in 0..4 {
            for (a, b) in new_k[lh].iter().zip(full.k_cache[lh].row(toks.len() - 1)) {
                assert!((a - b).abs() < 1e-3);
            }
            for (a, b) in new_v[lh].iter().zip(full.v_cache[lh].row(toks.len() - 1)) {
                assert!((a - b).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn decode_captured_is_bit_identical_and_shapes_attn() {
        let (t, cfg) = tiny();
        let toks: Vec<u32> = vec![1, 5, 3, 7, 2, 9];
        let part = t.prefill(&toks[..toks.len() - 1]);
        let caches: Vec<(&Matrix, &Matrix, Vec<f64>)> = part
            .k_cache
            .iter()
            .zip(&part.v_cache)
            .map(|(k, v)| (k, v, vec![1.0f64; k.rows()]))
            .collect();
        let refs: Vec<(&Matrix, &Matrix, &[f64])> =
            caches.iter().map(|(k, v, w)| (*k, *v, w.as_slice())).collect();
        let (logits, new_k, new_v) =
            t.decode(toks[toks.len() - 1], toks.len() - 1, &refs);
        let (cl, ck, cv, attn) =
            t.decode_captured(toks[toks.len() - 1], toks.len() - 1, &refs);
        // same code path: bit-identical outputs, plus one attention row
        // of d_head per (layer, head)
        assert_eq!(logits, cl);
        assert_eq!(new_k, ck);
        assert_eq!(new_v, cv);
        assert_eq!(attn.len(), cfg.n_layers * cfg.n_heads);
        assert!(attn.iter().all(|r| r.len() == cfg.d_head()));
        assert!(attn.iter().flatten().all(|x| x.is_finite()));
    }

    #[test]
    fn decode_padding_contract() {
        // arbitrary keys, zero values, zero weights must be inert
        let (t, _cfg) = tiny();
        let toks: Vec<u32> = vec![1, 2, 3, 4, 5];
        let part = t.prefill(&toks[..4]);
        let caches: Vec<(Matrix, Matrix, Vec<f64>)> = part
            .k_cache
            .iter()
            .zip(&part.v_cache)
            .map(|(k, v)| (k.clone(), v.clone(), vec![1.0f64; k.rows()]))
            .collect();
        let refs: Vec<(&Matrix, &Matrix, &[f64])> =
            caches.iter().map(|(k, v, w)| (k, v, w.as_slice())).collect();
        let (base, _, _) = t.decode(4, 4, &refs);
        // padded versions
        let mut rng = Rng::seed_from(3);
        let padded: Vec<(Matrix, Matrix, Vec<f64>)> = caches
            .iter()
            .map(|(k, v, w)| {
                let mut k2 = k.clone();
                let mut v2 = v.clone();
                let mut w2 = w.clone();
                for _ in 0..3 {
                    let junk: Vec<f32> = (0..k.cols()).map(|_| rng.gaussian() as f32).collect();
                    k2.push_row(&junk);
                    v2.push_row(&vec![0.0; v.cols()]);
                    w2.push(0.0);
                }
                (k2, v2, w2)
            })
            .collect();
        let prefs: Vec<(&Matrix, &Matrix, &[f64])> =
            padded.iter().map(|(k, v, w)| (k, v, w.as_slice())).collect();
        let (got, _, _) = t.decode(4, 4, &prefs);
        for (a, b) in got.iter().zip(&base) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn resumed_prefill_matches_cold_at_any_split() {
        let (t, cfg) = tiny();
        let toks: Vec<u32> = (0..24).map(|i| ((i * 5 + 3) % 16) as u32).collect();
        let cold = t.prefill(&toks);
        for split in [1usize, 7, 16, 23] {
            let part = t.prefill(&toks[..split]);
            let cached =
                CachedPrefix { len: split, keys: part.k_cache, values: part.v_cache };
            let resumed = t.prefill_from(&cached, &toks[split..]);
            for (a, b) in resumed.logits.iter().zip(&cold.logits) {
                assert!((a - b).abs() < 1e-4, "split {split}: {a} vs {b}");
            }
            // tail caches line up with the cold pass row-for-row
            for lh in 0..cfg.n_layers * cfg.n_heads {
                assert_eq!(resumed.k_cache[lh].rows(), toks.len() - split);
                for i in 0..toks.len() - split {
                    for (a, b) in resumed.k_cache[lh]
                        .row(i)
                        .iter()
                        .zip(cold.k_cache[lh].row(split + i))
                    {
                        assert!((a - b).abs() < 1e-4, "split {split} lh {lh} row {i}");
                    }
                }
            }
        }
        // the empty prefix degenerates to a cold prefill exactly
        let via_empty = t.prefill_from(&CachedPrefix::empty(), &toks);
        assert_eq!(via_empty.logits, cold.logits);
    }

    #[test]
    fn positional_encoding_matches_formula() {
        let cfg = ModelConfig::default();
        let pe = positional_encoding(&cfg);
        // pos 0: sin(0)=0, cos(0)=1 alternating
        for d in 0..cfg.d_model / 2 {
            assert_eq!(pe.get(0, 2 * d), 0.0);
            assert_eq!(pe.get(0, 2 * d + 1), 1.0);
        }
        // pos 1, dim 0: sin(1), cos(1)
        assert!((pe.get(1, 0) - (1.0f64).sin() as f32).abs() < 1e-6);
        assert!((pe.get(1, 1) - (1.0f64).cos() as f32).abs() < 1e-6);
    }

    #[test]
    fn deterministic_forward() {
        let (t, _) = tiny();
        let toks = vec![1u32, 2, 3];
        let a = t.prefill(&toks);
        let b = t.prefill(&toks);
        assert_eq!(a.logits, b.logits);
    }
}
