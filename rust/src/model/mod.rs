//! The native Rust serving model — a bit-faithful mirror of the JAX LM in
//! `python/compile/model.py` (pre-norm RMSNorm, sinusoidal positions,
//! GELU MLP, untied unembedding).
//!
//! Two execution paths serve the same weights:
//! * this module (native) — flexible shapes, used by benches and as a
//!   cross-check;
//! * [`crate::runtime`] (PJRT) — the AOT HLO artifacts, the paper's
//!   "python never on the request path" architecture.
//! `rust/tests/pjrt_roundtrip.rs` pins the two paths against each other.

pub mod generate;
pub mod transformer;
pub mod weights;

pub use generate::{greedy_decode, GenerateOutcome};
pub use transformer::{ModelConfig, PrefillOutput, Transformer};
pub use weights::WeightFile;

use crate::linalg::Matrix;

/// Abstraction over the two model execution paths (native / PJRT).
///
/// The coordinator's scheduler is generic over this trait; the PJRT
/// implementation lives in [`crate::runtime::backend`] (it is `!Send`, so
/// the server constructs it inside its worker thread).
pub trait ModelBackend {
    fn config(&self) -> ModelConfig;

    /// Causal prefill producing last-position logits and per-(layer, head)
    /// caches.
    fn prefill(&mut self, tokens: &[u32]) -> PrefillOutput;

    /// One decode step over weighted caches (`caches[layer*H + head]`).
    /// Returns (logits, new_k rows, new_v rows) per (layer, head).
    #[allow(clippy::type_complexity)]
    fn decode(
        &mut self,
        token: u32,
        pos: usize,
        caches: &[(&Matrix, &Matrix, &[f64])],
    ) -> (Vec<f32>, Vec<Vec<f32>>, Vec<Vec<f32>>);
}

impl ModelBackend for Transformer {
    fn config(&self) -> ModelConfig {
        self.cfg
    }

    fn prefill(&mut self, tokens: &[u32]) -> PrefillOutput {
        Transformer::prefill(self, tokens)
    }

    fn decode(
        &mut self,
        token: u32,
        pos: usize,
        caches: &[(&Matrix, &Matrix, &[f64])],
    ) -> (Vec<f32>, Vec<Vec<f32>>, Vec<Vec<f32>>) {
        Transformer::decode(self, token, pos, caches)
    }
}
