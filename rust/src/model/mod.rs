//! The native Rust serving model — a bit-faithful mirror of the JAX LM in
//! `python/compile/model.py` (pre-norm RMSNorm, sinusoidal positions,
//! GELU MLP, untied unembedding).
//!
//! Two execution paths serve the same weights:
//! * this module (native) — flexible shapes, used by benches and as a
//!   cross-check;
//! * [`crate::runtime`] (PJRT) — the AOT HLO artifacts, the paper's
//!   "python never on the request path" architecture.
//! `rust/tests/pjrt_roundtrip.rs` pins the two paths against each other.

pub mod generate;
pub mod transformer;
pub mod weights;

pub use generate::{greedy_decode, GenerateOutcome};
pub use transformer::{CachedPrefix, ModelConfig, PrefillOutput, Transformer};
pub use weights::WeightFile;

use crate::linalg::Matrix;

/// Abstraction over the two model execution paths (native / PJRT).
///
/// The coordinator's scheduler is generic over this trait; the PJRT
/// implementation lives in [`crate::runtime::backend`] (it is `!Send`, so
/// the server constructs it inside its worker thread).
pub trait ModelBackend {
    fn config(&self) -> ModelConfig;

    /// Causal prefill producing last-position logits and per-(layer, head)
    /// caches.
    fn prefill(&mut self, tokens: &[u32]) -> PrefillOutput;

    /// Whether [`ModelBackend::prefill_from`] is implemented. Backends
    /// that cannot seed attention from externally supplied K/V rows (the
    /// fixed-shape PJRT artifacts) keep the default `false`, and the
    /// scheduler falls back to cold prefill.
    fn supports_prefill_resume(&self) -> bool {
        false
    }

    /// Resume prefill from cached prefix K/V rows: run attention over
    /// `tail` only, with tail queries attending across `cached` + new
    /// keys, producing logits equivalent to a cold prefill of the full
    /// prompt and tail-only caches. Only called when
    /// [`ModelBackend::supports_prefill_resume`] is `true`.
    fn prefill_from(&mut self, cached: &CachedPrefix, tail: &[u32]) -> PrefillOutput {
        let _ = (cached, tail);
        unimplemented!("backend does not support resumed prefill")
    }

    /// One decode step over weighted caches (`caches[layer*H + head]`).
    /// Returns (logits, new_k rows, new_v rows) per (layer, head).
    #[allow(clippy::type_complexity)]
    fn decode(
        &mut self,
        token: u32,
        pos: usize,
        caches: &[(&Matrix, &Matrix, &[f64])],
    ) -> (Vec<f32>, Vec<Vec<f32>>, Vec<Vec<f32>>);

    /// [`ModelBackend::decode`] that additionally returns each
    /// (layer, head)'s attention output row (`attn[layer*H + head]`,
    /// length `d_head`) — the quantity the approximation-quality auditor
    /// compares against an exact-reference recompute. Backends that
    /// cannot capture per-head outputs (the AOT PJRT artifacts) return
    /// `None`; the auditor then skips the sampled step.
    #[allow(clippy::type_complexity)]
    fn decode_with_attn(
        &mut self,
        token: u32,
        pos: usize,
        caches: &[(&Matrix, &Matrix, &[f64])],
    ) -> Option<(Vec<f32>, Vec<Vec<f32>>, Vec<Vec<f32>>, Vec<Vec<f32>>)> {
        let _ = (token, pos, caches);
        None
    }
}

impl ModelBackend for Transformer {
    fn config(&self) -> ModelConfig {
        self.cfg
    }

    fn prefill(&mut self, tokens: &[u32]) -> PrefillOutput {
        Transformer::prefill(self, tokens)
    }

    fn supports_prefill_resume(&self) -> bool {
        true
    }

    fn prefill_from(&mut self, cached: &CachedPrefix, tail: &[u32]) -> PrefillOutput {
        Transformer::prefill_from(self, cached, tail)
    }

    fn decode(
        &mut self,
        token: u32,
        pos: usize,
        caches: &[(&Matrix, &Matrix, &[f64])],
    ) -> (Vec<f32>, Vec<Vec<f32>>, Vec<Vec<f32>>) {
        Transformer::decode(self, token, pos, caches)
    }

    fn decode_with_attn(
        &mut self,
        token: u32,
        pos: usize,
        caches: &[(&Matrix, &Matrix, &[f64])],
    ) -> Option<(Vec<f32>, Vec<Vec<f32>>, Vec<Vec<f32>>, Vec<Vec<f32>>)> {
        Some(Transformer::decode_captured(self, token, pos, caches))
    }
}
