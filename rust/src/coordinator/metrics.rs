//! Serving metrics: counters + streaming latency statistics, shared
//! across threads behind a mutex (recording is a few dozen ns; the model
//! step is milliseconds, so contention is negligible — re-examined in
//! EXPERIMENTS.md §Perf).

use crate::obs::quality::{QualityAudit, QualitySnapshot};
use crate::util::json::Json;
use crate::util::stats::{LogHistogram, Welford};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Monotonic serving counters (one replica's totals since start).
#[derive(Debug, Default, Clone, Copy)]
pub struct Counters {
    /// Requests submitted (accepted or not).
    pub submitted: u64,
    /// Requests rejected (queue backpressure or pool admission).
    pub rejected: u64,
    /// Requests answered with a full generation.
    pub completed: u64,
    /// Decode tokens produced across completed requests.
    pub tokens_generated: u64,
    /// Prompt tokens of completed requests (logical prefill volume).
    pub prefill_tokens: u64,
    /// Prompt tokens whose attention was actually computed at admission
    /// (the tail, under prefill skipping; the whole prompt otherwise).
    pub prefill_tokens_computed: u64,
    /// Prompt tokens served from KV-pool prefix hits instead of being
    /// recomputed (prefill skipping).
    pub prefill_tokens_skipped: u64,
    /// Admissions whose prompt resumed from a KV-pool prefix match
    /// (request-level hit counterpart of the token-level counters
    /// above, from which a per-request hit *rate* is not recoverable).
    pub prefix_hits: u64,
    /// Admissions that prefilled cold (no usable prefix match, or
    /// prefix sharing / prefill skipping disabled).
    pub prefix_misses: u64,
    /// Layer-head cache compressions performed by the scheduler.
    pub compressions: u64,
}

impl Counters {
    /// Accepted-but-not-finished load (queued + decoding).
    pub fn in_flight(&self) -> u64 {
        self.submitted.saturating_sub(self.rejected + self.completed)
    }
}

struct Inner {
    counters: Counters,
    queue_us: Welford,
    prefill_us: Welford,
    decode_per_token_us: Welford,
    /// Per-completed-request mean decode latency per token, as a
    /// histogram (exported as a Prometheus `histogram` family alongside
    /// the Welford mean gauge).
    decode_step_us: LogHistogram,
    e2e_us: LogHistogram,
    /// KV pool gauges pushed by the scheduler (current + peak bytes of
    /// the replica's pool ledger).
    kv_bytes_current: usize,
    kv_bytes_peak: usize,
    started: Instant,
}

/// Thread-safe serving metrics sink.
pub struct ServingMetrics {
    inner: Mutex<Inner>,
    /// The replica's approximation-quality auditor, when auditing is
    /// enabled — its snapshot renders into every export surface.
    quality: OnceLock<Arc<QualityAudit>>,
}

impl Default for ServingMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServingMetrics {
    /// A fresh sink with zeroed counters, started now.
    pub fn new() -> Self {
        ServingMetrics {
            inner: Mutex::new(Inner {
                counters: Counters::default(),
                queue_us: Welford::new(),
                prefill_us: Welford::new(),
                decode_per_token_us: Welford::new(),
                decode_step_us: LogHistogram::latency_us(),
                e2e_us: LogHistogram::latency_us(),
                kv_bytes_current: 0,
                kv_bytes_peak: 0,
                started: Instant::now(),
            }),
            quality: OnceLock::new(),
        }
    }

    /// Attach the replica's quality auditor so audit statistics render
    /// through this sink's JSON / Prometheus / report surfaces. A no-op
    /// when auditing is disabled (`--audit-rate 0` keeps every
    /// `wildcat_quality_*` metric and the `"quality"` JSON block absent).
    pub fn attach_quality(&self, audit: Arc<QualityAudit>) {
        if audit.enabled() {
            let _ = self.quality.set(audit);
        }
    }

    /// A consistent point-in-time snapshot of the attached auditor, or
    /// `None` when auditing is off. All export surfaces render from one
    /// snapshot, so they always agree on the audited values.
    pub fn quality_snapshot(&self) -> Option<QualitySnapshot> {
        self.quality.get().map(|a| a.snapshot())
    }

    /// Record a submission attempt.
    pub fn on_submit(&self) {
        self.inner.lock().unwrap().counters.submitted += 1;
    }

    /// Record a rejection (backpressure or pool admission).
    pub fn on_reject(&self) {
        self.inner.lock().unwrap().counters.rejected += 1;
    }

    /// Record a completed request: its latency split and token counts.
    pub fn on_complete(
        &self,
        queue: Duration,
        prefill: Duration,
        decode: Duration,
        n_prompt: usize,
        n_generated: usize,
    ) {
        let mut g = self.inner.lock().unwrap();
        g.counters.completed += 1;
        g.counters.tokens_generated += n_generated as u64;
        g.counters.prefill_tokens += n_prompt as u64;
        g.queue_us.push(queue.as_secs_f64() * 1e6);
        g.prefill_us.push(prefill.as_secs_f64() * 1e6);
        if n_generated > 0 {
            let per_token_us = decode.as_secs_f64() * 1e6 / n_generated as f64;
            g.decode_per_token_us.push(per_token_us);
            g.decode_step_us.record(per_token_us);
        }
        g.e2e_us.record((queue + prefill + decode).as_secs_f64() * 1e6);
    }

    /// Record one admission's prefill split: `computed` tokens ran
    /// through the backend, `skipped` were seeded from cached prefix KV
    /// rows. Recorded for every admission, including rejected ones (the
    /// compute has already happened by the time admission can reject).
    /// Also tallies the request-level prefix hit/miss pair: an admission
    /// counts as a hit iff any prompt token was skipped.
    pub fn on_prefill(&self, computed: usize, skipped: usize) {
        let mut g = self.inner.lock().unwrap();
        g.counters.prefill_tokens_computed += computed as u64;
        g.counters.prefill_tokens_skipped += skipped as u64;
        if skipped > 0 {
            g.counters.prefix_hits += 1;
        } else {
            g.counters.prefix_misses += 1;
        }
    }

    /// Record `n` cache compressions.
    pub fn on_compression(&self, n: u64) {
        self.inner.lock().unwrap().counters.compressions += n;
    }

    /// Record the replica's KV pool memory gauges (bytes, current +
    /// peak). Pushed by the scheduler after admissions and engine steps.
    pub fn set_kv_bytes(&self, current: usize, peak: usize) {
        let mut g = self.inner.lock().unwrap();
        g.kv_bytes_current = current;
        g.kv_bytes_peak = g.kv_bytes_peak.max(peak);
    }

    /// Current KV pool bytes as last pushed by the scheduler.
    pub fn kv_bytes(&self) -> (usize, usize) {
        let g = self.inner.lock().unwrap();
        (g.kv_bytes_current, g.kv_bytes_peak)
    }

    /// Copy of the current counter totals.
    pub fn counters(&self) -> Counters {
        self.inner.lock().unwrap().counters
    }

    /// Requests accepted but not yet completed (queued + actively
    /// decoding). The gauge the cluster router's `join_shortest_queue`
    /// policy balances on.
    pub fn in_flight(&self) -> u64 {
        self.inner.lock().unwrap().counters.in_flight()
    }

    /// Generated-token throughput since start (tokens/s).
    pub fn decode_throughput(&self) -> f64 {
        let g = self.inner.lock().unwrap();
        let dt = g.started.elapsed().as_secs_f64().max(1e-9);
        g.counters.tokens_generated as f64 / dt
    }

    /// Machine-readable snapshot — same data as [`ServingMetrics::report`]
    /// but as JSON, for `wildcat serve --metrics-json PATH` dumps and for
    /// the bench tooling's perf-trajectory files.
    pub fn to_json(&self) -> Json {
        let g = self.inner.lock().unwrap();
        let c = g.counters;
        // non-finite values (empty Welford extremes) have no JSON encoding
        let num = |x: f64| Json::Num(if x.is_finite() { x } else { 0.0 });
        let mut o = BTreeMap::new();
        o.insert("submitted".to_string(), Json::Num(c.submitted as f64));
        o.insert("rejected".to_string(), Json::Num(c.rejected as f64));
        o.insert("completed".to_string(), Json::Num(c.completed as f64));
        o.insert("prefill_tokens".to_string(), Json::Num(c.prefill_tokens as f64));
        o.insert(
            "prefill_tokens_computed".to_string(),
            Json::Num(c.prefill_tokens_computed as f64),
        );
        o.insert(
            "prefill_tokens_skipped".to_string(),
            Json::Num(c.prefill_tokens_skipped as f64),
        );
        o.insert("prefix_hits".to_string(), Json::Num(c.prefix_hits as f64));
        o.insert("prefix_misses".to_string(), Json::Num(c.prefix_misses as f64));
        o.insert("tokens_generated".to_string(), Json::Num(c.tokens_generated as f64));
        o.insert("compressions".to_string(), Json::Num(c.compressions as f64));
        o.insert("in_flight".to_string(), Json::Num(c.in_flight() as f64));
        o.insert("queue_us_mean".to_string(), num(g.queue_us.mean()));
        o.insert("prefill_us_mean".to_string(), num(g.prefill_us.mean()));
        o.insert(
            "decode_us_per_token_mean".to_string(),
            num(g.decode_per_token_us.mean()),
        );
        o.insert("e2e_ms_p50".to_string(), num(g.e2e_us.quantile(0.5) / 1e3));
        o.insert("e2e_ms_p99".to_string(), num(g.e2e_us.quantile(0.99) / 1e3));
        o.insert("kv_bytes_current".to_string(), Json::Num(g.kv_bytes_current as f64));
        o.insert("kv_bytes_peak".to_string(), Json::Num(g.kv_bytes_peak as f64));
        o.insert("uptime_s".to_string(), num(g.started.elapsed().as_secs_f64()));
        drop(g);
        if let Some(q) = self.quality_snapshot() {
            o.insert("quality".to_string(), q.to_json());
        }
        Json::Obj(o)
    }

    /// Write this replica's metrics into a Prometheus text-exposition
    /// builder, attaching `labels` (e.g. `[("replica", "2")]`) to every
    /// sample. Shared by [`ServingMetrics::to_prometheus`] and the
    /// cluster router's aggregated exposition.
    pub fn prom_write(&self, b: &mut crate::obs::PromBuilder, labels: &[(&str, &str)]) {
        let g = self.inner.lock().unwrap();
        let c = g.counters;
        let counters: [(&str, &str, u64); 8] = [
            (
                "wildcat_requests_submitted_total",
                "Requests submitted (accepted or not).",
                c.submitted,
            ),
            (
                "wildcat_requests_rejected_total",
                "Requests rejected (backpressure or pool admission).",
                c.rejected,
            ),
            (
                "wildcat_requests_completed_total",
                "Requests answered with a full generation.",
                c.completed,
            ),
            (
                "wildcat_tokens_generated_total",
                "Decode tokens produced across completed requests.",
                c.tokens_generated,
            ),
            (
                "wildcat_prefill_tokens_total",
                "Prompt tokens of completed requests.",
                c.prefill_tokens,
            ),
            (
                "wildcat_prefill_tokens_computed_total",
                "Prompt tokens actually computed at admission.",
                c.prefill_tokens_computed,
            ),
            (
                "wildcat_prefill_tokens_skipped_total",
                "Prompt tokens resumed from KV-pool prefix hits.",
                c.prefill_tokens_skipped,
            ),
            (
                "wildcat_compressions_total",
                "Layer-head cache compressions by the scheduler.",
                c.compressions,
            ),
        ];
        for (name, help, v) in counters {
            b.declare(name, "counter", help);
            b.sample(name, labels, v as f64);
        }
        b.declare(
            "wildcat_prefix_requests_total",
            "counter",
            "Admissions by request-level prefix-cache outcome.",
        );
        for (outcome, v) in [("hit", c.prefix_hits), ("miss", c.prefix_misses)] {
            let mut ls = labels.to_vec();
            ls.push(("outcome", outcome));
            b.sample("wildcat_prefix_requests_total", &ls, v as f64);
        }
        b.declare("wildcat_in_flight", "gauge", "Requests accepted but not yet completed.");
        b.sample("wildcat_in_flight", labels, c.in_flight() as f64);
        let gauges: [(&str, &str, f64); 3] = [
            ("wildcat_queue_us_mean", "Mean admission-queue wait (us).", g.queue_us.mean()),
            ("wildcat_prefill_us_mean", "Mean prefill latency (us).", g.prefill_us.mean()),
            (
                "wildcat_decode_us_per_token_mean",
                "Mean decode latency per generated token (us).",
                g.decode_per_token_us.mean(),
            ),
        ];
        for (name, help, v) in gauges {
            b.declare(name, "gauge", help);
            b.sample(name, labels, v);
        }
        // latency distributions as proper Prometheus histogram families
        // (cumulative _bucket/_sum/_count), scaled from recorded µs to ms
        b.histogram(
            "wildcat_e2e_latency_ms",
            "End-to-end request latency (ms).",
            labels,
            &g.e2e_us.cumulative_buckets(),
            g.e2e_us.sum(),
            g.e2e_us.total(),
            1e-3,
        );
        b.histogram(
            "wildcat_decode_step_latency_ms",
            "Mean decode latency per generated token, per completed request (ms).",
            labels,
            &g.decode_step_us.cumulative_buckets(),
            g.decode_step_us.sum(),
            g.decode_step_us.total(),
            1e-3,
        );
        b.declare("wildcat_kv_bytes", "gauge", "KV pool ledger bytes (current and peak).");
        for (state, v) in [("current", g.kv_bytes_current), ("peak", g.kv_bytes_peak)] {
            let mut ls = labels.to_vec();
            ls.push(("state", state));
            b.sample("wildcat_kv_bytes", &ls, v as f64);
        }
        b.declare("wildcat_uptime_seconds", "gauge", "Seconds since this metrics sink started.");
        b.sample("wildcat_uptime_seconds", labels, g.started.elapsed().as_secs_f64());
        drop(g);
        if let Some(q) = self.quality_snapshot() {
            q.prom_write(b, labels);
        }
    }

    /// Single-replica Prometheus text exposition (format 0.0.4); the
    /// cluster-wide aggregation lives on `cluster::Router`.
    pub fn to_prometheus(&self) -> String {
        let mut b = crate::obs::PromBuilder::new();
        self.prom_write(&mut b, &[]);
        b.finish()
    }

    /// Render a human-readable report block.
    pub fn report(&self) -> String {
        let g = self.inner.lock().unwrap();
        let c = g.counters;
        let dt = g.started.elapsed().as_secs_f64().max(1e-9);
        let base = format!(
            "requests: submitted={} rejected={} completed={}\n\
             tokens:   prefill={} generated={} ({:.1} tok/s decode)\n\
             prefill skipping: computed={} skipped={} (prefix hits={} misses={})\n\
             queue:    mean {:.1} us (max {:.1})\n\
             prefill:  mean {:.2} ms (max {:.2})\n\
             decode:   mean {:.2} ms/token\n\
             e2e:      p50 {:.2} ms  p99 {:.2} ms\n\
             kv pool:  {:.2} MiB current, {:.2} MiB peak\n\
             compressions: {}",
            c.submitted,
            c.rejected,
            c.completed,
            c.prefill_tokens,
            c.tokens_generated,
            c.tokens_generated as f64 / dt,
            c.prefill_tokens_computed,
            c.prefill_tokens_skipped,
            c.prefix_hits,
            c.prefix_misses,
            g.queue_us.mean(),
            if g.queue_us.count() > 0 { g.queue_us.max() } else { 0.0 },
            g.prefill_us.mean() / 1e3,
            if g.prefill_us.count() > 0 { g.prefill_us.max() / 1e3 } else { 0.0 },
            g.decode_per_token_us.mean() / 1e3,
            g.e2e_us.quantile(0.5) / 1e3,
            g.e2e_us.quantile(0.99) / 1e3,
            g.kv_bytes_current as f64 / (1024.0 * 1024.0),
            g.kv_bytes_peak as f64 / (1024.0 * 1024.0),
            c.compressions,
        );
        drop(g);
        match self.quality_snapshot() {
            Some(q) => format!(
                "{base}\nquality:  audited={} (decode={} folds={}) \
                 max_abs_err p50 {:.2e} p99 {:.2e} max {:.2e}\n\
                 slo:      degraded={} transitions {} degrade / {} recover",
                q.audited_total(),
                q.audited_decode,
                q.audited_folds,
                q.err_p50,
                q.err_p99,
                q.err_max,
                q.degraded,
                q.degradations,
                q.recoveries,
            ),
            None => base,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_flow() {
        let m = ServingMetrics::new();
        m.on_submit();
        m.on_submit();
        m.on_reject();
        m.on_complete(
            Duration::from_micros(100),
            Duration::from_millis(5),
            Duration::from_millis(10),
            64,
            8,
        );
        let c = m.counters();
        assert_eq!(c.submitted, 2);
        assert_eq!(c.rejected, 1);
        assert_eq!(c.completed, 1);
        assert_eq!(c.tokens_generated, 8);
        assert_eq!(c.prefill_tokens, 64);
        assert!(m.decode_throughput() > 0.0);
        let rep = m.report();
        assert!(rep.contains("completed=1"));
    }

    #[test]
    fn json_snapshot_roundtrips() {
        let m = ServingMetrics::new();
        // empty metrics: every field present and finite-encoded
        let j0 = m.to_json();
        assert_eq!(j0.get("completed").and_then(Json::as_f64), Some(0.0));
        m.on_submit();
        m.on_complete(
            Duration::from_micros(100),
            Duration::from_millis(5),
            Duration::from_millis(10),
            64,
            8,
        );
        let j = m.to_json();
        assert_eq!(j.get("submitted").and_then(Json::as_f64), Some(1.0));
        assert_eq!(j.get("tokens_generated").and_then(Json::as_f64), Some(8.0));
        assert!(j.get("e2e_ms_p50").and_then(Json::as_f64).unwrap() > 0.0);
        // serialise + reparse = fixed point
        let text = j.to_string_compact();
        assert_eq!(crate::util::json::parse(&text).unwrap(), j);
    }

    #[test]
    fn kv_gauges_track_current_and_sticky_peak() {
        let m = ServingMetrics::new();
        assert_eq!(m.kv_bytes(), (0, 0));
        m.set_kv_bytes(1000, 1500);
        m.set_kv_bytes(400, 400); // peak must not regress
        assert_eq!(m.kv_bytes(), (400, 1500));
        let j = m.to_json();
        assert_eq!(j.get("kv_bytes_current").and_then(Json::as_f64), Some(400.0));
        assert_eq!(j.get("kv_bytes_peak").and_then(Json::as_f64), Some(1500.0));
        assert!(m.report().contains("kv pool"));
    }

    #[test]
    fn prefix_hit_miss_pair_counts_requests() {
        let m = ServingMetrics::new();
        m.on_prefill(64, 0); // cold
        m.on_prefill(8, 56); // resumed from a prefix hit
        m.on_prefill(1, 63); // resumed
        let c = m.counters();
        assert_eq!(c.prefix_hits, 2);
        assert_eq!(c.prefix_misses, 1);
        assert_eq!(c.prefill_tokens_computed, 73);
        assert_eq!(c.prefill_tokens_skipped, 119);
        let j = m.to_json();
        assert_eq!(j.get("prefix_hits").and_then(Json::as_f64), Some(2.0));
        assert_eq!(j.get("prefix_misses").and_then(Json::as_f64), Some(1.0));
        assert!(m.report().contains("prefix hits=2 misses=1"));
    }

    #[test]
    fn prometheus_exposition_shape() {
        let m = ServingMetrics::new();
        m.on_submit();
        m.on_complete(
            Duration::from_micros(100),
            Duration::from_millis(5),
            Duration::from_millis(10),
            64,
            8,
        );
        m.on_prefill(32, 32);
        m.set_kv_bytes(1024, 2048);
        let text = m.to_prometheus();
        assert!(text.contains("# TYPE wildcat_requests_submitted_total counter"));
        assert!(text.contains("wildcat_requests_submitted_total 1\n"));
        assert!(text.contains("wildcat_tokens_generated_total 8\n"));
        assert!(text.contains("wildcat_prefix_requests_total{outcome=\"hit\"} 1\n"));
        assert!(text.contains("wildcat_prefix_requests_total{outcome=\"miss\"} 0\n"));
        assert!(text.contains("wildcat_kv_bytes{state=\"peak\"} 2048\n"));
        // latency families are proper Prometheus histograms
        assert!(text.contains("# TYPE wildcat_e2e_latency_ms histogram"));
        assert!(text.contains("wildcat_e2e_latency_ms_bucket{le=\"+Inf\"} 1\n"));
        assert!(text.contains("wildcat_e2e_latency_ms_count 1\n"));
        assert!(text.contains("# TYPE wildcat_decode_step_latency_ms histogram"));
        assert!(text.contains("wildcat_decode_step_latency_ms_count 1\n"));
        // no quality audit attached: no quality metrics
        assert!(!text.contains("wildcat_quality_"));
        // labeled variant used by the cluster aggregation
        let mut b = crate::obs::PromBuilder::new();
        m.prom_write(&mut b, &[("replica", "3")]);
        let labeled = b.finish();
        assert!(labeled.contains("wildcat_requests_submitted_total{replica=\"3\"} 1\n"));
        let want = "wildcat_prefix_requests_total{replica=\"3\",outcome=\"hit\"} 1\n";
        assert!(labeled.contains(want));
        assert!(labeled.contains("wildcat_e2e_latency_ms_bucket{replica=\"3\",le=\"+Inf\"} 1\n"));
    }

    #[test]
    fn quality_surfaces_absent_until_enabled_audit_attached() {
        use crate::obs::quality::{QualityAudit, QualityConfig};
        let m = ServingMetrics::new();
        // rate 0: attach is a no-op on every surface
        m.attach_quality(Arc::new(QualityAudit::new(QualityConfig::default())));
        assert!(m.to_json().get("quality").is_none());
        assert!(!m.to_prometheus().contains("wildcat_quality_"));
        assert!(!m.report().contains("quality:"));

        let m2 = ServingMetrics::new();
        let a = Arc::new(QualityAudit::new(QualityConfig { rate: 4, slo_abs_err: 0.0, seed: 3 }));
        a.observe_decode(0, &[(0, 1e-4, 1e-3)]);
        m2.attach_quality(a);
        let j = m2.to_json();
        let q = j.get("quality").expect("quality block present");
        assert_eq!(q.get("audited_samples").and_then(Json::as_f64), Some(1.0));
        let text = m2.to_prometheus();
        assert!(text.contains("wildcat_quality_audited_samples_total{kind=\"decode\"} 1\n"));
        assert!(text.contains("wildcat_quality_max_abs_err_hist_count 1\n"));
        assert!(m2.report().contains("quality:  audited=1"));
        // the JSON surface round-trips through our parser
        assert_eq!(crate::util::json::parse(&j.to_string_compact()).unwrap(), j);
    }

    #[test]
    fn concurrent_recording() {
        let m = std::sync::Arc::new(ServingMetrics::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let m = m.clone();
                s.spawn(move || {
                    for _ in 0..100 {
                        m.on_submit();
                        m.on_complete(
                            Duration::from_micros(10),
                            Duration::from_micros(50),
                            Duration::from_micros(100),
                            10,
                            2,
                        );
                    }
                });
            }
        });
        let c = m.counters();
        assert_eq!(c.submitted, 400);
        assert_eq!(c.completed, 400);
        assert_eq!(c.tokens_generated, 800);
    }
}
