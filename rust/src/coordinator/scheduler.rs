//! Continuous-batching scheduler: the engine loop that interleaves
//! prefill (admission) and decode (one token per active sequence per
//! step) over a [`ModelBackend`], with KV state held in the block-paged
//! [`KvPool`] through a [`CacheManager`] — prefill registration maps
//! shared prompt-prefix blocks, compression fires at prefill time and
//! past the per-sequence high-water mark during decode, and the pool's
//! pressure ladder (compress cold sequences → evict cached prefixes)
//! absorbs global memory pressure before admission ever rejects.

use super::batcher::Batcher;
use super::metrics::ServingMetrics;
use super::request::{Request, RequestTiming, Response};
use crate::kvcache::{CacheManager, KvCompressor};
use crate::kvpool::{KvPool, KvPoolConfig};
use crate::linalg::Matrix;
use crate::model::{generate::argmax, ModelBackend};
use crate::obs::quality::{self, QualityAudit};
use crate::obs::trace::{self, SpanKind};
use crate::rng::Rng;
use std::sync::Arc;
use std::time::Instant;

/// Scheduler tuning knobs (CLI surface: `--cache-budget`, `--slack`,
/// `--prefill-skip`).
#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Physical KV entries allowed per (layer, head) per sequence.
    pub cache_budget: usize,
    /// Hysteresis above the budget before decode-time re-compression.
    pub slack: usize,
    /// Resume prefill from KV-pool prefix hits instead of recomputing
    /// the matched tokens (`--prefill-skip`). Effective only when the
    /// backend reports [`ModelBackend::supports_prefill_resume`] and the
    /// pool has prefix sharing enabled; otherwise admissions silently
    /// fall back to cold prefill.
    pub prefill_skip: bool,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig { cache_budget: 192, slack: 32, prefill_skip: true }
    }
}

/// One active sequence's state (KV lives in the pool, keyed by `req.id`).
struct SeqState {
    req: Request,
    generated: Vec<u32>,
    next_token: u32,
    pos: usize,
    timing: RequestTiming,
    decode_started: Instant,
    // End of the last span traced on this sequence's lane (prefill end,
    // then each decode step): decode_step spans tile the window from
    // decode start to retirement with no gaps, so a request's lifecycle
    // spans sum to its recorded e2e latency.
    last_span_end: Instant,
    // Audit shadow: the full *uncompressed* per-(layer, head) KV rows of
    // a quality-sampled request. Exact attention over these is the
    // ground truth each decode step's served (possibly compressed)
    // attention is audited against. `None` for unsampled requests.
    shadow: Option<Vec<(Matrix, Matrix)>>,
}

/// The scheduler: owns the backend and active sequence set.
pub struct Scheduler<B: ModelBackend> {
    backend: B,
    /// The scheduler's tuning knobs.
    pub cfg: SchedulerConfig,
    cache: CacheManager,
    active: Vec<SeqState>,
    metrics: Arc<ServingMetrics>,
    rng: Rng,
    audit: Option<Arc<QualityAudit>>,
    /// `cache_budget` as configured — restored when a degraded SLO
    /// recovers (the degradation action doubles the live budget).
    base_budget: usize,
    degraded_applied: bool,
}

impl<B: ModelBackend> Scheduler<B> {
    /// Stand-alone scheduler over a private, unbounded pool.
    pub fn new(
        backend: B,
        cfg: SchedulerConfig,
        compressor: Arc<dyn KvCompressor>,
        metrics: Arc<ServingMetrics>,
        seed: u64,
    ) -> Self {
        let pool = Arc::new(KvPool::new(KvPoolConfig::default(), compressor));
        Self::with_pool(backend, cfg, metrics, seed, pool)
    }

    /// Scheduler over a shared pool (the server threads one per replica).
    pub fn with_pool(
        backend: B,
        cfg: SchedulerConfig,
        metrics: Arc<ServingMetrics>,
        seed: u64,
        pool: Arc<KvPool>,
    ) -> Self {
        let model_cfg = backend.config();
        let n_lh = model_cfg.n_layers * model_cfg.n_heads;
        let mut cache =
            CacheManager::with_pool(cfg.cache_budget, n_lh, model_cfg.beta() as f64, pool);
        cache.high_water = cfg.cache_budget + cfg.slack;
        let base_budget = cfg.cache_budget;
        Scheduler {
            backend,
            cfg,
            cache,
            active: Vec::new(),
            metrics,
            rng: Rng::seed_from(seed),
            audit: None,
            base_budget,
            degraded_applied: false,
        }
    }

    /// Attach the replica's approximation-quality auditor: sampled
    /// requests keep a shadow uncompressed KV cache whose exact attention
    /// is recomputed every decode step, and while the error SLO holds the
    /// stack degraded the per-sequence coreset budget is doubled (a
    /// larger coreset ⇒ lower approximation error). No-op when auditing
    /// is disabled (`rate == 0`).
    pub fn set_quality_audit(&mut self, audit: Arc<QualityAudit>) {
        if audit.enabled() {
            self.audit = Some(audit);
        }
    }

    /// The per-sequence physical budget currently in force — the
    /// configured `cache_budget`, or double that while the error SLO
    /// holds the stack degraded.
    pub fn effective_cache_budget(&self) -> usize {
        self.cache.budget
    }

    /// Sequences currently decoding.
    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    /// The pool backing this scheduler's caches (metrics surface).
    pub fn pool(&self) -> &Arc<KvPool> {
        self.cache.pool()
    }

    /// Admit one request: prefill, register the caches with the pool
    /// (prefix sharing + admission control), compress past budget, seed
    /// decode state. `None` on success; a `Some` response means the
    /// pool's pressure ladder could not make room — the request is
    /// answered immediately with zero tokens and counted as rejected
    /// (never silently dropped).
    pub fn admit(&mut self, req: Request) -> Option<Response> {
        let queue = req.arrived.elapsed();
        let t0 = Instant::now();
        // One relaxed atomic load; all tracing below (including every
        // extra Instant::now) is skipped when the tracer is off.
        let tracing = trace::enabled();
        if tracing {
            trace::span(SpanKind::Queue, req.arrived, t0, req.id, req.tokens.len() as u64, 0);
        }
        let n = req.tokens.len();
        let before = self.cache.compressions();
        // prefill skipping: lookup → compute (tail only) → seal. Falls
        // back to the cold path when disabled, when the backend cannot
        // seed attention from cached rows, or when sharing is off.
        let resume = self.cfg.prefill_skip
            && self.backend.supports_prefill_resume()
            && self.cache.pool().config().prefix_sharing;
        // Quality sampling is decided at admission: a sampled request
        // keeps a shadow copy of its uncompressed prefill KV rows as the
        // audit's exact reference.
        let audit_this = self.audit.as_ref().is_some_and(|a| a.audit_request(req.id));
        let (logits, skipped, ingested, shadow) = if resume {
            let lk0 = if tracing { Some(Instant::now()) } else { None };
            let handle = self.cache.lookup_prefix(&req.tokens);
            if let Some(lk0) = lk0 {
                let matched = handle.matched_tokens() as u64;
                let hit = u64::from(handle.is_hit());
                trace::span(SpanKind::PrefixLookup, lk0, Instant::now(), req.id, matched, hit);
            }
            let skipped = handle.matched_tokens();
            // `ingest_resumed` consumes the handle; the shadow needs its
            // uncompressed prefix rows, so clone them first.
            let prefix = (audit_this && handle.is_hit())
                .then(|| (handle.kv.keys.clone(), handle.kv.values.clone()));
            let out = if handle.is_hit() {
                self.backend.prefill_from(&handle.kv, &req.tokens[skipped..])
            } else {
                self.backend.prefill(&req.tokens)
            };
            let shadow: Option<Vec<(Matrix, Matrix)>> = audit_this.then(|| match &prefix {
                // resumed prefill returns tail-only caches: the shadow is
                // prefix rows ++ tail rows (the full uncompressed prompt)
                Some((pk, pv)) => pk
                    .iter()
                    .zip(pv)
                    .zip(out.k_cache.iter().zip(&out.v_cache))
                    .map(|((pk, pv), (tk, tv))| {
                        (Matrix::vcat(&[pk, tk]), Matrix::vcat(&[pv, tv]))
                    })
                    .collect(),
                None => out
                    .k_cache
                    .iter()
                    .zip(&out.v_cache)
                    .map(|(k, v)| (k.clone(), v.clone()))
                    .collect(),
            });
            let ok = self
                .cache
                .ingest_resumed(req.id, &req.tokens, handle, &out.k_cache, &out.v_cache)
                .is_ok();
            (out.logits, skipped, ok, shadow)
        } else {
            let out = self.backend.prefill(&req.tokens);
            let shadow: Option<Vec<(Matrix, Matrix)>> = audit_this.then(|| {
                out.k_cache
                    .iter()
                    .zip(&out.v_cache)
                    .map(|(k, v)| (k.clone(), v.clone()))
                    .collect()
            });
            let ok = self
                .cache
                .ingest_prefill(req.id, &req.tokens, &out.k_cache, &out.v_cache)
                .is_ok();
            (out.logits, 0, ok, shadow)
        };
        self.metrics.on_prefill(n - skipped, skipped);
        if !ingested {
            self.metrics.on_reject();
            self.push_kv_gauges();
            let prefill = t0.elapsed();
            if tracing {
                let now = Instant::now();
                let computed = (n - skipped) as u64;
                trace::span(SpanKind::Prefill, t0, now, req.id, computed, skipped as u64);
                let e2e_us = (queue + prefill).as_micros() as u64;
                trace::span(SpanKind::Retire, now, now, req.id, 0, e2e_us);
            }
            return Some(Response {
                id: req.id,
                tokens: Vec::new(),
                timing: RequestTiming { queue, prefill, ..Default::default() },
                cache_entries: 0,
                context_len: req.tokens.len(),
            });
        }
        // prefill compression down to the per-sequence budget
        self.cache.compress_sequence(req.id, None, &mut self.rng);
        self.metrics.on_compression(self.cache.compressions() - before);
        self.push_kv_gauges();
        let prefill_end = Instant::now();
        let prefill = prefill_end.saturating_duration_since(t0);
        if tracing {
            let computed = (n - skipped) as u64;
            trace::span(SpanKind::Prefill, t0, prefill_end, req.id, computed, skipped as u64);
        }
        let pos = req.tokens.len();
        let next_token = argmax(&logits) as u32;
        self.active.push(SeqState {
            req,
            generated: Vec::new(),
            next_token,
            pos,
            timing: RequestTiming { queue, prefill, ..Default::default() },
            // decode timing starts where the prefill span ended, so the
            // traced lifecycle spans tile the request end to end
            decode_started: prefill_end,
            last_span_end: prefill_end,
            shadow,
        });
        None
    }

    fn push_kv_gauges(&self) {
        let pool = self.cache.pool();
        self.metrics.set_kv_bytes(pool.used_bytes(), pool.peak_bytes());
        if trace::enabled() {
            let snap = pool.snapshot();
            trace::gauge(SpanKind::GAUGE_BLOCKS_IN_USE, snap.blocks as u64);
            trace::gauge(SpanKind::GAUGE_IN_FLIGHT, self.active.len() as u64);
        }
    }

    /// Poll the SLO's degraded flag once per engine step and apply the
    /// adaptive-degradation action: double the per-sequence coreset
    /// budget (retaining more entries per layer-head lowers the
    /// approximation error) while degraded, restore the configured
    /// budget on recovery. The kvpool's pressure ladder reads the same
    /// flag to pause its compression rung.
    fn apply_slo_budget(&mut self) {
        let Some(a) = &self.audit else { return };
        let degraded = a.is_degraded();
        if degraded == self.degraded_applied {
            return;
        }
        self.degraded_applied = degraded;
        let budget = if degraded { self.base_budget * 2 } else { self.base_budget };
        self.cache.budget = budget;
        self.cache.high_water = budget + self.cfg.slack;
    }

    /// Audit one sampled decode step: recompute exact attention over the
    /// request's shadow uncompressed KV and feed the per-(layer, head)
    /// errors to the audit sink. Runs after the served output of this
    /// step is already decided — it never perturbs served tokens.
    fn audit_decode_step(
        audit: Option<&QualityAudit>,
        backend: &mut B,
        st: &SeqState,
        token: u32,
        pos: usize,
        attn: &[Vec<f32>],
    ) {
        let Some(a) = audit else { return };
        let Some(shadow) = st.shadow.as_ref() else { return };
        let ws: Vec<Vec<f64>> = shadow.iter().map(|(k, _)| vec![1.0f64; k.rows()]).collect();
        let refs: Vec<(&Matrix, &Matrix, &[f64])> = shadow
            .iter()
            .zip(&ws)
            .map(|((k, v), w)| (k, v, w.as_slice()))
            .collect();
        let Some((_, _, _, reference)) = backend.decode_with_attn(token, pos, &refs) else {
            return;
        };
        let errs: Vec<(usize, f64, f64)> = reference
            .iter()
            .zip(attn)
            .enumerate()
            .map(|(lh, (r, ap))| {
                let (max_abs, rel) = quality::matrix_error(r, ap);
                (lh, max_abs, rel)
            })
            .collect();
        a.observe_decode(st.req.id, &errs);
    }

    /// One engine iteration: decode one token for every active sequence.
    /// Returns completed responses.
    pub fn step(&mut self) -> Vec<Response> {
        self.apply_slo_budget();
        let model_cfg = self.backend.config();
        let n_lh = model_cfg.n_layers * model_cfg.n_heads;
        let max_pos = model_cfg.max_len - 1;
        let mut done = Vec::new();
        let mut i = 0;
        let compressions_before = self.cache.compressions();
        while i < self.active.len() {
            // emit the pending token, then compute the next one
            let finished = {
                let st = &mut self.active[i];
                st.generated.push(st.next_token);
                st.generated.len() >= st.req.max_new
            };
            if !finished {
                let st = &mut self.active[i];
                let caches = self.cache.gather(st.req.id).expect("active sequence in pool");
                let refs: Vec<(&Matrix, &Matrix, &[f64])> =
                    caches.iter().map(|(k, v, w)| (k, v, w.as_slice())).collect();
                let token = st.next_token;
                let pos = st.pos.min(max_pos);
                let (logits, new_k, new_v) = if st.shadow.is_some() {
                    // audited step: the capturing decode serves the
                    // request (same code path, identical logits) and its
                    // attention rows are compared to the shadow-exact
                    // recompute
                    match self.backend.decode_with_attn(token, pos, &refs) {
                        Some((logits, new_k, new_v, attn)) => {
                            Self::audit_decode_step(
                                self.audit.as_deref(),
                                &mut self.backend,
                                st,
                                token,
                                pos,
                                &attn,
                            );
                            (logits, new_k, new_v)
                        }
                        None => {
                            // backend cannot capture per-head outputs
                            st.shadow = None;
                            self.backend.decode(token, pos, &refs)
                        }
                    }
                } else {
                    self.backend.decode(token, pos, &refs)
                };
                for lh in 0..n_lh {
                    // crossing budget + slack triggers sequence
                    // re-compression inside the manager
                    self.cache.append_and_maybe_compress(
                        st.req.id,
                        lh,
                        &new_k[lh],
                        &new_v[lh],
                        None,
                        &mut self.rng,
                    );
                }
                if let Some(shadow) = st.shadow.as_mut() {
                    // the shadow grows by the same (exact) rows the pool
                    // just appended
                    for lh in 0..n_lh {
                        shadow[lh].0.push_row(&new_k[lh]);
                        shadow[lh].1.push_row(&new_v[lh]);
                    }
                }
                st.pos += 1;
                st.next_token = argmax(&logits) as u32;
                if trace::enabled() {
                    // inter-token span: previous span end → this token
                    // emitted, inclusive of batch-mate interference
                    let now = Instant::now();
                    let emitted = st.generated.len() as u64;
                    trace::span(SpanKind::DecodeStep, st.last_span_end, now, st.req.id, emitted, 0);
                    st.last_span_end = now;
                }
                i += 1;
            } else {
                let mut st = self.active.swap_remove(i);
                st.timing.decode = st.decode_started.elapsed();
                if trace::enabled() {
                    let now = Instant::now();
                    trace::span(
                        SpanKind::Retire,
                        st.last_span_end,
                        now,
                        st.req.id,
                        st.generated.len() as u64,
                        st.timing.total().as_micros() as u64,
                    );
                }
                self.metrics.on_complete(
                    st.timing.queue,
                    st.timing.prefill,
                    st.timing.decode,
                    st.req.tokens.len(),
                    st.generated.len(),
                );
                let cache_entries = self
                    .cache
                    .pool()
                    .seq_stats(st.req.id)
                    .map(|s| s.physical_max)
                    .unwrap_or(0);
                // retire exactly once: a false return here means the
                // sequence leaked or was double-freed
                assert!(
                    self.cache.drop_sequence(st.req.id),
                    "retired unknown sequence {}",
                    st.req.id
                );
                done.push(Response {
                    id: st.req.id,
                    tokens: st.generated,
                    timing: st.timing,
                    cache_entries,
                    context_len: st.req.tokens.len(),
                });
            }
        }
        self.metrics
            .on_compression(self.cache.compressions() - compressions_before);
        self.push_kv_gauges();
        done
    }

    /// Drive a full offline run: admit per the batcher policy from a FIFO
    /// of requests, stepping until everything completes. Pool-rejected
    /// admissions surface as zero-token responses.
    pub fn run_to_completion(&mut self, mut queue: Vec<Request>, batcher: &Batcher) -> Vec<Response> {
        queue.reverse(); // pop from the back = FIFO front
        let mut responses = Vec::new();
        while !queue.is_empty() || !self.active.is_empty() {
            let oldest_wait = queue
                .last()
                .map(|r| r.arrived.elapsed())
                .unwrap_or_default();
            let n = batcher.admit_count(self.active.len(), queue.len(), oldest_wait);
            for _ in 0..n {
                let req = queue.pop().unwrap();
                if let Some(rejected) = self.admit(req) {
                    responses.push(rejected);
                }
            }
            if self.active.is_empty() {
                continue;
            }
            responses.extend(self.step());
        }
        responses
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatcherConfig;
    use crate::kvcache::{StreamingLlm, UniformKv};
    use crate::model::{ModelConfig, Transformer};

    fn mk_sched(budget: usize) -> Scheduler<Transformer> {
        let cfg = ModelConfig { vocab: 16, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 32, max_len: 256 };
        let mut rng = Rng::seed_from(11);
        let model = Transformer::random(cfg, &mut rng);
        Scheduler::new(
            model,
            SchedulerConfig { cache_budget: budget, slack: 8, ..Default::default() },
            Arc::new(StreamingLlm),
            Arc::new(ServingMetrics::new()),
            7,
        )
    }

    fn reqs(n: usize, prompt_len: usize, max_new: usize) -> Vec<Request> {
        (0..n)
            .map(|i| {
                Request::new(i as u64, (0..prompt_len).map(|j| ((i + j) % 16) as u32).collect(), max_new)
            })
            .collect()
    }

    #[test]
    fn completes_all_requests_exactly_once() {
        let mut s = mk_sched(1000);
        let batcher = Batcher::new(BatcherConfig::default());
        let rs = s.run_to_completion(reqs(9, 12, 4), &batcher);
        assert_eq!(rs.len(), 9);
        let mut ids: Vec<u64> = rs.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..9).collect::<Vec<_>>());
        assert!(rs.iter().all(|r| r.tokens.len() == 4));
        // all sequences retired: the pool is empty again
        assert_eq!(s.pool().snapshot().sequences, 0);
    }

    #[test]
    fn respects_cache_budget_during_decode() {
        let mut s = mk_sched(40);
        let batcher = Batcher::new(BatcherConfig::default());
        let rs = s.run_to_completion(reqs(2, 100, 30), &batcher);
        for r in rs {
            // budget + slack + a step of growth
            assert!(r.cache_entries <= 40 + 8 + 1, "entries={}", r.cache_entries);
        }
    }

    #[test]
    fn single_sequence_matches_generate() {
        // The scheduler path must produce the same tokens as the direct
        // greedy_decode helper under the same compressor/budget.
        let cfg = ModelConfig { vocab: 16, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 32, max_len: 256 };
        let mut rng = Rng::seed_from(11);
        let model = Transformer::random(cfg, &mut rng);
        let prompt: Vec<u32> = (0..20).map(|j| (j % 16) as u32).collect();
        let direct = crate::model::greedy_decode(
            &model,
            &prompt,
            5,
            1000,
            &UniformKv,
            &mut Rng::seed_from(3),
        );
        let mut s = Scheduler::new(
            model,
            SchedulerConfig { cache_budget: 1000, slack: 8, ..Default::default() },
            Arc::new(UniformKv),
            Arc::new(ServingMetrics::new()),
            3,
        );
        assert!(s.admit(Request::new(0, prompt, 5)).is_none());
        let mut out = Vec::new();
        while out.is_empty() {
            out = s.step();
        }
        assert_eq!(out[0].tokens, direct.tokens);
    }

    #[test]
    fn interleaves_multiple_sequences() {
        let mut s = mk_sched(1000);
        assert!(s.admit(Request::new(0, vec![1, 2, 3], 3)).is_none());
        assert!(s.admit(Request::new(1, vec![4, 5, 6, 7], 2)).is_none());
        assert_eq!(s.active_count(), 2);
        let mut all = Vec::new();
        for _ in 0..5 {
            all.extend(s.step());
        }
        assert_eq!(all.len(), 2);
        assert_eq!(s.active_count(), 0);
        let r1 = all.iter().find(|r| r.id == 1).unwrap();
        assert_eq!(r1.tokens.len(), 2);
    }

    #[test]
    fn shared_prompt_prefixes_are_stored_once() {
        let mut s = mk_sched(1000);
        // 6 requests over 2 distinct prompts — blocks dedup the prefills
        let prompt_a: Vec<u32> = (0..40).map(|j| (j % 16) as u32).collect();
        let prompt_b: Vec<u32> = (0..40).map(|j| ((j + 5) % 16) as u32).collect();
        for i in 0..6u64 {
            let p = if i % 2 == 0 { prompt_a.clone() } else { prompt_b.clone() };
            assert!(s.admit(Request::new(i, p, 2)).is_none());
        }
        let snap = s.pool().snapshot();
        assert_eq!(snap.prefix_queries, 6);
        assert_eq!(snap.prefix_hits, 4, "4 of 6 admissions reuse a stored prefix");
        assert!(snap.shared_tokens > 0);
        // pool bytes are well below six private copies
        let per_seq = snap.used_floats / 6;
        // 6 private copies would cost 6 seqs x 40 tokens x 4 lh x 17
        // floats (d_head 8 keys + 8 values + 1 weight)
        assert!(
            snap.used_floats < 6 * 40 * 4 * 17,
            "no deduplication happened: used={} (per seq {per_seq})",
            snap.used_floats
        );
        while s.active_count() > 0 {
            s.step();
        }
    }

    #[test]
    fn audited_exact_path_reports_identically_zero_error() {
        use crate::obs::quality::{QualityAudit, QualityConfig};
        // budget far above every sequence length: no compression ever
        // fires, so the served attention IS the exact attention and every
        // audited error must be identically 0.0 (not merely small)
        let mut s = mk_sched(1000);
        let audit =
            Arc::new(QualityAudit::new(QualityConfig { rate: 1, slo_abs_err: 0.0, seed: 9 }));
        s.set_quality_audit(audit.clone());
        s.pool().set_quality_audit(audit.clone());
        let batcher = Batcher::new(BatcherConfig::default());
        let rs = s.run_to_completion(reqs(4, 12, 5), &batcher);
        assert_eq!(rs.len(), 4);
        let snap = audit.snapshot();
        assert!(snap.audited_decode > 0, "rate 1 must audit decode steps");
        assert_eq!(snap.err_max, 0.0);
        assert_eq!(snap.err_p99, 0.0);
        assert_eq!(snap.rel_p99, 0.0);
    }

    #[test]
    fn auditing_does_not_perturb_served_tokens() {
        use crate::obs::quality::{QualityAudit, QualityConfig};
        let run = |rate: u32| {
            let mut s = mk_sched(24); // tight: decode re-compression fires
            if rate > 0 {
                let audit = Arc::new(QualityAudit::new(QualityConfig {
                    rate,
                    slo_abs_err: 0.0,
                    seed: 1,
                }));
                s.set_quality_audit(audit.clone());
                s.pool().set_quality_audit(audit);
            }
            let batcher = Batcher::new(BatcherConfig::default());
            let mut rs = s.run_to_completion(reqs(3, 40, 6), &batcher);
            rs.sort_by_key(|r| r.id);
            rs.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
        };
        assert_eq!(run(0), run(1), "audits must be invisible to served output");
    }

    #[test]
    fn degraded_slo_doubles_coreset_budget_until_recovery() {
        use crate::obs::quality::{slo, QualityAudit, QualityConfig};
        let mut s = mk_sched(50);
        let audit =
            Arc::new(QualityAudit::new(QualityConfig { rate: 1, slo_abs_err: 1e-3, seed: 2 }));
        s.set_quality_audit(audit.clone());
        assert!(s.admit(Request::new(0, vec![1, 2, 3], 8)).is_none());
        assert_eq!(s.effective_cache_budget(), 50);
        // breach the SLO through the shared sink, as a kvpool fold would
        for _ in 0..slo::WINDOW {
            audit.observe_fold(0, 0, 5e-3, 1e-2);
        }
        s.step();
        assert_eq!(s.effective_cache_budget(), 100, "degradation doubles the budget");
        for _ in 0..2 * slo::WINDOW {
            audit.observe_fold(0, 0, 1e-6, 1e-5);
        }
        s.step();
        assert_eq!(s.effective_cache_budget(), 50, "recovery restores the budget");
        let snap = audit.snapshot();
        assert_eq!((snap.degradations, snap.recoveries), (1, 1));
    }

    #[test]
    fn tight_pool_budget_absorbs_pressure_without_rejection() {
        let cfg = ModelConfig { vocab: 16, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 32, max_len: 256 };
        let model = Transformer::random(cfg, &mut Rng::seed_from(11));
        // one uncompressed 64-token sequence = 64 * 4 lh * 17 floats
        let pool = Arc::new(KvPool::new(
            KvPoolConfig {
                budget_floats: 2 * 64 * 4 * 17,
                compress_budget: 16,
                block_tokens: 8,
                ..Default::default()
            },
            Arc::new(StreamingLlm) as Arc<dyn KvCompressor>,
        ));
        let metrics = Arc::new(ServingMetrics::new());
        let mut s = Scheduler::with_pool(
            model,
            SchedulerConfig { cache_budget: 1000, slack: 8, ..Default::default() },
            metrics.clone(),
            7,
            pool,
        );
        let batcher = Batcher::new(BatcherConfig::default());
        let rs = s.run_to_completion(reqs(6, 64, 4), &batcher);
        assert_eq!(rs.len(), 6);
        assert!(rs.iter().all(|r| r.tokens.len() == 4), "pressure rejected load");
        let snap = s.pool().snapshot();
        assert_eq!(snap.admission_rejects, 0);
        assert!(snap.tier_compressions > 0, "compression tier never fired");
        assert_eq!(metrics.counters().rejected, 0);
    }
}
