//! Continuous-batching scheduler: the engine loop that interleaves
//! prefill (admission) and decode (one token per active sequence per
//! step) over a [`ModelBackend`], with KV compression at prefill time and
//! budget-triggered re-compression during decode.

use super::batcher::Batcher;
use super::metrics::ServingMetrics;
use super::request::{Request, RequestTiming, Response};
use crate::kvcache::{CompressionCtx, KvCompressor, KvEntry};
use crate::linalg::Matrix;
use crate::model::{generate::argmax, ModelBackend};
use crate::rng::Rng;
use std::sync::Arc;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Physical KV entries allowed per (layer, head) per sequence.
    pub cache_budget: usize,
    /// Hysteresis above the budget before decode-time re-compression.
    pub slack: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig { cache_budget: 192, slack: 32 }
    }
}

/// One active sequence's state.
struct SeqState {
    req: Request,
    caches: Vec<(Matrix, Matrix, Vec<f64>)>,
    generated: Vec<u32>,
    next_token: u32,
    pos: usize,
    timing: RequestTiming,
    decode_started: Instant,
}

/// The scheduler: owns the backend and active sequence set.
pub struct Scheduler<B: ModelBackend> {
    backend: B,
    pub cfg: SchedulerConfig,
    compressor: Arc<dyn KvCompressor>,
    active: Vec<SeqState>,
    metrics: Arc<ServingMetrics>,
    rng: Rng,
}

impl<B: ModelBackend> Scheduler<B> {
    pub fn new(
        backend: B,
        cfg: SchedulerConfig,
        compressor: Arc<dyn KvCompressor>,
        metrics: Arc<ServingMetrics>,
        seed: u64,
    ) -> Self {
        Scheduler {
            backend,
            cfg,
            compressor,
            active: Vec::new(),
            metrics,
            rng: Rng::seed_from(seed),
        }
    }

    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    /// Admit one request: prefill, compress the caches, seed decode state.
    pub fn admit(&mut self, req: Request) {
        let queue = req.arrived.elapsed();
        let t0 = Instant::now();
        let model_cfg = self.backend.config();
        let n_lh = model_cfg.n_layers * model_cfg.n_heads;
        let out = self.backend.prefill(&req.tokens);
        let mut caches = Vec::with_capacity(n_lh);
        let mut compressions = 0;
        for lh in 0..n_lh {
            let keys = &out.k_cache[lh];
            let values = &out.v_cache[lh];
            let entry = if keys.rows() <= self.cfg.cache_budget {
                KvEntry::exact(keys.clone(), values.clone())
            } else {
                compressions += 1;
                let ctx = CompressionCtx {
                    keys,
                    values,
                    budget: self.cfg.cache_budget,
                    beta: model_cfg.beta() as f64,
                    layer: lh / model_cfg.n_heads,
                    n_layers: model_cfg.n_layers,
                    obs_queries: None,
                };
                self.compressor.compress(&ctx, &mut self.rng)
            };
            caches.push((entry.keys, entry.values, entry.weights));
        }
        self.metrics.on_compression(compressions);
        let prefill = t0.elapsed();
        let pos = req.tokens.len();
        let next_token = argmax(&out.logits) as u32;
        self.active.push(SeqState {
            req,
            caches,
            generated: Vec::new(),
            next_token,
            pos,
            timing: RequestTiming { queue, prefill, ..Default::default() },
            decode_started: Instant::now(),
        });
    }

    /// One engine iteration: decode one token for every active sequence.
    /// Returns completed responses.
    pub fn step(&mut self) -> Vec<Response> {
        let model_cfg = self.backend.config();
        let max_pos = model_cfg.max_len - 1;
        let mut done = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            // emit the pending token, then compute the next one
            let finished = {
                let st = &mut self.active[i];
                st.generated.push(st.next_token);
                st.generated.len() >= st.req.max_new
            };
            if !finished {
                let st = &mut self.active[i];
                let refs: Vec<(&Matrix, &Matrix, &[f64])> = st
                    .caches
                    .iter()
                    .map(|(k, v, w)| (k, v, w.as_slice()))
                    .collect();
                let (logits, new_k, new_v) =
                    self.backend
                        .decode(st.next_token, st.pos.min(max_pos), &refs);
                for (lh, (k, v, w)) in st.caches.iter_mut().enumerate() {
                    k.push_row(&new_k[lh]);
                    v.push_row(&new_v[lh]);
                    w.push(1.0);
                }
                st.pos += 1;
                st.next_token = argmax(&logits) as u32;
                // decode-time re-compression past budget + slack
                let limit = self.cfg.cache_budget + self.cfg.slack;
                if st.caches[0].0.rows() > limit {
                    let mut n_comp = 0;
                    for (lh, (k, v, w)) in st.caches.iter_mut().enumerate() {
                        let ctx = CompressionCtx {
                            keys: k,
                            values: v,
                            budget: self.cfg.cache_budget,
                            beta: model_cfg.beta() as f64,
                            layer: lh / model_cfg.n_heads,
                            n_layers: model_cfg.n_layers,
                            obs_queries: None,
                        };
                        let entry = self.compressor.compress(&ctx, &mut self.rng);
                        *k = entry.keys;
                        *v = entry.values;
                        *w = entry.weights;
                        n_comp += 1;
                    }
                    self.metrics.on_compression(n_comp);
                }
                i += 1;
            } else {
                let mut st = self.active.swap_remove(i);
                st.timing.decode = st.decode_started.elapsed();
                self.metrics.on_complete(
                    st.timing.queue,
                    st.timing.prefill,
                    st.timing.decode,
                    st.req.tokens.len(),
                    st.generated.len(),
                );
                let cache_entries =
                    st.caches.iter().map(|(k, _, _)| k.rows()).max().unwrap_or(0);
                done.push(Response {
                    id: st.req.id,
                    tokens: st.generated,
                    timing: st.timing,
                    cache_entries,
                    context_len: st.req.tokens.len(),
                });
            }
        }
        done
    }

    /// Drive a full offline run: admit per the batcher policy from a FIFO
    /// of requests, stepping until everything completes.
    pub fn run_to_completion(&mut self, mut queue: Vec<Request>, batcher: &Batcher) -> Vec<Response> {
        queue.reverse(); // pop from the back = FIFO front
        let mut responses = Vec::new();
        while !queue.is_empty() || !self.active.is_empty() {
            let oldest_wait = queue
                .last()
                .map(|r| r.arrived.elapsed())
                .unwrap_or_default();
            let n = batcher.admit_count(self.active.len(), queue.len(), oldest_wait);
            for _ in 0..n {
                let req = queue.pop().unwrap();
                self.admit(req);
            }
            if self.active.is_empty() {
                continue;
            }
            responses.extend(self.step());
        }
        responses
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatcherConfig;
    use crate::kvcache::{StreamingLlm, UniformKv};
    use crate::model::{ModelConfig, Transformer};

    fn mk_sched(budget: usize) -> Scheduler<Transformer> {
        let cfg = ModelConfig { vocab: 16, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 32, max_len: 256 };
        let mut rng = Rng::seed_from(11);
        let model = Transformer::random(cfg, &mut rng);
        Scheduler::new(
            model,
            SchedulerConfig { cache_budget: budget, slack: 8 },
            Arc::new(StreamingLlm),
            Arc::new(ServingMetrics::new()),
            7,
        )
    }

    fn reqs(n: usize, prompt_len: usize, max_new: usize) -> Vec<Request> {
        (0..n)
            .map(|i| {
                Request::new(i as u64, (0..prompt_len).map(|j| ((i + j) % 16) as u32).collect(), max_new)
            })
            .collect()
    }

    #[test]
    fn completes_all_requests_exactly_once() {
        let mut s = mk_sched(1000);
        let batcher = Batcher::new(BatcherConfig::default());
        let rs = s.run_to_completion(reqs(9, 12, 4), &batcher);
        assert_eq!(rs.len(), 9);
        let mut ids: Vec<u64> = rs.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..9).collect::<Vec<_>>());
        assert!(rs.iter().all(|r| r.tokens.len() == 4));
    }

    #[test]
    fn respects_cache_budget_during_decode() {
        let mut s = mk_sched(40);
        let batcher = Batcher::new(BatcherConfig::default());
        let rs = s.run_to_completion(reqs(2, 100, 30), &batcher);
        for r in rs {
            // budget + slack + a step of growth
            assert!(r.cache_entries <= 40 + 8 + 1, "entries={}", r.cache_entries);
        }
    }

    #[test]
    fn single_sequence_matches_generate() {
        // The scheduler path must produce the same tokens as the direct
        // greedy_decode helper under the same compressor/budget.
        let cfg = ModelConfig { vocab: 16, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 32, max_len: 256 };
        let mut rng = Rng::seed_from(11);
        let model = Transformer::random(cfg, &mut rng);
        let prompt: Vec<u32> = (0..20).map(|j| (j % 16) as u32).collect();
        let direct = crate::model::greedy_decode(
            &model,
            &prompt,
            5,
            1000,
            &UniformKv,
            &mut Rng::seed_from(3),
        );
        let mut s = Scheduler::new(
            model,
            SchedulerConfig { cache_budget: 1000, slack: 8 },
            Arc::new(UniformKv),
            Arc::new(ServingMetrics::new()),
            3,
        );
        s.admit(Request::new(0, prompt, 5));
        let mut out = Vec::new();
        while out.is_empty() {
            out = s.step();
        }
        assert_eq!(out[0].tokens, direct.tokens);
    }

    #[test]
    fn interleaves_multiple_sequences() {
        let mut s = mk_sched(1000);
        s.admit(Request::new(0, vec![1, 2, 3], 3));
        s.admit(Request::new(1, vec![4, 5, 6, 7], 2));
        assert_eq!(s.active_count(), 2);
        let mut all = Vec::new();
        for _ in 0..5 {
            all.extend(s.step());
        }
        assert_eq!(all.len(), 2);
        assert_eq!(s.active_count(), 0);
        let r1 = all.iter().find(|r| r.id == 1).unwrap();
        assert_eq!(r1.tokens.len(), 2);
    }
}
