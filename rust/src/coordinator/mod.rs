//! The serving coordinator — Layer 3's system contribution.
//!
//! A vLLM-router-style stack in miniature, thread-based (tokio is not in
//! the offline image; `exec/` + std channels are the substrate):
//!
//! * [`request`] — request/response types and per-request metrics
//! * [`admission`] — bounded admission queue with backpressure
//! * [`batcher`] — dynamic batch formation (size/deadline policy)
//! * [`scheduler`] — continuous-batching engine loop: prefill on admit,
//!   per-iteration decode across active sequences, KV state in the
//!   block-paged [`crate::kvpool::KvPool`] (per-replica budget, prefix
//!   sharing, pressure ladder) via [`crate::kvcache::CacheManager`]
//! * [`server`] — the worker thread owning the model backend; clients
//!   submit over channels and receive a response handle
//! * [`metrics`] — latency histograms and throughput counters
//!
//! Invariants (property-tested in `rust/tests/coordinator_props.rs`):
//! every admitted request is answered exactly once; batch sizes never
//! exceed the configured maximum; per-sequence KV caches never exceed
//! budget + 1 entries between compressions; rejected requests are
//! reported as rejected, never dropped silently.
//!
//! One server is a single replica; [`crate::cluster`] shards load across
//! N of them behind pluggable routing policies.

#![warn(missing_docs)]

pub mod admission;
pub mod batcher;
pub mod metrics;
pub mod request;
pub mod scheduler;
pub mod server;

pub use admission::AdmissionQueue;
pub use batcher::{Batcher, BatcherConfig};
pub use metrics::ServingMetrics;
pub use request::{Request, RequestId, Response};
pub use scheduler::{Scheduler, SchedulerConfig};
pub use server::{Server, ServerClient, ServerConfig, ServerHandle};
