//! The serving front end: a worker thread that owns the model backend
//! (constructed *inside* the thread — the PJRT client is `!Send`) and
//! runs the continuous-batching loop; clients hold a [`ServerHandle`] and
//! submit requests over the admission queue, receiving responses on a
//! channel.

use super::admission::{AdmissionQueue, RejectReason};
use super::batcher::{Batcher, BatcherConfig};
use super::metrics::ServingMetrics;
use super::request::{Request, RequestId, Response};
use super::scheduler::{Scheduler, SchedulerConfig};
use crate::cluster::fault::FaultPlan;
use crate::kvcache::KvCompressor;
use crate::kvpool::{KvPool, KvPoolConfig, PoolSnapshot};
use crate::model::ModelBackend;
use crate::obs::quality::{QualityAudit, QualityConfig};
use crate::util::sync::lock_recover;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Everything one serving replica is configured by.
#[derive(Clone)]
pub struct ServerConfig {
    /// Admission queue capacity (beyond it, submissions are rejected).
    pub queue_capacity: usize,
    /// Maximum accepted prompt length in tokens.
    pub max_prompt: usize,
    /// Dynamic-batching policy knobs.
    pub batcher: BatcherConfig,
    /// Engine-loop knobs (cache budget, slack, prefill skipping).
    pub scheduler: SchedulerConfig,
    /// The replica's KV memory pool: global float budget, prefix
    /// sharing, pressure-ladder knobs (`--kv-budget-mb`,
    /// `--prefix-sharing` on the CLI). Default: unbounded, sharing on.
    pub pool: KvPoolConfig,
    /// Approximation-quality auditing: sample rate, error SLO, and
    /// sampler seed (`--audit-rate`, `--audit-slo-abs-err` on the CLI).
    /// Default: rate 0, auditing off.
    pub quality: QualityConfig,
    /// Base RNG seed (replica `i` of a pool runs `seed + i`).
    pub seed: u64,
    /// Replica index stamped onto every trace span this server's worker
    /// records (`pid` in Chrome trace exports). The cluster's
    /// `ReplicaPool` assigns it; stand-alone servers keep 0.
    pub replica: u32,
    /// Active fault-injection plan (`None` by default: the whole fault
    /// plane is then a single branch per site, same gate discipline as
    /// the tracer). Shared across replicas and respawns.
    pub faults: Option<Arc<FaultPlan>>,
    /// First request id this server hands out. The pool supervisor bumps
    /// it on respawn so a restarted replica never reuses ids from its
    /// previous incarnation (trace lanes and waiter keys stay unique).
    pub first_request_id: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            queue_capacity: 256,
            max_prompt: 1024,
            batcher: BatcherConfig::default(),
            scheduler: SchedulerConfig::default(),
            pool: KvPoolConfig::default(),
            quality: QualityConfig::default(),
            seed: 0,
            replica: 0,
            faults: None,
            first_request_id: 1,
        }
    }
}

type Waiters = Arc<Mutex<HashMap<RequestId, Sender<Response>>>>;

/// Cheap clone-able submit-side handle: everything a client (or the
/// cluster router) needs to drive one replica — submission, the
/// backpressure verdicts, and the load gauges the `join_shortest_queue`
/// routing policy balances on. Cloning shares the underlying server; the
/// owning [`ServerHandle`] keeps shutdown authority.
#[derive(Clone)]
pub struct ServerClient {
    queue: Arc<AdmissionQueue>,
    waiters: Waiters,
    metrics: Arc<ServingMetrics>,
    pool: Arc<KvPool>,
    next_id: Arc<AtomicU64>,
    replica: u32,
    faults: Option<Arc<FaultPlan>>,
}

impl ServerClient {
    /// Submit a generation request. Returns a receiver for the response,
    /// or the rejection reason (backpressure).
    pub fn submit(
        &self,
        tokens: Vec<u32>,
        max_new: usize,
    ) -> Result<(RequestId, Receiver<Response>), RejectReason> {
        if let Some(f) = &self.faults {
            if f.inject_admission_failure(self.replica as usize) {
                self.metrics.on_submit();
                self.metrics.on_reject();
                return Err(RejectReason::Injected);
            }
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        lock_recover(&self.waiters).insert(id, tx);
        self.metrics.on_submit();
        match self.queue.submit(Request::new(id, tokens, max_new)) {
            Ok(()) => Ok((id, rx)),
            Err(reason) => {
                lock_recover(&self.waiters).remove(&id);
                self.metrics.on_reject();
                Err(reason)
            }
        }
    }

    /// The replica's serving metrics (shared with its scheduler).
    pub fn metrics(&self) -> &ServingMetrics {
        &self.metrics
    }

    /// Shared handle to the replica's serving metrics (the supervised
    /// pool hands these out because its slots are behind a lock and a
    /// plain reference cannot escape the guard).
    pub fn metrics_arc(&self) -> Arc<ServingMetrics> {
        self.metrics.clone()
    }

    /// Fail every registered waiter by dropping its response sender —
    /// receivers observe `Disconnected` and the router fails the request
    /// over to a surviving replica. The pool supervisor calls this after
    /// detecting a dead worker. Returns how many in-flight requests were
    /// failed back.
    pub fn fail_pending(&self) -> usize {
        let mut g = lock_recover(&self.waiters);
        let n = g.len();
        g.clear();
        n
    }

    /// The replica's KV memory pool (shared with its scheduler).
    pub fn pool(&self) -> &Arc<KvPool> {
        &self.pool
    }

    /// Point-in-time KV pool gauges — what the cluster router aggregates.
    pub fn pool_snapshot(&self) -> PoolSnapshot {
        self.pool.snapshot()
    }

    /// Requests sitting in the admission queue (not yet prefilled).
    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Accepted-but-not-finished requests (queued + decoding) — the
    /// gauge `join_shortest_queue` routing balances on.
    pub fn in_flight(&self) -> u64 {
        self.metrics.in_flight()
    }
}

/// Owning handle: submit requests, read metrics, shut down.
pub struct ServerHandle {
    client: ServerClient,
    stopping: Arc<AtomicBool>,
    worker: Option<std::thread::JoinHandle<()>>,
}

/// The server: spawn with a backend factory (the factory runs on the
/// worker thread so `!Send` backends like PJRT work).
pub struct Server;

impl Server {
    /// Start a replica: spawn the worker thread, build the backend on it
    /// via `make_backend`, and return the owning handle.
    pub fn spawn<B, F>(cfg: ServerConfig, compressor: Arc<dyn KvCompressor>, make_backend: F) -> ServerHandle
    where
        B: ModelBackend,
        F: FnOnce() -> B + Send + 'static,
    {
        let queue = Arc::new(AdmissionQueue::new(cfg.queue_capacity, cfg.max_prompt));
        let waiters: Waiters = Arc::new(Mutex::new(HashMap::new()));
        let metrics = Arc::new(ServingMetrics::new());
        // the pool is created here (not on the worker) so clients and the
        // cluster router can read its gauges while the backend serves
        let mut pool_cfg = cfg.pool.clone();
        if let Some(sp) = pool_cfg.spill.as_mut() {
            // replicas run different weights (seed + i), so spilled KV
            // rows are only valid for the replica that wrote them: give
            // each replica its own subdirectory and span tag
            sp.replica = cfg.replica;
            sp.dir = sp.dir.join(format!("replica-{}", cfg.replica));
        }
        let pool = Arc::new(KvPool::new(pool_cfg, compressor));
        let stopping = Arc::new(AtomicBool::new(false));
        // one quality auditor per replica, shared by the scheduler
        // (decode-step audits, degraded budget), the pool (fold audits,
        // ladder gating), and the metrics sink (export); all three
        // attach points are no-ops when the audit rate is 0
        let audit = Arc::new(QualityAudit::new(cfg.quality.clone()));
        if audit.enabled() {
            metrics.attach_quality(audit.clone());
            pool.set_quality_audit(audit.clone());
        }
        let replica = cfg.replica;
        let faults = cfg.faults.clone();
        let first_request_id = cfg.first_request_id.max(1);

        let worker = {
            let queue = queue.clone();
            let waiters = waiters.clone();
            let metrics = metrics.clone();
            let pool = pool.clone();
            let stopping = stopping.clone();
            let audit = audit.clone();
            std::thread::spawn(move || {
                // close the admission queue however this thread exits: a
                // panicking backend factory must not leave a zombie queue
                // accepting requests that will never be served (clients —
                // and the cluster router — see ShuttingDown instead)
                struct CloseOnExit(Arc<AdmissionQueue>);
                impl Drop for CloseOnExit {
                    fn drop(&mut self) {
                        self.0.close();
                    }
                }
                let _close_guard = CloseOnExit(queue.clone());
                // tag every span this worker records with its replica
                crate::obs::trace::set_current_replica(cfg.replica);
                let backend = make_backend();
                let mut sched = Scheduler::with_pool(
                    backend,
                    cfg.scheduler.clone(),
                    metrics.clone(),
                    cfg.seed,
                    pool,
                );
                sched.set_quality_audit(audit);
                let batcher = Batcher::new(cfg.batcher);
                loop {
                    // Admission: poll the queue; block briefly only when idle.
                    let wait = if sched.active_count() == 0 {
                        Duration::from_millis(5)
                    } else {
                        Duration::ZERO
                    };
                    let admit_max =
                        batcher.admit_count(sched.active_count(), queue.len().max(1), Duration::MAX);
                    match queue.pop_batch(admit_max.max(1), wait) {
                        None => {
                            // closed + drained: finish active work then exit
                            if sched.active_count() == 0 {
                                break;
                            }
                        }
                        Some(batch) => {
                            for req in batch {
                                // a pool-rejected admission is answered
                                // immediately (zero tokens), never dropped
                                if let Some(rejected) = sched.admit(req) {
                                    let tx = lock_recover(&waiters).remove(&rejected.id);
                                    if let Some(tx) = tx {
                                        let _ = tx.send(rejected);
                                    }
                                }
                            }
                        }
                    }
                    if stopping.load(Ordering::Relaxed) && sched.active_count() == 0 {
                        break;
                    }
                    if sched.active_count() == 0 {
                        continue;
                    }
                    // fault-injection point: an armed plan may stall this
                    // step or panic the worker here (the panic is the
                    // injected crash; CloseOnExit + the pool supervisor
                    // turn it into ShuttingDown rejects and a respawn)
                    if let Some(f) = &cfg.faults {
                        f.before_step(cfg.replica as usize);
                    }
                    for resp in sched.step() {
                        let tx = lock_recover(&waiters).remove(&resp.id);
                        if let Some(tx) = tx {
                            let _ = tx.send(resp);
                        }
                    }
                }
            })
        };

        ServerHandle {
            client: ServerClient {
                queue,
                waiters,
                metrics,
                pool,
                next_id: Arc::new(AtomicU64::new(first_request_id)),
                replica,
                faults,
            },
            stopping,
            worker: Some(worker),
        }
    }
}

impl ServerHandle {
    /// Submit a generation request. Returns a receiver for the response,
    /// or the rejection reason (backpressure).
    pub fn submit(
        &self,
        tokens: Vec<u32>,
        max_new: usize,
    ) -> Result<(RequestId, Receiver<Response>), RejectReason> {
        self.client.submit(tokens, max_new)
    }

    /// A cheap clone-able submit-side handle sharing this server.
    pub fn client(&self) -> ServerClient {
        self.client.clone()
    }

    /// The server's serving metrics.
    pub fn metrics(&self) -> &ServingMetrics {
        self.client.metrics()
    }

    /// Requests sitting in the admission queue.
    pub fn queue_len(&self) -> usize {
        self.client.queue_depth()
    }

    /// True when the worker thread exited without being asked to stop —
    /// i.e. it panicked (a crashed backend or an injected fault). The
    /// admission queue is already closed by then (`CloseOnExit`), so new
    /// submits see `ShuttingDown`; the pool supervisor uses this to decide
    /// to fail in-flight work over and respawn the replica.
    pub fn worker_died(&self) -> bool {
        !self.stopping.load(Ordering::Relaxed)
            && self.worker.as_ref().map_or(true, |w| w.is_finished())
    }

    /// Graceful shutdown: stop admissions, finish in-flight work, join.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stopping.store(true, Ordering::Relaxed);
        self.client.queue.close();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::StreamingLlm;
    use crate::model::{ModelConfig, Transformer};
    use crate::rng::Rng;

    fn spawn_test_server(budget: usize) -> ServerHandle {
        let cfg = ServerConfig {
            scheduler: SchedulerConfig { cache_budget: budget, slack: 8, ..Default::default() },
            ..Default::default()
        };
        Server::spawn(cfg, Arc::new(StreamingLlm), move || {
            let mcfg = ModelConfig {
                vocab: 16,
                d_model: 16,
                n_layers: 2,
                n_heads: 2,
                d_ff: 32,
                max_len: 512,
            };
            Transformer::random(mcfg, &mut Rng::seed_from(42))
        })
    }

    #[test]
    fn serves_single_request() {
        let server = spawn_test_server(1000);
        let (id, rx) = server.submit(vec![1, 2, 3, 4], 3).unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(resp.id, id);
        assert_eq!(resp.tokens.len(), 3);
        assert!(resp.tokens.iter().all(|&t| t < 16));
        server.shutdown();
    }

    #[test]
    fn serves_concurrent_requests() {
        let server = spawn_test_server(1000);
        let mut rxs = Vec::new();
        for i in 0..12 {
            let prompt: Vec<u32> = (0..5 + i % 4).map(|j| (j % 16) as u32).collect();
            let (id, rx) = server.submit(prompt, 2 + i % 3).unwrap();
            rxs.push((id, rx, 2 + i % 3));
        }
        for (id, rx, want) in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert_eq!(resp.id, id);
            assert_eq!(resp.tokens.len(), want);
        }
        let c = server.metrics().counters();
        assert_eq!(c.completed, 12);
        assert_eq!(c.rejected, 0);
        server.shutdown();
    }

    #[test]
    fn panicking_backend_factory_closes_admissions() {
        let server = Server::spawn(
            ServerConfig::default(),
            Arc::new(StreamingLlm),
            || -> crate::model::Transformer { panic!("backend construction failed") },
        );
        // the worker dies at startup; the queue must close so clients see
        // backpressure (ShuttingDown) instead of hanging forever
        let mut closed = false;
        for _ in 0..1000 {
            match server.submit(vec![1, 2, 3], 1) {
                Err(RejectReason::ShuttingDown) => {
                    closed = true;
                    break;
                }
                _ => std::thread::sleep(Duration::from_millis(5)),
            }
        }
        assert!(closed, "queue never closed after worker panic");
        server.shutdown();
    }

    #[test]
    fn cloned_clients_share_server_and_gauges() {
        let server = spawn_test_server(1000);
        let c1 = server.client();
        let c2 = c1.clone();
        let (id1, rx1) = c1.submit(vec![1, 2, 3], 2).unwrap();
        let (id2, rx2) = c2.submit(vec![4, 5], 1).unwrap();
        assert_ne!(id1, id2, "clones must draw from one id space");
        assert_eq!(rx1.recv_timeout(Duration::from_secs(30)).unwrap().id, id1);
        assert_eq!(rx2.recv_timeout(Duration::from_secs(30)).unwrap().id, id2);
        // both clones observe the same shared metrics and drained gauges
        assert_eq!(c1.metrics().counters().completed, 2);
        assert_eq!(c2.in_flight(), 0);
        assert_eq!(c2.queue_depth(), 0);
        server.shutdown();
    }

    #[test]
    fn audited_server_exports_quality_metrics() {
        let cfg = ServerConfig {
            scheduler: SchedulerConfig { cache_budget: 1000, slack: 8, ..Default::default() },
            quality: QualityConfig { rate: 1, slo_abs_err: 0.0, seed: 5 },
            ..Default::default()
        };
        let server = Server::spawn(cfg, Arc::new(StreamingLlm), move || {
            let mcfg = ModelConfig {
                vocab: 16,
                d_model: 16,
                n_layers: 2,
                n_heads: 2,
                d_ff: 32,
                max_len: 512,
            };
            Transformer::random(mcfg, &mut Rng::seed_from(42))
        });
        let mut rxs = Vec::new();
        for i in 0..4u32 {
            let prompt: Vec<u32> = (0..8).map(|j| ((i + j) % 16)).collect();
            rxs.push(server.submit(prompt, 4).unwrap().1);
        }
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(30)).unwrap();
        }
        let snap = server.metrics().quality_snapshot().expect("audit attached at rate 1");
        assert!(snap.audited_decode > 0, "rate 1 must audit decode steps");
        // budget far above sequence length: nothing compressed, so the
        // served attention is exact and audits to identically zero
        assert_eq!(snap.err_max, 0.0);
        assert!(server.metrics().to_json().get("quality").is_some());
        server.shutdown();
    }

    #[test]
    fn rejects_overlong_prompt() {
        let server = spawn_test_server(1000);
        let err = server.submit(vec![0; 5000], 1).unwrap_err();
        assert!(matches!(err, RejectReason::PromptTooLong { .. }));
        assert_eq!(server.metrics().counters().rejected, 1);
        server.shutdown();
    }

    #[test]
    fn shutdown_completes_in_flight() {
        let server = spawn_test_server(1000);
        let (_, rx) = server.submit(vec![1, 2, 3], 2).unwrap();
        server.shutdown();
        // response arrived before or during shutdown
        let resp = rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(resp.tokens.len(), 2);
    }
}
