//! Admission control: a bounded FIFO with explicit backpressure. The
//! router rejects (rather than buffers unboundedly) when the queue is
//! full — the serving-system contract that keeps tail latencies bounded.

use super::request::Request;
use crate::util::sync::{lock_recover, wait_timeout_recover};
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// Rejection reason surfaced to clients.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// The admission queue is at capacity (backpressure).
    QueueFull,
    /// The prompt exceeds the server's configured maximum.
    PromptTooLong {
        /// The configured prompt-length limit.
        max: usize,
    },
    /// The server is draining and no longer accepts work.
    ShuttingDown,
    /// A transient failure injected by an active
    /// [`crate::cluster::FaultPlan`] (retryable).
    Injected,
}

impl RejectReason {
    /// Stable snake_case name for outcome-reason accounting.
    pub fn name(&self) -> &'static str {
        match self {
            RejectReason::QueueFull => "queue_full",
            RejectReason::PromptTooLong { .. } => "prompt_too_long",
            RejectReason::ShuttingDown => "shutting_down",
            RejectReason::Injected => "injected",
        }
    }
}

struct Inner {
    queue: VecDeque<Request>,
    closed: bool,
}

/// Bounded MPSC admission queue (mutex + condvar; the consumer is the
/// scheduler loop).
pub struct AdmissionQueue {
    capacity: usize,
    max_prompt: usize,
    inner: Mutex<Inner>,
    notify: Condvar,
}

impl AdmissionQueue {
    /// An empty queue bounded at `capacity` requests of up to
    /// `max_prompt` prompt tokens each.
    pub fn new(capacity: usize, max_prompt: usize) -> Self {
        AdmissionQueue {
            capacity,
            max_prompt,
            inner: Mutex::new(Inner { queue: VecDeque::new(), closed: false }),
            notify: Condvar::new(),
        }
    }

    /// Try to admit; `Err(reason)` applies backpressure to the caller.
    pub fn submit(&self, req: Request) -> Result<(), RejectReason> {
        if req.tokens.len() > self.max_prompt {
            return Err(RejectReason::PromptTooLong { max: self.max_prompt });
        }
        let mut g = lock_recover(&self.inner);
        if g.closed {
            return Err(RejectReason::ShuttingDown);
        }
        if g.queue.len() >= self.capacity {
            return Err(RejectReason::QueueFull);
        }
        g.queue.push_back(req);
        self.notify.notify_one();
        Ok(())
    }

    /// Pop up to `max` requests; blocks up to `timeout` when empty.
    /// Returns an empty vec on timeout, `None` once closed and drained.
    pub fn pop_batch(&self, max: usize, timeout: std::time::Duration) -> Option<Vec<Request>> {
        let mut g = lock_recover(&self.inner);
        if g.queue.is_empty() && !g.closed {
            g = wait_timeout_recover(&self.notify, g, timeout);
        }
        if g.queue.is_empty() {
            return if g.closed { None } else { Some(Vec::new()) };
        }
        let take = max.min(g.queue.len());
        Some(g.queue.drain(..take).collect())
    }

    /// Requests currently queued.
    pub fn len(&self) -> usize {
        lock_recover(&self.inner).queue.len()
    }

    /// Whether the queue is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Stop admissions; queued requests remain poppable until drained.
    pub fn close(&self) {
        lock_recover(&self.inner).closed = true;
        self.notify.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn req(id: u64) -> Request {
        Request::new(id, vec![1, 2, 3], 1)
    }

    #[test]
    fn fifo_order() {
        let q = AdmissionQueue::new(10, 100);
        for i in 0..5 {
            q.submit(req(i)).unwrap();
        }
        let batch = q.pop_batch(3, Duration::from_millis(1)).unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        let batch2 = q.pop_batch(10, Duration::from_millis(1)).unwrap();
        assert_eq!(batch2.iter().map(|r| r.id).collect::<Vec<_>>(), vec![3, 4]);
    }

    #[test]
    fn backpressure_on_full() {
        let q = AdmissionQueue::new(2, 100);
        q.submit(req(0)).unwrap();
        q.submit(req(1)).unwrap();
        assert_eq!(q.submit(req(2)), Err(RejectReason::QueueFull));
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn rejects_long_prompts() {
        let q = AdmissionQueue::new(10, 2);
        assert_eq!(
            q.submit(req(0)),
            Err(RejectReason::PromptTooLong { max: 2 })
        );
    }

    #[test]
    fn close_drains_then_none() {
        let q = AdmissionQueue::new(10, 100);
        q.submit(req(0)).unwrap();
        q.close();
        assert_eq!(q.submit(req(1)), Err(RejectReason::ShuttingDown));
        let batch = q.pop_batch(10, Duration::from_millis(1)).unwrap();
        assert_eq!(batch.len(), 1);
        assert!(q.pop_batch(10, Duration::from_millis(1)).is_none());
    }

    #[test]
    fn pop_timeout_empty() {
        let q = AdmissionQueue::new(10, 100);
        let batch = q.pop_batch(4, Duration::from_millis(5)).unwrap();
        assert!(batch.is_empty());
    }

    #[test]
    fn cross_thread_handoff() {
        let q = std::sync::Arc::new(AdmissionQueue::new(100, 100));
        let q2 = q.clone();
        let producer = std::thread::spawn(move || {
            for i in 0..50 {
                while q2.submit(req(i)).is_err() {
                    std::thread::yield_now();
                }
            }
            q2.close();
        });
        let mut seen = Vec::new();
        while let Some(batch) = q.pop_batch(8, Duration::from_millis(20)) {
            assert!(batch.len() <= 8);
            seen.extend(batch.iter().map(|r| r.id));
        }
        producer.join().unwrap();
        assert_eq!(seen, (0..50).collect::<Vec<_>>());
    }
}
