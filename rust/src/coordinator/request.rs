//! Request/response types for the serving coordinator.

use std::time::{Duration, Instant};

/// Per-replica request identifier (assigned at submission).
pub type RequestId = u64;

/// A generation request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Identifier responses are matched back to the caller by.
    pub id: RequestId,
    /// Prompt tokens.
    pub tokens: Vec<u32>,
    /// Number of tokens to generate.
    pub max_new: usize,
    /// Arrival timestamp (set by the admission queue).
    pub arrived: Instant,
}

impl Request {
    /// Build a request stamped with the current time as its arrival.
    pub fn new(id: RequestId, tokens: Vec<u32>, max_new: usize) -> Self {
        Request { id, tokens, max_new, arrived: Instant::now() }
    }
}

/// Per-request latency breakdown.
#[derive(Clone, Copy, Debug, Default)]
pub struct RequestTiming {
    /// Admission → prefill start.
    pub queue: Duration,
    /// Prefill (incl. cache compression).
    pub prefill: Duration,
    /// First decode step completion after prefill (TTFT − queue − prefill).
    pub decode: Duration,
}

impl RequestTiming {
    /// End-to-end latency: queue + prefill + decode.
    pub fn total(&self) -> Duration {
        self.queue + self.prefill + self.decode
    }
}

/// A completed generation.
#[derive(Clone, Debug)]
pub struct Response {
    /// The request this answers.
    pub id: RequestId,
    /// Generated tokens (empty for a pool-rejected admission).
    pub tokens: Vec<u32>,
    /// Latency breakdown measured by the scheduler.
    pub timing: RequestTiming,
    /// Physical KV entries held for this sequence after prefill
    /// compression (max over layer-heads).
    pub cache_entries: usize,
    /// Prompt length (logical tokens the cache summarises).
    pub context_len: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_total() {
        let t = RequestTiming {
            queue: Duration::from_millis(2),
            prefill: Duration::from_millis(30),
            decode: Duration::from_millis(10),
        };
        assert_eq!(t.total(), Duration::from_millis(42));
    }

    #[test]
    fn request_construction() {
        let r = Request::new(7, vec![1, 2, 3], 4);
        assert_eq!(r.id, 7);
        assert_eq!(r.tokens.len(), 3);
        assert_eq!(r.max_new, 4);
    }
}
