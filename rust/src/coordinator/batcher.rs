//! Dynamic batching policy for continuous batching.
//!
//! Two roles:
//! * [`Batcher::admit_count`] — iteration-level admission policy: how
//!   many queued requests to prefill this engine step, given the active
//!   set and how long the oldest request has waited (Orca-style
//!   continuous batching).
//! * [`Batcher::form_static_batches`] — offline/batch mode grouping used
//!   by the benches.

use super::request::Request;
use std::time::Duration;

/// Dynamic-batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Maximum concurrently active (decoding) sequences.
    pub max_active: usize,
    /// Maximum prefills per engine step (prefill is the expensive phase;
    /// bounding it caps decode-latency jitter for active sequences).
    pub max_admit_per_step: usize,
    /// If the oldest queued request has waited longer than this, admit
    /// even when the active set is "comfortably" full (up to max_active).
    pub max_wait: Duration,
    /// Soft target for the active set; below it we admit greedily, above
    /// it only when max_wait is exceeded.
    pub soft_active: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_active: 16,
            max_admit_per_step: 4,
            max_wait: Duration::from_millis(50),
            soft_active: 8,
        }
    }
}

/// The batching policy: pure decision logic, no queue ownership.
pub struct Batcher {
    /// The policy's tuning knobs.
    pub cfg: BatcherConfig,
}

impl Batcher {
    /// Validate and wrap a config.
    pub fn new(cfg: BatcherConfig) -> Self {
        assert!(cfg.max_active >= 1);
        assert!(cfg.soft_active <= cfg.max_active);
        Batcher { cfg }
    }

    /// How many new sequences to admit this step.
    pub fn admit_count(&self, active: usize, queued: usize, oldest_wait: Duration) -> usize {
        if queued == 0 || active >= self.cfg.max_active {
            return 0;
        }
        let headroom = self.cfg.max_active - active;
        let greedy_room = self.cfg.soft_active.saturating_sub(active);
        let room = if oldest_wait >= self.cfg.max_wait {
            headroom // deadline pressure: fill to the hard cap
        } else {
            greedy_room
        };
        room.min(self.cfg.max_admit_per_step).min(queued)
    }

    /// Group requests into fixed-size batches (offline mode).
    pub fn form_static_batches(&self, reqs: Vec<Request>, batch_size: usize) -> Vec<Vec<Request>> {
        assert!(batch_size >= 1);
        let mut out = Vec::new();
        let mut cur = Vec::with_capacity(batch_size);
        for r in reqs {
            cur.push(r);
            if cur.len() == batch_size {
                out.push(std::mem::take(&mut cur));
            }
        }
        if !cur.is_empty() {
            out.push(cur);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn b() -> Batcher {
        Batcher::new(BatcherConfig {
            max_active: 8,
            max_admit_per_step: 3,
            max_wait: Duration::from_millis(10),
            soft_active: 4,
        })
    }

    #[test]
    fn greedy_below_soft_cap() {
        let batcher = b();
        assert_eq!(batcher.admit_count(0, 10, Duration::ZERO), 3); // capped per step
        assert_eq!(batcher.admit_count(3, 10, Duration::ZERO), 1); // up to soft
        assert_eq!(batcher.admit_count(4, 10, Duration::ZERO), 0); // at soft cap
    }

    #[test]
    fn deadline_pressure_fills_to_hard_cap() {
        let batcher = b();
        let waited = Duration::from_millis(50);
        assert_eq!(batcher.admit_count(4, 10, waited), 3);
        assert_eq!(batcher.admit_count(7, 10, waited), 1);
        assert_eq!(batcher.admit_count(8, 10, waited), 0); // hard cap
    }

    #[test]
    fn bounded_by_queue() {
        let batcher = b();
        assert_eq!(batcher.admit_count(0, 2, Duration::ZERO), 2);
        assert_eq!(batcher.admit_count(0, 0, Duration::from_secs(1)), 0);
    }

    #[test]
    fn static_batches_cover_all() {
        let batcher = b();
        let reqs: Vec<Request> =
            (0..10).map(|i| Request::new(i, vec![1], 1)).collect();
        let batches = batcher.form_static_batches(reqs, 4);
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0].len(), 4);
        assert_eq!(batches[2].len(), 2);
        let ids: Vec<u64> = batches.iter().flatten().map(|r| r.id).collect();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
    }
}
